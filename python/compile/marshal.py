"""Padding helpers mirroring rust/src/runtime/batch.rs — used by tests to
drive the L2 model with the exact tensors the Rust runtime sends."""

import numpy as np

from . import model

PRICE_PAD = 1.0e9


def dealloc_order(delta, l):
    """Descending parallelism bound, ties by index — real tasks first,
    unused indices appended (matches MarshalledJob)."""
    real = sorted(range(l), key=lambda i: (-float(delta[i]), i))
    rest = [i for i in range(model.L_MAX) if i >= l]
    return np.asarray(real + rest, dtype=np.int32)


def pad_job(e, delta, z, prices, navail, window, dt, od_price=1.0):
    """Pad a raw job into the fixed AOT shapes. Returns a dict of arrays."""
    l = len(e)
    assert l <= model.L_MAX
    assert len(prices) <= model.S_MAX
    out = {
        "e": np.zeros(model.L_MAX, np.float32),
        "delta": np.ones(model.L_MAX, np.float32),
        "z": np.zeros(model.L_MAX, np.float32),
        "mask": np.zeros(model.L_MAX, np.float32),
        "order": dealloc_order(delta, l),
        "prices": np.full(model.S_MAX, PRICE_PAD, np.float32),
        "navail": np.zeros(model.S_MAX, np.float32),
        "window": np.float32(window),
        "dt": np.float32(dt),
        "od_price": np.float32(od_price),
    }
    out["e"][:l] = e
    out["delta"][:l] = delta
    out["z"][:l] = z
    out["mask"][:l] = 1.0
    p = np.asarray(prices, np.float64)
    p = np.where(np.isfinite(p), p, PRICE_PAD)
    out["prices"][: len(p)] = p.astype(np.float32)
    out["navail"][: len(navail)] = np.asarray(navail, np.float32)
    return out


def pad_grid(betas, beta0s, bids, has_pool):
    """Pad a policy grid to N_POL; bids deduplicate into
    (bid_values[NB_MAX], bid_idx[N_POL]). beta0 = 0 encodes 'no beta0'."""
    n = len(betas)
    assert n <= model.N_POL
    uniq = sorted(set(float(b) for b in bids))
    assert len(uniq) <= model.NB_MAX, f"too many distinct bids: {len(uniq)}"
    g = {
        "pol_beta": np.ones(model.N_POL, np.float32),
        "pol_beta0": np.zeros(model.N_POL, np.float32),
        "bid_values": np.zeros(model.NB_MAX, np.float32),  # pad 0: wins nothing
        "bid_idx": np.zeros(model.N_POL, np.int32),
        "pol_mask": np.zeros(model.N_POL, np.float32),
        "has_pool": np.float32(1.0 if has_pool else 0.0),
    }
    g["pol_beta"][:n] = betas
    g["pol_beta0"][:n] = beta0s
    g["bid_values"][: len(uniq)] = uniq
    g["bid_idx"][:n] = [uniq.index(float(b)) for b in bids]
    g["pol_mask"][:n] = 1.0
    return g


def run_model(job, grid):
    """Invoke the L2 model on padded inputs; returns numpy arrays truncated
    to the real policy count."""
    n = int(grid["pol_mask"].sum())
    cost, sw, ow, sow = model.policy_cost(
        job["e"],
        job["delta"],
        job["z"],
        job["mask"],
        job["order"],
        job["prices"],
        job["navail"],
        job["window"],
        job["dt"],
        grid["pol_beta"],
        grid["pol_beta0"],
        grid["bid_values"],
        grid["bid_idx"],
        grid["pol_mask"],
        job["od_price"],
        grid["has_pool"],
    )
    return (
        np.asarray(cost)[:n],
        np.asarray(sw)[:n],
        np.asarray(ow)[:n],
        np.asarray(sow)[:n],
    )
