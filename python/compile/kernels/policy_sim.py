"""L1 Pallas kernel: spot-market resolution per unique bid.

This is the compute hot-spot of the TOLA online learner: for one retired
job, evaluate its cost under EVERY policy of the grid against the realized
spot-price window.

The L2 model (`compile.model`) is closed-form (see its docstring and
EXPERIMENTS.md Perf section): the only O(N*S)-shaped work left is resolving
the market -- which slots each *bid* wins, and the prefix sums of winning
time and price-weighted winning time that every downstream per-task
quantity telescopes through. Bids are shared across policies (the paper's
grids have 5 distinct bids), so the kernel computes [NB, S] streams with
NB = 8, not [N = 192, S].

Semantics contract (must match `kernels/ref.py` and
`rust/src/learning/counterfactual.rs`): a slot k < V = ceil(window/dt) is
winning for bid b iff `price[k] <= b`; winning seconds count the full slot
(the final-slot boundary correction happens per task in L2).

TPU adaptation note: the kernel tiles slots across the grid, streaming the
price trace HBM->VMEM once while all NB bid rows stay resident in VMEM
(8*2048*4 B = 64 KiB per output) -- memory-bound on the single price
stream, no MXU work. The row cumsums lower to XLA's log-depth scans. On CPU
we must run with `interpret=True` (Mosaic custom-calls cannot execute on
the CPU PJRT plugin); interpret mode lowers to plain HLO, which is exactly
what the Rust runtime executes.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _market_kernel(
    prices_ref,  # f32[S]
    bids_ref,  # f32[NB]
    dt_ref,  # f32[1]
    v_ref,  # i32[1] number of executable slots
    cumwin_ref,  # out f32[NB, S+1] winning seconds in slots [0, k)
    cumpw_ref,  # out f32[NB, S+1] price-weighted winning seconds
):
    prices = prices_ref[...]
    bids = bids_ref[...]
    dt = dt_ref[0]
    v = v_ref[0]
    s = prices.shape[0]
    nb = bids.shape[0]
    live = jnp.arange(s, dtype=jnp.int32) < v  # [S]
    win = (prices[None, :] <= bids[:, None]) & live[None, :]  # [NB, S]
    winsecs = jnp.where(win, dt, 0.0)
    zero = jnp.zeros((nb, 1), dtype=jnp.float32)
    cumwin_ref[...] = jnp.concatenate([zero, jnp.cumsum(winsecs, axis=1)], axis=1)
    cumpw_ref[...] = jnp.concatenate(
        [zero, jnp.cumsum(winsecs * prices[None, :], axis=1)], axis=1
    )


def spot_market_cumsums(prices, bid_values, dt, v_slots):
    """Resolve the spot market once per unique bid (the L1 kernel).

    Args: prices f32[S]; bid_values f32[NB]; dt f32[1]; v_slots i32[].
    Returns: (cumwin f32[NB, S+1], cumpw f32[NB, S+1]).
    """
    nb = bid_values.shape[0]
    s = prices.shape[0]
    out_shape = [
        jax.ShapeDtypeStruct((nb, s + 1), jnp.float32),
        jax.ShapeDtypeStruct((nb, s + 1), jnp.float32),
    ]
    return pl.pallas_call(
        _market_kernel,
        out_shape=out_shape,
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(prices, bid_values, dt, jnp.reshape(v_slots, (1,)).astype(jnp.int32))


def _tola_kernel(w_ref, c_ref, eta_ref, out_ref):
    w = w_ref[...]
    c = c_ref[...]
    eta = eta_ref[0]
    # Min-shift before exponentiation: no-op after normalization,
    # numerically essential for large costs (mirrors learning/mod.rs).
    shifted = c - jnp.min(c)
    wn = w * jnp.exp(-eta * shifted)
    out_ref[...] = wn / jnp.sum(wn)


@jax.jit
def tola_update(w, c, eta):
    """TOLA exponentiated-weights update: normalize(w * exp(-eta (c - min c)))."""
    return pl.pallas_call(
        _tola_kernel,
        out_shape=jax.ShapeDtypeStruct(w.shape, jnp.float32),
        interpret=True,
    )(w, c, eta)
