"""Pure-numpy oracle for the counterfactual policy-grid cost model.

A direct, loopy transcription of the native Rust implementation
(`rust/src/learning/counterfactual.rs::eval_spec`, proposed-policy path).
The vectorized L2 model + L1 Pallas kernel must reproduce these numbers —
pytest (`python/tests/test_kernel.py`) asserts it across hypothesis sweeps,
and the Rust integration test `pjrt_cross.rs` closes the loop against the
compiled artifact.

Everything here is float64 numpy; the production paths are f32, so tests
compare with a relative tolerance.
"""

import numpy as np

EPS = 1e-6
# Turning-point threshold, scale-aware: fire at a slot start when
#   zt >= delta_eff * (deadline - slot_start) - FIRE_EPS * (1 + zt0),
# where zt0 is the task's initial spot/OD workload. The threshold uses the
# per-task CONSTANT zt0 (not the live zt) so the condition is affine in
# cumulative losing time -- the closed form in compile.model exploits that.
# Shared with the L2 model and rust/src/learning/counterfactual.rs so
# f32/f64 borderline slots classify identically.
FIRE_EPS = 1e-4
# Slot-ownership sample point: 63/128 of the slot (see compile.model).
OWNER_OFFSET = 0.4921875


def f_selfowned(z, delta, hat_s, x):
    """Eq. (11)."""
    if x >= 1.0:
        return 0.0
    return max((z - delta * hat_s * x) / (hat_s * (1.0 - x)), 0.0)


def dealloc_windows(e, order, window, beta):
    """Algorithm 1 on pre-sorted order; leftover to the last task of the
    order (matches rust `CounterfactualJob::windows`)."""
    e = np.asarray(e, dtype=np.float64)
    sizes = e.copy()
    omega = max(window - float(e.sum()), 0.0)
    for i in order:
        need = e[i] * (1.0 - beta) / beta
        grant = min(need, omega)
        sizes[i] += grant
        omega -= grant
    if omega > 0.0 and len(order) > 0:
        sizes[order[-1]] += omega
    return sizes


def eval_policy(
    e,
    delta,
    z,
    order,
    window,
    prices,
    dt,
    navail,
    od_price,
    beta,
    beta0,
    bid,
    has_pool,
):
    """Cost of one job under one policy `{beta, beta0, bid}`.

    beta0 <= 0 encodes "no beta0" (no self-owned machinery).
    Returns (cost, spot_work, od_work, so_work).
    """
    e = np.asarray(e, dtype=np.float64)
    delta = np.asarray(delta, dtype=np.float64)
    z = np.asarray(z, dtype=np.float64)
    prices = np.asarray(prices, dtype=np.float64)
    navail = np.asarray(navail, dtype=np.float64)
    l = len(e)

    beta_alloc = beta0 if (has_pool and 0.0 < beta0 <= beta) else beta
    sizes = dealloc_windows(e, order, window, beta_alloc)
    deadlines = np.cumsum(sizes)

    num_slots = min(int(np.ceil(window / dt)), len(prices))
    num_slots = max(num_slots, 1)

    # Self-owned grants + z-tilde initialization.
    r = np.zeros(l)
    ztilde = np.zeros(l)
    so_work = 0.0
    slot_cursor = 0
    for i in range(l):
        lo = 0.0 if i == 0 else deadlines[i - 1]
        hi = deadlines[i]
        nmin = np.inf
        if has_pool and beta0 > 0.0:
            while slot_cursor < num_slots:
                mid = (slot_cursor + OWNER_OFFSET) * dt
                if mid < lo:
                    slot_cursor += 1
                    continue
                if mid >= hi:
                    break
                nmin = min(nmin, navail[slot_cursor])
                slot_cursor += 1
            if not np.isfinite(nmin):
                nmin = 0.0
            hat_s = max(hi - lo, 1e-12)
            f = f_selfowned(z[i], delta[i], hat_s, beta0)
            # Fractional grant: §4.2.1 ignores rounding in the analysis.
            r[i] = max(min(f, nmin, delta[i]), 0.0)
        hat_s = max(hi - lo, 1e-12)
        covered = r[i] * hat_s
        ztilde[i] = max(z[i] - covered, 0.0)
        so_work += min(z[i], covered)

    # Slot walk.
    zt_init = ztilde.copy()
    spot_cost = 0.0
    spot_work = 0.0
    od_work = 0.0
    cur = 0
    for k in range(num_slots):
        t = k * dt
        mid = t + OWNER_OFFSET * dt
        while cur < l and mid >= deadlines[cur]:
            if ztilde[cur] > 0.0:
                od_work += ztilde[cur]
                ztilde[cur] = 0.0
            cur += 1
        if cur >= l:
            break
        i = cur
        if ztilde[i] <= 0.0:
            continue
        delta_eff = max(delta[i] - r[i], 0.0)
        if delta_eff <= 0.0:
            continue
        slot_end = t + dt
        deadline = deadlines[i]
        # Turning point (Def. 3.1, strict flexibility) checked BEFORE any
        # progress this slot, at the slot start.
        time_left = deadline - t
        if ztilde[i] >= delta_eff * time_left - FIRE_EPS * (1.0 + zt_init[i]):
            od_work += ztilde[i]
            ztilde[i] = 0.0
            continue
        price = prices[k]
        if price <= bid:
            room = delta_eff * max(min(slot_end, deadline) - t, 0.0)
            dw = min(room, ztilde[i])
            ztilde[i] -= dw
            spot_work += dw
            spot_cost += price * dw
    for i in range(cur, l):
        if ztilde[i] > 0.0:
            od_work += ztilde[i]
            ztilde[i] = 0.0

    cost = spot_cost + od_price * od_work
    return cost, spot_work, od_work, so_work


def eval_grid(
    e, delta, z, order, window, prices, dt, navail, od_price,
    betas, beta0s, bids, has_pool,
):
    """Sweep the policy grid; returns arrays of shape [n_policies]."""
    out = [
        eval_policy(
            e, delta, z, order, window, prices, dt, navail, od_price,
            float(b), float(b0), float(bd), has_pool,
        )
        for b, b0, bd in zip(betas, beta0s, bids)
    ]
    cost, sw, ow, sow = map(np.asarray, zip(*out))
    return cost, sw, ow, sow


def tola_update(w, c, eta):
    """Oracle for the TOLA weight update."""
    w = np.asarray(w, dtype=np.float64)
    c = np.asarray(c, dtype=np.float64)
    wn = w * np.exp(-eta * (c - c.min()))
    return wn / wn.sum()
