"""L2: the closed-form counterfactual policy-grid model (build-time JAX).

Evaluates one retired job's cost under every policy `{β, β₀, b}` of the
grid against the realized spot-price window — the TOLA hot path, AOT-lowered
to HLO and executed from Rust via PJRT.

## Closed form (see EXPERIMENTS.md §Perf for the derivation history)

The naive formulation walks the S slots sequentially; on XLA CPU both a
`fori_loop` walk (~36 ms) and an `[N, S]` segment-scatter formulation
(~276 ms) are dominated by loop/scatter overhead. The production model is
fully closed-form:

1. Spot availability depends on the *bid only*, and the §6.1 grids contain
   at most a handful of distinct bids — the L1 kernel
   (`kernels/policy_sim.spot_market_cumsums`) resolves the market once per
   unique bid: winning-time and price-volume prefix sums over the slots,
   shape `[NB, S+1]` with `NB = 8 ≪ N`.
2. Window geometry is uniform (slot k samples ownership at `(k+63/128)·dt`),
   so each task's slot range `[k0, k1)` is elementwise arithmetic, not a
   search.
3. Def. 3.1's turning point becomes a *suffix* condition on cumulative
   losing time (the affine identity `W(k) = (k−k0)·dt − lose(k)` turns
   `z̃₀ − δeff·W(k) ≥ δeff·(ς − k·dt) − tol` into `lose(k) ≥ D` with a
   per-task constant `D`), so the first firing slot is one `searchsorted`
   per task into the bid's losing-time prefix row.
4. Spot time used is `min(W(k_fire), W_end, z̃₀/δeff)`; its cost telescopes
   through the price-volume prefix sums with a single boundary-slot
   correction.

Everything after the kernel is `[N, L]` gathers and elementwise ops.

Fixed AOT shapes (DESIGN.md §6): L = 128 tasks, S = 2048 slots,
N = 192 policies, NB = 8 unique bids.
"""

import jax
import jax.numpy as jnp

from .kernels import policy_sim

# Fixed AOT shapes — keep in sync with
# rust/src/learning/counterfactual.rs::{L_MAX, S_MAX, N_POL, NB_MAX}.
L_MAX = 128
S_MAX = 2048
N_POL = 192
NB_MAX = 8

_BETA_FLOOR = 1e-3

# Slot-ownership sample point (63/128): exact window boundaries of the
# paper's rational grids (e.g. β = 1/1.3 on a 1/12 slot grid) land exactly
# on slot midpoints, where f32 and f64 round differently; 63/128 is exactly
# representable and collides with no small-denominator rational. Shared
# with kernels/ref.py and rust/src/learning/counterfactual.rs.
OWNER_OFFSET = 0.4921875

# Turning-point tolerance (shared: ref.py FIRE_EPS, counterfactual.rs).
FIRE_EPS = 1e-4


def policy_cost(
    e,  # f32[L] min execution times (pad 0)
    delta,  # f32[L] parallelism bounds (pad 1)
    z,  # f32[L] workloads (pad 0)
    mask,  # f32[L] 1 for real tasks
    order,  # i32[L] dealloc order, real tasks first (permutation of 0..L)
    prices,  # f32[S] resampled spot prices (pad large)
    navail,  # f32[S] per-slot self-owned availability
    window,  # f32[] job window length D
    dt,  # f32[] slot length
    pol_beta,  # f32[N]
    pol_beta0,  # f32[N] (0 = policy has no beta0)
    bid_values,  # f32[NB] distinct bid prices (padded with 0: wins nothing)
    bid_idx,  # i32[N] index of each policy's bid in bid_values
    pol_mask,  # f32[N] 1 for real policies
    od_price,  # f32[]
    has_pool,  # f32[] 1.0 when a self-owned pool exists
):
    """Per-policy (cost, spot_work, od_work, so_work), each f32[N]."""
    l_dim = e.shape[0]
    s_dim = prices.shape[0]

    # ---- Deadline allocation (Algorithm 1), vectorized over policies ----
    use_beta0 = (has_pool > 0.0) & (pol_beta0 > 0.0) & (pol_beta0 <= pol_beta)
    beta_alloc = jnp.clip(
        jnp.where(use_beta0, pol_beta0, pol_beta), _BETA_FLOOR, 1.0
    )  # [N]

    e_ord = e[order]  # [L]
    need = e_ord[None, :] * (1.0 - beta_alloc[:, None]) / beta_alloc[:, None]  # [N, L]
    omega = jnp.maximum(window - jnp.sum(e * mask), 0.0)
    cum_prev = jnp.cumsum(need, axis=1) - need
    grant_ord = jnp.clip(omega - cum_prev, 0.0, need)  # [N, L]
    leftover = omega - jnp.sum(grant_ord, axis=1)  # [N]
    l_real = jnp.sum(mask).astype(jnp.int32)
    last_pos = jnp.maximum(l_real - 1, 0)
    onehot_last = (jnp.arange(l_dim) == last_pos).astype(jnp.float32)  # [L]
    grant_ord = grant_ord + leftover[:, None] * onehot_last[None, :]
    grants = jnp.zeros_like(grant_ord).at[:, order].set(grant_ord)
    sizes = e[None, :] + grants  # [N, L]; pads have size 0
    deadlines = jnp.cumsum(sizes, axis=1)  # [N, L]
    lo = deadlines - sizes  # window starts

    # ---- Task slot ranges (uniform grid ⇒ pure arithmetic) ----
    # Slot k is owned by task i iff lo_i <= (k + OFF)·dt < ς_i, and only
    # the first V = ceil(window/dt) slots execute.
    v_slots = jnp.minimum(
        jnp.ceil(window / dt).astype(jnp.int32), jnp.int32(s_dim)
    )
    def first_slot_at(t):  # first k with (k+OFF)·dt >= t
        return jnp.clip(
            jnp.ceil(t / dt - OWNER_OFFSET).astype(jnp.int32), 0, v_slots
        )

    k0 = first_slot_at(lo)  # [N, L]
    k1 = first_slot_at(deadlines)  # [N, L] (exclusive)

    # ---- Self-owned grants (Eq. 11/12) via a sparse range-min table ----
    # navail is policy-independent; range-min over [k0, k1) uses a doubling
    # min-table (11 levels over S) — gathers only, no scatters.
    nmin = _range_min(navail, k0, k1)  # [N, L]; +inf for empty ranges
    nmin = jnp.where(jnp.isfinite(nmin), nmin, 0.0)
    hat_s = jnp.maximum(sizes, 1e-12)
    f = jnp.maximum(
        (z[None, :] - delta[None, :] * hat_s * pol_beta0[:, None])
        / (hat_s * (1.0 - jnp.minimum(pol_beta0[:, None], 1.0 - 1e-6))),
        0.0,
    )
    # Fractional grant (no floor): see ref.py / counterfactual.rs.
    r = jnp.minimum(jnp.minimum(f, nmin), delta[None, :])
    r = jnp.maximum(r, 0.0)
    r = jnp.where((has_pool > 0.0) & (pol_beta0[:, None] > 0.0), r, 0.0)
    r = r * mask[None, :]

    covered = r * hat_s
    zt0 = jnp.maximum(z[None, :] - covered, 0.0) * mask[None, :]  # [N, L]
    so_work = jnp.sum(jnp.minimum(z[None, :], covered) * mask[None, :], axis=1)
    delta_eff = jnp.maximum(delta[None, :] - r, 0.0)
    safe_de = jnp.maximum(delta_eff, 1e-12)

    # ---- L1 kernel: market resolution per unique bid ----
    # cumwin[b, k] = winning seconds in slots [0, k); cumpw likewise price-
    # weighted; both only over the V executable slots.
    cumwin, cumpw = policy_sim.spot_market_cumsums(
        prices, bid_values, jnp.reshape(dt, (1,)), v_slots
    )  # [NB, S+1] each

    # Per-policy rows (gather once: [N, S+1]).
    cumwin_n = cumwin[bid_idx]  # [N, S+1]
    cumpw_n = cumpw[bid_idx]
    win_n = (cumwin_n[:, 1:] - cumwin_n[:, :-1]) > 0.0  # [N, S] win flags

    def gat(tab, idx2):  # [N, S+1] gathered at [N, L] -> [N, L]
        return jnp.take_along_axis(tab, idx2, axis=1)

    w_at_k0 = gat(cumwin_n, k0)
    w_at_k1 = gat(cumwin_n, k1)
    w_full = w_at_k1 - w_at_k0  # full-slot winning time in the segment

    # Final-slot partial correction: the last slot may extend past ς_i.
    klast = jnp.maximum(k1 - 1, 0)
    win_last = jnp.take_along_axis(win_n, jnp.minimum(klast, s_dim - 1), axis=1)
    secs_last = jnp.clip(deadlines - klast.astype(jnp.float32) * dt, 0.0, dt)
    miss = jnp.where((k1 > k0) & win_last, dt - secs_last, 0.0)
    w_end = jnp.maximum(w_full - miss, 0.0)  # actually-available winning time

    # ---- Turning point (suffix condition on losing time) ----
    # lose(k) = (k − k0)·dt − W(k); fire at first k with lose(k) >= D,
    # D = (ς − k0·dt) − (z̃₀ + tol)/δeff, tol = FIRE_EPS·(1 + z̃₀).
    d_thresh = (deadlines - k0.astype(jnp.float32) * dt) - (
        zt0 + FIRE_EPS * (1.0 + zt0)
    ) / safe_de  # [N, L]
    cumlose_n = (
        jnp.arange(s_dim + 1, dtype=jnp.float32)[None, :] * dt - cumwin_n
    )  # [N, S+1], nondecreasing
    lose_at_k0 = gat(cumlose_n, k0)
    target = lose_at_k0 + d_thresh
    k_fire = jax.vmap(lambda row, t: jnp.searchsorted(row, t, side="left"))(
        cumlose_n, target
    ).astype(jnp.int32)
    k_fire = jnp.clip(k_fire, k0, k1)
    fires = k_fire < k1
    w_fire = jnp.where(fires, gat(cumwin_n, k_fire) - w_at_k0, jnp.inf)

    # ---- Spot time actually used & its telescoped cost ----
    spot_time = jnp.minimum(jnp.minimum(w_fire, w_end), zt0 / safe_de)
    spot_time = jnp.maximum(spot_time, 0.0)
    spot_time = jnp.where((delta_eff > 0.0) & (mask[None, :] > 0.0), spot_time, 0.0)

    # k_stop: first slot where cumulative winning time reaches spot_time.
    target_w = w_at_k0 + spot_time
    k_stop = jax.vmap(lambda row, t: jnp.searchsorted(row, t, side="left"))(
        cumwin_n, target_w
    ).astype(jnp.int32)
    k_stop = jnp.clip(k_stop, k0, k1)
    pw_span = gat(cumpw_n, k_stop) - gat(cumpw_n, k0)
    overshoot = jnp.maximum(gat(cumwin_n, k_stop) - target_w, 0.0)
    klast_stop = jnp.minimum(jnp.maximum(k_stop - 1, 0), s_dim - 1)
    price_last = jnp.take_along_axis(
        jnp.broadcast_to(prices[None, :], win_n.shape), klast_stop, axis=1
    )
    task_cost = delta_eff * jnp.maximum(pw_span - price_last * overshoot, 0.0)
    task_work = delta_eff * spot_time

    spot_work = jnp.sum(task_work * mask[None, :], axis=1)
    spot_cost = jnp.sum(task_cost * mask[None, :], axis=1)
    od_work = jnp.sum(
        jnp.maximum(zt0 - task_work, 0.0) * mask[None, :], axis=1
    )
    cost = spot_cost + od_price * od_work

    pm = pol_mask
    return (cost * pm, spot_work * pm, od_work * pm, so_work * pm)


def _range_min(values, k0, k1):
    """Range minimum of `values[k0:k1]` for `[N, L]` index pairs via a
    doubling sparse table (O(S log S) build, gathers only). Empty ranges
    give +inf."""
    s = values.shape[0]
    levels = max(s.bit_length() - 1, 0)
    tables = [values]
    span = 1
    for _ in range(levels):
        cur = tables[-1]
        shifted = jnp.concatenate(
            [cur[span:], jnp.full((span,), jnp.inf, values.dtype)]
        )
        tables.append(jnp.minimum(cur, shifted))
        span *= 2
    table = jnp.stack(tables)  # [levels+1, S]

    length = jnp.maximum(k1 - k0, 0)
    # floor(log2(length)) with length 0 -> empty.
    j = jnp.clip(
        jnp.log2(jnp.maximum(length.astype(jnp.float32), 1.0)).astype(jnp.int32),
        0,
        levels,
    )
    pow_j = jnp.left_shift(jnp.int32(1), j)
    a = jnp.clip(k0, 0, s - 1)
    b = jnp.clip(k1 - pow_j, 0, s - 1)
    left = table[j, a]
    right = table[j, b]
    out = jnp.minimum(left, right)
    return jnp.where(length > 0, out, jnp.inf)


def tola_update(w, c, eta):
    """The TOLA weight update (L1 kernel wrapper), fixed shape [N_POL]."""
    return (policy_sim.tola_update(w, c, jnp.reshape(eta, (1,))),)


def policy_cost_example_args():
    """ShapeDtypeStructs for AOT lowering."""
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((L_MAX,), f32),  # e
        jax.ShapeDtypeStruct((L_MAX,), f32),  # delta
        jax.ShapeDtypeStruct((L_MAX,), f32),  # z
        jax.ShapeDtypeStruct((L_MAX,), f32),  # mask
        jax.ShapeDtypeStruct((L_MAX,), jnp.int32),  # order
        jax.ShapeDtypeStruct((S_MAX,), f32),  # prices
        jax.ShapeDtypeStruct((S_MAX,), f32),  # navail
        jax.ShapeDtypeStruct((), f32),  # window
        jax.ShapeDtypeStruct((), f32),  # dt
        jax.ShapeDtypeStruct((N_POL,), f32),  # pol_beta
        jax.ShapeDtypeStruct((N_POL,), f32),  # pol_beta0
        jax.ShapeDtypeStruct((NB_MAX,), f32),  # bid_values
        jax.ShapeDtypeStruct((N_POL,), jnp.int32),  # bid_idx
        jax.ShapeDtypeStruct((N_POL,), f32),  # pol_mask
        jax.ShapeDtypeStruct((), f32),  # od_price
        jax.ShapeDtypeStruct((), f32),  # has_pool
    )


def tola_update_example_args():
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((N_POL,), f32),
        jax.ShapeDtypeStruct((N_POL,), f32),
        jax.ShapeDtypeStruct((), f32),
    )
