"""AOT lowering: JAX/Pallas model → HLO text artifacts for the Rust runtime.

Run via `make artifacts`:

    cd python && python -m compile.aot --out ../artifacts

Emits:
    artifacts/policy_cost.hlo.txt  — the counterfactual policy-grid sweep
    artifacts/tola_update.hlo.txt  — the TOLA weight update
    artifacts/MANIFEST.json        — shapes + git-free content hashes

HLO **text** is the interchange format, not serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version behind the published `xla` crate) rejects; the text
parser reassigns ids and round-trips cleanly. Lowered with
`return_tuple=True`, so the Rust side unwraps with `to_tuple4`/`to_tuple1`.
"""

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_policy_cost() -> str:
    lowered = jax.jit(model.policy_cost).lower(*model.policy_cost_example_args())
    return to_hlo_text(lowered)


def lower_tola_update() -> str:
    lowered = jax.jit(model.tola_update).lower(*model.tola_update_example_args())
    return to_hlo_text(lowered)


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts", help="output directory")
    args = parser.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {
        "shapes": {"L_MAX": model.L_MAX, "S_MAX": model.S_MAX, "N_POL": model.N_POL},
        "artifacts": {},
    }
    for name, fn in [
        ("policy_cost", lower_policy_cost),
        ("tola_update", lower_tola_update),
    ]:
        text = fn()
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        manifest["artifacts"][name] = {"bytes": len(text), "sha256_16": digest}
        print(f"wrote {path}: {len(text)} chars, sha256[:16]={digest}")

    with open(os.path.join(args.out, "MANIFEST.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(args.out, 'MANIFEST.json')}")


if __name__ == "__main__":
    main()
