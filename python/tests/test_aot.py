"""AOT lowering sanity: the artifacts must be emitted as parseable HLO text
with the agreed entry signature (shapes + dtypes), since the Rust runtime
feeds positional literals."""

import re

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def policy_cost_hlo():
    return aot.lower_policy_cost()


@pytest.fixture(scope="module")
def tola_hlo():
    return aot.lower_tola_update()


class TestPolicyCostArtifact:
    def test_is_hlo_text_with_entry(self, policy_cost_hlo):
        assert "HloModule" in policy_cost_hlo
        assert "ENTRY" in policy_cost_hlo

    def test_has_16_parameters_in_order(self, policy_cost_hlo):
        # The ENTRY computation must take the 16 inputs the Rust runtime
        # sends, in order (see runtime/exec.rs).
        entry = policy_cost_hlo[policy_cost_hlo.index("ENTRY"):]
        params = re.findall(r"parameter\((\d+)\)", entry)
        assert len(params) == 16, f"expected 16 params, got {len(params)}"
        shapes = re.findall(r"(\w+\[[\d,]*\])\{?[\d,]*\}? parameter\(\d+\)|(\w+\[\]) parameter\(\d+\)", entry)
        # Check the big-shape params exist.
        for want in [f"f32[{model.L_MAX}]", f"s32[{model.L_MAX}]",
                     f"f32[{model.S_MAX}]", f"f32[{model.N_POL}]",
                     f"f32[{model.NB_MAX}]", f"s32[{model.N_POL}]"]:
            assert want in entry, f"missing {want} in entry signature"

    def test_returns_4_tuple(self, policy_cost_hlo):
        entry = policy_cost_hlo[policy_cost_hlo.index("ENTRY"):]
        m = re.search(r"ROOT .*?\((.*?)\) tuple\(", entry)
        if m is None:
            # Alternative: root signature shows the tuple type.
            m = re.search(r"ROOT[^\n]*tuple[^\n]*", entry)
        assert m is not None, "no ROOT tuple found"
        root_line = m.group(0)
        assert root_line.count(f"f32[{model.N_POL}]") >= 4, root_line

    def test_closed_form_stays_compact(self, policy_cost_hlo):
        # The closed-form model must not unroll anything slot-shaped: the
        # artifact stays small (the original fori_loop version was ~48 KB;
        # a fully unrolled walk would be megabytes).
        assert len(policy_cost_hlo) < 5_000_000


class TestTolaArtifact:
    def test_signature(self, tola_hlo):
        assert "HloModule" in tola_hlo
        entry = tola_hlo[tola_hlo.index("ENTRY"):]
        params = re.findall(r"parameter\(\d+\)", entry)
        assert len(params) == 3
        assert f"f32[{model.N_POL}]" in entry

    def test_small(self, tola_hlo):
        assert len(tola_hlo) < 100_000
