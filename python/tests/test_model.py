"""L2 model component tests: window allocation, marshalling, shapes."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import marshal, model
from compile.kernels import ref


class TestDeallocWindows:
    def test_paper_example_windows(self):
        # §4.1.1: β=0.5 → window sizes (4/3, 1/2, 5/3, 1/2).
        e = np.array([0.75, 0.5, 2.5 / 3.0, 0.5])
        order = [2, 0, 1, 3]  # δ desc = (3, 2, 1, 1)
        sizes = ref.dealloc_windows(e, order, 4.0, 0.5)
        np.testing.assert_allclose(sizes, [4 / 3, 0.5, 5 / 3, 0.5], rtol=1e-12)

    @settings(max_examples=60, deadline=None)
    @given(seed=st.integers(0, 5000), l=st.integers(1, 20))
    def test_windows_tile_and_dominate_e(self, seed, l):
        rng = np.random.default_rng(seed)
        e = rng.uniform(0.1, 3.0, size=l)
        delta = rng.choice([1.0, 8.0, 64.0], size=l)
        window = float(e.sum() * rng.uniform(1.0, 3.0))
        order = [int(i) for i in marshal.dealloc_order(delta, l)[:l]]
        beta = rng.uniform(0.05, 1.0)
        sizes = ref.dealloc_windows(e, order, window, beta)
        assert sizes.sum() == pytest.approx(window, rel=1e-9)
        assert (sizes >= e - 1e-12).all()

    def test_vectorized_windows_match_ref_through_model(self):
        # Drive the full model with a no-spot trace: od_work == z exactly
        # when windows are correct (no spot, no pool); any window bug
        # changes the turning-point charges.
        rng = np.random.default_rng(5)
        l = 6
        e = rng.uniform(0.3, 2.0, size=l)
        delta = rng.choice([2.0, 8.0, 64.0], size=l)
        z = e * delta
        window = float(e.sum() * 1.8)
        prices = np.full(256, 5.0)  # never wins
        job = marshal.pad_job(e, delta, z, prices, np.zeros(256), window, window / 256)
        grid = marshal.pad_grid([0.5, 1.0, 1 / 2.2], [0.0] * 3, [0.3] * 3, False)
        cost, sw, ow, sow = marshal.run_model(job, grid)
        np.testing.assert_allclose(ow, float(z.sum()), rtol=1e-4)
        np.testing.assert_allclose(cost, float(z.sum()), rtol=1e-4)
        assert (sw == 0).all() and (sow == 0).all()


class TestMarshalling:
    def test_order_real_tasks_first(self):
        delta = [2.0, 64.0, 8.0]
        order = marshal.dealloc_order(delta, 3)
        assert list(order[:3]) == [1, 2, 0]
        assert len(order) == model.L_MAX

    def test_pad_job_shapes(self):
        job = marshal.pad_job([1.0], [2.0], [2.0], [0.2] * 10, [0.0] * 10, 3.0, 0.25)
        assert job["e"].shape == (model.L_MAX,)
        assert job["prices"].shape == (model.S_MAX,)
        assert job["prices"][10] == marshal.PRICE_PAD
        assert job["delta"][5] == 1.0  # pad δ

    def test_pad_grid_rejects_oversize(self):
        with pytest.raises(AssertionError):
            marshal.pad_grid([0.5] * (model.N_POL + 1), [0] * (model.N_POL + 1),
                             [0.2] * (model.N_POL + 1), False)


class TestSelfOwnedRule:
    def test_f_matches_eq11(self):
        # f(0) = z/ŝ; f(e/ŝ) = 0.
        assert ref.f_selfowned(6.0, 4.0, 2.0, 0.0) == 3.0
        assert ref.f_selfowned(6.0, 4.0, 2.0, 0.75) == 0.0

    @settings(max_examples=40, deadline=None)
    @given(seed=st.integers(0, 2000))
    def test_pool_never_grants_above_navail(self, seed):
        rng = np.random.default_rng(seed)
        l = int(rng.integers(1, 8))
        e = rng.uniform(0.3, 2.0, size=l)
        delta = rng.choice([2.0, 8.0], size=l)
        z = e * delta
        window = float(e.sum() * 1.5)
        n = min(int(np.ceil(window / (1 / 12))) + 1, model.S_MAX)
        navail = rng.integers(0, 6, size=n).astype(float)
        prices = np.full(n, 5.0)
        job = marshal.pad_job(e, delta, z, prices, navail, window, 1 / 12)
        grid = marshal.pad_grid([0.5], [0.25], [0.2], True)
        _, _, _, sow = marshal.run_model(job, grid)
        # so_work can't exceed max navail × window.
        assert sow[0] <= float(navail.max()) * window + 1e-3


class TestTolaUpdateShape:
    def test_uniform_stays_uniform_on_equal_costs(self):
        w = np.full(model.N_POL, 1.0 / model.N_POL, np.float32)
        c = np.full(model.N_POL, 3.0, np.float32)
        (out,) = model.tola_update(w, c, np.float32(0.1))
        np.testing.assert_allclose(np.asarray(out), w, rtol=1e-5)
