"""Kernel vs oracle: the CORE correctness signal of the L1/L2 stack.

The vectorized model (`compile.model.policy_cost`, which embeds the Pallas
slot-walk kernel) must reproduce the numpy oracle (`kernels/ref.py`) across
hypothesis-generated jobs, traces and policy grids.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import marshal, model
from compile.kernels import ref

RTOL = 2e-3  # f32 production path vs f64 oracle
ATOL = 2e-3

SLOT = 1.0 / 12.0


def make_job(rng, l, flex=2.0):
    delta = rng.choice([1.0, 2.0, 8.0, 64.0], size=l)
    e = rng.uniform(0.25, 3.0, size=l)
    z = e * delta
    window = float(e.sum() * rng.uniform(1.01, flex))
    return e, delta, z, window


def make_trace(rng, window, avail=0.5):
    n = min(int(np.ceil(window / SLOT)) + 1, model.S_MAX)
    cheap = rng.uniform(0.12, 0.3, size=n)
    dear = rng.uniform(0.4, 1.0, size=n)
    return np.where(rng.uniform(size=n) < avail, cheap, dear), SLOT


def assert_matches_oracle(e, delta, z, window, prices, dt, navail, grid_tuple, has_pool):
    betas, beta0s, bids = grid_tuple
    job = marshal.pad_job(e, delta, z, prices, navail, window, dt)
    grid = marshal.pad_grid(betas, beta0s, bids, has_pool)
    cost, sw, ow, sow = marshal.run_model(job, grid)
    order = [int(i) for i in job["order"][: len(e)]]
    rcost, rsw, row, rsow = ref.eval_grid(
        e, delta, z, order, window, job["prices"][: len(prices)], dt,
        navail, 1.0, betas, beta0s, bids, has_pool,
    )
    scale = max(float(np.sum(z)), 1.0)
    for name, got, want in [
        ("cost", cost, rcost),
        ("spot_work", sw, rsw),
        ("od_work", ow, row),
        ("so_work", sow, rsow),
    ]:
        np.testing.assert_allclose(
            got, want, rtol=RTOL, atol=ATOL * scale,
            err_msg=f"{name} mismatch (kernel vs oracle)",
        )


def paper_grid(has_pool):
    c1 = [2 / 12, 4 / 14, 6 / 16, 8 / 18, 0.5, 0.6, 0.7]
    c2 = [1.0, 1 / 1.3, 1 / 1.6, 1 / 1.9, 1 / 2.2]
    b = [0.18, 0.21, 0.24, 0.27, 0.3]
    if not has_pool:
        return (
            [x for x in c2 for _ in b],
            [0.0] * (len(c2) * len(b)),
            b * len(c2),
        )
    betas, beta0s, bids = [], [], []
    for b0 in c1:
        for beta in c2:
            for bid in b:
                betas.append(beta)
                beta0s.append(b0)
                bids.append(bid)
    return betas, beta0s, bids


class TestAgainstOracle:
    def test_paper_example_no_pool(self):
        # §4.1.1 chain, full paper spot-only grid.
        e = np.array([0.75, 0.5, 2.5 / 3.0, 0.5])
        delta = np.array([2.0, 1.0, 3.0, 1.0])
        z = e * delta
        rng = np.random.default_rng(1)
        prices, dt = make_trace(rng, 4.0)
        navail = np.zeros_like(prices)
        assert_matches_oracle(
            e, delta, z, 4.0, prices, dt, navail, paper_grid(False), False
        )

    def test_paper_example_with_pool(self):
        e = np.array([0.75, 0.5, 2.5 / 3.0, 0.5])
        delta = np.array([2.0, 1.0, 3.0, 1.0])
        z = e * delta
        rng = np.random.default_rng(2)
        prices, dt = make_trace(rng, 4.0)
        navail = np.full_like(prices, 5.0)
        assert_matches_oracle(
            e, delta, z, 4.0, prices, dt, navail, paper_grid(True), True
        )

    @settings(max_examples=25, deadline=None)
    @given(
        seed=st.integers(0, 10_000),
        l=st.integers(1, 12),
        avail=st.floats(0.0, 1.0),
        has_pool=st.booleans(),
    )
    def test_random_jobs_hypothesis(self, seed, l, avail, has_pool):
        rng = np.random.default_rng(seed)
        e, delta, z, window = make_job(rng, l)
        prices, dt = make_trace(rng, window, avail)
        navail = (
            rng.integers(0, 20, size=len(prices)).astype(np.float64)
            if has_pool
            else np.zeros(len(prices))
        )
        # Small random policy grid. Bids draw from a palette of <= 6
        # distinct values: the AOT interface dedupes bids (NB_MAX = 8).
        n = int(rng.integers(1, 12))
        betas = rng.uniform(0.3, 1.0, size=n).tolist()
        beta0s = (
            rng.uniform(0.1, 0.8, size=n).tolist() if has_pool else [0.0] * n
        )
        palette = rng.uniform(0.12, 0.35, size=int(rng.integers(1, 7)))
        bids = rng.choice(palette, size=n).tolist()
        assert_matches_oracle(
            e, delta, z, window, prices, dt, navail,
            (betas, beta0s, bids), has_pool,
        )

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 1000))
    def test_long_chains_resampled(self, seed):
        # Chains near L_MAX with windows forcing resampled (coarse) slots.
        rng = np.random.default_rng(seed)
        l = int(rng.integers(60, 97))
        e, delta, z, window = make_job(rng, l, flex=3.0)
        n_slots = int(rng.integers(200, model.S_MAX))
        dt = window / n_slots
        prices = rng.uniform(0.12, 1.0, size=n_slots)
        navail = np.zeros(n_slots)
        betas = [1.0, 1 / 1.6, 1 / 2.2]
        assert_matches_oracle(
            e, delta, z, window, prices, dt, navail,
            (betas, [0.0] * 3, [0.24] * 3), False,
        )


class TestKernelProperties:
    def test_all_available_cheap_spot_no_od(self):
        e = np.array([1.0, 0.5])
        delta = np.array([2.0, 4.0])
        z = e * delta
        window = 4.0
        n = int(np.ceil(window / SLOT)) + 1
        prices = np.full(n, 0.2)
        job = marshal.pad_job(e, delta, z, prices, np.zeros(n), window, SLOT)
        grid = marshal.pad_grid([0.5], [0.0], [0.3], False)
        cost, sw, ow, sow = marshal.run_model(job, grid)
        assert ow[0] == pytest.approx(0.0, abs=1e-4)
        assert sw[0] == pytest.approx(float(z.sum()), rel=1e-4)
        assert cost[0] == pytest.approx(0.2 * float(z.sum()), rel=1e-3)

    def test_never_available_all_od(self):
        e = np.array([1.0])
        delta = np.array([2.0])
        z = e * delta
        window = 3.0
        prices = np.full(40, 2.0)  # above any bid
        job = marshal.pad_job(e, delta, z, prices, np.zeros(40), window, SLOT)
        grid = marshal.pad_grid([0.5], [0.0], [0.3], False)
        cost, sw, ow, _ = marshal.run_model(job, grid)
        assert sw[0] == pytest.approx(0.0, abs=1e-5)
        assert ow[0] == pytest.approx(2.0, rel=1e-4)
        assert cost[0] == pytest.approx(2.0, rel=1e-4)

    def test_work_conservation(self):
        rng = np.random.default_rng(7)
        e, delta, z, window = make_job(rng, 8)
        prices, dt = make_trace(rng, window)
        navail = np.full(len(prices), 10.0)
        job = marshal.pad_job(e, delta, z, prices, navail, window, dt)
        grid = marshal.pad_grid(*paper_grid(True), True)
        cost, sw, ow, sow = marshal.run_model(job, grid)
        total = sw + ow + sow
        np.testing.assert_allclose(total, float(z.sum()), rtol=1e-3)
        assert (cost >= -1e-4).all()
        assert (cost <= float(z.sum()) * 1.001).all()

    def test_padded_policies_masked_to_zero(self):
        rng = np.random.default_rng(9)
        e, delta, z, window = make_job(rng, 3)
        prices, dt = make_trace(rng, window)
        job = marshal.pad_job(e, delta, z, prices, np.zeros(len(prices)), window, dt)
        grid = marshal.pad_grid([0.5], [0.0], [0.24], False)
        raw = model.policy_cost(
            job["e"], job["delta"], job["z"], job["mask"], job["order"],
            job["prices"], job["navail"], job["window"], job["dt"],
            grid["pol_beta"], grid["pol_beta0"], grid["bid_values"],
            grid["bid_idx"], grid["pol_mask"], job["od_price"], grid["has_pool"],
        )
        cost = np.asarray(raw[0])
        assert (cost[1:] == 0.0).all()


class TestTolaUpdateKernel:
    def test_matches_oracle(self):
        rng = np.random.default_rng(3)
        w = rng.uniform(0.1, 1.0, size=model.N_POL).astype(np.float32)
        w /= w.sum()
        c = rng.uniform(0.0, 50.0, size=model.N_POL).astype(np.float32)
        eta = np.float32(0.03)
        (got,) = model.tola_update(w, c, eta)
        want = ref.tola_update(w, c, float(eta))
        np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-6)
        assert np.asarray(got).sum() == pytest.approx(1.0, abs=1e-5)

    def test_large_costs_stable(self):
        w = np.full(model.N_POL, 1.0 / model.N_POL, np.float32)
        c = np.full(model.N_POL, 1e6, np.float32)
        c[5] = 1e6 - 1.0
        (got,) = model.tola_update(w, c, np.float32(1.0))
        got = np.asarray(got)
        assert np.isfinite(got).all()
        assert got[5] == got.max()
