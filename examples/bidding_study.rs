//! Bidding study: how the bid price and the spot-market regime shape cost.
//!
//! An ablation the paper motivates but does not plot: sweep the bid grid B
//! under three market models (the §6.1 bounded-exponential market, a
//! Markov calm/surge market, and a Google-style fixed-price market) and
//! report the average unit cost and realized spot availability for each —
//! showing why the bid must be *learned* (Table 6) rather than fixed.
//!
//! Run: `cargo run --release --example bidding_study -- [jobs]`

use dagcloud::market::{PriceTrace, SpotModel};
use dagcloud::policy::Policy;
use dagcloud::sim::horizon::{HorizonRunner, StrategySpec};
use dagcloud::workload::{transform, ChainJob, GeneratorConfig, JobStream};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let n_jobs: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(800);
    let seed = 77;

    let mut stream = JobStream::new(GeneratorConfig::for_job_type(2), seed);
    let jobs: Vec<ChainJob> = stream.take_jobs(n_jobs).iter().map(transform).collect();
    let horizon = jobs.iter().map(|j| j.deadline).fold(0.0, f64::max) + 1.0;

    let markets: Vec<(&str, SpotModel)> = vec![
        ("bounded-exp (§6.1)", SpotModel::paper_default()),
        (
            "markov calm/surge",
            SpotModel::Markov {
                calm_mean: 0.13,
                surge_mean: 0.7,
                lo: 0.12,
                hi: 1.0,
                p_calm_to_surge: 0.02,
                p_surge_to_calm: 0.1,
            },
        ),
        (
            "google fixed",
            SpotModel::GoogleFixed {
                price: 0.25,
                availability: 0.7,
            },
        ),
    ];
    // Extended bid sweep (paper grid B plus the tails).
    let bids = [0.12, 0.15, 0.18, 0.21, 0.24, 0.27, 0.3, 0.4, 0.6, 1.0];

    println!("=== bidding study: {} jobs per cell ===", n_jobs);
    for (name, model) in &markets {
        let trace = PriceTrace::generate(model.clone(), horizon, seed + 9);
        let runner = HorizonRunner::new(&trace, 0);
        println!("\nmarket: {name}");
        println!("  {:>6} {:>10} {:>12} {:>12}", "bid", "unit cost", "spot share", "avail");
        let mut best = (f64::INFINITY, 0.0);
        for &bid in &bids {
            let rep = runner.run(
                &jobs,
                StrategySpec::Proposed(Policy::new(1.0 / 1.6, None, bid)),
            );
            let alpha = rep.average_unit_cost();
            let spot_share = rep.ledger.work_spot / rep.ledger.total_work();
            let avail = trace.availability(0.0, horizon - 1.0, bid);
            println!(
                "  {:>6.2} {:>10.4} {:>11.1}% {:>11.1}%",
                bid,
                alpha,
                100.0 * spot_share,
                100.0 * avail
            );
            if alpha < best.0 {
                best = (alpha, bid);
            }
        }
        println!("  -> best bid {:.2} at unit cost {:.4}", best.1, best.0);
    }
    println!("\nbidding_study OK");
}
