//! Regenerate the data series behind the paper's Figures 1–4.
//!
//! Writes CSVs to `results/` (override with the first argument) and prints
//! the headline checks: the naive schedule of Fig. 3 processes 2 units of
//! workload on spot; the optimal schedule of Fig. 4 processes 22/6.
//!
//! Run: `cargo run --release --example figures -- [out_dir]`

fn main() -> anyhow::Result<()> {
    let out = std::env::args().nth(1).unwrap_or_else(|| "results".to_string());
    std::fs::create_dir_all(&out)?;
    dagcloud::experiments::figures::run_all(&out)?;

    // Echo the schedules in ASCII for a quick visual check.
    for (name, segs) in [
        ("figure3 (naive deadlines)", dagcloud::experiments::figures::figure3()),
        ("figure4 (Dealloc optimal)", dagcloud::experiments::figures::figure4()),
    ] {
        println!("\n{name}:");
        for s in &segs {
            println!(
                "  task {} {:>9}: [{:>6.3}, {:>6.3}] × {} instances ({:.3} instance-time)",
                s.task + 1,
                s.kind,
                s.t0,
                s.t1,
                s.instances,
                s.work()
            );
        }
        let spot = dagcloud::experiments::figures::spot_workload(&segs, 0.5);
        println!("  expected spot workload @ β=0.5: {spot:.4}");
    }
    Ok(())
}
