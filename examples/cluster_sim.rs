//! End-to-end driver: the full system on a realistic workload.
//!
//! Generates a §6.1 tenant workload (Poisson arrivals, random DAGs,
//! bounded-Pareto tasks), transforms every DAG to a chain, runs the TOLA
//! online learner over the full 175-policy grid with a shared self-owned
//! pool against a realized spot market — using the AOT-compiled PJRT
//! kernel for the counterfactual sweeps when `artifacts/` exists — and
//! reports cost, learning convergence, regret vs the Prop. B.1 bound, and
//! throughput. This is the run recorded in EXPERIMENTS.md §End-to-end.
//!
//! Run: `cargo run --release --example cluster_sim -- [jobs] [pool]`

use dagcloud::coordinator::{tola_run, Evaluator};
use dagcloud::learning::counterfactual::CfSpec;
use dagcloud::market::PriceTrace;
use dagcloud::policy::{policy_set_full, policy_set_spot_only};
use dagcloud::runtime::ArtifactRuntime;
use dagcloud::workload::{transform, ChainJob, GeneratorConfig, JobStream};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let n_jobs: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(2000);
    let pool: u32 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(600);
    let seed = 2021;

    println!("=== cluster_sim: end-to-end TOLA learning run ===");
    println!("jobs {n_jobs}, self-owned pool {pool}, seed {seed}\n");

    // Workload: job type 2 (x0 = 2), the paper's Table-6 setting.
    let t0 = std::time::Instant::now();
    let mut stream = JobStream::new(GeneratorConfig::for_job_type(2), seed);
    let dags = stream.take_jobs(n_jobs);
    let jobs: Vec<ChainJob> = dags.iter().map(transform).collect();
    let horizon = jobs.iter().map(|j| j.deadline).fold(0.0, f64::max) + 1.0;
    let tasks: usize = dags.iter().map(|d| d.num_tasks()).sum();
    println!(
        "generated {} DAG jobs ({} tasks, horizon {:.0} time units) in {:.2}s",
        n_jobs,
        tasks,
        horizon,
        t0.elapsed().as_secs_f64()
    );

    // Market.
    let trace = PriceTrace::generate(
        dagcloud::market::SpotModel::paper_default(),
        horizon,
        seed + 1,
    );
    println!("spot market: {} slots of {:.4} time units", trace.num_slots(), trace.slot_len());

    // Policy grid.
    let specs: Vec<CfSpec> = if pool == 0 {
        policy_set_spot_only().into_iter().map(CfSpec::Proposed).collect()
    } else {
        policy_set_full().into_iter().map(CfSpec::Proposed).collect()
    };
    println!("policy grid: {} policies", specs.len());

    // Evaluator: PJRT kernel if artifacts exist.
    let rt = ArtifactRuntime::load_default();
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let evaluator = match &rt {
        Ok(rt) => {
            println!("counterfactual evaluator: PJRT kernel (artifacts/)");
            Evaluator::Pjrt(rt)
        }
        Err(e) => {
            println!("counterfactual evaluator: native ({threads} threads) — {e}");
            Evaluator::Native { threads }
        }
    };

    // Learn.
    let t1 = std::time::Instant::now();
    let rep = tola_run(&jobs, &specs, &trace, pool, 1.0, seed + 2, &evaluator);
    let dt = t1.elapsed().as_secs_f64();

    println!("\n--- results ---");
    println!(
        "processed {} jobs in {:.2}s ({:.0} jobs/s, {:.0} policy-evals/s)",
        rep.jobs,
        dt,
        rep.jobs as f64 / dt,
        (rep.jobs * specs.len()) as f64 / dt
    );
    println!("realized average unit cost ᾱ = {:.4} (all-on-demand would be 1.0)", rep.average_unit_cost);
    if let CfSpec::Proposed(p) = specs[rep.best_policy] {
        println!(
            "learned best policy: β = {:.3}, β₀ = {}, b = {:.2} (weight {:.3})",
            p.beta,
            p.beta0.map(|x| format!("{x:.3}")).unwrap_or("-".into()),
            p.bid,
            rep.final_weights[rep.best_policy]
        );
    }
    println!(
        "average regret {:.4} ≤ Prop. B.1 bound {:.4}: {}",
        rep.average_regret,
        rep.regret_bound,
        rep.average_regret <= rep.regret_bound
    );
    println!("self-owned pool utilization: {:.1}%", 100.0 * rep.pool_utilization);
    println!(
        "cost breakdown: self-owned {:.0} work (free), spot {:.0} work / {:.1} cost, on-demand {:.0} work / {:.1} cost",
        rep.ledger.work_selfowned,
        rep.ledger.work_spot,
        rep.ledger.cost_spot,
        rep.ledger.work_ondemand,
        rep.ledger.cost_ondemand
    );
    println!(
        "weight convergence (max weight over time): start {:.4} → end {:.4}",
        rep.weight_trajectory.first().copied().unwrap_or(f64::NAN),
        rep.weight_trajectory.last().copied().unwrap_or(f64::NAN)
    );
    assert!(rep.average_regret <= rep.regret_bound, "regret bound violated");
    println!("\ncluster_sim OK");
}
