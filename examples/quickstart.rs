//! Quickstart: walk one DAG job through the whole framework.
//!
//! Reproduces the paper's worked example (§4.1.1): a chain of four tasks in
//! the window [0, 4] with β = 0.5, showing the optimal deadline allocation
//! (Algorithm 1), the expected instance allocation per task (Prop. 4.1),
//! and a realized execution against a synthetic spot-price trace.
//!
//! Run: `cargo run --release --example quickstart`

use dagcloud::market::{PriceTrace, SpotModel};
use dagcloud::policy::dealloc::{dealloc, expected_spot_workload, windows_to_deadlines};
use dagcloud::policy::single_task::expected_turning_point;
use dagcloud::sim::executor::{execute_chain, ChainStrategy, SelfOwnedRule};
use dagcloud::workload::{transform, ChainJob, DagJob, Task};

fn main() {
    println!("=== dagcloud quickstart: the §4.1.1 worked example ===\n");

    // 1. A DAG job. Here: the paper's 4-task chain (a chain is a DAG; for
    //    general DAGs `transform` reduces to a chain first — shown below).
    let job = ChainJob::paper_example();
    println!(
        "job: {} tasks, window [{}, {}], total work {}",
        job.num_tasks(),
        job.arrival,
        job.deadline,
        job.total_work()
    );
    for (i, t) in job.tasks.iter().enumerate() {
        println!(
            "  task {}: z = {:.2}, δ = {}, e = z/δ = {:.3}",
            i + 1,
            t.size,
            t.parallelism,
            t.min_exec_time()
        );
    }

    // 2. Optimal deadline allocation (Algorithm 1) at β = 0.5.
    let beta = 0.5;
    let alloc = dealloc(&job, beta);
    let deadlines = windows_to_deadlines(&job, &alloc);
    println!("\nDealloc(β = {beta}) window sizes: {:?}", alloc.sizes);
    println!("task deadlines ς_i: {deadlines:?}");
    let zo = expected_spot_workload(&job, &alloc);
    println!(
        "expected spot workload: {:.4} (paper: 22/6 = {:.4})",
        zo,
        22.0 / 6.0
    );
    assert!((zo - 22.0 / 6.0).abs() < 1e-9);

    // 3. Expected per-task instance allocation (Prop. 4.1).
    println!("\nexpected allocation per task:");
    let mut start = job.arrival;
    for (i, (t, d)) in job.tasks.iter().zip(&deadlines).enumerate() {
        let hat_s = d - start;
        match expected_turning_point(t.size, t.parallelism, hat_s, beta) {
            None => println!(
                "  task {}: all-spot in [{:.3}, {:.3}] (window ≥ e/β)",
                i + 1,
                start,
                start + t.min_exec_time() / beta
            ),
            Some(tau) if tau > 1e-12 => println!(
                "  task {}: {} spot in [{:.3}, {:.3}], then {} on-demand to {:.3}",
                i + 1,
                t.parallelism,
                start,
                start + tau,
                t.parallelism,
                d
            ),
            Some(_) => println!(
                "  task {}: no flexibility — {} on-demand in [{:.3}, {:.3}]",
                i + 1,
                t.parallelism,
                start,
                d
            ),
        }
        start = *d;
    }

    // 4. Realized execution against a synthetic spot market.
    let trace = PriceTrace::generate(SpotModel::paper_default(), 6.0, 42);
    let outcome = execute_chain(
        &job,
        &ChainStrategy::Windows {
            windows: &alloc,
            selfowned: SelfOwnedRule::None,
            bid: 0.24,
        },
        &trace,
        None,
        1.0,
    );
    println!("\nrealized execution (bid 0.24, §6.1 price process, seed 42):");
    println!(
        "  spot work {:.3} (cost {:.3}), on-demand work {:.3} (cost {:.3})",
        outcome.ledger.work_spot,
        outcome.ledger.cost_spot,
        outcome.ledger.work_ondemand,
        outcome.ledger.cost_ondemand
    );
    println!(
        "  total cost {:.3} vs all-on-demand cost {:.3}; deadline met: {}",
        outcome.cost(),
        job.total_work(),
        outcome.met_deadline
    );
    assert!(outcome.met_deadline);

    // 5. General DAGs: transform → chain, then everything above applies.
    let dag = DagJob::new(
        2,
        0.0,
        10.0,
        vec![
            Task::new(2.0, 2.0),
            Task::new(4.0, 2.0),
            Task::new(2.0, 2.0),
            Task::new(2.0, 2.0),
        ],
        vec![(0, 1), (0, 2), (1, 3), (2, 3)],
    );
    let chain = transform(&dag);
    println!(
        "\nDAG→chain (Nagarajan et al.): diamond DAG of {} tasks → chain of {} pseudo-tasks",
        dag.num_tasks(),
        chain.num_tasks()
    );
    println!(
        "  critical path {:.3} = chain makespan {:.3}; work {:.1} preserved",
        dag.critical_path(),
        chain.min_makespan(),
        chain.total_work()
    );
    println!("\nquickstart OK");
}
