//! Hot-path micro/meso benches: the per-component costs that determine
//! end-to-end throughput, plus the PJRT-vs-native counterfactual sweep
//! comparison used in EXPERIMENTS.md §Perf.

use dagcloud::learning::counterfactual::{eval_grid_naive, eval_grid_native, CounterfactualJob, S_MAX};
use dagcloud::learning::sweep;
use dagcloud::market::{PriceTrace, SelfOwnedPool, SpotModel, SLOTS_PER_UNIT};
use dagcloud::policy::dealloc::dealloc;
use dagcloud::policy::{policy_set_full, Policy};
use dagcloud::runtime::ArtifactRuntime;
use dagcloud::sim::executor::{execute_chain, ChainStrategy, SelfOwnedRule};
use dagcloud::util::bench::Bencher;
use dagcloud::util::rng::Pcg32;
use dagcloud::workload::{transform, ChainJob, GeneratorConfig, JobStream};

fn main() {
    let mut b = Bencher::new();
    println!("== bench_hotpath ==\n");

    // Workload pieces.
    let mut stream = JobStream::new(GeneratorConfig::paper_default(), 3);
    let dags: Vec<_> = stream.take_jobs(64);
    let chains: Vec<ChainJob> = dags.iter().map(transform).collect();
    let horizon = chains.iter().map(|j| j.deadline).fold(0.0, f64::max) + 1.0;
    let trace = PriceTrace::generate(SpotModel::paper_default(), horizon, 9);
    let grid = policy_set_full();

    // --- generator + transform ---
    let mut gen_stream = JobStream::new(GeneratorConfig::paper_default(), 11);
    b.bench_throughput("workload/generate_dag", 1.0, "jobs/s", || {
        gen_stream.next_job()
    });
    let mut i = 0;
    b.bench_throughput("workload/transform_dag_to_chain", 1.0, "jobs/s", || {
        i = (i + 1) % dags.len();
        transform(&dags[i])
    });

    // --- Dealloc ---
    let big = &chains[0];
    b.bench_throughput("policy/dealloc", 1.0, "allocs/s", || dealloc(big, 0.5));

    // --- realized executor ---
    let mut k = 0;
    b.bench_throughput("sim/execute_chain_realized", 1.0, "jobs/s", || {
        k = (k + 1) % chains.len();
        let job = &chains[k];
        let windows = dealloc(job, 1.0 / 1.6);
        execute_chain(
            job,
            &ChainStrategy::Windows {
                windows: &windows,
                selfowned: SelfOwnedRule::None,
                bid: 0.24,
            },
            &trace,
            None,
            1.0,
        )
    });

    // --- pool (segment tree) ---
    let mut pool = SelfOwnedPool::new(1200, horizon, 1.0 / SLOTS_PER_UNIT as f64);
    let mut rng = Pcg32::new(5);
    b.bench_throughput("market/pool_reserve_release", 2.0, "ops/s", || {
        let t0 = rng.uniform(0.0, horizon - 5.0);
        let t1 = t0 + rng.uniform(0.5, 4.0);
        let r = pool.available_over(t0, t1).min(4);
        pool.reserve(r, t0, t1);
        pool.release(r, t0, t1);
    });

    // --- counterfactual sweep: naive walk vs sweep engine vs PJRT ---
    let cf_jobs: Vec<CounterfactualJob> = chains
        .iter()
        .map(|job| {
            let (prices, dt) = trace.resample_window(job.arrival, job.deadline, S_MAX);
            let n = prices.len();
            CounterfactualJob::from_job(job, prices, dt, vec![8.0; n], 1.0)
        })
        .collect();
    let mut cn = 0;
    b.bench_throughput(
        "learning/counterfactual_naive_175pol",
        grid.len() as f64,
        "policy-evals/s",
        || {
            cn = (cn + 1) % 16;
            eval_grid_naive(&cf_jobs[cn], &grid, true)
        },
    );
    let mut ci = 0;
    b.bench_throughput(
        "learning/counterfactual_native_175pol",
        grid.len() as f64,
        "policy-evals/s",
        || {
            ci = (ci + 1) % 16;
            eval_grid_native(&cf_jobs[ci], &grid, true)
        },
    );
    // Batched retirements: the whole 64-job batch per iteration, fanned
    // across the worker pool (the coordinator's retire-burst path).
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    b.bench_throughput(
        "learning/sweep_batch_64jobs",
        cf_jobs.len() as f64,
        "jobs/s",
        || sweep::sweep_batch(&cf_jobs, &grid, true, threads),
    );

    match ArtifactRuntime::load_default() {
        Ok(rt) => {
            let mut cj = 0;
            b.bench_throughput(
                "learning/counterfactual_pjrt_175pol",
                grid.len() as f64,
                "policy-evals/s",
                || {
                    // Same 16-job cycle as the naive/native benches so the
                    // three evaluators measure an identical workload.
                    cj = (cj + 1) % 16;
                    rt.policy_cost.eval(&cf_jobs[cj], &grid, true).expect("pjrt eval")
                },
            );
            if let Some(tk) = rt.tola_update.as_ref() {
                let w = vec![1.0 / 175.0; 175];
                let costs: Vec<f64> = (0..175).map(|i| (i % 13) as f64).collect();
                b.bench("runtime/tola_update_pjrt", || {
                    tk.update(&w, &costs, 0.05).expect("tola update")
                });
            }
        }
        Err(e) => println!("(PJRT benches skipped: {e})"),
    }

    // --- single-policy counterfactual (the unit of the sweep) ---
    let p = Policy::new(1.0 / 1.6, Some(4.0 / 14.0), 0.24);
    b.bench_throughput("learning/counterfactual_single_policy", 1.0, "evals/s", || {
        cf_jobs[0].eval_policy(&p, true)
    });

    // --- trace ops ---
    b.bench("market/resample_window_2048", || {
        trace.resample_window(0.0, horizon.min(200.0), S_MAX)
    });

    std::fs::create_dir_all("results").ok();
    b.write_json("results/bench_hotpath.json").ok();
    println!("\nresults written to results/bench_hotpath.json");
}
