//! Streaming-feed benches: what ingestion costs and what the incremental
//! availability index saves.
//!
//! The headline numbers CI tracks (`BENCH_feed.json`):
//!
//! * `feed/ingest_events` — event → slot materialization throughput
//!   through a bounded [`FeedBuffer`] (steady-state memory);
//! * `feed/load_ec2_jsonl` — loader throughput on the JSON-lines dump
//!   shape (parse + normalize);
//! * `index/append_120_incremental` vs `index/rebuild_*` — the contract
//!   the subsystem exists for: appending k slots costs O(k·L) no matter
//!   how long the history is, while a batch rebuild pays O(S·L) again.
//!   The rebuild is measured at two history lengths to show it scaling
//!   with S while the incremental append does not.

use dagcloud::feed::{load_events, FeedBuffer, FeedFilter, FeedFormat, PriceEvent};
use dagcloud::market::{AvailabilityIndex, SLOTS_PER_UNIT};
use dagcloud::policy::grid_b;
use dagcloud::util::bench::Bencher;

const DT: f64 = 1.0 / SLOTS_PER_UNIT as f64;

/// Deterministic synthetic price path (no RNG dependency in benches).
fn price(i: usize) -> f64 {
    0.14 + 0.7 * (((i * 2_654_435_761) >> 7) & 0xff) as f64 / 255.0
}

fn main() {
    let mut b = Bencher::new();
    println!("== bench_feed ==\n");

    // --- event ingestion through a bounded buffer ---
    let events: Vec<PriceEvent> = (0..5_000)
        .map(|i| PriceEvent {
            time: (i as f64 + 1.0) * 0.25,
            price: price(i),
        })
        .collect();
    b.bench_throughput("feed/ingest_events_5k", events.len() as f64, "events/s", || {
        let mut buf = FeedBuffer::new(DT).with_retention(4_096);
        for &e in &events {
            buf.push_event(e).unwrap();
        }
        buf.close();
        buf.len_slots()
    });

    // --- loader throughput on the JSON-lines dump shape ---
    let jsonl: String = (0..2_000)
        .map(|i| {
            format!(
                "{{\"Timestamp\":\"2024-03-{:02}T{:02}:{:02}:00Z\",\"SpotPrice\":\"{:.4}\",\
                 \"AvailabilityZone\":\"us-east-1a\",\"InstanceType\":\"m5.large\"}}\n",
                1 + i / 96,
                (i / 4) % 24,
                (i % 4) * 15,
                price(i)
            )
        })
        .collect();
    b.bench_throughput("feed/load_ec2_jsonl_2k", 2_000.0, "records/s", || {
        load_events(&jsonl, FeedFormat::Ec2Json, &FeedFilter::default(), 1.0 / 3600.0, 1.0)
            .unwrap()
            .events
            .len()
    });

    // --- incremental index append vs batch rebuild ---
    // Contract: the incremental append's cost tracks the k new slots, the
    // rebuild's cost tracks the whole history S.
    let bids = grid_b();
    let short: Vec<f64> = (0..6_000).map(price).collect();
    let long: Vec<f64> = (0..48_000).map(price).collect();
    let fresh: Vec<f64> = (0..120).map(|i| price(i + 48_000)).collect();

    // Steady state: bounded retention keeps the buffer from growing across
    // iterations while each append still does the full O(k·L) index work.
    let mut live = FeedBuffer::with_bids(DT, bids.clone()).with_retention(64_000);
    live.push_slots(&long).unwrap();
    b.bench("index/append_120_incremental", || {
        live.push_slots(&fresh).unwrap();
        live.index().len_slots()
    });
    b.bench("index/rebuild_6k_slots", || {
        AvailabilityIndex::build(&short, bids.clone()).bids().len()
    });
    b.bench("index/rebuild_48k_slots", || {
        AvailabilityIndex::build(&long, bids.clone()).bids().len()
    });

    let incr = b.results.iter().find(|r| r.name.contains("incremental")).unwrap().mean_ns;
    let rebuild = b.results.iter().find(|r| r.name.contains("48k")).unwrap().mean_ns;
    println!(
        "\nappend 120 slots: incremental {:.1} µs vs 48k-history rebuild {:.1} µs ({:.0}x)",
        incr / 1e3,
        rebuild / 1e3,
        rebuild / incr.max(1.0)
    );

    std::fs::create_dir_all("results").ok();
    b.write_json("results/bench_feed.json").expect("write bench json");
    println!("\nwritten results/bench_feed.json");
}
