//! Migration-layer benches: the slot-granular migrating walk against the
//! pinned-offer walk it generalizes (the overhead of checking the switch
//! rule at every boundary), and the capacity replay that prices the
//! sweep's optimism (marshal + purchase re-reservation across the full
//! policy grid). See EXPERIMENTS.md §Migration.

use dagcloud::learning::counterfactual::CfSpec;
use dagcloud::learning::replay_specs;
use dagcloud::market::{CapacityLedger, MarketOffer, MarketView, PriceTrace, SLOTS_PER_UNIT};
use dagcloud::policy::routing::{MigrationPolicy, RoutingPolicy};
use dagcloud::policy::policy_set_full;
use dagcloud::sim::executor::{execute_task_routed_decide, execute_task_routed_migrating};
use dagcloud::util::bench::Bencher;
use dagcloud::util::rng::Pcg32;
use dagcloud::workload::{ChainJob, ChainTask};

/// Two-offer opposite-phase seesaw: the adversarial shape for the switch
/// rule (a candidate flip at every epoch boundary).
fn seesaw_view(horizon: f64, period_slots: usize, lo: f64, hi: f64) -> MarketView {
    let dt = 1.0 / SLOTS_PER_UNIT as f64;
    let n = (horizon / dt) as usize + 2;
    let phase = |s: usize| (s / period_slots) % 2 == 0;
    let offer = |name: &str, prices: Vec<f64>| MarketOffer {
        region: name.into(),
        instance_type: "default".into(),
        od_price: 1.0,
        trace: PriceTrace::from_prices(prices, dt),
        capacity: None,
    };
    let east: Vec<f64> = (0..n).map(|s| if phase(s) { lo } else { hi }).collect();
    let west: Vec<f64> = (0..n).map(|s| if phase(s) { hi } else { lo }).collect();
    MarketView::new(vec![offer("east", east), offer("west", west)]).unwrap()
}

fn main() {
    let mut b = Bencher::new();
    println!("== bench_migration ==\n");

    // 200 tasks of mixed geometry walked over a 40-unit seesaw, migration
    // on vs the pinned decide path — the per-boundary switch check is the
    // only difference between the two numbers.
    let horizon = 40.0;
    let view = seesaw_view(horizon, 4, 0.1, 0.6);
    let mut rng = Pcg32::new(0x316);
    let tasks: Vec<(f64, f64, f64, f64)> = (0..200)
        .map(|_| {
            let delta = rng.uniform(1.0, 12.0);
            let e = rng.uniform(0.5, 2.5);
            let start = rng.uniform(0.0, horizon - 8.0);
            (e * delta, delta, start, start + e * rng.uniform(1.1, 2.0))
        })
        .collect();
    let policy = MigrationPolicy { switch_cost: 0.01, hysteresis_slots: 2 };
    b.bench_throughput("migration/migrating_walk_200_tasks_seesaw", 200.0, "tasks/s", || {
        let mut cap = CapacityLedger::new(&view, horizon + 8.0);
        let mut cost = 0.0;
        for &(z, delta, start, deadline) in &tasks {
            let (_, out, _) = execute_task_routed_migrating(
                z,
                delta,
                start,
                deadline,
                0,
                0.9,
                &view,
                &mut cap,
                RoutingPolicy::CheapestFeasible,
                policy,
            );
            cost += out.spot_cost + out.od_cost;
        }
        cost
    });
    b.bench_throughput("migration/pinned_walk_200_tasks_seesaw", 200.0, "tasks/s", || {
        let mut cap = CapacityLedger::new(&view, horizon + 8.0);
        let mut cost = 0.0;
        for &(z, delta, start, deadline) in &tasks {
            let (_, out) = execute_task_routed_decide(
                z,
                delta,
                start,
                deadline,
                0,
                0.9,
                &view,
                &mut cap,
                RoutingPolicy::CheapestFeasible,
            );
            cost += out.spot_cost + out.od_cost;
        }
        cost
    });

    // Capacity replay over the full 175-policy grid: marshal 32 jobs once,
    // then re-reserve every policy's purchase stream through its own
    // ledger on a crunched 2-offer view.
    let mut rng = Pcg32::new(0x316A);
    let mut jobs: Vec<ChainJob> = (0..32)
        .map(|i| {
            let a = rng.uniform(0.0, 6.0);
            let tasks = vec![ChainTask::new(rng.uniform(0.5, 4.0), rng.uniform(1.0, 8.0))];
            let makespan: f64 = tasks.iter().map(|t| t.min_exec_time()).sum();
            ChainJob::new(i as u64, a, a + makespan * rng.uniform(1.1, 2.5), tasks)
        })
        .collect();
    jobs.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
    let rh = jobs.iter().map(|j| j.deadline).fold(1.0, f64::max) + 1.0;
    let n = (rh * SLOTS_PER_UNIT as f64) as usize + 2;
    let dt = 1.0 / SLOTS_PER_UNIT as f64;
    let mk_prices = |rng: &mut Pcg32| -> Vec<f64> {
        (0..n)
            .map(|_| {
                if rng.chance(0.5) {
                    rng.uniform(0.1, 0.3)
                } else {
                    rng.uniform(0.5, 1.2)
                }
            })
            .collect()
    };
    let replay_view = MarketView::new(vec![
        MarketOffer {
            region: "primary".into(),
            instance_type: "default".into(),
            od_price: 1.0,
            trace: PriceTrace::from_prices(mk_prices(&mut rng), dt),
            capacity: Some(4),
        },
        MarketOffer {
            region: "overflow".into(),
            instance_type: "default".into(),
            od_price: 1.2,
            trace: PriceTrace::from_prices(mk_prices(&mut rng), dt),
            capacity: Some(8),
        },
    ])
    .unwrap();
    let specs: Vec<CfSpec> = policy_set_full().into_iter().map(CfSpec::Proposed).collect();
    b.bench_throughput(
        "migration/capacity_replay_32jobs_175pol",
        (jobs.len() * specs.len()) as f64,
        "job*pol/s",
        || replay_specs(&jobs, &specs, &replay_view, RoutingPolicy::CheapestFeasible, false),
    );

    std::fs::create_dir_all("results").ok();
    b.write_json("results/bench_migration.json").ok();
    println!("\nresults written to results/bench_migration.json");
}
