//! Fleet-layer benches: what merging a fleet's worth of shard reports
//! costs — report parsing + cell union, canonical renormalization +
//! robustness scoring, and the manifest round-trip. The workload is
//! synthetic (rows shaped like real `dagcloud.scenarios/v1` details with
//! the full 175-policy cost surface) so the bench isolates the merge
//! layer from the coordinators that produced the rows.

use dagcloud::fleet::{merge_online, FleetAccumulator, OnlineSource, ShardManifest};
use dagcloud::learning::counterfactual::CfSpec;
use dagcloud::coordinator::OnlineSnapshot;
use dagcloud::policy::policy_set_full;
use dagcloud::scenario::{self, ScenarioOutcome};
use dagcloud::util::bench::Bencher;
use dagcloud::util::rng::Pcg32;

fn synthetic_outcome(world: usize, rep: u64, labels: &[String], rng: &mut Pcg32) -> ScenarioOutcome {
    let base = rng.uniform(0.2, 0.5);
    ScenarioOutcome {
        scenario: format!("world-{world:02}"),
        replicate: rep,
        run_seed: rng.next_u64(),
        jobs: 400,
        average_unit_cost: base,
        average_regret: rng.uniform(0.0, 0.05),
        regret_bound: rng.uniform(0.3, 0.6),
        pool_utilization: 0.0,
        so_share: 0.0,
        spot_share: 0.8,
        od_share: 0.2,
        availability_lo: 0.4,
        availability_hi: 0.9,
        best_policy: labels[0].clone(),
        offer_shares: Vec::new(),
        policy_costs: labels
            .iter()
            .map(|l| (l.clone(), base + rng.uniform(0.0, 0.2)))
            .collect(),
        tags: if world % 2 == 0 {
            vec!["calm".into()]
        } else {
            vec!["calm".into(), "surge".into()]
        },
        optimism_gap: Vec::new(),
        migrations: 0,
    }
}

fn main() {
    let mut b = Bencher::new();
    println!("== bench_fleet ==\n");

    // 10 worlds x 5 replicates, full 175-policy cost surface per row —
    // a registry-scale fleet's row volume.
    let labels: Vec<String> = policy_set_full()
        .into_iter()
        .map(|p| CfSpec::Proposed(p).label())
        .collect();
    let mut rng = Pcg32::new(0xF1EE7);
    let mut rows: Vec<ScenarioOutcome> = Vec::with_capacity(50);
    for w in 0..10usize {
        for rep in 0..5u64 {
            rows.push(synthetic_outcome(w, rep, &labels, &mut rng));
        }
    }

    let rows = rows; // frozen
    // Four shard documents, split round-robin like the manifest plans.
    let shard_docs: Vec<String> = (0..4usize)
        .map(|k| {
            let shard: Vec<ScenarioOutcome> = rows
                .iter()
                .enumerate()
                .filter(|(i, _)| i % 4 == k)
                .map(|(_, o)| o.clone())
                .collect();
            scenario::report_json(&shard, 5, 7, false).pretty()
        })
        .collect();
    let total_bytes: usize = shard_docs.iter().map(String::len).sum();

    b.bench_throughput(
        "fleet/merge_4_shards_50_cells_175pol",
        total_bytes as f64 / 1e6,
        "MB/s",
        || {
            let mut acc = FleetAccumulator::new();
            for doc in &shard_docs {
                acc.absorb(&dagcloud::util::json::Json::parse(doc).unwrap())
                    .unwrap();
            }
            acc.fleet_json(None).unwrap()
        },
    );

    // The renormalization half alone (rows already in memory): canonical
    // sort + aggregates + minimax scoring over 175 policies x 10 worlds.
    let mut acc = FleetAccumulator::new();
    for doc in &shard_docs {
        acc.absorb(&dagcloud::util::json::Json::parse(doc).unwrap())
            .unwrap();
    }
    b.bench("fleet/report_from_absorbed_rows", || {
        acc.fleet_json(None).unwrap()
    });
    let sorted = acc.canonical_outcomes();
    b.bench_throughput(
        "fleet/robustness_score_50_cells_175pol",
        (sorted.len() * labels.len()) as f64,
        "cells*pol/s",
        || dagcloud::fleet::score(&sorted),
    );

    // Manifest plan + JSON round-trip over the full registry.
    let specs = scenario::builtins();
    b.bench("fleet/manifest_plan_roundtrip_registry", || {
        let m = ShardManifest::plan(&specs, 4, 3, 7, false, None).unwrap();
        ShardManifest::from_json(&m.to_json()).unwrap()
    });

    // Online timeline merge: 8 coordinators x 100 snapshots.
    let sources: Vec<OnlineSource> = (0..8)
        .map(|k| OnlineSource {
            source: format!("coordinator-{k}"),
            snapshots: (1..=100u64)
                .map(|i| OnlineSnapshot {
                    jobs: i * 4,
                    sim_time: i as f64 + 0.1 * k as f64,
                    ingested_slots: (i * 16) as usize,
                    average_unit_cost: 0.4,
                    average_regret: 0.4 / i as f64,
                    regret_bound: 1.0 / (i as f64).sqrt(),
                    max_weight: 0.1,
                    best_policy: 0,
                })
                .collect(),
        })
        .collect();
    b.bench_throughput("fleet/online_merge_8x100_snapshots", 800.0, "snaps/s", || {
        merge_online(&sources).unwrap()
    });

    std::fs::create_dir_all("results").ok();
    b.write_json("results/bench_fleet.json").ok();
    println!("\nresults written to results/bench_fleet.json");
}
