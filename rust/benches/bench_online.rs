//! Online hot-loop benches: what a view refresh costs as the feed grows,
//! how long an appended slot takes to become decision-ready, and what the
//! append-incremental sweep tables save over a per-retirement rebuild.
//!
//! The headline numbers CI tracks (`BENCH_online.json`):
//!
//! * `online/view_refresh_*` — materializing a [`MarketView`] from the
//!   mux at 1k / 10k / 100k ingested slots. Shared-suffix traces make
//!   this O(new slots): the three numbers should sit flat (within
//!   noise) instead of scaling with history length;
//! * `online/append_to_decision` — one new slot pushed into a bounded
//!   buffer, trace refreshed, frontier price read: the latency from
//!   feed append to a decision-ready view;
//! * `tables/append_120_incremental` vs `tables/rebuild_48k_slots` —
//!   the contract of [`StreamingTables`]: extending the per-bid prefix
//!   tables costs O(new slots · bids) no matter how long the window
//!   already is, while a batch rebuild pays O(S · bids) per retirement.

use dagcloud::feed::{FeedBinding, FeedBuffer, FeedMux, PriceEvent};
use dagcloud::learning::sweep::StreamingTables;
use dagcloud::market::SLOTS_PER_UNIT;
use dagcloud::policy::grid_b;
use dagcloud::util::bench::Bencher;

const DT: f64 = 1.0 / SLOTS_PER_UNIT as f64;

/// Deterministic synthetic price path (no RNG dependency in benches).
fn price(i: usize) -> f64 {
    0.14 + 0.7 * (((i * 2_654_435_761) >> 7) & 0xff) as f64 / 255.0
}

/// A single-feed mux with `slots` determined slots, frontier advanced.
fn mux_with_slots(slots: usize) -> FeedMux {
    let events: Vec<PriceEvent> = (0..slots + 1)
        .map(|i| PriceEvent {
            time: (i as f64 + 1.0) * DT,
            price: price(i),
        })
        .collect();
    let binding = FeedBinding {
        region: "bench".into(),
        instance_type: "spot".into(),
        od_price: 1.0,
        capacity: None,
        events,
    };
    let mut mux = FeedMux::new(vec![binding], DT).expect("mux");
    mux.advance_to_slot(slots).expect("advance");
    mux
}

fn main() {
    let mut b = Bencher::new();
    println!("== bench_online ==\n");

    // --- view refresh vs ingested history ---
    // Contract: sealed chunks are referenced, not copied, so the refresh
    // cost tracks the open tail (bounded), not the history length.
    for &slots in &[1_000usize, 10_000, 100_000] {
        let mux = mux_with_slots(slots);
        let name = format!("online/view_refresh_{}k", slots / 1_000);
        b.bench(&name, || {
            let view = mux.view().expect("view");
            view.offers()[0].trace.num_slots()
        });
    }

    // --- append-to-decision latency ---
    // Steady state: bounded retention keeps the buffer resident-size
    // constant while each iteration appends one fresh slot, refreshes the
    // shared-suffix trace, and reads the frontier price.
    let long: Vec<f64> = (0..48_000).map(price).collect();
    let mut live = FeedBuffer::new(DT).with_retention(8_192);
    live.push_slots(&long).expect("seed live buffer");
    let mut next = 48_000usize;
    b.bench("online/append_to_decision", || {
        live.push_slots(&[price(next)]).expect("append");
        next += 1;
        let trace = live.shared_trace().expect("trace");
        trace.price_at(trace.horizon() - 0.5 * DT)
    });

    // --- incremental table append vs per-retirement rebuild ---
    // Contract: appending k fresh slots to [`StreamingTables`] costs
    // O(k·bids) regardless of how many slots the window already covers;
    // rebuilding from scratch (what every retirement paid before the
    // tables streamed) costs O(S·bids) again.
    let bids = grid_b();
    let fresh: Vec<f64> = (0..120).map(|i| price(i + 48_000)).collect();
    b.bench("tables/append_120_incremental", || {
        let mut st = StreamingTables::new(&bids, DT, fresh.len());
        for &p in &fresh {
            st.append(p);
        }
        st.filled()
    });
    b.bench("tables/rebuild_48k_slots", || {
        let mut st = StreamingTables::new(&bids, DT, long.len());
        for &p in &long {
            st.append(p);
        }
        st.filled()
    });

    let incr = b.results.iter().find(|r| r.name.contains("incremental")).unwrap().mean_ns;
    let rebuild = b.results.iter().find(|r| r.name.contains("48k")).unwrap().mean_ns;
    println!(
        "\nextend tables by 120 slots: incremental {:.1} µs vs 48k rebuild {:.1} µs ({:.0}x)",
        incr / 1e3,
        rebuild / 1e3,
        rebuild / incr.max(1.0)
    );
    let r1 = b.results.iter().find(|r| r.name.ends_with("refresh_1k")).unwrap().mean_ns;
    let r100 = b.results.iter().find(|r| r.name.ends_with("refresh_100k")).unwrap().mean_ns;
    println!(
        "view refresh: 1k {:.1} µs vs 100k {:.1} µs ({:.1}x — flat is the contract)",
        r1 / 1e3,
        r100 / 1e3,
        r100 / r1.max(1.0)
    );

    std::fs::create_dir_all("results").ok();
    b.write_json("results/bench_online.json").expect("write bench json");
    println!("\nwritten results/bench_online.json");
}
