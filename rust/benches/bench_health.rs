//! Health-plane and forensics benches: folding a cap-sized (262k-event)
//! deterministic log into `dagcloud.health/v1`, localizing the first
//! divergence between two cap-sized logs, and the shard merge.

use dagcloud::fleet::merge_health;
use dagcloud::telemetry::diff::{bisect_events, diff_docs};
use dagcloud::telemetry::event::EVENT_CAP;
use dagcloud::telemetry::health::{fold_events, health_doc};
use dagcloud::telemetry::{SimEvent, SimEventKind};
use dagcloud::util::bench::Bencher;
use dagcloud::util::json::Json;

/// Serialized canonical rows: `sources` cells, `per_source` events each,
/// with a realistic kind mix (decisions, frontier, routing, snapshots).
fn synth_rows(sources: usize, per_source: usize) -> Vec<Json> {
    let mut rows = Vec::with_capacity(sources * per_source);
    for s in 0..sources {
        let src = format!("world#{s}");
        for i in 0..per_source {
            let t = i as f64 * 0.25;
            let kind = match i % 8 {
                0 => SimEventKind::FrontierAdvanced { slots: i * 3 + 12 },
                1 => SimEventKind::SpecChosen { job: i, spec: i % 175 },
                2 => SimEventKind::WindowOpened {
                    job: i,
                    task: i % 4,
                    start: t,
                    deadline: t + 2.0,
                },
                3 => SimEventKind::OfferRouted { job: i, task: i % 4, offer: i % 3, spilled: i % 5 == 0 },
                4 => SimEventKind::CapacityExhausted { job: i, task: i % 4, offer: i % 3 },
                5 => SimEventKind::ResidencyProbe { slot: i * 3, first_resident: (i * 3) / 2 },
                6 => SimEventKind::ParamSnapshot {
                    jobs: i,
                    max_weight: 0.02,
                    best_policy: "p".to_string(),
                    regret: 0.01,
                    bound: 0.4,
                },
                _ => SimEventKind::SweepBatch { retired: 4, specs: 175 },
            };
            rows.push(SimEvent { sim_time: t, seq: i as u64, kind }.to_json(&src));
        }
    }
    rows
}

fn main() {
    let mut b = Bencher::new();
    println!("== bench_health ==\n");

    // --- fold throughput at the per-source event cap (one 262k source) ---
    let cap_rows = synth_rows(1, EVENT_CAP);
    b.bench_throughput("health/fold_262k_events", cap_rows.len() as f64, "events/s", || {
        fold_events(&cap_rows)
    });

    // --- a realistic fleet: 16 cells x 4096 events, fold + doc assembly ---
    let fleet_rows = synth_rows(16, 4096);
    b.bench_throughput("health/doc_16x4096", fleet_rows.len() as f64, "events/s", || {
        health_doc(&fold_events(&fleet_rows))
    });

    // --- shard merge of pre-folded sections ---
    let sections = fold_events(&fleet_rows);
    b.bench("health/merge_16_sections", || merge_health(&sections).unwrap());

    // --- first-divergence localization on cap-sized logs ---
    // Divergence seeded near the end: the scan pays for ~the whole log.
    let left = cap_rows.clone();
    let mut right = cap_rows.clone();
    let div_at = EVENT_CAP - 1024;
    right[div_at] = SimEvent {
        sim_time: div_at as f64 * 0.25,
        seq: div_at as u64,
        kind: SimEventKind::SpecChosen { job: div_at, spec: 999 },
    }
    .to_json("world#0");
    b.bench_throughput("health/diff_bisect_262k", left.len() as f64, "events/s", || {
        bisect_events(&left, &right, 8).unwrap().index
    });

    // --- full-document structural diff path (what CI runs on cmp failure) ---
    let mut doc_a = Json::obj();
    doc_a.set("schema", Json::Str("dagcloud.telemetry/v1".into())).set("deterministic", {
        let mut d = Json::obj();
        d.set("events", Json::Arr(left.clone()));
        d
    });
    let mut doc_b = Json::obj();
    doc_b.set("schema", Json::Str("dagcloud.telemetry/v1".into())).set("deterministic", {
        let mut d = Json::obj();
        d.set("events", Json::Arr(right.clone()));
        d
    });
    b.bench("health/diff_docs_262k", || diff_docs(&doc_a, &doc_b, 8).struct_count);

    std::fs::create_dir_all("results").ok();
    b.write_json("results/bench_health.json").ok();
    println!("\nresults written to results/bench_health.json");
}
