//! Telemetry overhead benches: what instrumentation costs when it is on,
//! and — the number that justifies leaving the hooks in the hot loops —
//! what it costs when it is off.

use dagcloud::telemetry::{Histogram, LogLevel, SimEventKind, Telemetry, TelemetryOptions};
use dagcloud::util::bench::Bencher;

fn enabled() -> Telemetry {
    Telemetry::new(TelemetryOptions {
        events: true,
        spans: true,
        level: LogLevel::Quiet,
    })
}

fn main() {
    let mut b = Bencher::new();
    println!("== bench_telemetry ==\n");

    // --- span guards: the per-scope RAII cost ---
    let t_on = enabled();
    b.bench_throughput("telemetry/span_enabled", 1.0, "spans/s", || {
        t_on.span("bench/scope")
    });
    let t_off = Telemetry::disabled();
    b.bench_throughput("telemetry/span_disabled", 1.0, "spans/s", || {
        t_off.span("bench/scope")
    });

    // --- event emission: the per-event cost inside the coordinator loop ---
    let mut rec_on = t_on.recorder("bench#0");
    let mut i = 0usize;
    b.bench_throughput("telemetry/emit_enabled", 1.0, "events/s", || {
        i = i.wrapping_add(1);
        rec_on.emit(i as f64, SimEventKind::FrontierAdvanced { slots: i });
    });
    let mut rec_off = t_off.recorder("bench#0");
    b.bench_throughput("telemetry/emit_disabled", 1.0, "events/s", || {
        i = i.wrapping_add(1);
        rec_off.emit(i as f64, SimEventKind::FrontierAdvanced { slots: i });
    });

    // --- histogram observe: the per-sample cost behind every span drop ---
    let mut h = Histogram::new();
    let mut ns = 1u64;
    b.bench_throughput("telemetry/hist_observe", 1.0, "samples/s", || {
        ns = ns.wrapping_mul(6364136223846793005).wrapping_add(1);
        h.observe(ns >> 34);
    });

    // --- export: canonical sort + serialization of a populated log ---
    let t_doc = enabled();
    for src in 0..8 {
        let mut r = t_doc.recorder(&format!("world#{src}"));
        for k in 0..512u32 {
            r.emit(k as f64 * 0.25, SimEventKind::SpecChosen { job: k as usize, spec: (k % 175) as usize });
        }
        t_doc.absorb(r);
    }
    b.bench("telemetry/deterministic_export_4096ev", || {
        t_doc.deterministic_json().pretty()
    });
    b.bench("telemetry/chrome_trace_export", || {
        t_on.chrome_trace_json().pretty()
    });

    std::fs::create_dir_all("results").ok();
    b.write_json("results/bench_telemetry.json").ok();
    println!("\nresults written to results/bench_telemetry.json");
}
