//! Robustness-engine benches: what deriving a world population costs
//! (trace realization + block resampling + CSV re-serialization per
//! world), and what the tail-risk scoring + promotion gate cost at
//! 1000-world population scale. The scoring workload is synthetic rows,
//! so the bench isolates the scoring layer from the runs that would
//! produce them.

use dagcloud::fleet;
use dagcloud::robustness::{derive_population, evaluate_gate, DeriveParams, GateConfig};
use dagcloud::scenario::{self, ScenarioOutcome};
use dagcloud::util::bench::Bencher;
use dagcloud::util::rng::Pcg32;

fn synthetic_outcome(world: usize, labels: &[String], rng: &mut Pcg32) -> ScenarioOutcome {
    let base = rng.uniform(0.2, 0.5);
    ScenarioOutcome {
        scenario: format!("world-{world:04}"),
        replicate: 0,
        run_seed: rng.next_u64(),
        jobs: 400,
        average_unit_cost: base,
        average_regret: rng.uniform(0.0, 0.05),
        regret_bound: rng.uniform(0.3, 0.6),
        pool_utilization: 0.0,
        so_share: 0.0,
        spot_share: 0.8,
        od_share: 0.2,
        availability_lo: 0.4,
        availability_hi: 0.9,
        best_policy: labels[0].clone(),
        offer_shares: Vec::new(),
        policy_costs: labels
            .iter()
            .map(|l| (l.clone(), base + rng.uniform(0.0, 0.2)))
            .collect(),
        tags: match world % 3 {
            0 => vec!["calm".into()],
            1 => vec!["calm".into(), "surge".into()],
            _ => vec!["fault".into()],
        },
        optimism_gap: Vec::new(),
        migrations: 0,
    }
}

fn main() {
    let mut b = Bencher::new();
    println!("== bench_robustness ==\n");

    // Derivation: 64 worlds from two bases (the CI smoke shape). Each
    // derived world realizes every offer trace, resamples it, and
    // re-serializes it as an inline replay CSV.
    let bases = vec![
        scenario::find("paper-default").unwrap(),
        scenario::find("capacity-crunch").unwrap(),
    ];
    let params = DeriveParams::default();
    b.bench_throughput("robustness/derive_64_worlds_2_bases", 64.0, "worlds/s", || {
        derive_population(&bases, 64, 7, &params).unwrap()
    });

    // Scoring at population scale: 1000 worlds x 25 policies, quantiles
    // + CVaR + difficulty weighting.
    let labels: Vec<String> = (0..25).map(|i| format!("policy-{i:02}")).collect();
    let mut rng = Pcg32::new(0xB0057);
    let rows: Vec<ScenarioOutcome> = (0..1000)
        .map(|w| synthetic_outcome(w, &labels, &mut rng))
        .collect();
    b.bench_throughput(
        "robustness/score_1000_worlds_25pol",
        (rows.len() * labels.len()) as f64,
        "cells*pol/s",
        || fleet::score(&rows),
    );
    b.bench_throughput(
        "robustness/gate_1000_worlds_25pol",
        rows.len() as f64,
        "worlds/s",
        || evaluate_gate(&rows, &GateConfig::default()),
    );

    std::fs::create_dir_all("results").ok();
    b.write_json("results/bench_robustness.json").ok();
    println!("\nresults written to results/bench_robustness.json");
}
