//! End-to-end benches: one per paper table. Each bench measures the
//! wall-clock of regenerating a (reduced-size) table cell and prints the
//! resulting cost-improvement figures, so `cargo bench` both times the
//! system and re-derives every table's numbers.
//!
//! Harness: `util::bench` (criterion is unavailable offline); wired via
//! `[[bench]] harness = false`.

use dagcloud::coordinator::{parallel_map, tola_run, Config, Evaluator};
use dagcloud::learning::counterfactual::CfSpec;
use dagcloud::policy::{benchmark_bids, policy_set_full, policy_set_spot_only};
use dagcloud::sim::cost::{cost_improvement, min_unit_cost, utilization_ratio};
use dagcloud::sim::horizon::{HorizonRunner, StrategySpec};
use dagcloud::util::bench::Bencher;

fn cfg(jobs: usize) -> Config {
    Config {
        jobs,
        seed: 7,
        threads: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
        use_pjrt: false,
        ..Config::default()
    }
}

fn main() {
    let jobs: usize = std::env::var("DAGCLOUD_BENCH_JOBS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);
    let c = cfg(jobs);
    let threads = c.effective_threads();
    let mut b = Bencher::new();
    println!("== bench_tables: {jobs} jobs per cell, {threads} threads ==\n");

    // ---- Table 2 (x2 = 2 cell) ----
    let (jobs2, trace2) = dagcloud::experiments::tables::workload(&c, 2);
    let proposed: Vec<StrategySpec> = policy_set_spot_only()
        .into_iter()
        .map(StrategySpec::Proposed)
        .collect();
    let greedy: Vec<StrategySpec> = benchmark_bids()
        .into_iter()
        .map(|bid| StrategySpec::GreedyBaseline { bid })
        .collect();
    let even: Vec<StrategySpec> = benchmark_bids()
        .into_iter()
        .map(|bid| StrategySpec::EvenBaseline { bid })
        .collect();
    let mut t2 = (0.0, 0.0);
    b.bench("table2/cell_x2=2 (25-policy sweep + baselines)", || {
        let runner = HorizonRunner::new(&trace2, 0);
        let (a, _) = min_unit_cost(&parallel_map(proposed.len(), threads, |i| {
            runner.run(&jobs2, proposed[i])
        }));
        let (ag, _) = min_unit_cost(&parallel_map(greedy.len(), threads, |i| {
            runner.run(&jobs2, greedy[i])
        }));
        let (ae, _) = min_unit_cost(&parallel_map(even.len(), threads, |i| {
            runner.run(&jobs2, even[i])
        }));
        t2 = (cost_improvement(a, ag), cost_improvement(a, ae));
        t2
    });
    println!("   -> rho_greedy = {:.2}%, rho_even = {:.2}%\n", 100.0 * t2.0, 100.0 * t2.1);

    // ---- Table 3 (x1 = 600, x2 = 2 cell) ----
    let full: Vec<StrategySpec> = policy_set_full()
        .into_iter()
        .map(StrategySpec::Proposed)
        .collect();
    let mut t3 = 0.0;
    b.bench("table3/cell_x1=600,x2=2 (175-policy sweep + pool)", || {
        let runner = HorizonRunner::new(&trace2, 600);
        let (a, _) = min_unit_cost(&parallel_map(full.len(), threads, |i| {
            runner.run(&jobs2, full[i])
        }));
        let (ae, _) = min_unit_cost(&parallel_map(even.len(), threads, |i| {
            runner.run(&jobs2, even[i])
        }));
        t3 = cost_improvement(a, ae);
        t3
    });
    println!("   -> rho = {:.2}%\n", 100.0 * t3);

    // ---- Tables 4+5 (x1 = 600, x2 = 2 cell) ----
    let naive: Vec<StrategySpec> = policy_set_spot_only()
        .into_iter()
        .map(StrategySpec::DeallocNaive)
        .collect();
    let mut t45 = (0.0, 0.0);
    b.bench("table4_5/cell_x1=600,x2=2 (rule12 vs naive)", || {
        let runner = HorizonRunner::new(&trace2, 600);
        let props = parallel_map(full.len(), threads, |i| runner.run(&jobs2, full[i]));
        let naives = parallel_map(naive.len(), threads, |i| runner.run(&jobs2, naive[i]));
        let (a, pi) = min_unit_cost(&props);
        let (an, bi) = min_unit_cost(&naives);
        t45 = (
            cost_improvement(a, an),
            utilization_ratio(&props[pi], &naives[bi]),
        );
        t45
    });
    println!("   -> rho = {:.2}%, mu = {:.2}%\n", 100.0 * t45.0, 100.0 * t45.1);

    // ---- Table 6 (x1 = 600 cell, TOLA) ----
    let specs: Vec<CfSpec> = policy_set_full().into_iter().map(CfSpec::Proposed).collect();
    let bench_specs: Vec<CfSpec> = benchmark_bids()
        .into_iter()
        .map(|bid| CfSpec::EvenNaive { bid })
        .collect();
    // Two TOLA runs per iteration; the jobs/s figure tracks the retire-path
    // throughput of the sweep engine + batched retirements end to end.
    let mut t6 = 0.0;
    b.bench_throughput("table6/cell_x1=600 (TOLA run, native evaluator)", 2.0 * jobs as f64, "jobs/s", || {
        let p = tola_run(
            &jobs2,
            &specs,
            &trace2,
            600,
            1.0,
            7,
            &Evaluator::Native { threads },
        );
        let q = tola_run(
            &jobs2,
            &bench_specs,
            &trace2,
            600,
            1.0,
            8,
            &Evaluator::Native { threads },
        );
        t6 = cost_improvement(p.average_unit_cost, q.average_unit_cost);
        t6
    });
    println!("   -> rho_bar = {:.2}%\n", 100.0 * t6);

    b.write_json("results/bench_tables.json").ok();
    println!("results written to results/bench_tables.json");
}
