//! Routing/`MarketView` benches: what the multi-offer generalization costs
//! on the sweep hot path and in the routed executor.
//!
//! The headline numbers CI tracks (`BENCH_routing.json`):
//!
//! * `sweep/one_offer_legacy` vs `sweep/one_offer_view` — the degenerate
//!   case's overhead (must be ~zero: the one-offer multi path is the same
//!   context evaluated through one more call frame);
//! * `sweep/four_offer_view` — the real multi-offer sweep (4x the prefix
//!   tables, 4x the closed-form walks, one min);
//! * routed vs legacy chain execution on a one-offer view, and a
//!   capacity-contended four-offer spillover execution.

use dagcloud::learning::counterfactual::{CfSpec, CounterfactualJob, S_MAX};
use dagcloud::learning::sweep;
use dagcloud::market::{CapacityLedger, MarketOffer, MarketView, PriceTrace, SpotModel};
use dagcloud::policy::dealloc::dealloc;
use dagcloud::policy::policy_set_full;
use dagcloud::policy::routing::RoutingPolicy;
use dagcloud::sim::executor::{execute_chain, execute_chain_routed, ChainStrategy, SelfOwnedRule};
use dagcloud::util::bench::Bencher;
use dagcloud::workload::{transform, ChainJob, GeneratorConfig, JobStream};

fn offer(region: &str, trace: PriceTrace, od: f64, capacity: Option<u32>) -> MarketOffer {
    MarketOffer {
        region: region.into(),
        instance_type: "default".into(),
        od_price: od,
        trace,
        capacity,
    }
}

fn main() {
    let mut b = Bencher::new();
    println!("== bench_routing ==\n");

    let mut stream = JobStream::new(GeneratorConfig::paper_default(), 5);
    let chains: Vec<ChainJob> = stream.take_jobs(32).iter().map(transform).collect();
    let horizon = chains.iter().map(|j| j.deadline).fold(0.0, f64::max) + 1.0;
    let traces: Vec<PriceTrace> = (0..4)
        .map(|k| PriceTrace::generate(SpotModel::paper_default(), horizon, 17 + k))
        .collect();

    // --- counterfactual sweep: one offer, legacy vs view path ---
    let grid: Vec<CfSpec> = policy_set_full().into_iter().map(CfSpec::Proposed).collect();
    let job = &chains[0];
    let (prices, dt) = traces[0].resample_window(job.arrival, job.deadline, S_MAX);
    let navail = vec![0.0; prices.len()];
    let cf_home = CounterfactualJob::from_job(job, prices, dt, navail.clone(), 1.0);
    b.bench_throughput("sweep/one_offer_legacy_175pol", 175.0, "evals/s", || {
        sweep::eval_spec_costs(&cf_home, &grid, false)
    });
    let one_offer = vec![cf_home.clone()];
    b.bench_throughput("sweep/one_offer_view_175pol", 175.0, "evals/s", || {
        sweep::eval_spec_costs_multi(&one_offer, &grid, false)
    });
    let four_offers: Vec<CounterfactualJob> = (0..4)
        .map(|k| {
            let (p, d) = traces[k].resample_window(job.arrival, job.deadline, S_MAX);
            CounterfactualJob::from_job(job, p, d, navail.clone(), 1.0 + 0.05 * k as f64)
        })
        .collect();
    b.bench_throughput("sweep/four_offer_view_175pol", 175.0, "evals/s", || {
        sweep::eval_spec_costs_multi(&four_offers, &grid, false)
    });

    // --- routed executor: degenerate overhead, then real contention ---
    let windows: Vec<_> = chains.iter().map(|j| dealloc(j, 1.0 / 1.6)).collect();
    let single_view = MarketView::single(traces[0].clone(), 1.0);
    let mut k = 0;
    b.bench_throughput("exec/legacy_chain", 1.0, "jobs/s", || {
        k = (k + 1) % chains.len();
        execute_chain(
            &chains[k],
            &ChainStrategy::Windows {
                windows: &windows[k],
                selfowned: SelfOwnedRule::None,
                bid: 0.24,
            },
            &traces[0],
            None,
            1.0,
        )
    });
    let mut k2 = 0;
    b.bench_throughput("exec/routed_chain_one_offer", 1.0, "jobs/s", || {
        k2 = (k2 + 1) % chains.len();
        let mut cap = CapacityLedger::new(&single_view, horizon);
        execute_chain_routed(
            &chains[k2],
            &windows[k2],
            SelfOwnedRule::None,
            0.24,
            &single_view,
            &mut cap,
            RoutingPolicy::Home,
            None,
        )
    });
    let four_view = MarketView::new(vec![
        offer("a", traces[0].clone(), 1.0, Some(24)),
        offer("b", traces[1].clone(), 1.05, Some(24)),
        offer("c", traces[2].clone(), 1.1, Some(48)),
        offer("d", traces[3].clone(), 1.2, None),
    ])
    .expect("valid view");
    b.bench_throughput("exec/routed_batch_four_offer_spillover", chains.len() as f64, "jobs/s", || {
        // One shared ledger across the batch: real contention.
        let mut cap = CapacityLedger::new(&four_view, horizon);
        for (j, w) in chains.iter().zip(&windows) {
            execute_chain_routed(
                j,
                w,
                SelfOwnedRule::None,
                0.24,
                &four_view,
                &mut cap,
                RoutingPolicy::Spillover,
                None,
            );
        }
    });

    std::fs::create_dir_all("results").ok();
    b.write_json("results/bench_routing.json").ok();
    println!("\nresults written to results/bench_routing.json");
}
