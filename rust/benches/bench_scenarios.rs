//! Scenario-engine benches: what a fleet-scale evaluation sweep costs —
//! spec parsing, world realization (workload + market), one full scenario
//! cell, and a sharded registry batch.

use dagcloud::scenario::{self, BatchOptions};
use dagcloud::util::bench::Bencher;

fn main() {
    let mut b = Bencher::new();
    println!("== bench_scenarios ==\n");

    let mut specs = scenario::builtins();
    for s in &mut specs {
        s.workload.small_tasks = true;
    }
    let paper = specs[0].clone();
    let replayed = specs
        .iter()
        .find(|s| s.name == "replayed-trace")
        .expect("registry has replayed-trace")
        .clone();

    // --- spec layer ---
    let text = paper.to_json().pretty();
    b.bench_throughput("scenario/spec_parse_roundtrip", 1.0, "specs/s", || {
        dagcloud::scenario::ScenarioSpec::parse(&text).expect("parse")
    });

    // --- world realization ---
    let seed = scenario::derive_run_seed(7, &paper.name, 0);
    b.bench_throughput("scenario/build_workload_64jobs", 64.0, "jobs/s", || {
        scenario::build_workload(&paper, 64, seed)
    });
    let jobs = scenario::build_workload(&paper, 64, seed);
    let horizon = jobs.iter().map(|j| j.deadline).fold(1.0, f64::max) + 1.0;
    b.bench("scenario/build_market_synthetic", || {
        scenario::build_market(&paper, horizon, seed).expect("market")
    });
    b.bench("scenario/build_market_replayed", || {
        scenario::build_market(&replayed, horizon, seed).expect("market")
    });

    // --- one full cell, then the sharded registry batch ---
    b.bench_throughput("scenario/run_once_32jobs", 32.0, "jobs/s", || {
        scenario::run_scenario_once(&paper, seed, Some(32)).expect("run")
    });
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4);
    let batch = BatchOptions {
        seeds: 1,
        base_seed: 7,
        threads,
        jobs_override: Some(16),
        telemetry: Default::default(),
    };
    b.bench_throughput(
        "scenario/registry_batch_16jobs",
        specs.len() as f64,
        "worlds/s",
        || scenario::run_batch(&specs, &batch).expect("batch"),
    );

    std::fs::create_dir_all("results").ok();
    b.write_json("results/bench_scenarios.json").ok();
    println!("\nresults written to results/bench_scenarios.json");
}
