//! Health-plane and forensics headline properties, end to end:
//!
//! * `dagcloud.health/v1` bytes are identical across `--threads 1` vs `8`
//!   and `--shards 1` vs `4`;
//! * health sections merged from random source partitions in random
//!   orders are byte-identical to the whole-log fold;
//! * enabling `--health` changes **zero bytes** of the existing reports;
//! * `repro diff` names the exact seeded divergent `(sim_time, source,
//!   seq)` event and exits non-zero.

use dagcloud::coordinator::Config;
use dagcloud::experiments::dispatch;
use dagcloud::experiments::fleet::{run_fleet, FleetCliOptions};
use dagcloud::fleet::merge_health;
use dagcloud::scenario::{self, BatchOptions, ScenarioSpec};
use dagcloud::telemetry::health::fold_events;
use dagcloud::telemetry::{LogLevel, Telemetry, TelemetryOptions};
use dagcloud::util::json::Json;

fn tele() -> Telemetry {
    Telemetry::new(TelemetryOptions {
        events: true,
        spans: false,
        level: LogLevel::Quiet,
    })
}

fn smoke_specs(names: &[&str]) -> Vec<ScenarioSpec> {
    names
        .iter()
        .map(|n| {
            let mut s = scenario::find(n).expect(n);
            s.workload.small_tasks = true;
            s
        })
        .collect()
}

fn tmp_dir(name: &str) -> String {
    let dir = std::env::temp_dir().join(name);
    std::fs::create_dir_all(&dir).unwrap();
    dir.to_string_lossy().into_owned()
}

fn read(dir: &str, file: &str) -> String {
    std::fs::read_to_string(format!("{dir}/{file}")).unwrap()
}

/// Deterministic splitmix-style generator: the partition/shuffle trials
/// must not depend on ambient entropy.
struct Rng(u64);
impl Rng {
    fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9e3779b97f4a7c15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        z ^ (z >> 31)
    }
}

#[test]
fn health_doc_bytes_identical_across_thread_counts() {
    let specs = smoke_specs(&["paper-default", "bursty-arrivals"]);
    let health_at = |threads: usize| {
        let t = tele();
        scenario::run_batch(
            &specs,
            &BatchOptions {
                seeds: 2,
                base_seed: 11,
                threads,
                jobs_override: Some(8),
                telemetry: t.clone(),
            },
        )
        .unwrap();
        t.health_json().pretty()
    };
    let one = health_at(1);
    let eight = health_at(8);
    assert_eq!(one, eight, "health doc differs between --threads 1 and 8");
    let doc = Json::parse(&one).unwrap();
    assert_eq!(doc.opt_str("schema", ""), "dagcloud.health/v1");
    assert_eq!(doc.opt_u64("sources", 0), 4, "2 worlds x 2 seeds = 4 cells");
    // The fold actually derived series, not just counted events.
    for key in ["decisions", "regret_last", "max_weight_last"] {
        assert!(one.contains(key), "health doc missing '{key}'");
    }
}

#[test]
fn health_doc_bytes_identical_across_shard_counts() {
    let cfg = |telemetry: Telemetry| Config {
        seed: 17,
        threads: 2,
        use_pjrt: false,
        telemetry,
        ..Config::default()
    };
    let opts = |shards: usize| FleetCliOptions {
        names: Some(vec![
            "paper-default".into(),
            "bursty-arrivals".into(),
            "deadline-tight".into(),
        ]),
        spec_file: None,
        seeds: 1,
        shards,
        smoke: true,
        jobs_override: Some(8),
        merge_only: None,
        online: Vec::new(),
    };
    let t1 = tele();
    let d1 = tmp_dir("dagcloud_health_fleet_k1");
    run_fleet(&cfg(t1.clone()), &opts(1), &d1).unwrap();
    let t4 = tele();
    let d4 = tmp_dir("dagcloud_health_fleet_k4");
    run_fleet(&cfg(t4.clone()), &opts(4), &d4).unwrap();
    let h1 = t1.health_json().pretty();
    assert_eq!(
        h1,
        t4.health_json().pretty(),
        "health doc differs between --shards 1 and --shards 4"
    );
    // Harness sources were excluded (they differ per shard plan), the
    // three cells were kept.
    let doc = Json::parse(&h1).unwrap();
    assert_eq!(doc.opt_u64("sources", 0), 3);
    assert!(!h1.contains("fleet/merge"));
}

#[test]
fn health_merge_is_partition_and_order_independent() {
    let specs = smoke_specs(&["paper-default", "bursty-arrivals", "deadline-tight"]);
    let t = tele();
    scenario::run_batch(
        &specs,
        &BatchOptions {
            seeds: 2,
            base_seed: 7,
            threads: 4,
            jobs_override: Some(8),
            telemetry: t.clone(),
        },
    )
    .unwrap();
    let det = t.deterministic_json();
    let events = det.get("events").unwrap().as_arr().unwrap();
    let baseline = merge_health(&fold_events(events)).unwrap().pretty();

    let mut rng = Rng(0xDA6C_100D);
    for trial in 0..6 {
        // Deal whole sources to 1..=4 shards (a cell never splits across
        // shards in a real fleet), fold each shard independently …
        let k = 1 + (rng.next() as usize % 4);
        let mut shard_of = std::collections::BTreeMap::new();
        let mut shards: Vec<Vec<Json>> = vec![Vec::new(); k];
        for e in events {
            let src = e.get("source").unwrap().as_str().unwrap().to_string();
            let s = *shard_of.entry(src).or_insert_with(|| rng.next() as usize % k);
            shards[s].push(e.clone());
        }
        let mut sections = Vec::new();
        for sh in &shards {
            sections.extend(fold_events(sh));
        }
        // … then merge the sections in a random order.
        for i in (1..sections.len()).rev() {
            sections.swap(i, rng.next() as usize % (i + 1));
        }
        assert_eq!(
            merge_health(&sections).unwrap().pretty(),
            baseline,
            "trial {trial}: merged health bytes depend on partition/order (k={k})"
        );
    }

    // Duplicate sources (a cell folded twice) are a hard error.
    let whole = fold_events(events);
    let mut dup = whole.clone();
    dup.extend(whole);
    let err = merge_health(&dup).unwrap_err().to_string();
    assert!(err.contains("duplicate source"), "{err}");
}

#[test]
fn health_flag_changes_zero_report_bytes() {
    let base = |out: &str, extra: &[&str]| {
        let mut argv = vec![
            "scenarios".to_string(),
            "--smoke".to_string(),
            "--scenario".to_string(),
            "paper-default".to_string(),
            "--seeds".to_string(),
            "1".to_string(),
            "--jobs".to_string(),
            "8".to_string(),
            "--quiet".to_string(),
            "--out".to_string(),
            out.to_string(),
        ];
        argv.extend(extra.iter().map(|s| s.to_string()));
        dispatch(argv).unwrap();
    };
    let d_off = tmp_dir("dagcloud_health_flag_off");
    base(&d_off, &[]);
    let d_on = tmp_dir("dagcloud_health_flag_on");
    base(&d_on, &["--health"]);
    assert_eq!(
        read(&d_off, "scenarios.json"),
        read(&d_on, "scenarios.json"),
        "--health perturbed scenarios.json bytes"
    );
    let health = Json::parse(&read(&d_on, "health.json")).unwrap();
    assert_eq!(health.opt_str("schema", ""), "dagcloud.health/v1");
    assert_eq!(health.opt_u64("sources", 0), 1);
    assert!(!std::path::Path::new(&format!("{d_off}/health.json")).exists());
}

#[test]
fn diff_subcommand_names_the_seeded_divergent_event() {
    use dagcloud::telemetry::{SimEvent, SimEventKind};
    let dir = tmp_dir("dagcloud_health_diff_cli");
    let write_doc = |path: &str, spec_at_41: usize| {
        let rows: Vec<Json> = (0..64u64)
            .map(|i| {
                SimEvent {
                    sim_time: i as f64 * 0.5,
                    seq: i,
                    kind: SimEventKind::SpecChosen {
                        job: i as usize,
                        spec: if i == 41 { spec_at_41 } else { 1 },
                    },
                }
                .to_json("w#0")
            })
            .collect();
        let mut det = Json::obj();
        det.set("events", Json::Arr(rows));
        let mut doc = Json::obj();
        doc.set("schema", Json::Str("dagcloud.telemetry/v1".into()))
            .set("deterministic", det);
        std::fs::write(path, doc.pretty()).unwrap();
    };
    let a = format!("{dir}/a.json");
    let b = format!("{dir}/b.json");
    write_doc(&a, 1);
    write_doc(&b, 9);
    let argv = |x: &str, y: &str| {
        vec![
            "diff".to_string(),
            x.to_string(),
            y.to_string(),
            "--context".to_string(),
            "2".to_string(),
            "--quiet".to_string(),
            "--out".to_string(),
            dir.clone(),
        ]
    };
    // Should-fail: the differing docs must exit non-zero AND the error
    // must name the first diverging event's canonical key.
    let err = dispatch(argv(&a, &b)).unwrap_err().to_string();
    assert!(err.contains("index 41"), "{err}");
    assert!(err.contains("sim_time=20.5"), "{err}");
    assert!(err.contains("source=w#0"), "{err}");
    assert!(err.contains("seq=41"), "{err}");
    // Identical inputs succeed (exit zero).
    dispatch(argv(&a, &a)).unwrap();

    // `repro health` folds the same file into a health doc on disk.
    dispatch(vec![
        "health".to_string(),
        a.clone(),
        "--quiet".to_string(),
        "--out".to_string(),
        dir.clone(),
    ])
    .unwrap();
    let health = Json::parse(&read(&dir, "health.json")).unwrap();
    assert_eq!(health.opt_str("schema", ""), "dagcloud.health/v1");
    assert_eq!(health.opt_u64("events", 0), 64);
}
