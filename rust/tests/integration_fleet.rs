//! Fleet-layer acceptance tests: the merged `dagcloud.fleet/v1` report is
//! byte-identical for any sharding of the scenario×seed cells and any
//! merge order (the golden reference being the single-runner report over
//! the same cell set), the robustness ranking is stable under detail-row
//! reordering, and `OnlineSnapshot` streams from many coordinators merge
//! into one order-independent timeline.

use dagcloud::coordinator::OnlineSnapshot;
use dagcloud::fleet::{merge_online, FleetAccumulator, OnlineSource};
use dagcloud::scenario::{self, BatchOptions, ScenarioOutcome, ScenarioSpec};
use dagcloud::util::json::Json;
use dagcloud::util::prop::{for_all, Config as PropConfig};

/// A small three-world batch (spot-only grids keep cells fast) whose
/// outcomes serve as the shared cell set for the sharding properties.
fn batch_outcomes() -> Vec<ScenarioOutcome> {
    let mut specs: Vec<ScenarioSpec> = ["paper-default", "bursty-arrivals", "deadline-tight"]
        .iter()
        .map(|n| scenario::find(n).unwrap())
        .collect();
    for s in &mut specs {
        s.workload.small_tasks = true;
    }
    scenario::run_batch(
        &specs,
        &BatchOptions {
            seeds: 2,
            base_seed: 23,
            threads: 4,
            jobs_override: Some(8),
            telemetry: Default::default(),
        },
    )
    .unwrap()
}

fn fleet_bytes_of_shards(shards: &[Vec<ScenarioOutcome>]) -> String {
    let mut acc = FleetAccumulator::new();
    for shard in shards {
        acc.absorb(&scenario::report_json(shard, 2, 23, true)).unwrap();
    }
    acc.fleet_json(None).unwrap().pretty()
}

/// The acceptance property: for ANY partition of the cells into shard
/// reports, absorbed in ANY order, with detail rows in ANY order inside
/// each shard report, the merged fleet report is byte-identical to the
/// single-runner (one-shard) report — robustness ranking included.
#[test]
fn fleet_merge_is_invariant_to_sharding_merge_order_and_row_order() {
    let all = batch_outcomes();
    assert_eq!(all.len(), 6);
    let reference = fleet_bytes_of_shards(&[all.clone()]);
    // Sanity: the reference carries a full robustness ranking.
    let j = Json::parse(&reference).unwrap();
    assert_eq!(
        j.get("robustness").unwrap().get("ranked").unwrap().as_u64().unwrap(),
        25,
        "every spot-only policy should rank across all 3 worlds"
    );

    for_all(PropConfig::cases(12).seed(0xF1EE7), |rng| {
        // Random partition into 1..=4 shards (some possibly empty —
        // empty shards are simply never serialized).
        let k = rng.range_inclusive(1, 4) as usize;
        let mut shards: Vec<Vec<ScenarioOutcome>> = vec![Vec::new(); k];
        for o in &all {
            shards[rng.below(k as u64) as usize].push(o.clone());
        }
        let mut shards: Vec<Vec<ScenarioOutcome>> =
            shards.into_iter().filter(|s| !s.is_empty()).collect();
        // Random row order inside each shard, random merge order.
        for s in &mut shards {
            rng.shuffle(s);
        }
        rng.shuffle(&mut shards);
        let merged = fleet_bytes_of_shards(&shards);
        if merged != reference {
            return Err(format!(
                "fleet report differs for a {}-shard partition",
                shards.len()
            ));
        }
        Ok(())
    });
}

#[test]
fn duplicate_cell_across_shards_is_a_hard_error() {
    let all = batch_outcomes();
    let mut acc = FleetAccumulator::new();
    acc.absorb(&scenario::report_json(&all, 2, 23, true)).unwrap();
    let err = acc
        .absorb(&scenario::report_json(&all[..1], 2, 23, true))
        .unwrap_err()
        .to_string();
    assert!(err.contains("duplicate fleet cell"), "{err}");
}

/// The robustness section is a pure function of the cell *set*: feeding
/// the scoring the same rows through differently-ordered shard documents
/// must reproduce the identical ranking array (not just the same winner).
#[test]
fn robustness_ranking_is_stable_under_report_row_reordering() {
    let all = batch_outcomes();
    let ranking_of = |rows: &[ScenarioOutcome]| -> String {
        let mut acc = FleetAccumulator::new();
        acc.absorb(&scenario::report_json(rows, 2, 23, true)).unwrap();
        acc.fleet_json(None)
            .unwrap()
            .get("robustness")
            .unwrap()
            .pretty()
    };
    let reference = ranking_of(&all);
    let mut reversed = all.clone();
    reversed.reverse();
    assert_eq!(ranking_of(&reversed), reference);
    // Interleave worlds: sort by replicate first, name second.
    let mut interleaved = all.clone();
    interleaved.sort_by(|a, b| {
        a.replicate
            .cmp(&b.replicate)
            .then(b.scenario.cmp(&a.scenario))
    });
    assert_eq!(ranking_of(&interleaved), reference);
}

fn snap(jobs: u64, t: f64, alpha: f64) -> OnlineSnapshot {
    OnlineSnapshot {
        jobs,
        sim_time: t,
        ingested_slots: (t * 16.0) as usize,
        average_unit_cost: alpha,
        average_regret: 0.05 / (jobs.max(1) as f64),
        regret_bound: 1.0 / (jobs.max(1) as f64).sqrt(),
        max_weight: 0.1,
        best_policy: 0,
    }
}

/// `OnlineSnapshot` streams from many coordinators merge into one
/// timeline whose bytes are independent of the source order, with a
/// cumulative fleet-wide job count.
#[test]
fn online_snapshot_streams_merge_order_independently() {
    let sources: Vec<OnlineSource> = (0..3)
        .map(|k| OnlineSource {
            source: format!("coordinator-{k}"),
            snapshots: (1..=4)
                .map(|i| snap(i * 2, i as f64 + 0.25 * k as f64, 0.4 - 0.01 * i as f64))
                .collect(),
        })
        .collect();
    let reference = merge_online(&sources).unwrap();
    assert_eq!(reference.total_jobs, 24);
    assert_eq!(reference.points.len(), 12);
    // fleet_jobs is monotone along the merged timeline and ends at the
    // fleet total.
    for w in reference.points.windows(2) {
        assert!(w[0].fleet_jobs <= w[1].fleet_jobs);
        assert!(w[0].sim_time <= w[1].sim_time);
    }
    assert_eq!(reference.points.last().unwrap().fleet_jobs, 24);

    let reference_bytes = reference.to_json().pretty();
    for_all(PropConfig::cases(8).seed(0x0A11E), |rng| {
        let mut shuffled = sources.clone();
        rng.shuffle(&mut shuffled);
        let merged = merge_online(&shuffled).unwrap().to_json().pretty();
        if merged != reference_bytes {
            return Err("online merge depends on source order".into());
        }
        Ok(())
    });
}

/// End-to-end: a real `tola_run_online` snapshot stream (the thing
/// `repro feed` serializes) round-trips through the feed/v1 document shape
/// into the fleet merge.
#[test]
fn real_online_snapshots_flow_into_the_fleet_merge() {
    use dagcloud::coordinator::{tola_run_online, Evaluator, OnlineOptions};
    use dagcloud::feed::FeedMux;
    use dagcloud::learning::counterfactual::CfSpec;
    use dagcloud::market::{PriceTrace, SpotModel};
    use dagcloud::policy::policy_set_spot_only;
    use dagcloud::workload::{transform, GeneratorConfig, JobStream};

    let mut stream = JobStream::new(GeneratorConfig::small(), 3);
    let jobs: Vec<_> = stream.take_jobs(24).iter().map(transform).collect();
    let horizon = jobs.iter().map(|j| j.deadline).fold(0.0, f64::max) + 2.0;
    let trace = PriceTrace::generate(SpotModel::paper_default(), horizon, 5);
    let specs: Vec<CfSpec> = policy_set_spot_only().into_iter().map(CfSpec::Proposed).collect();
    let run = |seed| {
        tola_run_online(
            &jobs,
            &specs,
            FeedMux::single_from_trace(&trace, 1.0),
            &OnlineOptions {
                seed,
                snapshot_every: 6,
                ..OnlineOptions::default()
            },
            &Evaluator::Native { threads: 2 },
        )
        .unwrap()
    };
    let a = run(7);
    let b = run(8);
    assert!(!a.snapshots.is_empty() && !b.snapshots.is_empty());
    let merged = merge_online(&[
        OnlineSource { source: "a".into(), snapshots: a.snapshots.clone() },
        OnlineSource { source: "b".into(), snapshots: b.snapshots.clone() },
    ])
    .unwrap();
    assert_eq!(
        merged.total_jobs,
        a.snapshots.last().unwrap().jobs + b.snapshots.last().unwrap().jobs
    );
    let j = merged.to_json();
    assert_eq!(j.get("sources").unwrap().as_arr().unwrap().len(), 2);
}
