//! Streaming-feed acceptance tests:
//!
//! * the incremental availability index is *exactly* equal (on `cum_wins`)
//!   to a batch rebuild under arbitrary append splits;
//! * `tola_run_online` over a fully pre-loaded feed — and over a live,
//!   event-gated feed — reproduces the batch `tola_run`/`tola_run_view`
//!   bit for bit, on degenerate and routed markets;
//! * the no-lookahead guard turns a feed that ends mid-stream into a hard
//!   error (the should-fail contract), never a silently clamped price.

use dagcloud::coordinator::{
    tola_run, tola_run_online, tola_run_view, Evaluator, LearningReport, OnlineOptions,
};
use dagcloud::feed::{
    FeedBinding, FeedMux, IncrementalAvailabilityIndex, PriceEvent,
};
use dagcloud::learning::counterfactual::CfSpec;
use dagcloud::market::{
    AvailabilityIndex, MarketOffer, MarketView, PriceTrace, SpotModel, SLOTS_PER_UNIT,
};
use dagcloud::policy::routing::RoutingPolicy;
use dagcloud::policy::{policy_set_full, policy_set_spot_only};
use dagcloud::util::prop::{for_all, Config as PropConfig};
use dagcloud::workload::{transform, ChainJob, GeneratorConfig, JobStream};

const DT: f64 = 1.0 / SLOTS_PER_UNIT as f64;

fn setup(n: usize, seed: u64) -> (Vec<ChainJob>, PriceTrace) {
    let mut stream = JobStream::new(GeneratorConfig::small(), seed);
    let jobs: Vec<ChainJob> = stream.take_jobs(n).iter().map(transform).collect();
    let horizon = jobs.iter().map(|j| j.deadline).fold(0.0, f64::max) + 1.0;
    let trace = PriceTrace::generate(SpotModel::paper_default(), horizon, seed + 1);
    (jobs, trace)
}

fn spot_specs() -> Vec<CfSpec> {
    policy_set_spot_only().into_iter().map(CfSpec::Proposed).collect()
}

/// Every field of the two reports, compared bitwise.
fn assert_reports_identical(a: &LearningReport, b: &LearningReport, ctx: &str) {
    assert_eq!(a.jobs, b.jobs, "{ctx}: jobs");
    assert_eq!(a.average_unit_cost, b.average_unit_cost, "{ctx}: alpha");
    assert_eq!(a.total_workload, b.total_workload, "{ctx}: workload");
    assert_eq!(a.final_weights, b.final_weights, "{ctx}: weights");
    assert_eq!(a.best_policy, b.best_policy, "{ctx}: best policy");
    assert_eq!(a.average_regret, b.average_regret, "{ctx}: regret");
    assert_eq!(a.regret_bound, b.regret_bound, "{ctx}: bound");
    assert_eq!(a.policy_mean_costs, b.policy_mean_costs, "{ctx}: policy costs");
    assert_eq!(a.pool_utilization, b.pool_utilization, "{ctx}: utilization");
    assert_eq!(a.weight_trajectory, b.weight_trajectory, "{ctx}: trajectory");
    assert_eq!(a.offer_work, b.offer_work, "{ctx}: offer work");
    assert_eq!(a.ledger, b.ledger, "{ctx}: ledger");
}

/// The trace's slots re-expressed as a live event stream (one observation
/// per slot boundary), so the online loop has to interleave ingestion with
/// event resolution instead of starting fully loaded.
fn trace_as_events(trace: &PriceTrace) -> Vec<PriceEvent> {
    (0..trace.num_slots())
        .map(|s| PriceEvent {
            time: s as f64 * trace.slot_len(),
            price: trace.price_of_slot(s),
        })
        .collect()
}

#[test]
fn incremental_index_equals_batch_under_any_append_split() {
    for_all(PropConfig::cases(200).seed(31), |rng| {
        let n = rng.range_inclusive(1, 400) as usize;
        let prices: Vec<f64> = (0..n)
            .map(|_| {
                if rng.chance(0.5) {
                    rng.uniform(0.1, 0.3)
                } else {
                    rng.uniform(0.3, 1.0)
                }
            })
            .collect();
        let n_bids = rng.range_inclusive(1, 6) as usize;
        let bids: Vec<f64> = (0..n_bids).map(|_| rng.uniform(0.1, 1.0)).collect();

        // Split the price stream into arbitrary append runs.
        let mut idx = IncrementalAvailabilityIndex::new(bids.clone());
        let mut pos = 0usize;
        while pos < n {
            let k = rng.range_inclusive(1, (n - pos) as u64) as usize;
            idx.append(&prices[pos..pos + k]);
            pos += k;
        }
        let batch = AvailabilityIndex::build(&prices, bids.clone());

        // Exact equality on the cumulative win counts, per bid.
        for &b in idx.bids() {
            let inc = idx.cum_wins(b).ok_or("bid missing in incremental")?;
            let bat = batch.cum_wins(b).ok_or("bid missing in batch")?;
            if inc != bat {
                return Err(format!("cum_wins diverged for bid {b}: {inc:?} vs {bat:?}"));
            }
        }
        // And identical query answers on random ranges (including ranges
        // clamped past the end).
        for _ in 0..10 {
            let s0 = rng.range_inclusive(0, n as u64 + 5) as usize;
            let s1 = rng.range_inclusive(0, n as u64 + 5) as usize;
            let bid = bids[rng.range_inclusive(0, n_bids as u64 - 1) as usize];
            if idx.winning_slots(s0, s1, bid) != batch.winning_slots(s0, s1, bid) {
                return Err(format!("winning_slots({s0},{s1},{bid}) diverged"));
            }
        }
        Ok(())
    });
}

#[test]
fn online_over_preloaded_feed_is_bit_identical_to_batch() {
    for (n_jobs, pool, seed) in [(50usize, 0u32, 11u64), (60, 150, 23), (40, 0, 47)] {
        let (jobs, trace) = setup(n_jobs, seed);
        let specs: Vec<CfSpec> = if pool > 0 {
            policy_set_full().into_iter().map(CfSpec::Proposed).collect()
        } else {
            spot_specs()
        };
        let batch = tola_run(
            &jobs,
            &specs,
            &trace,
            pool,
            1.0,
            seed,
            &Evaluator::Native { threads: 2 },
        );
        let mux = FeedMux::single_from_trace(&trace, 1.0);
        let online = tola_run_online(
            &jobs,
            &specs,
            mux,
            &OnlineOptions {
                routing: RoutingPolicy::Home,
                pool_capacity: pool,
                seed,
                snapshot_every: 16,
                ..OnlineOptions::default()
            },
            &Evaluator::Native { threads: 2 },
        )
        .unwrap();
        assert_reports_identical(
            &online.report,
            &batch,
            &format!("preloaded n={n_jobs} pool={pool} seed={seed}"),
        );
        assert_eq!(online.ingested_slots, trace.num_slots());
        assert!(!online.snapshots.is_empty());
        let last = online.snapshots.last().unwrap();
        assert!(last.jobs <= n_jobs as u64);
        assert!(last.regret_bound > 0.0);
    }
}

#[test]
fn online_over_live_event_stream_is_bit_identical_to_batch() {
    // The harder equivalence: the feed starts EMPTY and delivers one
    // observation per slot, so the loop must interleave ingestion with
    // event resolution (and rebuild its market view as the frontier
    // advances). Results must still match the batch run bit for bit.
    let (jobs, trace) = setup(40, 71);
    let specs = spot_specs();
    let batch = tola_run(
        &jobs,
        &specs,
        &trace,
        0,
        1.0,
        71,
        &Evaluator::Native { threads: 2 },
    );
    let mux = FeedMux::new(
        vec![FeedBinding {
            region: "default".into(),
            instance_type: "default".into(),
            od_price: 1.0,
            capacity: None,
            events: trace_as_events(&trace),
        }],
        DT,
    )
    .unwrap();
    let online = tola_run_online(
        &jobs,
        &specs,
        mux,
        &OnlineOptions {
            routing: RoutingPolicy::Home,
            pool_capacity: 0,
            seed: 71,
            snapshot_every: 10,
            ..OnlineOptions::default()
        },
        &Evaluator::Native { threads: 2 },
    )
    .unwrap();
    assert_reports_identical(&online.report, &batch, "live degenerate");
    // Snapshots are monotone in jobs and sim time.
    for w in online.snapshots.windows(2) {
        assert!(w[1].jobs > w[0].jobs);
        assert!(w[1].sim_time >= w[0].sim_time);
        assert!(w[1].ingested_slots >= w[0].ingested_slots);
    }
}

#[test]
fn online_routed_multi_offer_matches_batch_view_run() {
    let (jobs, trace) = setup(60, 13);
    let horizon = jobs.iter().map(|j| j.deadline).fold(0.0, f64::max) + 1.0;
    let n = (horizon * SLOTS_PER_UNIT as f64) as usize + 2;
    let alt = PriceTrace::from_prices(
        (0..n).map(|i| if i % 3 == 0 { 0.15 } else { 0.7 }).collect(),
        DT,
    );
    let offers = vec![
        MarketOffer {
            region: "primary".into(),
            instance_type: "default".into(),
            od_price: 1.0,
            trace: trace.clone(),
            capacity: Some(8),
        },
        MarketOffer {
            region: "overflow".into(),
            instance_type: "default".into(),
            od_price: 1.2,
            trace: alt.clone(),
            capacity: None,
        },
    ];
    let view = MarketView::new(offers).unwrap();
    let specs = spot_specs();
    for routing in [RoutingPolicy::CheapestFeasible, RoutingPolicy::Spillover] {
        let batch = tola_run_view(
            &jobs,
            &specs,
            &view,
            routing,
            0,
            29,
            &Evaluator::Native { threads: 2 },
        );
        // Preloaded mux with the identical offers.
        let mux = FeedMux::from_traces(&[
            ("primary".into(), "default".into(), 1.0, Some(8), trace.clone()),
            ("overflow".into(), "default".into(), 1.2, None, alt.clone()),
        ]);
        let online = tola_run_online(
            &jobs,
            &specs,
            mux,
            &OnlineOptions {
                routing,
                pool_capacity: 0,
                seed: 29,
                snapshot_every: 0,
                ..OnlineOptions::default()
            },
            &Evaluator::Native { threads: 2 },
        )
        .unwrap();
        assert_reports_identical(&online.report, &batch, &format!("routed {routing:?}"));
        assert_eq!(online.report.offer_work.len(), 2);
        assert!(online.snapshots.is_empty(), "snapshot_every = 0 emits none");
        // And the live-gated variant agrees as well.
        let live = FeedMux::new(
            vec![
                FeedBinding {
                    region: "primary".into(),
                    instance_type: "default".into(),
                    od_price: 1.0,
                    capacity: Some(8),
                    events: trace_as_events(&trace),
                },
                FeedBinding {
                    region: "overflow".into(),
                    instance_type: "default".into(),
                    od_price: 1.2,
                    capacity: None,
                    events: trace_as_events(&alt),
                },
            ],
            DT,
        )
        .unwrap();
        let streamed = tola_run_online(
            &jobs,
            &specs,
            live,
            &OnlineOptions {
                routing,
                pool_capacity: 0,
                seed: 29,
                snapshot_every: 0,
                ..OnlineOptions::default()
            },
            &Evaluator::Native { threads: 2 },
        )
        .unwrap();
        assert_reports_identical(&streamed.report, &batch, &format!("live routed {routing:?}"));
    }
}

#[test]
fn bounded_retention_is_bit_identical_when_windows_stay_resident() {
    // The streaming-memory contract: evicting sealed history that no live
    // counterfactual window can reach anymore must not change a single
    // report byte — bounded and unbounded runs over the same live event
    // stream are compared field-for-field, bitwise.
    let (jobs, trace) = setup(40, 71);
    let specs = spot_specs();
    let opts = OnlineOptions {
        routing: RoutingPolicy::Home,
        pool_capacity: 0,
        seed: 71,
        snapshot_every: 10,
        ..OnlineOptions::default()
    };
    let mk = || {
        FeedMux::new(
            vec![FeedBinding {
                region: "default".into(),
                instance_type: "default".into(),
                od_price: 1.0,
                capacity: None,
                events: trace_as_events(&trace),
            }],
            DT,
        )
        .unwrap()
    };
    let run = |mux: FeedMux| {
        tola_run_online(&jobs, &specs, mux, &opts, &Evaluator::Native { threads: 2 }).unwrap()
    };
    let unbounded = run(mk());
    // Smallest provably-safe retention: while job j is live, the frontier
    // can reach (with the mux's geometric ingestion, up to 2x overshoot)
    // the deadline of any job that arrived before j retired, and j's
    // retire-time marshal reads back to j's arrival slot.
    let total = trace.num_slots();
    let mut need = 0usize;
    for j in &jobs {
        let d = jobs
            .iter()
            .filter(|k| k.arrival <= j.deadline)
            .map(|k| k.deadline)
            .fold(j.deadline, f64::max);
        let frontier_cap = (2 * ((d + 1.0) / DT).ceil() as usize).min(total);
        let span = frontier_cap.saturating_sub((j.arrival / DT).floor() as usize);
        need = need.max(span);
    }
    let bounded = run(mk().with_retention(need + 64));
    assert_reports_identical(&bounded.report, &unbounded.report, "bounded retention");
    assert_eq!(bounded.ingested_slots, unbounded.ingested_slots, "ingested slots");
    assert_eq!(
        format!("{:?}", bounded.snapshots),
        format!("{:?}", unbounded.snapshots),
        "snapshot trajectory"
    );
}

#[test]
fn retention_reaching_an_evicted_slot_fails_hard() {
    // The should-fail contract, mirrored from the lookahead guard: a
    // retention too small for a live window must be a hard error naming
    // the evicted slot — never a silently clamped or imaginary price.
    let (jobs, trace) = setup(30, 5);
    let specs = spot_specs();
    let mux = FeedMux::new(
        vec![FeedBinding {
            region: "default".into(),
            instance_type: "default".into(),
            od_price: 1.0,
            capacity: None,
            events: trace_as_events(&trace),
        }],
        DT,
    )
    .unwrap()
    .with_retention(2);
    let err = tola_run_online(
        &jobs,
        &specs,
        mux,
        &OnlineOptions {
            routing: RoutingPolicy::Home,
            pool_capacity: 0,
            seed: 5,
            snapshot_every: 0,
            ..OnlineOptions::default()
        },
        &Evaluator::Native { threads: 1 },
    )
    .unwrap_err()
    .to_string();
    assert!(err.contains("evicted"), "{err}");
    assert!(err.contains("retention"), "{err}");
    assert!(err.contains("feed slot"), "{err}");
}

#[test]
fn lookahead_guard_fails_hard_when_the_feed_ends_early() {
    // The should-fail contract: a feed covering only part of the job
    // horizon must error — never silently price jobs against clamped or
    // imaginary slots.
    let (jobs, trace) = setup(30, 5);
    let specs = spot_specs();
    let short_slots = trace.num_slots() / 3;
    let short = PriceTrace::from_prices(
        (0..short_slots).map(|s| trace.price_of_slot(s)).collect(),
        DT,
    );
    let mux = FeedMux::single_from_trace(&short, 1.0);
    let err = tola_run_online(
        &jobs,
        &specs,
        mux,
        &OnlineOptions {
            routing: RoutingPolicy::Home,
            pool_capacity: 0,
            seed: 5,
            snapshot_every: 0,
            ..OnlineOptions::default()
        },
        &Evaluator::Native { threads: 1 },
    )
    .unwrap_err()
    .to_string();
    assert!(err.contains("lookahead"), "{err}");
    assert!(err.contains("frontier"), "{err}");
}

#[test]
fn online_handles_a_feed_with_margin_past_the_horizon() {
    // A feed longer than the workload needs: the loop simply stops
    // ingesting once the last retirement resolves; no error, identical
    // results to the batch run on the same (longer) trace.
    let (jobs, trace) = setup(25, 83);
    let specs = spot_specs();
    let batch = tola_run(
        &jobs,
        &specs,
        &trace,
        0,
        1.0,
        83,
        &Evaluator::Native { threads: 1 },
    );
    let mut events = trace_as_events(&trace);
    // Extend the stream well past the horizon.
    let last_t = events.last().unwrap().time;
    for k in 1..200 {
        events.push(PriceEvent {
            time: last_t + k as f64 * DT,
            price: 0.5,
        });
    }
    let mux = FeedMux::new(
        vec![FeedBinding {
            region: "default".into(),
            instance_type: "default".into(),
            od_price: 1.0,
            capacity: None,
            events,
        }],
        DT,
    )
    .unwrap();
    let online = tola_run_online(
        &jobs,
        &specs,
        mux,
        &OnlineOptions {
            routing: RoutingPolicy::Home,
            pool_capacity: 0,
            seed: 83,
            snapshot_every: 5,
            ..OnlineOptions::default()
        },
        &Evaluator::Native { threads: 1 },
    )
    .unwrap();
    assert_reports_identical(&online.report, &batch, "margin feed");
}
