//! Integration tests across workload → policy → simulator layers.

use dagcloud::market::{PriceTrace, SpotModel};
use dagcloud::policy::dealloc::{dealloc, expected_spot_workload};
use dagcloud::policy::{policy_set_spot_only, Policy};
use dagcloud::sim::executor::{execute_chain, ChainStrategy, SelfOwnedRule};
use dagcloud::sim::horizon::{HorizonRunner, StrategySpec};
use dagcloud::util::rng::Pcg32;
use dagcloud::workload::{transform, ChainJob, GeneratorConfig, JobStream};

fn chains(n: usize, job_type: u8, seed: u64) -> Vec<ChainJob> {
    let mut s = JobStream::new(GeneratorConfig::for_job_type(job_type), seed);
    s.take_jobs(n).iter().map(transform).collect()
}

fn trace_for(jobs: &[ChainJob], seed: u64) -> PriceTrace {
    let horizon = jobs.iter().map(|j| j.deadline).fold(0.0, f64::max) + 1.0;
    PriceTrace::generate(SpotModel::paper_default(), horizon, seed)
}

#[test]
fn end_to_end_deadlines_never_missed() {
    for job_type in 1..=4u8 {
        let jobs = chains(80, job_type, 100 + job_type as u64);
        let trace = trace_for(&jobs, 7);
        let runner = HorizonRunner::new(&trace, 0);
        for spec in [
            StrategySpec::Proposed(Policy::new(1.0 / 1.9, None, 0.24)),
            StrategySpec::EvenBaseline { bid: 0.24 },
            StrategySpec::GreedyBaseline { bid: 0.24 },
        ] {
            let rep = runner.run(&jobs, spec);
            assert_eq!(
                rep.deadlines_met,
                jobs.len(),
                "type {job_type}, {}",
                rep.strategy
            );
        }
    }
}

#[test]
fn dealloc_beats_even_in_expected_spot_workload() {
    // Prop. 4.3 end-to-end: on generated workloads, Algorithm 1's expected
    // spot workload dominates the Even split for every β in the grid.
    let jobs = chains(60, 2, 11);
    for &beta in &[1.0 / 1.3, 1.0 / 1.6, 1.0 / 2.2] {
        for job in &jobs {
            let opt = expected_spot_workload(job, &dealloc(job, beta));
            let even = dagcloud::policy::baselines::even_windows(job);
            // Evaluate Even's windows under the same β-capacity model.
            let even_alloc = dagcloud::policy::dealloc::WindowAllocation {
                sizes: even.sizes.clone(),
                beta,
            };
            let ev = expected_spot_workload(job, &even_alloc);
            assert!(
                opt >= ev - 1e-9,
                "job {}: dealloc {opt} < even {ev} at beta {beta}",
                job.id
            );
        }
    }
}

#[test]
fn spot_heavy_market_cheaper_than_spot_scarce() {
    let jobs = chains(60, 3, 13);
    let horizon = jobs.iter().map(|j| j.deadline).fold(0.0, f64::max) + 1.0;
    let cheap = PriceTrace::generate(
        SpotModel::BoundedExp { mean: 0.13, lo: 0.12, hi: 1.0 },
        horizon,
        5,
    );
    let dear = PriceTrace::generate(
        SpotModel::BoundedExp { mean: 0.6, lo: 0.12, hi: 1.0 },
        horizon,
        5,
    );
    let spec = StrategySpec::Proposed(Policy::new(1.0 / 1.6, None, 0.24));
    let a_cheap = HorizonRunner::new(&cheap, 0).run(&jobs, spec).average_unit_cost();
    let a_dear = HorizonRunner::new(&dear, 0).run(&jobs, spec).average_unit_cost();
    assert!(
        a_cheap < a_dear,
        "cheap market {a_cheap} should beat dear {a_dear}"
    );
}

#[test]
fn google_fixed_model_works_end_to_end() {
    let jobs = chains(40, 2, 17);
    let horizon = jobs.iter().map(|j| j.deadline).fold(0.0, f64::max) + 1.0;
    let trace = PriceTrace::generate(
        SpotModel::GoogleFixed { price: 0.3, availability: 0.6 },
        horizon,
        9,
    );
    // In the Google model bids are irrelevant; any bid >= price works.
    let rep = HorizonRunner::new(&trace, 0)
        .run(&jobs, StrategySpec::Proposed(Policy::new(0.6, None, 0.3)));
    assert_eq!(rep.deadlines_met, jobs.len());
    assert!(rep.ledger.work_spot > 0.0, "no spot work under Google model");
    // Spot charged at the fixed price.
    let unit = rep.ledger.cost_spot / rep.ledger.work_spot;
    assert!((unit - 0.3).abs() < 1e-9, "spot unit cost {unit}");
}

#[test]
fn higher_bids_win_more_spot() {
    let jobs = chains(60, 2, 19);
    let trace = trace_for(&jobs, 3);
    let runner = HorizonRunner::new(&trace, 0);
    let lo = runner.run(&jobs, StrategySpec::Proposed(Policy::new(0.5, None, 0.13)));
    let hi = runner.run(&jobs, StrategySpec::Proposed(Policy::new(0.5, None, 0.3)));
    assert!(
        hi.ledger.work_spot > lo.ledger.work_spot,
        "bid 0.3 spot work {} <= bid 0.13 spot work {}",
        hi.ledger.work_spot,
        lo.ledger.work_spot
    );
}

#[test]
fn pool_capacity_monotone_cost() {
    let jobs = chains(60, 2, 23);
    let trace = trace_for(&jobs, 29);
    let p = Policy::new(1.0 / 1.6, Some(4.0 / 14.0), 0.24);
    let mut prev = f64::INFINITY;
    for pool in [0u32, 100, 400, 1600] {
        let a = HorizonRunner::new(&trace, pool)
            .run(&jobs, StrategySpec::Proposed(p))
            .average_unit_cost();
        assert!(
            a <= prev + 0.02,
            "cost should not increase with pool size: {a} after {prev} (pool {pool})"
        );
        prev = a;
    }
}

#[test]
fn single_job_strategies_consistent_costs() {
    // For one job and one trace, the realized executor's cost must lie
    // between the all-spot lower bound and the all-on-demand upper bound.
    let mut rng = Pcg32::new(41);
    let jobs = chains(30, 4, 43);
    let trace = trace_for(&jobs, 47);
    for job in &jobs {
        let bid = 0.18 + 0.03 * rng.below(5) as f64;
        for beta in [1.0, 1.0 / 1.6, 1.0 / 2.2] {
            let windows = dealloc(job, beta);
            let o = execute_chain(
                job,
                &ChainStrategy::Windows {
                    windows: &windows,
                    selfowned: SelfOwnedRule::None,
                    bid,
                },
                &trace,
                None,
                1.0,
            );
            let cost = o.cost();
            let ub = job.total_work() * 1.0 + 1e-9;
            let lb = 0.0;
            assert!(cost <= ub, "cost {cost} above all-OD bound {ub}");
            assert!(cost >= lb);
        }
    }
}

#[test]
fn native_counterfactual_ranks_consistently_with_realized() {
    // The counterfactual model is an expected-timeline approximation of the
    // realized executor. Check rank agreement on extreme policies: cheapest
    // counterfactual policy should realize a cost no worse than the most
    // expensive counterfactual policy realizes.
    use dagcloud::learning::counterfactual::{CounterfactualJob, S_MAX};
    let jobs = chains(25, 2, 53);
    let trace = trace_for(&jobs, 59);
    let grid = policy_set_spot_only();
    let mut agree = 0;
    let mut total = 0;
    for job in &jobs {
        let (prices, dt) = trace.resample_window(job.arrival, job.deadline, S_MAX);
        let n = prices.len();
        let cf = CounterfactualJob::from_job(job, prices, dt, vec![0.0; n], 1.0);
        let costs: Vec<f64> = grid.iter().map(|p| cf.eval_policy(p, false).0).collect();
        let best = costs
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        let worst = costs
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        let realize = |p: &Policy| {
            let windows = dealloc(job, p.beta);
            execute_chain(
                job,
                &ChainStrategy::Windows {
                    windows: &windows,
                    selfowned: SelfOwnedRule::None,
                    bid: p.bid,
                },
                &trace,
                None,
                1.0,
            )
            .cost()
        };
        total += 1;
        if realize(&grid[best]) <= realize(&grid[worst]) + 1e-9 {
            agree += 1;
        }
    }
    assert!(
        agree * 10 >= total * 8,
        "counterfactual ranking agreed on only {agree}/{total} jobs"
    );
}
