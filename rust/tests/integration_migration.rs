//! Migration differential layer (ISSUE-10): the migration-off path is
//! byte-identical to the legacy pinned-offer routed path (spec-level:
//! an absent `migration` key and an explicit disabled policy produce the
//! same report bytes, across thread counts and fleet shardings), widening
//! hysteresis never increases the migration count, and the capacity-replay
//! optimism gap surfaces exactly on finite-capacity worlds and is ≥ 0.

use dagcloud::fleet::FleetAccumulator;
use dagcloud::market::{CapacityLedger, MarketOffer, MarketView, PriceTrace, SLOTS_PER_UNIT};
use dagcloud::policy::routing::{MigrationPolicy, RoutingPolicy};
use dagcloud::scenario::{self, BatchOptions, ScenarioOutcome, ScenarioSpec};
use dagcloud::sim::executor::execute_task_routed_migrating;
use dagcloud::util::prop::{for_all, Config as PropConfig};

/// The migration flagship world at smoke size.
fn spike_spec() -> ScenarioSpec {
    let mut s = scenario::find("spot-spike-migration").unwrap();
    s.workload.small_tasks = true;
    s
}

fn run(specs: &[ScenarioSpec], threads: usize, seeds: u64) -> Vec<ScenarioOutcome> {
    scenario::run_batch(
        specs,
        &BatchOptions {
            seeds,
            base_seed: 23,
            threads,
            jobs_override: Some(10),
            telemetry: Default::default(),
        },
    )
    .unwrap()
}

fn report_bytes(outcomes: &[ScenarioOutcome], seeds: u64) -> String {
    scenario::report_json(outcomes, seeds, 23, true).pretty()
}

/// A spec that never had a `migration` key parses as disabled, and a
/// disabled policy stays off disk — and both run to byte-identical
/// reports (the spec-level face of the structural "disabled means the
/// legacy pinned-offer code path" contract).
#[test]
fn absent_migration_key_equals_disabled_and_runs_byte_identical() {
    let enabled = spike_spec();
    assert!(enabled.migration.enabled());
    let enabled_json = enabled.to_json();
    assert!(enabled_json.pretty().contains("\"migration\""));
    let round = ScenarioSpec::from_json(&enabled_json).unwrap();
    assert_eq!(round.migration, enabled.migration, "enabled policy must round-trip");

    let mut disabled = enabled.clone();
    disabled.migration = MigrationPolicy::disabled();
    let dj = disabled.to_json();
    assert!(
        !dj.pretty().contains("\"migration\""),
        "disabled migration must stay off disk"
    );
    let absent = ScenarioSpec::from_json(&dj).unwrap();
    assert!(!absent.migration.enabled(), "absent key must parse as disabled");

    let a = report_bytes(&run(&[disabled], 4, 2), 2);
    let b = report_bytes(&run(&[absent], 4, 2), 2);
    assert_eq!(a, b, "absent-key and disabled-policy runs must be byte-identical");
    // Off-disk row contract: no migration/replay keys on an uncapped,
    // migration-off world.
    assert!(!a.contains("\"migrations\""));
    assert!(!a.contains("\"optimism_gap\""));
}

/// Thread-count invariance of the report bytes on a batch that exercises
/// both new report surfaces: the migration world (task_migrated counts)
/// and the capped crunch world (optimism-gap rows).
#[test]
fn migration_and_replay_report_is_thread_invariant() {
    let mut crunch = scenario::find("capacity-crunch").unwrap();
    crunch.workload.small_tasks = true;
    let specs = vec![spike_spec(), crunch];
    let one = report_bytes(&run(&specs, 1, 2), 2);
    let eight = report_bytes(&run(&specs, 8, 2), 2);
    assert_eq!(one, eight, "threads must not change report bytes");
    assert!(
        one.contains("\"optimism_gap\""),
        "capped world rows must carry per-policy optimism gaps"
    );
    assert!(
        one.contains("\"optimism_gap_mean\""),
        "capped world section must aggregate the gap"
    );
}

/// Fleet sharding invariance: merging shard reports that carry the new
/// `optimism_gap`/`migrations` row keys reproduces the one-shard report
/// byte-for-byte for any partition and merge order.
#[test]
fn fleet_merge_with_migration_rows_is_shard_invariant() {
    let mut crunch = scenario::find("capacity-crunch").unwrap();
    crunch.workload.small_tasks = true;
    let all = run(&[spike_spec(), crunch], 4, 2);
    assert_eq!(all.len(), 4);
    let bytes_of = |shards: &[Vec<ScenarioOutcome>]| {
        let mut acc = FleetAccumulator::new();
        for shard in shards {
            acc.absorb(&scenario::report_json(shard, 2, 23, true)).unwrap();
        }
        acc.fleet_json(None).unwrap().pretty()
    };
    let reference = bytes_of(&[all.clone()]);
    for_all(PropConfig::cases(8).seed(0x316A), |rng| {
        let k = rng.range_inclusive(1, 4) as usize;
        let mut shards: Vec<Vec<ScenarioOutcome>> = vec![Vec::new(); k];
        for o in &all {
            shards[rng.below(k as u64) as usize].push(o.clone());
        }
        let mut shards: Vec<Vec<ScenarioOutcome>> =
            shards.into_iter().filter(|s| !s.is_empty()).collect();
        for s in &mut shards {
            rng.shuffle(s);
        }
        rng.shuffle(&mut shards);
        if bytes_of(&shards) != reference {
            return Err(format!("fleet bytes differ for a {}-shard partition", shards.len()));
        }
        Ok(())
    });
}

/// Scenario-level hysteresis bound. The first switch of a task is never
/// hysteresis-gated and the walk before any switch is hysteresis-free, so
/// every task's first switch time is identical for all `hysteresis_slots`;
/// with the hold longer than the horizon each switching task moves exactly
/// once. Hence `migrations(huge) == #switching tasks <= migrations(0)`,
/// regardless of price regime. The flagship world must actually migrate.
#[test]
fn hysteresis_beyond_horizon_never_beats_zero_hysteresis() {
    let migrations_at = |hysteresis: u32| -> u64 {
        let mut s = spike_spec();
        s.migration.hysteresis_slots = hysteresis;
        run(&[s], 4, 3).iter().map(|o| o.migrations).sum()
    };
    let eager = migrations_at(0);
    let held = migrations_at(1_000_000);
    assert!(eager > 0, "the spike world is built to make migration profitable");
    assert!(
        held <= eager,
        "hysteresis past the horizon took {held} moves, zero hysteresis {eager}"
    );
}

/// Randomized executor-level monotonicity: on opposite-phase seesaws where
/// both sides are winnable at the bid (progress is then rate-identical on
/// either offer, so the remaining-work trajectory does not depend on which
/// offer the walk sits on), widening the hysteresis chain never increases
/// the migration count, deadlines hold, and work is conserved.
#[test]
fn prop_wider_hysteresis_never_migrates_more_on_winnable_seesaws() {
    let dt = 1.0 / SLOTS_PER_UNIT as f64;
    let offer = |name: &str, prices: Vec<f64>| MarketOffer {
        region: name.into(),
        instance_type: "default".into(),
        od_price: 1.0,
        trace: PriceTrace::from_prices(prices, dt),
        capacity: None,
    };
    for_all(PropConfig::cases(120).seed(0x3161), |rng| {
        let period = rng.range_inclusive(1, 6) as usize;
        let lo = rng.uniform(0.05, 0.2);
        let hi = rng.uniform(lo + 0.1, 0.8);
        let delta = rng.uniform(1.0, 12.0);
        let e = rng.uniform(0.3, 3.0);
        let z = e * delta;
        let deadline = e * rng.uniform(1.05, 2.5);
        let n = (deadline / dt) as usize + 2;
        let phase = |s: usize| (s / period) % 2 == 0;
        let east: Vec<f64> = (0..n).map(|s| if phase(s) { lo } else { hi }).collect();
        let west: Vec<f64> = (0..n).map(|s| if phase(s) { hi } else { lo }).collect();
        let view = MarketView::new(vec![offer("east", east), offer("west", west)])
            .map_err(|e| e.to_string())?;
        let bid = hi + 0.05; // both sides always winnable
        let mut last = usize::MAX;
        for h in [0u32, 1, 2, 4, 8, 32, 10_000] {
            let mut cap = CapacityLedger::new(&view, deadline + 1.0);
            let (_, out, migs) = execute_task_routed_migrating(
                z,
                delta,
                0.0,
                deadline,
                0,
                bid,
                &view,
                &mut cap,
                RoutingPolicy::CheapestFeasible,
                MigrationPolicy { switch_cost: 1e-9, hysteresis_slots: h },
            );
            if out.finish > deadline + 1e-6 {
                return Err(format!("h={h}: finish {} past deadline {deadline}", out.finish));
            }
            let w = out.so_work + out.spot_work + out.od_work;
            if (w - z).abs() > 1e-6 * z.max(1.0) {
                return Err(format!("h={h}: work {w} != {z}"));
            }
            if migs.len() > last {
                return Err(format!("h={h}: {} migrations > previous {last}", migs.len()));
            }
            last = migs.len();
        }
        Ok(())
    });
}

/// The capacity-replay columns surface exactly on finite-capacity worlds:
/// capped worlds report a per-policy gap, every gap is ≥ 0 (the replayed
/// cost can only add displacement surcharges), and capacity-free worlds
/// stay gap-free with zero migrations.
#[test]
fn optimism_gap_surfaces_only_on_capped_worlds_and_is_nonnegative() {
    let mut crunch = scenario::find("capacity-crunch").unwrap();
    crunch.workload.small_tasks = true;
    let out = scenario::run_scenario_once(&crunch, 23, Some(8)).unwrap();
    assert!(!out.optimism_gap.is_empty(), "capped world must carry per-policy gaps");
    for (label, gap) in &out.optimism_gap {
        assert!(!label.is_empty());
        assert!(gap.is_finite() && *gap >= 0.0, "negative optimism gap for {label}: {gap}");
    }
    let row = scenario::report_json(&[out], 1, 23, true).pretty();
    assert!(row.contains("\"optimism_gap\""));

    let mut free = scenario::find("paper-default").unwrap();
    free.workload.small_tasks = true;
    let out = scenario::run_scenario_once(&free, 23, Some(8)).unwrap();
    assert!(out.optimism_gap.is_empty(), "capacity-free world must not replay");
    assert_eq!(out.migrations, 0);
    let row = scenario::report_json(&[out], 1, 23, true).pretty();
    assert!(!row.contains("\"optimism_gap\""));
    assert!(!row.contains("\"migrations\""));
}
