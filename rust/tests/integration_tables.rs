//! Shape tests for the experiment harness: small-scale versions of the
//! paper's tables must reproduce the qualitative results (who wins, and
//! the direction of trends) even at reduced job counts.

use dagcloud::coordinator::Config;
use dagcloud::experiments::tables::{run_table2, run_table3, run_table6, workload};
use dagcloud::policy::{benchmark_bids, policy_set_full, policy_set_spot_only};
use dagcloud::sim::cost::min_unit_cost;
use dagcloud::sim::horizon::{HorizonRunner, StrategySpec};
use dagcloud::util::json::Json;

fn cfg(jobs: usize) -> Config {
    Config {
        jobs,
        seed: 97,
        threads: 4,
        pool_sizes: vec![80, 240],
        use_pjrt: false,
        ..Config::default()
    }
}

fn read_json(path: &std::path::Path) -> Json {
    Json::parse(&std::fs::read_to_string(path).unwrap()).unwrap()
}

#[test]
fn table2_proposed_wins_everywhere() {
    let dir = std::env::temp_dir().join("dagcloud_it_t2");
    std::fs::create_dir_all(&dir).unwrap();
    run_table2(&cfg(120), dir.to_str().unwrap()).unwrap();
    let j = read_json(&dir.join("table2.json"));
    for key in ["rho_greedy", "rho_even"] {
        let rho = j.get(key).unwrap().as_arr().unwrap();
        assert_eq!(rho.len(), 4);
        for (i, r) in rho.iter().enumerate() {
            let v = r.as_f64().unwrap();
            assert!(
                v > 0.0,
                "{key}[{i}] = {v}: proposed should beat the baseline"
            );
            assert!(v < 0.9, "{key}[{i}] = {v}: implausibly large improvement");
        }
    }
}

#[test]
fn table2_improvement_shrinks_with_flexibility() {
    // The paper's trend: tighter jobs (x2 = 1) benefit most from optimal
    // deadline allocation vs Greedy.
    let dir = std::env::temp_dir().join("dagcloud_it_t2b");
    std::fs::create_dir_all(&dir).unwrap();
    run_table2(&cfg(200), dir.to_str().unwrap()).unwrap();
    let j = read_json(&dir.join("table2.json"));
    let rho: Vec<f64> = j
        .get("rho_greedy")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|x| x.as_f64().unwrap())
        .collect();
    assert!(
        rho[0] > rho[3] - 0.03,
        "expected roughly decreasing trend, got {rho:?}"
    );
}

#[test]
fn table3_improvement_grows_with_pool() {
    let dir = std::env::temp_dir().join("dagcloud_it_t3");
    std::fs::create_dir_all(&dir).unwrap();
    run_table3(&cfg(100), dir.to_str().unwrap()).unwrap();
    let j = read_json(&dir.join("table3.json"));
    let rows = j.get("rho").unwrap().as_arr().unwrap();
    assert_eq!(rows.len(), 2); // pool sizes 80, 240
    let r0: Vec<f64> = rows[0].as_arr().unwrap().iter().map(|x| x.as_f64().unwrap()).collect();
    let r1: Vec<f64> = rows[1].as_arr().unwrap().iter().map(|x| x.as_f64().unwrap()).collect();
    // All positive, and the larger pool helps at least as much on average.
    for v in r0.iter().chain(&r1) {
        assert!(*v > -0.02, "rho {v} strongly negative");
    }
    let m0: f64 = r0.iter().sum::<f64>() / 4.0;
    let m1: f64 = r1.iter().sum::<f64>() / 4.0;
    assert!(m1 > m0 - 0.05, "bigger pool should help: {m0} vs {m1}");
}

#[test]
fn table6_tola_beats_benchmark() {
    // At this reduced scale (400 jobs vs the paper's 10000) TOLA has only
    // partially converged, so the no-pool cell is allowed a small negative
    // margin; the pooled cell must show a clear win.
    let mut c = cfg(400);
    c.pool_sizes = vec![120];
    let dir = std::env::temp_dir().join("dagcloud_it_t6");
    std::fs::create_dir_all(&dir).unwrap();
    run_table6(&c, dir.to_str().unwrap()).unwrap();
    let j = read_json(&dir.join("table6.json"));
    let rho: Vec<f64> = j
        .get("rho_bar")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|x| x.as_f64().unwrap())
        .collect();
    assert_eq!(rho.len(), 2); // x1 = 0 and 120
    assert!(rho[0] > -0.08, "TOLA (no pool) lost badly: {rho:?}");
    assert!(rho[1] > 0.05, "TOLA (pool) should clearly win: {rho:?}");
}

#[test]
fn fixed_policy_sweep_min_is_lower_bound_of_each() {
    let c = cfg(80);
    let (jobs, trace) = workload(&c, 2);
    let runner = HorizonRunner::new(&trace, 0);
    let specs: Vec<StrategySpec> = policy_set_spot_only()
        .into_iter()
        .map(StrategySpec::Proposed)
        .collect();
    let reports: Vec<_> = specs.iter().map(|s| runner.run(&jobs, *s)).collect();
    let (alpha, idx) = min_unit_cost(&reports);
    for r in &reports {
        assert!(alpha <= r.average_unit_cost() + 1e-12);
    }
    assert!(idx < reports.len());
    // Sanity on grid sizes used by the harness.
    assert_eq!(policy_set_full().len(), 175);
    assert_eq!(benchmark_bids().len(), 5);
}
