//! Telemetry headline properties, end to end:
//!
//! * enabling telemetry changes **zero bytes** of `scenarios.json`,
//!   `fleet.json`, `robustness.json`, and `feed_run.json`;
//! * the deterministic event log is byte-identical across `--threads`,
//!   and its per-cell half is byte-identical across `--shards`;
//! * the exported log is canonically ordered by `(sim_time, source, seq)`;
//! * the wall-clock plane (spans, Chrome trace) stays quarantined in the
//!   telemetry document.

use dagcloud::coordinator::Config;
use dagcloud::experiments::feed::{run_feed, FeedCliOptions};
use dagcloud::experiments::fleet::{run_fleet, FleetCliOptions};
use dagcloud::experiments::robustness::{run_robustness, RobustnessCliOptions};
use dagcloud::scenario::{self, BatchOptions, ScenarioSpec};
use dagcloud::telemetry::{LogLevel, Telemetry, TelemetryOptions};
use dagcloud::util::json::Json;

/// Both planes on, logger silenced (tests should not chat on stderr).
fn tele() -> Telemetry {
    Telemetry::new(TelemetryOptions {
        events: true,
        spans: true,
        level: LogLevel::Quiet,
    })
}

fn smoke_specs(names: &[&str]) -> Vec<ScenarioSpec> {
    names
        .iter()
        .map(|n| {
            let mut s = scenario::find(n).expect(n);
            s.workload.small_tasks = true;
            s
        })
        .collect()
}

fn tmp_dir(name: &str) -> String {
    let dir = std::env::temp_dir().join(name);
    std::fs::create_dir_all(&dir).unwrap();
    dir.to_string_lossy().into_owned()
}

fn read(dir: &str, file: &str) -> String {
    std::fs::read_to_string(format!("{dir}/{file}")).unwrap()
}

/// The per-cell half of a handle's event log (sources named `world#rep`),
/// serialized canonically. Harness-level sources (`fleet/merge`,
/// `robustness/gate`) are excluded: their row counts legitimately depend
/// on the shard plan.
fn cell_events(t: &Telemetry) -> String {
    let det = t.deterministic_json();
    let rows: Vec<Json> = det
        .get("events")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .filter(|e| e.get("source").unwrap().as_str().unwrap().contains('#'))
        .cloned()
        .collect();
    Json::Arr(rows).pretty()
}

#[test]
fn scenario_report_bytes_are_unchanged_by_telemetry() {
    let specs = smoke_specs(&["paper-default", "bursty-arrivals", "deadline-tight"]);
    let run = |telemetry: Telemetry| {
        let outs = scenario::run_batch(
            &specs,
            &BatchOptions {
                seeds: 2,
                base_seed: 7,
                threads: 4,
                jobs_override: Some(8),
                telemetry,
            },
        )
        .unwrap();
        scenario::report_json(&outs, 2, 7, true).pretty()
    };

    let off = run(Telemetry::disabled());
    let t = tele();
    let on = run(t.clone());
    assert_eq!(off, on, "telemetry perturbed scenarios.json bytes");

    // The run was actually observed: one source per cell, events in it,
    // and wall-clock spans on the other side of the wall.
    let det = t.deterministic_json();
    assert_eq!(det.get("sources").unwrap().as_f64(), Some(6.0));
    assert!(det.get("count").unwrap().as_f64().unwrap() > 0.0);
    let full = t.telemetry_json();
    assert_eq!(
        full.get("schema").unwrap().as_str(),
        Some("dagcloud.telemetry/v1")
    );
    let spans = full.get("wall_clock").unwrap().get("spans").unwrap();
    assert!(spans.get("runner/cell").is_some(), "runner span missing");
    // Chrome trace export is valid, non-empty JSON.
    let trace = t.chrome_trace_json();
    assert!(!trace.get("traceEvents").unwrap().as_arr().unwrap().is_empty());
    assert!(Json::parse(&trace.pretty()).is_ok());
}

#[test]
fn event_log_bytes_are_identical_across_thread_counts() {
    let specs = smoke_specs(&["paper-default", "replayed-trace"]);
    let log_at = |threads: usize| {
        let t = tele();
        scenario::run_batch(
            &specs,
            &BatchOptions {
                seeds: 2,
                base_seed: 11,
                threads,
                jobs_override: Some(8),
                telemetry: t.clone(),
            },
        )
        .unwrap();
        t.deterministic_json().pretty()
    };
    let one = log_at(1);
    let eight = log_at(8);
    assert_eq!(one, eight, "event log differs between --threads 1 and 8");
    for kind in ["window_opened", "spec_chosen", "sweep_batch", "param_snapshot"] {
        assert!(one.contains(kind), "no {kind} events recorded");
    }
}

#[test]
fn exported_event_log_is_canonically_ordered() {
    let specs = smoke_specs(&["paper-default", "bursty-arrivals"]);
    let t = tele();
    scenario::run_batch(
        &specs,
        &BatchOptions {
            seeds: 1,
            base_seed: 3,
            threads: 4,
            jobs_override: Some(8),
            telemetry: t.clone(),
        },
    )
    .unwrap();
    let det = t.deterministic_json();
    let events = det.get("events").unwrap().as_arr().unwrap();
    assert!(events.len() > 1);
    let key = |e: &Json| {
        (
            e.get("sim_time").unwrap().as_f64().unwrap(),
            e.get("source").unwrap().as_str().unwrap().to_string(),
            e.get("seq").unwrap().as_f64().unwrap(),
        )
    };
    for w in events.windows(2) {
        let (ta, sa, qa) = key(&w[0]);
        let (tb, sb, qb) = key(&w[1]);
        assert!(
            (ta, sa.as_str(), qa) <= (tb, sb.as_str(), qb),
            "events out of canonical order: ({ta},{sa},{qa}) then ({tb},{sb},{qb})"
        );
    }
}

#[test]
fn fleet_bytes_unchanged_and_cell_log_shard_invariant() {
    let cfg = |telemetry: Telemetry| Config {
        seed: 17,
        threads: 2,
        use_pjrt: false,
        telemetry,
        ..Config::default()
    };
    let opts = |shards: usize| FleetCliOptions {
        names: Some(vec![
            "paper-default".into(),
            "bursty-arrivals".into(),
            "deadline-tight".into(),
        ]),
        spec_file: None,
        seeds: 1,
        shards,
        smoke: true,
        jobs_override: Some(8),
        merge_only: None,
        online: Vec::new(),
    };

    // Telemetry on vs off at the same shard count: merged bytes identical.
    let d_off = tmp_dir("dagcloud_tele_fleet_off");
    run_fleet(&cfg(Telemetry::disabled()), &opts(2), &d_off).unwrap();
    let t2 = tele();
    let d_on = tmp_dir("dagcloud_tele_fleet_on");
    run_fleet(&cfg(t2.clone()), &opts(2), &d_on).unwrap();
    assert_eq!(
        read(&d_off, "fleet.json"),
        read(&d_on, "fleet.json"),
        "telemetry perturbed fleet.json bytes"
    );
    assert!(t2.deterministic_json().pretty().contains("report_absorbed"));

    // Per-cell event rows are invariant under the shard count.
    let t1 = tele();
    let d1 = tmp_dir("dagcloud_tele_fleet_k1");
    run_fleet(&cfg(t1.clone()), &opts(1), &d1).unwrap();
    let t4 = tele();
    let d4 = tmp_dir("dagcloud_tele_fleet_k4");
    run_fleet(&cfg(t4.clone()), &opts(4), &d4).unwrap();
    let cells1 = cell_events(&t1);
    assert_eq!(
        cells1,
        cell_events(&t4),
        "per-cell event log differs between --shards 1 and --shards 4"
    );
    assert!(cells1.len() > 2, "no cell events recorded");
}

#[test]
fn robustness_bytes_are_unchanged_by_telemetry() {
    let cfg = |telemetry: Telemetry| Config {
        seed: 31,
        threads: 2,
        use_pjrt: false,
        telemetry,
        ..Config::default()
    };
    let opts = RobustnessCliOptions {
        bases: Some(vec!["paper-default".into()]),
        derive: 4,
        shards: 2,
        smoke: true,
        jobs_override: Some(8),
        ..RobustnessCliOptions::default()
    };
    let d_off = tmp_dir("dagcloud_tele_rob_off");
    run_robustness(&cfg(Telemetry::disabled()), &opts, &d_off).unwrap();
    let t = tele();
    let d_on = tmp_dir("dagcloud_tele_rob_on");
    run_robustness(&cfg(t.clone()), &opts, &d_on).unwrap();
    for f in ["fleet.json", "robustness.json"] {
        assert_eq!(
            read(&d_off, f),
            read(&d_on, f),
            "telemetry perturbed {f} bytes"
        );
    }
    assert!(t.deterministic_json().pretty().contains("report_absorbed"));
}

#[test]
fn feed_run_bytes_are_unchanged_by_telemetry() {
    let dir = std::env::temp_dir().join("dagcloud_tele_feed_in");
    std::fs::create_dir_all(&dir).unwrap();
    let trace_path = dir.join("spot_sample.csv");
    std::fs::write(
        &trace_path,
        include_str!("../../examples/traces/spot_sample.csv"),
    )
    .unwrap();

    let cli = FeedCliOptions {
        trace_path: trace_path.to_string_lossy().into_owned(),
        format: None,
        scenario: None,
        time_scale: None,
        price_scale: 1.0,
        az: None,
        instance_type: None,
        snapshot_every: Some(8),
        jobs_override: Some(64),
        retention: None,
    };
    let cfg = |telemetry: Telemetry| Config {
        jobs: 64,
        seed: 5,
        threads: 2,
        use_pjrt: false,
        telemetry,
        ..Config::default()
    };

    let d_off = tmp_dir("dagcloud_tele_feed_off");
    run_feed(&cfg(Telemetry::disabled()), &cli, &d_off).unwrap();
    let t = tele();
    let d_on = tmp_dir("dagcloud_tele_feed_on");
    run_feed(&cfg(t.clone()), &cli, &d_on).unwrap();
    assert_eq!(
        read(&d_off, "feed_run.json"),
        read(&d_on, "feed_run.json"),
        "telemetry perturbed feed_run.json bytes"
    );
    let log = t.deterministic_json().pretty();
    assert!(log.contains("frontier_advanced"), "no frontier events from the online loop");
    assert!(log.contains("sweep_batch"));
}
