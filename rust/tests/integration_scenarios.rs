//! End-to-end scenario engine tests: registry health, JSON round-trips,
//! thread-count-independent determinism of the report, and CSV replay
//! through the full stack (loader → PriceTrace → coordinator → report).

use dagcloud::scenario::{self, BatchOptions, PriceSpec, ScenarioSpec};
use dagcloud::util::prop::{for_all, Config as PropConfig};

/// The registry at smoke size (small chains keep runtime in seconds).
fn smoke_specs() -> Vec<ScenarioSpec> {
    let mut specs = scenario::builtins();
    for s in &mut specs {
        s.workload.small_tasks = true;
    }
    specs
}

#[test]
fn every_builtin_parses_roundtrips_and_completes_a_run() {
    for spec in smoke_specs() {
        spec.validate().unwrap_or_else(|e| panic!("{}: {e}", spec.name));
        // JSON round-trip: value-level and text-level.
        let j = spec.to_json();
        let back = ScenarioSpec::from_json(&j)
            .unwrap_or_else(|e| panic!("{}: from_json: {e}", spec.name));
        assert_eq!(back, spec, "{}: JSON value round-trip", spec.name);
        let re = ScenarioSpec::parse(&j.pretty())
            .unwrap_or_else(|e| panic!("{}: parse: {e}", spec.name));
        assert_eq!(re, spec, "{}: JSON text round-trip", spec.name);

        // A small run completes with sane metrics.
        let seed = scenario::derive_run_seed(7, &spec.name, 0);
        let out = scenario::run_scenario_once(&spec, seed, Some(16))
            .unwrap_or_else(|e| panic!("{}: run: {e}", spec.name));
        assert_eq!(out.jobs, 16, "{}", spec.name);
        assert!(
            out.average_unit_cost.is_finite() && out.average_unit_cost >= 0.0,
            "{}: alpha {}",
            spec.name,
            out.average_unit_cost
        );
        let shares = out.so_share + out.spot_share + out.od_share;
        assert!(
            (shares - 1.0).abs() < 1e-6,
            "{}: work shares sum to {shares}",
            spec.name
        );
        assert!(
            (0.0..=1.0).contains(&out.availability_hi),
            "{}: availability {}",
            spec.name,
            out.availability_hi
        );
    }
}

/// The `repro scenarios` determinism contract: the report JSON is
/// byte-identical for `--threads 1` vs `--threads 8` on the same seed.
/// Property-tested across base seeds and scenario pairs.
#[test]
fn report_json_is_byte_identical_across_thread_counts() {
    let all = smoke_specs();
    for_all(PropConfig::cases(4).seed(0xD06), |rng| {
        let base_seed = rng.next_u64() % 1000;
        // A random pair of *distinct* worlds keeps each case fast while
        // covering the registry across cases (duplicate names are a batch
        // shape the CLI rejects).
        let i = rng.below(all.len() as u64) as usize;
        let j = (i + 1 + rng.below(all.len() as u64 - 1) as usize) % all.len();
        let specs: Vec<ScenarioSpec> = vec![all[i].clone(), all[j].clone()];
        let report_at = |threads: usize| {
            let outs = scenario::run_batch(
                &specs,
                &BatchOptions {
                    seeds: 2,
                    base_seed,
                    threads,
                    jobs_override: Some(10),
                    telemetry: Default::default(),
                },
            )
            .map_err(|e| e.to_string())?;
            Ok::<String, String>(scenario::report_json(&outs, 2, base_seed, true).pretty())
        };
        let single = report_at(1)?;
        let eight = report_at(8)?;
        if single != eight {
            return Err(format!(
                "report differs between --threads 1 and --threads 8 \
                 (base_seed {base_seed}, scenarios {:?})",
                specs.iter().map(|s| s.name.clone()).collect::<Vec<_>>()
            ));
        }
        Ok(())
    });
}

/// CSV replay end-to-end: loader → PriceTrace → coordinator → report, with
/// the market structure visible in the learned outcome.
#[test]
fn replayed_trace_scenario_reflects_its_market() {
    let mut spec = scenario::find("replayed-trace").unwrap();
    spec.workload.small_tasks = true;
    match &spec.market.regions[0].price {
        PriceSpec::Replay(r) => assert!(r.csv.is_some()),
        other => panic!("expected replay, got {other:?}"),
    }
    let out =
        scenario::run_scenario_once(&spec, scenario::derive_run_seed(7, &spec.name, 0), Some(40))
            .unwrap();
    // The sample trace's calm baseline sits near 0.15 with surge regimes:
    // the top grid bid (0.3) wins most slots, the bottom one (0.18) only
    // the calm dips.
    assert!(
        out.availability_hi > 0.5,
        "availability at bid 0.3: {}",
        out.availability_hi
    );
    assert!(
        out.availability_hi >= out.availability_lo,
        "bid monotonicity: {} < {}",
        out.availability_hi,
        out.availability_lo
    );
    // Learned cost must beat pure on-demand (alpha = 1.0) on this market.
    assert!(
        out.average_unit_cost < 1.0,
        "alpha {}",
        out.average_unit_cost
    );
    assert!(out.spot_share > 0.0);
}

#[test]
fn multi_region_arbitrage_never_loses_to_home_region() {
    let mut arb = scenario::find("multi-region-arbitrage").unwrap();
    arb.workload.small_tasks = true;
    // Same world restricted to the home region only.
    let mut home = arb.clone();
    home.name = "multi-region-home-only".into();
    home.market.regions.truncate(1);
    home.market.routing = scenario::RoutingSpec::Home;

    let seed = scenario::derive_run_seed(13, "arb-vs-home", 0);
    let a = scenario::run_scenario_once(&arb, seed, Some(60)).unwrap();
    let h = scenario::run_scenario_once(&home, seed, Some(60)).unwrap();
    // The composite price is a slot-wise lower bound of the home region's,
    // so availability at any bid can only improve.
    assert!(
        a.availability_hi >= h.availability_hi - 1e-9,
        "arbitrage availability {} vs home {}",
        a.availability_hi,
        h.availability_hi
    );
}

/// The acceptance golden-file contract: a one-offer `MarketView` world
/// produces the byte-identical report JSON whether its market is declared
/// the legacy way (single region, home routing) or flattened through the
/// view machinery with per-task routing enabled — the degenerate case must
/// be indistinguishable from the pre-refactor single-trace path.
#[test]
fn one_offer_view_report_is_byte_identical_to_single_trace_path() {
    let mut legacy = scenario::find("paper-default").unwrap();
    legacy.workload.small_tasks = true;
    // The same world but forced through the routed machinery: cheapest
    // routing over its single offer.
    let mut routed = legacy.clone();
    routed.market.routing = scenario::RoutingSpec::Cheapest;
    // Same name on purpose: the seed derivation and report grouping must
    // see the same world, just a different market declaration.
    let report_of = |spec: &ScenarioSpec| {
        let outs = scenario::run_batch(
            &[spec.clone()],
            &BatchOptions {
                seeds: 2,
                base_seed: 99,
                threads: 2,
                jobs_override: Some(12),
                telemetry: Default::default(),
            },
        )
        .unwrap();
        scenario::report_json(&outs, 2, 99, true).pretty()
    };
    assert_eq!(report_of(&legacy), report_of(&routed));
}

/// The new capacity/routing worlds keep the runner's determinism contract:
/// byte-identical reports for --threads 1 vs 8, and capacity exhaustion
/// actually shows up in the spillover world's offer shares.
#[test]
fn capacity_and_routing_worlds_are_deterministic_and_route() {
    let mut specs: Vec<ScenarioSpec> = ["capacity-crunch", "multi-region-routed"]
        .iter()
        .map(|n| scenario::find(n).unwrap())
        .collect();
    for s in &mut specs {
        s.workload.small_tasks = true;
    }
    let report_at = |threads: usize| {
        let outs = scenario::run_batch(
            &specs,
            &BatchOptions {
                seeds: 2,
                base_seed: 31,
                threads,
                jobs_override: Some(16),
                telemetry: Default::default(),
            },
        )
        .unwrap();
        (scenario::report_json(&outs, 2, 31, true).pretty(), outs)
    };
    let (one, outs) = report_at(1);
    let (eight, _) = report_at(8);
    assert_eq!(one, eight, "thread-count determinism broke for routed worlds");
    for o in &outs {
        assert!(
            !o.offer_shares.is_empty(),
            "{}: routed world reported no offer shares",
            o.scenario
        );
        let total: f64 = o.offer_shares.iter().map(|(_, s)| s).sum();
        assert!((total - 1.0).abs() < 1e-6, "{}: shares {total}", o.scenario);
    }
    // The capacity-crunch primary region is capped at 16 concurrent spot
    // instances: with 16 jobs in flight some work must leave it.
    let crunch = outs.iter().find(|o| o.scenario == "capacity-crunch").unwrap();
    let primary = crunch
        .offer_shares
        .iter()
        .find(|(l, _)| l.starts_with("primary"))
        .unwrap();
    assert!(
        primary.1 < 1.0 - 1e-9,
        "primary absorbed everything; capacity cap never bound"
    );
}
