//! End-to-end scenario engine tests: registry health, JSON round-trips,
//! thread-count-independent determinism of the report, and CSV replay
//! through the full stack (loader → PriceTrace → coordinator → report).

use dagcloud::scenario::{self, BatchOptions, PriceSpec, ScenarioSpec};
use dagcloud::util::prop::{for_all, Config as PropConfig};

/// The registry at smoke size (small chains keep runtime in seconds).
fn smoke_specs() -> Vec<ScenarioSpec> {
    let mut specs = scenario::builtins();
    for s in &mut specs {
        s.workload.small_tasks = true;
    }
    specs
}

#[test]
fn every_builtin_parses_roundtrips_and_completes_a_run() {
    for spec in smoke_specs() {
        spec.validate().unwrap_or_else(|e| panic!("{}: {e}", spec.name));
        // JSON round-trip: value-level and text-level.
        let j = spec.to_json();
        let back = ScenarioSpec::from_json(&j)
            .unwrap_or_else(|e| panic!("{}: from_json: {e}", spec.name));
        assert_eq!(back, spec, "{}: JSON value round-trip", spec.name);
        let re = ScenarioSpec::parse(&j.pretty())
            .unwrap_or_else(|e| panic!("{}: parse: {e}", spec.name));
        assert_eq!(re, spec, "{}: JSON text round-trip", spec.name);

        // A small run completes with sane metrics.
        let seed = scenario::derive_run_seed(7, &spec.name, 0);
        let out = scenario::run_scenario_once(&spec, seed, Some(16))
            .unwrap_or_else(|e| panic!("{}: run: {e}", spec.name));
        assert_eq!(out.jobs, 16, "{}", spec.name);
        assert!(
            out.average_unit_cost.is_finite() && out.average_unit_cost >= 0.0,
            "{}: alpha {}",
            spec.name,
            out.average_unit_cost
        );
        let shares = out.so_share + out.spot_share + out.od_share;
        assert!(
            (shares - 1.0).abs() < 1e-6,
            "{}: work shares sum to {shares}",
            spec.name
        );
        assert!(
            (0.0..=1.0).contains(&out.availability_hi),
            "{}: availability {}",
            spec.name,
            out.availability_hi
        );
    }
}

/// The `repro scenarios` determinism contract: the report JSON is
/// byte-identical for `--threads 1` vs `--threads 8` on the same seed.
/// Property-tested across base seeds and scenario pairs.
#[test]
fn report_json_is_byte_identical_across_thread_counts() {
    let all = smoke_specs();
    for_all(PropConfig::cases(4).seed(0xD06), |rng| {
        let base_seed = rng.next_u64() % 1000;
        // A random pair of *distinct* worlds keeps each case fast while
        // covering the registry across cases (duplicate names are a batch
        // shape the CLI rejects).
        let i = rng.below(all.len() as u64) as usize;
        let j = (i + 1 + rng.below(all.len() as u64 - 1) as usize) % all.len();
        let specs: Vec<ScenarioSpec> = vec![all[i].clone(), all[j].clone()];
        let report_at = |threads: usize| {
            let outs = scenario::run_batch(
                &specs,
                &BatchOptions {
                    seeds: 2,
                    base_seed,
                    threads,
                    jobs_override: Some(10),
                },
            )
            .map_err(|e| e.to_string())?;
            Ok::<String, String>(scenario::report_json(&outs, 2, base_seed, true).pretty())
        };
        let single = report_at(1)?;
        let eight = report_at(8)?;
        if single != eight {
            return Err(format!(
                "report differs between --threads 1 and --threads 8 \
                 (base_seed {base_seed}, scenarios {:?})",
                specs.iter().map(|s| s.name.clone()).collect::<Vec<_>>()
            ));
        }
        Ok(())
    });
}

/// CSV replay end-to-end: loader → PriceTrace → coordinator → report, with
/// the market structure visible in the learned outcome.
#[test]
fn replayed_trace_scenario_reflects_its_market() {
    let mut spec = scenario::find("replayed-trace").unwrap();
    spec.workload.small_tasks = true;
    match &spec.market.regions[0].price {
        PriceSpec::Replay(r) => assert!(r.csv.is_some()),
        other => panic!("expected replay, got {other:?}"),
    }
    let out =
        scenario::run_scenario_once(&spec, scenario::derive_run_seed(7, &spec.name, 0), Some(40))
            .unwrap();
    // The sample trace's calm baseline sits near 0.15 with surge regimes:
    // the top grid bid (0.3) wins most slots, the bottom one (0.18) only
    // the calm dips.
    assert!(
        out.availability_hi > 0.5,
        "availability at bid 0.3: {}",
        out.availability_hi
    );
    assert!(
        out.availability_hi >= out.availability_lo,
        "bid monotonicity: {} < {}",
        out.availability_hi,
        out.availability_lo
    );
    // Learned cost must beat pure on-demand (alpha = 1.0) on this market.
    assert!(
        out.average_unit_cost < 1.0,
        "alpha {}",
        out.average_unit_cost
    );
    assert!(out.spot_share > 0.0);
}

#[test]
fn multi_region_arbitrage_never_loses_to_home_region() {
    let mut arb = scenario::find("multi-region-arbitrage").unwrap();
    arb.workload.small_tasks = true;
    // Same world restricted to the home region only.
    let mut home = arb.clone();
    home.name = "multi-region-home-only".into();
    home.market.regions.truncate(1);
    home.market.arbitrage = false;

    let seed = scenario::derive_run_seed(13, "arb-vs-home", 0);
    let a = scenario::run_scenario_once(&arb, seed, Some(60)).unwrap();
    let h = scenario::run_scenario_once(&home, seed, Some(60)).unwrap();
    // The composite price is a slot-wise lower bound of the home region's,
    // so availability at any bid can only improve.
    assert!(
        a.availability_hi >= h.availability_hi - 1e-9,
        "arbitrage availability {} vs home {}",
        a.availability_hi,
        h.availability_hi
    );
}
