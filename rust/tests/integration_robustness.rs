//! Robustness-engine acceptance tests: derivation is a byte-deterministic
//! pure function of `(bases, total, seed, params)` even at 1000-world
//! population scale; a derived-population fleet run merges to identical
//! bytes for any shard partition and thread count; and the promotion
//! gate's verdict document is stable under report-row reordering.

use dagcloud::fleet::FleetAccumulator;
use dagcloud::robustness::{
    derive_population, derive_world, derivation_plan, evaluate_gate, gate_json, DeriveParams,
    GateConfig, Operator,
};
use dagcloud::scenario::{self, BatchOptions, ScenarioOutcome, ScenarioSpec};
use dagcloud::util::prop::{for_all, Config as PropConfig};

fn bases(names: &[&str]) -> Vec<ScenarioSpec> {
    names.iter().map(|n| scenario::find(n).unwrap()).collect()
}

/// The ISSUE's scale acceptance: deriving >= 1000 worlds is deterministic
/// byte-for-byte — every derived spec serializes to identical JSON on a
/// second derivation, names are unique, and every spec validates.
#[test]
fn thousand_world_derivation_is_byte_deterministic() {
    let b = bases(&["paper-default", "capacity-crunch"]);
    let p = DeriveParams::default();
    let pop1 = derive_population(&b, 1000, 99, &p).unwrap();
    let pop2 = derive_population(&b, 1000, 99, &p).unwrap();
    assert_eq!(pop1.len(), 1000);
    for (a, c) in pop1.iter().zip(&pop2) {
        assert_eq!(a.to_json().pretty(), c.to_json().pretty(), "world {}", a.name);
    }
    let mut names: Vec<&str> = pop1.iter().map(|s| s.name.as_str()).collect();
    names.sort_unstable();
    names.dedup();
    assert_eq!(names.len(), 1000, "derived names collide");
    for s in &pop1 {
        s.validate().unwrap();
    }
    // The census the CLI prints covers exactly the dealt population.
    let plan = derivation_plan(&b, 1000);
    assert_eq!(plan.iter().map(|(_, _, n)| n).sum::<usize>(), 1000);
    // A different seed derives a genuinely different population.
    let other = derive_population(&b, 1000, 100, &p).unwrap();
    assert!(
        pop1.iter().zip(&other).any(|(a, c)| a.market != c.market),
        "seed does not influence derivation"
    );
}

/// Per-world determinism across call paths: deriving a single world
/// directly equals the same world inside the dealt population.
#[test]
fn direct_and_population_derivation_agree() {
    let b = bases(&["paper-default", "capacity-crunch"]);
    let p = DeriveParams::default();
    let pop = derive_population(&b, 18, 7, &p).unwrap();
    // paper-default skips capdrop -> 4 + 5 = 9 pairs; world 0 of the
    // population is (paper-default, boot) replica 0, world 9 replica 1.
    let direct0 = derive_world(&b[0], Operator::BlockBootstrap, 0, 7, &p).unwrap();
    let direct1 = derive_world(&b[0], Operator::BlockBootstrap, 1, 7, &p).unwrap();
    assert_eq!(pop[0], direct0);
    assert_eq!(pop[9], direct1);
}

fn run_cells(specs: &[ScenarioSpec], threads: usize) -> Vec<ScenarioOutcome> {
    scenario::run_batch(
        specs,
        &BatchOptions {
            seeds: 1,
            base_seed: 41,
            threads,
            jobs_override: Some(8),
            telemetry: Default::default(),
        },
    )
    .unwrap()
}

fn fleet_and_gate_bytes(shards: &[Vec<ScenarioOutcome>]) -> (String, String) {
    let mut acc = FleetAccumulator::new();
    for shard in shards {
        acc.absorb(&scenario::report_json(shard, 1, 41, true)).unwrap();
    }
    let fleet = acc.fleet_json(None).unwrap().pretty();
    let gate = gate_json(&evaluate_gate(
        &acc.canonical_outcomes(),
        &GateConfig::default(),
    ))
    .pretty();
    (fleet, gate)
}

/// A derived-population fleet run is byte-identical across thread counts
/// and any shard partition / merge order — the derived worlds are plain
/// specs, so the fleet layer's invariance carries over, now including the
/// quantile/CVaR robustness section and the gate document.
#[test]
fn derived_population_fleet_is_invariant_under_shards_and_threads() {
    let mut b = bases(&["paper-default", "calm-surge-markov"]);
    for s in &mut b {
        s.workload.small_tasks = true;
    }
    let mut specs = b.clone();
    specs.extend(derive_population(&b, 6, 13, &DeriveParams::default()).unwrap());

    let all = run_cells(&specs, 4);
    assert_eq!(all.len(), 8, "2 bases + 6 derived, 1 seed each");
    // Thread count must not leak into any cell.
    let single_threaded = run_cells(&specs, 1);
    assert_eq!(all, single_threaded);

    let (fleet_ref, gate_ref) = fleet_and_gate_bytes(&[all.clone()]);
    for_all(PropConfig::cases(8).seed(0xB0B5), |rng| {
        let k = rng.range_inclusive(1, 4) as usize;
        let mut shards: Vec<Vec<ScenarioOutcome>> = vec![Vec::new(); k];
        for o in &all {
            shards[rng.below(k as u64) as usize].push(o.clone());
        }
        let mut shards: Vec<Vec<ScenarioOutcome>> =
            shards.into_iter().filter(|s| !s.is_empty()).collect();
        for s in &mut shards {
            rng.shuffle(s);
        }
        rng.shuffle(&mut shards);
        let (fleet, gate) = fleet_and_gate_bytes(&shards);
        if fleet != fleet_ref {
            return Err(format!("fleet.json differs for a {}-shard partition", shards.len()));
        }
        if gate != gate_ref {
            return Err(format!(
                "robustness.json differs for a {}-shard partition",
                shards.len()
            ));
        }
        Ok(())
    });

    // The derived fault worlds must be visible to the gate as a regime.
    let report = evaluate_gate(&all, &GateConfig::default());
    assert!(
        report.regimes.iter().any(|(t, _)| t == "fault"),
        "expected a fault regime from spike/gap/capdrop derivations, got {:?}",
        report.regimes
    );
    assert!(report.worlds == 8);
}
