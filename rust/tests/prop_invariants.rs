//! Cross-module property tests on the crate's key invariants, using the
//! in-repo mini property framework (`util::prop`).

use dagcloud::learning::counterfactual::{CfSpec, CounterfactualJob, S_MAX};
use dagcloud::learning::sweep::SweepContext;
use dagcloud::market::{PriceTrace, SelfOwnedPool, SpotModel, SLOTS_PER_UNIT};
use dagcloud::policy::dealloc::{dealloc, expected_spot_workload, windows_to_deadlines};
use dagcloud::policy::{benchmark_bids, policy_set_full, Policy};
use dagcloud::sim::executor::{execute_chain, ChainStrategy, SelfOwnedRule};
use dagcloud::util::prop::{for_all, Config};
use dagcloud::util::rng::Pcg32;
use dagcloud::workload::{transform, ChainJob, ChainTask, DagJob, GeneratorConfig, JobStream, Task};

fn random_chain(rng: &mut Pcg32, max_l: usize) -> ChainJob {
    let l = rng.range_inclusive(1, max_l as u64) as usize;
    let tasks: Vec<ChainTask> = (0..l)
        .map(|_| ChainTask::new(rng.uniform(0.2, 5.0), rng.uniform(1.0, 64.0)))
        .collect();
    let makespan: f64 = tasks.iter().map(|t| t.min_exec_time()).sum();
    ChainJob::new(0, 0.0, makespan * rng.uniform(1.0, 3.0), tasks)
}

#[test]
fn prop_dealloc_windows_feasible_and_tiling() {
    for_all(Config::cases(300).seed(1001), |rng| {
        let job = random_chain(rng, 12);
        let beta = rng.uniform(0.05, 1.0);
        let alloc = dealloc(&job, beta);
        let total: f64 = alloc.sizes.iter().sum();
        if (total - job.window()).abs() > 1e-9 * job.window().max(1.0) {
            return Err(format!("windows sum {total} != window {}", job.window()));
        }
        let dl = windows_to_deadlines(&job, &alloc);
        let mut prev = job.arrival;
        for (i, d) in dl.iter().enumerate() {
            if *d < prev - 1e-12 {
                return Err(format!("deadline {i} decreases: {d} < {prev}"));
            }
            prev = *d;
        }
        if (dl.last().unwrap() - job.deadline).abs() > 1e-9 {
            return Err("last deadline != job deadline".into());
        }
        Ok(())
    });
}

#[test]
fn prop_dealloc_spot_workload_bounded_by_total() {
    for_all(Config::cases(300).seed(1002), |rng| {
        let job = random_chain(rng, 10);
        let beta = rng.uniform(0.05, 1.0);
        let zo = expected_spot_workload(&job, &dealloc(&job, beta));
        if zo < -1e-9 || zo > job.total_work() + 1e-9 {
            return Err(format!("z^o {zo} outside [0, {}]", job.total_work()));
        }
        Ok(())
    });
}

#[test]
fn prop_transform_preserves_structure() {
    let cfg = GeneratorConfig::paper_default();
    for_all(Config::cases(60).seed(1003), |rng| {
        let mut stream = JobStream::new(cfg.clone(), rng.next_u64());
        let dag = stream.next_job();
        let chain = transform(&dag);
        if (chain.total_work() - dag.total_work()).abs() > 1e-6 * dag.total_work() {
            return Err("work not conserved".into());
        }
        if (chain.min_makespan() - dag.critical_path()).abs() > 1e-6 {
            return Err("critical path changed".into());
        }
        // Parallelism of every pseudo-task is at least the max δ of some
        // task running in that interval, hence ≥ min task δ and ≤ Σ δ.
        let max_total: f64 = dag.tasks.iter().map(|t| t.parallelism).sum();
        for t in &chain.tasks {
            if t.parallelism <= 0.0 || t.parallelism > max_total + 1e-9 {
                return Err(format!("pseudo-task δ {} outside (0, {max_total}]", t.parallelism));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_executor_work_conservation_and_deadline() {
    for_all(Config::cases(200).seed(1004), |rng| {
        let job = random_chain(rng, 8);
        let horizon = job.deadline + 1.0;
        let trace = PriceTrace::generate(SpotModel::paper_default(), horizon, rng.next_u64());
        let beta = rng.uniform(0.2, 1.0);
        let windows = dealloc(&job, beta);
        let mut pool = SelfOwnedPool::new(
            rng.range_inclusive(0, 30) as u32,
            horizon,
            1.0 / SLOTS_PER_UNIT as f64,
        );
        let has_pool = pool.capacity() > 0;
        let strategy = ChainStrategy::Windows {
            windows: &windows,
            selfowned: if has_pool {
                if rng.chance(0.5) {
                    SelfOwnedRule::Rule12 { beta0: rng.uniform(0.1, 0.8) }
                } else {
                    SelfOwnedRule::Naive
                }
            } else {
                SelfOwnedRule::None
            },
            bid: rng.uniform(0.12, 0.35),
        };
        let o = execute_chain(&job, &strategy, &trace, Some(&mut pool), 1.0);
        if !o.met_deadline {
            return Err(format!("deadline missed: {} > {}", o.finish, job.deadline));
        }
        let w = o.ledger.total_work();
        if (w - job.total_work()).abs() > 1e-6 * job.total_work().max(1.0) {
            return Err(format!("work {w} != {}", job.total_work()));
        }
        // Cost is bounded by running everything on-demand.
        if o.cost() > job.total_work() + 1e-6 {
            return Err(format!("cost {} above all-OD bound", o.cost()));
        }
        Ok(())
    });
}

#[test]
fn prop_counterfactual_bid_monotonicity() {
    // A higher bid wins a superset of slots, so z̃ declines at least as
    // fast and the turning point cannot fire earlier: on-demand work is
    // monotone non-increasing in the bid. (Total COST is *not* monotone —
    // a higher bid may buy expensive early slots in place of cheap later
    // ones; that non-monotonicity is exactly why the bid is learned in
    // Experiment 4.) Cost stays within the all-on-demand bound and pays at
    // most the bid per unit of spot work.
    for_all(Config::cases(150).seed(1005), |rng| {
        let job = random_chain(rng, 6);
        let trace =
            PriceTrace::generate(SpotModel::paper_default(), job.deadline + 1.0, rng.next_u64());
        let (prices, dt) = trace.resample_window(job.arrival, job.deadline, S_MAX);
        let n = prices.len();
        let cf = CounterfactualJob::from_job(&job, prices, dt, vec![0.0; n], 1.0);
        let beta = rng.uniform(0.3, 1.0);
        let b1 = rng.uniform(0.12, 0.25);
        let b2 = rng.uniform(b1, 0.4);
        let (c1, sw1, ow1, _) = cf.eval_policy(&Policy::new(beta, None, b1), false);
        let (c2, sw2, ow2, _) = cf.eval_policy(&Policy::new(beta, None, b2), false);
        if ow2 > ow1 + 1e-6 {
            return Err(format!("bid ↑ raised OD work: {ow1} -> {ow2}"));
        }
        if sw2 + 1e-6 < sw1 {
            return Err(format!("bid ↑ lowered spot work: {sw1} -> {sw2}"));
        }
        for (c, sw, ow, b) in [(c1, sw1, ow1, b1), (c2, sw2, ow2, b2)] {
            if c > b * sw + ow + 1e-6 {
                return Err(format!("cost {c} above bid·spot + od bound"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_sweep_engine_matches_naive_walk_end_to_end() {
    // The structure-sharing sweep engine against the naive slot walk on
    // jobs marshalled the way the coordinator does it — realized traces,
    // pool availabilities, and resampled windows, including windows forced
    // through the S_MAX-style truncation (coarse dt).
    for_all(Config::cases(40).seed(1009), |rng| {
        let job = random_chain(rng, 10);
        let trace = PriceTrace::generate(
            SpotModel::paper_default(),
            job.deadline + 1.0,
            rng.next_u64(),
        );
        // Half the cases shrink the resample budget far below the native
        // slot count, exercising the coarsened-window regime.
        let max_slots = if rng.chance(0.5) {
            rng.range_inclusive(4, 64) as usize
        } else {
            S_MAX
        };
        let (prices, dt) = trace.resample_window(job.arrival, job.deadline, max_slots);
        let n = prices.len();
        let has_pool = rng.chance(0.7);
        let navail: Vec<f64> = (0..n)
            .map(|_| if has_pool { rng.range_inclusive(0, 20) as f64 } else { 0.0 })
            .collect();
        let cf = CounterfactualJob::from_job(&job, prices, dt, navail, 1.0);
        let mut ctx = SweepContext::new(&cf, has_pool);
        let mut specs: Vec<CfSpec> =
            policy_set_full().into_iter().map(CfSpec::Proposed).collect();
        specs.extend(benchmark_bids().into_iter().map(|bid| CfSpec::EvenNaive { bid }));
        for spec in &specs {
            let a = cf.eval_spec(spec, has_pool);
            let b = ctx.eval_spec(spec);
            for (x, y) in [(a.0, b.0), (a.1, b.1), (a.2, b.2), (a.3, b.3)] {
                if (x - y).abs() > 1e-9 * x.abs().max(1.0) {
                    return Err(format!("sweep diverges on {spec:?}: {a:?} vs {b:?}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_pool_reservations_never_oversubscribe() {
    for_all(Config::cases(100).seed(1006), |rng| {
        let cap = rng.range_inclusive(1, 20) as u32;
        let mut pool = SelfOwnedPool::new(cap, 50.0, 0.25);
        for _ in 0..50 {
            let t0 = rng.uniform(0.0, 45.0);
            let t1 = t0 + rng.uniform(0.1, 4.0);
            let want = rng.range_inclusive(0, cap as u64 + 5) as u32;
            let avail = pool.available_over(t0, t1);
            let ok = pool.reserve(want, t0, t1);
            if want <= avail && !ok {
                return Err(format!("reserve {want} <= avail {avail} refused"));
            }
            if want > avail && ok {
                return Err(format!("reserve {want} > avail {avail} accepted"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_dag_generator_always_valid() {
    for_all(Config::cases(60).seed(1007), |rng| {
        let mut stream = JobStream::new(GeneratorConfig::paper_default(), rng.next_u64());
        let job: DagJob = stream.next_job();
        job.validate().map_err(|e| format!("invalid job: {e}"))?;
        if job.window() < job.critical_path() - 1e-9 {
            return Err("infeasible deadline generated".into());
        }
        Ok(())
    });
}

#[test]
fn prop_single_task_dag_equals_chain() {
    for_all(Config::cases(100).seed(1008), |rng| {
        let size = rng.uniform(0.5, 10.0);
        let para = rng.uniform(1.0, 32.0);
        let dag = DagJob::new(
            7,
            0.0,
            (size / para) * rng.uniform(1.1, 3.0),
            vec![Task::new(size, para)],
            vec![],
        );
        let chain = transform(&dag);
        if chain.num_tasks() != 1 {
            return Err(format!("single task became {} pseudo-tasks", chain.num_tasks()));
        }
        if (chain.tasks[0].size - size).abs() > 1e-9 {
            return Err("size changed".into());
        }
        Ok(())
    });
}

/// ISSUE-3 satellite: the arbitrage composite's price is slot-wise ≤ every
/// region's price (and its od price is the region minimum) on randomized
/// traces — the "free placement lower bound" the routed worlds are
/// measured against.
#[test]
fn prop_arbitrage_composite_is_slotwise_lower_bound() {
    use dagcloud::market::multi::{arbitrage_composite, RegionMarket};
    for_all(Config::cases(150).seed(1009), |rng| {
        let n_regions = rng.range_inclusive(1, 5) as usize;
        let slot_len = 1.0 / SLOTS_PER_UNIT as f64;
        let regions: Vec<RegionMarket> = (0..n_regions)
            .map(|k| {
                let n = rng.range_inclusive(1, 200) as usize;
                RegionMarket {
                    name: format!("r{k}"),
                    od_price: rng.uniform(0.5, 2.0),
                    trace: PriceTrace::from_prices(
                        (0..n).map(|_| rng.uniform(0.05, 1.5)).collect(),
                        slot_len,
                    ),
                }
            })
            .collect();
        let (composite, od) = arbitrage_composite(&regions).map_err(|e| e.to_string())?;
        let max_slots = regions.iter().map(|r| r.trace.num_slots()).max().unwrap();
        if composite.num_slots() != max_slots {
            return Err(format!(
                "composite spans {} slots, longest region {max_slots}",
                composite.num_slots()
            ));
        }
        for s in 0..max_slots {
            let c = composite.price_of_slot(s);
            for r in &regions {
                // price_of_slot clamps past-the-end lookups, matching the
                // composite's persist-last-price semantics.
                if c > r.trace.price_of_slot(s) + 1e-15 {
                    return Err(format!(
                        "slot {s}: composite {c} above region '{}' price {}",
                        r.name,
                        r.trace.price_of_slot(s)
                    ));
                }
            }
            if !regions.iter().any(|r| r.trace.price_of_slot(s) == c) {
                return Err(format!("slot {s}: composite {c} matches no region"));
            }
        }
        let od_min = regions.iter().map(|r| r.od_price).fold(f64::INFINITY, f64::min);
        if od != od_min {
            return Err(format!("composite od {od} != region min {od_min}"));
        }
        Ok(())
    });
}

/// ISSUE-10 satellite: the capacity replay is never optimistic about
/// itself — `replayed_mean ≥ free_mean` (gap ≥ 0) on randomized capped
/// worlds, because displaced units are surcharged `max(0, od − spot)`
/// term-by-term; and on fully uncapped worlds nothing displaces, so the
/// gap is exactly zero.
#[test]
fn prop_capacity_replay_gap_nonnegative_and_zero_when_uncapped() {
    use dagcloud::learning::replay_specs;
    use dagcloud::market::MarketOffer;
    use dagcloud::policy::routing::RoutingPolicy;
    for_all(Config::cases(80).seed(1011), |rng| {
        let mut jobs = Vec::new();
        for i in 0..rng.range_inclusive(2, 8) {
            let a = rng.uniform(0.0, 3.0);
            let tasks = vec![ChainTask::new(rng.uniform(0.5, 4.0), rng.uniform(1.0, 8.0))];
            let makespan: f64 = tasks.iter().map(|t| t.min_exec_time()).sum();
            jobs.push(ChainJob::new(i as u64, a, a + makespan * rng.uniform(1.05, 2.5), tasks));
        }
        jobs.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
        let horizon = jobs.iter().map(|j| j.deadline).fold(1.0, f64::max) + 1.0;
        let n = (horizon * SLOTS_PER_UNIT as f64) as usize + 2;
        let dt = 1.0 / SLOTS_PER_UNIT as f64;
        let capped = rng.chance(0.5);
        let mk_offer = |rng: &mut Pcg32, name: &str, od: f64| MarketOffer {
            region: name.into(),
            instance_type: "default".into(),
            od_price: od,
            trace: PriceTrace::from_prices(
                (0..n)
                    .map(|_| {
                        if rng.chance(0.5) {
                            rng.uniform(0.1, 0.3)
                        } else {
                            rng.uniform(0.4, 1.2)
                        }
                    })
                    .collect(),
                dt,
            ),
            capacity: if capped { Some(rng.range_inclusive(1, 5) as u32) } else { None },
        };
        let offer_a = mk_offer(rng, "a", 1.0);
        let od_b = rng.uniform(1.0, 1.4);
        let offers = vec![offer_a, mk_offer(rng, "b", od_b)];
        let view = dagcloud::market::MarketView::new(offers).map_err(|e| e.to_string())?;
        let specs = vec![
            CfSpec::Proposed(dagcloud::policy::Policy::new(
                rng.uniform(0.3, 1.0),
                None,
                rng.uniform(0.15, 0.5),
            )),
            CfSpec::EvenNaive { bid: rng.uniform(0.15, 0.5) },
        ];
        let reps = replay_specs(&jobs, &specs, &view, RoutingPolicy::CheapestFeasible, false);
        if reps.len() != specs.len() {
            return Err(format!("{} replays for {} specs", reps.len(), specs.len()));
        }
        for r in &reps {
            if !r.free_mean.is_finite() || !r.replayed_mean.is_finite() {
                return Err(format!("non-finite replay: {r:?}"));
            }
            if r.gap() < 0.0 {
                return Err(format!("negative optimism gap: {r:?}"));
            }
            if !capped && r.gap() != 0.0 {
                return Err(format!("uncapped world displaced work: {r:?}"));
            }
        }
        Ok(())
    });
}

/// ISSUE-3 satellite: a one-offer `MarketView` reproduces the legacy
/// single-trace executor cost exactly (1e-12) on randomized traces — the
/// degenerate case of the capacity-aware refactor is the old code path.
#[test]
fn prop_one_offer_view_reproduces_legacy_executor_cost() {
    use dagcloud::market::{CapacityLedger, MarketView};
    use dagcloud::policy::routing::RoutingPolicy;
    use dagcloud::sim::executor::execute_chain_routed;
    for_all(Config::cases(150).seed(1010), |rng| {
        let job = random_chain(rng, 8);
        let beta = rng.uniform(0.1, 1.0);
        let windows = dealloc(&job, beta);
        let bid = rng.uniform(0.1, 0.4);
        let od_price = rng.uniform(0.8, 1.5);
        let horizon = job.deadline + 1.0;
        let n = (horizon * SLOTS_PER_UNIT as f64) as usize + 2;
        let trace = PriceTrace::from_prices(
            (0..n)
                .map(|_| {
                    if rng.chance(0.5) {
                        rng.uniform(0.1, 0.3)
                    } else {
                        rng.uniform(0.5, 1.2)
                    }
                })
                .collect(),
            1.0 / SLOTS_PER_UNIT as f64,
        );
        let legacy = execute_chain(
            &job,
            &ChainStrategy::Windows {
                windows: &windows,
                selfowned: SelfOwnedRule::None,
                bid,
            },
            &trace,
            None,
            od_price,
        );
        let view = MarketView::single(trace.clone(), od_price);
        for routing in [
            RoutingPolicy::Home,
            RoutingPolicy::CheapestFeasible,
            RoutingPolicy::Spillover,
        ] {
            let mut cap = CapacityLedger::new(&view, horizon);
            let routed = execute_chain_routed(
                &job,
                &windows,
                SelfOwnedRule::None,
                bid,
                &view,
                &mut cap,
                routing,
                None,
            );
            let (a, b) = (routed.outcome.cost(), legacy.cost());
            if (a - b).abs() > 1e-12 * b.abs().max(1.0) {
                return Err(format!("{routing:?}: routed cost {a} != legacy {b}"));
            }
            if routed.outcome.finish != legacy.finish {
                return Err(format!(
                    "{routing:?}: finish {} != {}",
                    routed.outcome.finish, legacy.finish
                ));
            }
        }
        Ok(())
    });
}
