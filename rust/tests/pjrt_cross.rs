//! Cross-implementation check: the AOT-compiled PJRT kernel must agree
//! with the native Rust counterfactual model on identical inputs.
//!
//! Requires `make artifacts` to have run (skips with a message otherwise —
//! CI runs `make test`, which builds artifacts first).

use dagcloud::learning::counterfactual::{eval_grid_native, CounterfactualJob, S_MAX};
use dagcloud::market::{PriceTrace, SpotModel};
use dagcloud::policy::{policy_set_full, policy_set_spot_only, Policy};
use dagcloud::runtime::ArtifactRuntime;
use dagcloud::util::rng::Pcg32;
use dagcloud::workload::{transform, ChainJob, ChainTask, GeneratorConfig, JobStream};

fn runtime() -> Option<ArtifactRuntime> {
    match ArtifactRuntime::load_default() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP pjrt_cross: artifacts not available ({e})");
            None
        }
    }
}

fn cf_for(job: &ChainJob, trace: &PriceTrace, navail: f64) -> CounterfactualJob {
    let (prices, dt) = trace.resample_window(job.arrival, job.deadline, S_MAX);
    let n = prices.len();
    CounterfactualJob::from_job(job, prices, dt, vec![navail; n], 1.0)
}

fn assert_close(native: &[f64], kernel: &[f64], scale: f64, what: &str) {
    assert_eq!(native.len(), kernel.len());
    for (i, (n, k)) in native.iter().zip(kernel).enumerate() {
        let tol = 2e-3 * scale.max(1.0) + 2e-3 * n.abs();
        assert!(
            (n - k).abs() <= tol,
            "{what}[{i}]: native {n} vs kernel {k} (tol {tol})"
        );
    }
}

#[test]
fn kernel_matches_native_on_paper_example() {
    let Some(rt) = runtime() else { return };
    let job = ChainJob::paper_example();
    let trace = PriceTrace::generate(SpotModel::paper_default(), 6.0, 99);
    let cf = cf_for(&job, &trace, 0.0);
    let grid = policy_set_spot_only();
    let native = eval_grid_native(&cf, &grid, false);
    let kernel = rt.policy_cost.eval(&cf, &grid, false).expect("kernel eval");
    let scale = job.total_work();
    assert_close(&native.costs, &kernel.costs, scale, "cost");
    assert_close(&native.spot_work, &kernel.spot_work, scale, "spot");
    assert_close(&native.od_work, &kernel.od_work, scale, "od");
    assert_close(&native.so_work, &kernel.so_work, scale, "so");
}

#[test]
fn kernel_matches_native_with_pool_full_grid() {
    let Some(rt) = runtime() else { return };
    let job = ChainJob::paper_example();
    let trace = PriceTrace::generate(SpotModel::paper_default(), 6.0, 7);
    let cf = cf_for(&job, &trace, 6.0);
    let grid = policy_set_full();
    let native = eval_grid_native(&cf, &grid, true);
    let kernel = rt.policy_cost.eval(&cf, &grid, true).expect("kernel eval");
    let scale = job.total_work();
    assert_close(&native.costs, &kernel.costs, scale, "cost");
    assert_close(&native.so_work, &kernel.so_work, scale, "so");
}

#[test]
fn kernel_matches_native_on_generated_workload() {
    let Some(rt) = runtime() else { return };
    let mut stream = JobStream::new(GeneratorConfig::paper_default(), 5);
    let mut rng = Pcg32::new(17);
    let grid = policy_set_full();
    for _ in 0..8 {
        let dag = stream.next_job();
        let job = transform(&dag);
        let horizon = job.deadline + 1.0;
        let trace = PriceTrace::generate(SpotModel::paper_default(), horizon, rng.next_u64());
        let navail = rng.range_inclusive(0, 40) as f64;
        let cf = cf_for(&job, &trace, navail);
        let native = eval_grid_native(&cf, &grid, navail > 0.0);
        let kernel = rt
            .policy_cost
            .eval(&cf, &grid, navail > 0.0)
            .expect("kernel eval");
        // Large jobs accumulate f32 error across thousands of slots; the
        // tolerance scales with total work.
        let scale = job.total_work();
        assert_close(&native.costs, &kernel.costs, scale, "cost");
    }
}

#[test]
fn kernel_handles_long_chains_near_l_max() {
    let Some(rt) = runtime() else { return };
    let mut rng = Pcg32::new(23);
    let tasks: Vec<ChainTask> = (0..120)
        .map(|_| ChainTask::new(rng.uniform(0.3, 2.0), [8.0, 64.0][rng.below(2) as usize]))
        .collect();
    let makespan: f64 = tasks.iter().map(|t| t.min_exec_time()).sum();
    let job = ChainJob::new(1, 0.0, makespan * 1.7, tasks);
    let trace = PriceTrace::generate(SpotModel::paper_default(), job.deadline + 1.0, 3);
    let cf = cf_for(&job, &trace, 20.0);
    let grid = policy_set_full();
    let native = eval_grid_native(&cf, &grid, true);
    let kernel = rt.policy_cost.eval(&cf, &grid, true).expect("kernel eval");
    assert_close(&native.costs, &kernel.costs, job.total_work(), "cost");
}

#[test]
fn tola_update_kernel_matches_native() {
    let Some(rt) = runtime() else { return };
    let Some(tk) = rt.tola_update.as_ref() else {
        eprintln!("SKIP: tola_update artifact missing");
        return;
    };
    let mut rng = Pcg32::new(31);
    let n = 175;
    let mut w: Vec<f64> = (0..n).map(|_| rng.uniform(0.1, 1.0)).collect();
    let total: f64 = w.iter().sum();
    w.iter_mut().for_each(|x| *x /= total);
    let costs: Vec<f64> = (0..n).map(|_| rng.uniform(0.0, 80.0)).collect();
    let eta = 0.05;

    // Native update formula, computed directly.
    let cmin = costs.iter().cloned().fold(f64::INFINITY, f64::min);
    let mut native: Vec<f64> = w
        .iter()
        .zip(&costs)
        .map(|(wi, c)| wi * (-eta * (c - cmin)).exp())
        .collect();
    let total: f64 = native.iter().sum();
    native.iter_mut().for_each(|x| *x /= total);

    let kernel = tk.update(&w, &costs, eta).expect("tola kernel");
    for (i, (n, k)) in native.iter().zip(&kernel).enumerate() {
        assert!((n - k).abs() < 1e-5, "w[{i}]: {n} vs {k}");
    }
    let sum: f64 = kernel.iter().sum();
    assert!((sum - 1.0).abs() < 1e-4);
}
