//! The capacity-aware market view: slot-indexed offers over named
//! `(region, instance_type)` pairs.
//!
//! The paper's model (§3.1) has one spot market; real tenants face several
//! regions and instance types with independent price processes, different
//! on-demand list prices, and — crucially — *finite capacity*. A
//! [`MarketView`] is the seam every price consumer speaks:
//!
//! * each [`MarketOffer`] carries its own [`PriceTrace`], on-demand price,
//!   and an optional per-slot cap on concurrently placed spot instances;
//! * the legacy single-trace world is the one-offer degenerate case
//!   ([`MarketView::single`]) and reduces bit-identically to the old
//!   `(PriceTrace, od_price)` interface;
//! * the old arbitrage composite is re-expressed as a view whose capacities
//!   are all infinite, collapsed slot-wise ([`MarketView::arbitrage_collapse`]);
//! * remaining capacity is tracked by a [`CapacityLedger`] (one lazy
//!   range-add/range-min segment tree lane per finite-capacity offer, the
//!   same structure the self-owned pool uses), which routing policies
//!   ([`crate::policy::routing`]) consult before placing a task.
//!
//! On-demand instances stay elastic (the cloud's contract): capacity caps
//! bound *spot* placement only, so a market-wide capacity exhaustion
//! degrades a task to all-on-demand rather than stalling it.

use anyhow::{bail, ensure, Result};

use super::multi::RegionMarket;
use super::pool::RangeAddMinTree;
use super::trace::PriceTrace;

/// One placeable offer: a named `(region, instance_type)` pair with its own
/// realized price trace, on-demand price, and spot capacity.
#[derive(Debug, Clone)]
pub struct MarketOffer {
    pub region: String,
    pub instance_type: String,
    pub od_price: f64,
    pub trace: PriceTrace,
    /// Per-slot cap on concurrently placed spot instances; `None` = infinite
    /// (the paper's §3.1 assumption).
    pub capacity: Option<u32>,
}

impl MarketOffer {
    /// Canonical `region/instance_type` label (report keys, error paths).
    pub fn label(&self) -> String {
        format!("{}/{}", self.region, self.instance_type)
    }
}

/// A slot-indexed view over one or more market offers. Immutable once
/// built; mutable capacity state lives in [`CapacityLedger`].
#[derive(Debug, Clone)]
pub struct MarketView {
    offers: Vec<MarketOffer>,
}

impl MarketView {
    /// Validate and build a view. Errors (never silent defaults): empty
    /// offer set, mismatched slot grids, non-positive on-demand prices,
    /// zero capacities, duplicate `region/instance_type` labels.
    pub fn new(offers: Vec<MarketOffer>) -> Result<MarketView> {
        ensure!(!offers.is_empty(), "market view over an empty offer set");
        let slot_len = offers[0].trace.slot_len();
        for (i, o) in offers.iter().enumerate() {
            ensure!(
                (o.trace.slot_len() - slot_len).abs() < 1e-12,
                "offer '{}' is on a different slot grid ({} vs {})",
                o.label(),
                o.trace.slot_len(),
                slot_len
            );
            ensure!(
                o.od_price > 0.0,
                "offer '{}': od_price must be positive",
                o.label()
            );
            ensure!(
                o.capacity != Some(0),
                "offer '{}': capacity 0 is never placeable (omit it for infinite)",
                o.label()
            );
            ensure!(
                !offers[..i].iter().any(|p| p.label() == o.label()),
                "duplicate offer label '{}'",
                o.label()
            );
        }
        Ok(MarketView { offers })
    }

    /// The legacy single-trace market as a one-offer, infinite-capacity
    /// view — the degenerate case every pre-existing run reduces to.
    pub fn single(trace: PriceTrace, od_price: f64) -> MarketView {
        MarketView {
            offers: vec![MarketOffer {
                region: "default".into(),
                instance_type: "default".into(),
                od_price,
                trace,
                capacity: None,
            }],
        }
    }

    /// A view over whole regions (one offer per region, infinite capacity)
    /// — the shape the old `market::multi` layer produced.
    pub fn from_regions(regions: &[RegionMarket]) -> Result<MarketView> {
        MarketView::new(
            regions
                .iter()
                .map(|r| MarketOffer {
                    region: r.name.clone(),
                    instance_type: "default".into(),
                    od_price: r.od_price,
                    trace: r.trace.clone(),
                    capacity: None,
                })
                .collect(),
        )
    }

    pub fn offers(&self) -> &[MarketOffer] {
        &self.offers
    }

    pub fn len(&self) -> usize {
        self.offers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.offers.is_empty()
    }

    /// The home offer (index 0) — what legacy single-market paths run on.
    pub fn home(&self) -> &MarketOffer {
        &self.offers[0]
    }

    pub fn slot_len(&self) -> f64 {
        self.offers[0].trace.slot_len()
    }

    /// One offer, infinite capacity: the view reduces exactly to the legacy
    /// `(PriceTrace, od_price)` interface and consumers may take the
    /// bit-identical single-trace fast path.
    pub fn is_degenerate(&self) -> bool {
        self.offers.len() == 1 && self.offers[0].capacity.is_none()
    }

    pub fn has_finite_capacity(&self) -> bool {
        self.offers.iter().any(|o| o.capacity.is_some())
    }

    /// Offer index with the lowest on-demand price (ties → lowest index):
    /// where capacity-exhausted work degrades to all-on-demand.
    pub fn cheapest_od(&self) -> usize {
        let mut best = 0usize;
        for k in 1..self.offers.len() {
            if self.offers[k].od_price < self.offers[best].od_price {
                best = k;
            }
        }
        best
    }

    /// The old arbitrage composite, re-expressed on the view: slot-wise
    /// cheapest price across offers, minimum on-demand price. Only valid
    /// when every capacity is infinite — the composite models *free
    /// placement*, which a finite cap contradicts.
    pub fn arbitrage_collapse(&self) -> Result<(PriceTrace, f64)> {
        if let Some(o) = self.offers.iter().find(|o| o.capacity.is_some()) {
            bail!(
                "arbitrage composite assumes infinite capacity, but offer '{}' \
                 is capped at {} (use cheapest/spillover routing instead)",
                o.label(),
                o.capacity.unwrap()
            );
        }
        let slot_len = self.slot_len();
        let n = self
            .offers
            .iter()
            .map(|o| o.trace.num_slots())
            .max()
            .expect("validated non-empty");
        let mut prices = Vec::with_capacity(n);
        for s in 0..n {
            let p = self
                .offers
                .iter()
                .map(|o| o.trace.price_of_slot(s))
                .fold(f64::INFINITY, f64::min);
            prices.push(p);
        }
        let od = self
            .offers
            .iter()
            .map(|o| o.od_price)
            .fold(f64::INFINITY, f64::min);
        Ok((PriceTrace::from_prices(prices, slot_len), od))
    }
}

/// Parse an optional per-slot capacity key from JSON: absent = infinite;
/// present must be a positive integer that fits `u32` (0 or junk is an
/// error, never a silent infinite). Shared by coordinator configs and
/// scenario specs so the bounds and message cannot drift.
pub fn capacity_from_json(
    j: &crate::util::json::Json,
    key: &str,
    ctx: &str,
) -> Result<Option<u32>> {
    match j.get(key) {
        None => Ok(None),
        Some(c) => {
            let c = c.as_u64().ok_or_else(|| {
                anyhow::anyhow!("{ctx}: {key} must be a non-negative integer")
            })?;
            ensure!(
                c > 0 && c <= u32::MAX as u64,
                "{ctx}: {key} {c} outside 1..=u32::MAX (omit it for infinite)"
            );
            Ok(Some(c as u32))
        }
    }
}

/// Mutable remaining-capacity state for one simulation run: a segment-tree
/// lane per finite-capacity offer (range add, range min — O(log S) per
/// reservation/query), nothing at all for infinite offers.
#[derive(Debug, Clone)]
pub struct CapacityLedger {
    lanes: Vec<Option<RangeAddMinTree>>,
    slot_len: f64,
}

impl CapacityLedger {
    pub fn new(view: &MarketView, horizon: f64) -> CapacityLedger {
        let caps: Vec<Option<u32>> = view.offers().iter().map(|o| o.capacity).collect();
        CapacityLedger::from_capacities(&caps, view.slot_len(), horizon)
    }

    /// Build from bare per-offer capacities — for consumers (the streaming
    /// feed) whose traces grow after the ledger is sized. Identical lane
    /// sizing to [`CapacityLedger::new`], so reservations near the horizon
    /// clamp the same way on both paths.
    pub fn from_capacities(
        capacities: &[Option<u32>],
        slot_len: f64,
        horizon: f64,
    ) -> CapacityLedger {
        let slots = (horizon / slot_len).ceil() as usize + 1;
        CapacityLedger {
            lanes: capacities
                .iter()
                .map(|c| c.map(|c| RangeAddMinTree::new(slots, c as i64)))
                .collect(),
            slot_len,
        }
    }

    /// Slot-quantized `[lo, hi)` range of a time window, using the same
    /// convention as [`crate::market::SelfOwnedPool`]: a window ending
    /// exactly on a slot boundary does not occupy the next slot; a
    /// degenerate window reduces to its start slot.
    fn slot_range(&self, n_slots: usize, t1: f64, t2: f64) -> (usize, usize) {
        let lo = ((t1 / self.slot_len).floor() as usize).min(n_slots - 1);
        if t2 <= t1 {
            return (lo, lo + 1);
        }
        let hi_f = t2 / self.slot_len;
        let hi = if hi_f.fract() == 0.0 {
            hi_f as usize
        } else {
            hi_f.ceil() as usize
        }
        .max(lo + 1);
        (lo, hi.min(n_slots))
    }

    /// Can `units` spot instances be placed on `offer` over `[t1, t2)`?
    /// Infinite-capacity offers always say yes.
    pub fn can_place(&self, offer: usize, units: u32, t1: f64, t2: f64) -> bool {
        if units == 0 {
            return true;
        }
        match &self.lanes[offer] {
            None => true,
            Some(tree) => {
                let (lo, hi) = self.slot_range(tree.len(), t1, t2);
                tree.min(lo, hi) >= units as i64
            }
        }
    }

    /// Remaining continuously-available units over `[t1, t2)`; `None` for
    /// infinite offers.
    pub fn remaining_over(&self, offer: usize, t1: f64, t2: f64) -> Option<u32> {
        self.lanes[offer].as_ref().map(|tree| {
            let (lo, hi) = self.slot_range(tree.len(), t1, t2);
            tree.min(lo, hi).max(0) as u32
        })
    }

    /// Reserve `units` on `offer` over `[t1, t2)`. Returns `false` (and
    /// reserves nothing) when fewer than `units` are continuously free.
    pub fn reserve(&mut self, offer: usize, units: u32, t1: f64, t2: f64) -> bool {
        if units == 0 {
            return true;
        }
        if !self.can_place(offer, units, t1, t2) {
            return false;
        }
        let range = self.lanes[offer]
            .as_ref()
            .map(|tree| self.slot_range(tree.len(), t1, t2));
        if let (Some(tree), Some((lo, hi))) = (&mut self.lanes[offer], range) {
            tree.add(lo, hi, -(units as i64));
        }
        true
    }

    /// Release `units` previously reserved on `offer` over `[t1, t2)` —
    /// the inverse of [`CapacityLedger::reserve`], used when a migrating
    /// task abandons the unconsumed tail of its reservation. The caller
    /// must only release ranges it reserved; the ledger does not police
    /// over-release (it would require per-holder bookkeeping the hot path
    /// cannot afford).
    pub fn release(&mut self, offer: usize, units: u32, t1: f64, t2: f64) {
        if units == 0 {
            return;
        }
        let range = self.lanes[offer]
            .as_ref()
            .map(|tree| self.slot_range(tree.len(), t1, t2));
        if let (Some(tree), Some((lo, hi))) = (&mut self.lanes[offer], range) {
            tree.add(lo, hi, units as i64);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn offer(region: &str, itype: &str, od: f64, prices: Vec<f64>, cap: Option<u32>) -> MarketOffer {
        MarketOffer {
            region: region.into(),
            instance_type: itype.into(),
            od_price: od,
            trace: PriceTrace::from_prices(prices, 0.5),
            capacity: cap,
        }
    }

    #[test]
    fn validation_rejects_bad_views() {
        assert!(MarketView::new(vec![]).is_err());
        // zero capacity
        assert!(
            MarketView::new(vec![offer("a", "t", 1.0, vec![0.2], Some(0))]).is_err()
        );
        // duplicate labels
        assert!(MarketView::new(vec![
            offer("a", "t", 1.0, vec![0.2], None),
            offer("a", "t", 1.2, vec![0.3], None),
        ])
        .is_err());
        // mismatched slot grids
        let mut b = offer("b", "t", 1.0, vec![0.2], None);
        b.trace = PriceTrace::from_prices(vec![0.2], 0.25);
        assert!(
            MarketView::new(vec![offer("a", "t", 1.0, vec![0.2], None), b]).is_err()
        );
        // non-positive od price
        assert!(
            MarketView::new(vec![offer("a", "t", 0.0, vec![0.2], None)]).is_err()
        );
    }

    #[test]
    fn single_view_is_degenerate() {
        let v = MarketView::single(PriceTrace::from_prices(vec![0.2, 0.3], 0.5), 1.0);
        assert!(v.is_degenerate());
        assert!(!v.has_finite_capacity());
        assert_eq!(v.len(), 1);
        assert_eq!(v.home().od_price, 1.0);
        let mut cap = CapacityLedger::new(&v, 1.0);
        assert!(cap.can_place(0, 1_000_000, 0.0, 1.0));
        assert!(cap.reserve(0, 1_000_000, 0.0, 1.0));
    }

    #[test]
    fn capped_view_is_not_degenerate() {
        let v = MarketView::new(vec![offer("a", "t", 1.0, vec![0.2], Some(4))]).unwrap();
        assert!(!v.is_degenerate());
        assert!(v.has_finite_capacity());
    }

    #[test]
    fn arbitrage_collapse_takes_slotwise_min() {
        let v = MarketView::new(vec![
            offer("a", "t", 1.0, vec![0.2, 0.9, 0.3], None),
            offer("b", "t", 1.2, vec![0.5, 0.1, 0.4], None),
        ])
        .unwrap();
        let (t, od) = v.arbitrage_collapse().unwrap();
        assert_eq!(t.num_slots(), 3);
        assert_eq!(t.price_of_slot(0), 0.2);
        assert_eq!(t.price_of_slot(1), 0.1);
        assert_eq!(t.price_of_slot(2), 0.3);
        assert_eq!(od, 1.0);
    }

    #[test]
    fn arbitrage_collapse_refuses_finite_capacity() {
        let v = MarketView::new(vec![
            offer("a", "t", 1.0, vec![0.2], None),
            offer("b", "t", 1.0, vec![0.3], Some(8)),
        ])
        .unwrap();
        let err = v.arbitrage_collapse().unwrap_err().to_string();
        assert!(err.contains("b/t"), "{err}");
    }

    #[test]
    fn cheapest_od_breaks_ties_low_index() {
        let v = MarketView::new(vec![
            offer("a", "t", 1.1, vec![0.2], None),
            offer("b", "t", 0.9, vec![0.3], None),
            offer("c", "t", 0.9, vec![0.4], None),
        ])
        .unwrap();
        assert_eq!(v.cheapest_od(), 1);
    }

    #[test]
    fn ledger_tracks_per_offer_capacity() {
        let v = MarketView::new(vec![
            offer("a", "t", 1.0, vec![0.2; 20], Some(5)),
            offer("b", "t", 1.0, vec![0.3; 20], None),
        ])
        .unwrap();
        let mut cap = CapacityLedger::new(&v, 10.0);
        assert_eq!(cap.remaining_over(0, 0.0, 10.0), Some(5));
        assert_eq!(cap.remaining_over(1, 0.0, 10.0), None);
        assert!(cap.reserve(0, 3, 1.0, 4.0));
        assert_eq!(cap.remaining_over(0, 1.0, 4.0), Some(2));
        assert!(!cap.can_place(0, 3, 2.0, 3.0));
        assert!(cap.can_place(0, 2, 2.0, 3.0));
        // Outside the reserved window the full capacity remains.
        assert_eq!(cap.remaining_over(0, 5.0, 9.0), Some(5));
        // Offer b is never constrained.
        assert!(cap.reserve(1, 10_000, 0.0, 10.0));
    }

    #[test]
    fn release_restores_reserved_capacity() {
        let v = MarketView::new(vec![offer("a", "t", 1.0, vec![0.2; 20], Some(4))]).unwrap();
        let mut cap = CapacityLedger::new(&v, 10.0);
        assert!(cap.reserve(0, 3, 0.0, 6.0));
        assert_eq!(cap.remaining_over(0, 0.0, 6.0), Some(1));
        // Abandon the tail [2, 6): the consumed [0, 2) stays charged.
        cap.release(0, 3, 2.0, 6.0);
        assert_eq!(cap.remaining_over(0, 0.0, 2.0), Some(1));
        assert_eq!(cap.remaining_over(0, 2.0, 6.0), Some(4));
        // Infinite lanes ignore release, like reserve.
        let vi = MarketView::new(vec![offer("b", "t", 1.0, vec![0.2; 20], None)]).unwrap();
        let mut ci = CapacityLedger::new(&vi, 10.0);
        ci.release(0, 100, 0.0, 5.0);
        assert_eq!(ci.remaining_over(0, 0.0, 5.0), None);
    }

    #[test]
    fn ledger_boundary_excludes_end_slot() {
        let v = MarketView::new(vec![offer("a", "t", 1.0, vec![0.2; 20], Some(1))]).unwrap();
        let mut cap = CapacityLedger::new(&v, 10.0);
        assert!(cap.reserve(0, 1, 0.0, 2.0));
        // [0,2) ended exactly on a slot boundary: slot at t=2.0 is free.
        assert!(cap.can_place(0, 1, 2.0, 3.0));
        assert!(!cap.can_place(0, 1, 1.5, 2.5));
    }
}
