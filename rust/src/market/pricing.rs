//! Billing and cost accounting.
//!
//! The paper's billing model (§3.1): continuous, pay-for-what-you-use.
//! Using `k` on-demand instances for a period of length `x` costs `p·k·x`
//! with fractional `x`; spot usage is charged at the realized spot price of
//! each slot actually consumed; self-owned usage is free (Assumption 1
//! normalizes its cost to zero).

use std::fmt;

/// The three instance kinds of the paper, cheapest first (Assumption 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum InstanceKind {
    SelfOwned,
    Spot,
    OnDemand,
}

impl InstanceKind {
    pub const ALL: [InstanceKind; 3] =
        [InstanceKind::SelfOwned, InstanceKind::Spot, InstanceKind::OnDemand];

    pub fn name(&self) -> &'static str {
        match self {
            InstanceKind::SelfOwned => "self-owned",
            InstanceKind::Spot => "spot",
            InstanceKind::OnDemand => "on-demand",
        }
    }
}

impl fmt::Display for InstanceKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Accumulates cost and processed workload per instance kind.
///
/// "Workload" is instance-time actually spent processing (for spot, only
/// *available* slots count; requested-but-unavailable slots process nothing
/// and cost nothing).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CostLedger {
    pub cost_selfowned: f64,
    pub cost_spot: f64,
    pub cost_ondemand: f64,
    pub work_selfowned: f64,
    pub work_spot: f64,
    pub work_ondemand: f64,
}

impl CostLedger {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record usage: `instances` instances of `kind` for `duration` time at
    /// `unit_price` per instance-unit-time.
    pub fn charge(&mut self, kind: InstanceKind, instances: f64, duration: f64, unit_price: f64) {
        debug_assert!(instances >= 0.0 && duration >= 0.0 && unit_price >= 0.0);
        let work = instances * duration;
        let cost = work * unit_price;
        match kind {
            InstanceKind::SelfOwned => {
                self.work_selfowned += work;
                self.cost_selfowned += cost;
            }
            InstanceKind::Spot => {
                self.work_spot += work;
                self.cost_spot += cost;
            }
            InstanceKind::OnDemand => {
                self.work_ondemand += work;
                self.cost_ondemand += cost;
            }
        }
    }

    pub fn total_cost(&self) -> f64 {
        self.cost_selfowned + self.cost_spot + self.cost_ondemand
    }

    pub fn total_work(&self) -> f64 {
        self.work_selfowned + self.work_spot + self.work_ondemand
    }

    /// Average unit cost (the paper's performance metric denominator-wise:
    /// total cost over total processed workload).
    pub fn average_unit_cost(&self) -> f64 {
        if self.total_work() == 0.0 {
            0.0
        } else {
            self.total_cost() / self.total_work()
        }
    }

    pub fn work(&self, kind: InstanceKind) -> f64 {
        match kind {
            InstanceKind::SelfOwned => self.work_selfowned,
            InstanceKind::Spot => self.work_spot,
            InstanceKind::OnDemand => self.work_ondemand,
        }
    }

    pub fn cost(&self, kind: InstanceKind) -> f64 {
        match kind {
            InstanceKind::SelfOwned => self.cost_selfowned,
            InstanceKind::Spot => self.cost_spot,
            InstanceKind::OnDemand => self.cost_ondemand,
        }
    }

    pub fn merge(&mut self, other: &CostLedger) {
        self.cost_selfowned += other.cost_selfowned;
        self.cost_spot += other.cost_spot;
        self.cost_ondemand += other.cost_ondemand;
        self.work_selfowned += other.work_selfowned;
        self.work_spot += other.work_spot;
        self.work_ondemand += other.work_ondemand;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn charge_accumulates_by_kind() {
        let mut l = CostLedger::new();
        l.charge(InstanceKind::OnDemand, 2.0, 0.5, 1.0); // 1 instance-unit, cost 1
        l.charge(InstanceKind::Spot, 3.0, 1.0, 0.2); // 3 units, cost 0.6
        l.charge(InstanceKind::SelfOwned, 4.0, 1.0, 0.0); // 4 units, free
        assert!((l.total_cost() - 1.6).abs() < 1e-12);
        assert!((l.total_work() - 8.0).abs() < 1e-12);
        assert!((l.average_unit_cost() - 0.2).abs() < 1e-12);
        assert_eq!(l.work(InstanceKind::Spot), 3.0);
        assert_eq!(l.cost(InstanceKind::OnDemand), 1.0);
    }

    #[test]
    fn empty_ledger_unit_cost_zero() {
        assert_eq!(CostLedger::new().average_unit_cost(), 0.0);
    }

    #[test]
    fn merge_is_sum() {
        let mut a = CostLedger::new();
        a.charge(InstanceKind::Spot, 1.0, 1.0, 0.3);
        let mut b = CostLedger::new();
        b.charge(InstanceKind::Spot, 2.0, 1.0, 0.3);
        b.charge(InstanceKind::OnDemand, 1.0, 1.0, 1.0);
        a.merge(&b);
        assert!((a.work_spot - 3.0).abs() < 1e-12);
        assert!((a.total_cost() - (0.3 + 0.6 + 1.0)).abs() < 1e-12);
    }

    #[test]
    fn kind_names() {
        assert_eq!(InstanceKind::Spot.name(), "spot");
        assert_eq!(format!("{}", InstanceKind::OnDemand), "on-demand");
        assert_eq!(InstanceKind::ALL.len(), 3);
    }
}
