//! Realized price traces and bid-conditioned availability views.
//!
//! A [`PriceTrace`] is the ground-truth sequence of per-slot spot prices over
//! the whole simulation horizon. Jobs see *windows* of it; the PJRT
//! counterfactual kernel sees a *resampled* window of at most `S_MAX` slots
//! (the kernel has a fixed AOT shape, so long windows are coarsened and the
//! slot length `dt` travels alongside).

use std::sync::{Arc, OnceLock};

use super::spot::{SpotModel, SpotPriceProcess};
use super::SLOTS_PER_UNIT;

/// Prefix-sum index of winning-slot counts per bid of a fixed bid grid:
/// O(1) availability queries over any slot range instead of an O(S) filter
/// per call (the regret/figure paths query the same few §6.1 bids over and
/// over).
#[derive(Debug, Clone)]
pub struct AvailabilityIndex {
    /// Indexed bids, ascending and deduplicated.
    bids: Vec<f64>,
    /// Per bid: `cum[k]` = number of winning slots among `[0, k)`. `u64`:
    /// multi-week replayed traces at fine slot granularity overflow `u32`
    /// counters long before they exhaust memory.
    cum_wins: Vec<Vec<u64>>,
}

impl AvailabilityIndex {
    /// Build the full prefix-sum table over `prices` for a bid set —
    /// O(S·L). Public so the streaming layer can pin its incremental
    /// index ([`crate::feed::IncrementalAvailabilityIndex`]) exactly equal
    /// to a batch rebuild.
    pub fn build(prices: &[f64], mut bids: Vec<f64>) -> AvailabilityIndex {
        bids.sort_by(|a, b| a.partial_cmp(b).unwrap());
        bids.dedup();
        let cum_wins = bids
            .iter()
            .map(|&b| {
                let mut cum = Vec::with_capacity(prices.len() + 1);
                let mut c = 0u64;
                cum.push(0);
                for &p in prices {
                    c += (p <= b) as u64;
                    cum.push(c);
                }
                cum
            })
            .collect();
        AvailabilityIndex { bids, cum_wins }
    }

    pub fn bids(&self) -> &[f64] {
        &self.bids
    }

    /// Winning slots in the inclusive slot range `[s0, s1]` for an indexed
    /// bid; `None` when the bid is not part of the index.
    pub fn winning_slots(&self, s0: usize, s1: usize, bid: f64) -> Option<usize> {
        let i = self.bids.iter().position(|&b| b == bid)?;
        let cum = &self.cum_wins[i];
        let hi = (s1 + 1).min(cum.len() - 1);
        let lo = s0.min(hi);
        Some((cum[hi] - cum[lo]) as usize)
    }

    /// Fraction of winning slots over the inclusive slot range `[s0, s1]`.
    pub fn availability(&self, s0: usize, s1: usize, bid: f64) -> Option<f64> {
        let total = s1.saturating_sub(s0) + 1;
        self.winning_slots(s0, s1, bid)
            .map(|w| w as f64 / total as f64)
    }

    /// The raw cumulative win counts for an indexed bid (`cum[k]` = wins
    /// among slots `[0, k)`) — the array the streaming equality tests
    /// compare against the incremental index.
    pub fn cum_wins(&self, bid: f64) -> Option<&[u64]> {
        let i = self.bids.iter().position(|&b| b == bid)?;
        Some(&self.cum_wins[i])
    }
}

/// Slot-price storage behind a [`PriceTrace`].
///
/// `Flat` is the classic contiguous vector every batch path uses.
/// `Chunked` is the streaming representation: immutable `Arc`'d chunks
/// shared with the producing [`crate::feed::FeedBuffer`], so materializing
/// a fresh trace from a live feed costs O(chunk handles + open tail)
/// instead of cloning the whole ingested history. Under bounded retention
/// the leading chunks may have been evicted (`base_slot > 0`): slot
/// *indices* stay absolute, and reading an evicted slot is a hard error,
/// mirroring the feed's own eviction guard.
#[derive(Debug, Clone)]
enum Repr {
    Flat(Vec<f64>),
    Chunked {
        /// Resident chunks; chunk `i` holds absolute slots
        /// `[base_slot + i·chunk_len, …)`. All but the last hold exactly
        /// `chunk_len` prices; the last may be partial.
        chunks: Vec<Arc<[f64]>>,
        /// First resident absolute slot (a multiple of `chunk_len`).
        base_slot: usize,
        /// Absolute frontier: `base_slot` + resident slot count.
        len_slots: usize,
        chunk_len: usize,
    },
}

/// Ground-truth spot prices for the horizon, one per slot.
/// Slot `s` covers simulated time `[s·dt, (s+1)·dt)` with `dt = 1/SLOTS_PER_UNIT`.
#[derive(Debug, Clone)]
pub struct PriceTrace {
    repr: Repr,
    slot_len: f64,
    /// Lazily-built bid-grid availability index (immutable trace, so the
    /// prefix sums are computed at most once).
    index: OnceLock<AvailabilityIndex>,
}

impl PriceTrace {
    /// Generate a trace covering `horizon` time units.
    pub fn generate(model: SpotModel, horizon: f64, seed: u64) -> PriceTrace {
        let slot_len = 1.0 / SLOTS_PER_UNIT as f64;
        let n = (horizon / slot_len).ceil() as usize + 1;
        let mut proc = SpotPriceProcess::new(model, seed);
        PriceTrace {
            repr: Repr::Flat(proc.generate(n)),
            slot_len,
            index: OnceLock::new(),
        }
    }

    /// Build directly from explicit per-slot prices (tests, file loads).
    pub fn from_prices(prices: Vec<f64>, slot_len: f64) -> PriceTrace {
        assert!(slot_len > 0.0);
        PriceTrace {
            repr: Repr::Flat(prices),
            slot_len,
            index: OnceLock::new(),
        }
    }

    /// Build a shared-suffix trace over immutable chunks (the streaming
    /// feed's materialization path). Every chunk but the last must hold
    /// the same number of slots, and `base_slot` — the absolute slot of
    /// the first chunk's first price — must be chunk-aligned (eviction
    /// drops whole chunks).
    pub fn from_chunks(chunks: Vec<Arc<[f64]>>, base_slot: usize, slot_len: f64) -> PriceTrace {
        assert!(slot_len > 0.0);
        assert!(!chunks.is_empty(), "chunked trace needs at least one chunk");
        let chunk_len = chunks[0].len();
        assert!(chunk_len > 0, "empty leading chunk");
        for c in &chunks[..chunks.len() - 1] {
            assert_eq!(c.len(), chunk_len, "only the last chunk may be partial");
        }
        let last = chunks.last().expect("non-empty").len();
        assert!(last > 0 && last <= chunk_len, "trailing chunk of {last} slots");
        assert_eq!(base_slot % chunk_len, 0, "base slot must be chunk-aligned");
        let resident = (chunks.len() - 1) * chunk_len + last;
        PriceTrace {
            repr: Repr::Chunked {
                chunks,
                base_slot,
                len_slots: base_slot + resident,
                chunk_len,
            },
            slot_len,
            index: OnceLock::new(),
        }
    }

    pub fn slot_len(&self) -> f64 {
        self.slot_len
    }

    pub fn num_slots(&self) -> usize {
        match &self.repr {
            Repr::Flat(p) => p.len(),
            Repr::Chunked { len_slots, .. } => *len_slots,
        }
    }

    /// First readable absolute slot: 0 for flat traces, the retention
    /// boundary for chunked ones. Consumers of bounded-retention views
    /// gate window reads on this before touching prices.
    pub fn first_slot(&self) -> usize {
        match &self.repr {
            Repr::Flat(_) => 0,
            Repr::Chunked { base_slot, .. } => *base_slot,
        }
    }

    pub fn horizon(&self) -> f64 {
        self.num_slots() as f64 * self.slot_len
    }

    /// Slot index containing time `t` (clamped to the last slot).
    #[inline]
    pub fn slot_of(&self, t: f64) -> usize {
        ((t / self.slot_len).floor() as usize).min(self.num_slots().saturating_sub(1))
    }

    /// Price during the slot containing time `t`.
    #[inline]
    pub fn price_at(&self, t: f64) -> f64 {
        self.price_of_slot(self.slot_of(t))
    }

    #[inline]
    pub fn price_of_slot(&self, s: usize) -> f64 {
        match &self.repr {
            Repr::Flat(p) => p[s.min(p.len() - 1)],
            Repr::Chunked { chunks, base_slot, len_slots, chunk_len } => {
                let s = s.min(len_slots - 1);
                // Defense in depth behind the coordinator's retention
                // guard: reading an evicted slot is corruption, not a
                // clamp.
                assert!(
                    s >= *base_slot,
                    "feed slot {s} evicted (retention starts at slot {base_slot})"
                );
                let rel = s - base_slot;
                chunks[rel / chunk_len][rel % chunk_len]
            }
        }
    }

    /// Full price history as one contiguous slice (copying chunked storage
    /// on first use). Only defined from the stream origin: a
    /// retention-bounded trace no longer has its full history.
    fn full_prices(&self) -> std::borrow::Cow<'_, [f64]> {
        match &self.repr {
            Repr::Flat(p) => std::borrow::Cow::Borrowed(p),
            Repr::Chunked { chunks, base_slot, .. } => {
                assert_eq!(
                    *base_slot, 0,
                    "full-history access on a retention-bounded trace \
                     (slots [0, {base_slot}) evicted)"
                );
                let mut flat = Vec::with_capacity(self.num_slots());
                for c in chunks {
                    flat.extend_from_slice(c);
                }
                std::borrow::Cow::Owned(flat)
            }
        }
    }

    /// Is a bid `b` winning during the slot containing `t`?
    #[inline]
    pub fn spot_available(&self, t: f64, bid: f64) -> bool {
        self.price_at(t) <= bid
    }

    /// The bid-grid availability index, built once on first use over the
    /// §6.1 bid grid `B` (the bids the regret/figure paths actually query).
    pub fn availability_index(&self) -> &AvailabilityIndex {
        self.index
            .get_or_init(|| AvailabilityIndex::build(&self.full_prices(), crate::policy::grid_b()))
    }

    /// A one-off index over a caller-chosen bid set (not cached) — for
    /// off-grid bid sweeps that would otherwise fall back to O(S) scans.
    pub fn index_for_bids(&self, bids: Vec<f64>) -> AvailabilityIndex {
        AvailabilityIndex::build(&self.full_prices(), bids)
    }

    /// Empirical availability of bid `b` over a window (fraction of winning
    /// slots) — the realized counterpart of the paper's β. Grid bids are
    /// answered from the prefix-sum index in O(1); off-grid bids fall back
    /// to one scan of the range.
    pub fn availability(&self, t0: f64, t1: f64, bid: f64) -> f64 {
        let (s0, s1) = (self.slot_of(t0), self.slot_of(t1.max(t0)));
        if let Some(a) = self.availability_index().availability(s0, s1, bid) {
            return a;
        }
        let total = s1.saturating_sub(s0) + 1;
        let won = (s0..=s1)
            .filter(|&s| self.price_of_slot(s) <= bid)
            .count();
        won as f64 / total as f64
    }

    /// Resample the window `[t0, t1)` into at most `max_slots` equal slots
    /// for the fixed-shape AOT kernel. Returns `(prices, dt)`, where each
    /// output slot takes the price of the input slot containing its midpoint
    /// (nearest sampling; exact when the window already fits).
    ///
    /// The output is padded with `f64::INFINITY` (spot never available) up to
    /// `max_slots` so the kernel's fixed shape is always filled.
    pub fn resample_window(&self, t0: f64, t1: f64, max_slots: usize) -> (Vec<f64>, f64) {
        assert!(t1 > t0, "empty window");
        assert!(max_slots > 0);
        let native = ((t1 - t0) / self.slot_len).ceil() as usize;
        let n = native.clamp(1, max_slots);
        let dt = (t1 - t0) / n as f64;
        let mut out = Vec::with_capacity(max_slots);
        for k in 0..n {
            let mid = t0 + (k as f64 + 0.5) * dt;
            out.push(self.price_at(mid));
        }
        out.resize(max_slots, f64::INFINITY);
        (out, dt)
    }

    /// Contiguous availability segments for a bid (for Figure 1): returns
    /// `(start_time, end_time, available)` runs.
    pub fn availability_segments(&self, t0: f64, t1: f64, bid: f64) -> Vec<(f64, f64, bool)> {
        let (s0, s1) = (self.slot_of(t0), self.slot_of(t1));
        let mut runs: Vec<(f64, f64, bool)> = Vec::new();
        for s in s0..=s1 {
            let avail = self.price_of_slot(s) <= bid;
            let start = s as f64 * self.slot_len;
            let end = start + self.slot_len;
            match runs.last_mut() {
                Some((_, e, a)) if *a == avail => *e = end,
                _ => runs.push((start, end, avail)),
            }
        }
        runs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> PriceTrace {
        // slot_len 0.5; prices alternate cheap/expensive.
        PriceTrace::from_prices(vec![0.1, 0.9, 0.1, 0.9, 0.1, 0.9], 0.5)
    }

    #[test]
    fn slot_lookup() {
        let t = toy();
        assert_eq!(t.slot_of(0.0), 0);
        assert_eq!(t.slot_of(0.49), 0);
        assert_eq!(t.slot_of(0.5), 1);
        assert_eq!(t.slot_of(100.0), 5); // clamped
        assert_eq!(t.price_at(1.2), 0.1);
    }

    #[test]
    fn slot_of_horizon_boundary_clamps_to_last_slot() {
        // t == horizon falls exactly one past the last slot's index range;
        // it must clamp to the final slot, never index one past the end.
        let t = toy();
        assert_eq!(t.horizon(), 3.0);
        assert_eq!(t.slot_of(t.horizon()), 5);
        assert_eq!(t.price_at(t.horizon()), 0.9);
        // Just inside the final slot and just past the horizon agree.
        assert_eq!(t.slot_of(t.horizon() - 1e-12), 5);
        assert_eq!(t.slot_of(t.horizon() + 1e-12), 5);
        // Degenerate one-slot trace: every time maps to slot 0.
        let one = PriceTrace::from_prices(vec![0.4], 0.5);
        assert_eq!(one.slot_of(one.horizon()), 0);
        assert_eq!(one.slot_of(0.0), 0);
    }

    #[test]
    fn availability_fraction() {
        let t = toy();
        // bid 0.5 wins the cheap slots only => half the time.
        let a = t.availability(0.0, 2.99, 0.5);
        assert!((a - 0.5).abs() < 1e-9, "a={a}");
        assert_eq!(t.availability(0.0, 2.99, 1.0), 1.0);
        assert_eq!(t.availability(0.0, 2.99, 0.05), 0.0);
    }

    #[test]
    fn resample_exact_when_fits() {
        let t = toy();
        let (p, dt) = t.resample_window(0.0, 3.0, 16);
        assert!((dt - 0.5).abs() < 1e-12);
        assert_eq!(&p[..6], &[0.1, 0.9, 0.1, 0.9, 0.1, 0.9]);
        assert!(p[6..].iter().all(|x| x.is_infinite()));
        assert_eq!(p.len(), 16);
    }

    #[test]
    fn resample_coarsens_long_windows() {
        let trace = PriceTrace::generate(SpotModel::paper_default(), 100.0, 5);
        let (p, dt) = trace.resample_window(0.0, 100.0, 64);
        assert_eq!(p.len(), 64);
        assert!((dt - 100.0 / 64.0).abs() < 1e-12);
        assert!(p.iter().all(|&x| (0.12..=1.0).contains(&x)));
    }

    #[test]
    fn segments_merge_runs() {
        let t = toy();
        let segs = t.availability_segments(0.0, 2.9, 0.5);
        assert_eq!(segs.len(), 6); // alternating every slot
        assert!(segs[0].2);
        assert!(!segs[1].2);
        // Merged case: bid winning everywhere -> single run.
        let segs_all = t.availability_segments(0.0, 2.9, 1.0);
        assert_eq!(segs_all.len(), 1);
        assert!(segs_all[0].2);
    }

    #[test]
    fn index_matches_scan_on_grid_bids() {
        let trace = PriceTrace::generate(SpotModel::paper_default(), 40.0, 17);
        let idx = trace.availability_index();
        assert!(!idx.bids().is_empty());
        for &bid in &crate::policy::grid_b() {
            for (t0, t1) in [(0.0, 39.0), (3.25, 7.5), (12.0, 12.0)] {
                let (s0, s1) = (trace.slot_of(t0), trace.slot_of(t1));
                let scan = (s0..=s1)
                    .filter(|&s| trace.price_of_slot(s) <= bid)
                    .count();
                assert_eq!(idx.winning_slots(s0, s1, bid), Some(scan));
                let a = trace.availability(t0, t1, bid);
                let total = s1 - s0 + 1;
                assert!((a - scan as f64 / total as f64).abs() < 1e-12);
            }
        }
        // Off-grid bids still answer (scan fallback).
        assert_eq!(idx.winning_slots(0, 10, 0.12345), None);
        assert!(trace.availability(0.0, 10.0, 1.0) == 1.0);
    }

    #[test]
    fn generated_trace_covers_horizon() {
        let trace = PriceTrace::generate(SpotModel::paper_default(), 10.0, 1);
        assert!(trace.horizon() >= 10.0);
        assert_eq!(trace.slot_len(), 1.0 / 12.0);
    }

    #[test]
    fn chunked_trace_is_value_identical_to_flat() {
        let prices: Vec<f64> = (0..100).map(|i| 0.1 + 0.001 * i as f64).collect();
        let flat = PriceTrace::from_prices(prices.clone(), 0.5);
        let chunks: Vec<Arc<[f64]>> = prices.chunks(16).map(Arc::from).collect();
        let chunked = PriceTrace::from_chunks(chunks, 0, 0.5);
        assert_eq!(chunked.num_slots(), flat.num_slots());
        assert_eq!(chunked.first_slot(), 0);
        for s in 0..flat.num_slots() {
            assert_eq!(chunked.price_of_slot(s), flat.price_of_slot(s), "slot {s}");
        }
        // Derived views go through the same price reads: exact equality.
        let (pa, da) = flat.resample_window(1.0, 40.0, 64);
        let (pb, db) = chunked.resample_window(1.0, 40.0, 64);
        assert_eq!(pa, pb);
        assert_eq!(da, db);
        assert_eq!(
            chunked.availability(0.0, 49.0, 0.15),
            flat.availability(0.0, 49.0, 0.15)
        );
        assert_eq!(chunked.price_at(chunked.horizon()), flat.price_at(flat.horizon()));
    }

    #[test]
    fn retention_bounded_chunked_trace_guards_evicted_slots() {
        let chunks: Vec<Arc<[f64]>> = (0..3)
            .map(|c| {
                let v: Vec<f64> = (0..16).map(|i| 0.2 + (c * 16 + i) as f64 * 1e-3).collect();
                Arc::from(v)
            })
            .collect();
        let t = PriceTrace::from_chunks(chunks, 32, 0.5);
        assert_eq!(t.first_slot(), 32);
        assert_eq!(t.num_slots(), 80);
        assert_eq!(t.price_of_slot(32), 0.2);
        assert_eq!(t.price_of_slot(79), 0.2 + 47.0 * 1e-3);
        let hit = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| t.price_of_slot(31)));
        let msg = *hit.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("evicted"), "{msg}");
        assert!(msg.contains("slot 31"), "{msg}");
    }
}
