//! Multi-region markets: per-region price traces with per-region on-demand
//! prices, and the slot-wise arbitrage composite.
//!
//! The paper's model has one spot market; real tenants see several regions
//! (and instance types) with independent price processes and different
//! on-demand list prices. A region here is just a named `(PriceTrace,
//! od_price)` pair — how the traces were produced (synthetic process,
//! regime schedule, CSV replay) is the scenario layer's business.
//!
//! The *arbitrage composite* models a tenant free to place each slot of
//! work in whichever region is currently cheapest: its trace is the
//! slot-wise minimum across regions and its on-demand price the region
//! minimum. This folds a multi-market world into the single-trace interface
//! every existing consumer (executor, sweep engine, coordinator) speaks.

use super::trace::PriceTrace;

/// One region's realized market: a price trace plus its on-demand price.
#[derive(Debug, Clone)]
pub struct RegionMarket {
    pub name: String,
    pub od_price: f64,
    pub trace: PriceTrace,
}

/// Slot-wise cheapest-region composite over a non-empty region set.
///
/// All traces must share the slot grid; the composite spans the longest
/// region (shorter regions persist their final price via the trace's
/// clamped slot lookup). Returns the composite trace and the minimum
/// on-demand price.
pub fn arbitrage_composite(regions: &[RegionMarket]) -> (PriceTrace, f64) {
    assert!(!regions.is_empty(), "arbitrage over zero regions");
    let slot_len = regions[0].trace.slot_len();
    for r in regions {
        assert!(
            (r.trace.slot_len() - slot_len).abs() < 1e-12,
            "region '{}' is on a different slot grid",
            r.name
        );
    }
    let n = regions
        .iter()
        .map(|r| r.trace.num_slots())
        .max()
        .expect("non-empty");
    let mut prices = Vec::with_capacity(n);
    for s in 0..n {
        let p = regions
            .iter()
            .map(|r| r.trace.price_of_slot(s))
            .fold(f64::INFINITY, f64::min);
        prices.push(p);
    }
    let od = regions
        .iter()
        .map(|r| r.od_price)
        .fold(f64::INFINITY, f64::min);
    (PriceTrace::from_prices(prices, slot_len), od)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn region(name: &str, od: f64, prices: Vec<f64>) -> RegionMarket {
        RegionMarket {
            name: name.into(),
            od_price: od,
            trace: PriceTrace::from_prices(prices, 1.0 / 12.0),
        }
    }

    #[test]
    fn composite_takes_slotwise_min() {
        let a = region("a", 1.0, vec![0.2, 0.9, 0.3]);
        let b = region("b", 1.2, vec![0.5, 0.1, 0.4]);
        let (t, od) = arbitrage_composite(&[a, b]);
        assert_eq!(t.num_slots(), 3);
        assert_eq!(t.price_of_slot(0), 0.2);
        assert_eq!(t.price_of_slot(1), 0.1);
        assert_eq!(t.price_of_slot(2), 0.3);
        assert_eq!(od, 1.0);
    }

    #[test]
    fn shorter_region_persists_last_price() {
        let a = region("a", 1.0, vec![0.6, 0.6, 0.6, 0.6]);
        let b = region("b", 1.0, vec![0.2]);
        let (t, _) = arbitrage_composite(&[a, b]);
        assert_eq!(t.num_slots(), 4);
        // b's single 0.2 price clamps forward over the whole span.
        for s in 0..4 {
            assert_eq!(t.price_of_slot(s), 0.2);
        }
    }

    #[test]
    fn single_region_composite_is_identity() {
        let a = region("a", 1.1, vec![0.3, 0.4]);
        let (t, od) = arbitrage_composite(std::slice::from_ref(&a));
        assert_eq!(t.num_slots(), 2);
        assert_eq!(t.price_of_slot(1), 0.4);
        assert_eq!(od, 1.1);
    }

    #[test]
    #[should_panic]
    fn mismatched_grids_panic() {
        let a = region("a", 1.0, vec![0.3]);
        let b = RegionMarket {
            name: "b".into(),
            od_price: 1.0,
            trace: PriceTrace::from_prices(vec![0.3], 0.5),
        };
        arbitrage_composite(&[a, b]);
    }
}
