//! Multi-region markets: per-region price traces with per-region on-demand
//! prices, and the slot-wise arbitrage composite.
//!
//! The paper's model has one spot market; real tenants see several regions
//! (and instance types) with independent price processes and different
//! on-demand list prices. A region here is just a named `(PriceTrace,
//! od_price)` pair — how the traces were produced (synthetic process,
//! regime schedule, CSV replay) is the scenario layer's business.
//!
//! The *arbitrage composite* models a tenant free to place each slot of
//! work in whichever region is currently cheapest. Since the capacity-aware
//! [`MarketView`](super::view::MarketView) refactor, the composite is just
//! the degenerate all-infinite-capacity view collapsed slot-wise
//! ([`MarketView::arbitrage_collapse`](super::view::MarketView::arbitrage_collapse));
//! the free-standing function below is kept as the region-level entry
//! point. Worlds that model finite capacity or real placement route through
//! the view instead ([`crate::policy::routing`]).

use anyhow::Result;

use super::trace::PriceTrace;
use super::view::MarketView;

/// One region's realized market: a price trace plus its on-demand price.
#[derive(Debug, Clone)]
pub struct RegionMarket {
    pub name: String,
    pub od_price: f64,
    pub trace: PriceTrace,
}

/// Slot-wise cheapest-region composite over a non-empty region set.
///
/// All traces must share the slot grid; the composite spans the longest
/// region (shorter regions persist their final price via the trace's
/// clamped slot lookup). Returns the composite trace and the minimum
/// on-demand price, or an error for an empty region set / mismatched slot
/// grids (surfaced through scenario spec validation rather than a panic).
pub fn arbitrage_composite(regions: &[RegionMarket]) -> Result<(PriceTrace, f64)> {
    MarketView::from_regions(regions)?.arbitrage_collapse()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn region(name: &str, od: f64, prices: Vec<f64>) -> RegionMarket {
        RegionMarket {
            name: name.into(),
            od_price: od,
            trace: PriceTrace::from_prices(prices, 1.0 / 12.0),
        }
    }

    #[test]
    fn composite_takes_slotwise_min() {
        let a = region("a", 1.0, vec![0.2, 0.9, 0.3]);
        let b = region("b", 1.2, vec![0.5, 0.1, 0.4]);
        let (t, od) = arbitrage_composite(&[a, b]).unwrap();
        assert_eq!(t.num_slots(), 3);
        assert_eq!(t.price_of_slot(0), 0.2);
        assert_eq!(t.price_of_slot(1), 0.1);
        assert_eq!(t.price_of_slot(2), 0.3);
        assert_eq!(od, 1.0);
    }

    #[test]
    fn shorter_region_persists_last_price() {
        let a = region("a", 1.0, vec![0.6, 0.6, 0.6, 0.6]);
        let b = region("b", 1.0, vec![0.2]);
        let (t, _) = arbitrage_composite(&[a, b]).unwrap();
        assert_eq!(t.num_slots(), 4);
        // b's single 0.2 price clamps forward over the whole span.
        for s in 0..4 {
            assert_eq!(t.price_of_slot(s), 0.2);
        }
    }

    #[test]
    fn single_region_composite_is_identity() {
        let a = region("a", 1.1, vec![0.3, 0.4]);
        let (t, od) = arbitrage_composite(std::slice::from_ref(&a)).unwrap();
        assert_eq!(t.num_slots(), 2);
        assert_eq!(t.price_of_slot(1), 0.4);
        assert_eq!(od, 1.1);
    }

    #[test]
    fn empty_region_set_is_an_error_not_a_panic() {
        let err = arbitrage_composite(&[]).unwrap_err().to_string();
        assert!(err.contains("empty"), "{err}");
    }

    #[test]
    fn mismatched_grids_error_names_the_region() {
        let a = region("a", 1.0, vec![0.3]);
        let b = RegionMarket {
            name: "b".into(),
            od_price: 1.0,
            trace: PriceTrace::from_prices(vec![0.3], 0.5),
        };
        let err = arbitrage_composite(&[a, b]).unwrap_err().to_string();
        assert!(err.contains('b'), "{err}");
        assert!(err.contains("slot grid"), "{err}");
    }
}
