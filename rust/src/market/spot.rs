//! Spot price processes.
//!
//! §6.1 of the paper models spot prices as a bounded exponential distribution
//! (mean 0.13, bounds [0.12, 1.0]) redrawn independently each slot, citing
//! Zheng et al. [31]. We implement that as the default, plus two variants
//! used for ablations:
//!
//! * [`SpotModel::BoundedExp`] — the paper's §6.1 process (default);
//! * [`SpotModel::Markov`] — a two-state (calm/surge) Markov-modulated
//!   version capturing price autocorrelation (Zafer et al. [16] model spot
//!   prices as a Markov chain);
//! * [`SpotModel::GoogleFixed`] — Google-cloud style: constant discounted
//!   price with exogenous on/off availability (no bidding; §3.1).

use crate::util::json::Json;
use crate::util::rng::Pcg32;

/// Configuration of a spot price process.
#[derive(Debug, Clone, PartialEq)]
pub enum SpotModel {
    /// Price ~ bounded Exp(mean) redrawn each slot, clamped to [lo, hi].
    BoundedExp { mean: f64, lo: f64, hi: f64 },
    /// Two-state Markov chain; each state has its own bounded-exp draw.
    /// `p_calm_to_surge` / `p_surge_to_calm` are per-slot transition
    /// probabilities.
    Markov {
        calm_mean: f64,
        surge_mean: f64,
        lo: f64,
        hi: f64,
        p_calm_to_surge: f64,
        p_surge_to_calm: f64,
    },
    /// Fixed price; available each slot with probability `availability`
    /// (i.i.d.). Bids are ignored (Google model).
    GoogleFixed { price: f64, availability: f64 },
}

impl SpotModel {
    /// The paper's §6.1 default process.
    pub fn paper_default() -> SpotModel {
        SpotModel::BoundedExp {
            mean: 0.13,
            lo: 0.12,
            hi: 1.0,
        }
    }

    /// Whether availability is bid-dependent (EC2/Azure) or exogenous
    /// (Google).
    pub fn bid_dependent(&self) -> bool {
        !matches!(self, SpotModel::GoogleFixed { .. })
    }

    /// Sanity-check the process parameters so a malformed model fails with
    /// an error instead of a downstream panic (bounded-exp rejection
    /// sampling asserts `lo < hi`) or a degenerate run. Callers that know a
    /// path (scenario, region, offer) wrap the message with context.
    pub fn validate(&self) -> anyhow::Result<()> {
        match self {
            SpotModel::BoundedExp { mean, lo, hi } => {
                anyhow::ensure!(
                    *mean > 0.0 && *lo >= 0.0 && lo < hi,
                    "bounded_exp needs mean > 0 and 0 <= lo < hi (mean={mean}, lo={lo}, hi={hi})"
                );
            }
            SpotModel::Markov {
                calm_mean,
                surge_mean,
                lo,
                hi,
                p_calm_to_surge,
                p_surge_to_calm,
            } => {
                anyhow::ensure!(
                    *calm_mean > 0.0 && *surge_mean > 0.0 && *lo >= 0.0 && lo < hi,
                    "markov needs positive means and 0 <= lo < hi"
                );
                anyhow::ensure!(
                    (0.0..=1.0).contains(p_calm_to_surge)
                        && (0.0..=1.0).contains(p_surge_to_calm),
                    "markov transition probabilities must lie in [0, 1]"
                );
            }
            SpotModel::GoogleFixed {
                price,
                availability,
            } => {
                anyhow::ensure!(
                    *price > 0.0 && (0.0..=1.0).contains(availability),
                    "google needs price > 0 and availability in [0, 1]"
                );
            }
        }
        Ok(())
    }
}

/// Serialize a [`SpotModel`] (the shape `coordinator::Config` files and
/// scenario specs share).
pub fn spot_model_to_json(m: &SpotModel) -> Json {
    let mut sm = Json::obj();
    match m {
        SpotModel::BoundedExp { mean, lo, hi } => {
            sm.set("kind", Json::Str("bounded_exp".into()))
                .set("mean", Json::Num(*mean))
                .set("lo", Json::Num(*lo))
                .set("hi", Json::Num(*hi));
        }
        SpotModel::Markov {
            calm_mean,
            surge_mean,
            lo,
            hi,
            p_calm_to_surge,
            p_surge_to_calm,
        } => {
            sm.set("kind", Json::Str("markov".into()))
                .set("calm_mean", Json::Num(*calm_mean))
                .set("surge_mean", Json::Num(*surge_mean))
                .set("lo", Json::Num(*lo))
                .set("hi", Json::Num(*hi))
                .set("p_calm_to_surge", Json::Num(*p_calm_to_surge))
                .set("p_surge_to_calm", Json::Num(*p_surge_to_calm));
        }
        SpotModel::GoogleFixed {
            price,
            availability,
        } => {
            sm.set("kind", Json::Str("google".into()))
                .set("price", Json::Num(*price))
                .set("availability", Json::Num(*availability));
        }
    }
    sm
}

/// Parse a [`SpotModel`]. Missing *fields* fall back to §6.1-flavored
/// defaults (config files stay forward-compatible), but an unknown `kind`
/// is an error — a typo must not silently run the default market.
pub fn spot_model_from_json(sm: &Json) -> anyhow::Result<SpotModel> {
    // A present-but-non-string kind (null, number) must not silently fall
    // back to the default either.
    if let Some(k) = sm.get("kind") {
        anyhow::ensure!(
            matches!(k, Json::Str(_)),
            "spot model 'kind' must be a string"
        );
    }
    Ok(match sm.opt_str("kind", "bounded_exp") {
        "markov" => SpotModel::Markov {
            calm_mean: sm.opt_f64("calm_mean", 0.13),
            surge_mean: sm.opt_f64("surge_mean", 0.6),
            lo: sm.opt_f64("lo", 0.12),
            hi: sm.opt_f64("hi", 1.0),
            p_calm_to_surge: sm.opt_f64("p_calm_to_surge", 0.05),
            p_surge_to_calm: sm.opt_f64("p_surge_to_calm", 0.2),
        },
        "google" => SpotModel::GoogleFixed {
            price: sm.opt_f64("price", 0.3),
            availability: sm.opt_f64("availability", 0.7),
        },
        "bounded_exp" => SpotModel::BoundedExp {
            mean: sm.opt_f64("mean", 0.13),
            lo: sm.opt_f64("lo", 0.12),
            hi: sm.opt_f64("hi", 1.0),
        },
        other => anyhow::bail!(
            "unknown spot model kind '{other}' (bounded_exp|markov|google)"
        ),
    })
}

/// Stateful generator of per-slot spot prices.
#[derive(Debug, Clone)]
pub struct SpotPriceProcess {
    model: SpotModel,
    rng: Pcg32,
    /// Markov state: true = surge.
    surge: bool,
}

impl SpotPriceProcess {
    pub fn new(model: SpotModel, seed: u64) -> Self {
        Self {
            model,
            rng: Pcg32::new(seed ^ 0x5107_A11C_E5),
            surge: false,
        }
    }

    pub fn model(&self) -> &SpotModel {
        &self.model
    }

    /// Draw the price for the next slot. For `GoogleFixed`, an *unavailable*
    /// slot is encoded as `f64::INFINITY` (no finite bid can win it), which
    /// composes uniformly with the bid rule `price ≤ b`.
    pub fn next_price(&mut self) -> f64 {
        match &self.model {
            SpotModel::BoundedExp { mean, lo, hi } => {
                bounded_exp(&mut self.rng, *mean, *lo, *hi)
            }
            SpotModel::Markov {
                calm_mean,
                surge_mean,
                lo,
                hi,
                p_calm_to_surge,
                p_surge_to_calm,
            } => {
                if self.surge {
                    if self.rng.chance(*p_surge_to_calm) {
                        self.surge = false;
                    }
                } else if self.rng.chance(*p_calm_to_surge) {
                    self.surge = true;
                }
                let mean = if self.surge { *surge_mean } else { *calm_mean };
                bounded_exp(&mut self.rng, mean, *lo, *hi)
            }
            SpotModel::GoogleFixed {
                price,
                availability,
            } => {
                if self.rng.chance(*availability) {
                    *price
                } else {
                    f64::INFINITY
                }
            }
        }
    }

    /// Generate `n` slot prices.
    pub fn generate(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.next_price()).collect()
    }
}

/// Exponential(mean) truncated by rejection into [lo, hi].
///
/// Rejection keeps the in-range shape exactly exponential (a clamp would put
/// probability atoms at the bounds; the paper says "bounded exponential
/// distribution", and rejection is the standard reading — the mean parameter
/// refers to the underlying exponential).
fn bounded_exp(rng: &mut Pcg32, mean: f64, lo: f64, hi: f64) -> f64 {
    debug_assert!(lo < hi);
    for _ in 0..10_000 {
        let x = rng.exponential(mean);
        if (lo..=hi).contains(&x) {
            return x;
        }
    }
    // Pathological parameters (acceptance region has tiny mass): fall back to
    // the lower bound, the mode of the conditioned distribution.
    lo
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bounded_exp_respects_bounds() {
        let mut p = SpotPriceProcess::new(SpotModel::paper_default(), 1);
        for _ in 0..20_000 {
            let x = p.next_price();
            assert!((0.12..=1.0).contains(&x), "price {x} out of bounds");
        }
    }

    #[test]
    fn bounded_exp_mean_reasonable() {
        // Conditioning Exp(0.13) on [0.12, 1] shifts the mean to ≈ 0.12+0.128.
        let mut p = SpotPriceProcess::new(SpotModel::paper_default(), 2);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| p.next_price()).sum::<f64>() / n as f64;
        assert!(
            (0.2..0.3).contains(&mean),
            "conditioned mean {mean} outside plausible band"
        );
    }

    #[test]
    fn deterministic_under_seed() {
        let a: Vec<f64> =
            SpotPriceProcess::new(SpotModel::paper_default(), 7).generate(64);
        let b: Vec<f64> =
            SpotPriceProcess::new(SpotModel::paper_default(), 7).generate(64);
        assert_eq!(a, b);
    }

    #[test]
    fn markov_switches_states() {
        let model = SpotModel::Markov {
            calm_mean: 0.13,
            surge_mean: 0.8,
            lo: 0.12,
            hi: 1.0,
            p_calm_to_surge: 0.1,
            p_surge_to_calm: 0.1,
        };
        let mut p = SpotPriceProcess::new(model, 3);
        let xs = p.generate(50_000);
        let high = xs.iter().filter(|&&x| x > 0.5).count();
        // Surge state must actually occur.
        assert!(high > 1_000, "high-price slots: {high}");
    }

    #[test]
    fn google_fixed_encodes_unavailability_as_inf() {
        let model = SpotModel::GoogleFixed {
            price: 0.3,
            availability: 0.6,
        };
        let mut p = SpotPriceProcess::new(model, 4);
        let xs = p.generate(10_000);
        let avail = xs.iter().filter(|x| x.is_finite()).count() as f64 / 10_000.0;
        assert!((avail - 0.6).abs() < 0.03, "availability {avail}");
        assert!(xs.iter().all(|&x| x == 0.3 || x.is_infinite()));
    }

    #[test]
    fn bid_dependence_flags() {
        assert!(SpotModel::paper_default().bid_dependent());
        assert!(!SpotModel::GoogleFixed {
            price: 0.1,
            availability: 0.5
        }
        .bid_dependent());
    }

    #[test]
    fn spot_model_json_roundtrips_all_kinds() {
        for m in [
            SpotModel::paper_default(),
            SpotModel::Markov {
                calm_mean: 0.13,
                surge_mean: 0.6,
                lo: 0.12,
                hi: 1.0,
                p_calm_to_surge: 0.05,
                p_surge_to_calm: 0.2,
            },
            SpotModel::GoogleFixed {
                price: 0.3,
                availability: 0.7,
            },
        ] {
            let j = spot_model_to_json(&m);
            assert_eq!(spot_model_from_json(&j).unwrap(), m);
        }
    }

    #[test]
    fn unknown_model_kind_rejected() {
        let j = Json::parse(r#"{"kind": "markvo", "calm_mean": 0.2}"#).unwrap();
        assert!(spot_model_from_json(&j).is_err());
        // Present-but-non-string kind is rejected too, not defaulted.
        let n = Json::parse(r#"{"kind": 1, "mean": 0.6}"#).unwrap();
        assert!(spot_model_from_json(&n).is_err());
        // Missing kind still defaults to bounded_exp.
        let d = Json::parse(r#"{"mean": 0.2}"#).unwrap();
        assert!(matches!(
            spot_model_from_json(&d).unwrap(),
            SpotModel::BoundedExp { .. }
        ));
    }
}
