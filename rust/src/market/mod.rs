//! Cloud market substrate: price processes, billing, and the self-owned
//! instance pool.
//!
//! Models §3.1 of the paper:
//!
//! * **on-demand** instances — always available, fixed price `p` per unit
//!   time, billed per second (continuous billing: using an instance for `x`
//!   units costs `p·x` with fractional `x`);
//! * **spot** instances — intermittently available; in the EC2/Azure model a
//!   bid `b` wins a slot iff `price(slot) ≤ b` and the user pays the *spot*
//!   price, in the Google model the price is constant and availability is an
//!   exogenous on/off process;
//! * **self-owned** instances — a finite pool of `r` instances at zero
//!   marginal cost with `N(t)` idle at time `t` and
//!   `N(t1,t2) = min_{t∈[t1,t2]} N(t)` (Table 1).
//!
//! Beyond the paper's single market, [`view`] lifts all of the above into a
//! capacity-aware multi-offer [`MarketView`] over named
//! `(region, instance_type)` pairs; the single-trace world is its one-offer
//! degenerate case.

pub mod spot;
pub mod trace;
pub mod pricing;
pub mod pool;
pub mod replay;
pub mod multi;
pub mod view;

pub use multi::RegionMarket;
pub use pool::{RangeAddMinTree, SelfOwnedPool};
pub use pricing::{CostLedger, InstanceKind};
pub use spot::{spot_model_from_json, spot_model_to_json, SpotModel, SpotPriceProcess};
pub use trace::{AvailabilityIndex, PriceTrace};
pub use view::{CapacityLedger, MarketOffer, MarketView};

/// Number of price slots per unit of time (§6.1: "each unit of time is
/// divided into 12 equal time slots").
pub const SLOTS_PER_UNIT: u32 = 12;

/// Normalized on-demand price (§6.1: "the on-demand price p is normalized to
/// be 1").
pub const ON_DEMAND_PRICE: f64 = 1.0;
