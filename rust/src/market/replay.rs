//! CSV-replayed spot-price traces.
//!
//! Related work stresses bidding policies against *real* spot-market
//! histories rather than synthetic processes (Voorsluys et al.,
//! arXiv:1110.5972); this loader feeds such a history into [`PriceTrace`]
//! so every downstream consumer (executor, sweep engine, coordinator) sees
//! a replayed market exactly as it sees a generated one.
//!
//! ## Format
//!
//! Plain CSV, two accepted shapes:
//!
//! * **two columns** `time,price` — a step function over simulated time
//!   units: each observation holds until the next one. The trace is
//!   resampled onto the standard `1/SLOTS_PER_UNIT` slot grid (a slot takes
//!   the last observation at or before its midpoint) and timestamps are
//!   shifted so the first observation is `t = 0`;
//! * **one column** `price` — one price per slot directly on the standard
//!   grid.
//!
//! Empty lines and `#` comments are skipped; a single leading non-numeric
//! header row is tolerated. `time_scale` multiplies timestamps into
//! simulated time units (e.g. hours→units); `price_scale` normalizes prices
//! against the on-demand price (the paper normalizes `p = 1`).

use anyhow::{bail, ensure, Context, Result};

use super::trace::PriceTrace;
use super::SLOTS_PER_UNIT;

/// Parse CSV text into a [`PriceTrace`] on the standard slot grid,
/// rejecting out-of-order timestamps (the error names the offending
/// line). See [`trace_from_csv_opts`] for the sort-and-dedupe variant.
pub fn trace_from_csv(text: &str, time_scale: f64, price_scale: f64) -> Result<PriceTrace> {
    trace_from_csv_opts(text, time_scale, price_scale, false)
}

/// Parse CSV text into a [`PriceTrace`]. With `sort_dedup = false`
/// out-of-order timestamps are an error naming the offending line — a
/// garbled history must never silently become a garbled step function.
/// With `sort_dedup = true` (an explicit opt-in for dumps known to be
/// unordered) rows are stably sorted by timestamp and duplicate
/// timestamps collapsed, the last-listed observation winning.
pub fn trace_from_csv_opts(
    text: &str,
    time_scale: f64,
    price_scale: f64,
    sort_dedup: bool,
) -> Result<PriceTrace> {
    ensure!(
        time_scale > 0.0 && price_scale > 0.0,
        "replay csv: scales must be positive (time_scale={time_scale}, price_scale={price_scale})"
    );
    // (time, price, 1-based source line) per data row.
    let mut rows: Vec<(Option<f64>, f64, usize)> = Vec::new();
    let mut header_skipped = false;
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let fields: Vec<&str> = line.split(',').map(str::trim).collect();
        let parsed: Option<(Option<f64>, f64)> = match fields.len() {
            1 => fields[0].parse::<f64>().ok().map(|p| (None, p)),
            _ => match (fields[0].parse::<f64>(), fields[1].parse::<f64>()) {
                (Ok(t), Ok(p)) => Some((Some(t), p)),
                _ => None,
            },
        };
        match parsed {
            Some((_, p)) if !(p.is_finite() && p > 0.0) => {
                bail!("replay csv line {}: non-positive price '{line}'", lineno + 1)
            }
            Some((t, p)) => rows.push((t, p, lineno + 1)),
            // Exactly one leading non-numeric row is tolerated as the
            // header; any further unparsable row is data corruption.
            None if rows.is_empty() && !header_skipped => header_skipped = true,
            None => bail!("replay csv line {}: unparsable row '{line}'", lineno + 1),
        }
    }
    ensure!(!rows.is_empty(), "replay csv: no data rows");

    let slot_len = 1.0 / SLOTS_PER_UNIT as f64;
    let timed = rows.iter().any(|(t, _, _)| t.is_some());
    if !timed {
        let prices: Vec<f64> = rows.iter().map(|(_, p, _)| *p * price_scale).collect();
        return Ok(PriceTrace::from_prices(prices, slot_len));
    }
    ensure!(
        rows.iter().all(|(t, _, _)| t.is_some()),
        "replay csv: mixed timed and untimed rows"
    );
    let mut pts: Vec<(f64, f64, usize)> = rows
        .iter()
        .map(|(t, p, l)| (t.unwrap() * time_scale, *p * price_scale, *l))
        .collect();
    if let Some(bad) = pts.iter().find(|(t, _, _)| !t.is_finite()) {
        bail!(
            "replay csv line {}: non-finite timestamp {}",
            bad.2,
            bad.0
        );
    }
    if sort_dedup {
        pts = sort_dedup_by_time(pts, |p| p.0);
    } else {
        for w in pts.windows(2) {
            ensure!(
                w[1].0 >= w[0].0,
                "replay csv line {}: timestamp {} goes back in time (line {} has {}); \
                 sort the file or opt into sort_dedup",
                w[1].2,
                w[1].0,
                w[0].2,
                w[0].0
            );
        }
    }
    let t0 = pts[0].0;
    for p in &mut pts {
        p.0 -= t0;
    }
    let last = pts.last().unwrap().0;
    // Size the grid so the final observation's own slot midpoint is
    // covered — it holds for (at least) half a slot past its timestamp.
    let n = ((last / slot_len + 0.5).ceil() as usize).max(1);
    let mut prices = Vec::with_capacity(n);
    let mut j = 0usize;
    for s in 0..n {
        let mid = (s as f64 + 0.5) * slot_len;
        while j + 1 < pts.len() && pts[j + 1].0 <= mid {
            j += 1;
        }
        prices.push(pts[j].1);
    }
    Ok(PriceTrace::from_prices(prices, slot_len))
}

/// Stable-sort observations by (finite) timestamp and collapse duplicate
/// timestamps, the last-listed observation winning. The one shared
/// implementation of the normalization invariant — used by the
/// `sort_dedup` opt-in here and by the streaming feed loaders
/// ([`crate::feed::load_events`]), so the two paths cannot drift.
/// Callers validate timestamp finiteness first (NaN would panic the sort).
pub(crate) fn sort_dedup_by_time<T>(mut pts: Vec<T>, time: impl Fn(&T) -> f64) -> Vec<T> {
    // Stable sort keeps input order among equal timestamps, so "the
    // last-listed observation wins" is deterministic.
    pts.sort_by(|a, b| time(a).partial_cmp(&time(b)).unwrap());
    let mut out: Vec<T> = Vec::with_capacity(pts.len());
    for p in pts {
        match out.last_mut() {
            Some(last) if time(last) == time(&p) => *last = p,
            _ => out.push(p),
        }
    }
    out
}

/// Load a CSV trace from a file path.
pub fn trace_from_csv_file(path: &str, time_scale: f64, price_scale: f64) -> Result<PriceTrace> {
    trace_from_csv_file_opts(path, time_scale, price_scale, false)
}

/// Load a CSV trace from a file path, optionally sorting-and-deduplicating
/// unordered timestamps (see [`trace_from_csv_opts`]).
pub fn trace_from_csv_file_opts(
    path: &str,
    time_scale: f64,
    price_scale: f64,
    sort_dedup: bool,
) -> Result<PriceTrace> {
    let text = std::fs::read_to_string(path).with_context(|| format!("replay csv '{path}'"))?;
    trace_from_csv_opts(&text, time_scale, price_scale, sort_dedup)
}

/// Tile a replayed trace so it covers at least `horizon` time units (short
/// real histories wrap around; a no-op when the trace is already long
/// enough).
pub fn tile_to_horizon(trace: &PriceTrace, horizon: f64) -> PriceTrace {
    let need = ((horizon / trace.slot_len()).ceil() as usize).max(1);
    let n = trace.num_slots();
    if n >= need {
        return trace.clone();
    }
    let mut prices = Vec::with_capacity(need);
    for s in 0..need {
        prices.push(trace.price_of_slot(s % n));
    }
    PriceTrace::from_prices(prices, trace.slot_len())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_column_is_one_price_per_slot() {
        let t = trace_from_csv("0.2\n0.3\n0.4\n", 1.0, 1.0).unwrap();
        assert_eq!(t.num_slots(), 3);
        assert_eq!(t.price_of_slot(0), 0.2);
        assert_eq!(t.price_of_slot(2), 0.4);
        assert!((t.slot_len() - 1.0 / 12.0).abs() < 1e-12);
    }

    #[test]
    fn two_column_step_function_resamples_to_grid() {
        // Price 0.2 on [0,1), then 0.8: the final observation gets its own
        // slot (13 slots: 12 at 0.2 plus the closing 0.8).
        let t = trace_from_csv("time,price\n0,0.2\n1,0.8\n", 1.0, 1.0).unwrap();
        assert_eq!(t.num_slots(), 13);
        assert_eq!(t.price_of_slot(0), 0.2);
        assert_eq!(t.price_of_slot(11), 0.2);
        assert_eq!(t.price_of_slot(12), 0.8);
        assert_eq!(t.price_at(0.99), 0.2);
        // Longer history: every segment materializes.
        let t2 = trace_from_csv("0,0.2\n1,0.8\n3,0.5\n", 1.0, 1.0).unwrap();
        assert_eq!(t2.num_slots(), 37);
        assert_eq!(t2.price_at(0.5), 0.2);
        assert_eq!(t2.price_at(1.5), 0.8);
        assert_eq!(t2.price_at(2.9), 0.8);
        assert_eq!(t2.price_of_slot(36), 0.5);
    }

    #[test]
    fn scales_apply() {
        // Timestamps in hours (24 h = 1 unit), prices in cents of OD.
        let t = trace_from_csv("0,20\n24,80\n48,20\n", 1.0 / 24.0, 0.01).unwrap();
        assert_eq!(t.num_slots(), 25);
        assert!((t.price_at(0.5) - 0.2).abs() < 1e-12);
        assert!((t.price_at(1.5) - 0.8).abs() < 1e-12);
        assert!((t.price_of_slot(24) - 0.2).abs() < 1e-12);
    }

    #[test]
    fn comments_headers_and_blanks_skipped() {
        let t = trace_from_csv("# comment\ntime,price\n\n0,0.3\n2,0.6\n", 1.0, 1.0).unwrap();
        assert_eq!(t.price_at(0.0), 0.3);
        assert_eq!(t.price_at(1.99), 0.3);
    }

    #[test]
    fn bad_rows_rejected() {
        assert!(trace_from_csv("", 1.0, 1.0).is_err());
        assert!(trace_from_csv("time,price\n", 1.0, 1.0).is_err());
        assert!(trace_from_csv("0,0.2\njunk,row\n", 1.0, 1.0).is_err());
        // Only ONE leading header row is tolerated; a second bad row before
        // any data is corruption, not a header.
        assert!(trace_from_csv("time,price\nstill,bad\n0,0.2\n", 1.0, 1.0).is_err());
        assert!(trace_from_csv("0,-0.5\n", 1.0, 1.0).is_err());
        assert!(trace_from_csv("5,0.2\n1,0.3\n", 1.0, 1.0).is_err()); // unsorted
        assert!(trace_from_csv("0.2\n", 0.0, 1.0).is_err()); // bad scale
    }

    #[test]
    fn out_of_order_error_names_the_offending_line() {
        let err = trace_from_csv("# c\ntime,price\n0,0.2\n5,0.3\n1,0.4\n", 1.0, 1.0)
            .unwrap_err()
            .to_string();
        // Line 5 (`1,0.4`) steps back behind line 4 (`5,0.3`).
        assert!(err.contains("line 5"), "{err}");
        assert!(err.contains("line 4"), "{err}");
        assert!(err.contains("sort_dedup"), "{err}");
    }

    #[test]
    fn sort_dedup_flag_normalizes_unordered_dumps() {
        // Unordered with a duplicate timestamp: strict mode refuses,
        // normalize mode sorts and lets the last-listed duplicate win.
        let text = "5,0.3\n0,0.2\n5,0.9\n2,0.4\n";
        assert!(trace_from_csv(text, 1.0, 1.0).is_err());
        let t = trace_from_csv_opts(text, 1.0, 1.0, true).unwrap();
        let sorted = trace_from_csv("0,0.2\n2,0.4\n5,0.9\n", 1.0, 1.0).unwrap();
        assert_eq!(t.num_slots(), sorted.num_slots());
        for s in 0..t.num_slots() {
            assert_eq!(t.price_of_slot(s), sorted.price_of_slot(s), "slot {s}");
        }
        // Already-sorted input is unchanged by the flag.
        let a = trace_from_csv("0,0.2\n2,0.4\n", 1.0, 1.0).unwrap();
        let b = trace_from_csv_opts("0,0.2\n2,0.4\n", 1.0, 1.0, true).unwrap();
        assert_eq!(a.num_slots(), b.num_slots());
        assert_eq!(a.price_of_slot(1), b.price_of_slot(1));
    }

    #[test]
    fn non_finite_timestamps_error_not_panic() {
        // `parse::<f64>()` happily accepts "nan"/"inf"; both modes must
        // return an error (the sort in normalize mode would panic on NaN).
        for text in ["nan,0.2\n0,0.3\n", "0,0.2\ninf,0.3\n"] {
            for sort in [false, true] {
                let err = trace_from_csv_opts(text, 1.0, 1.0, sort)
                    .unwrap_err()
                    .to_string();
                assert!(err.contains("timestamp"), "{sort}: {err}");
            }
        }
    }

    #[test]
    fn tile_wraps_short_traces() {
        let t = trace_from_csv("0.2\n0.4\n", 1.0, 1.0).unwrap();
        let tiled = tile_to_horizon(&t, 1.0); // 12 slots
        assert_eq!(tiled.num_slots(), 12);
        assert_eq!(tiled.price_of_slot(0), 0.2);
        assert_eq!(tiled.price_of_slot(1), 0.4);
        assert_eq!(tiled.price_of_slot(2), 0.2);
        assert_eq!(tiled.price_of_slot(11), 0.4);
        // Long enough already: untouched.
        let same = tile_to_horizon(&t, 0.1);
        assert_eq!(same.num_slots(), 2);
    }

    #[test]
    fn sample_trace_ships_and_loads() {
        let text = include_str!("../../../examples/traces/spot_sample.csv");
        let t = trace_from_csv(text, 1.0, 1.0).unwrap();
        assert!(t.horizon() > 100.0, "horizon {}", t.horizon());
        // Calm baseline plus surge regimes: prices span a wide band.
        let lo = (0..t.num_slots()).map(|s| t.price_of_slot(s)).fold(f64::INFINITY, f64::min);
        let hi = (0..t.num_slots()).map(|s| t.price_of_slot(s)).fold(0.0, f64::max);
        assert!(lo >= 0.12 && lo < 0.2, "lo {lo}");
        assert!(hi > 0.5 && hi <= 1.0, "hi {hi}");
    }
}
