//! Self-owned instance pool.
//!
//! Tracks `N(t)` — the number of idle self-owned instances at time `t` — and
//! answers the range query `N(t1,t2) = min_{t∈[t1,t2]} N(t)` used by the
//! allocation rule (12). Reservations are slot-quantized (the simulator's
//! clock is slot-based), so the pool is a lazy segment tree over slots with
//! *range add* updates and *range min* queries: both O(log S) on a horizon of
//! S slots, which matters because every task of every job reserves a window.

/// Lazy segment tree: range add, range min over `i64`.
#[derive(Debug, Clone)]
pub struct RangeAddMinTree {
    n: usize,
    /// min of each node's segment (including pending lazy of ancestors? no —
    /// standard convention: node value already includes its own lazy).
    min: Vec<i64>,
    lazy: Vec<i64>,
}

impl RangeAddMinTree {
    pub fn new(n: usize, initial: i64) -> Self {
        let n = n.max(1);
        let mut t = Self {
            n,
            min: vec![0; 4 * n],
            lazy: vec![0; 4 * n],
        };
        if initial != 0 {
            t.add(0, n, initial);
        }
        t
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Add `delta` on the half-open slot range `[lo, hi)`.
    pub fn add(&mut self, lo: usize, hi: usize, delta: i64) {
        if lo >= hi {
            return;
        }
        let hi = hi.min(self.n);
        self.add_rec(1, 0, self.n, lo, hi, delta);
    }

    /// Min over the half-open slot range `[lo, hi)`.
    pub fn min(&self, lo: usize, hi: usize) -> i64 {
        assert!(lo < hi, "empty range query");
        let hi = hi.min(self.n);
        self.min_rec(1, 0, self.n, lo, hi, 0)
    }

    /// Point read.
    pub fn get(&self, i: usize) -> i64 {
        self.min(i, i + 1)
    }

    fn add_rec(&mut self, node: usize, nl: usize, nr: usize, lo: usize, hi: usize, d: i64) {
        if hi <= nl || nr <= lo {
            return;
        }
        if lo <= nl && nr <= hi {
            self.min[node] += d;
            self.lazy[node] += d;
            return;
        }
        let mid = (nl + nr) / 2;
        self.add_rec(node * 2, nl, mid, lo, hi, d);
        self.add_rec(node * 2 + 1, mid, nr, lo, hi, d);
        self.min[node] = self.min[node * 2].min(self.min[node * 2 + 1]) + self.lazy[node];
    }

    fn min_rec(&self, node: usize, nl: usize, nr: usize, lo: usize, hi: usize, acc: i64) -> i64 {
        if lo <= nl && nr <= hi {
            return self.min[node] + acc;
        }
        let mid = (nl + nr) / 2;
        let acc = acc + self.lazy[node];
        if hi <= mid {
            self.min_rec(node * 2, nl, mid, lo, hi, acc)
        } else if lo >= mid {
            self.min_rec(node * 2 + 1, mid, nr, lo, hi, acc)
        } else {
            self.min_rec(node * 2, nl, mid, lo, hi, acc)
                .min(self.min_rec(node * 2 + 1, mid, nr, lo, hi, acc))
        }
    }
}

/// The tenant's pool of `r` self-owned instances over a slotted horizon.
#[derive(Debug, Clone)]
pub struct SelfOwnedPool {
    capacity: u32,
    slot_len: f64,
    tree: RangeAddMinTree,
    /// Total reserved instance-time (for utilization metrics).
    reserved_instance_time: f64,
}

impl SelfOwnedPool {
    /// `capacity` = the paper's `r`; `horizon` in time units; `slot_len` must
    /// match the simulator clock.
    pub fn new(capacity: u32, horizon: f64, slot_len: f64) -> Self {
        let slots = (horizon / slot_len).ceil() as usize + 1;
        Self {
            capacity,
            slot_len,
            tree: RangeAddMinTree::new(slots, capacity as i64),
            reserved_instance_time: 0.0,
        }
    }

    pub fn capacity(&self) -> u32 {
        self.capacity
    }

    fn slot(&self, t: f64) -> usize {
        ((t / self.slot_len).floor() as usize).min(self.tree.len() - 1)
    }

    /// `N(t)`: idle self-owned instances during the slot containing `t`.
    pub fn available_at(&self, t: f64) -> u32 {
        self.tree.get(self.slot(t)).max(0) as u32
    }

    /// `N(t1,t2) = min_{t∈[t1,t2]} N(t)` (Table 1). Inclusive of the slot
    /// containing `t2` only if `t2` lies strictly inside it. A degenerate
    /// window (`t2 ≤ t1`, which arises when a task's realized start lands
    /// exactly on its deadline) reduces to the point query `N(t1)`.
    pub fn available_over(&self, t1: f64, t2: f64) -> u32 {
        if t2 <= t1 {
            return self.available_at(t1);
        }
        let lo = self.slot(t1);
        // Window end exactly on a slot boundary does not occupy the next slot.
        let hi_f = t2 / self.slot_len;
        let hi = if hi_f.fract() == 0.0 {
            hi_f as usize
        } else {
            hi_f.ceil() as usize
        }
        .max(lo + 1);
        self.tree.min(lo, hi).max(0) as u32
    }

    /// Reserve `k` instances for the window `[t1, t2)`. Returns `false`
    /// (and reserves nothing) if fewer than `k` are continuously available.
    pub fn reserve(&mut self, k: u32, t1: f64, t2: f64) -> bool {
        if k == 0 {
            return true;
        }
        if self.available_over(t1, t2) < k {
            return false;
        }
        let lo = self.slot(t1);
        let hi_f = t2 / self.slot_len;
        let hi = if hi_f.fract() == 0.0 {
            hi_f as usize
        } else {
            hi_f.ceil() as usize
        }
        .max(lo + 1);
        self.tree.add(lo, hi, -(k as i64));
        self.reserved_instance_time += k as f64 * (t2 - t1);
        true
    }

    /// Release `k` instances over `[t1, t2)` (early task completion).
    pub fn release(&mut self, k: u32, t1: f64, t2: f64) {
        if k == 0 || t2 <= t1 {
            return;
        }
        let lo = self.slot(t1);
        let hi_f = t2 / self.slot_len;
        let hi = if hi_f.fract() == 0.0 {
            hi_f as usize
        } else {
            hi_f.ceil() as usize
        }
        .max(lo + 1);
        self.tree.add(lo, hi, k as i64);
        self.reserved_instance_time -= k as f64 * (t2 - t1);
    }

    /// Total instance-time reserved so far.
    pub fn reserved_instance_time(&self) -> f64 {
        self.reserved_instance_time
    }

    /// Pool utilization over a horizon `[0, T]`: reserved instance-time over
    /// capacity·T.
    pub fn utilization(&self, horizon: f64) -> f64 {
        if self.capacity == 0 || horizon <= 0.0 {
            return 0.0;
        }
        self.reserved_instance_time / (self.capacity as f64 * horizon)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{for_all, Config};

    #[test]
    fn tree_basic_add_min() {
        let mut t = RangeAddMinTree::new(10, 5);
        assert_eq!(t.min(0, 10), 5);
        t.add(2, 5, -3);
        assert_eq!(t.min(0, 10), 2);
        assert_eq!(t.min(0, 2), 5);
        assert_eq!(t.min(2, 5), 2);
        assert_eq!(t.min(5, 10), 5);
        t.add(0, 10, 1);
        assert_eq!(t.get(3), 3);
        assert_eq!(t.get(0), 6);
    }

    #[test]
    fn tree_matches_naive_array() {
        for_all(Config::cases(200).seed(77), |rng| {
            let n = rng.range_inclusive(1, 64) as usize;
            let mut tree = RangeAddMinTree::new(n, 0);
            let mut naive = vec![0i64; n];
            for _ in 0..30 {
                let a = rng.below(n as u64) as usize;
                let b = rng.range_inclusive(a as u64 + 1, n as u64) as usize;
                if rng.chance(0.6) {
                    let d = rng.range_inclusive(0, 10) as i64 - 5;
                    tree.add(a, b, d);
                    for x in &mut naive[a..b] {
                        *x += d;
                    }
                } else {
                    let want = *naive[a..b].iter().min().unwrap();
                    let got = tree.min(a, b);
                    if want != got {
                        return Err(format!("min({a},{b}): naive {want}, tree {got}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn pool_reserve_and_query() {
        let mut p = SelfOwnedPool::new(10, 100.0, 0.5);
        assert_eq!(p.available_over(0.0, 100.0), 10);
        assert!(p.reserve(4, 10.0, 20.0));
        assert_eq!(p.available_over(10.0, 20.0), 6);
        assert_eq!(p.available_over(0.0, 10.0), 10); // boundary excluded
        assert_eq!(p.available_at(15.0), 6);
        assert!(p.reserve(6, 15.0, 17.0));
        assert_eq!(p.available_over(15.0, 17.0), 0);
        assert!(!p.reserve(1, 16.0, 18.0)); // overlap with exhausted region
        assert_eq!(p.available_over(16.0, 18.0), 0);
    }

    #[test]
    fn pool_release_restores() {
        let mut p = SelfOwnedPool::new(5, 10.0, 0.25);
        assert!(p.reserve(5, 0.0, 10.0));
        assert_eq!(p.available_over(0.0, 10.0), 0);
        p.release(5, 4.0, 10.0);
        assert_eq!(p.available_over(4.0, 10.0), 5);
        assert_eq!(p.available_over(0.0, 4.0), 0);
    }

    #[test]
    fn pool_utilization() {
        let mut p = SelfOwnedPool::new(10, 100.0, 0.5);
        assert!(p.reserve(10, 0.0, 50.0));
        assert!((p.utilization(100.0) - 0.5).abs() < 1e-12);
        p.release(10, 25.0, 50.0);
        assert!((p.utilization(100.0) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn zero_capacity_pool() {
        let p = SelfOwnedPool::new(0, 10.0, 0.5);
        assert_eq!(p.available_over(0.0, 10.0), 0);
        assert_eq!(p.utilization(10.0), 0.0);
    }

    #[test]
    fn pool_never_negative_availability() {
        for_all(Config::cases(100).seed(78), |rng| {
            let mut p = SelfOwnedPool::new(8, 20.0, 0.5);
            for _ in 0..20 {
                let a = rng.uniform(0.0, 19.0);
                let b = a + rng.uniform(0.1, 1.0);
                let k = rng.range_inclusive(0, 9) as u32;
                p.reserve(k, a, b); // may fail; fine
            }
            for _ in 0..20 {
                let a = rng.uniform(0.0, 19.0);
                let b = a + rng.uniform(0.1, 1.0);
                let n = p.available_over(a, b);
                if n > 8 {
                    return Err(format!("availability {n} exceeds capacity"));
                }
            }
            Ok(())
        });
    }
}
