//! DAG → chain transformation of Nagarajan et al. (FlowFlex, MiddleWare'13),
//! as described in Appendix B.1.
//!
//! Construction:
//!
//! 1. Build the *pseudo-schedule*: give every task its full parallelism
//!    `δ_i` and start it as early as possible (`q_i`), so it runs exactly in
//!    `[q_i, q_i + e_i]`.
//! 2. Partition `[0, T_j]` (`T_j = max q_i + e_i`) at all task start/finish
//!    boundaries into intervals `I_1 … I_{l'}` — the minimal partition such
//!    that any task running in an interval runs through all of it.
//! 3. Interval `I_k` becomes pseudo-task `k` with parallelism
//!    `δ(k) = r_k = Σ_{i runs in I_k} δ_i` and size `z(k) = r_k · |I_k|`.
//! 4. Chain the pseudo-tasks: `1 ≺ 2 ≺ … ≺ l'`.
//!
//! Any feasible schedule of the pseudo-job is feasible for the original DAG
//! (parallelism, precedence and deadline respected), so all chain policies
//! of §4 apply to general DAGs.

use super::chain::{ChainJob, ChainTask};
use super::dag::DagJob;

/// Boundary-merge tolerance: boundaries closer than this collapse (guards
/// against floating-point near-duplicates producing sliver intervals).
const EPS: f64 = 1e-9;

/// Transform a DAG job into its chain pseudo-job (Eq. 19: `j' ← transform(j)`).
///
/// Jobs that are already chains pass through unchanged (Algorithm 3).
pub fn transform(job: &DagJob) -> ChainJob {
    if job.is_chain() {
        let mut chain = ChainJob::new(
            job.id,
            job.arrival,
            job.deadline,
            job.tasks.iter().map(ChainTask::from).collect(),
        );
        chain.job_type = job.job_type;
        return chain;
    }

    let q = job.earliest_starts();
    let e: Vec<f64> = job.tasks.iter().map(|t| t.min_exec_time()).collect();

    // Interval boundaries = all starts and finishes.
    let mut bounds: Vec<f64> = Vec::with_capacity(2 * q.len());
    for i in 0..q.len() {
        bounds.push(q[i]);
        bounds.push(q[i] + e[i]);
    }
    bounds.sort_by(|a, b| a.partial_cmp(b).unwrap());
    bounds.dedup_by(|a, b| (*a - *b).abs() < EPS);

    let mut tasks = Vec::with_capacity(bounds.len().saturating_sub(1));
    for w in bounds.windows(2) {
        let (lo, hi) = (w[0], w[1]);
        let len = hi - lo;
        if len < EPS {
            continue;
        }
        let mid = 0.5 * (lo + hi);
        // Total parallelism of tasks running through this interval.
        let r_k: f64 = (0..q.len())
            .filter(|&i| q[i] - EPS <= mid && mid <= q[i] + e[i] + EPS)
            .map(|i| job.tasks[i].parallelism)
            .sum();
        debug_assert!(
            r_k > 0.0,
            "pseudo-schedule gap at [{lo},{hi}] — earliest-start schedule must be gapless"
        );
        tasks.push(ChainTask::new(r_k * len, r_k));
    }

    let mut chain = ChainJob::new(job.id, job.arrival, job.deadline, tasks);
    chain.job_type = job.job_type;
    chain
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{for_all, Config};
    use crate::workload::dag::Task;
    use crate::workload::generator::{GeneratorConfig, JobStream};

    #[test]
    fn chain_passes_through() {
        let dag = DagJob::chain_of(
            7,
            1.0,
            5.0,
            vec![Task::new(1.0, 2.0), Task::new(2.0, 1.0)],
        );
        let chain = transform(&dag);
        assert_eq!(chain.num_tasks(), 2);
        assert_eq!(chain.id, 7);
        assert!((chain.total_work() - dag.total_work()).abs() < 1e-12);
    }

    #[test]
    fn diamond_transform_preserves_work_and_makespan() {
        // 0 -> {1,2} -> 3 with e = 1, 2, 1, 1 (δ all 2 → z = 2e).
        let dag = DagJob::new(
            1,
            0.0,
            10.0,
            vec![
                Task::new(2.0, 2.0),
                Task::new(4.0, 2.0),
                Task::new(2.0, 2.0),
                Task::new(2.0, 2.0),
            ],
            vec![(0, 1), (0, 2), (1, 3), (2, 3)],
        );
        let chain = transform(&dag);
        // Pseudo-schedule: task0 [0,1], task1 [1,3], task2 [1,2], task3 [3,4].
        // Boundaries 0,1,2,3,4 → 4 pseudo-tasks.
        assert_eq!(chain.num_tasks(), 4);
        assert!((chain.total_work() - dag.total_work()).abs() < 1e-12);
        // Pseudo-task parallelism: [2, 4, 2, 2].
        let deltas: Vec<f64> = chain.tasks.iter().map(|t| t.parallelism).collect();
        assert_eq!(deltas, vec![2.0, 4.0, 2.0, 2.0]);
        // Chain makespan equals DAG critical path (pseudo-schedule length).
        assert!((chain.min_makespan() - dag.critical_path()).abs() < 1e-12);
    }

    #[test]
    fn parallel_tasks_merge_into_one_interval() {
        // Two equal independent tasks: single interval with summed δ.
        let dag = DagJob::new(
            2,
            0.0,
            5.0,
            vec![Task::new(2.0, 2.0), Task::new(3.0, 3.0)],
            vec![],
        );
        let chain = transform(&dag);
        assert_eq!(chain.num_tasks(), 1);
        assert_eq!(chain.tasks[0].parallelism, 5.0);
        assert!((chain.tasks[0].size - 5.0).abs() < 1e-12);
    }

    #[test]
    fn transform_properties_on_random_dags() {
        let cfg = GeneratorConfig::paper_default();
        for_all(Config::cases(60).seed(1234), |rng| {
            let mut stream = JobStream::new(cfg.clone(), rng.next_u64());
            let dag = stream.next_job();
            let chain = transform(&dag);
            // (1) workload conserved
            if (chain.total_work() - dag.total_work()).abs() > 1e-6 * dag.total_work() {
                return Err(format!(
                    "work not conserved: {} vs {}",
                    chain.total_work(),
                    dag.total_work()
                ));
            }
            // (2) makespan = critical path
            if (chain.min_makespan() - dag.critical_path()).abs() > 1e-6 {
                return Err(format!(
                    "makespan {} != critical path {}",
                    chain.min_makespan(),
                    dag.critical_path()
                ));
            }
            // (3) pseudo-task count ≤ 2l − 1
            if chain.num_tasks() > 2 * dag.num_tasks() {
                return Err(format!(
                    "too many pseudo-tasks: {} for l={}",
                    chain.num_tasks(),
                    dag.num_tasks()
                ));
            }
            // (4) same window
            if chain.arrival != dag.arrival || chain.deadline != dag.deadline {
                return Err("window changed".into());
            }
            // (5) feasibility preserved (deadline ≥ critical path by
            //     construction of the generator)
            if !chain.is_feasible() {
                return Err("transformed chain infeasible".into());
            }
            Ok(())
        });
    }
}
