//! Workload substrate: DAG jobs, the §6.1 synthetic generator, and the
//! DAG→chain transformation of Nagarajan et al. (Appendix B.1).

pub mod pareto;
pub mod dag;
pub mod chain;
pub mod generator;
pub mod transform;
pub mod mix;

pub use chain::{ChainJob, ChainTask};
pub use dag::{DagJob, Task, TaskId};
pub use generator::{GeneratorConfig, JobStream};
pub use mix::{ArrivalSchedule, MixComponent, MixStream};
pub use transform::transform;
