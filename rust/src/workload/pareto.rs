//! Bounded Pareto sampling for minimum execution times.
//!
//! §6.1: "the minimum execution time e_i of every task i follows a bounded
//! Pareto distribution with a shape parameter ε=7/8, a scale parameter
//! σ=7/32 and a location parameter μ=1/4; the maximum and minimum values of
//! x are set to 2 and 10."
//!
//! The quoted bound sentence is garbled in the paper (a max of 2 with a min
//! of 10 is impossible; a min of 2 contradicts the location 1/4). We read it
//! as a typo and default to bounds `[0.25, 10]` — the location parameter is
//! the natural lower bound of a Pareto-with-location — while exposing the
//! bounds in the config so both readings can be run. See DESIGN.md §3.

use crate::util::rng::Pcg32;

/// Generalized (Type-II style) Pareto with location, truncated to
/// `[lower, upper]` by rejection.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BoundedPareto {
    /// Shape ε (tail index).
    pub shape: f64,
    /// Scale σ.
    pub scale: f64,
    /// Location μ (left shift).
    pub location: f64,
    pub lower: f64,
    pub upper: f64,
}

impl BoundedPareto {
    /// The paper's §6.1 parameters with bounds [0.25, 10].
    pub fn paper_default() -> Self {
        Self {
            shape: 7.0 / 8.0,
            scale: 7.0 / 32.0,
            location: 0.25,
            lower: 0.25,
            upper: 10.0,
        }
    }

    /// Inverse-CDF draw from the *unbounded* Pareto(shape, scale, location):
    /// `x = μ + σ·(U^{-1/ε} − 1)`, i.e. a Lomax shifted by μ.
    pub fn sample_unbounded(&self, rng: &mut Pcg32) -> f64 {
        let u = 1.0 - rng.f64(); // (0, 1]
        self.location + self.scale * (u.powf(-1.0 / self.shape) - 1.0)
    }

    /// Truncated draw (rejection; the acceptance region has large mass for
    /// the paper's parameters, so this terminates fast).
    pub fn sample(&self, rng: &mut Pcg32) -> f64 {
        debug_assert!(self.lower < self.upper);
        for _ in 0..100_000 {
            let x = self.sample_unbounded(rng);
            if x >= self.lower && x <= self.upper {
                return x;
            }
        }
        self.lower
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_within_bounds() {
        let d = BoundedPareto::paper_default();
        let mut rng = Pcg32::new(1);
        for _ in 0..20_000 {
            let x = d.sample(&mut rng);
            assert!((0.25..=10.0).contains(&x), "x={x}");
        }
    }

    #[test]
    fn heavy_tail_present() {
        // With shape 7/8 the tail is heavy: values above 2 must occur.
        let d = BoundedPareto::paper_default();
        let mut rng = Pcg32::new(2);
        let n = 50_000;
        let big = (0..n).filter(|_| d.sample(&mut rng) > 2.0).count();
        assert!(big > n / 100, "tail too light: {big}/{n}");
        assert!(big < n / 2, "tail too heavy: {big}/{n}");
    }

    #[test]
    fn location_is_infimum() {
        let d = BoundedPareto::paper_default();
        let mut rng = Pcg32::new(3);
        let min = (0..50_000)
            .map(|_| d.sample(&mut rng))
            .fold(f64::INFINITY, f64::min);
        assert!(min >= 0.25);
        assert!(min < 0.3, "samples never approach the location: min={min}");
    }

    #[test]
    fn unbounded_inverse_cdf_median() {
        // Median of μ + σ(U^{-1/ε} − 1) at U=0.5.
        let d = BoundedPareto::paper_default();
        let mut rng = Pcg32::new(4);
        let mut xs: Vec<f64> = (0..100_000).map(|_| d.sample_unbounded(&mut rng)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = xs[xs.len() / 2];
        let want = 0.25 + (7.0 / 32.0) * (0.5f64.powf(-8.0 / 7.0) - 1.0);
        assert!((med - want).abs() < 0.01, "median {med} vs {want}");
    }
}
