//! Synthetic workload generator reproducing §6.1.
//!
//! * job arrivals: Poisson process, mean inter-arrival count 4 per unit time;
//! * tasks per job: `l ∈ {7, 49}` (random);
//! * precedence: generation order is the topological order; each pair
//!   `(i1, i2)` gets an edge with probability 0.5; connectivity fix-up wires
//!   successor-less tasks forward and predecessor-less tasks backward;
//! * parallelism bound: `δ_i ∈ {8, 64}` (random);
//! * min execution time: bounded Pareto (see [`super::pareto`]); task size
//!   `z_i = e_i · δ_i`;
//! * deadline: `d_j − a_j = x · e_j^c` with `x ~ U[1, x₀]`,
//!   `x₀ ∈ {1.5, 2, 2.5, 3}` for job types 1–4.

use super::dag::{DagJob, Task};
use super::pareto::BoundedPareto;
use crate::util::rng::Pcg32;

/// Generator configuration (§6.1 defaults).
#[derive(Debug, Clone, PartialEq)]
pub struct GeneratorConfig {
    /// Poisson arrival rate per unit time.
    pub arrival_rate: f64,
    /// Possible task counts.
    pub task_counts: Vec<usize>,
    /// Edge probability between any forward pair.
    pub edge_prob: f64,
    /// Possible parallelism bounds.
    pub parallelism_choices: Vec<f64>,
    /// Min-exec-time distribution.
    pub exec_time: BoundedPareto,
    /// Deadline flexibility upper bound x₀ (job type selects it).
    pub x0: f64,
    /// Job type label (1–4) recorded on generated jobs.
    pub job_type: u8,
}

impl GeneratorConfig {
    /// §6.1 defaults for job type 2 (x₀ = 2).
    pub fn paper_default() -> GeneratorConfig {
        GeneratorConfig::for_job_type(2)
    }

    /// §6.1 parameters for job type `x₂ ∈ 1..=4` (x₀ = 1.5, 2, 2.5, 3).
    pub fn for_job_type(x2: u8) -> GeneratorConfig {
        assert!((1..=4).contains(&x2), "job type must be 1..=4");
        GeneratorConfig {
            arrival_rate: 4.0,
            task_counts: vec![7, 49],
            edge_prob: 0.5,
            parallelism_choices: vec![8.0, 64.0],
            exec_time: BoundedPareto::paper_default(),
            x0: 1.0 + 0.5 * x2 as f64,
            job_type: x2,
        }
    }

    /// Smaller jobs for fast tests/benches.
    pub fn small() -> GeneratorConfig {
        GeneratorConfig {
            task_counts: vec![3, 7],
            ..GeneratorConfig::paper_default()
        }
    }
}

/// Stateful stream of jobs arriving over time.
#[derive(Debug, Clone)]
pub struct JobStream {
    cfg: GeneratorConfig,
    rng: Pcg32,
    clock: f64,
    next_id: u64,
}

impl JobStream {
    pub fn new(cfg: GeneratorConfig, seed: u64) -> JobStream {
        JobStream {
            cfg,
            rng: Pcg32::new(seed ^ 0x10B5),
            clock: 0.0,
            next_id: 0,
        }
    }

    pub fn config(&self) -> &GeneratorConfig {
        &self.cfg
    }

    /// Generate the next arriving job (advances the Poisson clock).
    pub fn next_job(&mut self) -> DagJob {
        // Exponential inter-arrival with rate λ (mean 1/λ).
        self.clock += self.rng.exponential(1.0 / self.cfg.arrival_rate);
        let arrival = self.clock;
        let id = self.next_id;
        self.next_id += 1;
        self.generate_at(id, arrival)
    }

    /// Generate `n` jobs.
    pub fn take_jobs(&mut self, n: usize) -> Vec<DagJob> {
        (0..n).map(|_| self.next_job()).collect()
    }

    /// Generate a job with a fixed arrival time (no clock advance).
    pub fn generate_at(&mut self, id: u64, arrival: f64) -> DagJob {
        let l = {
            let k = self.rng.below(self.cfg.task_counts.len() as u64) as usize;
            self.cfg.task_counts[k]
        };
        let tasks: Vec<Task> = (0..l)
            .map(|_| {
                let delta = {
                    let k = self.rng.below(self.cfg.parallelism_choices.len() as u64) as usize;
                    self.cfg.parallelism_choices[k]
                };
                let e = self.cfg.exec_time.sample(&mut self.rng);
                Task::new(e * delta, delta)
            })
            .collect();

        // Random forward edges (generation order = topological order).
        let mut edges: Vec<(usize, usize)> = Vec::new();
        for i1 in 0..l {
            for i2 in (i1 + 1)..l {
                if self.rng.chance(self.cfg.edge_prob) {
                    edges.push((i1, i2));
                }
            }
        }

        // Connectivity fix-up (§6.1): successor-less non-final tasks get a
        // random later successor; predecessor-less non-initial tasks get a
        // random earlier predecessor.
        let mut has_succ = vec![false; l];
        let mut has_pred = vec![false; l];
        for &(u, v) in &edges {
            has_succ[u] = true;
            has_pred[v] = true;
        }
        for i in 0..l.saturating_sub(1) {
            if !has_succ[i] {
                let v = self.rng.range_inclusive(i as u64 + 1, l as u64 - 1) as usize;
                edges.push((i, v));
                has_pred[v] = true;
                has_succ[i] = true;
            }
        }
        for i in 1..l {
            if !has_pred[i] {
                let u = self.rng.below(i as u64) as usize;
                edges.push((u, i));
                has_pred[i] = true;
            }
        }
        edges.sort();
        edges.dedup();

        let mut job = DagJob::new(id, arrival, arrival + 1.0, tasks, edges);
        // Deadline: x·e_c with x ~ U[1, x₀].
        let x = self.rng.uniform(1.0, self.cfg.x0);
        job.deadline = arrival + x * job.critical_path();
        job.job_type = self.cfg.job_type;
        debug_assert!(job.validate().is_ok());
        job
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{for_all, Config};

    #[test]
    fn arrival_rate_matches_poisson() {
        let mut s = JobStream::new(GeneratorConfig::paper_default(), 1);
        let jobs = s.take_jobs(4000);
        let horizon = jobs.last().unwrap().arrival;
        let rate = jobs.len() as f64 / horizon;
        assert!((rate - 4.0).abs() < 0.25, "rate={rate}");
        // Arrivals strictly increasing.
        for w in jobs.windows(2) {
            assert!(w[1].arrival >= w[0].arrival);
        }
    }

    #[test]
    fn jobs_match_section_6_1_shape() {
        let mut s = JobStream::new(GeneratorConfig::paper_default(), 2);
        let mut seen7 = false;
        let mut seen49 = false;
        for job in s.take_jobs(60) {
            assert!(job.num_tasks() == 7 || job.num_tasks() == 49);
            seen7 |= job.num_tasks() == 7;
            seen49 |= job.num_tasks() == 49;
            for t in &job.tasks {
                assert!(t.parallelism == 8.0 || t.parallelism == 64.0);
                let e = t.min_exec_time();
                assert!((0.25..=10.0).contains(&e), "e_i={e}");
            }
            assert!(job.validate().is_ok());
            // deadline ∈ [a + e_c, a + x₀·e_c]
            let cp = job.critical_path();
            let rel = job.window();
            assert!(rel >= cp - 1e-9 && rel <= 2.0 * cp + 1e-9, "rel={rel} cp={cp}");
        }
        assert!(seen7 && seen49);
    }

    #[test]
    fn connectivity_fixup_leaves_no_isolated_middle_tasks() {
        for_all(Config::cases(40).seed(3), |rng| {
            let mut s = JobStream::new(GeneratorConfig::paper_default(), rng.next_u64());
            let job = s.next_job();
            let l = job.num_tasks();
            let mut has_succ = vec![false; l];
            let mut has_pred = vec![false; l];
            for &(u, v) in &job.edges {
                has_succ[u] = true;
                has_pred[v] = true;
            }
            for i in 0..l - 1 {
                if !has_succ[i] {
                    return Err(format!("task {i} of {l} has no successor"));
                }
            }
            for i in 1..l {
                if !has_pred[i] {
                    return Err(format!("task {i} of {l} has no predecessor"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn job_types_change_flexibility() {
        let mut tight = JobStream::new(GeneratorConfig::for_job_type(1), 5);
        let mut loose = JobStream::new(GeneratorConfig::for_job_type(4), 5);
        let avg = |jobs: Vec<DagJob>| {
            let xs: Vec<f64> = jobs
                .iter()
                .map(|j| j.window() / j.critical_path())
                .collect();
            xs.iter().sum::<f64>() / xs.len() as f64
        };
        let a1 = avg(tight.take_jobs(300));
        let a4 = avg(loose.take_jobs(300));
        assert!(a1 < 1.3, "type-1 mean flexibility {a1}");
        assert!(a4 > 1.7, "type-4 mean flexibility {a4}");
    }

    #[test]
    fn deterministic_under_seed() {
        let a = JobStream::new(GeneratorConfig::paper_default(), 9).take_jobs(10);
        let b = JobStream::new(GeneratorConfig::paper_default(), 9).take_jobs(10);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.edges, y.edges);
            assert_eq!(x.tasks.len(), y.tasks.len());
        }
    }
}
