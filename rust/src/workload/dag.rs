//! DAG-structured jobs (§3.2).
//!
//! A job `j` is a DAG whose nodes are *malleable tasks*: task `i` has a
//! workload `z_i` (instance-time), a parallelism bound `δ_i` (max concurrent
//! instances), hence a minimum execution time `e_i = z_i / δ_i` (Eq. 1).
//! Edges are precedence constraints. The job arrives at `a_j` and must
//! finish by `d_j`.

use std::collections::VecDeque;

pub type TaskId = usize;

/// A malleable task.
#[derive(Debug, Clone, PartialEq)]
pub struct Task {
    /// Workload `z_i` in instance-time.
    pub size: f64,
    /// Parallelism bound `δ_i`.
    pub parallelism: f64,
}

impl Task {
    pub fn new(size: f64, parallelism: f64) -> Task {
        assert!(size > 0.0 && parallelism > 0.0);
        Task { size, parallelism }
    }

    /// Minimum execution time `e_i = z_i / δ_i` (Eq. 1).
    pub fn min_exec_time(&self) -> f64 {
        self.size / self.parallelism
    }
}

/// A DAG job.
#[derive(Debug, Clone)]
pub struct DagJob {
    pub id: u64,
    pub arrival: f64,
    pub deadline: f64,
    pub tasks: Vec<Task>,
    /// Edges `(u, v)` meaning `u ≺ v` (u must finish before v starts).
    pub edges: Vec<(TaskId, TaskId)>,
    /// Which of the paper's four flexibility classes generated this job
    /// (x₂ ∈ 1..=4); 0 for hand-built jobs.
    pub job_type: u8,
}

impl DagJob {
    pub fn new(
        id: u64,
        arrival: f64,
        deadline: f64,
        tasks: Vec<Task>,
        edges: Vec<(TaskId, TaskId)>,
    ) -> DagJob {
        let job = DagJob {
            id,
            arrival,
            deadline,
            tasks,
            edges,
            job_type: 0,
        };
        debug_assert!(job.validate().is_ok(), "{:?}", job.validate());
        job
    }

    pub fn num_tasks(&self) -> usize {
        self.tasks.len()
    }

    /// Total workload `Z_j = Σ z_i`.
    pub fn total_work(&self) -> f64 {
        self.tasks.iter().map(|t| t.size).sum()
    }

    /// Relative deadline `d_j − a_j`.
    pub fn window(&self) -> f64 {
        self.deadline - self.arrival
    }

    /// Structural validation: edge endpoints in range, acyclic, positive
    /// window.
    pub fn validate(&self) -> Result<(), String> {
        if self.tasks.is_empty() {
            return Err("job has no tasks".into());
        }
        if self.deadline <= self.arrival {
            return Err(format!(
                "deadline {} not after arrival {}",
                self.deadline, self.arrival
            ));
        }
        for &(u, v) in &self.edges {
            if u >= self.tasks.len() || v >= self.tasks.len() {
                return Err(format!("edge ({u},{v}) out of range"));
            }
            if u == v {
                return Err(format!("self-loop at {u}"));
            }
        }
        if self.topo_order().is_none() {
            return Err("precedence graph has a cycle".into());
        }
        Ok(())
    }

    /// Adjacency lists (successors).
    pub fn successors(&self) -> Vec<Vec<TaskId>> {
        let mut adj = vec![Vec::new(); self.tasks.len()];
        for &(u, v) in &self.edges {
            adj[u].push(v);
        }
        adj
    }

    /// In-degrees.
    pub fn in_degrees(&self) -> Vec<usize> {
        let mut deg = vec![0; self.tasks.len()];
        for &(_, v) in &self.edges {
            deg[v] += 1;
        }
        deg
    }

    /// Kahn topological order; `None` if cyclic.
    pub fn topo_order(&self) -> Option<Vec<TaskId>> {
        let adj = self.successors();
        let mut deg = self.in_degrees();
        let mut queue: VecDeque<TaskId> =
            (0..self.tasks.len()).filter(|&i| deg[i] == 0).collect();
        let mut order = Vec::with_capacity(self.tasks.len());
        while let Some(u) = queue.pop_front() {
            order.push(u);
            for &v in &adj[u] {
                deg[v] -= 1;
                if deg[v] == 0 {
                    queue.push_back(v);
                }
            }
        }
        (order.len() == self.tasks.len()).then_some(order)
    }

    /// Earliest start times `q_i` of the pseudo-schedule (App. B.1): every
    /// task gets δ_i instances and starts as early as possible, so
    /// `q_i = max(0, max_{i'≺i} (q_{i'} + e_{i'}))` relative to arrival.
    pub fn earliest_starts(&self) -> Vec<f64> {
        let order = self.topo_order().expect("validated DAG");
        let adj = self.successors();
        let mut q = vec![0.0f64; self.tasks.len()];
        for &u in &order {
            let finish = q[u] + self.tasks[u].min_exec_time();
            for &v in &adj[u] {
                if finish > q[v] {
                    q[v] = finish;
                }
            }
        }
        q
    }

    /// Critical-path length `e_j^c` — the minimum time to finish the job
    /// with all parallelism bounds saturated (§6.1 uses it to set
    /// deadlines).
    pub fn critical_path(&self) -> f64 {
        let q = self.earliest_starts();
        q.iter()
            .zip(&self.tasks)
            .map(|(qi, t)| qi + t.min_exec_time())
            .fold(0.0, f64::max)
    }

    /// Single-task convenience constructor.
    pub fn single(id: u64, arrival: f64, deadline: f64, size: f64, parallelism: f64) -> DagJob {
        DagJob::new(id, arrival, deadline, vec![Task::new(size, parallelism)], vec![])
    }

    /// Chain-of-tasks convenience constructor (tasks already in chain
    /// order).
    pub fn chain_of(id: u64, arrival: f64, deadline: f64, tasks: Vec<Task>) -> DagJob {
        let edges = (1..tasks.len()).map(|i| (i - 1, i)).collect();
        DagJob::new(id, arrival, deadline, tasks, edges)
    }

    /// Is the precedence graph already a simple chain `0 ≺ 1 ≺ … ≺ l−1`?
    pub fn is_chain(&self) -> bool {
        if self.edges.len() != self.tasks.len().saturating_sub(1) {
            return false;
        }
        let mut want: Vec<(TaskId, TaskId)> = (1..self.tasks.len()).map(|i| (i - 1, i)).collect();
        let mut got = self.edges.clone();
        want.sort();
        got.sort();
        got.dedup();
        want == got
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The worked example of §4.1.1: 4 tasks, chain, sizes 1.5/0.5/2.5/0.5,
    /// parallelism 2/1/3/1, window [0,4].
    pub fn paper_chain_example() -> DagJob {
        DagJob::chain_of(
            1,
            0.0,
            4.0,
            vec![
                Task::new(1.5, 2.0),
                Task::new(0.5, 1.0),
                Task::new(2.5, 3.0),
                Task::new(0.5, 1.0),
            ],
        )
    }

    #[test]
    fn min_exec_time_eq1() {
        let t = Task::new(2.0, 4.0);
        assert_eq!(t.min_exec_time(), 0.5);
    }

    #[test]
    fn chain_example_critical_path() {
        let j = paper_chain_example();
        // e = (0.75, 0.5, 5/6, 0.5) summed = 2.583…
        let want = 0.75 + 0.5 + 2.5 / 3.0 + 0.5;
        assert!((j.critical_path() - want).abs() < 1e-12);
        assert!(j.is_chain());
        assert!((j.total_work() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn diamond_dag_critical_path() {
        // 0 -> {1, 2} -> 3 ; e = 1, 2, 3, 1 → cp = 1 + 3 + 1 = 5.
        let j = DagJob::new(
            1,
            0.0,
            10.0,
            vec![
                Task::new(1.0, 1.0),
                Task::new(2.0, 1.0),
                Task::new(3.0, 1.0),
                Task::new(1.0, 1.0),
            ],
            vec![(0, 1), (0, 2), (1, 3), (2, 3)],
        );
        assert_eq!(j.critical_path(), 5.0);
        let q = j.earliest_starts();
        assert_eq!(q, vec![0.0, 1.0, 1.0, 4.0]);
        assert!(!j.is_chain());
    }

    #[test]
    fn topo_detects_cycle() {
        let j = DagJob {
            id: 0,
            arrival: 0.0,
            deadline: 1.0,
            tasks: vec![Task::new(1.0, 1.0), Task::new(1.0, 1.0)],
            edges: vec![(0, 1), (1, 0)],
            job_type: 0,
        };
        assert!(j.topo_order().is_none());
        assert!(j.validate().is_err());
    }

    #[test]
    fn validate_rejects_bad_edges_and_windows() {
        let t = vec![Task::new(1.0, 1.0)];
        let j = DagJob {
            id: 0,
            arrival: 0.0,
            deadline: 1.0,
            tasks: t.clone(),
            edges: vec![(0, 5)],
            job_type: 0,
        };
        assert!(j.validate().is_err());
        let j2 = DagJob {
            id: 0,
            arrival: 2.0,
            deadline: 1.0,
            tasks: t,
            edges: vec![],
            job_type: 0,
        };
        assert!(j2.validate().is_err());
    }

    #[test]
    fn independent_tasks_critical_path_is_max() {
        let j = DagJob::new(
            0,
            0.0,
            10.0,
            vec![Task::new(4.0, 2.0), Task::new(9.0, 3.0)],
            vec![],
        );
        assert_eq!(j.critical_path(), 3.0);
    }
}
