//! Workload mixes and arrival-rate schedules.
//!
//! The §6.1 generator produces one homogeneous stream (one job type, a
//! constant Poisson rate). Scenarios compose heterogeneous worlds: a
//! [`MixStream`] draws each arriving job from a weighted set of
//! [`GeneratorConfig`] components (e.g. 3:1 deadline-tight to flexible) and
//! modulates the arrival rate through a cyclic [`ArrivalSchedule`]
//! (bursty/diurnal load). Everything stays a deterministic function of the
//! seed.

use super::dag::DagJob;
use super::generator::{GeneratorConfig, JobStream};
use crate::util::rng::Pcg32;

/// One component of a workload mix: a job type with a sampling weight.
#[derive(Debug, Clone, PartialEq)]
pub struct MixComponent {
    /// §6.1 flexibility class x₂ ∈ 1..=4.
    pub job_type: u8,
    /// Relative sampling weight (need not be normalized).
    pub weight: f64,
}

/// A cyclic piecewise-constant arrival-rate schedule.
#[derive(Debug, Clone, PartialEq)]
pub struct ArrivalSchedule {
    /// Base Poisson rate λ (jobs per unit time).
    pub base_rate: f64,
    /// Cyclic `(duration, multiplier)` phases; empty = constant rate.
    pub phases: Vec<(f64, f64)>,
}

impl ArrivalSchedule {
    pub fn constant(rate: f64) -> ArrivalSchedule {
        ArrivalSchedule {
            base_rate: rate,
            phases: Vec::new(),
        }
    }

    /// The instantaneous rate at time `t` (phases cycle forever).
    pub fn rate_at(&self, t: f64) -> f64 {
        if self.phases.is_empty() {
            return self.base_rate;
        }
        let cycle: f64 = self.phases.iter().map(|p| p.0).sum();
        if cycle <= 0.0 {
            return self.base_rate;
        }
        let mut pos = t.rem_euclid(cycle);
        for &(d, m) in &self.phases {
            if pos < d {
                return self.base_rate * m;
            }
            pos -= d;
        }
        self.base_rate * self.phases.last().expect("non-empty").1
    }
}

/// A stream of jobs drawn from a weighted component mix under an arrival
/// schedule. Per-component [`JobStream`]s get independent seed-derived RNG
/// streams, so adding a component never perturbs the others' draws.
#[derive(Debug, Clone)]
pub struct MixStream {
    weights: Vec<f64>,
    schedule: ArrivalSchedule,
    streams: Vec<JobStream>,
    rng: Pcg32,
    clock: f64,
    next_id: u64,
}

impl MixStream {
    pub fn new(
        components: Vec<(GeneratorConfig, f64)>,
        schedule: ArrivalSchedule,
        seed: u64,
    ) -> MixStream {
        assert!(!components.is_empty(), "empty workload mix");
        let weights: Vec<f64> = components.iter().map(|c| c.1).collect();
        assert!(
            weights.iter().all(|w| *w >= 0.0) && weights.iter().sum::<f64>() > 0.0,
            "mix weights must be non-negative with positive total: {weights:?}"
        );
        assert!(schedule.base_rate > 0.0, "arrival rate must be positive");
        let streams = components
            .into_iter()
            .enumerate()
            .map(|(k, (cfg, _))| {
                JobStream::new(cfg, seed ^ (k as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15))
            })
            .collect();
        MixStream {
            weights,
            schedule,
            streams,
            rng: Pcg32::new(seed ^ 0x3117_A911),
            clock: 0.0,
            next_id: 0,
        }
    }

    /// Generate the next arriving job. The inter-arrival gap is drawn at
    /// the rate in effect at the current clock — exact for constant
    /// schedules; for piecewise ones the phase boundary is resolved at
    /// arrival granularity, which preserves the burst structure without a
    /// thinning loop.
    pub fn next_job(&mut self) -> DagJob {
        let rate = self.schedule.rate_at(self.clock).max(1e-9);
        self.clock += self.rng.exponential(1.0 / rate);
        let k = self.rng.weighted_index(&self.weights);
        let id = self.next_id;
        self.next_id += 1;
        self.streams[k].generate_at(id, self.clock)
    }

    pub fn take_jobs(&mut self, n: usize) -> Vec<DagJob> {
        (0..n).map(|_| self.next_job()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_type_mix(seed: u64, w1: f64, w2: f64) -> MixStream {
        MixStream::new(
            vec![
                (GeneratorConfig::for_job_type(1), w1),
                (GeneratorConfig::for_job_type(4), w2),
            ],
            ArrivalSchedule::constant(4.0),
            seed,
        )
    }

    #[test]
    fn mix_respects_weights() {
        let mut s = two_type_mix(1, 3.0, 1.0);
        let jobs = s.take_jobs(2000);
        let tight = jobs.iter().filter(|j| j.job_type == 1).count() as f64;
        let frac = tight / jobs.len() as f64;
        assert!((frac - 0.75).abs() < 0.04, "type-1 fraction {frac}");
    }

    #[test]
    fn arrivals_monotone_and_ids_unique() {
        let mut s = two_type_mix(2, 1.0, 1.0);
        let jobs = s.take_jobs(300);
        for w in jobs.windows(2) {
            assert!(w[1].arrival >= w[0].arrival);
        }
        let mut ids: Vec<u64> = jobs.iter().map(|j| j.id).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), jobs.len());
    }

    #[test]
    fn deterministic_under_seed() {
        let a = two_type_mix(9, 1.0, 2.0).take_jobs(50);
        let b = two_type_mix(9, 1.0, 2.0).take_jobs(50);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.job_type, y.job_type);
            assert_eq!(x.edges, y.edges);
        }
    }

    #[test]
    fn schedule_cycles() {
        let s = ArrivalSchedule {
            base_rate: 4.0,
            phases: vec![(6.0, 0.25), (2.0, 4.0)],
        };
        assert_eq!(s.rate_at(0.0), 1.0);
        assert_eq!(s.rate_at(5.9), 1.0);
        assert_eq!(s.rate_at(6.5), 16.0);
        assert_eq!(s.rate_at(8.1), 1.0); // wrapped into the next cycle
        assert_eq!(ArrivalSchedule::constant(3.0).rate_at(100.0), 3.0);
    }

    #[test]
    fn bursty_schedule_clusters_arrivals() {
        let mut s = MixStream::new(
            vec![(GeneratorConfig::small(), 1.0)],
            ArrivalSchedule {
                base_rate: 4.0,
                phases: vec![(6.0, 0.25), (2.0, 4.0)],
            },
            5,
        );
        let jobs = s.take_jobs(2000);
        let horizon = jobs.last().unwrap().arrival;
        // Average rate over a cycle: (6·1 + 2·16)/8 = 4.75 — but gaps are
        // drawn at the rate at the gap's *start*, which biases toward long
        // calm gaps; just check bursts exist: many arrivals share burst
        // windows (rate 16) so the minimum gap is far below the calm mean.
        let mut min_gap = f64::INFINITY;
        for w in jobs.windows(2) {
            min_gap = min_gap.min(w[1].arrival - w[0].arrival);
        }
        assert!(min_gap < 0.05, "min gap {min_gap}");
        assert!(horizon > 100.0, "horizon {horizon}");
    }

    #[test]
    #[should_panic]
    fn zero_weight_total_rejected() {
        MixStream::new(
            vec![(GeneratorConfig::small(), 0.0)],
            ArrivalSchedule::constant(4.0),
            1,
        );
    }
}
