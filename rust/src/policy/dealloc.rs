//! Algorithm 1 — `Dealloc(x)`: the optimal allocation of time-window sizes
//! to the tasks of a chain job (Prop. 4.3).
//!
//! Every task first gets its minimum execution time `e_i`; the remaining
//! slack `ω = (d_j − a_j) − Σ e_i` is then handed out in non-increasing
//! order of parallelism bound `δ_i`: a task with bound `δ` converts slack
//! into spot workload at rate `β/(1−β)·δ` (Prop. 4.2) until its window
//! reaches `e_i/β` (saturation), so the greedy order is optimal for the ILP
//! (10). The task at which slack runs out receives the remainder (and the
//! very last saturated task absorbs any slack left over after everyone
//! saturates, so the windows always tile `[a_j, d_j]` exactly).

use crate::workload::ChainJob;

/// Result of the deadline allocation for one job.
#[derive(Debug, Clone, PartialEq)]
pub struct WindowAllocation {
    /// `ŝ_i` — window size of task i (chain order).
    pub sizes: Vec<f64>,
    /// The β (or β₀) the allocation was computed with.
    pub beta: f64,
}

impl WindowAllocation {
    /// Slack beyond the minimum execution time, `x_i = ŝ_i − e_i`.
    pub fn slack_of(&self, job: &ChainJob) -> Vec<f64> {
        self.sizes
            .iter()
            .zip(&job.tasks)
            .map(|(s, t)| s - t.min_exec_time())
            .collect()
    }
}

/// `Dealloc(x)` (Algorithm 1). `beta` is the availability parameter — the
/// spot availability β, or the sufficiency index β₀ when self-owned
/// instances dominate (Algorithm 2 lines 1–5 pick which).
///
/// Infeasible jobs (window < Σe_i) still get an allocation: every task
/// receives `e_i` and the job will overrun; callers check
/// [`ChainJob::is_feasible`] upstream.
pub fn dealloc(job: &ChainJob, beta: f64) -> WindowAllocation {
    assert!(beta > 0.0 && beta <= 1.0, "beta={beta}");
    let l = job.num_tasks();
    let e: Vec<f64> = job.tasks.iter().map(|t| t.min_exec_time()).collect();
    let mut sizes = e.clone();
    let mut omega = job.slack().max(0.0);

    // Tasks in non-increasing order of parallelism bound (stable on index:
    // ties resolve to the earlier task, matching the paper's notation
    // δ_{i1} ≥ δ_{i2} ≥ …).
    let mut order: Vec<usize> = (0..l).collect();
    order.sort_by(|&a, &b| {
        job.tasks[b]
            .parallelism
            .partial_cmp(&job.tasks[a].parallelism)
            .unwrap()
            .then(a.cmp(&b))
    });

    let mut last = None;
    for &i in &order {
        if omega <= 0.0 {
            break;
        }
        // Saturating slack: window e_i/β ⇔ extra e_i·(1−β)/β.
        let need = e[i] * (1.0 - beta) / beta;
        let grant = need.min(omega);
        sizes[i] += grant;
        omega -= grant;
        last = Some(i);
    }
    // All tasks saturated but slack remains: give it to the last task
    // touched (lines 6–7 put the remainder on task i_{l*}); it buys no
    // expected spot workload but keeps Σŝ_i = d_j − a_j so the executor's
    // task deadlines tile the whole window.
    if omega > 0.0 {
        let i = last.unwrap_or(*order.first().expect("non-empty chain"));
        sizes[i] += omega;
    }

    WindowAllocation { sizes, beta }
}

/// Convert window sizes to absolute task deadlines `ς_1 < … < ς_l`
/// (Eq. 4): `ς_i = a_j + Σ_{k≤i} ŝ_k`.
pub fn windows_to_deadlines(job: &ChainJob, alloc: &WindowAllocation) -> Vec<f64> {
    let mut t = job.arrival;
    alloc
        .sizes
        .iter()
        .map(|s| {
            t += s;
            t
        })
        .collect()
}

/// Expected total spot workload of an allocation (objective of ILP (10)),
/// used by tests and the brute-force optimality check.
pub fn expected_spot_workload(job: &ChainJob, alloc: &WindowAllocation) -> f64 {
    job.tasks
        .iter()
        .zip(&alloc.sizes)
        .map(|(t, &s)| super::single_task::spot_capacity(t.size, t.parallelism, s, alloc.beta))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{for_all, Config};
    use crate::util::rng::Pcg32;
    use crate::workload::ChainTask;

    #[test]
    fn paper_example_allocation() {
        // §4.1.1: optimal window sizes 4/3, 1/2, 5/3, 1/2; spot workload 22/6.
        let job = ChainJob::paper_example();
        let alloc = dealloc(&job, 0.5);
        let want = [4.0 / 3.0, 0.5, 5.0 / 3.0, 0.5];
        for (got, want) in alloc.sizes.iter().zip(want) {
            assert!((got - want).abs() < 1e-12, "{:?}", alloc.sizes);
        }
        let zo = expected_spot_workload(&job, &alloc);
        assert!((zo - 22.0 / 6.0).abs() < 1e-12, "zo={zo}");
        // Deadlines are cumulative and end exactly at d_j.
        let dl = windows_to_deadlines(&job, &alloc);
        assert!((dl[3] - 4.0).abs() < 1e-12);
        assert!(dl.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn windows_tile_the_job_window() {
        for_all(Config::cases(200).seed(10), |rng| {
            let job = random_chain(rng);
            let beta = rng.uniform(0.1, 1.0);
            let alloc = dealloc(&job, beta);
            let total: f64 = alloc.sizes.iter().sum();
            if (total - job.window()).abs() > 1e-9 * job.window().max(1.0) {
                return Err(format!("Σŝ={total} != window={}", job.window()));
            }
            for (s, t) in alloc.sizes.iter().zip(&job.tasks) {
                if *s < t.min_exec_time() - 1e-9 {
                    return Err(format!("window {s} < e={}", t.min_exec_time()));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn optimality_vs_brute_force() {
        // Exhaustive grid search over slack splits on small chains cannot
        // beat Dealloc (Prop. 4.3).
        for_all(Config::cases(60).seed(11), |rng| {
            let l = rng.range_inclusive(2, 3) as usize;
            let tasks: Vec<ChainTask> = (0..l)
                .map(|_| {
                    ChainTask::new(
                        rng.uniform(0.5, 4.0),
                        [1.0, 2.0, 4.0][rng.below(3) as usize],
                    )
                })
                .collect();
            let makespan: f64 = tasks.iter().map(|t| t.min_exec_time()).sum();
            let omega = rng.uniform(0.0, 2.0 * makespan);
            let job = ChainJob::new(0, 0.0, makespan + omega, tasks);
            let beta = [0.3, 0.5, 1.0 / 1.3][rng.below(3) as usize];

            let best_greedy = expected_spot_workload(&job, &dealloc(&job, beta));

            // Brute force: split ω over l tasks on a grid of 21 steps.
            let steps = 20;
            let mut best = 0.0f64;
            let mut splits = vec![0usize; l];
            loop {
                let used: usize = splits.iter().sum();
                if used <= steps {
                    let sizes: Vec<f64> = job
                        .tasks
                        .iter()
                        .zip(&splits)
                        .map(|(t, &k)| {
                            t.min_exec_time() + omega * k as f64 / steps as f64
                        })
                        .collect();
                    let total: f64 = sizes.iter().sum();
                    if total <= job.window() + 1e-9 {
                        let alloc = WindowAllocation { sizes, beta };
                        best = best.max(expected_spot_workload(&job, &alloc));
                    }
                }
                // Odometer increment.
                let mut i = 0;
                loop {
                    if i == l {
                        break;
                    }
                    splits[i] += 1;
                    if splits[i] <= steps {
                        break;
                    }
                    splits[i] = 0;
                    i += 1;
                }
                if i == l {
                    break;
                }
            }
            if best > best_greedy + 1e-6 {
                return Err(format!("brute force {best} beats Dealloc {best_greedy}"));
            }
            Ok(())
        });
    }

    #[test]
    fn higher_beta_never_lowers_spot_workload() {
        for_all(Config::cases(150).seed(12), |rng| {
            let job = random_chain(rng);
            let b1 = rng.uniform(0.1, 0.9);
            let b2 = rng.uniform(b1, 1.0);
            let z1 = expected_spot_workload(&job, &dealloc(&job, b1));
            let z2 = expected_spot_workload(&job, &dealloc(&job, b2));
            if z2 + 1e-9 < z1 {
                return Err(format!("β↑ lowered z^o: {z1} -> {z2}"));
            }
            Ok(())
        });
    }

    #[test]
    fn beta_one_gives_no_extra_slack_needs() {
        // β=1: saturation needs are zero, remainder lands on the largest-δ
        // task; every task keeps at least e_i and totals still tile.
        let job = ChainJob::paper_example();
        let alloc = dealloc(&job, 1.0);
        let total: f64 = alloc.sizes.iter().sum();
        assert!((total - 4.0).abs() < 1e-12);
        assert!((expected_spot_workload(&job, &alloc) - job.total_work()).abs() < 1e-12);
    }

    #[test]
    fn infeasible_job_gets_min_windows() {
        let job = ChainJob::new(
            0,
            0.0,
            1.0,
            vec![ChainTask::new(2.0, 1.0), ChainTask::new(2.0, 1.0)],
        );
        let alloc = dealloc(&job, 0.5);
        assert_eq!(alloc.sizes, vec![2.0, 2.0]);
    }

    fn random_chain(rng: &mut Pcg32) -> ChainJob {
        let l = rng.range_inclusive(1, 8) as usize;
        let tasks: Vec<ChainTask> = (0..l)
            .map(|_| ChainTask::new(rng.uniform(0.2, 5.0), rng.uniform(1.0, 64.0)))
            .collect();
        let makespan: f64 = tasks.iter().map(|t| t.min_exec_time()).sum();
        let window = makespan * rng.uniform(1.0, 3.0);
        ChainJob::new(0, 0.0, window, tasks)
    }
}
