//! Baseline heuristics of §6.1 used to measure the proposed policies.
//!
//! * **Even** — pre-allocate consecutive task windows with the slack
//!   `ω = d_j − a_j − Σ e_i` split evenly: `x_i = ω / l`.
//! * **Greedy** — no pre-allocation: bid `δ_i` spot instances for the
//!   current task until the critical path of the *remaining* workload
//!   reaches the remaining window, then run everything on-demand at full
//!   parallelism. (Implemented in the executor as a runtime strategy; this
//!   module computes its switch condition.)
//! * the **naive self-owned** rule lives in [`super::selfowned::naive_allocation`].

use super::dealloc::WindowAllocation;
use crate::workload::ChainJob;

/// Which deadline pre-allocation a run uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeadlinePolicy {
    /// Algorithm 1 (the paper's optimal allocation).
    Dealloc,
    /// The Even baseline.
    Even,
}

/// Even window allocation: `ŝ_i = e_i + ω/l`.
pub fn even_windows(job: &ChainJob) -> WindowAllocation {
    let l = job.num_tasks() as f64;
    let share = job.slack().max(0.0) / l;
    WindowAllocation {
        sizes: job
            .tasks
            .iter()
            .map(|t| t.min_exec_time() + share)
            .collect(),
        // Even is β-agnostic; record β=1 as a neutral marker.
        beta: 1.0,
    }
}

/// Greedy switch test: at elapsed remaining-window `time_left`, with
/// per-task remaining workloads `z_rem` (chain order, current task first),
/// should the job abandon spot and switch to all on-demand?
///
/// The switch fires when the critical path of the remaining workload —
/// `Σ z_rem_k / δ_k` — is no longer strictly below the remaining window.
pub fn greedy_must_switch(remaining: &[(f64, f64)], time_left: f64) -> bool {
    let critical: f64 = remaining
        .iter()
        .map(|(z, delta)| z / delta)
        .sum();
    critical >= time_left - 1e-12
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::ChainTask;

    #[test]
    fn even_splits_slack_equally() {
        let job = ChainJob::paper_example();
        let alloc = even_windows(&job);
        let omega = job.slack();
        let share = omega / 4.0;
        for (s, t) in alloc.sizes.iter().zip(&job.tasks) {
            assert!((s - (t.min_exec_time() + share)).abs() < 1e-12);
        }
        let total: f64 = alloc.sizes.iter().sum();
        assert!((total - job.window()).abs() < 1e-12);
    }

    #[test]
    fn even_handles_infeasible() {
        let job = ChainJob::new(0, 0.0, 0.5, vec![ChainTask::new(2.0, 1.0)]);
        let alloc = even_windows(&job);
        assert_eq!(alloc.sizes, vec![2.0]);
    }

    #[test]
    fn greedy_switch_condition() {
        // remaining cp = 1.0 + 0.5 = 1.5
        let rem = [(2.0, 2.0), (1.0, 2.0)];
        assert!(!greedy_must_switch(&rem, 2.0));
        assert!(greedy_must_switch(&rem, 1.5));
        assert!(greedy_must_switch(&rem, 1.0));
    }

    #[test]
    fn greedy_empty_remaining_never_switches() {
        assert!(!greedy_must_switch(&[], 0.5));
    }
}
