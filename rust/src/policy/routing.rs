//! Offer routing: which `(region, instance_type)` offer a task is placed
//! on when the market is a multi-offer [`MarketView`].
//!
//! Routing happens at *task granularity*: at a task's realized start the
//! router picks one offer, the task reserves its spot units there for the
//! whole window (the paper holds instances through the task deadline), and
//! the executor charges that offer's realized prices. This is deliberately
//! coarser than the old slot-wise arbitrage composite — the composite
//! assumed free per-slot placement and infinite capacity, which is exactly
//! the assumption the capacity-aware view removes. The composite survives
//! as [`MarketView::arbitrage_collapse`] for worlds that want it.
//!
//! Capacity bounds *spot* placement only; on-demand stays elastic (§3.1's
//! "always available" contract). When no offer can fit a task's spot
//! units, the task degrades to all-on-demand on the cheapest-OD offer
//! instead of stalling — deadlines are never sacrificed to a capacity
//! wall.

use anyhow::{bail, ensure, Result};

use crate::market::{CapacityLedger, MarketView};

/// How tasks are routed across a view's offers.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RoutingPolicy {
    /// Always offer 0 — the legacy single-trace behavior (and the only
    /// sensible choice for a degenerate view).
    #[default]
    Home,
    /// The offer with the lowest current spot price among those with
    /// enough remaining capacity for the task's units (ties → lowest
    /// index).
    CheapestFeasible,
    /// Offers in declared order; the first with enough remaining capacity
    /// wins. Models a primary region with overflow targets.
    Spillover,
}

impl RoutingPolicy {
    pub fn as_str(&self) -> &'static str {
        match self {
            RoutingPolicy::Home => "home",
            RoutingPolicy::CheapestFeasible => "cheapest",
            RoutingPolicy::Spillover => "spillover",
        }
    }

    pub fn from_str(s: &str) -> Result<RoutingPolicy> {
        Ok(match s {
            "home" => RoutingPolicy::Home,
            "cheapest" => RoutingPolicy::CheapestFeasible,
            "spillover" => RoutingPolicy::Spillover,
            other => bail!("unknown routing policy '{other}' (home|cheapest|spillover)"),
        })
    }
}

/// Mid-window migration policy: whether an in-flight task may be moved to
/// a cheaper feasible offer at a slot boundary instead of staying pinned
/// to the offer it was routed to at its start.
///
/// Migration is evaluated wherever the execution walk's cursor rests on a
/// slot boundary (prices are slot-piecewise constant, so boundaries are
/// the only moments the comparison can change). A move is taken when the
/// projected saving over the remaining spot/on-demand workload exceeds
/// `switch_cost`, and at most once every `hysteresis_slots` slots. The
/// disabled policy (`switch_cost = +inf`) is the default; callers branch
/// on [`MigrationPolicy::enabled`] and keep the exact pinned-offer code
/// path when it is off, so disabling migration is byte-identical to the
/// pre-migration executor by construction.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MigrationPolicy {
    /// Cost charged for one move (checkpoint + transfer). A switch is only
    /// taken when the projected remaining-window saving exceeds it.
    /// `+inf` disables migration entirely.
    pub switch_cost: f64,
    /// Minimum slots between consecutive switches of one task (0 = every
    /// boundary is eligible).
    pub hysteresis_slots: u32,
}

impl MigrationPolicy {
    /// The no-migration policy: an infinite switch cost that no projected
    /// saving can exceed.
    pub fn disabled() -> MigrationPolicy {
        MigrationPolicy {
            switch_cost: f64::INFINITY,
            hysteresis_slots: 0,
        }
    }

    /// Whether any switch can ever be taken.
    pub fn enabled(&self) -> bool {
        self.switch_cost.is_finite()
    }

    /// Validate spec-provided parameters: a finite switch cost must be
    /// non-negative (a negative cost would *pay* tasks to thrash).
    pub fn validate(&self) -> Result<()> {
        ensure!(
            self.switch_cost.is_infinite() || self.switch_cost >= 0.0,
            "migration switch_cost must be >= 0 (got {})",
            self.switch_cost
        );
        Ok(())
    }
}

impl Default for MigrationPolicy {
    fn default() -> Self {
        MigrationPolicy::disabled()
    }
}

/// Where a task landed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RouteDecision {
    /// Offer index into the view.
    pub offer: usize,
    /// `true`: the offer can hold the task's spot units (the caller
    /// reserves them). `false`: capacity is exhausted everywhere the
    /// policy looks — run the task all-on-demand on `offer` (the
    /// cheapest-OD offer for capacity-seeking policies, home for `Home`).
    pub spot_capacity: bool,
}

/// Route one task: `units` spot instances wanted over `[t, deadline)`.
///
/// Pure decision — the caller reserves capacity on the returned offer.
/// Only price *comparisons* are made, so routing introduces no floating-
/// point arithmetic of its own and a one-offer infinite-capacity view
/// routes identically (offer 0, spot OK) under every policy.
pub fn route(
    policy: RoutingPolicy,
    view: &MarketView,
    cap: &CapacityLedger,
    units: u32,
    t: f64,
    deadline: f64,
) -> RouteDecision {
    match policy {
        RoutingPolicy::Home => RouteDecision {
            offer: 0,
            spot_capacity: cap.can_place(0, units, t, deadline),
        },
        RoutingPolicy::CheapestFeasible => {
            let mut best: Option<(usize, f64)> = None;
            for (k, o) in view.offers().iter().enumerate() {
                if !cap.can_place(k, units, t, deadline) {
                    continue;
                }
                let p = o.trace.price_at(t);
                if best.map_or(true, |(_, bp)| p < bp) {
                    best = Some((k, p));
                }
            }
            match best {
                Some((k, _)) => RouteDecision {
                    offer: k,
                    spot_capacity: true,
                },
                None => RouteDecision {
                    offer: view.cheapest_od(),
                    spot_capacity: false,
                },
            }
        }
        RoutingPolicy::Spillover => {
            for k in 0..view.len() {
                if cap.can_place(k, units, t, deadline) {
                    return RouteDecision {
                        offer: k,
                        spot_capacity: true,
                    };
                }
            }
            RouteDecision {
                offer: view.cheapest_od(),
                spot_capacity: false,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::market::{MarketOffer, PriceTrace};

    fn view(specs: &[(&str, f64, f64, Option<u32>)]) -> MarketView {
        // (name, od, flat price, capacity)
        MarketView::new(
            specs
                .iter()
                .map(|(name, od, price, cap)| MarketOffer {
                    region: name.to_string(),
                    instance_type: "default".into(),
                    od_price: *od,
                    trace: PriceTrace::from_prices(vec![*price; 24], 0.5),
                    capacity: *cap,
                })
                .collect(),
        )
        .unwrap()
    }

    #[test]
    fn migration_policy_default_is_disabled() {
        let m = MigrationPolicy::default();
        assert!(!m.enabled());
        assert_eq!(m.hysteresis_slots, 0);
        assert!(m.validate().is_ok());
        assert!(MigrationPolicy { switch_cost: 0.01, hysteresis_slots: 3 }.enabled());
    }

    #[test]
    fn migration_policy_validation_rejects_bad_costs() {
        assert!(MigrationPolicy { switch_cost: -0.1, hysteresis_slots: 0 }
            .validate()
            .is_err());
        assert!(MigrationPolicy { switch_cost: f64::NAN, hysteresis_slots: 0 }
            .validate()
            .is_err());
        assert!(MigrationPolicy { switch_cost: 0.0, hysteresis_slots: 9 }
            .validate()
            .is_ok());
    }

    #[test]
    fn roundtrip_strings() {
        for p in [
            RoutingPolicy::Home,
            RoutingPolicy::CheapestFeasible,
            RoutingPolicy::Spillover,
        ] {
            assert_eq!(RoutingPolicy::from_str(p.as_str()).unwrap(), p);
        }
        assert!(RoutingPolicy::from_str("nope").is_err());
    }

    #[test]
    fn home_always_offer_zero() {
        let v = view(&[("a", 1.0, 0.5, None), ("b", 1.0, 0.1, None)]);
        let cap = CapacityLedger::new(&v, 12.0);
        let d = route(RoutingPolicy::Home, &v, &cap, 8, 0.0, 2.0);
        assert_eq!(d.offer, 0);
        assert!(d.spot_capacity);
    }

    #[test]
    fn cheapest_picks_lowest_price_with_capacity() {
        let v = view(&[("a", 1.0, 0.5, None), ("b", 1.0, 0.1, Some(4))]);
        let mut cap = CapacityLedger::new(&v, 12.0);
        let d = route(RoutingPolicy::CheapestFeasible, &v, &cap, 4, 0.0, 2.0);
        assert_eq!(d.offer, 1, "cheap offer fits");
        assert!(cap.reserve(d.offer, 4, 0.0, 2.0));
        // b is now full over [0,2): the pricier a wins.
        let d2 = route(RoutingPolicy::CheapestFeasible, &v, &cap, 1, 0.5, 1.5);
        assert_eq!(d2.offer, 0);
        assert!(d2.spot_capacity);
    }

    #[test]
    fn spillover_takes_declared_order() {
        let v = view(&[("a", 1.0, 0.5, Some(2)), ("b", 1.2, 0.1, None)]);
        let mut cap = CapacityLedger::new(&v, 12.0);
        let d = route(RoutingPolicy::Spillover, &v, &cap, 2, 0.0, 2.0);
        assert_eq!(d.offer, 0, "primary has room despite pricier spot");
        assert!(cap.reserve(0, 2, 0.0, 2.0));
        let d2 = route(RoutingPolicy::Spillover, &v, &cap, 1, 0.5, 1.5);
        assert_eq!(d2.offer, 1, "primary full: spill to b");
        assert!(d2.spot_capacity);
    }

    #[test]
    fn exhausted_everywhere_degrades_to_cheapest_od() {
        let v = view(&[("a", 1.3, 0.2, Some(1)), ("b", 1.1, 0.3, Some(1))]);
        let mut cap = CapacityLedger::new(&v, 12.0);
        assert!(cap.reserve(0, 1, 0.0, 6.0));
        assert!(cap.reserve(1, 1, 0.0, 6.0));
        for policy in [RoutingPolicy::CheapestFeasible, RoutingPolicy::Spillover] {
            let d = route(policy, &v, &cap, 1, 1.0, 3.0);
            assert!(!d.spot_capacity);
            assert_eq!(d.offer, 1, "b has the cheaper on-demand fallback");
        }
    }
}
