//! Self-owned instance allocation: Eq. (11)/(12) and the naive baseline.
//!
//! `f(x)` (Eq. 11) is the minimum number of self-owned instances that lets a
//! task finish inside its window using only self-owned + spot capacity at
//! assumed availability `x`; the rule (12) allocates
//! `r_i = min{f(β₀), N(ς_{i-1}, ς_i), δ_i}`.

/// Eq. (11): `f(x) = max{ (z − δ·ŝ·x) / (ŝ·(1−x)), 0 }` for a task with
/// workload `z`, parallelism `δ`, window `ŝ`.
///
/// `x = 1` is the degenerate all-spot belief: the numerator is `z − δ·ŝ ≤ 0`
/// for any feasible window, so `f(1) = 0`.
pub fn f_selfowned(z: f64, delta: f64, hat_s: f64, x: f64) -> f64 {
    assert!(hat_s > 0.0);
    assert!((0.0..=1.0).contains(&x), "x={x}");
    if x >= 1.0 {
        return 0.0;
    }
    ((z - delta * hat_s * x) / (hat_s * (1.0 - x))).max(0.0)
}

/// Rule (12): self-owned instances granted to a task, given the pool's
/// guaranteed availability `n_avail = N(ς_{i-1}, ς_i)` over its window.
///
/// The paper ignores integer rounding in the analysis and rounds in
/// practice; we floor (a partial instance cannot be held), which keeps the
/// reservation within `N` and `δ`.
pub fn rule12(z: f64, delta: f64, hat_s: f64, beta0: f64, n_avail: u32) -> u32 {
    let f = f_selfowned(z, delta, hat_s, beta0);
    let r = f.min(n_avail as f64).min(delta);
    r.floor().max(0.0) as u32
}

/// The benchmark policy for self-owned instances (§6.1): grab as many as
/// possible, first-come-first-served: `r_i = min{N(ς_{i-1}, ς_i), δ_i}`.
pub fn naive_allocation(delta: f64, n_avail: u32) -> u32 {
    (n_avail as f64).min(delta).floor() as u32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{for_all, Config};

    #[test]
    fn f_endpoints() {
        // x = 0 → z/ŝ (run everything on self-owned).
        let (z, d, s) = (6.0, 4.0, 2.0);
        assert_eq!(f_selfowned(z, d, s, 0.0), 3.0);
        // x ≥ e/ŝ → 0 (spot alone suffices).
        let e = z / d; // 1.5
        assert_eq!(f_selfowned(z, d, s, e / s), 0.0);
        assert_eq!(f_selfowned(z, d, s, 0.9), 0.0);
        assert_eq!(f_selfowned(z, d, s, 1.0), 0.0);
    }

    #[test]
    fn f_nonincreasing_in_x_prop44() {
        for_all(Config::cases(300).seed(44), |rng| {
            let delta = rng.uniform(1.0, 64.0);
            let e = rng.uniform(0.1, 5.0);
            let z = e * delta;
            let s = rng.uniform(e, 4.0 * e);
            let x1 = rng.uniform(0.0, 0.999);
            let x2 = rng.uniform(x1, 0.999);
            let f1 = f_selfowned(z, delta, s, x1);
            let f2 = f_selfowned(z, delta, s, x2);
            if f2 > f1 + 1e-9 {
                return Err(format!("f not non-increasing: f({x1})={f1} < f({x2})={f2}"));
            }
            if !(0.0..=z / s + 1e-9).contains(&f1) {
                return Err(format!("f out of [0, z/ŝ]: {f1}"));
            }
            Ok(())
        });
    }

    #[test]
    fn f_beta_is_minimal_selfowned_for_spot_finish_prop44() {
        // After granting f(β), remaining work z − f·ŝ must be finishable by
        // (δ−f) spot instances at availability β: β·(δ−f)·ŝ ≥ z − f·ŝ.
        for_all(Config::cases(300).seed(45), |rng| {
            let delta = rng.uniform(1.0, 64.0);
            let e = rng.uniform(0.1, 5.0);
            let z = e * delta;
            let s = rng.uniform(e, 4.0 * e);
            let beta = rng.uniform(0.05, 0.95);
            let f = f_selfowned(z, delta, s, beta);
            let spot_cap = beta * (delta - f) * s;
            let rem = z - f * s;
            if spot_cap + 1e-6 < rem {
                return Err(format!("f(β)={f} insufficient: cap {spot_cap} < rem {rem}"));
            }
            // Minimality: slightly fewer instances must NOT suffice when f>0.
            if f > 1e-6 {
                let g = f - 1e-4 * f.max(1.0);
                let cap2 = beta * (delta - g) * s;
                let rem2 = z - g * s;
                if cap2 > rem2 + 1e-6 {
                    return Err(format!("f(β)={f} not minimal"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn rule12_respects_all_three_caps() {
        // f large, pool small → pool caps.
        assert_eq!(rule12(100.0, 10.0, 2.0, 0.1, 3), 3);
        // f small → f caps.
        let r = rule12(4.0, 10.0, 2.0, 0.1, 100);
        let f = f_selfowned(4.0, 10.0, 2.0, 0.1); // (4-2)/1.8 = 1.111
        assert_eq!(r, f.floor() as u32);
        // δ caps.
        assert_eq!(rule12(1000.0, 5.0, 2.0, 0.0, 100), 5);
    }

    #[test]
    fn naive_grabs_everything_within_delta() {
        assert_eq!(naive_allocation(8.0, 100), 8);
        assert_eq!(naive_allocation(64.0, 10), 10);
        assert_eq!(naive_allocation(8.0, 0), 0);
    }

    #[test]
    fn sufficiency_index_semantics() {
        // Smaller β₀ (more self-owned sufficiency) → more instances granted.
        let (z, d, s) = (32.0, 8.0, 8.0);
        let lo = rule12(z, d, s, 0.1, 1000);
        let hi = rule12(z, d, s, 0.7, 1000);
        assert!(lo >= hi, "β₀↓ should not grant fewer: {lo} vs {hi}");
    }
}
