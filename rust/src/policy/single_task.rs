//! Closed-form single-task analysis: Definitions 3.1/3.2 and
//! Propositions 4.1, 4.2, 4.5.
//!
//! A task with workload `z`, parallelism `δ` and minimum execution time
//! `e = z/δ` runs in a window of size `ŝ`. Under assumed spot availability
//! `β`, the expected-optimal strategy is all-spot until the (expected)
//! turning point, then all on-demand.

/// Expected spot-processable workload `z^o` for a window of size `hat_s`
/// (Prop. 4.2, Eq. 9). `x = ŝ − e` is the slack beyond the minimum
/// execution time.
pub fn spot_capacity(z: f64, delta: f64, hat_s: f64, beta: f64) -> f64 {
    let e = z / delta;
    debug_assert!(hat_s >= e - 1e-9, "window {hat_s} below e={e}");
    if beta >= 1.0 {
        // Perfectly available spot: everything fits on spot.
        return z;
    }
    if hat_s >= e / beta {
        z
    } else {
        let x = (hat_s - e).max(0.0);
        (beta / (1.0 - beta) * delta * x).min(z)
    }
}

/// Expected turning point, as the duration `τ` of the all-spot phase from
/// the window start (Prop. 4.1 / Eq. 15–16): `τ = (δ·ŝ − z) / (δ·(1−β))`.
///
/// Returns `None` when the window is large enough (`ŝ ≥ e/β`) that the task
/// is expected to finish on spot alone (no turning point).
pub fn expected_turning_point(z: f64, delta: f64, hat_s: f64, beta: f64) -> Option<f64> {
    let e = z / delta;
    if beta >= 1.0 || hat_s >= e / beta {
        return None;
    }
    let tau = (delta * hat_s - z) / (delta * (1.0 - beta));
    Some(tau.clamp(0.0, hat_s))
}

/// Expected turning point for a general mix of `s` spot and `o` on-demand
/// instances (the process of Definition 3.2 before Prop. 4.1 specializes to
/// all-spot): `z̃(t) = z̃ − (o + β·s)·t` meets `(ŝ − t)·δeff` at
/// `τ = (δeff·ŝ − z̃) / (δeff − o − β·s)`. Used by the Figure-2 toy, which
/// runs `o = s = 1`.
pub fn expected_turning_point_mixed(
    z_rem: f64,
    delta_eff: f64,
    hat_s: f64,
    beta: f64,
    s: f64,
    o: f64,
) -> Option<f64> {
    debug_assert!(s + o <= delta_eff + 1e-9);
    let drain = o + beta * s;
    // Completion before turning: z̃/drain if the margin never closes.
    let denom = delta_eff - drain;
    if denom <= 1e-12 {
        // Remaining capacity fully deployed; no turning point possible.
        return None;
    }
    let tau = (delta_eff * hat_s - z_rem) / denom;
    if tau >= z_rem / drain.max(1e-12) {
        // z̃ hits zero before the turning point.
        return None;
    }
    Some(tau.clamp(0.0, hat_s))
}

/// Definition 3.1: does a task with remaining workload `z_rem`, effective
/// parallelism `delta_eff = δ − r`, at time-to-deadline `time_left`, still
/// have flexibility to gamble on spot?
///
/// Flexibility holds while `z_rem / delta_eff < time_left`; equality is the
/// turning point (Def. 3.2) where the allocation must switch to all
/// on-demand to meet the deadline.
pub fn has_flexibility(z_rem: f64, delta_eff: f64, time_left: f64) -> bool {
    debug_assert!(delta_eff > 0.0);
    z_rem / delta_eff < time_left - 1e-12
}

/// Expected workload processed by spot for a task that also holds `r`
/// self-owned instances for the whole window (Prop. 4.5).
///
/// With `r = f(β₀)` (Eq. 11) the result depends only on `min(β, β₀)`:
/// both cases (13) and (14) have the form of Eq. (9) with β replaced by
/// `min(β, β₀)`.
pub fn spot_capacity_with_selfowned(
    z: f64,
    delta: f64,
    hat_s: f64,
    beta: f64,
    beta0: f64,
) -> f64 {
    spot_capacity(z, delta, hat_s, beta.min(beta0))
}

/// Minimum window size for an all-spot finish: `e/β` (Prop. 4.1, Eq. 6).
pub fn all_spot_window(e: f64, beta: f64) -> f64 {
    e / beta
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{for_all, Config};

    #[test]
    fn prop41_boundary_cases() {
        // ŝ = e → turning point at window start, zero spot.
        let (z, d, beta) = (2.0, 2.0, 0.5);
        let e = z / d;
        assert_eq!(spot_capacity(z, d, e, beta), 0.0);
        assert_eq!(expected_turning_point(z, d, e, beta), Some(0.0));
        // ŝ = e/β → all spot, no turning point.
        assert_eq!(spot_capacity(z, d, e / beta, beta), z);
        assert_eq!(expected_turning_point(z, d, e / beta, beta), None);
    }

    #[test]
    fn paper_4_1_1_first_task() {
        // §4.1.1 / Fig. 4: task 1 (z=1.5, δ=2) with ŝ = 4/3, β = 0.5:
        // spot phase τ = 7/6, spot workload 7/6.
        let tau = expected_turning_point(1.5, 2.0, 4.0 / 3.0, 0.5).unwrap();
        assert!((tau - 7.0 / 6.0).abs() < 1e-12, "tau={tau}");
        let zo = spot_capacity(1.5, 2.0, 4.0 / 3.0, 0.5);
        assert!((zo - 7.0 / 6.0).abs() < 1e-12, "zo={zo}");
    }

    #[test]
    fn toy_example_of_section_3_3_1() {
        // δ=3, window [0,2], r=1 self-owned ⇒ effective δ−r=2, β=0.5.
        // The paper's toy runs o=s=1 (Fig. 2): z=5.5 → z̃=3.5 → turning
        // point at t=1; z=3.5 → z̃=1.5 → no turning point.
        assert!(expected_turning_point_mixed(1.5, 2.0, 2.0, 0.5, 1.0, 1.0).is_none());
        let tau = expected_turning_point_mixed(3.5, 2.0, 2.0, 0.5, 1.0, 1.0).unwrap();
        assert!((tau - 1.0).abs() < 1e-12, "tau={tau}");
        // Under the expected-OPTIMAL all-spot strategy (Prop. 4.1) the
        // turning point moves earlier: τ = (δeff·ŝ − z̃)/(δeff(1−β)) = 0.5.
        let tau_opt = expected_turning_point(3.5, 2.0, 2.0, 0.5).unwrap();
        assert!((tau_opt - 0.5).abs() < 1e-12, "tau_opt={tau_opt}");
    }

    #[test]
    fn flexibility_definition() {
        assert!(has_flexibility(1.0, 2.0, 1.0)); // 0.5 < 1
        assert!(!has_flexibility(2.0, 2.0, 1.0)); // exactly the turning point
        assert!(!has_flexibility(3.0, 2.0, 1.0)); // past it
    }

    #[test]
    fn spot_capacity_monotone_and_capped() {
        for_all(Config::cases(300).seed(41), |rng| {
            let delta = rng.uniform(1.0, 64.0);
            let e = rng.uniform(0.1, 10.0);
            let z = e * delta;
            let beta = rng.uniform(0.05, 0.99);
            let s1 = e + rng.uniform(0.0, 3.0 * e / beta);
            let s2 = s1 + rng.uniform(0.0, e);
            let c1 = spot_capacity(z, delta, s1, beta);
            let c2 = spot_capacity(z, delta, s2, beta);
            if c2 + 1e-9 < c1 {
                return Err(format!("not monotone: {c1} > {c2}"));
            }
            if c1 > z + 1e-9 || c1 < -1e-9 {
                return Err(format!("out of [0, z]: {c1} (z={z})"));
            }
            // Saturation beyond e/β.
            let cbig = spot_capacity(z, delta, 10.0 * e / beta, beta);
            if (cbig - z).abs() > 1e-9 {
                return Err(format!("no saturation: {cbig} != {z}"));
            }
            Ok(())
        });
    }

    #[test]
    fn turning_point_consistency_with_capacity() {
        // Workload identity: spot phase τ at δ·β plus on-demand tail
        // δ·(ŝ−τ) must equal z (Eq. 15 with s=δ, o=0).
        for_all(Config::cases(300).seed(42), |rng| {
            let delta = rng.uniform(1.0, 64.0);
            let e = rng.uniform(0.1, 10.0);
            let z = e * delta;
            let beta = rng.uniform(0.05, 0.95);
            let hat_s = rng.uniform(e, e / beta);
            if let Some(tau) = expected_turning_point(z, delta, hat_s, beta) {
                let processed = tau * delta * beta + (hat_s - tau) * delta;
                if (processed - z).abs() > 1e-6 * z.max(1.0) {
                    return Err(format!("identity violated: {processed} vs {z}"));
                }
                let zo = spot_capacity(z, delta, hat_s, beta);
                if (zo - tau * delta * beta).abs() > 1e-6 * z.max(1.0) {
                    return Err(format!("z^o mismatch: {zo} vs {}", tau * delta * beta));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn selfowned_capacity_uses_min_beta() {
        let (z, d) = (4.0, 2.0);
        let s = 3.0;
        assert_eq!(
            spot_capacity_with_selfowned(z, d, s, 0.5, 0.3),
            spot_capacity(z, d, s, 0.3)
        );
        assert_eq!(
            spot_capacity_with_selfowned(z, d, s, 0.3, 0.5),
            spot_capacity(z, d, s, 0.3)
        );
    }

    #[test]
    fn beta_one_is_all_spot() {
        assert_eq!(spot_capacity(5.0, 2.0, 2.5, 1.0), 5.0);
        assert!(expected_turning_point(5.0, 2.0, 2.5, 1.0).is_none());
    }
}
