//! The paper's parametric policies and the baseline heuristics.
//!
//! A policy is the tuple `π = {β, β₀, b}` (§5):
//!
//! * `β`  — assumed availability of spot instances (expected fraction of
//!   time a spot request is filled);
//! * `β₀` — sufficiency index of self-owned instances, driving Eq. (12);
//! * `b`  — bid price for spot instances (EC2/Azure; `None` for Google).
//!
//! The grids `C1`, `C2`, `B` and the policy sets `P` (proposed) and `P'`
//! (benchmark) replicate §6.1 exactly.

pub mod single_task;
pub mod dealloc;
pub mod selfowned;
pub mod baselines;
pub mod routing;

pub use baselines::DeadlinePolicy;
pub use dealloc::{dealloc, windows_to_deadlines};
pub use routing::{route, RouteDecision, RoutingPolicy};

/// A parametric policy `{β, β₀, b}`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Policy {
    /// Assumed spot availability β ∈ (0, 1].
    pub beta: f64,
    /// Sufficiency index β₀ of self-owned instances; `None` when the user
    /// has no self-owned instances (the β₀ machinery is bypassed).
    pub beta0: Option<f64>,
    /// Bid price `b` for spot instances.
    pub bid: f64,
}

impl Policy {
    pub fn new(beta: f64, beta0: Option<f64>, bid: f64) -> Policy {
        assert!(beta > 0.0 && beta <= 1.0, "beta={beta}");
        if let Some(b0) = beta0 {
            assert!(b0 > 0.0 && b0 <= 1.0, "beta0={b0}");
        }
        Policy { beta, beta0, bid }
    }

    /// The β used by the deadline allocation (Algorithm 2 lines 1–5):
    /// `Dealloc(β)` when `r = 0` or `β < β₀`, else `Dealloc(β₀)`.
    pub fn dealloc_beta(&self, has_pool: bool) -> f64 {
        match self.beta0 {
            Some(b0) if has_pool && b0 <= self.beta => b0,
            _ => self.beta,
        }
    }
}

/// §6.1 grid `C1` for β₀ (sufficiency index).
pub fn grid_c1() -> Vec<f64> {
    vec![
        2.0 / 12.0,
        4.0 / 14.0,
        6.0 / 16.0,
        8.0 / 18.0,
        0.5,
        0.6,
        0.7,
    ]
}

/// §6.1 grid `C2` for β (spot availability).
pub fn grid_c2() -> Vec<f64> {
    vec![1.0, 1.0 / 1.3, 1.0 / 1.6, 1.0 / 1.9, 1.0 / 2.2]
}

/// §6.1 grid `B` for bids.
pub fn grid_b() -> Vec<f64> {
    vec![0.18, 0.21, 0.24, 0.27, 0.3]
}

/// The proposed policy set `P` without self-owned instances:
/// `{(β, b) | β ∈ C2, b ∈ B}` (25 policies).
pub fn policy_set_spot_only() -> Vec<Policy> {
    let mut out = Vec::new();
    for &beta in &grid_c2() {
        for &bid in &grid_b() {
            out.push(Policy::new(beta, None, bid));
        }
    }
    out
}

/// The proposed policy set `P` with self-owned instances:
/// `{(β, b, β₀) | β₀ ∈ C1, β ∈ C2, b ∈ B}` (175 policies).
pub fn policy_set_full() -> Vec<Policy> {
    let mut out = Vec::new();
    for &beta0 in &grid_c1() {
        for &beta in &grid_c2() {
            for &bid in &grid_b() {
                out.push(Policy::new(beta, Some(beta0), bid));
            }
        }
    }
    out
}

/// The benchmark policy set `P' = {b | b ∈ B}` (bid-only; deadline and
/// self-owned allocation come from the baseline heuristics).
pub fn benchmark_bids() -> Vec<f64> {
    grid_b()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grids_match_paper() {
        assert_eq!(grid_c1().len(), 7);
        assert_eq!(grid_c2().len(), 5);
        assert_eq!(grid_b().len(), 5);
        assert_eq!(policy_set_spot_only().len(), 25);
        assert_eq!(policy_set_full().len(), 175);
        assert!((grid_c1()[0] - 1.0 / 6.0).abs() < 1e-12);
        assert_eq!(grid_c2()[0], 1.0);
        assert_eq!(grid_b()[4], 0.3);
    }

    #[test]
    fn dealloc_beta_selection() {
        // r=0: always β.
        let p = Policy::new(0.5, Some(0.2), 0.2);
        assert_eq!(p.dealloc_beta(false), 0.5);
        // pool + β₀ ≤ β: Dealloc(β₀).
        assert_eq!(p.dealloc_beta(true), 0.2);
        // pool + β < β₀: Dealloc(β).
        let q = Policy::new(0.5, Some(0.7), 0.2);
        assert_eq!(q.dealloc_beta(true), 0.5);
        // no β₀ at all.
        let r = Policy::new(0.5, None, 0.2);
        assert_eq!(r.dealloc_beta(true), 0.5);
    }

    #[test]
    #[should_panic]
    fn rejects_bad_beta() {
        Policy::new(0.0, None, 0.2);
    }
}
