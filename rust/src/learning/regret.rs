//! Regret accounting for TOLA (Proposition B.1).
//!
//! Tracks, per processed job, the realized cost under the sampled policy and
//! the matrix of counterfactual costs, and reports the average regret
//! against the best *fixed* policy in hindsight together with the paper's
//! high-probability bound `9·sqrt(2·d·log(n/δ) / N')`.

/// A cheap point-in-time view of the tracker — what the online
/// coordinator emits per reporting window without cloning the per-policy
/// totals.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RegretSnapshot {
    /// Jobs recorded so far (N').
    pub jobs: u64,
    /// Average regret vs the best fixed policy in hindsight.
    pub average_regret: f64,
    /// The Prop. B.1 bound at the snapshot's confidence level.
    pub bound: f64,
}

/// Accumulates realized and counterfactual costs.
#[derive(Debug, Clone)]
pub struct RegretTracker {
    /// Σ realized cost of the sampled policies.
    realized_total: f64,
    /// Per-policy totals of counterfactual costs.
    per_policy_total: Vec<f64>,
    jobs: u64,
    /// `d`: max relative deadline (for the bound).
    d: f64,
}

impl RegretTracker {
    pub fn new(num_policies: usize, max_relative_deadline: f64) -> RegretTracker {
        RegretTracker {
            realized_total: 0.0,
            per_policy_total: vec![0.0; num_policies],
            jobs: 0,
            d: max_relative_deadline,
        }
    }

    /// Record one job: realized cost and the full counterfactual vector.
    pub fn record(&mut self, realized: f64, counterfactuals: &[f64]) {
        assert_eq!(counterfactuals.len(), self.per_policy_total.len());
        self.realized_total += realized;
        for (acc, c) in self.per_policy_total.iter_mut().zip(counterfactuals) {
            *acc += c;
        }
        self.jobs += 1;
    }

    pub fn jobs(&self) -> u64 {
        self.jobs
    }

    /// Total cost of the best fixed policy in hindsight (π*).
    pub fn best_fixed_total(&self) -> f64 {
        self.per_policy_total
            .iter()
            .cloned()
            .fold(f64::INFINITY, f64::min)
    }

    /// Per-policy mean counterfactual cost per job (`Σ c_π / N'`) — the
    /// fixed-policy cost surface the fleet layer's cross-scenario
    /// robustness scoring compares across worlds. Zeros before any job is
    /// recorded.
    pub fn per_policy_means(&self) -> Vec<f64> {
        if self.jobs == 0 {
            return vec![0.0; self.per_policy_total.len()];
        }
        self.per_policy_total
            .iter()
            .map(|&t| t / self.jobs as f64)
            .collect()
    }

    /// Index of π*.
    pub fn best_fixed_policy(&self) -> usize {
        self.per_policy_total
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0)
    }

    /// Average regret `(Σ c(π_j) − Σ c(π*)) / N'` (LHS of Prop. B.1).
    pub fn average_regret(&self) -> f64 {
        if self.jobs == 0 {
            return 0.0;
        }
        (self.realized_total - self.best_fixed_total()) / self.jobs as f64
    }

    /// O(L) point-in-time snapshot (jobs, average regret, bound) — the
    /// per-window reporting path of the online loop; no allocation, no
    /// clone of the per-policy totals.
    pub fn snapshot(&self, delta: f64) -> RegretSnapshot {
        RegretSnapshot {
            jobs: self.jobs,
            average_regret: self.average_regret(),
            bound: self.bound(delta),
        }
    }

    /// The Prop. B.1 bound `9·sqrt(2·d·log(n/δ)/N')` at confidence `1−δ`.
    pub fn bound(&self, delta: f64) -> f64 {
        assert!((0.0..1.0).contains(&delta) && delta > 0.0);
        if self.jobs == 0 {
            return f64::INFINITY;
        }
        let n = self.per_policy_total.len() as f64;
        9.0 * (2.0 * self.d * (n / delta).ln() / self.jobs as f64).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn regret_against_best_fixed() {
        let mut r = RegretTracker::new(3, 4.0);
        // Policy 1 is always cheapest (1.0); we "realized" alternating 2/3.
        for i in 0..10 {
            let realized = if i % 2 == 0 { 2.0 } else { 3.0 };
            r.record(realized, &[2.0, 1.0, 3.0]);
        }
        assert_eq!(r.best_fixed_policy(), 1);
        assert_eq!(r.best_fixed_total(), 10.0);
        assert!((r.average_regret() - 1.5).abs() < 1e-12);
        assert!(r.bound(0.05) > 0.0);
    }

    #[test]
    fn per_policy_means_divide_totals_by_jobs() {
        let mut r = RegretTracker::new(3, 4.0);
        assert_eq!(r.per_policy_means(), vec![0.0, 0.0, 0.0]);
        for _ in 0..4 {
            r.record(2.0, &[2.0, 1.0, 3.0]);
        }
        assert_eq!(r.per_policy_means(), vec![2.0, 1.0, 3.0]);
    }

    #[test]
    fn snapshot_matches_the_accessors() {
        let mut r = RegretTracker::new(3, 4.0);
        for _ in 0..6 {
            r.record(2.0, &[2.0, 1.0, 3.0]);
        }
        let s = r.snapshot(0.05);
        assert_eq!(s.jobs, r.jobs());
        assert_eq!(s.average_regret, r.average_regret());
        assert_eq!(s.bound, r.bound(0.05));
    }

    #[test]
    fn zero_jobs_safe() {
        let r = RegretTracker::new(2, 1.0);
        assert_eq!(r.average_regret(), 0.0);
        assert!(r.bound(0.1).is_infinite());
    }

    #[test]
    fn bound_shrinks_with_jobs() {
        let mut r = RegretTracker::new(5, 2.0);
        r.record(1.0, &[1.0; 5]);
        let b1 = r.bound(0.05);
        for _ in 0..99 {
            r.record(1.0, &[1.0; 5]);
        }
        assert!(r.bound(0.05) < b1 / 5.0);
    }
}
