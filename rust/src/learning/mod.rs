//! Online learning: the TOLA algorithm (Appendix B.2, Algorithm 4) and the
//! counterfactual cost model that feeds it.
//!
//! TOLA keeps an exponentiated-weights distribution over the policy grid
//! `P`. Each arriving job is assigned a policy sampled from the current
//! distribution; once a job's deadline has passed (so the spot prices over
//! its whole window are known), its cost under *every* policy of `P` is
//! evaluated and the weights are re-normalized with
//! `w ← w · exp(−η_t · c_j(π))`, `η_t = sqrt(2·log n / (d·(t−d)))`.
//!
//! The per-job all-policy sweep is the hot path; [`counterfactual`] defines
//! its exact semantics, implemented three ways that must agree: natively
//! (the [`sweep`] engine, with the naive walk kept as oracle), in pure jnp
//! (`python/compile/kernels/ref.py`), and as the AOT Pallas kernel executed
//! through PJRT ([`crate::runtime`]).

pub mod counterfactual;
pub mod regret;
pub mod replay;
pub mod sweep;

pub use counterfactual::{CounterfactualJob, PolicyGridEval};
pub use replay::{replay_specs, PolicyReplay};
pub use sweep::{sweep_batch, SweepContext};

use crate::util::rng::Pcg32;

/// TOLA state (Algorithm 4).
#[derive(Debug, Clone)]
pub struct Tola {
    /// Weights over the n policies (always normalized).
    weights: Vec<f64>,
    /// `d` — the maximum relative deadline over all jobs (sets η_t).
    pub max_relative_deadline: f64,
    /// Number of weight updates performed (κ in the paper).
    pub updates: u64,
}

impl Tola {
    pub fn new(num_policies: usize, max_relative_deadline: f64) -> Tola {
        assert!(num_policies > 0);
        assert!(max_relative_deadline > 0.0);
        Tola {
            weights: vec![1.0 / num_policies as f64; num_policies],
            max_relative_deadline,
            updates: 0,
        }
    }

    pub fn num_policies(&self) -> usize {
        self.weights.len()
    }

    pub fn weights(&self) -> &[f64] {
        &self.weights
    }

    /// Sample a policy index from the current distribution (line 8).
    pub fn pick(&self, rng: &mut Pcg32) -> usize {
        rng.weighted_index(&self.weights)
    }

    /// The learning rate at wall-clock time `t` (line 16):
    /// `η_t = sqrt(2 log n / (d (t − d)))`, guarded for `t ≤ d`.
    pub fn eta(&self, t: f64) -> f64 {
        let d = self.max_relative_deadline;
        let denom = (d * (t - d)).max(d * d * 1e-3).max(1e-12);
        (2.0 * (self.weights.len() as f64).ln() / denom).sqrt()
    }

    /// Weight update for one retired job with per-policy costs `costs`
    /// (lines 14–21). `t` is the current time.
    pub fn update(&mut self, costs: &[f64], t: f64) {
        assert_eq!(costs.len(), self.weights.len());
        let eta = self.eta(t);
        // Subtract the min cost before exponentiating: mathematically a
        // no-op after normalization, numerically essential for large costs.
        let cmin = costs.iter().cloned().fold(f64::INFINITY, f64::min);
        let mut total = 0.0;
        for (w, c) in self.weights.iter_mut().zip(costs) {
            *w *= (-eta * (c - cmin)).exp();
            total += *w;
        }
        if total <= 0.0 || !total.is_finite() {
            // Degenerate collapse: reset to uniform (cannot happen with the
            // min-shift unless costs are non-finite).
            let n = self.weights.len() as f64;
            self.weights.iter_mut().for_each(|w| *w = 1.0 / n);
        } else {
            self.weights.iter_mut().for_each(|w| *w /= total);
        }
        self.updates += 1;
    }

    /// Index of the currently most-probable policy.
    pub fn best(&self) -> usize {
        self.weights
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starts_uniform_and_stays_simplex() {
        let mut t = Tola::new(4, 10.0);
        assert!(t.weights().iter().all(|&w| (w - 0.25).abs() < 1e-12));
        for step in 0..50 {
            t.update(&[1.0, 2.0, 3.0, 4.0], 10.0 + step as f64);
            let sum: f64 = t.weights().iter().sum();
            assert!((sum - 1.0).abs() < 1e-9);
            assert!(t.weights().iter().all(|&w| w >= 0.0));
        }
    }

    #[test]
    fn converges_to_cheapest_policy() {
        let mut t = Tola::new(3, 5.0);
        for step in 0..2000 {
            t.update(&[2.0, 0.5, 1.0], 5.0 + step as f64);
        }
        assert_eq!(t.best(), 1);
        assert!(t.weights()[1] > 0.9, "{:?}", t.weights());
    }

    #[test]
    fn eta_decreases_with_time() {
        let t = Tola::new(10, 5.0);
        assert!(t.eta(10.0) > t.eta(100.0));
        assert!(t.eta(100.0) > t.eta(10_000.0));
        assert!(t.eta(1.0).is_finite()); // guard below t = d
    }

    #[test]
    fn huge_costs_do_not_collapse_numerically() {
        let mut t = Tola::new(2, 1.0);
        t.update(&[1e6, 1e6 + 1.0], 2.0);
        let sum: f64 = t.weights().iter().sum();
        assert!((sum - 1.0).abs() < 1e-9);
        assert!(t.weights()[0] > t.weights()[1]);
    }

    #[test]
    fn pick_follows_distribution() {
        let mut t = Tola::new(2, 1.0);
        for step in 0..500 {
            t.update(&[0.1, 5.0], 2.0 + step as f64);
        }
        let mut rng = Pcg32::new(3);
        let picks0 = (0..1000).filter(|_| t.pick(&mut rng) == 0).count();
        assert!(picks0 > 900, "picks0={picks0}");
    }
}
