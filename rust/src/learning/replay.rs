//! Capacity replay: bound the sweep engine's capacity-free optimism.
//!
//! Counterfactual costs are capacity-free by construction — one job's
//! "what if" cannot replay the whole market's contention (see
//! [`super::counterfactual::eval_spec_multi_naive`]). That makes every
//! per-policy mean an *optimistic* estimate on finite-capacity worlds: the
//! sweep assumes each job's spot request is always grantable. This module
//! re-executes each policy's chosen allocations, for all jobs in arrival
//! order, through a real [`CapacityLedger`], and reports the per-policy
//! **optimism gap**: the difference between the capacity-free counterfactual
//! mean and the capacity-constrained replayed mean.
//!
//! Replay semantics: each spot purchase the counterfactual walk makes
//! ([`SpotPurchase`]) is re-reserved against the chosen offer's lane. Units
//! that no longer fit are *displaced to on-demand* — the same degrade rule
//! the realized executor uses — so the displaced share of the purchase's
//! work is surcharged `max(0, od_price − spot_price)`. The clamp makes the
//! surcharge non-negative purchase-by-purchase, so
//! `replayed_mean ≥ free_mean` holds by construction (the ≥ 0 invariant
//! pinned in `tests/prop_invariants.rs`).
//!
//! The replay marshals windows with an empty self-owned pool (`navail = 0`
//! — capacity optimism is a market phenomenon; pool contention is already
//! realized in the run), while window *geometry* still honors `has_pool`
//! through `dealloc_beta`. Offer choice per job matches the multi-sweep
//! rule: cheapest capacity-free offer, ties to the lowest index.

use crate::learning::counterfactual::{CfSpec, CounterfactualJob, SpotPurchase, S_MAX};
use crate::market::{CapacityLedger, MarketView};
use crate::policy::routing::RoutingPolicy;
use crate::workload::ChainJob;

/// One policy's capacity replay result (per-job means).
#[derive(Debug, Clone, PartialEq)]
pub struct PolicyReplay {
    /// The spec's human-readable label (report key).
    pub label: String,
    /// Mean capacity-free counterfactual cost per job.
    pub free_mean: f64,
    /// Mean cost per job after replaying the allocations through the
    /// ledger (free cost plus displacement surcharges).
    pub replayed_mean: f64,
}

impl PolicyReplay {
    /// The optimism gap: `replayed_mean − free_mean`, ≥ 0 by construction.
    pub fn gap(&self) -> f64 {
        self.replayed_mean - self.free_mean
    }
}

/// Re-reserve one job's purchase stream on `offer` and return the
/// displacement surcharge: for each purchase, units that no longer fit run
/// on-demand instead, so the displaced share of the work is surcharged
/// `max(0, od_price − spot_price)`. Non-negative term-by-term.
pub fn surcharge(
    cap: &mut CapacityLedger,
    offer: usize,
    arrival: f64,
    od_price: f64,
    purchases: &[SpotPurchase],
) -> f64 {
    let mut extra = 0.0;
    for p in purchases {
        if p.units == 0 || p.work <= 0.0 {
            continue;
        }
        let (a0, a1) = (arrival + p.t0, arrival + p.t1);
        let granted = match cap.remaining_over(offer, a0, a1) {
            None => p.units,
            Some(m) => m.min(p.units),
        };
        if granted > 0 {
            let ok = cap.reserve(offer, granted, a0, a1);
            debug_assert!(ok, "remaining_over approved units reserve refused");
        }
        let displaced = (p.units - granted) as f64 / p.units as f64;
        extra += (od_price - p.price).max(0.0) * p.work * displaced;
    }
    extra
}

/// Replay every spec's chosen allocations through a fresh per-spec
/// [`CapacityLedger`] (each policy is replayed as if it were *the* fleet
/// policy, which is exactly the counterfactual the per-policy means claim
/// to estimate). Jobs are processed in slice order — the coordinator's
/// arrival-order contract. Ledger sizing matches the coordinator
/// (`horizon + d_max + 1`), so reservations clamp identically near the
/// horizon.
pub fn replay_specs(
    jobs: &[ChainJob],
    specs: &[CfSpec],
    view: &MarketView,
    routing: RoutingPolicy,
    has_pool: bool,
) -> Vec<PolicyReplay> {
    assert!(!jobs.is_empty() && !specs.is_empty());
    let sweep_offers = match routing {
        RoutingPolicy::Home => &view.offers()[..1],
        _ => view.offers(),
    };
    let horizon = jobs.iter().map(|j| j.deadline).fold(1.0, f64::max);
    let d_max = jobs.iter().map(|j| j.window()).fold(1.0, f64::max);
    let caps: Vec<Option<u32>> = sweep_offers.iter().map(|o| o.capacity).collect();

    // Marshal once, shared across all specs (the resample dominates).
    let cfs: Vec<Vec<CounterfactualJob>> = jobs
        .iter()
        .map(|job| {
            let mut navail: Option<std::sync::Arc<[f64]>> = None;
            sweep_offers
                .iter()
                .map(|o| {
                    let (prices, dt) =
                        o.trace.resample_window(job.arrival, job.deadline, S_MAX);
                    let na = navail
                        .get_or_insert_with(|| vec![0.0; prices.len()].into())
                        .clone();
                    CounterfactualJob::from_job(job, prices, dt, na, o.od_price)
                })
                .collect()
        })
        .collect();

    let n = jobs.len() as f64;
    specs
        .iter()
        .map(|spec| {
            let mut ledger =
                CapacityLedger::from_capacities(&caps, view.slot_len(), horizon + d_max + 1.0);
            let mut free_sum = 0.0;
            let mut extra_sum = 0.0;
            for (job, row) in jobs.iter().zip(&cfs) {
                let (q0, p0) = row[0].eval_spec_purchases(spec, has_pool);
                let mut best_k = 0usize;
                let mut best_cost = q0.0;
                let mut best_purchases = p0;
                for (k, cf) in row.iter().enumerate().skip(1) {
                    let (q, p) = cf.eval_spec_purchases(spec, has_pool);
                    if q.0 < best_cost {
                        best_k = k;
                        best_cost = q.0;
                        best_purchases = p;
                    }
                }
                free_sum += best_cost;
                extra_sum += surcharge(
                    &mut ledger,
                    best_k,
                    job.arrival,
                    sweep_offers[best_k].od_price,
                    &best_purchases,
                );
            }
            PolicyReplay {
                label: spec.label(),
                free_mean: free_sum / n,
                replayed_mean: (free_sum + extra_sum) / n,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::market::{MarketOffer, PriceTrace, SLOTS_PER_UNIT};
    use crate::policy::Policy;
    use crate::util::prop::{for_all, Config};
    use crate::util::rng::Pcg32;
    use crate::workload::{ChainJob, ChainTask};

    fn flat_view(price: f64, horizon: f64, capacity: Option<u32>) -> MarketView {
        let n = (horizon * SLOTS_PER_UNIT as f64) as usize + 2;
        MarketView::new(vec![MarketOffer {
            region: "a".into(),
            instance_type: "default".into(),
            od_price: 1.0,
            trace: PriceTrace::from_prices(vec![price; n], 1.0 / SLOTS_PER_UNIT as f64),
            capacity,
        }])
        .unwrap()
    }

    fn jobs_at(arrivals: &[f64], delta: f64) -> Vec<ChainJob> {
        arrivals
            .iter()
            .enumerate()
            .map(|(i, &a)| {
                ChainJob::new(i as u64, a, a + 4.0, vec![ChainTask::new(delta * 2.0, delta)])
            })
            .collect()
    }

    #[test]
    fn infinite_capacity_has_zero_gap() {
        let jobs = jobs_at(&[0.0, 0.0, 0.5, 1.0], 4.0);
        let specs = vec![CfSpec::Proposed(Policy::new(0.7, None, 0.5))];
        let view = flat_view(0.2, 10.0, None);
        let reps = replay_specs(&jobs, &specs, &view, RoutingPolicy::Home, false);
        assert_eq!(reps.len(), 1);
        assert_eq!(reps[0].gap(), 0.0);
        assert!(reps[0].free_mean > 0.0);
    }

    #[test]
    fn crunched_capacity_surcharges_displaced_work() {
        // Eight concurrent jobs each wanting 4 spot units on a 4-unit
        // lane: most requests displace, at od − spot = 0.8 per unit work.
        let jobs = jobs_at(&[0.0; 8], 4.0);
        let specs = vec![CfSpec::Proposed(Policy::new(0.7, None, 0.5))];
        let view = flat_view(0.2, 10.0, Some(4));
        let reps = replay_specs(&jobs, &specs, &view, RoutingPolicy::Home, false);
        assert!(
            reps[0].gap() > 0.0,
            "8×4 units on a 4-unit lane should displace: {reps:?}"
        );
        assert!(reps[0].replayed_mean > reps[0].free_mean);
        // The first job through the ledger fits; the gap stays below the
        // everything-displaced bound.
        let all_displaced = reps[0].free_mean / 0.2 * (1.0 - 0.2);
        assert!(reps[0].gap() < all_displaced);
    }

    #[test]
    fn free_mean_matches_unrecorded_eval_bitwise() {
        let jobs = jobs_at(&[0.0, 1.0, 2.0], 2.0);
        let spec = CfSpec::Proposed(Policy::new(0.6, None, 0.4));
        let view = flat_view(0.3, 12.0, Some(2));
        let reps = replay_specs(&jobs, &[spec], &view, RoutingPolicy::Home, false);
        let mut expect = 0.0;
        for job in &jobs {
            let (prices, dt) =
                view.home().trace.resample_window(job.arrival, job.deadline, S_MAX);
            let navail = vec![0.0; prices.len()];
            let cf = CounterfactualJob::from_job(job, prices, dt, navail, 1.0);
            expect += cf.eval_spec(&spec, false).0;
        }
        assert_eq!(reps[0].free_mean, expect / jobs.len() as f64);
    }

    #[test]
    fn gap_is_nonnegative_on_random_worlds() {
        for_all(Config::cases(60).seed(41), |rng| {
            let mut jobs = Vec::new();
            for i in 0..rng.range_inclusive(2, 10) {
                let a = rng.uniform(0.0, 4.0);
                let l = rng.range_inclusive(1, 3) as usize;
                let tasks: Vec<ChainTask> = (0..l)
                    .map(|_| ChainTask::new(rng.uniform(0.5, 4.0), rng.uniform(1.0, 8.0)))
                    .collect();
                let makespan: f64 = tasks.iter().map(|t| t.min_exec_time()).sum();
                jobs.push(ChainJob::new(
                    i as u64,
                    a,
                    a + makespan * rng.uniform(1.05, 2.5),
                    tasks,
                ));
            }
            jobs.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
            let horizon = jobs.iter().map(|j| j.deadline).fold(1.0, f64::max) + 1.0;
            let n = (horizon * SLOTS_PER_UNIT as f64) as usize + 2;
            let dt = 1.0 / SLOTS_PER_UNIT as f64;
            let mk_prices = |rng: &mut Pcg32| -> Vec<f64> {
                (0..n)
                    .map(|_| {
                        if rng.chance(0.5) {
                            rng.uniform(0.1, 0.3)
                        } else {
                            rng.uniform(0.4, 1.2)
                        }
                    })
                    .collect()
            };
            let view = MarketView::new(vec![
                MarketOffer {
                    region: "a".into(),
                    instance_type: "default".into(),
                    od_price: 1.0,
                    trace: PriceTrace::from_prices(mk_prices(rng), dt),
                    capacity: Some(rng.range_inclusive(1, 6) as u32),
                },
                MarketOffer {
                    region: "b".into(),
                    instance_type: "default".into(),
                    od_price: rng.uniform(1.0, 1.4),
                    trace: PriceTrace::from_prices(mk_prices(rng), dt),
                    capacity: if rng.chance(0.5) {
                        Some(rng.range_inclusive(1, 4) as u32)
                    } else {
                        None
                    },
                },
            ])
            .unwrap();
            let specs = vec![
                CfSpec::Proposed(Policy::new(rng.uniform(0.3, 1.0), None, rng.uniform(0.15, 0.5))),
                CfSpec::EvenNaive { bid: rng.uniform(0.15, 0.5) },
            ];
            let reps = replay_specs(&jobs, &specs, &view, RoutingPolicy::CheapestFeasible, false);
            for r in &reps {
                if r.gap() < 0.0 {
                    return Err(format!("negative optimism gap: {r:?}"));
                }
                if !r.replayed_mean.is_finite() || !r.free_mean.is_finite() {
                    return Err(format!("non-finite replay: {r:?}"));
                }
            }
            Ok(())
        });
    }
}
