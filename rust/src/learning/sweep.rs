//! Structure-sharing counterfactual sweep engine: the native fast path for
//! the per-job all-policy evaluation (the TOLA hot path).
//!
//! [`CounterfactualJob::eval_spec`] is the specification: an O(S) slot walk
//! per policy, O(N_POL·S) per retired job. But the grid shares almost all of
//! that work, and the walk itself has a closed form — the same insight the
//! AOT Pallas model exploits (`python/compile/model.py`):
//!
//! 1. **Dealloc dedup** — `Dealloc(β')` depends only on the *effective*
//!    allocation parameter (`β₀` when a pool exists and `β₀ ≤ β`, else `β`),
//!    which the §6.1 grids confine to `C1 ∪ C2` (≤ 12 distinct values). The
//!    windows, task deadlines, slot-ownership ranges, and per-window pool
//!    minima are computed once per distinct β', not once per policy.
//! 2. **Per-bid market tables** — spot availability depends on the bid
//!    only, and a grid holds ≤ [`NB_MAX`](super::counterfactual::NB_MAX)
//!    distinct bids. One O(S) pass per
//!    distinct bid builds prefix sums of winning time and winning
//!    price-mass over the resampled window.
//! 3. **Closed-form slot walk** — Def. 3.1's turning-point test uses the
//!    per-task *constant* z̃₀, so the firing condition is affine in
//!    cumulative losing time and monotone along the window: the first firing
//!    slot and the completion slot are both binary searches into the bid's
//!    prefix rows, and the spot cost telescopes through the price-mass
//!    prefix with a single boundary-slot correction.
//!
//! Total: O((NB + NW)·S) precompute + O(N_POL·L·log S) evaluation, against
//! the naive O(N_POL·S). The engine is rankings-faithful to the naive walk
//! (identical window/grant/ownership arithmetic, identical strict
//! turning-point test); `eval_spec` stays in [`super::counterfactual`] as
//! the test oracle and the property tests below pin the two paths together
//! to 1e-9 across random jobs, grids, pool availabilities, and coarsened
//! (`S_MAX`-truncated) windows.

use crate::policy::selfowned::f_selfowned;
use crate::policy::Policy;

use super::counterfactual::{CfSpec, CounterfactualJob, PolicyGridEval, OWNER_OFFSET};

/// Turning-point tolerance, shared with the naive walk and the AOT model
/// (`FIRE_EPS` in `python/compile/kernels/ref.py`).
const FIRE_EPS: f64 = 1e-4;

/// Window layout selector: one plan per distinct effective Dealloc β', plus
/// the even-split baseline layout.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum WindowKey {
    /// `Dealloc(β')`, keyed by the exact bit pattern of β'.
    Dealloc(u64),
    /// Even windows `ŝ_i = e_i + ω/l` (benchmark set P').
    Even,
}

/// Self-owned grant rule for an allocation plan.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum AllocRule {
    /// Rule (12) with sufficiency index β₀ (bit pattern).
    Rule12 { beta0_bits: u64 },
    /// A proposed policy without β₀: the self-owned machinery is bypassed.
    Rule12None,
    /// The naive grab-everything benchmark rule.
    Naive,
}

/// Prefix tables over the resampled window for one distinct bid.
#[derive(Debug, Clone)]
struct BidTables {
    /// `cum_win[k]` = winning seconds in slots `[0, k)` (length S+1).
    cum_win: Vec<f64>,
    /// `cum_price[k]` = Σ `price_j·dt` over winning slots `j < k`.
    cum_price: Vec<f64>,
}

/// The number of in-window slots a sweep over a job covers — the shape
/// [`StreamingTables`] must be built with to be adopted by
/// [`SweepContext::with_tables`]. Shared with [`SweepContext::new`] so the
/// streaming and batch paths can never disagree on the slot count.
pub fn sweep_num_slots(window: f64, dt: f64, prices_len: usize) -> usize {
    let num_slots = (window / dt).ceil() as usize;
    num_slots.min(prices_len).max(1)
}

/// Append-incremental per-bid prefix tables: the same `cum_win`/`cum_price`
/// rows [`SweepContext`] builds per distinct bid, but grown one slot at a
/// time as the feed ingests prices instead of rebuilt O(S) per retirement.
///
/// Each [`append`] executes the exact accumulation the batch build runs per
/// slot (`if price <= bid { w += dt; pw += price·dt }` then push), so a
/// table streamed under *any* split of appends is bitwise identical to the
/// batch-built one — the property tests below pin this.
///
/// **Cache invalidation rule:** a streamed table set is only adopted by
/// [`SweepContext::with_tables`] when its `dt` (exact bits) and `num_slots`
/// match the context's and every slot has been appended ([`is_complete`]);
/// on any mismatch the context silently falls back to the on-demand batch
/// build, so seeding can change cost but never results.
///
/// [`append`]: StreamingTables::append
/// [`is_complete`]: StreamingTables::is_complete
#[derive(Debug, Clone)]
pub struct StreamingTables {
    dt: f64,
    num_slots: usize,
    filled: usize,
    bids: Vec<(u64, BidTables)>,
}

impl StreamingTables {
    /// Start empty tables for the given distinct bids (duplicates are
    /// dropped, first occurrence wins) over a window of `num_slots` slots
    /// of length `dt` (use [`sweep_num_slots`] for the shape).
    pub fn new(bids: &[f64], dt: f64, num_slots: usize) -> StreamingTables {
        let mut uniq: Vec<(u64, BidTables)> = Vec::new();
        for b in bids {
            let key = b.to_bits();
            if uniq.iter().any(|(k, _)| *k == key) {
                continue;
            }
            let mut cum_win = Vec::with_capacity(num_slots + 1);
            let mut cum_price = Vec::with_capacity(num_slots + 1);
            cum_win.push(0.0);
            cum_price.push(0.0);
            uniq.push((key, BidTables { cum_win, cum_price }));
        }
        StreamingTables { dt, num_slots, filled: 0, bids: uniq }
    }

    /// Extend every bid's prefix row by one slot. Appends past `num_slots`
    /// are ignored: the window shape is fixed at construction, and trailing
    /// feed slots are outside it.
    pub fn append(&mut self, price: f64) {
        if self.filled >= self.num_slots {
            return;
        }
        let dt = self.dt;
        for (key, tab) in &mut self.bids {
            let bid = f64::from_bits(*key);
            let mut w = *tab.cum_win.last().expect("cum_win starts at 0.0");
            let mut pw = *tab.cum_price.last().expect("cum_price starts at 0.0");
            if price <= bid {
                w += dt;
                pw += price * dt;
            }
            tab.cum_win.push(w);
            tab.cum_price.push(pw);
        }
        self.filled += 1;
    }

    /// Slots appended so far.
    pub fn filled(&self) -> usize {
        self.filled
    }

    /// The window shape these tables were built for.
    pub fn num_slots(&self) -> usize {
        self.num_slots
    }

    /// True once every in-window slot has been appended — the only state
    /// in which [`SweepContext::with_tables`] will adopt the tables.
    pub fn is_complete(&self) -> bool {
        self.filled == self.num_slots
    }

    fn lookup(&self, key: u64) -> Option<&BidTables> {
        self.bids.iter().find(|(k, _)| *k == key).map(|(_, t)| t)
    }
}

/// A bid's prefix tables inside a context: built on demand (owned) or
/// borrowed from pre-streamed [`StreamingTables`].
enum TabRef<'a> {
    Own(BidTables),
    Pre(&'a BidTables),
}

impl TabRef<'_> {
    fn get(&self) -> &BidTables {
        match self {
            TabRef::Own(t) => t,
            TabRef::Pre(t) => t,
        }
    }
}

/// Geometry shared by every policy with the same window layout.
#[derive(Debug, Clone)]
struct WindowPlan {
    /// Cumulative task deadlines (relative).
    deadlines: Vec<f64>,
    /// Slot-ownership ranges `[k0, k1)` per task — the exact partition the
    /// naive walk's `mid >= deadlines[cur]` cursor produces (tasks whose
    /// window contains no slot sample point get an empty range).
    ranges: Vec<(usize, usize)>,
    /// Per-task `min_slot navail` over the window (naive two-pointer
    /// semantics; 0 for windows containing no slot sample point).
    nmin: Vec<f64>,
}

/// Per-task allocation state for one (window layout, self-owned rule) pair.
#[derive(Debug, Clone)]
struct AllocPlan {
    /// `δ_i − r_i`, clamped at 0.
    delta_eff: Vec<f64>,
    /// Initial spot/on-demand workload `z̃_i = max(0, z_i − r_i·ŝ_i)`.
    zt0: Vec<f64>,
    /// Σ self-owned work (policy-invariant given the pair).
    so_work: f64,
}

/// Lazily-built shared state for sweeping one job under many strategies.
///
/// Construction is O(1); each distinct window layout costs one O(S + L)
/// pass, each distinct bid one O(S) pass, and every [`eval_spec`] after
/// that is O(L·log S).
///
/// [`eval_spec`]: SweepContext::eval_spec
pub struct SweepContext<'a> {
    job: &'a CounterfactualJob,
    has_pool: bool,
    num_slots: usize,
    prebuilt: Option<&'a StreamingTables>,
    bids: Vec<(u64, TabRef<'a>)>,
    windows: Vec<(WindowKey, WindowPlan)>,
    allocs: Vec<((usize, AllocRule), AllocPlan)>,
}

impl<'a> SweepContext<'a> {
    pub fn new(job: &'a CounterfactualJob, has_pool: bool) -> SweepContext<'a> {
        let num_slots = sweep_num_slots(job.window, job.dt, job.prices.len());
        SweepContext {
            job,
            has_pool,
            num_slots,
            prebuilt: None,
            bids: Vec::new(),
            windows: Vec::new(),
            allocs: Vec::new(),
        }
    }

    /// Like [`new`], but seeded with pre-streamed per-bid tables. The seed
    /// is adopted only when its shape matches exactly (same `dt` bits, same
    /// `num_slots`, fully filled); otherwise the context behaves as if
    /// unseeded — identical results either way, only the per-bid O(S) build
    /// is skipped when adopted.
    ///
    /// [`new`]: SweepContext::new
    pub fn with_tables(
        job: &'a CounterfactualJob,
        has_pool: bool,
        tables: &'a StreamingTables,
    ) -> SweepContext<'a> {
        let mut ctx = SweepContext::new(job, has_pool);
        if tables.num_slots == ctx.num_slots
            && tables.is_complete()
            && tables.dt.to_bits() == job.dt.to_bits()
        {
            ctx.prebuilt = Some(tables);
        }
        ctx
    }

    /// Evaluate one proposed policy: `(cost, spot_work, od_work, so_work)`,
    /// matching [`CounterfactualJob::eval_policy`] to ~1e-12.
    pub fn eval_policy(&mut self, policy: &Policy) -> (f64, f64, f64, f64) {
        self.eval_spec(&CfSpec::Proposed(*policy))
    }

    /// Evaluate any strategy spec (proposed or benchmark).
    pub fn eval_spec(&mut self, spec: &CfSpec) -> (f64, f64, f64, f64) {
        let (wkey, rule, bid) = match spec {
            CfSpec::Proposed(p) => (
                WindowKey::Dealloc(p.dealloc_beta(self.has_pool).to_bits()),
                match p.beta0 {
                    Some(b0) => AllocRule::Rule12 { beta0_bits: b0.to_bits() },
                    None => AllocRule::Rule12None,
                },
                p.bid,
            ),
            CfSpec::EvenNaive { bid } => (WindowKey::Even, AllocRule::Naive, *bid),
            CfSpec::DeallocNaive(p) => {
                (WindowKey::Dealloc(p.beta.to_bits()), AllocRule::Naive, p.bid)
            }
        };
        let wi = self.window_index(wkey);
        let ai = self.alloc_index(wi, rule);
        let bi = self.bid_index(bid);
        let plan = &self.windows[wi].1;
        let alloc = &self.allocs[ai].1;
        let tab = self.bids[bi].1.get();
        let (dt, prices) = (self.job.dt, &self.job.prices);

        let mut spot_work = 0.0;
        let mut spot_cost = 0.0;
        let mut od_work = 0.0;
        for i in 0..self.job.l {
            let zt0 = alloc.zt0[i];
            if zt0 <= 0.0 {
                continue;
            }
            let de = alloc.delta_eff[i];
            let (k0, k1) = plan.ranges[i];
            if de <= 0.0 || k0 >= k1 {
                // No capacity or no owned slots: the whole z̃ runs on-demand
                // (the naive walk charges it when the cursor passes the
                // task, or in the final cleanup).
                od_work += zt0;
                continue;
            }
            let deadline = plan.deadlines[i];
            let w_k0 = tab.cum_win[k0];
            let tol = FIRE_EPS * (1.0 + zt0);

            // First firing slot: Def. 3.1's strict test at slot start,
            //   z̃(k) >= δeff·(ς − k·dt) − tol,  z̃(k) = z̃₀ − δeff·W(k),
            // monotone in k because W grows by at most dt per slot.
            let mut lo = k0;
            let mut hi = k1;
            while lo < hi {
                let m = (lo + hi) / 2;
                let fired = zt0 - de * (tab.cum_win[m] - w_k0)
                    >= de * (deadline - m as f64 * dt) - tol;
                if fired {
                    hi = m;
                } else {
                    lo = m + 1;
                }
            }
            let w_fire = if lo < k1 {
                tab.cum_win[lo] - w_k0
            } else {
                f64::INFINITY
            };

            // Winning time actually available: only the last owned slot can
            // extend past the deadline (clip it).
            let w_full = tab.cum_win[k1] - w_k0;
            let k_last = k1 - 1;
            let miss = if tab.cum_win[k_last + 1] > tab.cum_win[k_last] {
                let secs_last = (deadline - k_last as f64 * dt).clamp(0.0, dt);
                dt - secs_last
            } else {
                0.0
            };
            let w_end = (w_full - miss).max(0.0);

            let spot_time = w_fire.min(w_end).min(zt0 / de).max(0.0);
            od_work += (zt0 - de * spot_time).max(0.0);
            if spot_time <= 0.0 {
                continue;
            }
            spot_work += de * spot_time;

            // Spot cost telescopes through the price-mass prefix: find the
            // slot where cumulative winning time reaches `spot_time` and
            // refund the unconsumed tail of that boundary slot.
            let target_w = w_k0 + spot_time;
            let mut lo2 = k0;
            let mut hi2 = k1;
            while lo2 < hi2 {
                let m = (lo2 + hi2) / 2;
                if tab.cum_win[m] >= target_w {
                    hi2 = m;
                } else {
                    lo2 = m + 1;
                }
            }
            let k_stop = lo2; // first k with cum_win[k] >= target_w (or k1)
            let pw = tab.cum_price[k_stop] - tab.cum_price[k0];
            let overshoot = (tab.cum_win[k_stop] - target_w).max(0.0);
            let price_last = prices[k_stop - 1];
            spot_cost += de * (pw - price_last * overshoot).max(0.0);
        }

        let cost = spot_cost + self.job.od_price * od_work;
        (cost, spot_work, od_work, alloc.so_work)
    }

    fn bid_index(&mut self, bid: f64) -> usize {
        let key = bid.to_bits();
        if let Some(i) = self.bids.iter().position(|(k, _)| *k == key) {
            return i;
        }
        if let Some(tab) = self.prebuilt.and_then(|t| t.lookup(key)) {
            self.bids.push((key, TabRef::Pre(tab)));
            return self.bids.len() - 1;
        }
        let dt = self.job.dt;
        let mut cum_win = Vec::with_capacity(self.num_slots + 1);
        let mut cum_price = Vec::with_capacity(self.num_slots + 1);
        let (mut w, mut pw) = (0.0f64, 0.0f64);
        cum_win.push(0.0);
        cum_price.push(0.0);
        for k in 0..self.num_slots {
            let price = self.job.prices[k];
            if price <= bid {
                w += dt;
                pw += price * dt;
            }
            cum_win.push(w);
            cum_price.push(pw);
        }
        self.bids.push((key, TabRef::Own(BidTables { cum_win, cum_price })));
        self.bids.len() - 1
    }

    fn window_index(&mut self, wkey: WindowKey) -> usize {
        if let Some(i) = self.windows.iter().position(|(k, _)| *k == wkey) {
            return i;
        }
        let job = self.job;
        let sizes = match wkey {
            WindowKey::Dealloc(bits) => job.windows(f64::from_bits(bits)),
            WindowKey::Even => job.windows_even(),
        };
        let mut deadlines = Vec::with_capacity(job.l);
        let mut acc = 0.0;
        for s in &sizes {
            acc += s;
            deadlines.push(acc);
        }

        // Slot-ownership ranges: the same traversal as the naive slot walk
        // (sample point `k·dt + OFFSET·dt`, cursor advances on `mid >= ς`).
        let dt = job.dt;
        let mut ranges = vec![(self.num_slots, self.num_slots); job.l];
        let mut started = vec![false; job.l];
        let mut cur = 0usize;
        for k in 0..self.num_slots {
            let mid = k as f64 * dt + OWNER_OFFSET * dt;
            while cur < job.l && mid >= deadlines[cur] {
                cur += 1;
            }
            if cur >= job.l {
                break;
            }
            if started[cur] {
                ranges[cur].1 = k + 1;
            } else {
                ranges[cur] = (k, k + 1);
                started[cur] = true;
            }
        }

        // Per-window pool minima: the naive grant loop's two-pointer
        // (sample point `(k + OFFSET)·dt` — kept bit-identical to it).
        let mut nmin = vec![0.0f64; job.l];
        let mut slot_cursor = 0usize;
        for i in 0..job.l {
            let lo = if i == 0 { 0.0 } else { deadlines[i - 1] };
            let hi = deadlines[i];
            let mut nm = f64::INFINITY;
            while slot_cursor < self.num_slots {
                let mid = (slot_cursor as f64 + OWNER_OFFSET) * dt;
                if mid < lo {
                    slot_cursor += 1;
                    continue;
                }
                if mid >= hi {
                    break;
                }
                nm = nm.min(job.navail[slot_cursor]);
                slot_cursor += 1;
            }
            nmin[i] = if nm.is_finite() { nm } else { 0.0 };
        }

        self.windows.push((wkey, WindowPlan { deadlines, ranges, nmin }));
        self.windows.len() - 1
    }

    fn alloc_index(&mut self, wi: usize, rule: AllocRule) -> usize {
        let key = (wi, rule);
        if let Some(i) = self.allocs.iter().position(|(k, _)| *k == key) {
            return i;
        }
        let job = self.job;
        let plan = &self.windows[wi].1;
        let mut delta_eff = Vec::with_capacity(job.l);
        let mut zt0 = Vec::with_capacity(job.l);
        let mut so_work = 0.0;
        for i in 0..job.l {
            let lo = if i == 0 { 0.0 } else { plan.deadlines[i - 1] };
            let hi = plan.deadlines[i];
            let hat_s = (hi - lo).max(1e-12);
            let ri = if !self.has_pool {
                0.0
            } else {
                match rule {
                    AllocRule::Rule12 { beta0_bits } => {
                        let b0 = f64::from_bits(beta0_bits);
                        let f = f_selfowned(job.z[i], job.delta[i], hat_s, b0);
                        f.min(plan.nmin[i]).min(job.delta[i]).max(0.0)
                    }
                    AllocRule::Rule12None => 0.0,
                    AllocRule::Naive => plan.nmin[i].min(job.delta[i]).max(0.0),
                }
            };
            let covered = ri * hat_s;
            zt0.push((job.z[i] - covered).max(0.0));
            so_work += job.z[i].min(covered);
            delta_eff.push((job.delta[i] - ri).max(0.0));
        }
        self.allocs.push((key, AllocPlan { delta_eff, zt0, so_work }));
        self.allocs.len() - 1
    }
}

/// Sweep one job over a proposed-policy grid through the shared-structure
/// engine (the fast path behind
/// [`super::counterfactual::eval_grid_native`]).
pub fn eval_grid(
    job: &CounterfactualJob,
    policies: &[Policy],
    has_pool: bool,
) -> PolicyGridEval {
    let mut ctx = SweepContext::new(job, has_pool);
    let mut out = PolicyGridEval {
        costs: Vec::with_capacity(policies.len()),
        spot_work: Vec::with_capacity(policies.len()),
        od_work: Vec::with_capacity(policies.len()),
        so_work: Vec::with_capacity(policies.len()),
    };
    for p in policies {
        let (c, sw, ow, sow) = ctx.eval_policy(p);
        out.costs.push(c);
        out.spot_work.push(sw);
        out.od_work.push(ow);
        out.so_work.push(sow);
    }
    out
}

/// Sweep one job over arbitrary strategy specs, costs only (the shape the
/// TOLA weight update consumes).
pub fn eval_spec_costs(job: &CounterfactualJob, specs: &[CfSpec], has_pool: bool) -> Vec<f64> {
    let mut ctx = SweepContext::new(job, has_pool);
    specs.iter().map(|s| ctx.eval_spec(s).0).collect()
}

/// [`eval_spec_costs`] seeded with pre-streamed per-bid tables (`None` or a
/// shape mismatch falls back to the unseeded build — same results either
/// way, pinned exactly by the streaming property tests).
pub fn eval_spec_costs_seeded(
    job: &CounterfactualJob,
    tables: Option<&StreamingTables>,
    specs: &[CfSpec],
    has_pool: bool,
) -> Vec<f64> {
    let mut ctx = match tables {
        Some(t) => SweepContext::with_tables(job, has_pool, t),
        None => SweepContext::new(job, has_pool),
    };
    specs.iter().map(|s| ctx.eval_spec(s).0).collect()
}

/// Batched retirement sweep: evaluate every job of a batch against the full
/// grid, fanning jobs across [`crate::coordinator::exec_pool::parallel_map`]
/// workers. Results are in job order.
pub fn sweep_batch(
    jobs: &[CounterfactualJob],
    grid: &[Policy],
    has_pool: bool,
    threads: usize,
) -> Vec<PolicyGridEval> {
    crate::coordinator::exec_pool::parallel_map(jobs.len(), threads, |i| {
        eval_grid(&jobs[i], grid, has_pool)
    })
}

/// Batched retirement sweep over strategy specs, costs only — the entry
/// point the coordinator's event loop uses when several jobs retire between
/// consecutive task events.
pub fn sweep_batch_costs(
    jobs: &[CounterfactualJob],
    specs: &[CfSpec],
    has_pool: bool,
    threads: usize,
) -> Vec<Vec<f64>> {
    crate::coordinator::exec_pool::parallel_map(jobs.len(), threads, |i| {
        eval_spec_costs(&jobs[i], specs, has_pool)
    })
}

/// [`sweep_batch_costs`] with one optional pre-streamed table set per job
/// (`tables.len() == jobs.len()`); `None` entries build tables on demand.
pub fn sweep_batch_costs_seeded(
    jobs: &[CounterfactualJob],
    tables: &[Option<StreamingTables>],
    specs: &[CfSpec],
    has_pool: bool,
    threads: usize,
) -> Vec<Vec<f64>> {
    assert_eq!(jobs.len(), tables.len(), "one table seed slot per job");
    crate::coordinator::exec_pool::parallel_map(jobs.len(), threads, |i| {
        eval_spec_costs_seeded(&jobs[i], tables[i].as_ref(), specs, has_pool)
    })
}

/// The multi-offer sweep: one structure-sharing [`SweepContext`] per market
/// offer, sharing nothing *across* offers (each offer has its own realized
/// prices) but everything *within* one — per-offer bid prefix tables,
/// window plans, and allocation plans are all built at most once per
/// distinct value, exactly as in the single-offer engine.
///
/// A counterfactual is capacity-free by construction (one job's "what if"
/// cannot replay the whole market's contention), so the counterfactual
/// router places each (job, policy) pair on its cheapest offer: the cost
/// is the min over offers, ties to the lowest index. A one-element offer
/// set is the degenerate case and returns the single context's numbers
/// unchanged — the same floating-point ops in the same order.
pub struct MultiSweepContext<'a> {
    ctxs: Vec<SweepContext<'a>>,
}

impl<'a> MultiSweepContext<'a> {
    /// `offers` holds the same retired job marshalled once per market
    /// offer (that offer's resampled prices and on-demand price).
    pub fn new(offers: &'a [CounterfactualJob], has_pool: bool) -> MultiSweepContext<'a> {
        assert!(!offers.is_empty(), "multi-sweep over zero offers");
        MultiSweepContext {
            ctxs: offers
                .iter()
                .map(|cf| SweepContext::new(cf, has_pool))
                .collect(),
        }
    }

    /// Like [`new`], but with one optional pre-streamed table set per offer
    /// (`tables.len() == offers.len()`); `None` or shape-mismatched entries
    /// build on demand, exactly as unseeded.
    ///
    /// [`new`]: MultiSweepContext::new
    pub fn with_tables(
        offers: &'a [CounterfactualJob],
        tables: &'a [Option<StreamingTables>],
        has_pool: bool,
    ) -> MultiSweepContext<'a> {
        assert!(!offers.is_empty(), "multi-sweep over zero offers");
        assert_eq!(offers.len(), tables.len(), "one table seed slot per offer");
        MultiSweepContext {
            ctxs: offers
                .iter()
                .zip(tables)
                .map(|(cf, t)| match t {
                    Some(t) => SweepContext::with_tables(cf, has_pool, t),
                    None => SweepContext::new(cf, has_pool),
                })
                .collect(),
        }
    }

    /// Evaluate one spec: `(offer, (cost, spot_work, od_work, so_work))`
    /// of the cheapest offer. Matches [`eval_spec_multi_naive`]
    /// (min over per-offer naive walks) to the single-offer tolerance.
    ///
    /// [`eval_spec_multi_naive`]: super::counterfactual::eval_spec_multi_naive
    pub fn eval_spec(&mut self, spec: &CfSpec) -> (usize, (f64, f64, f64, f64)) {
        let mut best_k = 0usize;
        let mut best = self.ctxs[0].eval_spec(spec);
        for k in 1..self.ctxs.len() {
            let q = self.ctxs[k].eval_spec(spec);
            if q.0 < best.0 {
                best = q;
                best_k = k;
            }
        }
        (best_k, best)
    }
}

/// Sweep one retired job (marshalled per offer) over strategy specs,
/// costs only — the multi-offer counterpart of [`eval_spec_costs`].
pub fn eval_spec_costs_multi(
    offers: &[CounterfactualJob],
    specs: &[CfSpec],
    has_pool: bool,
) -> Vec<f64> {
    let mut ctx = MultiSweepContext::new(offers, has_pool);
    specs.iter().map(|s| ctx.eval_spec(s).1 .0).collect()
}

/// [`eval_spec_costs_multi`] seeded with one optional pre-streamed table
/// set per offer.
pub fn eval_spec_costs_multi_seeded(
    offers: &[CounterfactualJob],
    tables: &[Option<StreamingTables>],
    specs: &[CfSpec],
    has_pool: bool,
) -> Vec<f64> {
    let mut ctx = MultiSweepContext::with_tables(offers, tables, has_pool);
    specs.iter().map(|s| ctx.eval_spec(s).1 .0).collect()
}

/// Batched multi-offer retirement sweep: `jobs[i]` is one retired job
/// marshalled once per offer. Results are in job order.
pub fn sweep_batch_costs_multi(
    jobs: &[Vec<CounterfactualJob>],
    specs: &[CfSpec],
    has_pool: bool,
    threads: usize,
) -> Vec<Vec<f64>> {
    crate::coordinator::exec_pool::parallel_map(jobs.len(), threads, |i| {
        eval_spec_costs_multi(&jobs[i], specs, has_pool)
    })
}

/// [`sweep_batch_costs_multi`] with one optional pre-streamed table set
/// per (job, offer) pair — `tables[i].len() == jobs[i].len()`.
pub fn sweep_batch_costs_multi_seeded(
    jobs: &[Vec<CounterfactualJob>],
    tables: &[Vec<Option<StreamingTables>>],
    specs: &[CfSpec],
    has_pool: bool,
    threads: usize,
) -> Vec<Vec<f64>> {
    assert_eq!(jobs.len(), tables.len(), "one table seed row per job");
    crate::coordinator::exec_pool::parallel_map(jobs.len(), threads, |i| {
        eval_spec_costs_multi_seeded(&jobs[i], &tables[i], specs, has_pool)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::market::SLOTS_PER_UNIT;
    use crate::policy::{benchmark_bids, grid_c2, policy_set_full};
    use crate::util::prop::{for_all, Config};
    use crate::util::rng::Pcg32;
    use crate::workload::{ChainJob, ChainTask};

    fn random_cf(rng: &mut Pcg32, coarsen: bool) -> CounterfactualJob {
        let l = rng.range_inclusive(1, 8) as usize;
        let tasks: Vec<ChainTask> = (0..l)
            .map(|_| ChainTask::new(rng.uniform(0.3, 12.0), rng.uniform(1.0, 16.0)))
            .collect();
        let makespan: f64 = tasks.iter().map(|t| t.min_exec_time()).sum();
        // Include zero-slack windows (multiplier 1.0): every task fires its
        // turning point immediately.
        let mult = if rng.chance(0.15) { 1.0 } else { rng.uniform(1.02, 2.5) };
        let job = ChainJob::new(0, 0.0, makespan * mult, tasks);
        let dt = if coarsen {
            // Long window truncated to few slots — the S_MAX resampling
            // regime (slot length grows so the fixed shape still covers it).
            job.window() / rng.range_inclusive(4, 48) as f64
        } else {
            1.0 / SLOTS_PER_UNIT as f64
        };
        let n = (job.window() / dt).ceil() as usize + rng.range_inclusive(0, 2) as usize;
        let n = n.max(1);
        let prices: Vec<f64> = (0..n)
            .map(|_| {
                if rng.chance(0.15) {
                    f64::INFINITY // padding-style never-winning slots
                } else if rng.chance(0.5) {
                    rng.uniform(0.12, 0.3)
                } else {
                    rng.uniform(0.4, 1.0)
                }
            })
            .collect();
        let pooled = rng.chance(0.7);
        let navail: Vec<f64> = (0..n)
            .map(|_| if pooled { rng.range_inclusive(0, 50) as f64 } else { 0.0 })
            .collect();
        CounterfactualJob::from_job(&job, prices, dt, navail, 1.0)
    }

    fn assert_quad_close(a: (f64, f64, f64, f64), b: (f64, f64, f64, f64)) -> Result<(), String> {
        for (x, y) in [(a.0, b.0), (a.1, b.1), (a.2, b.2), (a.3, b.3)] {
            if (x - y).abs() > 1e-9 * x.abs().max(1.0) {
                return Err(format!("naive {a:?} vs sweep {b:?}"));
            }
        }
        Ok(())
    }

    #[test]
    fn matches_oracle_on_paper_example() {
        let job = ChainJob::paper_example();
        let dt = 1.0 / SLOTS_PER_UNIT as f64;
        let n = (job.window() / dt).ceil() as usize + 1;
        let prices: Vec<f64> = (0..n).map(|k| if k % 3 == 0 { 0.2 } else { 0.6 }).collect();
        let cf = CounterfactualJob::from_job(&job, prices, dt, vec![6.0; n], 1.0);
        let grid = policy_set_full();
        let mut ctx = SweepContext::new(&cf, true);
        for p in &grid {
            assert_quad_close(cf.eval_policy(p, true), ctx.eval_policy(p)).unwrap();
        }
    }

    #[test]
    fn prop_sweep_matches_oracle_across_jobs_grids_and_pools() {
        // The tentpole equivalence: (cost, spot, od, so) quadruples of the
        // fast path match the naive oracle to 1e-9 across random jobs,
        // random sub-grids, pool availabilities, and coarsened windows.
        for_all(Config::cases(60).seed(2026), |rng| {
            let coarsen = rng.chance(0.34);
            let cf = random_cf(rng, coarsen);
            let has_pool = cf.navail.iter().any(|&v| v > 0.0);
            let mut ctx = SweepContext::new(&cf, has_pool);
            // Full proposed grid.
            for p in policy_set_full() {
                assert_quad_close(cf.eval_policy(&p, has_pool), ctx.eval_policy(&p))?;
            }
            // Benchmark specs share the same context.
            for bid in benchmark_bids() {
                let spec = CfSpec::EvenNaive { bid };
                assert_quad_close(cf.eval_spec(&spec, has_pool), ctx.eval_spec(&spec))?;
            }
            for beta in grid_c2() {
                let spec = CfSpec::DeallocNaive(Policy::new(beta, None, 0.24));
                assert_quad_close(cf.eval_spec(&spec, has_pool), ctx.eval_spec(&spec))?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_structure_sharing_is_policy_order_independent() {
        // Cached plans must not leak state between policies: evaluating the
        // grid in reverse through the same context gives identical numbers.
        for_all(Config::cases(20).seed(2027), |rng| {
            let cf = random_cf(rng, false);
            let has_pool = cf.navail.iter().any(|&v| v > 0.0);
            let grid = policy_set_full();
            let fwd = eval_grid(&cf, &grid, has_pool);
            let mut ctx = SweepContext::new(&cf, has_pool);
            let mut rev: Vec<(f64, f64, f64, f64)> =
                grid.iter().rev().map(|p| ctx.eval_policy(p)).collect();
            rev.reverse();
            for (i, r) in rev.iter().enumerate() {
                if fwd.costs[i] != r.0 || fwd.od_work[i] != r.2 {
                    return Err(format!("order-dependent result at policy {i}"));
                }
            }
            Ok(())
        });
    }

    /// Re-marshal one job with fresh prices/od, as one market offer would.
    fn offer_variant(rng: &mut Pcg32, cf: &CounterfactualJob, od: f64) -> CounterfactualJob {
        let prices: Vec<f64> = (0..cf.prices.len())
            .map(|_| {
                if rng.chance(0.1) {
                    f64::INFINITY
                } else {
                    rng.uniform(0.12, 1.0)
                }
            })
            .collect();
        CounterfactualJob {
            prices: prices.into(),
            od_price: od,
            ..cf.clone()
        }
    }

    /// The bid a spec sweeps at (mirrors the coordinator's marshaling).
    fn spec_bid(spec: &CfSpec) -> f64 {
        match spec {
            CfSpec::Proposed(p) => p.bid,
            CfSpec::EvenNaive { bid } => *bid,
            CfSpec::DeallocNaive(p) => p.bid,
        }
    }

    /// Stream `cf.prices[..num_slots]` into fresh tables using `rng`-sized
    /// append chunks (including size-1 and all-at-once extremes by chance).
    fn stream_tables(rng: &mut Pcg32, cf: &CounterfactualJob, specs: &[CfSpec]) -> StreamingTables {
        let bids: Vec<f64> = specs.iter().map(spec_bid).collect();
        let num_slots = sweep_num_slots(cf.window, cf.dt, cf.prices.len());
        let mut st = StreamingTables::new(&bids, cf.dt, num_slots);
        let mut k = 0usize;
        while k < num_slots {
            let step = if rng.chance(0.1) {
                num_slots // all-remaining at once
            } else {
                rng.range_inclusive(1, 7) as usize
            };
            for _ in 0..step {
                if k >= num_slots {
                    break;
                }
                st.append(cf.prices[k]);
                k += 1;
            }
        }
        // Appends past the window shape must be ignored.
        st.append(0.01);
        assert!(st.is_complete(), "streamed {} of {num_slots}", st.filled());
        st
    }

    #[test]
    fn prop_streaming_tables_match_batch_under_arbitrary_splits() {
        // The tentpole (b) equivalence: per-bid tables streamed under ANY
        // split of appends give bit-identical sweep results to the batch
        // O(S) rebuild — exact equality, not tolerance.
        for_all(Config::cases(40).seed(2029), |rng| {
            let cf = random_cf(rng, rng.chance(0.34));
            let has_pool = cf.navail.iter().any(|&v| v > 0.0);
            let mut specs: Vec<CfSpec> =
                policy_set_full().into_iter().map(CfSpec::Proposed).collect();
            specs.extend(benchmark_bids().into_iter().map(|bid| CfSpec::EvenNaive { bid }));
            let st = stream_tables(rng, &cf, &specs);
            let seeded = eval_spec_costs_seeded(&cf, Some(&st), &specs, has_pool);
            let batch = eval_spec_costs(&cf, &specs, has_pool);
            if seeded != batch {
                return Err("seeded sweep diverged from batch build".into());
            }
            Ok(())
        });
    }

    #[test]
    fn incomplete_or_mismatched_tables_fall_back_to_batch_build() {
        let mut rng = Pcg32::new(81);
        let cf = random_cf(&mut rng, false);
        let has_pool = cf.navail.iter().any(|&v| v > 0.0);
        let specs: Vec<CfSpec> = benchmark_bids()
            .into_iter()
            .map(|bid| CfSpec::EvenNaive { bid })
            .collect();
        let batch = eval_spec_costs(&cf, &specs, has_pool);
        let bids: Vec<f64> = specs.iter().map(spec_bid).collect();
        let num_slots = sweep_num_slots(cf.window, cf.dt, cf.prices.len());
        // Incomplete tables (one slot short) must not be adopted.
        let mut partial = StreamingTables::new(&bids, cf.dt, num_slots);
        for k in 0..num_slots.saturating_sub(1) {
            partial.append(cf.prices[k]);
        }
        assert!(!partial.is_complete() || num_slots == 1);
        assert_eq!(eval_spec_costs_seeded(&cf, Some(&partial), &specs, has_pool), batch);
        // Wrong shape (different num_slots) must not be adopted either.
        let mut wrong = StreamingTables::new(&bids, cf.dt, num_slots + 3);
        for k in 0..num_slots + 3 {
            wrong.append(cf.prices[k % cf.prices.len()]);
        }
        assert!(wrong.is_complete());
        assert_eq!(eval_spec_costs_seeded(&cf, Some(&wrong), &specs, has_pool), batch);
    }

    #[test]
    fn prop_seeded_multi_sweep_is_bit_identical_to_unseeded() {
        // Mixed seeding (some offers streamed, some not) must route and
        // cost identically to the fully unseeded multi-offer sweep.
        for_all(Config::cases(25).seed(2030), |rng| {
            let base = random_cf(rng, rng.chance(0.3));
            let n_offers = rng.range_inclusive(1, 4) as usize;
            let offers: Vec<CounterfactualJob> = (0..n_offers)
                .map(|k| {
                    if k == 0 {
                        base.clone()
                    } else {
                        offer_variant(rng, &base, rng.uniform(0.8, 1.4))
                    }
                })
                .collect();
            let has_pool = base.navail.iter().any(|&v| v > 0.0);
            let mut specs: Vec<CfSpec> =
                policy_set_full().into_iter().map(CfSpec::Proposed).collect();
            specs.extend(benchmark_bids().into_iter().map(|bid| CfSpec::EvenNaive { bid }));
            let tables: Vec<Option<StreamingTables>> = offers
                .iter()
                .map(|cf| rng.chance(0.75).then(|| stream_tables(rng, cf, &specs)))
                .collect();
            let seeded = eval_spec_costs_multi_seeded(&offers, &tables, &specs, has_pool);
            let plain = eval_spec_costs_multi(&offers, &specs, has_pool);
            if seeded != plain {
                return Err("seeded multi sweep diverged from unseeded".into());
            }
            Ok(())
        });
    }

    #[test]
    fn prop_multi_sweep_matches_min_over_offer_oracles() {
        use super::super::counterfactual::eval_spec_multi_naive;
        // The multi-offer generalization: per-offer prefix tables, cheapest
        // offer wins. Pinned against the min-over-naive-walks oracle across
        // random jobs, offer counts, and the full spec zoo.
        for_all(Config::cases(40).seed(2028), |rng| {
            let base = random_cf(rng, rng.chance(0.3));
            let n_offers = rng.range_inclusive(1, 4) as usize;
            let offers: Vec<CounterfactualJob> = (0..n_offers)
                .map(|k| {
                    if k == 0 {
                        base.clone()
                    } else {
                        offer_variant(rng, &base, rng.uniform(0.8, 1.4))
                    }
                })
                .collect();
            let has_pool = base.navail.iter().any(|&v| v > 0.0);
            let mut ctx = MultiSweepContext::new(&offers, has_pool);
            let mut specs: Vec<CfSpec> =
                policy_set_full().into_iter().map(CfSpec::Proposed).collect();
            specs.extend(benchmark_bids().into_iter().map(|bid| CfSpec::EvenNaive { bid }));
            for spec in &specs {
                let (ko, oracle) = eval_spec_multi_naive(&offers, spec, has_pool);
                let (ks, fast) = ctx.eval_spec(spec);
                // The min cost must always agree.
                if (fast.0 - oracle.0).abs() > 1e-9 * oracle.0.abs().max(1.0) {
                    return Err(format!(
                        "min cost {} (offer {ks}) vs oracle {} (offer {ko})",
                        fast.0, oracle.0
                    ));
                }
                // The full work breakdown is only comparable when both
                // picked the same offer; a near-tie may legitimately
                // resolve differently between the 1e-12-close paths.
                if ko == ks {
                    assert_quad_close(oracle, fast)?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn one_offer_multi_sweep_is_bit_identical_to_single() {
        // The degenerate case must not just be close — it must be the same
        // floating-point results, or one-offer view runs would drift from
        // the legacy single-trace path.
        let mut rng = Pcg32::new(79);
        for _ in 0..10 {
            let cf = random_cf(&mut rng, false);
            let has_pool = cf.navail.iter().any(|&v| v > 0.0);
            let offers = vec![cf.clone()];
            let specs: Vec<CfSpec> = policy_set_full()
                .into_iter()
                .map(CfSpec::Proposed)
                .collect();
            let single = eval_spec_costs(&cf, &specs, has_pool);
            let multi = eval_spec_costs_multi(&offers, &specs, has_pool);
            assert_eq!(single, multi);
        }
    }

    #[test]
    fn multi_batch_matches_per_job_path() {
        let mut rng = Pcg32::new(80);
        let jobs: Vec<Vec<CounterfactualJob>> = (0..5)
            .map(|_| {
                let base = random_cf(&mut rng, false);
                let extra = offer_variant(&mut rng, &base, 1.1);
                vec![base, extra]
            })
            .collect();
        let specs: Vec<CfSpec> = benchmark_bids()
            .into_iter()
            .map(|bid| CfSpec::EvenNaive { bid })
            .collect();
        let batched = sweep_batch_costs_multi(&jobs, &specs, false, 3);
        for (job, row) in jobs.iter().zip(&batched) {
            assert_eq!(row, &eval_spec_costs_multi(job, &specs, false));
        }
    }

    #[test]
    fn sweep_batch_matches_single_job_path() {
        let mut rng = Pcg32::new(77);
        let jobs: Vec<CounterfactualJob> = (0..6).map(|_| random_cf(&mut rng, false)).collect();
        let grid = policy_set_full();
        let batched = sweep_batch(&jobs, &grid, true, 4);
        assert_eq!(batched.len(), jobs.len());
        for (job, got) in jobs.iter().zip(&batched) {
            let solo = eval_grid(job, &grid, true);
            assert_eq!(solo.costs, got.costs);
            assert_eq!(solo.so_work, got.so_work);
        }
    }

    #[test]
    fn batch_costs_match_spec_evaluation() {
        let mut rng = Pcg32::new(78);
        let jobs: Vec<CounterfactualJob> = (0..4).map(|_| random_cf(&mut rng, true)).collect();
        let specs: Vec<CfSpec> = benchmark_bids()
            .into_iter()
            .map(|bid| CfSpec::EvenNaive { bid })
            .collect();
        let got = sweep_batch_costs(&jobs, &specs, false, 2);
        for (job, row) in jobs.iter().zip(&got) {
            for (spec, c) in specs.iter().zip(row) {
                let (oracle, _, _, _) = job.eval_spec(spec, false);
                assert!((oracle - c).abs() <= 1e-9 * oracle.abs().max(1.0));
            }
        }
    }
}
