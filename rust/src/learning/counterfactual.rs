//! The counterfactual cost model: cost of a retired job under *every*
//! policy of the grid, from the realized spot prices over its window.
//!
//! This is the TOLA hot path (one all-policy sweep per job) and the exact
//! specification implemented by the AOT Pallas kernel
//! (`python/compile/kernels/policy_sim.py`), its pure-jnp oracle
//! (`kernels/ref.py`), and this native Rust version. All three must agree.
//!
//! ## Model semantics (fixed-shape, slot-quantized)
//!
//! The evaluation uses the paper's *expected timeline*: each task occupies
//! exactly its allocated window `[ς_{i-1}, ς_i]` (Algorithm 2's windows; no
//! early-finish cascading — the realized executor in [`crate::sim`] keeps
//! that, but counterfactuals follow the analytical model the weights are
//! meant to rank):
//!
//! 1. `Dealloc(β')` splits the window (`β' = β₀` when a pool exists and
//!    `β₀ ≤ β`, else `β`), with the slack handed out in the pre-computed
//!    `order` (descending parallelism bound, ties by index) and any
//!    leftover going to the last task of the order.
//! 2. Per task: `r_i = ⌊min{f(β₀), min_slot navail, δ_i}⌋` (Eq. 11/12)
//!    from the per-slot pool availability `navail`, and
//!    `z̃_i = max(0, z_i − r_i·ŝ_i)`.
//! 3. Slot walk over the resampled window (slot length `dt`): the task
//!    owning a slot is the one whose window contains the slot midpoint.
//!    While it has flexibility (Def. 3.1) it takes `δ_i − r_i` spot
//!    instances in winning slots (`price ≤ b`), paying the realized price;
//!    at the turning point — Def. 3.1's strict flexibility test, checked at
//!    each slot start before progress — the rest of `z̃` goes on-demand at
//!    price `p` in one analytic charge (the tail runs to the task deadline
//!    by construction).
//!
//! Costs are expected to be *rankings-faithful*: TOLA only needs relative
//! costs, and the slot-end turning-point check is applied uniformly across
//! policies.

use crate::policy::selfowned::f_selfowned;
use crate::policy::Policy;
use crate::workload::ChainJob;

/// Fixed shapes shared with the AOT artifacts (see DESIGN.md §6 and
/// `python/compile/aot.py`). Changing these requires re-running
/// `make artifacts`.
pub const L_MAX: usize = 128;
pub const S_MAX: usize = 2048;
pub const N_POL: usize = 192;
/// Max distinct bid values in a grid (the §6.1 grid has 5).
pub const NB_MAX: usize = 8;

/// Slot-ownership sample point: 63/128 of the slot. Exact window
/// boundaries of the paper's rational grids (e.g. β=1/1.3 on a 1/12 slot
/// grid) land exactly on slot midpoints, where f32 vs f64 disagree; 63/128
/// is exactly representable and collides with no small-denominator
/// rational. Shared with compile/model.py and kernels/ref.py.
pub const OWNER_OFFSET: f64 = 0.4921875;

/// A job marshalled for the counterfactual sweep (padded, relative times:
/// the window is `[0, window]`).
#[derive(Debug, Clone)]
pub struct CounterfactualJob {
    /// Number of (real) tasks `l ≤ L_MAX`.
    pub l: usize,
    /// Minimum execution times `e_i` (chain order).
    pub e: Vec<f64>,
    /// Parallelism bounds `δ_i`.
    pub delta: Vec<f64>,
    /// Workloads `z_i`.
    pub z: Vec<f64>,
    /// Dealloc order: task indices by descending `δ`, ties by index.
    pub order: Vec<usize>,
    /// Window length `D = d_j − a_j`.
    pub window: f64,
    /// Resampled spot prices, one per slot (`s` slots of length `dt`
    /// covering `[0, D]`; padding slots carry `+inf`). Shared: one retired
    /// job marshalled for several market offers shares its per-job arrays
    /// instead of cloning them per offer.
    pub prices: std::sync::Arc<[f64]>,
    /// Slot length of the resampled window.
    pub dt: f64,
    /// Per-slot self-owned availability (0 everywhere when no pool).
    /// Offer-independent, so the coordinators share one allocation per
    /// job across all of its per-offer marshalings.
    pub navail: std::sync::Arc<[f64]>,
    /// On-demand price `p`.
    pub od_price: f64,
}

impl CounterfactualJob {
    /// Marshal a chain job + realized trace segment into the fixed-shape
    /// form. `navail_of(t0, t1)` supplies pool availability per slot.
    /// Prices/availability accept owned vectors, borrowed slices, or
    /// already-shared `Arc<[f64]>` handles (zero-copy).
    pub fn from_job(
        job: &ChainJob,
        prices: impl Into<std::sync::Arc<[f64]>>,
        dt: f64,
        navail: impl Into<std::sync::Arc<[f64]>>,
        od_price: f64,
    ) -> CounterfactualJob {
        let (prices, navail) = (prices.into(), navail.into());
        assert!(job.num_tasks() <= L_MAX, "chain too long: {}", job.num_tasks());
        assert_eq!(prices.len(), navail.len());
        let e: Vec<f64> = job.tasks.iter().map(|t| t.min_exec_time()).collect();
        let delta: Vec<f64> = job.tasks.iter().map(|t| t.parallelism).collect();
        let z: Vec<f64> = job.tasks.iter().map(|t| t.size).collect();
        let mut order: Vec<usize> = (0..job.num_tasks()).collect();
        order.sort_by(|&a, &b| delta[b].partial_cmp(&delta[a]).unwrap().then(a.cmp(&b)));
        CounterfactualJob {
            l: job.num_tasks(),
            e,
            delta,
            z,
            order,
            window: job.window(),
            prices,
            dt,
            navail,
            od_price,
        }
    }

    /// Dealloc window sizes under availability parameter `beta`
    /// (vector-friendly restatement of Algorithm 1; must match
    /// `policy::dealloc` on the same input).
    pub fn windows(&self, beta: f64) -> Vec<f64> {
        let mut sizes = self.e.clone();
        let slack: f64 = (self.window - self.e.iter().sum::<f64>()).max(0.0);
        let mut omega = slack;
        for &i in &self.order {
            let need = self.e[i] * (1.0 - beta) / beta;
            let grant = need.min(omega);
            sizes[i] += grant;
            omega -= grant;
        }
        if omega > 0.0 {
            sizes[*self.order.last().expect("non-empty")] += omega;
        }
        sizes
    }

    /// Even-baseline window sizes: `ŝ_i = e_i + ω/l`.
    pub fn windows_even(&self) -> Vec<f64> {
        let slack: f64 = (self.window - self.e.iter().sum::<f64>()).max(0.0);
        let share = slack / self.l as f64;
        self.e.iter().map(|e| e + share).collect()
    }

    /// Evaluate the cost of this job under one proposed policy. Returns
    /// `(total_cost, spot_work, od_work, so_work)`.
    pub fn eval_policy(&self, policy: &Policy, has_pool: bool) -> (f64, f64, f64, f64) {
        self.eval_spec(&CfSpec::Proposed(*policy), has_pool)
    }

    /// Evaluate under any strategy spec (proposed or benchmark).
    pub fn eval_spec(&self, spec: &CfSpec, has_pool: bool) -> (f64, f64, f64, f64) {
        self.eval_spec_inner(spec, has_pool, None)
    }

    /// [`CounterfactualJob::eval_spec`] that also reports every spot
    /// purchase the walk makes, in window-relative time — the allocation
    /// stream the capacity replay ([`crate::learning::replay`]) re-reserves
    /// against a real ledger. The recorded walk is the same code path as
    /// the unrecorded one (the recorder only observes), so the returned
    /// cost tuple is bitwise identical to [`CounterfactualJob::eval_spec`].
    pub fn eval_spec_purchases(
        &self,
        spec: &CfSpec,
        has_pool: bool,
    ) -> ((f64, f64, f64, f64), Vec<SpotPurchase>) {
        let mut purchases = Vec::new();
        let out = self.eval_spec_inner(spec, has_pool, Some(&mut purchases));
        (out, purchases)
    }

    fn eval_spec_inner(
        &self,
        spec: &CfSpec,
        has_pool: bool,
        mut rec: Option<&mut Vec<SpotPurchase>>,
    ) -> (f64, f64, f64, f64) {
        let (sizes, so_rule, bid, beta0) = match spec {
            CfSpec::Proposed(policy) => (
                self.windows(policy.dealloc_beta(has_pool)),
                SoRule::Rule12,
                policy.bid,
                policy.beta0,
            ),
            CfSpec::EvenNaive { bid } => (self.windows_even(), SoRule::Naive, *bid, None),
            CfSpec::DeallocNaive(policy) => (
                self.windows(policy.beta),
                SoRule::Naive,
                policy.bid,
                policy.beta0,
            ),
        };
        // Task deadlines (cumulative, relative).
        let mut deadlines = Vec::with_capacity(self.l);
        let mut acc = 0.0;
        for s in &sizes {
            acc += s;
            deadlines.push(acc);
        }

        // Per-task self-owned grant and z̃ initialization.
        let num_slots = (self.window / self.dt).ceil() as usize;
        let num_slots = num_slots.min(self.prices.len()).max(1);
        let mut r = vec![0.0f64; self.l];
        let mut ztilde = vec![0.0f64; self.l];
        let mut so_work = 0.0;
        // Two-pointer slot cursor: windows are consecutive, so the per-task
        // navail range-min is a single forward sweep (O(L + S), not O(L·S)).
        let mut slot_cursor = 0usize;
        for i in 0..self.l {
            let lo = if i == 0 { 0.0 } else { deadlines[i - 1] };
            let hi = deadlines[i];
            let needs_navail = has_pool
                && (matches!(so_rule, SoRule::Naive) || beta0.is_some());
            let nmin = if needs_navail {
                let mut nmin = f64::INFINITY;
                while slot_cursor < num_slots {
                    let mid = (slot_cursor as f64 + OWNER_OFFSET) * self.dt;
                    if mid < lo {
                        slot_cursor += 1;
                        continue;
                    }
                    if mid >= hi {
                        break;
                    }
                    nmin = nmin.min(self.navail[slot_cursor]);
                    slot_cursor += 1;
                }
                if nmin.is_finite() {
                    nmin
                } else {
                    0.0
                }
            } else {
                0.0
            };
            let hat_s = (hi - lo).max(1e-12);
            let ri = if !has_pool {
                0.0
            } else {
                match (so_rule, beta0) {
                    // Counterfactual grants stay fractional: §4.2.1 ignores
                    // integer rounding in the analysis, and a floor() here
                    // would make the f32 kernel and f64 native disagree by
                    // a whole instance on near-integer f values. The
                    // realized executor (policy::selfowned::rule12) floors.
                    (SoRule::Rule12, Some(b0)) => {
                        let f = f_selfowned(self.z[i], self.delta[i], hat_s, b0);
                        f.min(nmin).min(self.delta[i]).max(0.0)
                    }
                    (SoRule::Rule12, None) => 0.0,
                    (SoRule::Naive, _) => nmin.min(self.delta[i]).max(0.0),
                }
            };
            r[i] = ri;
            let covered = ri * hat_s;
            let zt = (self.z[i] - covered).max(0.0);
            so_work += self.z[i].min(covered);
            ztilde[i] = zt;
        }

        // Slot walk.
        let zt_init = ztilde.clone();
        let mut spot_cost = 0.0;
        let mut spot_work = 0.0;
        let mut od_work = 0.0;
        let mut cur = 0usize;
        for k in 0..num_slots {
            let t = k as f64 * self.dt;
            let mid = t + OWNER_OFFSET * self.dt;
            // Advance task ownership; charge leftover z̃ of passed tasks to
            // on-demand (their turning point fired before their deadline).
            while cur < self.l && mid >= deadlines[cur] {
                if ztilde[cur] > 0.0 {
                    od_work += ztilde[cur];
                    ztilde[cur] = 0.0;
                }
                cur += 1;
            }
            if cur >= self.l {
                break;
            }
            let i = cur;
            if ztilde[i] <= 0.0 {
                continue;
            }
            let delta_eff = (self.delta[i] - r[i]).max(0.0);
            if delta_eff <= 0.0 {
                continue;
            }
            let slot_end = t + self.dt;
            let deadline = deadlines[i];
            // Turning point (Def. 3.1 is strict: flexibility requires
            // z̃/(δ−r) < ς−t) checked BEFORE any progress this slot, at the
            // slot start. The threshold uses the per-task CONSTANT z̃₀ so
            // it is affine in cumulative losing time — the AOT closed form
            // exploits that (FIRE_EPS in kernels/ref.py; compile/model.py).
            let time_left = deadline - t;
            if ztilde[i] >= delta_eff * time_left - 1e-4 * (1.0 + zt_init[i]) {
                od_work += ztilde[i];
                ztilde[i] = 0.0;
                continue;
            }
            let price = self.prices[k];
            if price <= bid {
                let room = delta_eff * (slot_end.min(deadline) - t).max(0.0);
                let dw = room.min(ztilde[i]);
                ztilde[i] -= dw;
                spot_work += dw;
                spot_cost += price * dw;
                if dw > 0.0 {
                    if let Some(r) = rec.as_deref_mut() {
                        r.push(SpotPurchase {
                            t0: t,
                            t1: t + dw / delta_eff,
                            units: delta_eff.ceil() as u32,
                            work: dw,
                            price,
                        });
                    }
                }
            }
        }
        // Any remaining z̃ (window ran out of slots): on-demand.
        for i in cur..self.l {
            if ztilde[i] > 0.0 {
                od_work += ztilde[i];
                ztilde[i] = 0.0;
            }
        }

        let cost = spot_cost + self.od_price * od_work;
        (cost, spot_work, od_work, so_work)
    }
}

/// One spot purchase the counterfactual walk makes, in window-relative
/// time (`[t0, t1)` with 0 at the job's arrival). The capacity replay
/// ([`crate::learning::replay`]) shifts these by the arrival and
/// re-reserves them against a real [`crate::market::CapacityLedger`].
#[derive(Debug, Clone, PartialEq)]
pub struct SpotPurchase {
    pub t0: f64,
    pub t1: f64,
    /// Whole spot instances the slot's `δ − r` request rounds up to
    /// (capacity is counted in whole instances, like
    /// [`crate::sim::executor::spot_units`]).
    pub units: u32,
    /// Work processed in the purchase.
    pub work: f64,
    /// Realized spot price paid per unit of work.
    pub price: f64,
}

/// A strategy evaluated counterfactually: the proposed framework or one of
/// the §6.1 benchmark combinations.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CfSpec {
    /// Dealloc windows + rule (12).
    Proposed(Policy),
    /// Even windows + naive self-owned (the benchmark set P').
    EvenNaive { bid: f64 },
    /// Dealloc windows + naive self-owned (Experiment 3's benchmark side).
    DeallocNaive(Policy),
}

impl CfSpec {
    /// Human-readable label (the grammar scenario reports key on).
    pub fn label(&self) -> String {
        match self {
            CfSpec::Proposed(p) => format!(
                "proposed(β={:.3},β₀={},b={:.2})",
                p.beta,
                p.beta0.map(|x| format!("{x:.3}")).unwrap_or("-".into()),
                p.bid
            ),
            CfSpec::EvenNaive { bid } => format!("even+naive(b={bid:.2})"),
            CfSpec::DeallocNaive(p) => {
                format!("dealloc+naive(β={:.3},b={:.2})", p.beta, p.bid)
            }
        }
    }
}

/// Self-owned rule selector (internal).
#[derive(Debug, Clone, Copy)]
enum SoRule {
    Rule12,
    Naive,
}

/// Per-policy evaluation results for one job.
#[derive(Debug, Clone)]
pub struct PolicyGridEval {
    pub costs: Vec<f64>,
    pub spot_work: Vec<f64>,
    pub od_work: Vec<f64>,
    pub so_work: Vec<f64>,
}

/// Sweep the whole policy grid natively.
///
/// Delegates to the structure-sharing closed-form engine
/// ([`crate::learning::sweep`]); [`eval_grid_naive`] keeps the O(N_POL·S)
/// slot-walk formulation as the test oracle.
pub fn eval_grid_native(
    job: &CounterfactualJob,
    policies: &[Policy],
    has_pool: bool,
) -> PolicyGridEval {
    super::sweep::eval_grid(job, policies, has_pool)
}

/// The naive multi-offer oracle: evaluate one spec independently on each
/// offer's marshalled job (that offer's prices and on-demand price) and
/// take the cheapest, ties to the lowest offer index — the specification
/// [`super::sweep::MultiSweepContext`] must match. Counterfactuals are
/// capacity-free: one job's "what if" cannot replay the whole market's
/// contention, so the counterfactual router is pure price arbitrage at
/// job granularity.
pub fn eval_spec_multi_naive(
    offers: &[CounterfactualJob],
    spec: &CfSpec,
    has_pool: bool,
) -> (usize, (f64, f64, f64, f64)) {
    assert!(!offers.is_empty(), "multi-offer oracle over zero offers");
    let mut best_k = 0usize;
    let mut best = offers[0].eval_spec(spec, has_pool);
    for (k, cf) in offers.iter().enumerate().skip(1) {
        let q = cf.eval_spec(spec, has_pool);
        if q.0 < best.0 {
            best = q;
            best_k = k;
        }
    }
    (best_k, best)
}

/// The naive per-policy slot walk over the whole grid — the specification
/// the sweep engine (and the AOT kernel) must match. Kept for tests and
/// the `bench_hotpath` before/after comparison.
pub fn eval_grid_naive(
    job: &CounterfactualJob,
    policies: &[Policy],
    has_pool: bool,
) -> PolicyGridEval {
    let mut out = PolicyGridEval {
        costs: Vec::with_capacity(policies.len()),
        spot_work: Vec::with_capacity(policies.len()),
        od_work: Vec::with_capacity(policies.len()),
        so_work: Vec::with_capacity(policies.len()),
    };
    for p in policies {
        let (c, sw, ow, sow) = job.eval_policy(p, has_pool);
        out.costs.push(c);
        out.spot_work.push(sw);
        out.od_work.push(ow);
        out.so_work.push(sow);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::market::SLOTS_PER_UNIT;
    use crate::util::prop::{for_all, Config};
    use crate::util::rng::Pcg32;
    use crate::workload::{ChainJob, ChainTask};

    fn cf(job: &ChainJob, prices: Vec<f64>, navail: f64) -> CounterfactualJob {
        let dt = 1.0 / SLOTS_PER_UNIT as f64;
        let n = (job.window() / dt).ceil() as usize + 1;
        let mut p = prices;
        p.resize(n, f64::INFINITY);
        CounterfactualJob::from_job(job, p.clone(), dt, vec![navail; p.len()], 1.0)
    }

    #[test]
    fn windows_match_dealloc_algorithm() {
        let job = ChainJob::paper_example();
        let c = cf(&job, vec![], 0.0);
        let sizes = c.windows(0.5);
        let reference = crate::policy::dealloc::dealloc(&job, 0.5).sizes;
        for (a, b) in sizes.iter().zip(&reference) {
            assert!((a - b).abs() < 1e-12, "{sizes:?} vs {reference:?}");
        }
    }

    #[test]
    fn all_spot_available_means_no_od() {
        // Tasks 2 and 4 get minimum windows (ŝ = e) under Dealloc(0.5), so
        // Def. 3.1 gives them no flexibility: they run on-demand even when
        // spot is available (Prop. 4.1 third case). Tasks 1 and 3 ride spot.
        let job = ChainJob::paper_example();
        let n = (job.window() * SLOTS_PER_UNIT as f64) as usize + 2;
        let c = cf(&job, vec![0.2; n], 0.0);
        let (cost, sw, ow, _) = c.eval_policy(&Policy::new(0.5, None, 0.3), false);
        assert!((sw - 4.0).abs() < 1e-6, "spot work {sw}");
        assert!((ow - 1.0).abs() < 1e-6, "od work {ow}");
        assert!((cost - (4.0 * 0.2 + 1.0)).abs() < 1e-6, "cost {cost}");
    }

    #[test]
    fn no_spot_means_all_od() {
        let job = ChainJob::paper_example();
        let c = cf(&job, vec![], 0.0);
        let (cost, sw, ow, _) = c.eval_policy(&Policy::new(0.5, None, 0.3), false);
        assert_eq!(sw, 0.0);
        assert!((ow - 5.0).abs() < 1e-6);
        assert!((cost - 5.0).abs() < 1e-6);
    }

    #[test]
    fn selfowned_covers_work_and_cuts_cost() {
        let job = ChainJob::paper_example();
        let c = cf(&job, vec![], 100.0);
        let pol = Policy::new(0.5, Some(2.0 / 12.0), 0.3);
        let (cost, _, ow, sow) = c.eval_policy(&pol, true);
        assert!(sow > 0.0, "self-owned unused");
        assert!(ow < 5.0);
        assert!(cost < 5.0);
        // Work is conserved across the three kinds.
        let (_, sw2, ow2, sow2) = c.eval_policy(&pol, true);
        assert!((sw2 + ow2 + sow2 - 5.0).abs() < 1e-6);
    }

    #[test]
    fn work_conservation_across_random_jobs_and_policies() {
        for_all(Config::cases(120).seed(31), |rng| {
            let job = random_job(rng);
            let dt = 1.0 / SLOTS_PER_UNIT as f64;
            let n = (job.window() / dt).ceil() as usize + 1;
            let prices: Vec<f64> = (0..n)
                .map(|_| {
                    if rng.chance(0.5) {
                        rng.uniform(0.12, 0.3)
                    } else {
                        rng.uniform(0.4, 1.0)
                    }
                })
                .collect();
            let navail = rng.range_inclusive(0, 50) as f64;
            let c = CounterfactualJob::from_job(&job, prices.clone(), dt, vec![navail; n], 1.0);
            let has_pool = navail > 0.0;
            let pol = Policy::new(
                rng.uniform(0.3, 1.0),
                has_pool.then(|| rng.uniform(0.15, 0.7)),
                rng.uniform(0.15, 0.35),
            );
            let (cost, sw, ow, sow) = c.eval_policy(&pol, has_pool);
            let total = sw + ow + sow;
            if (total - job.total_work()).abs() > 1e-6 * job.total_work().max(1.0) {
                return Err(format!("work {total} != {}", job.total_work()));
            }
            if cost < -1e-9 || !cost.is_finite() {
                return Err(format!("bad cost {cost}"));
            }
            // Cost bounded by all-on-demand.
            if cost > job.total_work() + 1e-6 {
                return Err(format!("cost {cost} above all-OD bound"));
            }
            Ok(())
        });
    }

    #[test]
    fn grid_eval_shapes() {
        let job = ChainJob::paper_example();
        let c = cf(&job, vec![0.2; 64], 10.0);
        let grid = crate::policy::policy_set_full();
        let eval = eval_grid_native(&c, &grid, true);
        assert_eq!(eval.costs.len(), 175);
        assert!(eval.costs.iter().all(|&x| x.is_finite() && x >= 0.0));
        // The fast path must agree with the naive oracle grid-wide.
        let oracle = eval_grid_naive(&c, &grid, true);
        for (a, b) in eval.costs.iter().zip(&oracle.costs) {
            assert!((a - b).abs() <= 1e-9 * b.abs().max(1.0), "{a} vs {b}");
        }
    }

    fn random_job(rng: &mut Pcg32) -> ChainJob {
        let l = rng.range_inclusive(1, 6) as usize;
        let tasks: Vec<ChainTask> = (0..l)
            .map(|_| ChainTask::new(rng.uniform(0.3, 3.0), rng.uniform(1.0, 16.0)))
            .collect();
        let makespan: f64 = tasks.iter().map(|t| t.min_exec_time()).sum();
        ChainJob::new(0, 0.0, makespan * rng.uniform(1.05, 2.5), tasks)
    }

    fn random_spec(rng: &mut Pcg32, has_pool: bool) -> CfSpec {
        let pol = Policy::new(
            rng.uniform(0.3, 1.0),
            (has_pool && rng.chance(0.5)).then(|| rng.uniform(0.15, 0.7)),
            rng.uniform(0.15, 0.35),
        );
        match rng.range_inclusive(0, 2) {
            0 => CfSpec::Proposed(pol),
            1 => CfSpec::EvenNaive { bid: pol.bid },
            _ => CfSpec::DeallocNaive(pol),
        }
    }

    #[test]
    fn recorded_walk_is_bitwise_identical_and_accounts_spot_work() {
        for_all(Config::cases(120).seed(37), |rng| {
            let job = random_job(rng);
            let dt = 1.0 / SLOTS_PER_UNIT as f64;
            let n = (job.window() / dt).ceil() as usize + 1;
            let prices: Vec<f64> = (0..n)
                .map(|_| {
                    if rng.chance(0.5) {
                        rng.uniform(0.12, 0.3)
                    } else {
                        rng.uniform(0.4, 1.0)
                    }
                })
                .collect();
            let navail = rng.range_inclusive(0, 50) as f64;
            let c = CounterfactualJob::from_job(&job, prices, dt, vec![navail; n], 1.0);
            let has_pool = navail > 0.0;
            let spec = random_spec(rng, has_pool);
            let plain = c.eval_spec(&spec, has_pool);
            let (recorded, purchases) = c.eval_spec_purchases(&spec, has_pool);
            if plain != recorded {
                return Err(format!("recorder changed the walk: {plain:?} vs {recorded:?}"));
            }
            let bought: f64 = purchases.iter().map(|p| p.work).sum();
            if (bought - recorded.1).abs() > 1e-9 * recorded.1.max(1.0) {
                return Err(format!("purchases {bought} != spot work {}", recorded.1));
            }
            let paid: f64 = purchases.iter().map(|p| p.price * p.work).sum();
            let spot_cost = recorded.0 - recorded.2; // od_price = 1
            if (paid - spot_cost).abs() > 1e-9 * spot_cost.abs().max(1.0) {
                return Err(format!("purchase cost {paid} != spot cost {spot_cost}"));
            }
            for p in &purchases {
                if !(p.t1 > p.t0 && p.t0 >= 0.0 && p.t1 <= job.window() + dt) {
                    return Err(format!("purchase outside window: {p:?}"));
                }
                if p.units == 0 || p.work <= 0.0 || !p.price.is_finite() {
                    return Err(format!("degenerate purchase: {p:?}"));
                }
            }
            Ok(())
        });
    }

    /// Brute-force replay oracle: a plain per-slot counter array using the
    /// exact [`crate::market::CapacityLedger`] slot-quantization convention
    /// (floor start; a window ending exactly on a boundary does not occupy
    /// the next slot; degenerate windows take their start slot).
    struct NaiveLane {
        avail: Vec<i64>,
        slot_len: f64,
    }

    impl NaiveLane {
        fn new(cap: u32, slot_len: f64, horizon: f64) -> NaiveLane {
            let slots = (horizon / slot_len).ceil() as usize + 1;
            NaiveLane {
                avail: vec![cap as i64; slots],
                slot_len,
            }
        }

        fn range(&self, t1: f64, t2: f64) -> (usize, usize) {
            let n = self.avail.len();
            let lo = ((t1 / self.slot_len).floor() as usize).min(n - 1);
            if t2 <= t1 {
                return (lo, lo + 1);
            }
            let hi_f = t2 / self.slot_len;
            let hi = if hi_f.fract() == 0.0 {
                hi_f as usize
            } else {
                hi_f.ceil() as usize
            }
            .max(lo + 1);
            (lo, hi.min(n))
        }

        fn replay(&mut self, arrival: f64, od: f64, purchases: &[SpotPurchase]) -> f64 {
            let mut extra = 0.0;
            for p in purchases {
                if p.units == 0 || p.work <= 0.0 {
                    continue;
                }
                let (lo, hi) = self.range(arrival + p.t0, arrival + p.t1);
                let avail = self.avail[lo..hi].iter().min().copied().unwrap().max(0) as u32;
                let granted = avail.min(p.units);
                if granted > 0 {
                    for s in lo..hi {
                        self.avail[s] -= granted as i64;
                    }
                }
                let displaced = (p.units - granted) as f64 / p.units as f64;
                extra += (od - p.price).max(0.0) * p.work * displaced;
            }
            extra
        }
    }

    #[test]
    fn capacity_replay_matches_naive_slot_counter_oracle() {
        use crate::learning::replay::surcharge;
        use crate::market::CapacityLedger;
        for_all(Config::cases(80).seed(43), |rng| {
            let dt = 1.0 / SLOTS_PER_UNIT as f64;
            let cap = rng.range_inclusive(1, 6) as u32;
            let njobs = rng.range_inclusive(1, 6) as usize;
            let mut streams = Vec::new();
            let mut horizon: f64 = 1.0;
            for _ in 0..njobs {
                let job = random_job(rng);
                let arrival = rng.uniform(0.0, 5.0);
                horizon = horizon.max(arrival + job.window() + 1.0);
                let n = (job.window() / dt).ceil() as usize + 1;
                let prices: Vec<f64> =
                    (0..n).map(|_| rng.uniform(0.1, 0.6)).collect();
                let c = CounterfactualJob::from_job(&job, prices, dt, vec![0.0; n], 1.0);
                let spec = random_spec(rng, false);
                let (_, purchases) = c.eval_spec_purchases(&spec, false);
                streams.push((arrival, purchases));
            }
            let mut ledger = CapacityLedger::from_capacities(&[Some(cap)], dt, horizon);
            let mut oracle = NaiveLane::new(cap, dt, horizon);
            for (arrival, purchases) in &streams {
                let fast = surcharge(&mut ledger, 0, *arrival, 1.0, purchases);
                let slow = oracle.replay(*arrival, 1.0, purchases);
                if (fast - slow).abs() > 1e-12 {
                    return Err(format!("surcharge diverged: {fast} vs {slow}"));
                }
            }
            // Ledger state agrees slot-by-slot after all reservations.
            for (s, &avail) in oracle.avail.iter().enumerate() {
                let t = s as f64 * dt;
                let got = ledger.remaining_over(0, t, t + dt).expect("finite lane");
                if got != avail.max(0) as u32 {
                    return Err(format!("slot {s}: ledger {got} vs oracle {avail}"));
                }
            }
            Ok(())
        });
    }
}
