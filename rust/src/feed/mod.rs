//! Streaming market feed: incremental price ingestion for a long-running
//! coordinator.
//!
//! Every pre-existing market path is batch: `market::replay` loads a whole
//! CSV, [`crate::market::PriceTrace`] freezes its prices, and the
//! availability index is rebuilt with full prefix sums. This subsystem is
//! the online counterpart the paper's *online* learning claim actually
//! needs:
//!
//! * [`buffer`] — an append-only, slot-aligned [`FeedBuffer`]: strictly
//!   monotone price events materialized onto the standard slot grid with
//!   the batch loader's step-function semantics, bounded or unbounded
//!   retention, and hard *lookahead errors* on any read past the ingested
//!   frontier;
//! * [`index`] — an [`IncrementalAvailabilityIndex`] extending per-bid
//!   cumulative win counts in O(k·L) per k appended slots, exactly equal
//!   to an O(S·L) batch rebuild (property-tested bit for bit);
//! * [`loaders`] — the public EC2 spot-price-history dump formats
//!   (`describe-spot-price-history` JSON / JSON-lines and the region/AZ
//!   CSV dump), normalizing out-of-order and duplicate timestamps into a
//!   clean step function;
//! * [`mux`] — a [`FeedMux`] binding named feeds to
//!   [`crate::market::MarketView`] offers and advancing them on one shared
//!   slot grid (the frontier is the minimum across feeds).
//!
//! The consumer is [`crate::coordinator::online::tola_run_online`]: a
//! coordinator loop that schedules jobs against only already-ingested
//! prices and reproduces the batch run bit for bit when the feed is fully
//! pre-loaded.

pub mod buffer;
pub mod index;
pub mod loaders;
pub mod mux;

pub use buffer::{FeedBuffer, PriceEvent};
pub use index::IncrementalAvailabilityIndex;
pub use loaders::{
    events_to_trace, load_events, load_events_file, parse_iso8601, FeedFilter, FeedFormat,
    FeedLoad,
};
pub use mux::{FeedBinding, FeedMux};
