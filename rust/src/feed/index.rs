//! Incremental per-bid availability index over an append-only price stream.
//!
//! The batch [`crate::market::AvailabilityIndex`] rebuilds its prefix sums
//! from scratch — O(S·L) for S slots and L bids — which is fine for an
//! immutable trace but wrong for a live feed where a handful of slots
//! arrive per tick. [`IncrementalAvailabilityIndex`] maintains the *same*
//! per-bid cumulative win counts but extends them in place: appending `k`
//! slots costs O(k·L) amortized, and on an unbounded index the stored
//! `cum_wins` arrays are exactly equal — bit for bit — to what
//! [`crate::market::AvailabilityIndex::build`] produces over the
//! concatenated prices (the property the streaming tests pin).
//!
//! Bounded retention evicts whole leading runs of entries: counts stay
//! *absolute* (wins among slots `[0, s)` since the stream origin), so
//! range queries inside the retained window return the identical values
//! the batch index would, while queries reaching into evicted history
//! return `None` instead of a silently wrong count.

/// Prefix-sum availability index that grows with the stream.
#[derive(Debug, Clone)]
pub struct IncrementalAvailabilityIndex {
    /// Indexed bids, ascending and deduplicated (same canonical form as the
    /// batch index).
    bids: Vec<f64>,
    /// Absolute slot index of `cum[i][0]`: `cum[i][j]` counts winning slots
    /// among absolute slots `[0, base + j)`.
    base: usize,
    /// One cumulative array per bid, `len = retained_slots + 1`.
    cum: Vec<Vec<u64>>,
    /// Total slots ever appended (independent of eviction and of `bids`
    /// being empty).
    slots: usize,
    /// Maximum retained slots; `None` = unbounded.
    retention: Option<usize>,
}

impl IncrementalAvailabilityIndex {
    /// Empty index over a bid grid (sorted + deduplicated, like the batch
    /// index).
    pub fn new(mut bids: Vec<f64>) -> IncrementalAvailabilityIndex {
        bids.sort_by(|a, b| a.partial_cmp(b).unwrap());
        bids.dedup();
        let cum = bids.iter().map(|_| vec![0u64]).collect();
        IncrementalAvailabilityIndex {
            bids,
            base: 0,
            cum,
            slots: 0,
            retention: None,
        }
    }

    /// Bound retained history to `max_slots` (eviction happens on append,
    /// in amortized-O(1) chunks). `max_slots` must be positive.
    pub fn with_retention(mut self, max_slots: usize) -> IncrementalAvailabilityIndex {
        assert!(max_slots > 0, "retention of zero slots retains nothing");
        self.retention = Some(max_slots);
        self
    }

    pub fn bids(&self) -> &[f64] {
        &self.bids
    }

    /// Total slots appended since the stream origin.
    pub fn len_slots(&self) -> usize {
        self.slots
    }

    /// First absolute slot still answerable (0 until eviction kicks in).
    pub fn base_slot(&self) -> usize {
        self.base
    }

    /// Append one slot price. O(L).
    pub fn append_one(&mut self, price: f64) {
        for (b, cum) in self.bids.iter().zip(self.cum.iter_mut()) {
            let last = *cum.last().expect("cum never empty");
            cum.push(last + (price <= *b) as u64);
        }
        self.slots += 1;
        self.maybe_evict();
    }

    /// Append a run of slot prices. O(k·L) amortized.
    pub fn append(&mut self, prices: &[f64]) {
        for &p in prices {
            self.append_one(p);
        }
    }

    /// Evict leading entries once the retained window overshoots its bound
    /// by half (chunked, so the per-append cost stays amortized O(1) per
    /// bid rather than an O(S) drain on every slot).
    fn maybe_evict(&mut self) {
        let Some(max) = self.retention else { return };
        let retained = self.slots - self.base;
        if retained > max + max / 2 {
            let drop = retained - max;
            for cum in &mut self.cum {
                cum.drain(..drop);
            }
            self.base += drop;
        }
    }

    /// Winning slots in the inclusive absolute slot range `[s0, s1]` for an
    /// indexed bid. `None` when the bid is not indexed or the range starts
    /// before the retained window. Ranges past the ingested frontier clamp
    /// to it, exactly as the batch index clamps to its trace end.
    pub fn winning_slots(&self, s0: usize, s1: usize, bid: f64) -> Option<usize> {
        let i = self.bids.iter().position(|&b| b == bid)?;
        if s0 < self.base {
            return None;
        }
        let cum = &self.cum[i];
        let hi = (s1 + 1).saturating_sub(self.base).min(cum.len() - 1);
        let lo = (s0 - self.base).min(hi);
        Some((cum[hi] - cum[lo]) as usize)
    }

    /// Fraction of winning slots over the inclusive range `[s0, s1]` (same
    /// contract as the batch index).
    pub fn availability(&self, s0: usize, s1: usize, bid: f64) -> Option<f64> {
        let total = s1.saturating_sub(s0) + 1;
        self.winning_slots(s0, s1, bid)
            .map(|w| w as f64 / total as f64)
    }

    /// The retained cumulative array for an indexed bid — on an unbounded
    /// index this is exactly the batch index's `cum_wins` row, which the
    /// streaming property tests compare for equality.
    pub fn cum_wins(&self, bid: f64) -> Option<&[u64]> {
        let i = self.bids.iter().position(|&b| b == bid)?;
        Some(&self.cum[i])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::market::AvailabilityIndex;

    fn bids() -> Vec<f64> {
        vec![0.3, 0.18, 0.24, 0.3] // unsorted + dup on purpose
    }

    #[test]
    fn canonicalizes_bids_like_batch() {
        let idx = IncrementalAvailabilityIndex::new(bids());
        assert_eq!(idx.bids(), &[0.18, 0.24, 0.3]);
    }

    #[test]
    fn matches_batch_index_after_appends() {
        let prices: Vec<f64> = (0..200)
            .map(|i| 0.12 + 0.8 * ((i * 37 % 100) as f64 / 100.0))
            .collect();
        let mut idx = IncrementalAvailabilityIndex::new(bids());
        idx.append(&prices[..77]);
        idx.append(&prices[77..77]); // empty run is a no-op
        idx.append(&prices[77..]);
        let batch = AvailabilityIndex::build(&prices, bids());
        assert_eq!(idx.len_slots(), 200);
        for &b in idx.bids() {
            assert_eq!(idx.cum_wins(b).unwrap(), batch.cum_wins(b).unwrap());
            for (s0, s1) in [(0, 199), (13, 57), (42, 42), (150, 400)] {
                assert_eq!(idx.winning_slots(s0, s1, b), batch.winning_slots(s0, s1, b));
                assert_eq!(idx.availability(s0, s1, b), batch.availability(s0, s1, b));
            }
        }
        assert_eq!(idx.winning_slots(0, 10, 0.5), None, "unindexed bid");
    }

    #[test]
    fn retention_evicts_but_keeps_absolute_counts() {
        let prices: Vec<f64> = (0..1000).map(|i| if i % 3 == 0 { 0.2 } else { 0.9 }).collect();
        let mut idx = IncrementalAvailabilityIndex::new(vec![0.5]).with_retention(100);
        idx.append(&prices);
        assert_eq!(idx.len_slots(), 1000);
        assert!(idx.base_slot() >= 900 - 50, "base {}", idx.base_slot());
        assert!(idx.base_slot() <= 900, "retains at least 100: base {}", idx.base_slot());
        // Inside the retained window: identical to the batch answer.
        let batch = AvailabilityIndex::build(&prices, vec![0.5]);
        let s0 = idx.base_slot();
        assert_eq!(
            idx.winning_slots(s0, 999, 0.5),
            batch.winning_slots(s0, 999, 0.5)
        );
        // Evicted history answers None, never a wrong count.
        assert_eq!(idx.winning_slots(0, 999, 0.5), None);
    }

    #[test]
    #[should_panic(expected = "retention of zero")]
    fn zero_retention_rejected() {
        let _ = IncrementalAvailabilityIndex::new(vec![0.2]).with_retention(0);
    }
}
