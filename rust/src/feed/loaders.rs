//! Loaders for the public EC2 spot-price-history dump formats.
//!
//! Two shapes cover what the ecosystem actually publishes:
//!
//! * **`ec2-json`** — the `aws ec2 describe-spot-price-history` output:
//!   either the whole-document `{"SpotPriceHistory": [...]}` object or one
//!   JSON record per line (the common `jq -c '.SpotPriceHistory[]'` dump),
//!   each record carrying `Timestamp` (ISO-8601), `SpotPrice` (a decimal
//!   *string*, sic), and optionally `AvailabilityZone` / `InstanceType`;
//! * **`csv`** — the region/AZ CSV dump shape
//!   (`Timestamp,AvailabilityZone,InstanceType,ProductDescription,SpotPrice`,
//!   header optional when the columns are in canonical order), plus the
//!   repo's own simple numeric `time,price` shape so
//!   `examples/traces/spot_sample.csv` streams through the same front end.
//!
//! Real dumps are *not* clean event streams: records arrive newest-first,
//! series interleave, and timestamps repeat. The loader normalizes all of
//! that into the strictly-monotone step function [`FeedBuffer`] requires —
//! stable-sorted by timestamp, duplicate timestamps collapsed (the
//! last-listed observation wins), first observation shifted to `t = 0` —
//! and refuses to silently mix distinct `(zone, instance type)` series:
//! pick one with a [`FeedFilter`] or get an error naming what's present.

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::market::PriceTrace;
use crate::util::json::Json;

use super::buffer::{FeedBuffer, PriceEvent};

/// Supported on-disk feed formats.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FeedFormat {
    /// `describe-spot-price-history` JSON (whole document or JSON-lines).
    Ec2Json,
    /// Region/AZ CSV dump, or the simple numeric `time,price` shape.
    Csv,
}

impl FeedFormat {
    pub fn as_str(&self) -> &'static str {
        match self {
            FeedFormat::Ec2Json => "ec2-json",
            FeedFormat::Csv => "csv",
        }
    }

    pub fn from_str(s: &str) -> Result<FeedFormat> {
        Ok(match s {
            "ec2-json" => FeedFormat::Ec2Json,
            "csv" => FeedFormat::Csv,
            other => bail!("unknown feed format '{other}' (ec2-json|csv)"),
        })
    }

    /// Infer from a file extension (`.json` / `.jsonl` → `ec2-json`,
    /// anything else → `csv`).
    pub fn infer(path: &str) -> FeedFormat {
        let lower = path.to_ascii_lowercase();
        if lower.ends_with(".json") || lower.ends_with(".jsonl") {
            FeedFormat::Ec2Json
        } else {
            FeedFormat::Csv
        }
    }
}

/// Restrict a multi-series dump to one `(zone, instance type)` series.
#[derive(Debug, Clone, Default)]
pub struct FeedFilter {
    pub availability_zone: Option<String>,
    pub instance_type: Option<String>,
}

/// A normalized event stream plus ingestion statistics.
#[derive(Debug, Clone)]
pub struct FeedLoad {
    /// Strictly-monotone events, first observation at `t = 0`, times and
    /// prices already scaled.
    pub events: Vec<PriceEvent>,
    /// `zone/instance_type` of the surviving series (`-` when the dump
    /// carries no series labels).
    pub series: String,
    /// Raw records read (before filtering and deduplication).
    pub records: usize,
    /// Records discarded because a later-listed record shares their
    /// timestamp.
    pub duplicates: usize,
    /// Adjacent timestamp inversions in the raw order (how out-of-order
    /// the dump was).
    pub out_of_order: usize,
    /// Raw timestamps were ISO-8601 (epoch seconds) rather than already
    /// in simulated units — callers picking a default `time_scale` (the
    /// CLI) branch on this.
    pub iso_timestamps: bool,
}

/// One raw record before normalization.
struct RawRecord {
    time: f64,
    price: f64,
    zone: String,
    instance_type: String,
}

impl RawRecord {
    fn series(&self) -> String {
        if self.zone.is_empty() && self.instance_type.is_empty() {
            "-".into()
        } else {
            format!("{}/{}", self.zone, self.instance_type)
        }
    }
}

/// Load and normalize a feed. `time_scale` multiplies raw timestamps into
/// simulated time units (ISO formats yield epoch *seconds*; e.g.
/// `1/3600` makes one simulated unit an hour); `price_scale` normalizes
/// prices against the on-demand price (the paper sets `p = 1`).
pub fn load_events(
    text: &str,
    format: FeedFormat,
    filter: &FeedFilter,
    time_scale: f64,
    price_scale: f64,
) -> Result<FeedLoad> {
    ensure!(
        time_scale > 0.0 && price_scale > 0.0,
        "feed: scales must be positive (time_scale={time_scale}, price_scale={price_scale})"
    );
    let (raw, iso_timestamps) = match format {
        FeedFormat::Ec2Json => (parse_ec2_json(text)?, true),
        FeedFormat::Csv => parse_csv(text)?,
    };
    let records = raw.len();
    ensure!(records > 0, "feed: no records in input");

    let kept: Vec<RawRecord> = raw
        .into_iter()
        .filter(|r| {
            filter
                .availability_zone
                .as_ref()
                .map_or(true, |z| &r.zone == z)
                && filter
                    .instance_type
                    .as_ref()
                    .map_or(true, |it| &r.instance_type == it)
        })
        .collect();
    ensure!(
        !kept.is_empty(),
        "feed: filter (zone={:?}, instance_type={:?}) matched none of {records} records",
        filter.availability_zone,
        filter.instance_type
    );

    // One series or an explicit choice — never a silent interleave of two
    // different markets' prices.
    let mut series: Vec<String> = kept.iter().map(RawRecord::series).collect();
    series.sort();
    series.dedup();
    ensure!(
        series.len() == 1,
        "feed: {} distinct (zone, instance type) series in input [{}]; \
         select one with --az / --instance-type",
        series.len(),
        series.join(", ")
    );

    let out_of_order = kept.windows(2).filter(|w| w[1].time < w[0].time).count();
    let ordered: Vec<(f64, f64)> = kept.iter().map(|r| (r.time, r.price)).collect();
    let deduped = crate::market::replay::sort_dedup_by_time(ordered, |p| p.0);
    let duplicates = kept.len() - deduped.len();

    let t0 = deduped[0].0;
    let events: Vec<PriceEvent> = deduped
        .into_iter()
        .map(|(t, p)| PriceEvent {
            time: (t - t0) * time_scale,
            price: p * price_scale,
        })
        .collect();
    for e in &events {
        ensure!(
            e.price.is_finite() && e.price > 0.0,
            "feed: non-positive price {} after scaling",
            e.price
        );
    }
    Ok(FeedLoad {
        events,
        series: series.pop().unwrap_or_else(|| "-".into()),
        records,
        duplicates,
        out_of_order,
        iso_timestamps,
    })
}

/// Load a feed from a file path (format inferred from the extension when
/// `format` is `None`).
pub fn load_events_file(
    path: &str,
    format: Option<FeedFormat>,
    filter: &FeedFilter,
    time_scale: f64,
    price_scale: f64,
) -> Result<FeedLoad> {
    let text = std::fs::read_to_string(path).with_context(|| format!("feed '{path}'"))?;
    let fmt = format.unwrap_or_else(|| FeedFormat::infer(path));
    load_events(&text, fmt, filter, time_scale, price_scale)
        .with_context(|| format!("feed '{path}' ({})", fmt.as_str()))
}

/// Materialize a normalized event stream as a batch [`PriceTrace`] on a
/// slot grid — the bridge from the streaming loaders to every batch
/// consumer (scenario worlds, the legacy coordinator).
pub fn events_to_trace(events: &[PriceEvent], slot_len: f64) -> Result<PriceTrace> {
    ensure!(!events.is_empty(), "feed: no events to materialize");
    let mut buf = FeedBuffer::with_bids(slot_len, Vec::new());
    for &e in events {
        buf.push_event(e)?;
    }
    buf.close();
    buf.trace_prefix()
}

fn parse_ec2_json(text: &str) -> Result<Vec<RawRecord>> {
    // A whole-document parse succeeds for the `{"SpotPriceHistory": [...]}`
    // shape (and a single bare record); JSON-lines dumps fail it with
    // "trailing characters" and fall through to per-line parsing.
    if let Ok(doc) = Json::parse(text) {
        let records = match doc.get("SpotPriceHistory").and_then(Json::as_arr) {
            Some(arr) => arr.iter().map(ec2_record).collect::<Result<Vec<_>>>()?,
            None => vec![ec2_record(&doc)?],
        };
        return Ok(records);
    }
    let mut out = Vec::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let j = Json::parse(line)
            .map_err(|e| anyhow!("feed json line {}: {e}", lineno + 1))?;
        out.push(ec2_record(&j).with_context(|| format!("feed json line {}", lineno + 1))?);
    }
    Ok(out)
}

fn ec2_record(j: &Json) -> Result<RawRecord> {
    let ts = j
        .get("Timestamp")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("record missing string 'Timestamp'"))?;
    let time = parse_iso8601(ts)?;
    // The AWS API returns SpotPrice as a decimal *string*; tolerate a
    // number too.
    let price = match j.get("SpotPrice") {
        Some(Json::Str(s)) => s
            .trim()
            .parse::<f64>()
            .map_err(|_| anyhow!("bad SpotPrice '{s}'"))?,
        Some(Json::Num(x)) => *x,
        _ => bail!("record missing 'SpotPrice'"),
    };
    ensure!(
        price.is_finite() && price > 0.0,
        "record at {ts}: non-positive SpotPrice {price}"
    );
    Ok(RawRecord {
        time,
        price,
        zone: j.opt_str("AvailabilityZone", "").to_string(),
        instance_type: j.opt_str("InstanceType", "").to_string(),
    })
}

/// Returns the records plus whether the shape carried ISO (epoch-second)
/// timestamps.
fn parse_csv(text: &str) -> Result<(Vec<RawRecord>, bool)> {
    #[derive(Clone, Copy)]
    enum Shape {
        /// Numeric `time,price` (or price-only) rows.
        Simple { time_col: Option<usize>, price_col: usize },
        /// ISO `Timestamp` + labeled columns.
        Dump {
            time_col: usize,
            zone_col: Option<usize>,
            itype_col: Option<usize>,
            price_col: usize,
        },
    }

    let mut shape: Option<Shape> = None;
    let mut out = Vec::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let fields: Vec<&str> = line.split(',').map(str::trim).collect();
        if shape.is_none() {
            // Header row: map columns by (normalized) name.
            let norm: Vec<String> = fields
                .iter()
                .map(|f| {
                    f.chars()
                        .filter(char::is_ascii_alphanumeric)
                        .collect::<String>()
                        .to_ascii_lowercase()
                })
                .collect();
            let col = |names: &[&str]| -> Option<usize> {
                norm.iter().position(|n| names.contains(&n.as_str()))
            };
            if let (Some(tc), Some(pc)) = (col(&["timestamp"]), col(&["spotprice", "price"])) {
                shape = Some(Shape::Dump {
                    time_col: tc,
                    zone_col: col(&["availabilityzone", "zone"]),
                    itype_col: col(&["instancetype"]),
                    price_col: pc,
                });
                continue;
            }
            if let (Some(tc), Some(pc)) = (col(&["time"]), col(&["price"])) {
                shape = Some(Shape::Simple {
                    time_col: Some(tc),
                    price_col: pc,
                });
                continue;
            }
            // No header: infer from the first data row.
            shape = Some(if fields.len() >= 4 && parse_iso8601(fields[0]).is_ok() {
                // Canonical dump order: Timestamp, AZ, InstanceType,
                // ProductDescription, SpotPrice.
                Shape::Dump {
                    time_col: 0,
                    zone_col: Some(1),
                    itype_col: Some(2),
                    price_col: fields.len() - 1,
                }
            } else if fields.len() >= 2 && fields[0].parse::<f64>().is_ok() {
                Shape::Simple {
                    time_col: Some(0),
                    price_col: 1,
                }
            } else if fields.len() == 1 && fields[0].parse::<f64>().is_ok() {
                Shape::Simple {
                    time_col: None,
                    price_col: 0,
                }
            } else {
                bail!(
                    "feed csv line {}: unrecognized shape '{line}' (expected an \
                     EC2 dump header, ISO rows, or numeric time,price rows)",
                    lineno + 1
                )
            });
            // The inferred row is data: fall through to parse it.
        }
        let field = |idx: usize| {
            fields.get(idx).copied().ok_or_else(|| {
                anyhow!("feed csv line {}: missing column {idx} in '{line}'", lineno + 1)
            })
        };
        let rec = match shape.expect("set above") {
            Shape::Simple { time_col, price_col } => {
                let time = match time_col {
                    // Slot-per-row shape: synthesize the grid time.
                    None => out.len() as f64 / crate::market::SLOTS_PER_UNIT as f64,
                    Some(tc) => field(tc)?.parse::<f64>().map_err(|_| {
                        anyhow!("feed csv line {}: bad time '{}'", lineno + 1, fields[tc])
                    })?,
                };
                // `parse::<f64>()` accepts "nan"/"inf"; the normalization
                // sort would panic on NaN downstream.
                ensure!(
                    time.is_finite(),
                    "feed csv line {}: non-finite time in '{line}'",
                    lineno + 1
                );
                let p = field(price_col)?;
                RawRecord {
                    time,
                    price: p.parse::<f64>().map_err(|_| {
                        anyhow!("feed csv line {}: bad price '{p}'", lineno + 1)
                    })?,
                    zone: String::new(),
                    instance_type: String::new(),
                }
            }
            Shape::Dump {
                time_col,
                zone_col,
                itype_col,
                price_col,
            } => {
                let ts = field(time_col)?;
                let p = field(price_col)?;
                RawRecord {
                    time: parse_iso8601(ts)
                        .with_context(|| format!("feed csv line {}", lineno + 1))?,
                    price: p.parse::<f64>().map_err(|_| {
                        anyhow!("feed csv line {}: bad price '{p}'", lineno + 1)
                    })?,
                    zone: zone_col
                        .and_then(|c| fields.get(c))
                        .unwrap_or(&"")
                        .to_string(),
                    instance_type: itype_col
                        .and_then(|c| fields.get(c))
                        .unwrap_or(&"")
                        .to_string(),
                }
            }
        };
        ensure!(
            rec.price.is_finite() && rec.price > 0.0,
            "feed csv line {}: non-positive price in '{line}'",
            lineno + 1
        );
        out.push(rec);
    }
    let iso = matches!(shape, Some(Shape::Dump { .. }));
    Ok((out, iso))
}

/// Parse an ISO-8601 timestamp (`2024-03-01T00:05:00.000Z`,
/// `2024-03-01 00:05:00+00:00`, `20240301T000500Z` is *not* supported —
/// dumps use the extended format) into Unix epoch seconds. A missing
/// offset means UTC (what AWS emits).
pub fn parse_iso8601(s: &str) -> Result<f64> {
    let b = s.trim().as_bytes();
    let digits = |lo: usize, hi: usize| -> Result<i64> {
        ensure!(hi <= b.len(), "timestamp '{s}': truncated");
        let mut v = 0i64;
        for &c in &b[lo..hi] {
            ensure!(c.is_ascii_digit(), "timestamp '{s}': expected digit");
            v = v * 10 + (c - b'0') as i64;
        }
        Ok(v)
    };
    let sep = |at: usize, ok: &[u8]| -> Result<()> {
        ensure!(
            at < b.len() && ok.contains(&b[at]),
            "timestamp '{s}': malformed at byte {at}"
        );
        Ok(())
    };
    let (y, mo, d) = (digits(0, 4)?, digits(5, 7)?, digits(8, 10)?);
    sep(4, b"-")?;
    sep(7, b"-")?;
    sep(10, b"T ")?;
    let (h, mi, sec) = (digits(11, 13)?, digits(14, 16)?, digits(17, 19)?);
    sep(13, b":")?;
    sep(16, b":")?;
    ensure!(
        (1..=12).contains(&mo) && (1..=31).contains(&d) && h < 24 && mi < 60 && sec <= 60,
        "timestamp '{s}': field out of range"
    );
    let mut pos = 19;
    let mut frac = 0.0f64;
    if pos < b.len() && b[pos] == b'.' {
        pos += 1;
        let start = pos;
        let mut scale = 0.1;
        while pos < b.len() && b[pos].is_ascii_digit() {
            frac += (b[pos] - b'0') as f64 * scale;
            scale *= 0.1;
            pos += 1;
        }
        ensure!(pos > start, "timestamp '{s}': empty fraction");
    }
    let offset_secs = match b.get(pos).copied() {
        None => 0i64, // bare timestamp: UTC (the AWS convention)
        Some(b'Z' | b'z') => {
            pos += 1;
            0
        }
        Some(sign @ (b'+' | b'-')) => {
            let neg = sign == b'-';
            pos += 1;
            let oh = digits(pos, pos + 2)?;
            pos += 2;
            if b.get(pos) == Some(&b':') {
                pos += 1;
            }
            let om = if pos < b.len() { digits(pos, pos + 2)? } else { 0 };
            if pos < b.len() {
                pos += 2;
            }
            ensure!(oh < 24 && om < 60, "timestamp '{s}': bad offset");
            let o = oh * 3600 + om * 60;
            if neg {
                -o
            } else {
                o
            }
        }
        Some(c) => bail!("timestamp '{s}': unexpected trailing byte '{}'", c as char),
    };
    ensure!(pos == b.len(), "timestamp '{s}': trailing characters");

    // Howard Hinnant's days-from-civil: exact for the proleptic Gregorian
    // calendar, no table lookups.
    let yy = if mo <= 2 { y - 1 } else { y };
    let era = if yy >= 0 { yy } else { yy - 399 } / 400;
    let yoe = yy - era * 400;
    let mp = (mo + 9) % 12;
    let doy = (153 * mp + 2) / 5 + d - 1;
    let doe = yoe * 365 + yoe / 4 - yoe / 100 + doy;
    let days = era * 146097 + doe - 719_468;
    Ok((days * 86_400 + h * 3600 + mi * 60 + sec - offset_secs) as f64 + frac)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iso8601_known_values() {
        assert_eq!(parse_iso8601("1970-01-01T00:00:00Z").unwrap(), 0.0);
        assert_eq!(parse_iso8601("1970-01-02T00:00:00").unwrap(), 86_400.0);
        // 2024-03-01T00:00:00Z = 1709251200 (leap year, post-Feb).
        assert_eq!(parse_iso8601("2024-03-01T00:00:00.000Z").unwrap(), 1_709_251_200.0);
        // Offsets shift back to UTC; space separator accepted.
        assert_eq!(
            parse_iso8601("2024-03-01 02:00:00+02:00").unwrap(),
            1_709_251_200.0
        );
        assert_eq!(
            parse_iso8601("2024-02-29T23:30:00-00:30").unwrap(),
            1_709_251_200.0
        );
        // Fractional seconds survive.
        assert_eq!(parse_iso8601("1970-01-01T00:00:01.25Z").unwrap(), 1.25);
        for bad in [
            "2024-13-01T00:00:00Z",
            "2024-03-01",
            "not a time",
            "2024-03-01T00:00:00ZZ",
            "2024-03-01T00:00:00.Z",
        ] {
            assert!(parse_iso8601(bad).is_err(), "{bad}");
        }
    }

    const JSONL: &str = r#"{"Timestamp":"2024-03-01T02:00:00Z","SpotPrice":"0.0450","AvailabilityZone":"us-east-1a","InstanceType":"m5.large","ProductDescription":"Linux/UNIX"}
{"Timestamp":"2024-03-01T00:00:00Z","SpotPrice":"0.0300","AvailabilityZone":"us-east-1a","InstanceType":"m5.large","ProductDescription":"Linux/UNIX"}
{"Timestamp":"2024-03-01T01:00:00Z","SpotPrice":"0.0380","AvailabilityZone":"us-east-1a","InstanceType":"m5.large","ProductDescription":"Linux/UNIX"}
{"Timestamp":"2024-03-01T01:00:00Z","SpotPrice":"0.0390","AvailabilityZone":"us-east-1a","InstanceType":"m5.large","ProductDescription":"Linux/UNIX"}"#;

    #[test]
    fn jsonl_normalizes_order_and_duplicates() {
        // Newest-first with a duplicate timestamp: sorted, deduped
        // (last-listed wins), shifted to t0 = 0, scaled.
        let load = load_events(JSONL, FeedFormat::Ec2Json, &FeedFilter::default(), 1.0 / 3600.0, 10.0)
            .unwrap();
        assert_eq!(load.records, 4);
        assert_eq!(load.duplicates, 1);
        assert!(load.out_of_order >= 1);
        assert!(load.iso_timestamps);
        assert_eq!(load.series, "us-east-1a/m5.large");
        let e = &load.events;
        assert_eq!(e.len(), 3);
        assert_eq!(e[0].time, 0.0);
        assert!((e[0].price - 0.30).abs() < 1e-12);
        assert!((e[1].time - 1.0).abs() < 1e-12);
        assert!((e[1].price - 0.39).abs() < 1e-12, "last duplicate wins: {}", e[1].price);
        assert!((e[2].time - 2.0).abs() < 1e-12);
    }

    #[test]
    fn whole_document_shape_parses_too() {
        let doc = format!(
            r#"{{"SpotPriceHistory": [{}]}}"#,
            JSONL.lines().collect::<Vec<_>>().join(",")
        );
        let load = load_events(&doc, FeedFormat::Ec2Json, &FeedFilter::default(), 1.0, 1.0).unwrap();
        assert_eq!(load.records, 4);
        assert_eq!(load.events.len(), 3);
    }

    #[test]
    fn mixed_series_require_a_filter() {
        let two = r#"{"Timestamp":"2024-03-01T00:00:00Z","SpotPrice":"0.03","AvailabilityZone":"us-east-1a","InstanceType":"m5.large"}
{"Timestamp":"2024-03-01T01:00:00Z","SpotPrice":"0.09","AvailabilityZone":"us-east-1b","InstanceType":"m5.large"}"#;
        let err = load_events(two, FeedFormat::Ec2Json, &FeedFilter::default(), 1.0, 1.0)
            .unwrap_err()
            .to_string();
        assert!(err.contains("us-east-1b"), "{err}");
        let one = load_events(
            two,
            FeedFormat::Ec2Json,
            &FeedFilter {
                availability_zone: Some("us-east-1b".into()),
                instance_type: None,
            },
            1.0,
            1.0,
        )
        .unwrap();
        assert_eq!(one.events.len(), 1);
        assert_eq!(one.series, "us-east-1b/m5.large");
        // A filter matching nothing errors instead of an empty feed.
        assert!(load_events(
            two,
            FeedFormat::Ec2Json,
            &FeedFilter {
                availability_zone: Some("eu-west-1a".into()),
                instance_type: None
            },
            1.0,
            1.0,
        )
        .is_err());
    }

    #[test]
    fn csv_dump_shape_with_header() {
        let csv = "Timestamp,AvailabilityZone,InstanceType,ProductDescription,SpotPrice\n\
                   2024-03-01T01:00:00Z,us-east-1a,m5.large,Linux/UNIX,0.045\n\
                   2024-03-01T00:00:00Z,us-east-1a,m5.large,Linux/UNIX,0.030\n";
        let load =
            load_events(csv, FeedFormat::Csv, &FeedFilter::default(), 1.0 / 3600.0, 1.0).unwrap();
        assert_eq!(load.events.len(), 2);
        assert_eq!(load.out_of_order, 1);
        assert!(load.iso_timestamps, "dump shape carries epoch timestamps");
        assert_eq!(load.events[0].price, 0.030);
        assert!((load.events[1].time - 1.0).abs() < 1e-12);
    }

    #[test]
    fn csv_dump_shape_headerless_canonical_order() {
        let csv = "2024-03-01T00:00:00Z,us-east-1a,m5.large,Linux/UNIX,0.030\n\
                   2024-03-01T02:00:00Z,us-east-1a,m5.large,Linux/UNIX,0.060\n";
        let load = load_events(csv, FeedFormat::Csv, &FeedFilter::default(), 1.0, 1.0).unwrap();
        assert_eq!(load.events.len(), 2);
        assert_eq!(load.series, "us-east-1a/m5.large");
    }

    #[test]
    fn simple_numeric_csv_streams_through_the_same_front_end() {
        let text = include_str!("../../../examples/traces/spot_sample.csv");
        let load = load_events(text, FeedFormat::Csv, &FeedFilter::default(), 1.0, 1.0).unwrap();
        assert!(load.events.len() > 100);
        assert_eq!(load.series, "-");
        assert_eq!(load.duplicates, 0);
        assert!(!load.iso_timestamps, "numeric shape is already in units");
        assert_eq!(load.events[0].time, 0.0);
        // And it materializes to the same trace the batch loader builds.
        let slot_len = 1.0 / crate::market::SLOTS_PER_UNIT as f64;
        let streamed = events_to_trace(&load.events, slot_len).unwrap();
        let batch = crate::market::replay::trace_from_csv(text, 1.0, 1.0).unwrap();
        assert_eq!(streamed.num_slots(), batch.num_slots());
        for s in 0..batch.num_slots() {
            assert_eq!(streamed.price_of_slot(s), batch.price_of_slot(s), "slot {s}");
        }
    }

    #[test]
    fn bad_rows_error_with_line_numbers() {
        let err = load_events(
            "Timestamp,SpotPrice\n2024-03-01T00:00:00Z,zzz\n",
            FeedFormat::Csv,
            &FeedFilter::default(),
            1.0,
            1.0,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("line 2"), "{err}");
        let err = load_events(
            "{\"Timestamp\":\"2024-03-01T00:00:00Z\"}\n",
            FeedFormat::Ec2Json,
            &FeedFilter::default(),
            1.0,
            1.0,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("SpotPrice"), "{err}");
        assert!(load_events("", FeedFormat::Csv, &FeedFilter::default(), 1.0, 1.0).is_err());
        // NaN times error instead of panicking the normalization sort.
        let err = load_events(
            "time,price\n0,0.2\nnan,0.3\n",
            FeedFormat::Csv,
            &FeedFilter::default(),
            1.0,
            1.0,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("non-finite"), "{err}");
    }
}
