//! Multiplexing named feeds onto a [`MarketView`]'s offers.
//!
//! A routed market consumes several price streams — one per
//! `(region, instance_type)` offer — but the coordinator advances a single
//! simulated clock. [`FeedMux`] binds each offer to its own
//! [`FeedBuffer`] + pending event queue and advances them together on one
//! shared slot grid: the mux's *frontier* is the minimum ingested slot
//! across feeds, so a consumer gated on the frontier can never read a
//! price any one of its markets has not delivered.

use anyhow::{ensure, Result};

use crate::market::{MarketOffer, MarketView, PriceTrace};

use super::buffer::{FeedBuffer, PriceEvent};

/// One feed bound to a named offer.
#[derive(Debug, Clone)]
pub struct FeedBinding {
    pub region: String,
    pub instance_type: String,
    pub od_price: f64,
    /// Per-slot concurrent spot cap; `None` = infinite.
    pub capacity: Option<u32>,
    /// Normalized (strictly-monotone) pending events.
    pub events: Vec<PriceEvent>,
}

impl FeedBinding {
    pub fn label(&self) -> String {
        format!("{}/{}", self.region, self.instance_type)
    }
}

/// A set of named feeds advancing on one shared slot grid.
#[derive(Debug, Clone)]
pub struct FeedMux {
    meta: Vec<FeedBinding>,
    buffers: Vec<FeedBuffer>,
    cursors: Vec<usize>,
    slot_len: f64,
}

impl FeedMux {
    /// Bind feeds to offers. Validation mirrors [`MarketView::new`] so a
    /// bad mux fails at construction, not at the first materialization.
    pub fn new(bindings: Vec<FeedBinding>, slot_len: f64) -> Result<FeedMux> {
        ensure!(!bindings.is_empty(), "feed mux over an empty feed set");
        ensure!(slot_len > 0.0, "feed mux: slot_len must be positive");
        for (i, b) in bindings.iter().enumerate() {
            ensure!(
                b.od_price > 0.0,
                "feed '{}': od_price must be positive",
                b.label()
            );
            ensure!(
                b.capacity != Some(0),
                "feed '{}': capacity 0 is never placeable (omit it for infinite)",
                b.label()
            );
            ensure!(
                !bindings[..i].iter().any(|p| p.label() == b.label()),
                "duplicate feed label '{}'",
                b.label()
            );
            for w in b.events.windows(2) {
                ensure!(
                    w[1].time > w[0].time,
                    "feed '{}': events not strictly monotone ({} after {}); \
                     normalize the source first",
                    b.label(),
                    w[1].time,
                    w[0].time
                );
            }
        }
        // No bid index on mux buffers: the online coordinator reads prices
        // through materialized view prefixes, so maintaining per-bid win
        // counts here would be O(L) dead work per ingested slot. Consumers
        // that want the incremental index drive a [`FeedBuffer`] directly.
        let buffers = bindings
            .iter()
            .map(|_| FeedBuffer::with_bids(slot_len, Vec::new()))
            .collect();
        let cursors = vec![0; bindings.len()];
        Ok(FeedMux {
            meta: bindings,
            buffers,
            cursors,
            slot_len,
        })
    }

    /// One-feed mux preloaded from a realized trace (the "replay a batch
    /// world online" entry point; the whole history is ingested upfront).
    pub fn single_from_trace(trace: &PriceTrace, od_price: f64) -> FeedMux {
        FeedMux::from_traces(&[("default".into(), "default".into(), od_price, None, trace.clone())])
    }

    /// Preloaded multi-offer mux: `(region, instance_type, od_price,
    /// capacity, trace)` per offer, every slot ingested upfront.
    pub fn from_traces(offers: &[(String, String, f64, Option<u32>, PriceTrace)]) -> FeedMux {
        assert!(!offers.is_empty());
        let slot_len = offers[0].4.slot_len();
        FeedMux {
            meta: offers
                .iter()
                .map(|(r, it, od, cap, _)| FeedBinding {
                    region: r.clone(),
                    instance_type: it.clone(),
                    od_price: *od,
                    capacity: *cap,
                    events: Vec::new(),
                })
                .collect(),
            buffers: offers.iter().map(|(_, _, _, _, t)| FeedBuffer::from_trace(t)).collect(),
            cursors: vec![0; offers.len()],
            slot_len,
        }
    }

    /// Bound every feed's retained history to `max_slots` (resident memory
    /// becomes O(retention) instead of O(ingested history)). Must be
    /// applied before ingestion starts — i.e. on a [`FeedMux::new`] mux,
    /// not a preloaded one — because the chunk granularity backing
    /// eviction is derived from the bound. Views over a bounded mux carry
    /// retention-bounded traces: a consumer whose window reaches an
    /// evicted slot gets a hard error naming it, mirroring the lookahead
    /// guard.
    pub fn with_retention(mut self, max_slots: usize) -> FeedMux {
        self.buffers = self
            .buffers
            .into_iter()
            .map(|b| b.with_retention(max_slots))
            .collect();
        self
    }

    pub fn len(&self) -> usize {
        self.meta.len()
    }

    pub fn is_empty(&self) -> bool {
        self.meta.is_empty()
    }

    pub fn slot_len(&self) -> f64 {
        self.slot_len
    }

    /// One infinite-capacity feed: consumers may take the degenerate
    /// single-market fast path (mirrors [`MarketView::is_degenerate`]).
    pub fn is_degenerate(&self) -> bool {
        self.meta.len() == 1 && self.meta[0].capacity.is_none()
    }

    pub fn capacities(&self) -> Vec<Option<u32>> {
        self.meta.iter().map(|b| b.capacity).collect()
    }

    /// Shared frontier: slots every feed has determined.
    pub fn frontier_slot(&self) -> usize {
        self.buffers
            .iter()
            .map(FeedBuffer::len_slots)
            .min()
            .unwrap_or(0)
    }

    /// Prices are known on every feed for `[0, frontier_time())`.
    pub fn frontier_time(&self) -> f64 {
        self.frontier_slot() as f64 * self.slot_len
    }

    /// The feed holding the frontier back (label, determined slots).
    pub fn laggard(&self) -> (String, usize) {
        self.meta
            .iter()
            .zip(&self.buffers)
            .map(|(m, b)| (m.label(), b.len_slots()))
            .min_by_key(|(_, n)| *n)
            .expect("validated non-empty")
    }

    /// Drain pending events until every feed has determined at least
    /// `slots` slots. A feed that runs out of events is closed (its final
    /// observation committed); returns `false` when the frontier still
    /// cannot reach `slots` — the caller decides whether that is a clean
    /// end-of-feed or a lookahead violation.
    pub fn advance_to_slot(&mut self, slots: usize) -> Result<bool> {
        for k in 0..self.buffers.len() {
            let buf = &mut self.buffers[k];
            let events = &self.meta[k].events;
            while buf.len_slots() < slots {
                match events.get(self.cursors[k]) {
                    Some(&e) => {
                        buf.push_event(e)?;
                        self.cursors[k] += 1;
                    }
                    None => {
                        buf.close();
                        break;
                    }
                }
            }
        }
        Ok(self.frontier_slot() >= slots)
    }

    /// Advance until every feed covers simulated time `t`.
    pub fn advance_to_time(&mut self, t: f64) -> Result<bool> {
        self.advance_to_slot((t / self.slot_len).ceil().max(0.0) as usize)
    }

    /// Every pending event ingested and every feed closed?
    pub fn is_exhausted(&self) -> bool {
        self.cursors
            .iter()
            .zip(&self.meta)
            .all(|(&c, m)| c >= m.events.len())
            && self.buffers.iter().all(FeedBuffer::is_closed)
    }

    /// Per-feed buffers (availability indices, watermarks).
    pub fn buffers(&self) -> &[FeedBuffer] {
        &self.buffers
    }

    pub fn bindings(&self) -> &[FeedBinding] {
        &self.meta
    }

    /// Materialize the ingested prefixes as a capacity-aware
    /// [`MarketView`]. Each offer's trace covers *its own* watermark (≥
    /// the shared frontier); consumers gated on the frontier never read
    /// past any of them. Traces are shared-suffix: sealed feed chunks are
    /// referenced, not copied, so a refresh costs O(new slots), and under
    /// bounded retention each trace starts at its buffer's retention
    /// boundary ([`crate::market::PriceTrace::first_slot`]).
    pub fn view(&self) -> Result<MarketView> {
        let offers = self
            .meta
            .iter()
            .zip(&self.buffers)
            .map(|(m, b)| {
                Ok(MarketOffer {
                    region: m.region.clone(),
                    instance_type: m.instance_type.clone(),
                    od_price: m.od_price,
                    trace: b.shared_trace().map_err(|e| {
                        anyhow::anyhow!("feed '{}': {e}", m.label())
                    })?,
                    capacity: m.capacity,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        MarketView::new(offers)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::market::SLOTS_PER_UNIT;

    const DT: f64 = 1.0 / SLOTS_PER_UNIT as f64;

    fn ev(t: f64, p: f64) -> PriceEvent {
        PriceEvent { time: t, price: p }
    }

    fn binding(region: &str, od: f64, cap: Option<u32>, events: Vec<PriceEvent>) -> FeedBinding {
        FeedBinding {
            region: region.into(),
            instance_type: "default".into(),
            od_price: od,
            capacity: cap,
            events,
        }
    }

    #[test]
    fn frontier_is_the_minimum_across_feeds() {
        let mut mux = FeedMux::new(
            vec![
                binding("fast", 1.0, None, vec![ev(0.0, 0.2), ev(4.0, 0.3)]),
                binding("slow", 1.1, Some(8), vec![ev(0.0, 0.5), ev(2.0, 0.6), ev(4.0, 0.4)]),
            ],
            DT,
        )
        .unwrap();
        assert!(!mux.is_degenerate());
        assert_eq!(mux.frontier_slot(), 0);
        assert!(mux.advance_to_time(1.5).unwrap());
        // Both feeds have events past 1.5: frontier covers it.
        assert!(mux.frontier_time() >= 1.5);
        assert!(mux.advance_to_time(4.0).unwrap());
        let v = mux.view().unwrap();
        assert_eq!(v.len(), 2);
        assert_eq!(v.offers()[1].capacity, Some(8));
        // Beyond the last events: feeds close and the frontier stalls.
        assert!(!mux.advance_to_time(10.0).unwrap());
        assert!(mux.is_exhausted());
        let (label, _) = mux.laggard();
        assert!(label.contains('/'));
    }

    #[test]
    fn preloaded_mux_is_exhausted_and_covers_its_trace() {
        let trace = PriceTrace::from_prices(vec![0.2; 24], DT);
        let mut mux = FeedMux::single_from_trace(&trace, 1.0);
        assert!(mux.is_degenerate());
        assert!(mux.is_exhausted());
        assert_eq!(mux.frontier_slot(), 24);
        assert!(mux.advance_to_time(2.0).unwrap());
        assert!(!mux.advance_to_time(2.1).unwrap());
        let v = mux.view().unwrap();
        assert_eq!(v.home().trace.num_slots(), 24);
    }

    #[test]
    fn bounded_mux_views_carry_retention_boundaries() {
        let events: Vec<PriceEvent> = (0..200)
            .map(|i| ev(i as f64 * 0.25, 0.2 + 0.001 * (i % 7) as f64))
            .collect();
        let mut mux = FeedMux::new(vec![binding("a", 1.0, None, events)], DT)
            .unwrap()
            .with_retention(40);
        assert!(mux.advance_to_slot(500).unwrap());
        let v = mux.view().unwrap();
        let trace = &v.home().trace;
        assert!(trace.first_slot() > 0, "retention should have evicted");
        assert_eq!(trace.num_slots(), mux.frontier_slot());
        // Recent slots readable; evicted history is a buffer-level error.
        assert!(mux.buffers()[0]
            .price_of_slot(trace.first_slot().saturating_sub(1))
            .unwrap_err()
            .to_string()
            .contains("evicted"));
    }

    #[test]
    fn validation_mirrors_market_view() {
        assert!(FeedMux::new(vec![], DT).is_err());
        assert!(FeedMux::new(vec![binding("a", 0.0, None, vec![])], DT).is_err());
        assert!(FeedMux::new(vec![binding("a", 1.0, Some(0), vec![])], DT).is_err());
        assert!(FeedMux::new(
            vec![
                binding("a", 1.0, None, vec![]),
                binding("a", 1.0, None, vec![])
            ],
            DT
        )
        .is_err());
        // Non-monotone events are the loader's job to fix; the mux refuses.
        assert!(
            FeedMux::new(vec![binding("a", 1.0, None, vec![ev(2.0, 0.2), ev(1.0, 0.3)])], DT)
                .is_err()
        );
    }
}
