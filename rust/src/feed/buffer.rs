//! Append-only, slot-aligned price ingestion.
//!
//! A [`FeedBuffer`] is the streaming counterpart of a
//! [`crate::market::PriceTrace`]: price *events* (strictly monotone
//! timestamps) arrive one at a time and are materialized onto the standard
//! slot grid with exactly the step-function semantics the batch CSV loader
//! uses — a slot takes the last observation at or before its midpoint — so
//! a buffer fed a trace's observations and then [`FeedBuffer::close`]d
//! reproduces [`crate::market::replay::trace_from_csv`]'s slot prices
//! bit for bit.
//!
//! The buffer feeds an [`IncrementalAvailabilityIndex`] as slots
//! materialize (O(k·L) per k new slots, never an O(S·L) rebuild) and hands
//! consumers a *prefix* view of the ingested history. Reading a slot at or
//! past the ingested frontier is a hard error, not a clamp: the online
//! coordinator leans on this to prove it never peeks at prices the feed
//! has not delivered yet.

use std::sync::Arc;

use anyhow::{bail, ensure, Result};

use crate::market::PriceTrace;

use super::index::IncrementalAvailabilityIndex;

/// Chunk granularity for unbounded buffers: large enough that sealing and
/// handle-cloning overheads vanish, small enough that the open tail copied
/// on each materialization stays tiny.
const DEFAULT_CHUNK_SLOTS: usize = 1024;

/// One price observation: the price takes effect at `time` and holds until
/// the next event.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PriceEvent {
    pub time: f64,
    pub price: f64,
}

/// Append-only slot-aligned price buffer with an incremental availability
/// index.
#[derive(Debug, Clone)]
pub struct FeedBuffer {
    slot_len: f64,
    /// Sealed immutable chunks of exactly `chunk_len` slots each; absolute
    /// slot `base_slot + i·chunk_len + j` has price `sealed[i][j]`. Sealed
    /// chunks are shared (`Arc`) with every trace materialized from this
    /// buffer, so a view refresh clones chunk *handles*, not history.
    sealed: Vec<Arc<[f64]>>,
    /// Open tail: the newest `< chunk_len` slots, sealed once full.
    tail: Vec<f64>,
    /// Absolute slot of `sealed[0]` (always a multiple of `chunk_len`:
    /// eviction drops whole chunks).
    base_slot: usize,
    chunk_len: usize,
    index: IncrementalAvailabilityIndex,
    /// Maximum retained slots; `None` = unbounded (required for trace
    /// materialization).
    retention: Option<usize>,
    /// Timestamp of the latest accepted event (events must be strictly
    /// after it); direct slot appends advance it to the grid watermark.
    last_event: Option<f64>,
    /// Price in force after the latest event (extends forward as slots
    /// materialize).
    cur_price: f64,
    /// No further events accepted once the final observation's slot has
    /// been committed.
    closed: bool,
}

impl FeedBuffer {
    /// Empty buffer on a slot grid, indexing the §6.1 bid grid (the bids
    /// the regret and availability paths actually query).
    pub fn new(slot_len: f64) -> FeedBuffer {
        FeedBuffer::with_bids(slot_len, crate::policy::grid_b())
    }

    /// Empty buffer indexing a caller-chosen bid set.
    pub fn with_bids(slot_len: f64, bids: Vec<f64>) -> FeedBuffer {
        assert!(slot_len > 0.0);
        FeedBuffer {
            slot_len,
            sealed: Vec::new(),
            tail: Vec::new(),
            base_slot: 0,
            chunk_len: DEFAULT_CHUNK_SLOTS,
            index: IncrementalAvailabilityIndex::new(bids),
            retention: None,
            last_event: None,
            cur_price: f64::NAN,
            closed: false,
        }
    }

    /// Bound retained slot history (the index is bounded alongside).
    /// A bounded buffer cannot materialize a full-history [`PriceTrace`]
    /// prefix, but still materializes retention-bounded shared traces via
    /// [`FeedBuffer::shared_trace`]. Must be configured before ingestion:
    /// the chunk granularity is derived from the retention bound so
    /// eviction (which drops whole chunks) can actually engage.
    pub fn with_retention(mut self, max_slots: usize) -> FeedBuffer {
        assert!(max_slots > 0, "retention of zero slots retains nothing");
        assert!(
            self.sealed.is_empty() && self.tail.is_empty(),
            "set retention before ingesting slots"
        );
        self.retention = Some(max_slots);
        self.chunk_len = (max_slots / 2).clamp(1, DEFAULT_CHUNK_SLOTS);
        self.index = self.index.with_retention(max_slots);
        self
    }

    /// Preloaded buffer over an already-realized trace (every slot
    /// ingested, feed closed) — what "replay a batch trace through the
    /// online path" means. No bid index: replay consumers read prices
    /// through the trace prefix (use [`FeedBuffer::with_bids`] +
    /// [`FeedBuffer::push_slots`] for an indexed preload).
    pub fn from_trace(trace: &PriceTrace) -> FeedBuffer {
        let mut b = FeedBuffer::with_bids(trace.slot_len(), Vec::new());
        let prices: Vec<f64> = (0..trace.num_slots()).map(|s| trace.price_of_slot(s)).collect();
        b.push_slots(&prices).expect("trace prices are valid slot prices");
        b.closed = true;
        b
    }

    pub fn slot_len(&self) -> f64 {
        self.slot_len
    }

    /// Resident (non-evicted) slot count.
    fn resident(&self) -> usize {
        self.sealed.len() * self.chunk_len + self.tail.len()
    }

    /// Total determined slots since the stream origin (absolute frontier).
    pub fn len_slots(&self) -> usize {
        self.base_slot + self.resident()
    }

    /// First retained absolute slot (0 until bounded retention evicts).
    pub fn base_slot(&self) -> usize {
        self.base_slot
    }

    /// Prices are known for simulated time `[0, watermark_time())`.
    pub fn watermark_time(&self) -> f64 {
        self.len_slots() as f64 * self.slot_len
    }

    pub fn is_closed(&self) -> bool {
        self.closed
    }

    /// The incremental per-bid availability index over the ingested slots.
    pub fn index(&self) -> &IncrementalAvailabilityIndex {
        &self.index
    }

    /// Accept one price event. Timestamps must be strictly monotone —
    /// loaders normalize out-of-order dumps *before* the buffer, so a
    /// violation here is data corruption, not a reorder to paper over.
    /// Returns the number of newly determined slots.
    pub fn push_event(&mut self, event: PriceEvent) -> Result<usize> {
        let PriceEvent { time, price } = event;
        ensure!(!self.closed, "feed buffer is closed; no further events");
        ensure!(
            time.is_finite() && time >= 0.0,
            "feed event at t={time}: timestamps must be finite and non-negative"
        );
        ensure!(
            price.is_finite() && price > 0.0,
            "feed event at t={time}: price {price} must be finite and positive"
        );
        if let Some(last) = self.last_event {
            ensure!(
                time > last,
                "feed event at t={time} is not strictly after t={last}: \
                 normalize (sort + dedupe) the source before ingestion"
            );
        }
        // Slots whose midpoint is before `time` are now final: no later
        // event (strictly after `time`) can be their last observation at or
        // before the midpoint. The first event's price anchors the grid
        // origin (loaders shift the first observation to t = 0).
        let fill = if self.last_event.is_none() { price } else { self.cur_price };
        let determined = ((time / self.slot_len) - 0.5).ceil().max(0.0) as usize;
        let added = self.extend_to(determined, fill);
        self.cur_price = price;
        self.last_event = Some(time);
        Ok(added)
    }

    /// Append already-slot-aligned prices directly (a feed that is on the
    /// grid natively, or a preloaded trace). Advances the event clock to
    /// the new watermark so interleaved events stay monotone.
    pub fn push_slots(&mut self, prices: &[f64]) -> Result<()> {
        ensure!(!self.closed, "feed buffer is closed; no further slots");
        if prices.is_empty() {
            return Ok(());
        }
        for &p in prices {
            ensure!(
                p > 0.0 && !p.is_nan(),
                "feed slot price {p} must be positive (use +inf for never-available)"
            );
        }
        for &p in prices {
            self.append_one(p);
        }
        if let Some(&last) = prices.last() {
            self.cur_price = last;
        }
        self.last_event = Some(self.watermark_time().max(self.last_event.unwrap_or(0.0)));
        Ok(())
    }

    /// Append one determined slot: the tail grows, seals into an immutable
    /// shared chunk when full, and bounded retention evicts whole leading
    /// chunks — O(1) amortized, never an O(history) move.
    fn append_one(&mut self, price: f64) {
        self.tail.push(price);
        self.index.append_one(price);
        if self.tail.len() == self.chunk_len {
            self.sealed.push(Arc::from(self.tail.as_slice()));
            self.tail.clear();
            self.maybe_evict();
        }
    }

    /// Commit the final observation's own slot (the batch CSV loader's
    /// `n = ceil(t_last/dt + 0.5)` rule) and refuse further events.
    /// Returns the number of newly determined slots.
    pub fn close(&mut self) -> usize {
        if self.closed {
            return 0;
        }
        self.closed = true;
        match self.last_event {
            None => 0,
            Some(t) => {
                let target = ((t / self.slot_len + 0.5).ceil() as usize).max(1);
                self.extend_to(target, self.cur_price)
            }
        }
    }

    fn extend_to(&mut self, target_slots: usize, fill: f64) -> usize {
        let have = self.len_slots();
        if target_slots <= have {
            return 0;
        }
        let add = target_slots - have;
        for _ in 0..add {
            self.append_one(fill);
        }
        add
    }

    /// Drop whole leading chunks while at least `retention` slots stay
    /// resident afterwards; sealed chunks are `Arc`s, so already-handed-out
    /// traces keep their history alive independently.
    fn maybe_evict(&mut self) {
        let Some(max) = self.retention else { return };
        while !self.sealed.is_empty() && self.resident() - self.chunk_len >= max {
            self.sealed.remove(0);
            self.base_slot += self.chunk_len;
        }
    }

    /// Price of an *ingested* absolute slot. Reading at or past the
    /// frontier is the lookahead hard error the online coordinator relies
    /// on; reading before the retained window is an eviction error.
    pub fn price_of_slot(&self, slot: usize) -> Result<f64> {
        if slot < self.base_slot {
            bail!(
                "feed slot {slot} evicted (retention starts at slot {})",
                self.base_slot
            );
        }
        if slot >= self.len_slots() {
            bail!(
                "lookahead: slot {slot} is past the ingested frontier \
                 ({} slots, t < {:.4})",
                self.len_slots(),
                self.watermark_time()
            );
        }
        let rel = slot - self.base_slot;
        let in_sealed = self.sealed.len() * self.chunk_len;
        Ok(if rel < in_sealed {
            self.sealed[rel / self.chunk_len][rel % self.chunk_len]
        } else {
            self.tail[rel - in_sealed]
        })
    }

    /// Materialize the ingested prefix as an immutable [`PriceTrace`]
    /// (what executors and counterfactual sweeps consume). Only defined
    /// for unbounded buffers with at least one slot; bounded buffers use
    /// [`FeedBuffer::shared_trace`].
    pub fn trace_prefix(&self) -> Result<PriceTrace> {
        ensure!(
            self.base_slot == 0,
            "cannot materialize a trace: retention evicted slots [0, {})",
            self.base_slot
        );
        self.shared_trace()
    }

    /// Materialize the retained history as a shared-suffix
    /// [`PriceTrace`]: sealed chunks are shared by handle and only the
    /// open tail is copied, so refreshing a consumer's view costs
    /// O(chunk handles + tail), not O(ingested history). Under bounded
    /// retention the trace starts at [`PriceTrace::first_slot`] > 0 and
    /// reads below it are hard errors.
    pub fn shared_trace(&self) -> Result<PriceTrace> {
        ensure!(self.resident() > 0, "cannot materialize an empty feed");
        let mut chunks = self.sealed.clone();
        if !self.tail.is_empty() {
            chunks.push(Arc::from(self.tail.as_slice()));
        }
        Ok(PriceTrace::from_chunks(chunks, self.base_slot, self.slot_len))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::market::replay::trace_from_csv;
    use crate::market::SLOTS_PER_UNIT;

    const DT: f64 = 1.0 / SLOTS_PER_UNIT as f64;

    fn ev(t: f64, p: f64) -> PriceEvent {
        PriceEvent { time: t, price: p }
    }

    #[test]
    fn events_reproduce_the_batch_csv_step_function() {
        // Same observations through both paths: identical slot prices.
        let csv = "time,price\n0,0.2\n1,0.8\n3,0.5\n";
        let batch = trace_from_csv(csv, 1.0, 1.0).unwrap();
        let mut feed = FeedBuffer::new(DT);
        for (t, p) in [(0.0, 0.2), (1.0, 0.8), (3.0, 0.5)] {
            feed.push_event(ev(t, p)).unwrap();
        }
        feed.close();
        assert_eq!(feed.len_slots(), batch.num_slots());
        for s in 0..batch.num_slots() {
            assert_eq!(feed.price_of_slot(s).unwrap(), batch.price_of_slot(s), "slot {s}");
        }
        let trace = feed.trace_prefix().unwrap();
        assert_eq!(trace.num_slots(), batch.num_slots());
        assert_eq!(trace.price_at(1.5), 0.8);
    }

    #[test]
    fn watermark_advances_only_to_determined_slots() {
        let mut feed = FeedBuffer::new(DT);
        // First event at t=0 determines nothing yet (its own slot's
        // midpoint is still ahead).
        assert_eq!(feed.push_event(ev(0.0, 0.3)).unwrap(), 0);
        assert_eq!(feed.len_slots(), 0);
        // An event one unit later determines the 12 slots whose midpoints
        // precede it, all at the first observation's price.
        assert_eq!(feed.push_event(ev(1.0, 0.6)).unwrap(), 12);
        assert_eq!(feed.len_slots(), 12);
        assert_eq!(feed.price_of_slot(5).unwrap(), 0.3);
        // Peeking past the frontier is a hard error, not a clamp.
        let err = feed.price_of_slot(12).unwrap_err().to_string();
        assert!(err.contains("lookahead"), "{err}");
        // Closing commits the final observation's own slot.
        assert_eq!(feed.close(), 1);
        assert_eq!(feed.price_of_slot(12).unwrap(), 0.6);
        assert!(feed.push_event(ev(2.0, 0.4)).is_err(), "closed feed");
    }

    #[test]
    fn monotonicity_is_enforced() {
        let mut feed = FeedBuffer::new(DT);
        feed.push_event(ev(1.0, 0.2)).unwrap();
        let err = feed.push_event(ev(1.0, 0.3)).unwrap_err().to_string();
        assert!(err.contains("strictly after"), "{err}");
        assert!(feed.push_event(ev(0.5, 0.3)).is_err());
        assert!(feed.push_event(ev(f64::NAN, 0.3)).is_err());
        assert!(feed.push_event(ev(2.0, -0.1)).is_err());
        assert!(feed.push_event(ev(2.0, 0.3)).is_ok());
    }

    #[test]
    fn preloaded_buffer_matches_its_trace() {
        let trace = trace_from_csv("0,0.2\n2,0.7\n5,0.3\n", 1.0, 1.0).unwrap();
        let feed = FeedBuffer::from_trace(&trace);
        assert!(feed.is_closed());
        assert_eq!(feed.len_slots(), trace.num_slots());
        let back = feed.trace_prefix().unwrap();
        for s in 0..trace.num_slots() {
            assert_eq!(back.price_of_slot(s), trace.price_of_slot(s));
        }
    }

    #[test]
    fn retention_bounds_memory_and_blocks_trace_materialization() {
        let mut feed = FeedBuffer::new(DT).with_retention(50);
        let prices: Vec<f64> = (0..500).map(|i| 0.2 + 0.001 * i as f64).collect();
        feed.push_slots(&prices).unwrap();
        assert_eq!(feed.len_slots(), 500);
        assert!(feed.base_slot() > 400);
        assert!(feed.price_of_slot(499).is_ok());
        let err = feed.price_of_slot(0).unwrap_err().to_string();
        assert!(err.contains("evicted"), "{err}");
        assert!(feed.trace_prefix().is_err());
        // The index stays bounded too, and answers inside the window.
        assert!(feed.index().base_slot() > 0);
    }

    #[test]
    fn shared_trace_matches_slot_reads_and_survives_eviction() {
        let mut feed = FeedBuffer::new(DT).with_retention(60);
        let prices: Vec<f64> = (0..400).map(|i| 0.15 + 0.002 * (i % 37) as f64).collect();
        feed.push_slots(&prices).unwrap();
        let t = feed.shared_trace().unwrap();
        assert_eq!(t.num_slots(), feed.len_slots());
        assert_eq!(t.first_slot(), feed.base_slot());
        for s in feed.base_slot()..feed.len_slots() {
            assert_eq!(t.price_of_slot(s), feed.price_of_slot(s).unwrap(), "slot {s}");
        }
        // Ingesting further evicts in the buffer, but the materialized
        // trace owns its chunks: its history stays readable.
        let base_before = feed.base_slot();
        feed.push_slots(&prices).unwrap();
        assert!(feed.base_slot() > base_before);
        assert_eq!(t.price_of_slot(base_before), prices[base_before]);
        assert!(feed.price_of_slot(base_before).is_err());
    }

    #[test]
    fn unbounded_shared_trace_equals_trace_prefix() {
        let mut feed = FeedBuffer::new(DT);
        // Fewer slots than a chunk: everything lives in the open tail.
        feed.push_slots(&[0.3; 30]).unwrap();
        let shared = feed.shared_trace().unwrap();
        let prefix = feed.trace_prefix().unwrap();
        assert_eq!(shared.num_slots(), prefix.num_slots());
        assert_eq!(shared.first_slot(), 0);
        for s in 0..prefix.num_slots() {
            assert_eq!(shared.price_of_slot(s), prefix.price_of_slot(s));
        }
    }

    #[test]
    fn slots_then_events_keep_the_clock_monotone() {
        let mut feed = FeedBuffer::new(DT);
        feed.push_slots(&[0.2; 12]).unwrap(); // watermark t = 1
        assert!(feed.push_event(ev(0.5, 0.4)).is_err(), "behind the watermark");
        assert_eq!(feed.push_event(ev(2.0, 0.4)).unwrap(), 12);
        // The run between watermark and the new event holds the last price.
        assert_eq!(feed.price_of_slot(13).unwrap(), 0.2);
    }
}
