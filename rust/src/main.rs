//! `repro` — CLI entrypoint for the dagcloud reproduction.
//!
//! Subcommands map one-to-one onto the paper's evaluation section, plus
//! the observability drivers (`trace`, `health`, `diff`); see
//! `repro help`.

fn main() {
    std::process::exit(dagcloud::coordinator::cli_main());
}
