//! # dagcloud
//!
//! A production-quality reproduction of *"Towards Cost-Optimal Policies for
//! DAGs to Utilize IaaS Clouds with Online Learning"* (Wu, Yu, Casale, Gao,
//! 2021).
//!
//! The crate implements the paper's full stack:
//!
//! * a **cloud market substrate** ([`market`]): spot-price processes,
//!   per-second on-demand billing, a self-owned instance pool with
//!   `N(t)` / `N(t1,t2)` queries, and a capacity-aware multi-offer
//!   [`market::MarketView`] over named `(region, instance_type)` pairs
//!   (the paper's single market is its one-offer degenerate case);
//! * a **workload substrate** ([`workload`]): DAG jobs, the §6.1 synthetic
//!   generator, and the Nagarajan et al. DAG→chain transformation;
//! * the **paper's policies** ([`policy`]): the optimal deadline allocation
//!   `Dealloc` (Algorithm 1), the single-task spot/on-demand strategy
//!   (Prop. 4.1), the self-owned allocation rule (Eq. 12), and the baseline
//!   heuristics (Greedy / Even / naive self-owned);
//! * a **discrete-event simulator** ([`sim`]) that executes chain jobs
//!   against realized spot-price traces (Definitions 3.1/3.2);
//! * **online learning** ([`learning`]): the TOLA exponentiated-weights
//!   algorithm (Appendix B.2) with regret accounting;
//! * a **PJRT runtime** ([`runtime`]) that loads the AOT-compiled JAX/Pallas
//!   counterfactual-cost kernel (HLO text in `artifacts/`) and runs it on the
//!   TOLA hot path — Python never runs at request time;
//! * the **L3 coordinator** ([`coordinator`]): leader event loop, worker
//!   thread pool, metrics and config;
//! * a **scenario engine** ([`scenario`]): declarative multi-market worlds
//!   (multi-region processes, regime schedules, CSV trace replay), a
//!   built-in registry, and a sharded deterministic batch runner;
//! * a **streaming market feed** ([`feed`]): append-only slot-aligned
//!   price ingestion with an incremental availability index, loaders for
//!   the public EC2 spot-history dump formats, and a feed mux — consumed
//!   by the online coordinator loop
//!   ([`coordinator::online::tola_run_online`]), which schedules against
//!   only already-ingested prices;
//! * a **fleet layer** ([`fleet`]): a shard manifest dealing worlds to
//!   many coordinators, an associative order-independent merge of their
//!   reports into one `dagcloud.fleet/v1` document, and cross-scenario
//!   policy-robustness scoring (least-bad fixed policy across all
//!   worlds);
//! * a **robustness engine** ([`robustness`]): deterministic derivation
//!   operators (block bootstrap, regime oversampling, price spikes,
//!   capacity dropout, feed gaps) growing large world populations from
//!   registry bases, regime tagging, and a cross-regime promotion gate
//!   over the fleet layer's tail-risk scores (`dagcloud.robustness/v1`);
//! * a **telemetry layer** ([`telemetry`]): a deterministic sim-time event
//!   log (byte-identical across threads/shards, property-tested), a
//!   wall-clock span profiler with log-scale latency histograms exported
//!   as `dagcloud.telemetry/v1` + Chrome trace JSON, a run-health plane
//!   ([`telemetry::health`], `dagcloud.health/v1`) derived purely from
//!   the event log — feed lag, retention pressure, capacity headroom,
//!   regret-vs-bound trajectory, deterministic anomaly annotations — a
//!   forensics differ ([`telemetry::diff`], `repro diff`) that localizes
//!   determinism breaks to the first diverging `(sim_time, source, seq)`
//!   event, and the leveled status logger behind `-v`/`--quiet` — all
//!   threaded through handles, never globals, so report bytes are
//!   provably telemetry-independent;
//! * an **experiment harness** ([`experiments`]) regenerating every table and
//!   figure of the paper's evaluation section.
//!
//! `ARCHITECTURE.md` (repo root) walks the data flow between these
//! subsystems and the determinism invariants each layer pins;
//! `docs/SCHEMAS.md` documents every report schema field by field.

pub mod util;
pub mod market;
pub mod feed;
pub mod workload;
pub mod policy;
pub mod sim;
pub mod learning;
pub mod runtime;
pub mod coordinator;
pub mod scenario;
pub mod fleet;
pub mod robustness;
pub mod telemetry;
pub mod experiments;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
