//! Wall-clock plane: scoped-span profiler.
//!
//! [`SpanGuard`] is an RAII timer: construct it around a region, and on
//! drop it folds the elapsed wall time into per-name aggregates (count,
//! total ns, log-scale latency histogram) plus a bounded list of raw
//! trace events for the Chrome trace export. Everything here is
//! nondeterministic by nature and is quarantined in the `wall_clock`
//! section of `dagcloud.telemetry/v1` — never in a scenario/fleet/
//! robustness report.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::util::json::Json;

use super::hist::Histogram;

/// Raw trace events kept for the Chrome export. Aggregates keep counting
/// past the cap; only the per-event list is truncated.
pub const TRACE_CAP: usize = 100_000;

/// Per-name span aggregate.
#[derive(Debug, Clone)]
pub struct SpanAgg {
    pub count: u64,
    pub total_ns: u64,
    pub hist: Histogram,
}

impl SpanAgg {
    fn new() -> SpanAgg {
        SpanAgg { count: 0, total_ns: 0, hist: Histogram::new() }
    }
}

/// One completed span occurrence, for the Chrome trace-event export.
/// Timestamps are µs since the telemetry handle's epoch.
#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub name: &'static str,
    pub ts_us: f64,
    pub dur_us: f64,
    /// Small display-only thread index parsed from the OS thread id, so
    /// overlapping spans from the worker pool land on distinct tracks in
    /// Perfetto. Display only — never serialized outside the trace file.
    pub tid: u64,
}

/// All wall-clock span state for one telemetry handle.
#[derive(Debug, Clone, Default)]
pub struct SpanStats {
    agg: BTreeMap<&'static str, SpanAgg>,
    trace: Vec<TraceEvent>,
    trace_dropped: u64,
}

impl SpanStats {
    pub fn record(&mut self, name: &'static str, ts_us: f64, dur_ns: u64, tid: u64) {
        let a = self.agg.entry(name).or_insert_with(SpanAgg::new);
        a.count += 1;
        a.total_ns += dur_ns;
        a.hist.observe(dur_ns);
        if self.trace.len() < TRACE_CAP {
            self.trace.push(TraceEvent {
                name,
                ts_us,
                dur_us: dur_ns as f64 / 1_000.0,
                tid,
            });
        } else {
            self.trace_dropped += 1;
        }
    }

    pub fn aggregates(&self) -> &BTreeMap<&'static str, SpanAgg> {
        &self.agg
    }

    pub fn trace_events(&self) -> &[TraceEvent] {
        &self.trace
    }

    pub fn trace_dropped(&self) -> u64 {
        self.trace_dropped
    }

    /// `{name: {count, total_ns, mean_ns, hist}}` — span names are
    /// `&'static str`, so the BTreeMap (and the JSON) is canonically
    /// ordered by name.
    pub fn to_json(&self) -> Json {
        let mut spans = Json::obj();
        for (name, a) in &self.agg {
            let mut j = Json::obj();
            j.set("count", Json::Num(a.count as f64))
                .set("total_ns", Json::Num(a.total_ns as f64))
                .set(
                    "mean_ns",
                    Json::Num(if a.count == 0 {
                        0.0
                    } else {
                        a.total_ns as f64 / a.count as f64
                    }),
                )
                .set("hist", a.hist.to_json());
            spans.set(name, j);
        }
        spans
    }
}

/// Small display thread index from the OS thread id (`ThreadId(17)` →
/// 17). Purely cosmetic: it spreads concurrent spans across Perfetto
/// tracks and appears only in the Chrome trace file.
fn display_tid() -> u64 {
    let s = format!("{:?}", std::thread::current().id());
    s.chars()
        .filter(|c| c.is_ascii_digit())
        .collect::<String>()
        .parse()
        .unwrap_or(0)
}

/// RAII wall-clock timer. When the handle's span plane is off the guard
/// holds `None` and drop is a no-op.
#[derive(Debug)]
pub struct SpanGuard {
    stats: Option<Arc<Mutex<SpanStats>>>,
    epoch: Instant,
    name: &'static str,
    start: Instant,
}

impl SpanGuard {
    pub(super) fn new(
        stats: Option<Arc<Mutex<SpanStats>>>,
        epoch: Instant,
        name: &'static str,
    ) -> SpanGuard {
        SpanGuard { stats, epoch, name, start: Instant::now() }
    }

    /// A guard that times nothing (span plane disabled).
    pub fn disabled() -> SpanGuard {
        SpanGuard::new(None, Instant::now(), "")
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some(stats) = self.stats.take() {
            let dur_ns = self.start.elapsed().as_nanos().min(u128::from(u64::MAX)) as u64;
            let ts_us = self.start.duration_since(self.epoch).as_secs_f64() * 1e6;
            if let Ok(mut s) = stats.lock() {
                s.record(self.name, ts_us, dur_ns, display_tid());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_guard_is_a_noop() {
        let g = SpanGuard::disabled();
        drop(g);
    }

    #[test]
    fn guard_records_into_aggregate_and_trace() {
        let stats = Arc::new(Mutex::new(SpanStats::default()));
        let epoch = Instant::now();
        {
            let _g = SpanGuard::new(Some(stats.clone()), epoch, "sweep");
            std::hint::black_box((0..1000u64).sum::<u64>());
        }
        {
            let _g = SpanGuard::new(Some(stats.clone()), epoch, "sweep");
        }
        let s = stats.lock().unwrap();
        let a = &s.aggregates()["sweep"];
        assert_eq!(a.count, 2);
        assert_eq!(a.hist.count(), 2);
        assert_eq!(s.trace_events().len(), 2);
        assert_eq!(s.trace_events()[0].name, "sweep");
        assert!(s.trace_events()[0].dur_us >= 0.0);
    }

    #[test]
    fn trace_cap_preserves_aggregates() {
        let mut s = SpanStats::default();
        for i in 0..(TRACE_CAP + 3) {
            s.record("hot", i as f64, 10, 0);
        }
        assert_eq!(s.trace_events().len(), TRACE_CAP);
        assert_eq!(s.trace_dropped(), 3);
        assert_eq!(s.aggregates()["hot"].count, (TRACE_CAP + 3) as u64);
    }

    #[test]
    fn span_json_has_mean_and_hist() {
        let mut s = SpanStats::default();
        s.record("merge", 0.0, 100, 0);
        s.record("merge", 5.0, 300, 0);
        let j = s.to_json();
        let m = j.get("merge").unwrap();
        assert_eq!(m.get("count").unwrap().as_f64(), Some(2.0));
        assert_eq!(m.get("total_ns").unwrap().as_f64(), Some(400.0));
        assert_eq!(m.get("mean_ns").unwrap().as_f64(), Some(200.0));
        assert_eq!(m.get("hist").unwrap().get("count").unwrap().as_f64(), Some(2.0));
    }
}
