//! Run-health plane: `dagcloud.health/v1`.
//!
//! Health is **derived, not recorded**: every series here is a pure fold
//! of the deterministic event log (the serialized rows of
//! `dagcloud.telemetry/v1 → deterministic.events`), so the in-process
//! path (`Telemetry::health_json`) and the offline path
//! (`repro health telemetry.json`) produce byte-identical documents, and
//! the coordinator loops carry zero health-specific state.
//!
//! Only per-cell sources (names containing `#`) are folded. Harness
//! sources (`fleet/merge`, `robustness/gate`, names containing `/`) are
//! functions of the CLI invocation — their row counts change with the
//! shard plan — so excluding them is what makes `dagcloud.health/v1`
//! byte-identical across `--threads` and `--shards` (property-tested in
//! `tests/integration_health.rs`).
//!
//! Per source, the fold buckets events into [`HEALTH_WINDOWS`] equal
//! sim-time windows spanning that source's own `[first, last]` event
//! times and derives:
//!
//! - **decisions** — `window_opened` + `spec_chosen` counts (loop
//!   activity);
//! - **feed lag** — decision sim-time minus the frontier position
//!   (`frontier_advanced.slots / SLOTS_PER_UNIT`); negative lag means the
//!   feed frontier runs ahead of the coordinator clock (healthy);
//! - **retention pressure** — minimum `slot - first_resident` over
//!   `residency_probe` events whose trace had already begun evicting
//!   (`first_resident > 0`): the closest any read came to the
//!   `--retention` eviction floor;
//! - **capacity headroom** — per-offer `offer_routed` vs
//!   `capacity_exhausted` counts, `headroom = 1 - exhausted/routed`;
//! - **regret trajectory** — realized average regret vs the Prop. B.1
//!   bound from `param_snapshot` (`ratio → 0` as learning converges).
//!
//! Anomaly annotations use fixed deterministic thresholds — no
//! wall-clock, no adaptive state — so the same log always yields the
//! same annotations: a **spike** is a window with ≥ [`SPIKE_MIN_DECISIONS`]
//! decisions exceeding [`SPIKE_FACTOR`]× the source mean, a **gap** is an
//! empty window inside a log with ≥ [`GAP_MIN_EVENTS`] events, and an
//! **eviction near-miss** is a residency margin ≤ [`NEAR_MISS_SLOTS`].

use std::collections::BTreeMap;

use crate::market::SLOTS_PER_UNIT;
use crate::util::json::Json;

/// Fixed per-source window count. Each source's span is divided into this
/// many equal sim-time buckets regardless of run length, so health docs
/// stay small and window geometry is a pure function of one source's log.
pub const HEALTH_WINDOWS: usize = 16;

/// A window is a decision spike when its count exceeds this multiple of
/// the source's mean per-window decisions …
pub const SPIKE_FACTOR: f64 = 4.0;

/// … and is at least this large in absolute terms (suppresses spikes in
/// near-empty logs where the mean is a fraction of one event).
pub const SPIKE_MIN_DECISIONS: u64 = 8;

/// Empty windows are only anomalous in logs with at least this many
/// events (2× windows: sparse smoke runs legitimately skip buckets).
pub const GAP_MIN_EVENTS: u64 = 2 * HEALTH_WINDOWS as u64;

/// A residency margin at or below this many slots is an eviction
/// near-miss: one retention-budget notch away from a hard error in
/// `ensure_resident`.
pub const NEAR_MISS_SLOTS: i64 = 64;

/// One source's folded health series plus its derived JSON section.
#[derive(Debug, Clone, PartialEq)]
pub struct HealthSection {
    pub source: String,
    /// Events folded into this section.
    pub events: u64,
    /// Anomaly annotations derived for this section.
    pub anomalies: u64,
    /// The serialized per-source section (goes into `cells`).
    pub json: Json,
}

/// Per-window accumulator (internal to the fold).
#[derive(Debug, Clone, Default)]
struct Win {
    events: u64,
    decisions: u64,
    frontier_slots: Option<u64>,
    feed_lag_last: Option<f64>,
    feed_lag_min: Option<f64>,
    residency_margin_min: Option<i64>,
    /// offer id → (routed, exhausted) counts.
    offers: BTreeMap<u64, (u64, u64)>,
    /// `task_migrated` count (only ever non-zero when the run enabled
    /// mid-window migration, so it is emitted off-disk-when-zero and
    /// migration-off health docs stay byte-identical).
    migrations: u64,
    regret_last: Option<f64>,
    bound_last: Option<f64>,
    max_weight_last: Option<f64>,
    jobs_last: Option<u64>,
}

impl Win {
    fn absorb(&mut self, row: &Json, t: f64) {
        self.events += 1;
        match row.opt_str("kind", "") {
            "window_opened" | "spec_chosen" => self.decisions += 1,
            "frontier_advanced" => {
                let slots = row.opt_u64("slots", 0);
                let lag = t - slots as f64 / SLOTS_PER_UNIT as f64;
                self.frontier_slots = Some(slots);
                self.feed_lag_last = Some(lag);
                self.feed_lag_min =
                    Some(self.feed_lag_min.map_or(lag, |m| m.min(lag)));
            }
            "residency_probe" => {
                let first = row.opt_u64("first_resident", 0);
                if first > 0 {
                    let margin = row.opt_u64("slot", 0) as i64 - first as i64;
                    self.residency_margin_min = Some(
                        self.residency_margin_min.map_or(margin, |m| m.min(margin)),
                    );
                }
            }
            "offer_routed" => {
                self.offers.entry(row.opt_u64("offer", 0)).or_default().0 += 1;
            }
            "capacity_exhausted" => {
                self.offers.entry(row.opt_u64("offer", 0)).or_default().1 += 1;
            }
            "task_migrated" => self.migrations += 1,
            "param_snapshot" => {
                self.regret_last = Some(row.opt_f64("regret", 0.0));
                self.bound_last = Some(row.opt_f64("bound", 0.0));
                self.max_weight_last = Some(row.opt_f64("max_weight", 0.0));
                self.jobs_last = Some(row.opt_u64("jobs", 0));
            }
            _ => {}
        }
    }

    fn to_json(&self, window: usize, t0: f64, t1: f64) -> Json {
        let mut j = Json::obj();
        j.set("window", Json::Num(window as f64))
            .set("t0", Json::Num(t0))
            .set("t1", Json::Num(t1))
            .set("events", Json::Num(self.events as f64))
            .set("decisions", Json::Num(self.decisions as f64));
        if let Some(s) = self.frontier_slots {
            j.set("frontier_slots", Json::Num(s as f64));
        }
        if let Some(l) = self.feed_lag_last {
            j.set("feed_lag_last", Json::Num(l));
        }
        if let Some(l) = self.feed_lag_min {
            j.set("feed_lag_min", Json::Num(l));
        }
        if let Some(m) = self.residency_margin_min {
            j.set("residency_margin_min", Json::Num(m as f64));
        }
        if !self.offers.is_empty() {
            let offers: Vec<Json> = self
                .offers
                .iter()
                .map(|(offer, (routed, exhausted))| {
                    let headroom =
                        (1.0 - *exhausted as f64 / (*routed).max(1) as f64).max(0.0);
                    let mut o = Json::obj();
                    o.set("offer", Json::Num(*offer as f64))
                        .set("routed", Json::Num(*routed as f64))
                        .set("exhausted", Json::Num(*exhausted as f64))
                        .set("headroom", Json::Num(headroom));
                    o
                })
                .collect();
            j.set("offers", Json::Arr(offers));
        }
        if self.migrations > 0 {
            j.set("migrations", Json::Num(self.migrations as f64));
        }
        if let Some(r) = self.regret_last {
            j.set("regret_last", Json::Num(r));
        }
        if let Some(b) = self.bound_last {
            j.set("regret_bound_last", Json::Num(b));
            if b > 0.0 {
                if let Some(r) = self.regret_last {
                    j.set("regret_ratio_last", Json::Num(r / b));
                }
            }
        }
        if let Some(w) = self.max_weight_last {
            j.set("max_weight_last", Json::Num(w));
        }
        if let Some(n) = self.jobs_last {
            j.set("jobs_last", Json::Num(n as f64));
        }
        j
    }
}

/// Fold one source's canonically-ordered event rows into a section.
fn fold_source(source: &str, rows: &[&Json]) -> HealthSection {
    let times: Vec<f64> = rows.iter().map(|r| r.opt_f64("sim_time", 0.0)).collect();
    let first = times.iter().copied().fold(f64::INFINITY, f64::min);
    let last = times.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    let span = (last - first).max(0.0);
    let window_len = if span > 0.0 { span / HEALTH_WINDOWS as f64 } else { 1.0 };

    let mut wins = vec![Win::default(); HEALTH_WINDOWS];
    for (row, &t) in rows.iter().zip(times.iter()) {
        let wi = if span > 0.0 {
            ((((t - first) / span) * HEALTH_WINDOWS as f64) as usize)
                .min(HEALTH_WINDOWS - 1)
        } else {
            0
        };
        wins[wi].absorb(row, t);
    }

    let total_events: u64 = wins.iter().map(|w| w.events).sum();
    let total_decisions: u64 = wins.iter().map(|w| w.decisions).sum();
    let mean_decisions = total_decisions as f64 / HEALTH_WINDOWS as f64;

    let mut anomalies: Vec<Json> = Vec::new();
    for (wi, w) in wins.iter().enumerate() {
        if w.decisions >= SPIKE_MIN_DECISIONS
            && w.decisions as f64 > SPIKE_FACTOR * mean_decisions
        {
            let mut a = Json::obj();
            a.set("kind", Json::Str("spike".to_string()))
                .set("window", Json::Num(wi as f64))
                .set("decisions", Json::Num(w.decisions as f64))
                .set("mean_decisions", Json::Num(mean_decisions));
            anomalies.push(a);
        }
        if w.events == 0 && total_events >= GAP_MIN_EVENTS {
            let mut a = Json::obj();
            a.set("kind", Json::Str("gap".to_string()))
                .set("window", Json::Num(wi as f64));
            anomalies.push(a);
        }
        if let Some(m) = w.residency_margin_min {
            if m <= NEAR_MISS_SLOTS {
                let mut a = Json::obj();
                a.set("kind", Json::Str("eviction_near_miss".to_string()))
                    .set("window", Json::Num(wi as f64))
                    .set("margin_slots", Json::Num(m as f64));
                anomalies.push(a);
            }
        }
    }

    let windows: Vec<Json> = wins
        .iter()
        .enumerate()
        .map(|(wi, w)| {
            let t0 = first + wi as f64 * window_len;
            w.to_json(wi, t0, t0 + window_len)
        })
        .collect();

    let n_anomalies = anomalies.len() as u64;
    let mut j = Json::obj();
    j.set("source", Json::Str(source.to_string()))
        .set("events", Json::Num(total_events as f64))
        .set("first_time", Json::Num(first))
        .set("last_time", Json::Num(last))
        .set("window_len", Json::Num(window_len))
        .set("windows", Json::Arr(windows))
        .set("anomalies", Json::Arr(anomalies));
    HealthSection {
        source: source.to_string(),
        events: total_events,
        anomalies: n_anomalies,
        json: j,
    }
}

/// Fold serialized event rows (the `deterministic.events` array) into
/// per-source health sections. Rows must be in canonical
/// `(sim_time, source, seq)` order — which both `deterministic_doc` and a
/// parsed `telemetry.json` guarantee — so grouping preserves it. Harness
/// sources (containing `/`, no `#`) are skipped; rows without a source
/// are ignored.
pub fn fold_events(events: &[Json]) -> Vec<HealthSection> {
    let mut by_source: BTreeMap<&str, Vec<&Json>> = BTreeMap::new();
    for row in events {
        if let Some(src) = row.get("source").and_then(|s| s.as_str()) {
            if src.contains('#') {
                by_source.entry(src).or_default().push(row);
            }
        }
    }
    by_source
        .iter()
        .map(|(src, rows)| fold_source(src, rows))
        .collect()
}

/// Assemble the `dagcloud.health/v1` document from folded sections.
/// Sections are sorted by source, so the document is a pure function of
/// the section *set* — independent of fold, merge, or shard order.
pub fn health_doc(sections: &[HealthSection]) -> Json {
    let mut sorted: Vec<&HealthSection> = sections.iter().collect();
    sorted.sort_by(|a, b| a.source.cmp(&b.source));
    let events: u64 = sorted.iter().map(|s| s.events).sum();
    let anomalies: u64 = sorted.iter().map(|s| s.anomalies).sum();
    let cells: Vec<Json> = sorted.iter().map(|s| s.json.clone()).collect();
    let mut doc = Json::obj();
    doc.set("schema", Json::Str("dagcloud.health/v1".to_string()))
        .set("sources", Json::Num(sorted.len() as f64))
        .set("events", Json::Num(events as f64))
        .set("anomalies", Json::Num(anomalies as f64))
        .set("windows_per_source", Json::Num(HEALTH_WINDOWS as f64))
        .set("cells", Json::Arr(cells));
    doc
}

/// The event rows of any supported document: a full
/// `dagcloud.telemetry/v1` doc (`deterministic.events`) or a bare
/// deterministic section (`events`).
pub fn events_of_doc(doc: &Json) -> Option<&[Json]> {
    doc.get("deterministic")
        .and_then(|d| d.get("events"))
        .or_else(|| doc.get("events"))
        .and_then(|e| e.as_arr())
}

#[cfg(test)]
mod tests {
    use super::super::event::{SimEvent, SimEventKind};
    use super::*;

    fn row(source: &str, t: f64, seq: u64, kind: SimEventKind) -> Json {
        SimEvent { sim_time: t, seq, kind }.to_json(source)
    }

    #[test]
    fn fold_buckets_events_and_skips_harness_sources() {
        let mut rows = Vec::new();
        for i in 0..16 {
            rows.push(row(
                "w#0",
                i as f64,
                i,
                SimEventKind::SpecChosen { job: i as usize, spec: 1 },
            ));
        }
        rows.push(row("fleet/merge", 0.0, 0, SimEventKind::ReportAbsorbed { rows: 2 }));
        let sections = fold_events(&rows);
        assert_eq!(sections.len(), 1);
        let s = &sections[0];
        assert_eq!(s.source, "w#0");
        assert_eq!(s.events, 16);
        let wins = s.json.get("windows").unwrap().as_arr().unwrap();
        assert_eq!(wins.len(), HEALTH_WINDOWS);
        // 16 evenly spaced events over 16 windows: one decision each.
        for w in wins {
            assert_eq!(w.get("decisions").unwrap().as_f64(), Some(1.0));
        }
    }

    #[test]
    fn feed_lag_is_time_minus_frontier() {
        let rows = vec![
            row("w#0", 0.0, 0, SimEventKind::FrontierAdvanced { slots: 24 }),
            row("w#0", 4.0, 1, SimEventKind::FrontierAdvanced { slots: 24 }),
        ];
        let sections = fold_events(&rows);
        let wins = sections[0].json.get("windows").unwrap().as_arr().unwrap();
        // slots=24 at SLOTS_PER_UNIT=12 covers sim-time 2.0: lag at t=0 is
        // -2 (frontier ahead), at t=4 is +2 (coordinator starved).
        assert_eq!(wins[0].get("feed_lag_last").unwrap().as_f64(), Some(-2.0));
        assert_eq!(
            wins[HEALTH_WINDOWS - 1].get("feed_lag_last").unwrap().as_f64(),
            Some(2.0)
        );
    }

    #[test]
    fn near_miss_fires_only_after_eviction_began() {
        // first_resident = 0: nothing evicted, margin undefined, no alarm
        // even though slot - 0 would be tiny.
        let quiet = fold_events(&[row(
            "w#0",
            1.0,
            0,
            SimEventKind::ResidencyProbe { slot: 3, first_resident: 0 },
        )]);
        assert_eq!(quiet[0].anomalies, 0);
        // first_resident > 0 with a margin inside NEAR_MISS_SLOTS: alarm.
        let close = fold_events(&[row(
            "w#0",
            1.0,
            0,
            SimEventKind::ResidencyProbe { slot: 100, first_resident: 90 },
        )]);
        assert_eq!(close[0].anomalies, 1);
        let a = &close[0].json.get("anomalies").unwrap().as_arr().unwrap()[0];
        assert_eq!(a.get("kind").unwrap().as_str(), Some("eviction_near_miss"));
        assert_eq!(a.get("margin_slots").unwrap().as_f64(), Some(10.0));
    }

    #[test]
    fn offer_headroom_counts_routed_vs_exhausted() {
        let rows = vec![
            row("w#0", 1.0, 0, SimEventKind::OfferRouted { job: 0, task: 0, offer: 2, spilled: false }),
            row("w#0", 1.0, 1, SimEventKind::OfferRouted { job: 0, task: 1, offer: 2, spilled: false }),
            row("w#0", 1.0, 2, SimEventKind::OfferRouted { job: 0, task: 2, offer: 2, spilled: false }),
            row("w#0", 1.0, 3, SimEventKind::CapacityExhausted { job: 0, task: 2, offer: 2 }),
        ];
        let sections = fold_events(&rows);
        let wins = sections[0].json.get("windows").unwrap().as_arr().unwrap();
        let offers = wins[0].get("offers").unwrap().as_arr().unwrap();
        assert_eq!(offers.len(), 1);
        assert_eq!(offers[0].get("routed").unwrap().as_f64(), Some(3.0));
        assert_eq!(offers[0].get("exhausted").unwrap().as_f64(), Some(1.0));
        assert_eq!(offers[0].get("headroom").unwrap().as_f64(), Some(1.0 - 1.0 / 3.0));
    }

    #[test]
    fn migrations_fold_into_windows_and_stay_off_disk_when_zero() {
        let quiet = fold_events(&[row(
            "w#0",
            1.0,
            0,
            SimEventKind::OfferRouted { job: 0, task: 0, offer: 0, spilled: false },
        )]);
        let wins = quiet[0].json.get("windows").unwrap().as_arr().unwrap();
        assert!(wins[0].get("migrations").is_none(), "zero count must stay off disk");
        let rows = vec![
            row("w#0", 1.0, 0, SimEventKind::TaskMigrated { job: 0, task: 0, from_offer: 0, to_offer: 1 }),
            row("w#0", 1.0, 1, SimEventKind::TaskMigrated { job: 0, task: 0, from_offer: 1, to_offer: 0 }),
        ];
        let sections = fold_events(&rows);
        let wins = sections[0].json.get("windows").unwrap().as_arr().unwrap();
        assert_eq!(wins[0].get("migrations").unwrap().as_f64(), Some(2.0));
    }

    #[test]
    fn health_doc_bytes_are_independent_of_section_order() {
        let rows = vec![
            row("b#0", 1.0, 0, SimEventKind::FrontierAdvanced { slots: 12 }),
            row("a#0", 2.0, 0, SimEventKind::SpecChosen { job: 0, spec: 3 }),
        ];
        let mut sections = fold_events(&rows);
        let forward = health_doc(&sections).pretty();
        sections.reverse();
        assert_eq!(health_doc(&sections).pretty(), forward);
    }

    #[test]
    fn events_of_doc_handles_both_shapes() {
        let rows = vec![row("w#0", 1.0, 0, SimEventKind::FrontierAdvanced { slots: 1 })];
        let mut det = Json::obj();
        det.set("events", Json::Arr(rows.clone()));
        assert_eq!(events_of_doc(&det).unwrap().len(), 1);
        let mut full = Json::obj();
        full.set("deterministic", det);
        assert_eq!(events_of_doc(&full).unwrap().len(), 1);
        assert!(events_of_doc(&Json::obj()).is_none());
    }
}
