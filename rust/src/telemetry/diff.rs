//! Determinism forensics: structural diff over `dagcloud.*` documents
//! plus first-divergence localization in deterministic event logs.
//!
//! The repo's correctness regime is byte-identity (`cmp` in CI smokes),
//! but a failed `cmp` says nothing about *where* two runs forked. This
//! module turns "bytes differ" into a diagnosis:
//!
//! - the **structural differ** walks two parsed JSON trees in canonical
//!   key order and reports the first differing paths
//!   (`$.cells[3].regret: 0.21 != 0.22`, missing keys, length
//!   mismatches);
//! - the **event-log bisector** aligns the two documents'
//!   `deterministic.events` arrays — both in canonical
//!   `(sim_time, source, seq)` order — and pinpoints the first index
//!   where they disagree, printing that event's key triple and a ±K
//!   context window from each side. Because per-source `seq` numbers the
//!   coordinator loop's emission order, the first diverging triple names
//!   the first *simulation decision* that differed, not merely the first
//!   differing byte.

use crate::util::json::Json;

/// Cap on reported structural paths (the count is still exact).
pub const MAX_STRUCT_DIFFS: usize = 20;

/// Default ±context half-width around the first diverging event.
pub const DEFAULT_CONTEXT: usize = 8;

/// One side of the first diverging event row.
#[derive(Debug, Clone, PartialEq)]
pub struct DivergentRow {
    pub sim_time: f64,
    pub source: String,
    pub seq: u64,
    /// Compact serialization of the full row ("<absent>" past array end).
    pub line: String,
}

/// First divergence between two canonical event logs.
#[derive(Debug, Clone, PartialEq)]
pub struct EventDivergence {
    /// Index into the canonical event arrays where they first disagree.
    pub index: usize,
    pub left_len: usize,
    pub right_len: usize,
    pub left: Option<DivergentRow>,
    pub right: Option<DivergentRow>,
    /// `(index, left_line, right_line)` for the ±K window (compact JSON,
    /// "<absent>" past either array's end).
    pub context: Vec<(usize, String, String)>,
}

/// Full diff report for two documents.
#[derive(Debug, Clone, PartialEq)]
pub struct DiffReport {
    pub identical: bool,
    /// Total structural differences found (may exceed `paths.len()`).
    pub struct_count: usize,
    /// First [`MAX_STRUCT_DIFFS`] differing paths, rendered.
    pub paths: Vec<String>,
    /// Present when both documents carry deterministic event arrays that
    /// disagree.
    pub divergence: Option<EventDivergence>,
}

fn describe(j: &Json) -> String {
    match j {
        Json::Obj(_) => "{…}".to_string(),
        Json::Arr(a) => format!("[…{} items]", a.len()),
        other => other.to_string(),
    }
}

/// Recursive walk; appends rendered paths, counts every difference.
fn walk(path: &str, a: &Json, b: &Json, count: &mut usize, out: &mut Vec<String>) {
    let mut note = |line: String| {
        *count += 1;
        if out.len() < MAX_STRUCT_DIFFS {
            out.push(line);
        }
    };
    match (a, b) {
        (Json::Obj(ma), Json::Obj(mb)) => {
            let keys: std::collections::BTreeSet<&String> =
                ma.keys().chain(mb.keys()).collect();
            for k in keys {
                let p = format!("{path}.{k}");
                match (ma.get(k), mb.get(k)) {
                    (Some(va), Some(vb)) => walk(&p, va, vb, count, out),
                    (Some(va), None) => note(format!("{p}: {} != <absent>", describe(va))),
                    (None, Some(vb)) => note(format!("{p}: <absent> != {}", describe(vb))),
                    (None, None) => {}
                }
            }
        }
        (Json::Arr(xa), Json::Arr(xb)) => {
            if xa.len() != xb.len() {
                note(format!("{path}: array length {} != {}", xa.len(), xb.len()));
            }
            for (i, (va, vb)) in xa.iter().zip(xb.iter()).enumerate() {
                walk(&format!("{path}[{i}]"), va, vb, count, out);
            }
        }
        _ => {
            if a != b {
                note(format!("{path}: {} != {}", describe(a), describe(b)));
            }
        }
    }
}

fn divergent_row(events: &[Json], i: usize) -> Option<DivergentRow> {
    events.get(i).map(|e| DivergentRow {
        sim_time: e.opt_f64("sim_time", f64::NAN),
        source: e.opt_str("source", "?").to_string(),
        seq: e.opt_u64("seq", 0),
        line: e.to_string(),
    })
}

/// Locate the first index where two canonical event arrays disagree and
/// capture a ±`k` context window. `None` when the arrays are identical.
pub fn bisect_events(a: &[Json], b: &[Json], k: usize) -> Option<EventDivergence> {
    let common = a.len().min(b.len());
    let index = match (0..common).find(|&i| a[i] != b[i]) {
        Some(i) => i,
        None if a.len() != b.len() => common,
        None => return None,
    };
    let lo = index.saturating_sub(k);
    let hi = (index + k + 1).min(a.len().max(b.len()));
    let line = |events: &[Json], i: usize| {
        events.get(i).map_or("<absent>".to_string(), |e| e.to_string())
    };
    let context =
        (lo..hi).map(|i| (i, line(a, i), line(b, i))).collect();
    Some(EventDivergence {
        index,
        left_len: a.len(),
        right_len: b.len(),
        left: divergent_row(a, index),
        right: divergent_row(b, index),
        context,
    })
}

/// Diff two parsed documents: structural walk plus, when both carry
/// deterministic event arrays, first-divergence localization.
pub fn diff_docs(a: &Json, b: &Json, k: usize) -> DiffReport {
    let mut count = 0usize;
    let mut paths = Vec::new();
    walk("$", a, b, &mut count, &mut paths);
    let divergence = match (
        super::health::events_of_doc(a),
        super::health::events_of_doc(b),
    ) {
        (Some(ea), Some(eb)) => bisect_events(ea, eb, k),
        _ => None,
    };
    DiffReport {
        identical: count == 0 && divergence.is_none(),
        struct_count: count,
        paths,
        divergence,
    }
}

/// Human-readable rendering (what CI prints on a failed `cmp`).
pub fn render(left_name: &str, right_name: &str, r: &DiffReport) -> String {
    let mut out = String::new();
    if r.identical {
        out.push_str(&format!("{left_name} and {right_name}: documents are identical\n"));
        return out;
    }
    out.push_str(&format!(
        "{left_name} vs {right_name}: {} structural difference(s)\n",
        r.struct_count
    ));
    for p in &r.paths {
        out.push_str(&format!("  {p}\n"));
    }
    if r.struct_count > r.paths.len() {
        out.push_str(&format!(
            "  … and {} more\n",
            r.struct_count - r.paths.len()
        ));
    }
    if let Some(d) = &r.divergence {
        out.push_str(&format!(
            "first diverging event at index {} (left has {} events, right has {}):\n",
            d.index, d.left_len, d.right_len
        ));
        for side in [("left", &d.left), ("right", &d.right)] {
            match side.1 {
                Some(row) => out.push_str(&format!(
                    "  {}: sim_time={} source={} seq={}\n",
                    side.0, row.sim_time, row.source, row.seq
                )),
                None => out.push_str(&format!("  {}: <absent — log ends earlier>\n", side.0)),
            }
        }
        out.push_str("context (left | right):\n");
        for (i, l, r_) in &d.context {
            let marker = if *i == d.index { ">>>" } else { "   " };
            if l == r_ {
                out.push_str(&format!("{marker} [{i}] {l}\n"));
            } else {
                out.push_str(&format!("{marker} [{i}] {l}\n{marker}       | {r_}\n"));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::super::event::{SimEvent, SimEventKind};
    use super::*;

    fn row(source: &str, t: f64, seq: u64, spec: usize) -> Json {
        SimEvent { sim_time: t, seq, kind: SimEventKind::SpecChosen { job: seq as usize, spec } }
            .to_json(source)
    }

    #[test]
    fn identical_docs_report_identical() {
        let mut a = Json::obj();
        a.set("schema", Json::Str("dagcloud.fleet/v1".into()))
            .set("cells", Json::Arr(vec![Json::Num(1.0)]));
        let r = diff_docs(&a, &a.clone(), DEFAULT_CONTEXT);
        assert!(r.identical);
        assert!(render("a", "b", &r).contains("identical"));
    }

    #[test]
    fn structural_diff_names_the_path() {
        let mut a = Json::obj();
        a.set("x", Json::Num(1.0)).set("y", Json::Str("keep".into()));
        let mut b = Json::obj();
        b.set("x", Json::Num(2.0)).set("y", Json::Str("keep".into()));
        let r = diff_docs(&a, &b, DEFAULT_CONTEXT);
        assert!(!r.identical);
        assert_eq!(r.struct_count, 1);
        assert_eq!(r.paths, vec!["$.x: 1 != 2".to_string()]);
    }

    #[test]
    fn bisector_names_the_first_diverging_triple() {
        let a: Vec<Json> = (0..100).map(|i| row("w#0", i as f64, i, 3)).collect();
        let mut b = a.clone();
        b[57] = row("w#0", 57.0, 57, 4); // seeded divergence
        let d = bisect_events(&a, &b, 2).unwrap();
        assert_eq!(d.index, 57);
        let left = d.left.unwrap();
        assert_eq!((left.sim_time, left.source.as_str(), left.seq), (57.0, "w#0", 57));
        // ±2 context: indices 55..=59.
        assert_eq!(d.context.first().unwrap().0, 55);
        assert_eq!(d.context.last().unwrap().0, 59);
    }

    #[test]
    fn bisector_handles_truncated_logs() {
        let a: Vec<Json> = (0..10).map(|i| row("w#0", i as f64, i, 3)).collect();
        let b = a[..7].to_vec();
        let d = bisect_events(&a, &b, 1).unwrap();
        assert_eq!(d.index, 7);
        assert!(d.right.is_none());
        assert_eq!(d.left.unwrap().seq, 7);
    }

    #[test]
    fn equal_logs_have_no_divergence() {
        let a: Vec<Json> = (0..10).map(|i| row("w#0", i as f64, i, 3)).collect();
        assert!(bisect_events(&a, &a.clone(), 3).is_none());
    }
}
