//! Leveled status logger (stderr only).
//!
//! Every human-facing progress line in the binary goes through [`Logger`]
//! so `--quiet` can silence it and `-v` can widen it, while machine-readable
//! results (tables, listings, JSON) keep printing to stdout untouched. The
//! logger never writes to stdout, which is what makes
//! `repro bench ... > out.json` safe: redirected output can only ever
//! contain the report itself.

/// Verbosity threshold. Ordered: `Quiet < Info < Debug`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum LogLevel {
    /// Progress chatter suppressed; warnings and errors still print.
    Quiet,
    /// Default: one-line progress per phase.
    Info,
    /// `-v`: per-cell / per-shard detail.
    Debug,
}

impl Default for LogLevel {
    fn default() -> Self {
        LogLevel::Info
    }
}

/// A copyable handle gating status output by [`LogLevel`].
///
/// All output goes to **stderr**, prefixed `[component]`. `warn`/`error`
/// ignore the level: operational problems must never be silenced by
/// `--quiet`.
#[derive(Debug, Clone, Copy)]
pub struct Logger {
    level: LogLevel,
}

impl Default for Logger {
    fn default() -> Self {
        Logger { level: LogLevel::Info }
    }
}

impl Logger {
    pub fn new(level: LogLevel) -> Logger {
        Logger { level }
    }

    /// Level from `DAGCLOUD_LOG` (`quiet`|`info`|`debug`), defaulting to
    /// `Info`. Used by contexts that have no CLI flags of their own (the
    /// bench harness binaries).
    pub fn from_env() -> Logger {
        let level = match std::env::var("DAGCLOUD_LOG").as_deref() {
            Ok("quiet") => LogLevel::Quiet,
            Ok("debug") => LogLevel::Debug,
            _ => LogLevel::Info,
        };
        Logger { level }
    }

    pub fn level(&self) -> LogLevel {
        self.level
    }

    /// Progress line, shown at `Info` and above.
    pub fn info(&self, component: &str, msg: &str) {
        if self.level >= LogLevel::Info {
            eprintln!("[{component}] {msg}");
        }
    }

    /// Detail line, shown only at `Debug` (`-v`).
    pub fn debug(&self, component: &str, msg: &str) {
        if self.level >= LogLevel::Debug {
            eprintln!("[{component}] {msg}");
        }
    }

    /// Warning: printed at every level, including `Quiet`.
    pub fn warn(&self, component: &str, msg: &str) {
        eprintln!("[{component}] warning: {msg}");
    }

    /// Error: printed at every level, including `Quiet`.
    pub fn error(&self, component: &str, msg: &str) {
        eprintln!("[{component}] error: {msg}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_are_ordered() {
        assert!(LogLevel::Quiet < LogLevel::Info);
        assert!(LogLevel::Info < LogLevel::Debug);
    }

    #[test]
    fn default_is_info() {
        assert_eq!(Logger::default().level(), LogLevel::Info);
    }

    #[test]
    fn logging_never_panics_at_any_level() {
        for level in [LogLevel::Quiet, LogLevel::Info, LogLevel::Debug] {
            let log = Logger::new(level);
            log.info("test", "info line");
            log.debug("test", "debug line");
            log.warn("test", "warn line");
            log.error("test", "error line");
        }
    }
}
