//! Fixed log-scale latency histogram (power-of-two ns buckets).
//!
//! Wall-clock plane only: histogram contents are nondeterministic by
//! nature and must never leak into scenario/fleet/robustness reports —
//! they are serialized exclusively under the `wall_clock` section of
//! `dagcloud.telemetry/v1` and `Metrics::to_json`.

use crate::util::json::Json;

/// Number of buckets. Bucket 0 holds exact zeros, bucket `b` in
/// `1..BUCKETS-1` holds `[2^(b-1), 2^b)` ns, and the last bucket is the
/// overflow catch-all `[2^(BUCKETS-2), u64::MAX]`. With 40 buckets the
/// overflow threshold is 2^38 ns ≈ 275 s — far beyond any span we time.
pub const BUCKETS: usize = 40;

/// Bucket index for a nanosecond observation (see [`BUCKETS`]).
pub fn bucket_index(ns: u64) -> usize {
    if ns == 0 {
        0
    } else {
        ((64 - ns.leading_zeros()) as usize).min(BUCKETS - 1)
    }
}

/// Inclusive lower bound of bucket `b` in ns.
pub fn bucket_lo(b: usize) -> u64 {
    if b == 0 {
        0
    } else {
        1u64 << (b - 1)
    }
}

/// Fixed-size log-scale histogram over nanosecond durations.
#[derive(Debug, Clone)]
pub struct Histogram {
    counts: [u64; BUCKETS],
    count: u64,
    min_ns: u64,
    max_ns: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            counts: [0; BUCKETS],
            count: 0,
            min_ns: u64::MAX,
            max_ns: 0,
        }
    }

    pub fn observe(&mut self, ns: u64) {
        self.counts[bucket_index(ns)] += 1;
        self.count += 1;
        self.min_ns = self.min_ns.min(ns);
        self.max_ns = self.max_ns.max(ns);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn bucket_count(&self, b: usize) -> u64 {
        self.counts[b]
    }

    /// `{count, min_ns, max_ns, buckets: [[lo_ns, count], ...]}` with only
    /// the nonzero buckets listed (ascending by lower bound).
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("count", Json::Num(self.count as f64))
            .set(
                "min_ns",
                Json::Num(if self.count == 0 { 0.0 } else { self.min_ns as f64 }),
            )
            .set("max_ns", Json::Num(self.max_ns as f64));
        let buckets: Vec<Json> = self
            .counts
            .iter()
            .enumerate()
            .filter(|(_, c)| **c > 0)
            .map(|(b, c)| {
                Json::Arr(vec![Json::Num(bucket_lo(b) as f64), Json::Num(*c as f64)])
            })
            .collect();
        j.set("buckets", Json::Arr(buckets));
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_goes_to_bucket_zero() {
        assert_eq!(bucket_index(0), 0);
        let mut h = Histogram::new();
        h.observe(0);
        assert_eq!(h.bucket_count(0), 1);
        assert_eq!(h.count(), 1);
    }

    #[test]
    fn sub_bucket_values_land_in_first_real_bucket() {
        // 1 ns is the smallest nonzero observation: bucket 1 = [1, 2).
        assert_eq!(bucket_index(1), 1);
        let mut h = Histogram::new();
        h.observe(1);
        assert_eq!(h.bucket_count(1), 1);
    }

    #[test]
    fn exact_power_of_two_boundary_opens_the_next_bucket() {
        // Bucket b covers [2^(b-1), 2^b): the boundary value belongs to
        // the bucket it opens, not the one it closes.
        assert_eq!(bucket_index(1023), 10); // [512, 1024)
        assert_eq!(bucket_index(1024), 11); // [1024, 2048)
        assert_eq!(bucket_index(1025), 11);
    }

    #[test]
    fn overflow_clamps_to_last_bucket() {
        assert_eq!(bucket_index(u64::MAX), BUCKETS - 1);
        assert_eq!(bucket_index(1u64 << 63), BUCKETS - 1);
        let mut h = Histogram::new();
        h.observe(u64::MAX);
        assert_eq!(h.bucket_count(BUCKETS - 1), 1);
    }

    #[test]
    fn json_lists_only_nonzero_buckets() {
        let mut h = Histogram::new();
        h.observe(0);
        h.observe(3);
        h.observe(3);
        let j = h.to_json();
        assert_eq!(j.get("count").unwrap().as_f64(), Some(3.0));
        assert_eq!(j.get("min_ns").unwrap().as_f64(), Some(0.0));
        assert_eq!(j.get("max_ns").unwrap().as_f64(), Some(3.0));
        let buckets = match j.get("buckets").unwrap() {
            Json::Arr(v) => v.clone(),
            _ => panic!("buckets must be an array"),
        };
        assert_eq!(buckets.len(), 2); // bucket 0 and bucket [2,4)
        assert_eq!(buckets[1], Json::Arr(vec![Json::Num(2.0), Json::Num(2.0)]));
    }

    #[test]
    fn empty_histogram_serializes_cleanly() {
        let j = Histogram::new().to_json();
        assert_eq!(j.get("count").unwrap().as_f64(), Some(0.0));
        assert_eq!(j.get("min_ns").unwrap().as_f64(), Some(0.0));
    }
}
