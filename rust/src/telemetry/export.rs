//! Serialization of the two telemetry planes.
//!
//! `dagcloud.telemetry/v1` keeps them strictly separated: everything
//! under `deterministic` is a pure function of the run (byte-identical
//! across thread/shard counts), everything under `wall_clock` is
//! profiling data that may differ between runs and must never be copied
//! into another report. The Chrome trace export flattens the wall-clock
//! span occurrences into the trace-event JSON consumed by
//! `chrome://tracing` and Perfetto (`ph: "X"` complete events, µs
//! timestamps).

use crate::util::json::Json;

use super::event::{canonical_rows, SourceLog};
use super::span::SpanStats;

/// Per-source overflow past [`super::event::EVENT_CAP`], non-zero entries
/// only. Keys are source names, so the object is canonical (BTreeMap) and
/// byte-stable across flush order.
fn dropped_by_source(logs: &[SourceLog]) -> Json {
    let mut by_src = Json::obj();
    for l in logs {
        if l.dropped > 0 {
            by_src.set(&l.source, Json::Num(l.dropped as f64));
        }
    }
    by_src
}

/// Assemble the `dagcloud.telemetry/v1` document.
pub fn telemetry_doc(logs: &[SourceLog], spans: &SpanStats) -> Json {
    let rows = canonical_rows(logs);
    let events: Vec<Json> = rows.iter().map(|(src, e)| e.to_json(src)).collect();
    let dropped: u64 = logs.iter().map(|l| l.dropped).sum();

    let mut det = Json::obj();
    det.set("count", Json::Num(events.len() as f64))
        .set("dropped", Json::Num(dropped as f64))
        .set("dropped_by_source", dropped_by_source(logs))
        .set("sources", Json::Num(logs.len() as f64))
        .set("events", Json::Arr(events));

    let mut wall = Json::obj();
    wall.set("spans", spans.to_json())
        .set("trace_events", Json::Num(spans.trace_events().len() as f64))
        .set("trace_dropped", Json::Num(spans.trace_dropped() as f64));

    let mut doc = Json::obj();
    doc.set("schema", Json::Str("dagcloud.telemetry/v1".to_string()))
        .set("deterministic", det)
        .set("wall_clock", wall);
    doc
}

/// Just the deterministic section (used by the byte-identity tests:
/// comparing these bytes across `--threads`/shard counts must succeed,
/// which would be false for the full document's wall-clock half).
pub fn deterministic_doc(logs: &[SourceLog]) -> Json {
    let rows = canonical_rows(logs);
    let events: Vec<Json> = rows.iter().map(|(src, e)| e.to_json(src)).collect();
    let dropped: u64 = logs.iter().map(|l| l.dropped).sum();
    let mut det = Json::obj();
    det.set("count", Json::Num(events.len() as f64))
        .set("dropped", Json::Num(dropped as f64))
        .set("dropped_by_source", dropped_by_source(logs))
        .set("sources", Json::Num(logs.len() as f64))
        .set("events", Json::Arr(events));
    det
}

/// Chrome trace-event JSON (the `{"traceEvents": [...]}` object form,
/// which both `chrome://tracing` and Perfetto accept). One `ph: "X"`
/// complete event per recorded span occurrence.
pub fn chrome_trace(spans: &SpanStats) -> Json {
    let events: Vec<Json> = spans
        .trace_events()
        .iter()
        .map(|t| {
            let mut e = Json::obj();
            e.set("name", Json::Str(t.name.to_string()))
                .set("cat", Json::Str("dagcloud".to_string()))
                .set("ph", Json::Str("X".to_string()))
                .set("ts", Json::Num(t.ts_us))
                .set("dur", Json::Num(t.dur_us.max(0.001)))
                .set("pid", Json::Num(1.0))
                .set("tid", Json::Num(t.tid as f64));
            e
        })
        .collect();
    let mut doc = Json::obj();
    doc.set("traceEvents", Json::Arr(events))
        .set("displayTimeUnit", Json::Str("ms".to_string()));
    doc
}

#[cfg(test)]
mod tests {
    use super::super::event::{SimEvent, SimEventKind};
    use super::*;

    fn sample_logs() -> Vec<SourceLog> {
        vec![
            SourceLog {
                source: "b#0".into(),
                events: vec![SimEvent {
                    sim_time: 2.0,
                    seq: 0,
                    kind: SimEventKind::SweepBatch { retired: 3, specs: 5 },
                }],
                dropped: 1,
            },
            SourceLog {
                source: "a#0".into(),
                events: vec![SimEvent {
                    sim_time: 2.0,
                    seq: 0,
                    kind: SimEventKind::SpecChosen { job: 0, spec: 1 },
                }],
                dropped: 0,
            },
        ]
    }

    #[test]
    fn doc_bytes_are_independent_of_flush_order() {
        let logs = sample_logs();
        let mut rev = logs.clone();
        rev.reverse();
        // `sources` counts logs either way; event ordering is canonical.
        assert_eq!(
            deterministic_doc(&logs).pretty(),
            deterministic_doc(&rev).pretty()
        );
    }

    #[test]
    fn doc_has_schema_and_both_planes() {
        let doc = telemetry_doc(&sample_logs(), &SpanStats::default());
        assert_eq!(doc.get("schema").unwrap().as_str(), Some("dagcloud.telemetry/v1"));
        let det = doc.get("deterministic").unwrap();
        assert_eq!(det.get("count").unwrap().as_f64(), Some(2.0));
        assert_eq!(det.get("dropped").unwrap().as_f64(), Some(1.0));
        assert!(doc.get("wall_clock").unwrap().get("spans").is_some());
    }

    #[test]
    fn dropped_counts_are_exported_per_source() {
        // Only sources that actually overflowed appear; the exact count
        // survives even though the overflowing events themselves do not.
        let doc = deterministic_doc(&sample_logs());
        let by_src = doc.get("dropped_by_source").unwrap();
        assert_eq!(by_src.get("b#0").unwrap().as_f64(), Some(1.0));
        assert!(by_src.get("a#0").is_none());
    }

    #[test]
    fn chrome_trace_shape_is_loadable() {
        let mut spans = SpanStats::default();
        spans.record("sweep", 10.0, 2_000, 3);
        let doc = chrome_trace(&spans);
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 1);
        let e = &events[0];
        assert_eq!(e.get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(e.get("name").unwrap().as_str(), Some("sweep"));
        assert_eq!(e.get("ts").unwrap().as_f64(), Some(10.0));
        assert_eq!(e.get("dur").unwrap().as_f64(), Some(2.0));
        assert_eq!(e.get("pid").unwrap().as_f64(), Some(1.0));
        assert_eq!(e.get("tid").unwrap().as_f64(), Some(3.0));
        // Round-trips through the parser (valid JSON).
        let text = doc.pretty();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(parsed.get("traceEvents").unwrap().as_arr().unwrap().len(), 1);
    }
}
