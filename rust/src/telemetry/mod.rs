//! Observability layer: deterministic sim-time tracing + wall-clock
//! profiling, with a hard wall between the two planes.
//!
//! # Two planes, one contract
//!
//! - **Deterministic plane** ([`event`]): typed sim-time events emitted by
//!   the coordinator loops, router, sweep engine, fleet merge, and
//!   robustness gate. Events carry only simulation state and are keyed by
//!   `(sim_time, source, seq)`. Per-run-cell sources (`"{world}#{rep}"`)
//!   are pure functions of the run — byte-identical across `--threads`
//!   and shard counts; harness-level sources (`"fleet/merge"`,
//!   `"robustness/gate"`) are pure functions of the CLI invocation
//!   (property-tested in `tests/integration_telemetry.rs`).
//! - **Wall-clock plane** ([`span`], [`hist`]): RAII span guards feeding
//!   per-span totals, log-scale latency histograms, and a Chrome
//!   trace-event export. Inherently nondeterministic, and therefore
//!   quarantined: it is serialized only into `results/telemetry.json`
//!   (`dagcloud.telemetry/v1`, [`export`]) and `results/trace.json`, never
//!   into a scenario/fleet/robustness report.
//!
//! The headline invariant — enforced by test, not convention — is that
//! enabling telemetry changes **zero bytes** of `scenarios.json`,
//! `fleet.json`, and `robustness.json`.
//!
//! # No global state
//!
//! There is no global collector: a [`Telemetry`] handle is threaded
//! through `Config` → runner → fleet explicitly. Handles are cheap clones
//! sharing one sink (`Arc`), recorders are per-run-cell and merged on
//! flush, and `exec_pool` is untouched, so the worker-pool determinism
//! argument is exactly what it was before this module existed.

pub mod diff;
pub mod event;
pub mod export;
pub mod health;
pub mod hist;
pub mod log;
pub mod span;

pub use event::{Recorder, SimEvent, SimEventKind, SourceLog};
pub use health::HealthSection;
pub use hist::Histogram;
pub use log::{LogLevel, Logger};
pub use span::SpanGuard;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::util::json::Json;

use span::SpanStats;

/// Which planes to enable on a fresh handle.
#[derive(Debug, Clone, Copy, Default)]
pub struct TelemetryOptions {
    /// Deterministic sim-time event log (`--telemetry`).
    pub events: bool,
    /// Wall-clock span profiler (`--telemetry` / `--trace`).
    pub spans: bool,
    /// Status-logger verbosity (`-v` / `--quiet`).
    pub level: LogLevel,
}

/// Shared sink state behind a [`Telemetry`] handle.
#[derive(Debug)]
struct Planes {
    events_on: bool,
    spans_on: bool,
    epoch: Instant,
    sinks: Mutex<Vec<SourceLog>>,
    spans: Arc<Mutex<SpanStats>>,
    /// One warning per run when a source overflows `EVENT_CAP` — the
    /// per-source counts stay exact in `dropped_by_source`, but silent
    /// truncation of the stored events would be a trap.
    warned_event_drop: AtomicBool,
    /// Same, for the wall-clock plane's `TRACE_CAP`.
    warned_trace_drop: AtomicBool,
}

/// The telemetry handle threaded through `Config`/runner/fleet.
///
/// Clones share the same sinks, so a handle can be captured by parallel
/// scenario cells and flushed from each; with both planes disabled (the
/// default) every operation is a cheap no-op and the handle carries only
/// the status [`Logger`].
#[derive(Debug, Clone)]
pub struct Telemetry {
    log: Logger,
    planes: Option<Arc<Planes>>,
}

impl Default for Telemetry {
    fn default() -> Self {
        Telemetry::disabled()
    }
}

impl Telemetry {
    /// Both planes off; Info-level logger. The state every run starts in
    /// unless `--telemetry`/`--trace` is given.
    pub fn disabled() -> Telemetry {
        Telemetry { log: Logger::default(), planes: None }
    }

    pub fn new(opts: TelemetryOptions) -> Telemetry {
        let planes = (opts.events || opts.spans).then(|| {
            Arc::new(Planes {
                events_on: opts.events,
                spans_on: opts.spans,
                epoch: Instant::now(),
                sinks: Mutex::new(Vec::new()),
                spans: Arc::new(Mutex::new(SpanStats::default())),
                warned_event_drop: AtomicBool::new(false),
                warned_trace_drop: AtomicBool::new(false),
            })
        });
        Telemetry { log: Logger::new(opts.level), planes }
    }

    /// A disabled-planes handle with the given logger level.
    pub fn with_level(level: LogLevel) -> Telemetry {
        Telemetry { log: Logger::new(level), planes: None }
    }

    pub fn logger(&self) -> &Logger {
        &self.log
    }

    pub fn events_enabled(&self) -> bool {
        self.planes.as_ref().is_some_and(|p| p.events_on)
    }

    pub fn spans_enabled(&self) -> bool {
        self.planes.as_ref().is_some_and(|p| p.spans_on)
    }

    /// Either plane live (decides whether `telemetry.json` is written).
    pub fn enabled(&self) -> bool {
        self.planes.is_some()
    }

    /// A recorder for one run cell. `source` must be unique per cell
    /// within a batch (`"{scenario}#{replicate}"` by convention) so the
    /// canonical `(sim_time, source, seq)` sort is total.
    pub fn recorder(&self, source: &str) -> Recorder {
        Recorder::new(source, self.events_enabled())
    }

    /// Flush a finished recorder into the shared sink. Empty recorders
    /// from disabled runs are dropped silently; a recorder that overflowed
    /// [`event::EVENT_CAP`] warns once per run (counts stay exact in the
    /// exported `dropped_by_source`).
    pub fn absorb(&self, rec: Recorder) {
        if !rec.is_on() {
            return;
        }
        if let Some(p) = &self.planes {
            let log = rec.into_log();
            if log.dropped > 0 && !p.warned_event_drop.swap(true, Ordering::Relaxed) {
                self.log.warn(
                    "telemetry",
                    &format!(
                        "source '{}' overflowed the {}-event cap ({} dropped); \
                         counts stay exact in dropped_by_source, stored events are truncated",
                        log.source,
                        event::EVENT_CAP,
                        log.dropped
                    ),
                );
            }
            if let Ok(mut sinks) = p.sinks.lock() {
                sinks.push(log);
            }
        }
    }

    /// Start a wall-clock span. No-op guard when the span plane is off.
    pub fn span(&self, name: &'static str) -> SpanGuard {
        match &self.planes {
            Some(p) if p.spans_on => {
                SpanGuard::new(Some(p.spans.clone()), p.epoch, name)
            }
            _ => SpanGuard::disabled(),
        }
    }

    /// The full `dagcloud.telemetry/v1` document (both planes).
    pub fn telemetry_json(&self) -> Json {
        match &self.planes {
            Some(p) => {
                let sinks = p.sinks.lock().map(|s| s.clone()).unwrap_or_default();
                let spans = p.spans.lock().map(|s| s.clone()).unwrap_or_default();
                self.warn_trace_drops(p, &spans);
                export::telemetry_doc(&sinks, &spans)
            }
            None => export::telemetry_doc(&[], &SpanStats::default()),
        }
    }

    /// Warn once per run when the wall-clock plane hit `TRACE_CAP`
    /// (aggregate span stats stay exact; only trace events truncate).
    fn warn_trace_drops(&self, p: &Planes, spans: &SpanStats) {
        if spans.trace_dropped() > 0 && !p.warned_trace_drop.swap(true, Ordering::Relaxed) {
            self.log.warn(
                "telemetry",
                &format!(
                    "wall-clock trace overflowed the {}-occurrence cap ({} dropped); \
                     span totals stay exact, the Chrome trace is truncated",
                    span::TRACE_CAP,
                    spans.trace_dropped()
                ),
            );
        }
    }

    /// Just the deterministic event-log section (byte-stable across
    /// thread/shard counts — what the determinism property tests compare).
    pub fn deterministic_json(&self) -> Json {
        match &self.planes {
            Some(p) => {
                let sinks = p.sinks.lock().map(|s| s.clone()).unwrap_or_default();
                export::deterministic_doc(&sinks)
            }
            None => export::deterministic_doc(&[]),
        }
    }

    /// Chrome trace-event JSON for `chrome://tracing` / Perfetto.
    pub fn chrome_trace_json(&self) -> Json {
        match &self.planes {
            Some(p) => {
                let spans = p.spans.lock().map(|s| s.clone()).unwrap_or_default();
                self.warn_trace_drops(p, &spans);
                export::chrome_trace(&spans)
            }
            None => export::chrome_trace(&SpanStats::default()),
        }
    }

    /// The `dagcloud.health/v1` document: a pure fold of the current
    /// deterministic event log (see [`health`]). Byte-identical across
    /// `--threads` and shard counts because the fold only sees per-cell
    /// (`#`) sources and the event log itself is canonical.
    pub fn health_json(&self) -> Json {
        let det = self.deterministic_json();
        let sections = match health::events_of_doc(&det) {
            Some(events) => health::fold_events(events),
            None => Vec::new(),
        };
        health::health_doc(&sections)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_handle_is_inert() {
        let t = Telemetry::disabled();
        assert!(!t.enabled());
        assert!(!t.events_enabled());
        assert!(!t.spans_enabled());
        let mut r = t.recorder("x#0");
        r.emit(1.0, SimEventKind::FrontierAdvanced { slots: 3 });
        assert!(r.is_empty());
        t.absorb(r);
        drop(t.span("noop"));
        let det = t.deterministic_json();
        assert_eq!(det.get("count").unwrap().as_f64(), Some(0.0));
    }

    #[test]
    fn events_flow_into_the_deterministic_doc() {
        let t = Telemetry::new(TelemetryOptions {
            events: true,
            spans: false,
            level: LogLevel::Info,
        });
        let mut r = t.recorder("w#0");
        r.emit(1.0, SimEventKind::SpecChosen { job: 0, spec: 4 });
        r.emit(2.0, SimEventKind::SweepBatch { retired: 1, specs: 9 });
        t.absorb(r);
        let det = t.deterministic_json();
        assert_eq!(det.get("count").unwrap().as_f64(), Some(2.0));
        assert_eq!(det.get("sources").unwrap().as_f64(), Some(1.0));
        // Span plane stayed off.
        assert!(!t.spans_enabled());
        let full = t.telemetry_json();
        assert_eq!(
            full.get("wall_clock").unwrap().get("trace_events").unwrap().as_f64(),
            Some(0.0)
        );
    }

    #[test]
    fn clones_share_one_sink() {
        let t = Telemetry::new(TelemetryOptions {
            events: true,
            spans: true,
            level: LogLevel::Quiet,
        });
        let t2 = t.clone();
        let mut r = t2.recorder("a#0");
        r.emit(0.5, SimEventKind::FrontierAdvanced { slots: 8 });
        t2.absorb(r);
        {
            let _g = t2.span("shared");
        }
        let det = t.deterministic_json();
        assert_eq!(det.get("count").unwrap().as_f64(), Some(1.0));
        let full = t.telemetry_json();
        assert_eq!(
            full.get("wall_clock")
                .unwrap()
                .get("spans")
                .unwrap()
                .get("shared")
                .unwrap()
                .get("count")
                .unwrap()
                .as_f64(),
            Some(1.0)
        );
    }

    #[test]
    fn chrome_trace_json_is_valid_even_when_empty() {
        let t = Telemetry::disabled();
        let doc = t.chrome_trace_json();
        assert_eq!(doc.get("traceEvents").unwrap().as_arr().unwrap().len(), 0);
        assert!(Json::parse(&doc.pretty()).is_ok());
    }
}
