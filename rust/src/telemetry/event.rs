//! Deterministic plane: the sim-time event log.
//!
//! Events are keyed by `(sim_time, source, seq)` and carry **only**
//! simulation-derived data — no wall-clock timestamps, thread ids, or
//! iteration counts that could differ between runs. Each scenario cell
//! (or coordinator run) owns one [`Recorder`]; within a recorder `seq`
//! is the emission order of the single-threaded coordinator loop, so the
//! flattened, sorted log is a pure function of the run and byte-identical
//! across `--threads`, shard counts, and merge order.

use crate::util::json::Json;

/// Per-source event cap. A recorder past the cap stops storing events and
/// counts the overflow deterministically instead, so an enormous run
/// degrades to a truncated-but-still-deterministic log rather than
/// unbounded memory.
pub const EVENT_CAP: usize = 262_144;

/// A typed sim-time event. Fields are simulation state only.
#[derive(Debug, Clone, PartialEq)]
pub enum SimEventKind {
    /// The dealloc policy opened an execution window for a task.
    WindowOpened { job: usize, task: usize, start: f64, deadline: f64 },
    /// TOLA sampled a policy spec for an arriving job.
    SpecChosen { job: usize, spec: usize },
    /// The router placed a task window on an offer (`spilled` when the
    /// offer differs from the home offer 0).
    OfferRouted { job: usize, task: usize, offer: usize, spilled: bool },
    /// Spot capacity was exhausted on every feasible offer; the window
    /// ran all-on-demand.
    CapacityExhausted { job: usize, task: usize, offer: usize },
    /// Mid-window migration: an in-flight task moved to a cheaper feasible
    /// offer at a slot boundary (only emitted when the run's
    /// [`crate::policy::routing::MigrationPolicy`] is enabled).
    TaskMigrated { job: usize, task: usize, from_offer: usize, to_offer: usize },
    /// A retirement burst entered the counterfactual sweep engine.
    SweepBatch { retired: usize, specs: usize },
    /// The online feed frontier advanced to cover more slots.
    FrontierAdvanced { slots: usize },
    /// A bounded-retention residency guard passed: a read at `slot` found
    /// the earliest still-resident trace slot at `first_resident`
    /// (0 = nothing evicted yet). The margin `slot - first_resident` is
    /// the read's distance from an eviction near-miss.
    ResidencyProbe { slot: usize, first_resident: usize },
    /// Learned-parameter snapshot: max policy weight, current best
    /// policy, and realized average regret vs. the Prop. B.1 bound after
    /// `jobs` retirements.
    ParamSnapshot { jobs: usize, max_weight: f64, best_policy: String, regret: f64, bound: f64 },
    /// The fleet accumulator absorbed a shard report with `rows` cells.
    ReportAbsorbed { rows: usize },
    /// The robustness gate demoted a policy for failing `regime`.
    GateDemotion { policy: String, regime: String },
}

impl SimEventKind {
    /// Stable kind tag used in the serialized log.
    pub fn tag(&self) -> &'static str {
        match self {
            SimEventKind::WindowOpened { .. } => "window_opened",
            SimEventKind::SpecChosen { .. } => "spec_chosen",
            SimEventKind::OfferRouted { .. } => "offer_routed",
            SimEventKind::CapacityExhausted { .. } => "capacity_exhausted",
            SimEventKind::TaskMigrated { .. } => "task_migrated",
            SimEventKind::SweepBatch { .. } => "sweep_batch",
            SimEventKind::FrontierAdvanced { .. } => "frontier_advanced",
            SimEventKind::ResidencyProbe { .. } => "residency_probe",
            SimEventKind::ParamSnapshot { .. } => "param_snapshot",
            SimEventKind::ReportAbsorbed { .. } => "report_absorbed",
            SimEventKind::GateDemotion { .. } => "gate_demotion",
        }
    }

    fn fields(&self, j: &mut Json) {
        match self {
            SimEventKind::WindowOpened { job, task, start, deadline } => {
                j.set("job", Json::Num(*job as f64))
                    .set("task", Json::Num(*task as f64))
                    .set("start", Json::Num(*start))
                    .set("deadline", Json::Num(*deadline));
            }
            SimEventKind::SpecChosen { job, spec } => {
                j.set("job", Json::Num(*job as f64))
                    .set("spec", Json::Num(*spec as f64));
            }
            SimEventKind::OfferRouted { job, task, offer, spilled } => {
                j.set("job", Json::Num(*job as f64))
                    .set("task", Json::Num(*task as f64))
                    .set("offer", Json::Num(*offer as f64))
                    .set("spilled", Json::Bool(*spilled));
            }
            SimEventKind::CapacityExhausted { job, task, offer } => {
                j.set("job", Json::Num(*job as f64))
                    .set("task", Json::Num(*task as f64))
                    .set("offer", Json::Num(*offer as f64));
            }
            SimEventKind::TaskMigrated { job, task, from_offer, to_offer } => {
                j.set("job", Json::Num(*job as f64))
                    .set("task", Json::Num(*task as f64))
                    .set("from_offer", Json::Num(*from_offer as f64))
                    .set("to_offer", Json::Num(*to_offer as f64));
            }
            SimEventKind::SweepBatch { retired, specs } => {
                j.set("retired", Json::Num(*retired as f64))
                    .set("specs", Json::Num(*specs as f64));
            }
            SimEventKind::FrontierAdvanced { slots } => {
                j.set("slots", Json::Num(*slots as f64));
            }
            SimEventKind::ResidencyProbe { slot, first_resident } => {
                j.set("slot", Json::Num(*slot as f64))
                    .set("first_resident", Json::Num(*first_resident as f64));
            }
            SimEventKind::ParamSnapshot { jobs, max_weight, best_policy, regret, bound } => {
                j.set("jobs", Json::Num(*jobs as f64))
                    .set("max_weight", Json::Num(*max_weight))
                    .set("best_policy", Json::Str(best_policy.clone()))
                    .set("regret", Json::Num(*regret))
                    .set("bound", Json::Num(*bound));
            }
            SimEventKind::ReportAbsorbed { rows } => {
                j.set("rows", Json::Num(*rows as f64));
            }
            SimEventKind::GateDemotion { policy, regime } => {
                j.set("policy", Json::Str(policy.clone()))
                    .set("regime", Json::Str(regime.clone()));
            }
        }
    }
}

/// One event in a source's log.
#[derive(Debug, Clone, PartialEq)]
pub struct SimEvent {
    pub sim_time: f64,
    pub seq: u64,
    pub kind: SimEventKind,
}

impl SimEvent {
    /// Serialize with the owning source name attached (keys sort as
    /// `deadline, job, kind, seq, sim_time, source, ...` — `Json` objects
    /// are BTreeMap-backed, so field order is canonical automatically).
    pub fn to_json(&self, source: &str) -> Json {
        let mut j = Json::obj();
        j.set("sim_time", Json::Num(self.sim_time))
            .set("source", Json::Str(source.to_string()))
            .set("seq", Json::Num(self.seq as f64))
            .set("kind", Json::Str(self.kind.tag().to_string()));
        self.kind.fields(&mut j);
        j
    }
}

/// One source's completed event log, as flushed into the telemetry sinks.
#[derive(Debug, Clone, PartialEq)]
pub struct SourceLog {
    pub source: String,
    pub events: Vec<SimEvent>,
    /// Events past [`EVENT_CAP`] that were counted but not stored.
    pub dropped: u64,
}

/// Per-run event collector. Cheap to construct; `emit` is a no-op when
/// the deterministic plane is off, so instrumented loops pay one branch.
#[derive(Debug)]
pub struct Recorder {
    on: bool,
    source: String,
    seq: u64,
    events: Vec<SimEvent>,
    dropped: u64,
}

impl Recorder {
    pub fn new(source: &str, on: bool) -> Recorder {
        Recorder {
            on,
            source: source.to_string(),
            seq: 0,
            events: Vec::new(),
            dropped: 0,
        }
    }

    /// A recorder that records nothing (telemetry disabled).
    pub fn disabled() -> Recorder {
        Recorder::new("", false)
    }

    pub fn is_on(&self) -> bool {
        self.on
    }

    /// Record one event at `sim_time`. `seq` numbers every emission
    /// (including capped ones) in loop order.
    pub fn emit(&mut self, sim_time: f64, kind: SimEventKind) {
        if !self.on {
            return;
        }
        if self.events.len() < EVENT_CAP {
            self.events.push(SimEvent { sim_time, seq: self.seq, kind });
        } else {
            self.dropped += 1;
        }
        self.seq += 1;
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Consume the recorder into its finished log.
    pub fn into_log(self) -> SourceLog {
        SourceLog {
            source: self.source,
            events: self.events,
            dropped: self.dropped,
        }
    }
}

/// Flatten source logs into `(source, event)` rows sorted by the canonical
/// `(sim_time, source, seq)` key. Sources are unique per run cell, so the
/// key is total and the output is independent of flush order.
pub fn canonical_rows(logs: &[SourceLog]) -> Vec<(&str, &SimEvent)> {
    let mut rows: Vec<(&str, &SimEvent)> = logs
        .iter()
        .flat_map(|l| l.events.iter().map(move |e| (l.source.as_str(), e)))
        .collect();
    rows.sort_by(|a, b| {
        a.1.sim_time
            .total_cmp(&b.1.sim_time)
            .then_with(|| a.0.cmp(b.0))
            .then_with(|| a.1.seq.cmp(&b.1.seq))
    });
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_recorder_records_nothing() {
        let mut r = Recorder::disabled();
        r.emit(1.0, SimEventKind::SweepBatch { retired: 4, specs: 9 });
        assert!(r.is_empty());
        assert_eq!(r.into_log().dropped, 0);
    }

    #[test]
    fn seq_numbers_emission_order() {
        let mut r = Recorder::new("cell#0", true);
        r.emit(2.0, SimEventKind::SpecChosen { job: 0, spec: 3 });
        r.emit(1.0, SimEventKind::SpecChosen { job: 1, spec: 4 });
        let log = r.into_log();
        assert_eq!(log.events[0].seq, 0);
        assert_eq!(log.events[1].seq, 1);
        assert_eq!(log.source, "cell#0");
    }

    #[test]
    fn canonical_rows_sort_by_time_source_seq() {
        let a = SourceLog {
            source: "b#0".into(),
            events: vec![
                SimEvent { sim_time: 1.0, seq: 0, kind: SimEventKind::FrontierAdvanced { slots: 1 } },
                SimEvent { sim_time: 3.0, seq: 1, kind: SimEventKind::FrontierAdvanced { slots: 2 } },
            ],
            dropped: 0,
        };
        let b = SourceLog {
            source: "a#0".into(),
            events: vec![SimEvent {
                sim_time: 1.0,
                seq: 0,
                kind: SimEventKind::FrontierAdvanced { slots: 7 },
            }],
            dropped: 0,
        };
        // Flush order b-after-a vs a-after-b must not matter.
        let r1 = canonical_rows(&[a.clone(), b.clone()]);
        let r2 = canonical_rows(&[b, a]);
        let key = |rows: &Vec<(&str, &SimEvent)>| -> Vec<(String, f64, u64)> {
            rows.iter()
                .map(|(s, e)| (s.to_string(), e.sim_time, e.seq))
                .collect()
        };
        assert_eq!(key(&r1), key(&r2));
        assert_eq!(r1[0].0, "a#0"); // ties on sim_time break by source
        assert_eq!(r1[1].0, "b#0");
        assert_eq!(r1[2].1.sim_time, 3.0);
    }

    #[test]
    fn cap_counts_overflow_deterministically() {
        let mut r = Recorder::new("x", true);
        for i in 0..(EVENT_CAP + 5) {
            r.emit(i as f64, SimEventKind::FrontierAdvanced { slots: i });
        }
        let log = r.into_log();
        assert_eq!(log.events.len(), EVENT_CAP);
        assert_eq!(log.dropped, 5);
    }

    #[test]
    fn event_json_carries_kind_tag_and_fields() {
        let e = SimEvent {
            sim_time: 4.5,
            seq: 9,
            kind: SimEventKind::OfferRouted { job: 2, task: 1, offer: 3, spilled: true },
        };
        let j = e.to_json("paper-default#1");
        assert_eq!(j.get("kind").unwrap().as_str(), Some("offer_routed"));
        assert_eq!(j.get("source").unwrap().as_str(), Some("paper-default#1"));
        assert_eq!(j.get("offer").unwrap().as_f64(), Some(3.0));
        assert_eq!(j.get("spilled"), Some(&Json::Bool(true)));
    }
}
