//! Deterministic derivation operators: grow world populations from bases.
//!
//! Each operator maps `(base world, seed, index)` to a complete, valid
//! [`ScenarioSpec`]. Trace-resampling operators realize every flattened
//! offer of the base at a fixed reference horizon (the same per-offer
//! seed shape the runner uses), transform the realized prices, and embed
//! the result as an inline single-column replay CSV — so a derived world
//! is self-contained bytes inside the shard manifest and replays
//! identically on any shard, thread count, or machine.
//!
//! Determinism contract: the derivation seed is a pure function of
//! `(user seed, base name, operator id, index)` (the same FNV-1a →
//! SplitMix64 idiom as [`crate::scenario::derive_run_seed`]); every
//! random draw comes from one [`Pcg32`] stream seeded by it; prices are
//! serialized with Rust's shortest-roundtrip float formatting. Same
//! inputs → byte-identical derived spec (property-tested in
//! `rust/tests/integration_robustness.rs`).

use anyhow::{ensure, Result};

use crate::feed::{self, PriceEvent};
use crate::market::{PriceTrace, SLOTS_PER_UNIT};
use crate::scenario::runner::region_trace;
use crate::scenario::{MarketSpec, PriceSpec, ReplaySpec, RoutingSpec, ScenarioSpec};
use crate::util::rng::{Pcg32, SplitMix64};

use super::tag::{classify_trace, world_tags, SURGE_THRESHOLD};

/// One derivation operator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Operator {
    /// Resample multi-slot blocks of the realized trace (with
    /// replacement). Blocks preserve intra-block autocorrelation; block
    /// start fractions are shared across offers so cross-offer structure
    /// survives approximately.
    BlockBootstrap,
    /// Block bootstrap biased toward the base trace's *minority* regime
    /// (calm or surge blocks, whichever is rarer) — amplifies the regime
    /// the base rarely shows so the gate sees it often.
    RegimeOversample,
    /// Multiply a few random windows of the realized trace by a spike
    /// factor: sudden surge stress. Tagged `fault`.
    PriceSpike,
    /// Shrink every finite per-offer spot capacity: contention stress.
    /// Applicable only to capacity-aware worlds (arbitrage routing
    /// requires infinite capacities). Tagged `fault`.
    CapacityDropout,
    /// Replay the realized trace through [`crate::feed::FeedBuffer`] with
    /// event gaps punched out — the previous price holds across each gap,
    /// the step-function semantics of a stalled feed. Tagged `fault`.
    FeedGap,
}

impl Operator {
    /// Every operator, in canonical dealing order.
    pub fn all() -> &'static [Operator] {
        &[
            Operator::BlockBootstrap,
            Operator::RegimeOversample,
            Operator::PriceSpike,
            Operator::CapacityDropout,
            Operator::FeedGap,
        ]
    }

    /// Stable short id — part of derived-world names and the derivation
    /// seed, so renaming an operator is a determinism break.
    pub fn id(&self) -> &'static str {
        match self {
            Operator::BlockBootstrap => "boot",
            Operator::RegimeOversample => "oversample",
            Operator::PriceSpike => "spike",
            Operator::CapacityDropout => "capdrop",
            Operator::FeedGap => "gap",
        }
    }

    /// Can this operator derive anything meaningful from `base`?
    pub fn applicable(&self, base: &ScenarioSpec) -> bool {
        match self {
            Operator::CapacityDropout => {
                base.market.routing != RoutingSpec::Arbitrage
                    && base
                        .market
                        .flattened_offers()
                        .iter()
                        .any(|o| o.capacity.is_some())
            }
            _ => true,
        }
    }
}

/// Knobs shared by every operator. The defaults are what `repro
/// robustness` uses; the CLI exposes `--block-slots`.
#[derive(Debug, Clone, PartialEq)]
pub struct DeriveParams {
    /// Bootstrap block length in slots (default 24 = two simulated units
    /// on the 1/12 grid — long enough to hold a surge onset together).
    pub block_slots: usize,
    /// Horizon (simulated units) at which base traces are realized before
    /// resampling. Derived replay specs tile past it at run time.
    pub reference_horizon: f64,
    /// Price multiplier inside spike windows.
    pub spike_factor: f64,
    /// Spike windows per derived world.
    pub spikes: usize,
    /// Spike window length in simulated units.
    pub spike_units: f64,
    /// Feed-gap windows per derived world.
    pub gaps: usize,
    /// Feed-gap length in simulated units.
    pub gap_units: f64,
    /// Probability an oversampled block is drawn from the minority-regime
    /// pool (the rest draw from all blocks).
    pub oversample_bias: f64,
}

impl Default for DeriveParams {
    fn default() -> DeriveParams {
        DeriveParams {
            block_slots: 24,
            reference_horizon: 48.0,
            spike_factor: 2.5,
            spikes: 3,
            spike_units: 2.0,
            gaps: 2,
            gap_units: 4.0,
            oversample_bias: 0.75,
        }
    }
}

impl DeriveParams {
    pub fn validate(&self) -> Result<()> {
        ensure!(self.block_slots >= 1, "derive: block_slots must be >= 1");
        ensure!(
            self.reference_horizon > 0.0,
            "derive: reference_horizon must be positive"
        );
        ensure!(
            self.spike_factor.is_finite() && self.spike_factor > 0.0,
            "derive: spike_factor must be positive"
        );
        ensure!(
            self.spike_units > 0.0 && self.gap_units > 0.0,
            "derive: window lengths must be positive"
        );
        ensure!(
            (0.0..=1.0).contains(&self.oversample_bias),
            "derive: oversample_bias must be in [0, 1]"
        );
        Ok(())
    }
}

/// Deterministic derivation seed: FNV-1a over `base \0 op` folded with
/// the user seed and the per-pair index through SplitMix64 — the same
/// idiom as [`crate::scenario::derive_run_seed`], so nearby indices give
/// unrelated streams.
pub fn derivation_seed(seed: u64, base: &str, op: &str, index: u64) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in base.bytes().chain(std::iter::once(0u8)).chain(op.bytes()) {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    let mut sm = SplitMix64::new(
        h ^ seed.rotate_left(17) ^ index.wrapping_mul(0xA24B_AED4_963E_E407),
    );
    sm.next_u64()
}

/// Serialize a realized trace as the repo's single-column replay CSV
/// (one price per slot on the 1/12 grid). Rust's float `Display` is
/// shortest-roundtrip, so the bytes are a pure function of the prices.
fn trace_to_csv(trace: &PriceTrace) -> String {
    let mut s = String::with_capacity(trace.num_slots() * 8);
    for i in 0..trace.num_slots() {
        s.push_str(&format!("{}\n", trace.price_of_slot(i)));
    }
    s
}

/// Rebuild a market with each flattened offer's price spec replaced, in
/// flattened-offer order.
fn replace_offer_prices(market: &MarketSpec, prices: Vec<PriceSpec>) -> MarketSpec {
    let mut out = market.clone();
    let mut it = prices.into_iter();
    for r in &mut out.regions {
        r.price = it.next().expect("offer count mismatch");
        for t in &mut r.instance_types {
            t.price = it.next().expect("offer count mismatch");
        }
    }
    debug_assert!(it.next().is_none(), "offer count mismatch");
    out
}

/// Realize every flattened offer's base trace at the reference horizon,
/// with the runner's per-offer seed shape so offer `k` of the derived
/// world resamples what offer `k` of a real run would see.
fn realize_offers(base: &ScenarioSpec, horizon: f64, dseed: u64) -> Result<Vec<PriceTrace>> {
    base.market
        .flattened_offers()
        .iter()
        .enumerate()
        .map(|(k, o)| region_trace(&o.price, horizon, dseed ^ ((k as u64 + 1) << 8)))
        .collect()
}

/// Resample `base` into blocks chosen by shared start fractions. Each
/// fraction maps to a start slot within this trace's valid range, so
/// offers of different lengths stay aligned in *relative* time.
fn resample_blocks(base: &[f64], block: usize, fracs: &[f64]) -> Vec<f64> {
    let n = base.len();
    let bs = block.min(n).max(1);
    let max_start = n - bs;
    let mut out = Vec::with_capacity(n);
    for f in fracs {
        if out.len() >= n {
            break;
        }
        let start = ((f * (max_start as f64 + 1.0)) as usize).min(max_start);
        let take = bs.min(n - out.len());
        out.extend_from_slice(&base[start..start + take]);
    }
    out
}

/// Block index pools by regime: (calm blocks, surge blocks), classified
/// by block mean price against [`SURGE_THRESHOLD`].
fn regime_pools(base: &[f64], block: usize) -> (Vec<usize>, Vec<usize>) {
    let n = base.len();
    let bs = block.min(n).max(1);
    let mut calm = Vec::new();
    let mut surge = Vec::new();
    let mut b = 0usize;
    let mut s = 0usize;
    while s < n {
        let end = (s + bs).min(n);
        let mean: f64 = base[s..end].iter().sum::<f64>() / (end - s) as f64;
        if mean >= SURGE_THRESHOLD {
            surge.push(b);
        } else {
            calm.push(b);
        }
        b += 1;
        s = end;
    }
    (calm, surge)
}

/// Oversample toward the minority regime using shared draw decisions:
/// each `(minority?, fraction)` pair picks a block index from the chosen
/// pool. Falls back to plain bootstrap when the base never leaves one
/// regime.
fn oversample_blocks(base: &[f64], block: usize, picks: &[(bool, f64)]) -> Vec<f64> {
    let n = base.len();
    let bs = block.min(n).max(1);
    let (calm, surge) = regime_pools(base, bs);
    let minority: &[usize] = if calm.is_empty() || surge.is_empty() {
        &[]
    } else if surge.len() <= calm.len() {
        &surge
    } else {
        &calm
    };
    let total_blocks = (n + bs - 1) / bs;
    let mut out = Vec::with_capacity(n);
    for (want_minority, f) in picks {
        if out.len() >= n {
            break;
        }
        let b = if *want_minority && !minority.is_empty() {
            minority[((f * minority.len() as f64) as usize).min(minority.len() - 1)]
        } else {
            ((f * total_blocks as f64) as usize).min(total_blocks - 1)
        };
        let s = b * bs;
        let end = (s + bs).min(n);
        let take = (end - s).min(n - out.len());
        out.extend_from_slice(&base[s..s + take]);
    }
    out
}

/// Derive one world. `index` is the per-`(base, operator)` replica
/// counter; `seed` is the population seed shared by the whole derivation.
pub fn derive_world(
    base: &ScenarioSpec,
    op: Operator,
    index: u64,
    seed: u64,
    p: &DeriveParams,
) -> Result<ScenarioSpec> {
    p.validate()?;
    base.validate()?;
    ensure!(
        op.applicable(base),
        "derive: operator '{}' is not applicable to world '{}'",
        op.id(),
        base.name
    );
    let dseed = derivation_seed(seed, &base.name, op.id(), index);
    let mut rng = Pcg32::new(dseed);
    let slot_len = 1.0 / SLOTS_PER_UNIT as f64;

    let mut derived = base.clone();
    derived.name = format!("{}~{}-{:03}", base.name, op.id(), index);
    derived.description = format!(
        "derived from '{}' by {} (replica {index})",
        base.name,
        op.id()
    );

    let mut tags: Vec<String> = Vec::new();
    match op {
        Operator::CapacityDropout => {
            // Shrink every finite capacity by an independent keep
            // fraction; at least one instance always survives.
            let shrink = |cap: &mut Option<u32>, rng: &mut Pcg32| {
                if let Some(c) = cap {
                    let keep = rng.uniform(0.3, 0.8);
                    *c = ((*c as f64 * keep).floor() as u32).max(1);
                }
            };
            for r in &mut derived.market.regions {
                shrink(&mut r.capacity, &mut rng);
                for t in &mut r.instance_types {
                    shrink(&mut t.capacity, &mut rng);
                }
            }
            tags.extend(world_tags(base)?);
            tags.push("fault".into());
        }
        Operator::BlockBootstrap | Operator::RegimeOversample => {
            let traces = realize_offers(base, p.reference_horizon, dseed)?;
            let max_blocks = traces
                .iter()
                .map(|t| {
                    let n = t.num_slots();
                    let bs = p.block_slots.min(n).max(1);
                    (n + bs - 1) / bs
                })
                .max()
                .unwrap_or(0);
            ensure!(max_blocks > 0, "derive: world '{}' realized no slots", base.name);
            // One shared draw per output block keeps offers aligned.
            let picks: Vec<(bool, f64)> = (0..max_blocks)
                .map(|_| (rng.f64() < p.oversample_bias, rng.f64()))
                .collect();
            let prices: Vec<PriceSpec> = traces
                .iter()
                .map(|t| {
                    let src: Vec<f64> =
                        (0..t.num_slots()).map(|i| t.price_of_slot(i)).collect();
                    let out = match op {
                        Operator::BlockBootstrap => {
                            let fracs: Vec<f64> =
                                picks.iter().map(|(_, f)| *f).collect();
                            resample_blocks(&src, p.block_slots, &fracs)
                        }
                        _ => oversample_blocks(&src, p.block_slots, &picks),
                    };
                    let derived_trace = PriceTrace::from_prices(out, slot_len);
                    tags.extend(
                        classify_trace(&derived_trace).iter().map(|t| t.to_string()),
                    );
                    PriceSpec::Replay(ReplaySpec::inline(&trace_to_csv(&derived_trace)))
                })
                .collect();
            derived.market = replace_offer_prices(&base.market, prices);
        }
        Operator::PriceSpike => {
            let traces = realize_offers(base, p.reference_horizon, dseed)?;
            let spike_slots = ((p.spike_units * SLOTS_PER_UNIT as f64).round() as usize).max(1);
            // Shared window fractions and jittered factors across offers:
            // a spike is a market event, not a per-offer one.
            let windows: Vec<(f64, f64)> = (0..p.spikes)
                .map(|_| (rng.f64(), p.spike_factor * rng.uniform(0.8, 1.2)))
                .collect();
            let prices: Vec<PriceSpec> = traces
                .iter()
                .map(|t| {
                    let mut src: Vec<f64> =
                        (0..t.num_slots()).map(|i| t.price_of_slot(i)).collect();
                    let n = src.len();
                    for (f, factor) in &windows {
                        let start =
                            ((f * n as f64) as usize).min(n.saturating_sub(1));
                        for v in src.iter_mut().skip(start).take(spike_slots) {
                            *v *= factor;
                        }
                    }
                    let derived_trace = PriceTrace::from_prices(src, slot_len);
                    tags.extend(
                        classify_trace(&derived_trace).iter().map(|t| t.to_string()),
                    );
                    PriceSpec::Replay(ReplaySpec::inline(&trace_to_csv(&derived_trace)))
                })
                .collect();
            derived.market = replace_offer_prices(&base.market, prices);
            tags.push("fault".into());
        }
        Operator::FeedGap => {
            let traces = realize_offers(base, p.reference_horizon, dseed)?;
            let gap_slots = ((p.gap_units * SLOTS_PER_UNIT as f64).round() as usize).max(1);
            let starts: Vec<f64> = (0..p.gaps).map(|_| rng.f64()).collect();
            let prices: Vec<PriceSpec> = traces
                .iter()
                .map(|t| {
                    let n = t.num_slots();
                    let in_gap = |slot: usize| {
                        starts.iter().any(|f| {
                            let s = ((f * n as f64) as usize).min(n.saturating_sub(1));
                            slot > 0 && slot >= s && slot < s + gap_slots
                        })
                    };
                    // Slot 0 always survives so the buffer has an origin
                    // price; inside a gap the previous price holds — the
                    // feed layer's step-function semantics, exercised for
                    // real through FeedBuffer.
                    let events: Vec<PriceEvent> = (0..n)
                        .filter(|&i| !in_gap(i))
                        .map(|i| PriceEvent {
                            time: i as f64 * slot_len,
                            price: t.price_of_slot(i),
                        })
                        .collect();
                    let derived_trace = feed::events_to_trace(&events, slot_len)?;
                    tags.extend(
                        classify_trace(&derived_trace).iter().map(|t| t.to_string()),
                    );
                    Ok(PriceSpec::Replay(ReplaySpec::inline(&trace_to_csv(
                        &derived_trace,
                    ))))
                })
                .collect::<Result<_>>()?;
            derived.market = replace_offer_prices(&base.market, prices);
            tags.push("fault".into());
        }
    }

    tags.sort_unstable();
    tags.dedup();
    derived.tags = tags;
    derived.validate()?;
    Ok(derived)
}

/// Derive a population of `total` worlds by dealing replicas round-robin
/// over every `(base, applicable operator)` pair in declared order. Pure
/// function of `(bases, total, seed, params)` — byte-identical specs on
/// every call.
pub fn derive_population(
    bases: &[ScenarioSpec],
    total: usize,
    seed: u64,
    p: &DeriveParams,
) -> Result<Vec<ScenarioSpec>> {
    ensure!(!bases.is_empty(), "derive: no base worlds");
    p.validate()?;
    let pairs: Vec<(usize, Operator)> = pair_list(bases);
    ensure!(!pairs.is_empty(), "derive: no applicable (base, operator) pairs");
    let mut local = vec![0u64; pairs.len()];
    let mut out = Vec::with_capacity(total);
    for i in 0..total {
        let slot = i % pairs.len();
        let (bi, op) = pairs[slot];
        out.push(derive_world(&bases[bi], op, local[slot], seed, p)?);
        local[slot] += 1;
    }
    Ok(out)
}

/// The `(base, operator)` dealing order: bases in declared order, each
/// crossed with every applicable operator in canonical order.
fn pair_list(bases: &[ScenarioSpec]) -> Vec<(usize, Operator)> {
    let mut pairs = Vec::new();
    for (bi, b) in bases.iter().enumerate() {
        for op in Operator::all() {
            if op.applicable(b) {
                pairs.push((bi, *op));
            }
        }
    }
    pairs
}

/// How many worlds each `(base, operator)` pair would receive when
/// deriving `total` worlds — what `repro scenarios --list --derive N`
/// prints. Same dealing as [`derive_population`], without deriving.
pub fn derivation_plan(bases: &[ScenarioSpec], total: usize) -> Vec<(String, &'static str, usize)> {
    let pairs = pair_list(bases);
    let mut counts = vec![0usize; pairs.len()];
    if !pairs.is_empty() {
        for i in 0..total {
            counts[i % pairs.len()] += 1;
        }
    }
    pairs
        .into_iter()
        .zip(counts)
        .map(|((bi, op), n)| (bases[bi].name.clone(), op.id(), n))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::registry;

    fn base(name: &str) -> ScenarioSpec {
        registry::find(name).unwrap()
    }

    #[test]
    fn derivation_is_a_pure_function_of_its_inputs() {
        let b = base("paper-default");
        let p = DeriveParams::default();
        let a1 = derive_world(&b, Operator::BlockBootstrap, 3, 42, &p).unwrap();
        let a2 = derive_world(&b, Operator::BlockBootstrap, 3, 42, &p).unwrap();
        assert_eq!(a1, a2);
        assert_eq!(a1.to_json().pretty(), a2.to_json().pretty());
        assert_eq!(a1.name, "paper-default~boot-003");
        // Different index or seed -> different resample.
        let b1 = derive_world(&b, Operator::BlockBootstrap, 4, 42, &p).unwrap();
        let c1 = derive_world(&b, Operator::BlockBootstrap, 3, 43, &p).unwrap();
        assert_ne!(a1.market, b1.market);
        assert_ne!(a1.market, c1.market);
    }

    #[test]
    fn derived_worlds_are_valid_inline_replays() {
        let b = base("calm-surge-markov");
        let p = DeriveParams::default();
        for op in [
            Operator::BlockBootstrap,
            Operator::RegimeOversample,
            Operator::PriceSpike,
            Operator::FeedGap,
        ] {
            let d = derive_world(&b, op, 0, 7, &p).unwrap();
            d.validate().unwrap();
            for o in d.market.flattened_offers() {
                match o.price {
                    PriceSpec::Replay(r) => assert!(r.csv.is_some(), "inline csv"),
                    other => panic!("{}: expected replay, got {other:?}", op.id()),
                }
            }
            assert!(!d.tags.is_empty(), "{}: derived world untagged", op.id());
        }
    }

    #[test]
    fn bootstrap_preserves_price_support() {
        let b = base("paper-default");
        let p = DeriveParams::default();
        let dseed = derivation_seed(9, &b.name, "boot", 0);
        let src = realize_offers(&b, p.reference_horizon, dseed).unwrap();
        let (lo, hi) = (0..src[0].num_slots())
            .map(|i| src[0].price_of_slot(i))
            .fold((f64::INFINITY, f64::NEG_INFINITY), |(l, h), v| {
                (l.min(v), h.max(v))
            });
        let d = derive_world(&b, Operator::BlockBootstrap, 0, 9, &p).unwrap();
        let trace = region_trace(&d.market.regions[0].price, p.reference_horizon, 0).unwrap();
        for i in 0..trace.num_slots() {
            let v = trace.price_of_slot(i);
            assert!(v >= lo - 1e-12 && v <= hi + 1e-12, "resampled price off-support");
        }
    }

    #[test]
    fn capacity_dropout_applies_only_to_capacity_aware_worlds() {
        assert!(Operator::CapacityDropout.applicable(&base("capacity-crunch")));
        assert!(!Operator::CapacityDropout.applicable(&base("paper-default")));
        assert!(!Operator::CapacityDropout.applicable(&base("multi-region-arbitrage")));
        let b = base("capacity-crunch");
        let d = derive_world(&b, Operator::CapacityDropout, 0, 5, &DeriveParams::default())
            .unwrap();
        for (orig, derived) in b
            .market
            .flattened_offers()
            .iter()
            .zip(d.market.flattened_offers())
        {
            match (orig.capacity, derived.capacity) {
                (Some(o), Some(n)) => assert!(n >= 1 && n <= o, "cap {o} -> {n}"),
                (None, None) => {}
                other => panic!("capacity shape changed: {other:?}"),
            }
            // Price processes untouched.
            assert_eq!(orig.price, derived.price);
        }
        assert!(d.tags.iter().any(|t| t == "fault"));
    }

    #[test]
    fn fault_operators_tag_fault_and_spikes_raise_prices() {
        let b = base("paper-default");
        let p = DeriveParams::default();
        let spiked = derive_world(&b, Operator::PriceSpike, 0, 11, &p).unwrap();
        assert!(spiked.tags.iter().any(|t| t == "fault"));
        let gapped = derive_world(&b, Operator::FeedGap, 0, 11, &p).unwrap();
        assert!(gapped.tags.iter().any(|t| t == "fault"));
        // Spike windows multiply the realized base prices by >= 2x
        // (spike_factor 2.5 jittered by [0.8, 1.2]); every other slot is
        // bit-identical after the CSV round-trip.
        let dseed = derivation_seed(11, &b.name, "spike", 0);
        let src = &realize_offers(&b, p.reference_horizon, dseed).unwrap()[0];
        let spiked_trace =
            region_trace(&spiked.market.regions[0].price, p.reference_horizon, 0).unwrap();
        assert_eq!(spiked_trace.num_slots(), src.num_slots());
        let mut spiked_slots = 0usize;
        for i in 0..src.num_slots() {
            let (s, v) = (src.price_of_slot(i), spiked_trace.price_of_slot(i));
            if v > s * 1.5 {
                spiked_slots += 1;
            } else {
                assert_eq!(s, v, "slot {i} neither spiked nor preserved");
            }
        }
        assert!(spiked_slots >= 1, "no slot was spiked");
    }

    #[test]
    fn population_deals_round_robin_with_unique_names() {
        let bases = vec![base("paper-default"), base("capacity-crunch")];
        let pop = derive_population(&bases, 19, 123, &DeriveParams::default()).unwrap();
        assert_eq!(pop.len(), 19);
        let mut names: Vec<&str> = pop.iter().map(|s| s.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), 19, "derived names must be unique");
        // paper-default skips capdrop (no finite caps): 4 ops; crunch: 5.
        let plan = derivation_plan(&bases, 19);
        assert_eq!(plan.len(), 9);
        assert_eq!(plan.iter().map(|(_, _, n)| n).sum::<usize>(), 19);
        assert!(plan
            .iter()
            .all(|(b, op, _)| !(b == "paper-default" && *op == "capdrop")));
        // The population is itself reproducible.
        let pop2 = derive_population(&bases, 19, 123, &DeriveParams::default()).unwrap();
        assert_eq!(pop, pop2);
    }
}
