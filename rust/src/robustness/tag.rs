//! Regime tagging: classify worlds calm / surge for the promotion gate.
//!
//! Explicit [`ScenarioSpec::tags`] always win — the registry hand-tags
//! its worlds, and derivation operators tag what they produce. This
//! module supplies the fallback for untagged specs (e.g. user-supplied
//! files): a structural classification of the world's price processes.
//! The classification is a pure function of the spec, never of a
//! realized run, so tagging cannot perturb report bytes.

use anyhow::Result;

use crate::market::{PriceTrace, SpotModel};
use crate::scenario::runner::region_trace;
use crate::scenario::{PriceSpec, ScenarioSpec};

/// Normalized-price threshold separating calm from surge regimes. The
/// registry's calm processes sit near the paper's 0.13 mean and its surge
/// bands near 0.55, so the midpoint-ish 0.4 splits them with margin.
pub const SURGE_THRESHOLD: f64 = 0.4;

/// Horizon (simulated units) at which replayed traces are materialized
/// for classification — long enough to see the sample traces' surge
/// windows, short enough to stay cheap.
const CLASSIFY_HORIZON: f64 = 48.0;

/// Slots per classification block (one simulated unit on the 1/12 grid).
const BLOCK: usize = 12;

/// Regime tags a synthetic price model can realize.
pub fn classify_model(m: &SpotModel) -> Vec<&'static str> {
    match m {
        SpotModel::BoundedExp { mean, .. } => {
            if *mean >= SURGE_THRESHOLD {
                vec!["surge"]
            } else {
                vec!["calm"]
            }
        }
        SpotModel::Markov {
            calm_mean,
            surge_mean,
            ..
        } => {
            let mut tags = Vec::new();
            if *calm_mean < SURGE_THRESHOLD || *surge_mean < SURGE_THRESHOLD {
                tags.push("calm");
            }
            if *calm_mean >= SURGE_THRESHOLD || *surge_mean >= SURGE_THRESHOLD {
                tags.push("surge");
            }
            tags
        }
        SpotModel::GoogleFixed { price, .. } => {
            if *price >= SURGE_THRESHOLD {
                vec!["surge"]
            } else {
                vec!["calm"]
            }
        }
    }
}

/// Regime tags realized by a concrete trace: block (one-unit) mean prices
/// below the threshold yield `calm`, at or above it `surge`.
pub fn classify_trace(trace: &PriceTrace) -> Vec<&'static str> {
    let n = trace.num_slots();
    let mut calm = false;
    let mut surge = false;
    let mut s = 0;
    while s < n {
        let end = (s + BLOCK).min(n);
        let mean: f64 =
            (s..end).map(|i| trace.price_of_slot(i)).sum::<f64>() / (end - s) as f64;
        if mean >= SURGE_THRESHOLD {
            surge = true;
        } else {
            calm = true;
        }
        s = end;
    }
    let mut tags = Vec::new();
    if calm {
        tags.push("calm");
    }
    if surge {
        tags.push("surge");
    }
    tags
}

/// The world's regime tags: the spec's explicit tags if present,
/// otherwise a structural classification over every flattened offer's
/// price process (sorted, deduplicated). Replayed offers are materialized
/// at a short horizon (replay realization ignores the seed).
pub fn world_tags(spec: &ScenarioSpec) -> Result<Vec<String>> {
    if !spec.tags.is_empty() {
        return Ok(spec.tags.clone());
    }
    let mut tags: Vec<&'static str> = Vec::new();
    for offer in spec.market.flattened_offers() {
        let offer_tags = match &offer.price {
            PriceSpec::Model(m) => classify_model(m),
            PriceSpec::Regimes(segments) => {
                let mut t = Vec::new();
                for (_, m) in segments {
                    t.extend(classify_model(m));
                }
                t
            }
            PriceSpec::Replay(_) => {
                classify_trace(&region_trace(&offer.price, CLASSIFY_HORIZON, 0)?)
            }
        };
        tags.extend(offer_tags);
    }
    tags.sort_unstable();
    tags.dedup();
    Ok(tags.into_iter().map(String::from).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::registry;

    #[test]
    fn models_classify_by_mean_price() {
        assert_eq!(classify_model(&SpotModel::paper_default()), vec!["calm"]);
        assert_eq!(
            classify_model(&SpotModel::BoundedExp { mean: 0.55, lo: 0.12, hi: 1.0 }),
            vec!["surge"]
        );
        assert_eq!(
            classify_model(&SpotModel::Markov {
                calm_mean: 0.13,
                surge_mean: 0.6,
                lo: 0.12,
                hi: 1.0,
                p_calm_to_surge: 0.05,
                p_surge_to_calm: 0.2,
            }),
            vec!["calm", "surge"]
        );
        assert_eq!(
            classify_model(&SpotModel::GoogleFixed { price: 0.2, availability: 0.9 }),
            vec!["calm"]
        );
    }

    #[test]
    fn traces_classify_by_block_means() {
        let calm = PriceTrace::from_prices(vec![0.13; 36], 1.0 / 12.0);
        assert_eq!(classify_trace(&calm), vec!["calm"]);
        let mut prices = vec![0.13; 24];
        prices.extend(vec![0.8; 12]);
        let mixed = PriceTrace::from_prices(prices, 1.0 / 12.0);
        assert_eq!(classify_trace(&mixed), vec!["calm", "surge"]);
    }

    #[test]
    fn explicit_spec_tags_win_and_untagged_specs_fall_back_to_structure() {
        // Registry worlds carry explicit tags.
        let world = registry::find("calm-surge-markov").unwrap();
        assert_eq!(world_tags(&world).unwrap(), world.tags);
        // Stripping the tags falls back to the structural classification,
        // which agrees for the Markov world.
        let mut stripped = world;
        stripped.tags.clear();
        assert_eq!(
            world_tags(&stripped).unwrap(),
            vec!["calm".to_string(), "surge".to_string()]
        );
        let mut calm_only = registry::find("paper-default").unwrap();
        calm_only.tags.clear();
        assert_eq!(world_tags(&calm_only).unwrap(), vec!["calm".to_string()]);
    }

    #[test]
    fn replayed_worlds_classify_from_the_materialized_trace() {
        let mut replayed = registry::find("replayed-trace").unwrap();
        replayed.tags.clear();
        // The sample CSV has calm stretches and two surge windows.
        assert_eq!(
            world_tags(&replayed).unwrap(),
            vec!["calm".to_string(), "surge".to_string()]
        );
    }
}
