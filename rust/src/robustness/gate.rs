//! The cross-regime promotion gate.
//!
//! The fleet layer's robustness section ranks policies by worst-case and
//! tail statistics pooled over *all* worlds — but a pooled mean can hide
//! a regime-shaped hole: a policy that is excellent in the many calm
//! worlds and terrible in the few surge ones still looks fine on
//! average. The gate closes that hole: group worlds by regime tag
//! ([`crate::scenario::ScenarioSpec::tags`], `untagged` as the catch-all
//! group), compute each policy's difficulty-weighted mean regret/bound
//! ratio *per regime*, and promote a policy only if it is fully covered
//! and clears the threshold in **every** regime. The verdict records the
//! pooled-mean result too, so "passes on mean, demoted by the gate" is
//! visible in the report rather than silently corrected.
//!
//! Every statistic reuses [`crate::fleet::robustness::world_table`] —
//! the gate and the fleet ranking cannot disagree on a ratio.

use std::collections::{BTreeMap, BTreeSet};

use crate::fleet::robustness::{world_table, WorldStat};
use crate::scenario::ScenarioOutcome;
use crate::util::json::Json;

/// Regime group name for worlds with no tags.
pub const UNTAGGED: &str = "untagged";

/// Gate knobs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GateConfig {
    /// Max bound-normalized mean regret ratio a policy may show in any
    /// regime and still be promoted. The default 0.25 means: within a
    /// regime, the policy's average fixed-policy regret stays below a
    /// quarter of the Prop. B.1 online-learning budget.
    pub threshold: f64,
}

impl Default for GateConfig {
    fn default() -> GateConfig {
        GateConfig { threshold: 0.25 }
    }
}

/// One policy's standing inside one regime group.
#[derive(Debug, Clone, PartialEq)]
pub struct RegimeScore {
    pub tag: String,
    /// Worlds in this regime group.
    pub worlds: usize,
    /// Worlds in this group the policy was scored in.
    pub covered: usize,
    /// Difficulty-weighted mean regret/bound ratio over the covered
    /// worlds of this group (0.0 when the policy covers none of them —
    /// `pass` is false in that case regardless).
    pub mean_ratio: f64,
    /// Full group coverage and `mean_ratio <= threshold`.
    pub pass: bool,
}

/// One policy's gate verdict.
#[derive(Debug, Clone, PartialEq)]
pub struct GateVerdict {
    pub policy: String,
    /// Scored in every world of the population.
    pub covered: bool,
    /// Worlds the policy was not scored in.
    pub missing_worlds: usize,
    /// Difficulty-weighted mean ratio pooled over all covered worlds —
    /// the statistic a gate-less ranking would use.
    pub overall_mean_ratio: f64,
    /// Whether the pooled mean alone clears the threshold.
    pub mean_pass: bool,
    /// The gate's decision: covered and passing in every regime.
    pub promoted: bool,
    /// Per-regime standing, in the report's regime order.
    pub regimes: Vec<RegimeScore>,
    /// Regimes that blocked promotion (empty iff promoted or uncovered
    /// with no regime failures).
    pub failing_regimes: Vec<String>,
}

/// The whole gate run: every policy's verdict plus the regime census.
#[derive(Debug, Clone, PartialEq)]
pub struct GateReport {
    pub threshold: f64,
    /// Worlds with at least one scorable run.
    pub worlds: usize,
    /// `(tag, world count)` census in sorted tag order.
    pub regimes: Vec<(String, usize)>,
    /// Promoted policy count.
    pub promoted: usize,
    /// Verdicts: promoted first, then by pooled mean.
    pub verdicts: Vec<GateVerdict>,
}

fn weighted_mean(rows: &[(f64, f64)]) -> f64 {
    if rows.is_empty() {
        return 0.0;
    }
    let total_d: f64 = rows.iter().map(|(_, d)| *d).sum();
    if total_d > 0.0 {
        rows.iter().map(|(r, d)| r * d).sum::<f64>() / total_d
    } else {
        rows.iter().map(|(r, _)| *r).sum::<f64>() / rows.len() as f64
    }
}

/// Regime groups over the world table: sorted tag -> world indices. A
/// world belongs to every group its tags name; untagged worlds form the
/// [`UNTAGGED`] group.
fn regime_groups(table: &[WorldStat]) -> BTreeMap<String, Vec<usize>> {
    let mut groups: BTreeMap<String, Vec<usize>> = BTreeMap::new();
    for (i, w) in table.iter().enumerate() {
        if w.tags.is_empty() {
            groups.entry(UNTAGGED.to_string()).or_default().push(i);
        } else {
            for t in &w.tags {
                groups.entry(t.clone()).or_default().push(i);
            }
        }
    }
    groups
}

/// Run the gate over canonical fleet outcomes.
pub fn evaluate_gate(outcomes: &[ScenarioOutcome], cfg: &GateConfig) -> GateReport {
    let table = world_table(outcomes);
    let groups = regime_groups(&table);
    let policies: BTreeSet<&str> = table
        .iter()
        .flat_map(|w| w.policy_mean_ratio.keys().map(String::as_str))
        .collect();

    let mut verdicts: Vec<GateVerdict> = policies
        .into_iter()
        .map(|policy| {
            let all_rows: Vec<(f64, f64)> = table
                .iter()
                .filter_map(|w| {
                    w.policy_mean_ratio.get(policy).map(|r| (*r, w.difficulty))
                })
                .collect();
            let covered_worlds = all_rows.len();
            let overall = weighted_mean(&all_rows);
            let regimes: Vec<RegimeScore> = groups
                .iter()
                .map(|(tag, idxs)| {
                    let rows: Vec<(f64, f64)> = idxs
                        .iter()
                        .filter_map(|&i| {
                            table[i]
                                .policy_mean_ratio
                                .get(policy)
                                .map(|r| (*r, table[i].difficulty))
                        })
                        .collect();
                    let mean = weighted_mean(&rows);
                    RegimeScore {
                        tag: tag.clone(),
                        worlds: idxs.len(),
                        covered: rows.len(),
                        mean_ratio: mean,
                        pass: rows.len() == idxs.len() && mean <= cfg.threshold,
                    }
                })
                .collect();
            let covered = covered_worlds == table.len() && !table.is_empty();
            let failing_regimes: Vec<String> = regimes
                .iter()
                .filter(|r| !r.pass)
                .map(|r| r.tag.clone())
                .collect();
            GateVerdict {
                policy: policy.to_string(),
                covered,
                missing_worlds: table.len() - covered_worlds,
                overall_mean_ratio: overall,
                mean_pass: overall <= cfg.threshold,
                promoted: covered && failing_regimes.is_empty(),
                regimes,
                failing_regimes,
            }
        })
        .collect();

    verdicts.sort_by(|a, b| {
        b.promoted
            .cmp(&a.promoted)
            .then(b.covered.cmp(&a.covered))
            .then(a.overall_mean_ratio.total_cmp(&b.overall_mean_ratio))
            .then(a.policy.cmp(&b.policy))
    });

    GateReport {
        threshold: cfg.threshold,
        worlds: table.len(),
        regimes: groups.into_iter().map(|(t, v)| (t, v.len())).collect(),
        promoted: verdicts.iter().filter(|v| v.promoted).count(),
        verdicts,
    }
}

/// Serialize the gate run as the standalone `dagcloud.robustness/v1`
/// document (see `docs/SCHEMAS.md`).
pub fn gate_json(r: &GateReport) -> Json {
    let mut j = Json::obj();
    j.set("schema", Json::Str("dagcloud.robustness/v1".into()))
        .set("threshold", Json::Num(r.threshold))
        .set("worlds", Json::Num(r.worlds as f64))
        .set("promoted", Json::Num(r.promoted as f64))
        .set(
            "regimes",
            Json::Arr(
                r.regimes
                    .iter()
                    .map(|(t, n)| {
                        let mut rj = Json::obj();
                        rj.set("tag", Json::Str(t.clone()))
                            .set("worlds", Json::Num(*n as f64));
                        rj
                    })
                    .collect(),
            ),
        )
        .set(
            "policies",
            Json::Arr(
                r.verdicts
                    .iter()
                    .map(|v| {
                        let mut vj = Json::obj();
                        vj.set("policy", Json::Str(v.policy.clone()))
                            .set("covered", Json::Bool(v.covered))
                            .set("overall_mean_ratio", Json::Num(v.overall_mean_ratio))
                            .set("mean_pass", Json::Bool(v.mean_pass))
                            .set("promoted", Json::Bool(v.promoted))
                            .set(
                                "regimes",
                                Json::Arr(
                                    v.regimes
                                        .iter()
                                        .map(|s| {
                                            let mut sj = Json::obj();
                                            sj.set("tag", Json::Str(s.tag.clone()))
                                                .set("worlds", Json::Num(s.worlds as f64))
                                                .set("covered", Json::Num(s.covered as f64))
                                                .set("mean_ratio", Json::Num(s.mean_ratio))
                                                .set("pass", Json::Bool(s.pass));
                                            sj
                                        })
                                        .collect(),
                                ),
                            );
                        if v.missing_worlds > 0 {
                            vj.set("missing_worlds", Json::Num(v.missing_worlds as f64));
                        }
                        if !v.failing_regimes.is_empty() {
                            vj.set(
                                "failing_regimes",
                                Json::Arr(
                                    v.failing_regimes
                                        .iter()
                                        .map(|t| Json::Str(t.clone()))
                                        .collect(),
                                ),
                            );
                        }
                        vj
                    })
                    .collect(),
            ),
        );
    j
}

/// Render the verdict table `repro robustness` prints: one row per
/// policy, one mean-ratio column per regime, and the gate decision.
pub fn render_gate_table(r: &GateReport) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "promotion gate: threshold {:.3}, {} worlds, {} regimes, {} promoted\n",
        r.threshold,
        r.worlds,
        r.regimes.len(),
        r.promoted
    ));
    let mut header = format!("{:<42} {:>8}", "policy", "overall");
    for (tag, n) in &r.regimes {
        header.push_str(&format!(" {:>12}", format!("{tag}({n})")));
    }
    header.push_str("  verdict");
    out.push_str(&header);
    out.push('\n');
    for v in &r.verdicts {
        let mut row = format!("{:<42} {:>8.4}", v.policy, v.overall_mean_ratio);
        for s in &v.regimes {
            if s.covered == 0 {
                row.push_str(&format!(" {:>12}", "-"));
            } else {
                row.push_str(&format!(" {:>12.4}", s.mean_ratio));
            }
        }
        let verdict = if v.promoted {
            "PROMOTED".to_string()
        } else if !v.covered {
            format!("unranked ({} worlds missing)", v.missing_worlds)
        } else {
            format!("demoted ({})", v.failing_regimes.join(", "))
        };
        row.push_str("  ");
        row.push_str(&verdict);
        out.push_str(&row);
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome(
        world: &str,
        tags: &[&str],
        costs: &[(&str, f64)],
        bound: f64,
    ) -> ScenarioOutcome {
        ScenarioOutcome {
            scenario: world.into(),
            replicate: 0,
            run_seed: 0,
            jobs: 10,
            average_unit_cost: 0.3,
            average_regret: 0.01,
            regret_bound: bound,
            pool_utilization: 0.0,
            so_share: 0.0,
            spot_share: 0.8,
            od_share: 0.2,
            availability_lo: 0.4,
            availability_hi: 0.9,
            best_policy: costs.first().map(|(l, _)| l.to_string()).unwrap_or_default(),
            offer_shares: Vec::new(),
            policy_costs: costs.iter().map(|(l, c)| (l.to_string(), *c)).collect(),
            tags: tags.iter().map(|t| t.to_string()).collect(),
            optimism_gap: Vec::new(),
            migrations: 0,
        }
    }

    /// The worked example in EXPERIMENTS.md §Robustness: pA is excellent
    /// in the three calm worlds and terrible in the one surge world, so
    /// it clears the pooled mean but the gate demotes it; pB is mediocre
    /// everywhere and is promoted. pC (bad in calm, fine in surge) gives
    /// the calm worlds a policy-cost spread, hence difficulty weight —
    /// without it the surge world's spread dominates the pooled mean and
    /// pA's surge hole would not be hidden in the first place.
    #[test]
    fn gate_demotes_a_policy_that_passes_on_the_pooled_mean() {
        let outs = vec![
            outcome("c1", &["calm"], &[("pA", 0.00), ("pB", 0.10), ("pC", 0.80)], 1.0),
            outcome("c2", &["calm"], &[("pA", 0.00), ("pB", 0.10), ("pC", 0.80)], 1.0),
            outcome("c3", &["calm"], &[("pA", 0.00), ("pB", 0.10), ("pC", 0.80)], 1.0),
            outcome("s1", &["surge"], &[("pA", 0.90), ("pB", 0.20), ("pC", 0.20)], 1.0),
        ];
        let r = evaluate_gate(&outs, &GateConfig { threshold: 0.25 });
        assert_eq!(r.worlds, 4);
        assert_eq!(r.regimes, vec![("calm".into(), 3), ("surge".into(), 1)]);
        // Per-world ratios are (cost - min)/bound; world difficulty is the
        // policy-cost spread: calm worlds 0.8, the surge world 0.7.
        let pa = r.verdicts.iter().find(|v| v.policy == "pA").unwrap();
        // Pooled: (3*0.8*0.0 + 0.7*0.7)/3.1 ~= 0.158 <= 0.25 — passes.
        assert!(pa.mean_pass, "pooled mean hides the surge hole: {}", pa.overall_mean_ratio);
        assert!((pa.overall_mean_ratio - 0.49 / 3.1).abs() < 1e-12);
        assert!(!pa.promoted);
        assert_eq!(pa.failing_regimes, vec!["surge".to_string()]);
        let surge = pa.regimes.iter().find(|g| g.tag == "surge").unwrap();
        assert!((surge.mean_ratio - 0.7).abs() < 1e-12);
        let pb = r.verdicts.iter().find(|v| v.policy == "pB").unwrap();
        assert!(pb.promoted);
        let pc = r.verdicts.iter().find(|v| v.policy == "pC").unwrap();
        assert!(!pc.promoted);
        assert_eq!(pc.failing_regimes, vec!["calm".to_string()]);
        assert_eq!(r.promoted, 1);
        // Promoted policies sort first, then pooled mean: pB, pA, pC.
        let order: Vec<&str> = r.verdicts.iter().map(|v| v.policy.as_str()).collect();
        assert_eq!(order, vec!["pB", "pA", "pC"]);
    }

    #[test]
    fn untagged_worlds_form_their_own_regime() {
        let outs = vec![
            outcome("w1", &[], &[("p", 0.1), ("q", 0.3)], 1.0),
            outcome("w2", &["calm"], &[("p", 0.1), ("q", 0.3)], 1.0),
        ];
        let r = evaluate_gate(&outs, &GateConfig::default());
        assert_eq!(
            r.regimes,
            vec![("calm".into(), 1), (UNTAGGED.into(), 1)]
        );
    }

    #[test]
    fn partial_coverage_blocks_promotion_and_is_reported() {
        let outs = vec![
            outcome("w1", &["calm"], &[("p", 0.0), ("q", 0.0)], 1.0),
            outcome("w2", &["surge"], &[("p", 0.0)], 1.0),
        ];
        let r = evaluate_gate(&outs, &GateConfig::default());
        let q = r.verdicts.iter().find(|v| v.policy == "q").unwrap();
        assert!(!q.covered && !q.promoted);
        assert_eq!(q.missing_worlds, 1);
        assert_eq!(q.failing_regimes, vec!["surge".to_string()]);
        let table = render_gate_table(&r);
        assert!(table.contains("unranked (1 worlds missing)"), "{table}");
        assert!(table.contains("PROMOTED"), "{table}");
    }

    #[test]
    fn gate_json_shape_is_stable() {
        let outs = vec![outcome("w1", &["calm"], &[("p", 0.0), ("q", 0.5)], 1.0)];
        let j = gate_json(&evaluate_gate(&outs, &GateConfig::default()));
        assert_eq!(
            j.get("schema").unwrap().as_str().unwrap(),
            "dagcloud.robustness/v1"
        );
        assert_eq!(j.get("worlds").unwrap().as_u64().unwrap(), 1);
        assert_eq!(j.get("promoted").unwrap().as_u64().unwrap(), 1);
        let pols = j.get("policies").unwrap().as_arr().unwrap();
        assert_eq!(pols[0].get("policy").unwrap().as_str().unwrap(), "p");
        assert_eq!(pols[0].get("promoted").unwrap().as_bool().unwrap(), true);
        assert!(pols[0].get("failing_regimes").is_none());
        let q = &pols[1];
        assert_eq!(q.get("promoted").unwrap().as_bool().unwrap(), false);
        assert_eq!(
            q.get("failing_regimes").unwrap().as_arr().unwrap()[0]
                .as_str()
                .unwrap(),
            "calm"
        );
    }
}
