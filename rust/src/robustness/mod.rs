//! The robustness engine: resampled worlds, tail-risk scoring, and the
//! cross-regime promotion gate.
//!
//! The paper's evaluation (§6) scores policies in a handful of
//! hand-picked markets; its online-learning claim, however, is about
//! *distributions* of markets. This subsystem stress-tests that claim by
//! growing large world populations from the registry bases and asking
//! which fixed policies stay cheap in the tail, not just on average:
//!
//! * [`derive`] — deterministic derivation operators: block bootstrap of
//!   realized price traces (multi-slot blocks preserve autocorrelation),
//!   regime oversampling (rare calm/surge blocks get amplified),
//!   injected price spikes, capacity dropout on finite-capacity offers,
//!   and feed-event gaps replayed through [`crate::feed::FeedBuffer`].
//!   Each derived world is a pure function of `(base world, operator,
//!   seed, index)` and is a complete [`crate::scenario::ScenarioSpec`],
//!   so the population
//!   flows through the unchanged [`crate::fleet::ShardManifest`] →
//!   [`crate::fleet::FleetAccumulator`] path and inherits the fleet
//!   layer's byte-invariance under shard count and merge order;
//! * [`tag`] — regime tagging: explicit spec tags win, otherwise the
//!   world's price structure is classified calm/surge;
//! * [`gate`] — the promotion gate over the fleet layer's tail-risk
//!   scores ([`crate::fleet::robustness`]): a policy is *robust* only if
//!   its bound-normalized mean regret clears the threshold in **every**
//!   regime tag — a policy that looks fine on the pooled mean but folds
//!   in surge worlds is demoted. The verdict table serializes as
//!   `dagcloud.robustness/v1` (see `docs/SCHEMAS.md`).
//!
//! CLI front-end: `repro robustness --base WORLD --derive N` (see
//! `rust/src/experiments/robustness.rs`).

pub mod derive;
pub mod gate;
pub mod tag;

pub use derive::{
    derivation_plan, derivation_seed, derive_population, derive_world, DeriveParams, Operator,
};
pub use gate::{evaluate_gate, gate_json, render_gate_table, GateConfig, GateReport, GateVerdict};
pub use tag::{classify_model, classify_trace, world_tags, SURGE_THRESHOLD};
