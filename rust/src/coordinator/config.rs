//! Experiment configuration: JSON-loadable with §6.1 defaults.

use crate::market::SpotModel;
use crate::util::json::Json;
use crate::workload::GeneratorConfig;

/// Full configuration of a simulation / experiment run.
#[derive(Debug, Clone)]
pub struct Config {
    /// Number of jobs to simulate (§6.2 uses ~10000).
    pub jobs: usize,
    /// RNG seed (workload, trace and policy sampling derive streams).
    pub seed: u64,
    /// Job type x₂ ∈ 1..=4 (deadline flexibility class).
    pub job_type: u8,
    /// Self-owned pool capacities to sweep (x₁ values).
    pub pool_sizes: Vec<u64>,
    /// Spot price model.
    pub spot_model: SpotModel,
    /// On-demand price (normalized to 1 in the paper).
    pub od_price: f64,
    /// Worker threads for policy sweeps (0 = all cores).
    pub threads: usize,
    /// Use the PJRT kernel for counterfactual sweeps when artifacts exist.
    pub use_pjrt: bool,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            jobs: 2000,
            seed: 7,
            job_type: 2,
            pool_sizes: vec![300, 600, 900, 1200],
            spot_model: SpotModel::paper_default(),
            od_price: crate::market::ON_DEMAND_PRICE,
            threads: 0,
            use_pjrt: true,
        }
    }
}

impl Config {
    /// Generator for a specific job type with this config's seed.
    pub fn generator(&self, job_type: u8) -> GeneratorConfig {
        GeneratorConfig::for_job_type(job_type)
    }

    pub fn effective_threads(&self) -> usize {
        if self.threads > 0 {
            self.threads
        } else {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
        }
    }

    /// Load from a JSON file; missing keys keep defaults.
    pub fn from_json_file(path: &str) -> anyhow::Result<Config> {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
        Self::from_json(&j)
    }

    /// Missing keys keep defaults; malformed values (e.g. an unknown spot
    /// model kind) are errors rather than silent fallbacks.
    pub fn from_json(j: &Json) -> anyhow::Result<Config> {
        let d = Config::default();
        let spot_model = match j.get("spot_model") {
            Some(sm) => crate::market::spot_model_from_json(sm)?,
            None => d.spot_model.clone(),
        };
        Ok(Config {
            jobs: j.opt_u64("jobs", d.jobs as u64) as usize,
            seed: j.opt_u64("seed", d.seed),
            job_type: j.opt_u64("job_type", d.job_type as u64) as u8,
            pool_sizes: j
                .get("pool_sizes")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_u64).collect())
                .unwrap_or(d.pool_sizes),
            spot_model,
            od_price: j.opt_f64("od_price", d.od_price),
            threads: j.opt_u64("threads", d.threads as u64) as usize,
            use_pjrt: j.opt_bool("use_pjrt", d.use_pjrt),
        })
    }

    /// The coordinator-facing view of a scenario: home-region price model
    /// (synthetic single-model markets only — regime/replay/composite
    /// markets realize their trace in the scenario runner and hand it to
    /// `tola_run` directly), home on-demand price, the scenario's pool and
    /// job count, and the dominant job type.
    pub fn from_scenario(spec: &crate::scenario::ScenarioSpec) -> Config {
        let d = Config::default();
        let home = spec.market.regions.first();
        let spot_model = match home.map(|r| &r.price) {
            Some(crate::scenario::PriceSpec::Model(m)) => m.clone(),
            _ => d.spot_model.clone(),
        };
        Config {
            jobs: spec.jobs,
            job_type: spec
                .workload
                .components
                .first()
                .map(|c| c.job_type)
                .unwrap_or(d.job_type),
            pool_sizes: vec![spec.pool_capacity as u64],
            spot_model,
            od_price: home.map(|r| r.od_price).unwrap_or(d.od_price),
            ..d
        }
    }

    pub fn to_json(&self) -> Json {
        let sm = crate::market::spot_model_to_json(&self.spot_model);
        let mut j = Json::obj();
        j.set("jobs", Json::Num(self.jobs as f64))
            .set("seed", Json::Num(self.seed as f64))
            .set("job_type", Json::Num(self.job_type as f64))
            .set(
                "pool_sizes",
                Json::Arr(self.pool_sizes.iter().map(|&x| Json::Num(x as f64)).collect()),
            )
            .set("spot_model", sm)
            .set("od_price", Json::Num(self.od_price))
            .set("threads", Json::Num(self.threads as f64))
            .set("use_pjrt", Json::Bool(self.use_pjrt));
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = Config::default();
        assert_eq!(c.job_type, 2);
        assert_eq!(c.pool_sizes, vec![300, 600, 900, 1200]);
        assert_eq!(c.spot_model, SpotModel::paper_default());
        assert_eq!(c.od_price, 1.0);
    }

    #[test]
    fn json_roundtrip() {
        let c = Config {
            jobs: 123,
            seed: 9,
            job_type: 3,
            pool_sizes: vec![10, 20],
            spot_model: SpotModel::GoogleFixed {
                price: 0.25,
                availability: 0.8,
            },
            od_price: 2.0,
            threads: 2,
            use_pjrt: false,
        };
        let j = c.to_json();
        let c2 = Config::from_json(&j).unwrap();
        assert_eq!(c2.jobs, 123);
        assert_eq!(c2.job_type, 3);
        assert_eq!(c2.pool_sizes, vec![10, 20]);
        assert_eq!(c2.spot_model, c.spot_model);
        assert!(!c2.use_pjrt);
    }

    #[test]
    fn from_scenario_maps_home_region() {
        let mut spec = crate::scenario::registry::find("pool-heavy").unwrap();
        spec.jobs = 99;
        let c = Config::from_scenario(&spec);
        assert_eq!(c.jobs, 99);
        assert_eq!(c.pool_sizes, vec![600]);
        assert_eq!(c.job_type, 2);
        assert_eq!(c.spot_model, SpotModel::paper_default());
        assert_eq!(c.od_price, 1.0);
    }

    #[test]
    fn partial_json_keeps_defaults() {
        let j = Json::parse(r#"{"jobs": 50}"#).unwrap();
        let c = Config::from_json(&j).unwrap();
        assert_eq!(c.jobs, 50);
        assert_eq!(c.seed, Config::default().seed);
    }
}
