//! Experiment configuration: JSON-loadable with §6.1 defaults.
//!
//! Since the `MarketView` refactor a config describes a whole market view:
//! the legacy `(spot_model, od_price)` pair is the *home offer*, and
//! `extra_offers` (empty by default) adds named `(region, instance_type)`
//! offers with their own price processes, on-demand prices, and spot
//! capacities. The default config is the one-offer degenerate case, so
//! pre-existing runs are bit-identical.

use anyhow::{ensure, Result};

use crate::market::{MarketOffer, MarketView, PriceTrace, SpotModel};
use crate::policy::routing::{MigrationPolicy, RoutingPolicy};
use crate::util::json::Json;
use crate::workload::GeneratorConfig;

/// One additional market offer beyond the home `(spot_model, od_price)`.
#[derive(Debug, Clone, PartialEq)]
pub struct OfferConfig {
    pub region: String,
    pub instance_type: String,
    pub od_price: f64,
    pub spot_model: SpotModel,
    /// Per-slot concurrent spot-instance cap; `None` = infinite.
    pub capacity: Option<u32>,
}

/// Full configuration of a simulation / experiment run.
#[derive(Debug, Clone)]
pub struct Config {
    /// Number of jobs to simulate (§6.2 uses ~10000).
    pub jobs: usize,
    /// RNG seed (workload, trace and policy sampling derive streams).
    pub seed: u64,
    /// Job type x₂ ∈ 1..=4 (deadline flexibility class).
    pub job_type: u8,
    /// Self-owned pool capacities to sweep (x₁ values).
    pub pool_sizes: Vec<u64>,
    /// Spot price model of the home offer.
    pub spot_model: SpotModel,
    /// On-demand price of the home offer (normalized to 1 in the paper).
    pub od_price: f64,
    /// Per-slot spot capacity of the home offer; `None` = infinite (the
    /// legacy assumption).
    pub home_capacity: Option<u32>,
    /// Additional market offers; empty = the legacy single market.
    pub extra_offers: Vec<OfferConfig>,
    /// How tasks are routed across offers (ignored for the single market).
    pub routing: RoutingPolicy,
    /// Mid-window migration policy (disabled by default; only meaningful
    /// for routed multi-offer markets).
    pub migration: MigrationPolicy,
    /// Worker threads for policy sweeps (0 = all cores).
    pub threads: usize,
    /// Use the PJRT kernel for counterfactual sweeps when artifacts exist.
    pub use_pjrt: bool,
    /// Observability handle (event log / span profiler / status logger).
    /// Run-level, not world-level: never serialized by [`Config::to_json`]
    /// and never part of a run's identity — report bytes are identical
    /// whatever its planes are set to.
    pub telemetry: crate::telemetry::Telemetry,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            jobs: 2000,
            seed: 7,
            job_type: 2,
            pool_sizes: vec![300, 600, 900, 1200],
            spot_model: SpotModel::paper_default(),
            od_price: crate::market::ON_DEMAND_PRICE,
            home_capacity: None,
            extra_offers: Vec::new(),
            routing: RoutingPolicy::Home,
            migration: MigrationPolicy::disabled(),
            threads: 0,
            use_pjrt: true,
            telemetry: crate::telemetry::Telemetry::disabled(),
        }
    }
}

impl Config {
    /// Generator for a specific job type with this config's seed.
    pub fn generator(&self, job_type: u8) -> GeneratorConfig {
        GeneratorConfig::for_job_type(job_type)
    }

    pub fn effective_threads(&self) -> usize {
        if self.threads > 0 {
            self.threads
        } else {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
        }
    }

    /// Whether this config describes more than the degenerate home market.
    pub fn is_multi_market(&self) -> bool {
        !self.extra_offers.is_empty()
    }

    /// Realize the configured market view: `home_trace` is the home
    /// offer's already-generated trace (the legacy `workload()` trace, so
    /// degenerate runs stay bit-identical); extra offers generate their
    /// own traces from per-offer derived seeds.
    pub fn realize_view(&self, home_trace: PriceTrace, horizon: f64) -> Result<MarketView> {
        let mut offers = vec![MarketOffer {
            region: "home".into(),
            instance_type: "default".into(),
            od_price: self.od_price,
            trace: home_trace,
            capacity: self.home_capacity,
        }];
        for (k, o) in self.extra_offers.iter().enumerate() {
            offers.push(MarketOffer {
                region: o.region.clone(),
                instance_type: o.instance_type.clone(),
                od_price: o.od_price,
                trace: PriceTrace::generate(
                    o.spot_model.clone(),
                    horizon,
                    self.seed ^ 0x7ACE ^ ((k as u64 + 1) << 8),
                ),
                capacity: o.capacity,
            });
        }
        MarketView::new(offers)
    }

    /// Load from a JSON file; missing keys keep defaults.
    pub fn from_json_file(path: &str) -> anyhow::Result<Config> {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
        Self::from_json(&j)
    }

    /// Missing keys keep defaults; malformed values (e.g. an unknown spot
    /// model kind, a zero offer capacity, an unknown routing policy) are
    /// errors rather than silent fallbacks.
    pub fn from_json(j: &Json) -> anyhow::Result<Config> {
        let d = Config::default();
        let spot_model = match j.get("spot_model") {
            Some(sm) => crate::market::spot_model_from_json(sm)?,
            None => d.spot_model.clone(),
        };
        spot_model
            .validate()
            .map_err(|e| anyhow::anyhow!("config: spot_model: {e}"))?;
        let routing = match j.get("routing") {
            Some(Json::Str(s)) => RoutingPolicy::from_str(s)?,
            Some(_) => anyhow::bail!("config: 'routing' must be a string"),
            None => d.routing,
        };
        let mut extra_offers = Vec::new();
        if let Some(arr) = j.get("offers").and_then(Json::as_arr) {
            for (k, oj) in arr.iter().enumerate() {
                extra_offers.push(offer_from_json(oj, k)?);
            }
        }
        // Dead-weight guard: home routing never places work on the extra
        // offers, so a config combining the two is a mistake, not a world.
        ensure!(
            extra_offers.is_empty() || routing != RoutingPolicy::Home,
            "config: 'offers' requires routing cheapest|spillover (home routing \
             ignores every offer but the first)"
        );
        let migration = migration_from_json(j, "config")?;
        // Same dead-weight logic: a Home-pinned task can never migrate.
        ensure!(
            !migration.enabled() || routing != RoutingPolicy::Home,
            "config: 'migration' requires routing cheapest|spillover (home \
             routing pins every task to offer 0)"
        );
        let home_capacity =
            crate::market::view::capacity_from_json(j, "home_capacity", "config")?;
        Ok(Config {
            jobs: j.opt_u64("jobs", d.jobs as u64) as usize,
            seed: j.opt_u64("seed", d.seed),
            job_type: j.opt_u64("job_type", d.job_type as u64) as u8,
            pool_sizes: j
                .get("pool_sizes")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_u64).collect())
                .unwrap_or(d.pool_sizes),
            spot_model,
            od_price: j.opt_f64("od_price", d.od_price),
            home_capacity,
            extra_offers,
            routing,
            migration,
            threads: j.opt_u64("threads", d.threads as u64) as usize,
            use_pjrt: j.opt_bool("use_pjrt", d.use_pjrt),
            telemetry: d.telemetry,
        })
    }

    /// The coordinator-facing view of a scenario: home-region price model
    /// (synthetic single-model markets only — regime/replay/composite
    /// markets realize their trace in the scenario runner and hand it to
    /// `tola_run` directly), home on-demand price, the scenario's pool and
    /// job count, the dominant job type — and, for routed all-synthetic
    /// worlds, the remaining offers plus the routing policy, so
    /// `repro run --scenario` drives real multi-offer routing end to end.
    ///
    /// Errors when a routed (cheapest/spillover) world has a
    /// replay/regime-priced offer: dropping it would silently simulate a
    /// different market than named — run those through the scenario
    /// runner instead.
    pub fn from_scenario(spec: &crate::scenario::ScenarioSpec) -> Result<Config> {
        let d = Config::default();
        let offers = spec.market.flattened_offers();
        let home = offers.first();
        let spot_model = match home.map(|o| &o.price) {
            Some(crate::scenario::PriceSpec::Model(m)) => m.clone(),
            _ => d.spot_model.clone(),
        };
        let extra_offers = match spec.market.routing.runtime() {
            // Arbitrage collapses pre-run and Home ignores the rest: both
            // stay the single home market here.
            None | Some(RoutingPolicy::Home) => Vec::new(),
            Some(_) => offers
                .iter()
                .skip(1)
                .map(|o| match &o.price {
                    crate::scenario::PriceSpec::Model(m) => Ok(OfferConfig {
                        region: o.region.clone(),
                        instance_type: o.instance_type.clone(),
                        od_price: o.od_price,
                        spot_model: m.clone(),
                        capacity: o.capacity,
                    }),
                    _ => Err(anyhow::anyhow!(
                        "scenario '{}': routed offer '{}/{}' uses a replay/regime \
                         price process; `repro scenarios --scenario {}` realizes it",
                        spec.name,
                        o.region,
                        o.instance_type,
                        spec.name
                    )),
                })
                .collect::<Result<Vec<_>>>()?,
        };
        Ok(Config {
            jobs: spec.jobs,
            job_type: spec
                .workload
                .components
                .first()
                .map(|c| c.job_type)
                .unwrap_or(d.job_type),
            pool_sizes: vec![spec.pool_capacity as u64],
            spot_model,
            od_price: home.map(|o| o.od_price).unwrap_or(d.od_price),
            home_capacity: home.and_then(|o| o.capacity),
            extra_offers,
            routing: spec.market.routing.runtime().unwrap_or(RoutingPolicy::Home),
            migration: spec.migration,
            ..d
        })
    }

    pub fn to_json(&self) -> Json {
        let sm = crate::market::spot_model_to_json(&self.spot_model);
        let mut j = Json::obj();
        j.set("jobs", Json::Num(self.jobs as f64))
            .set("seed", Json::Num(self.seed as f64))
            .set("job_type", Json::Num(self.job_type as f64))
            .set(
                "pool_sizes",
                Json::Arr(self.pool_sizes.iter().map(|&x| Json::Num(x as f64)).collect()),
            )
            .set("spot_model", sm)
            .set("od_price", Json::Num(self.od_price))
            .set("threads", Json::Num(self.threads as f64))
            .set("use_pjrt", Json::Bool(self.use_pjrt));
        if !self.extra_offers.is_empty() || self.routing != RoutingPolicy::Home {
            j.set("routing", Json::Str(self.routing.as_str().into()));
        }
        if let Some(c) = self.home_capacity {
            j.set("home_capacity", Json::Num(c as f64));
        }
        if !self.extra_offers.is_empty() {
            j.set(
                "offers",
                Json::Arr(self.extra_offers.iter().map(offer_to_json).collect()),
            );
        }
        if self.migration.enabled() {
            j.set("migration", migration_to_json(&self.migration));
        }
        j
    }
}

/// Serialize an *enabled* migration policy. JSON has no `+inf`, so the
/// disabled default is encoded as key absence — which is also what keeps
/// pre-migration config files round-tripping byte-identically.
pub(crate) fn migration_to_json(m: &MigrationPolicy) -> Json {
    let mut j = Json::obj();
    j.set("switch_cost", Json::Num(m.switch_cost))
        .set("hysteresis_slots", Json::Num(m.hysteresis_slots as f64));
    j
}

/// Parse an optional `"migration"` object; absence means disabled. A
/// present object must carry a finite, non-negative `switch_cost` —
/// presence means enabled, so an infinite or missing cost is an error,
/// not a silent disable.
pub(crate) fn migration_from_json(j: &Json, ctx: &str) -> Result<MigrationPolicy> {
    let Some(mj) = j.get("migration") else {
        return Ok(MigrationPolicy::disabled());
    };
    let switch_cost = mj
        .get("switch_cost")
        .and_then(Json::as_f64)
        .ok_or_else(|| anyhow::anyhow!("{ctx}: migration: missing numeric 'switch_cost'"))?;
    ensure!(
        switch_cost.is_finite(),
        "{ctx}: migration: switch_cost must be finite (omit the 'migration' key \
         to disable migration)"
    );
    let m = MigrationPolicy {
        switch_cost,
        hysteresis_slots: mj.opt_u64("hysteresis_slots", 0) as u32,
    };
    m.validate().map_err(|e| anyhow::anyhow!("{ctx}: migration: {e}"))?;
    Ok(m)
}

fn offer_to_json(o: &OfferConfig) -> Json {
    let mut j = Json::obj();
    j.set("region", Json::Str(o.region.clone()))
        .set("instance_type", Json::Str(o.instance_type.clone()))
        .set("od_price", Json::Num(o.od_price))
        .set(
            "spot_model",
            crate::market::spot_model_to_json(&o.spot_model),
        );
    if let Some(c) = o.capacity {
        j.set("capacity", Json::Num(c as f64));
    }
    j
}

fn offer_from_json(j: &Json, index: usize) -> Result<OfferConfig> {
    let sm = j
        .get("spot_model")
        .ok_or_else(|| anyhow::anyhow!("config offer {index}: missing 'spot_model'"))?;
    let spot_model = crate::market::spot_model_from_json(sm)?;
    spot_model
        .validate()
        .map_err(|e| anyhow::anyhow!("config offer {index}: {e}"))?;
    let capacity = crate::market::view::capacity_from_json(
        j,
        "capacity",
        &format!("config offer {index}"),
    )?;
    let od_price = j.opt_f64("od_price", crate::market::ON_DEMAND_PRICE);
    ensure!(
        od_price > 0.0,
        "config offer {index}: od_price must be positive"
    );
    Ok(OfferConfig {
        region: j.opt_str("region", &format!("region-{index}")).to_string(),
        instance_type: j.opt_str("instance_type", "default").to_string(),
        od_price,
        spot_model,
        capacity,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = Config::default();
        assert_eq!(c.job_type, 2);
        assert_eq!(c.pool_sizes, vec![300, 600, 900, 1200]);
        assert_eq!(c.spot_model, SpotModel::paper_default());
        assert_eq!(c.od_price, 1.0);
        assert!(!c.is_multi_market());
        assert_eq!(c.routing, RoutingPolicy::Home);
    }

    #[test]
    fn json_roundtrip() {
        let c = Config {
            jobs: 123,
            seed: 9,
            job_type: 3,
            pool_sizes: vec![10, 20],
            spot_model: SpotModel::GoogleFixed {
                price: 0.25,
                availability: 0.8,
            },
            od_price: 2.0,
            home_capacity: None,
            extra_offers: Vec::new(),
            routing: RoutingPolicy::Home,
            migration: MigrationPolicy::disabled(),
            threads: 2,
            use_pjrt: false,
            telemetry: crate::telemetry::Telemetry::disabled(),
        };
        let j = c.to_json();
        assert!(j.get("offers").is_none(), "degenerate config stays legacy-shaped");
        assert!(j.get("routing").is_none());
        assert!(j.get("migration").is_none(), "disabled migration stays off disk");
        let c2 = Config::from_json(&j).unwrap();
        assert_eq!(c2.jobs, 123);
        assert_eq!(c2.job_type, 3);
        assert_eq!(c2.pool_sizes, vec![10, 20]);
        assert_eq!(c2.spot_model, c.spot_model);
        assert!(!c2.use_pjrt);
    }

    #[test]
    fn multi_offer_json_roundtrip() {
        let c = Config {
            extra_offers: vec![OfferConfig {
                region: "eu-west".into(),
                instance_type: "m5".into(),
                od_price: 1.2,
                spot_model: SpotModel::paper_default(),
                capacity: Some(64),
            }],
            routing: RoutingPolicy::CheapestFeasible,
            ..Config::default()
        };
        let j = c.to_json();
        let c2 = Config::from_json(&j).unwrap();
        assert_eq!(c2.extra_offers, c.extra_offers);
        assert_eq!(c2.routing, RoutingPolicy::CheapestFeasible);
        assert!(c2.is_multi_market());
    }

    #[test]
    fn migration_json_roundtrip_and_guards() {
        let c = Config {
            extra_offers: vec![OfferConfig {
                region: "eu-west".into(),
                instance_type: "m5".into(),
                od_price: 1.2,
                spot_model: SpotModel::paper_default(),
                capacity: Some(64),
            }],
            routing: RoutingPolicy::CheapestFeasible,
            migration: MigrationPolicy { switch_cost: 0.05, hysteresis_slots: 3 },
            ..Config::default()
        };
        let j = c.to_json();
        let c2 = Config::from_json(&j).unwrap();
        assert_eq!(c2.migration, c.migration);
        // Home routing can never migrate: dead-weight guard.
        let j = Json::parse(r#"{"migration": {"switch_cost": 0.1}}"#).unwrap();
        let err = Config::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("migration"), "{err}");
        // A present migration object must be well-formed.
        for bad in [
            r#"{"routing": "cheapest", "migration": {}}"#,
            r#"{"routing": "cheapest", "migration": {"switch_cost": -0.1}}"#,
        ] {
            assert!(Config::from_json(&Json::parse(bad).unwrap()).is_err(), "{bad}");
        }
        let j = Json::parse(r#"{"routing": "cheapest", "migration": {"switch_cost": 0.0}}"#)
            .unwrap();
        let c = Config::from_json(&j).unwrap();
        assert!(c.migration.enabled());
        assert_eq!(c.migration.hysteresis_slots, 0);
    }

    #[test]
    fn bad_offer_and_routing_are_errors() {
        let j = Json::parse(r#"{"routing": "teleport"}"#).unwrap();
        assert!(Config::from_json(&j).is_err());
        let j = Json::parse(
            r#"{"offers": [{"spot_model": {"kind": "bounded_exp"}, "capacity": 0}]}"#,
        )
        .unwrap();
        assert!(Config::from_json(&j).is_err());
        let j = Json::parse(r#"{"offers": [{"capacity": 4}]}"#).unwrap();
        assert!(Config::from_json(&j).is_err(), "offer without spot_model");
        let j = Json::parse(
            r#"{"offers": [{"spot_model": {"kind": "bounded_exp", "mean": 0.2, "lo": 0.9, "hi": 0.5}}]}"#,
        )
        .unwrap();
        assert!(Config::from_json(&j).is_err(), "degenerate model params");
        // The *home* spot model gets the same scrutiny as the offers.
        let j = Json::parse(
            r#"{"spot_model": {"kind": "bounded_exp", "mean": 0.2, "lo": 0.9, "hi": 0.5}}"#,
        )
        .unwrap();
        assert!(Config::from_json(&j).is_err(), "degenerate home model params");
        // Offers with (default) home routing are dead weight: reject.
        let j = Json::parse(
            r#"{"offers": [{"spot_model": {"kind": "bounded_exp", "mean": 0.13, "lo": 0.12, "hi": 1.0}}]}"#,
        )
        .unwrap();
        let err = Config::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("routing"), "{err}");
    }

    #[test]
    fn realize_view_home_first_with_extras() {
        let c = Config {
            extra_offers: vec![OfferConfig {
                region: "b".into(),
                instance_type: "default".into(),
                od_price: 1.1,
                spot_model: SpotModel::paper_default(),
                capacity: Some(32),
            }],
            routing: RoutingPolicy::Spillover,
            ..Config::default()
        };
        let home = PriceTrace::generate(c.spot_model.clone(), 10.0, c.seed ^ 0x7ACE);
        let v = c.realize_view(home, 10.0).unwrap();
        assert_eq!(v.len(), 2);
        assert_eq!(v.home().region, "home");
        assert_eq!(v.offers()[1].capacity, Some(32));
        assert!(!v.is_degenerate());
    }

    #[test]
    fn from_scenario_maps_home_region() {
        let mut spec = crate::scenario::registry::find("pool-heavy").unwrap();
        spec.jobs = 99;
        let c = Config::from_scenario(&spec).unwrap();
        assert_eq!(c.jobs, 99);
        assert_eq!(c.pool_sizes, vec![600]);
        assert_eq!(c.job_type, 2);
        assert_eq!(c.spot_model, SpotModel::paper_default());
        assert_eq!(c.od_price, 1.0);
        assert!(!c.is_multi_market());
    }

    #[test]
    fn from_scenario_maps_routed_offers() {
        let spec = crate::scenario::registry::find("capacity-crunch").unwrap();
        let c = Config::from_scenario(&spec).unwrap();
        assert!(c.is_multi_market(), "routed world should carry its offers");
        assert_ne!(c.routing, RoutingPolicy::Home);
    }

    #[test]
    fn partial_json_keeps_defaults() {
        let j = Json::parse(r#"{"jobs": 50}"#).unwrap();
        let c = Config::from_json(&j).unwrap();
        assert_eq!(c.jobs, 50);
        assert_eq!(c.seed, Config::default().seed);
        assert!(c.extra_offers.is_empty());
    }
}
