//! Run metrics: counters/gauges collected by the coordinator and dumped as
//! JSON for EXPERIMENTS.md.

use std::collections::BTreeMap;
use std::time::Instant;

use crate::util::json::Json;

/// A lightweight metrics registry.
#[derive(Debug)]
pub struct Metrics {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    started: Instant,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            counters: BTreeMap::new(),
            gauges: BTreeMap::new(),
            started: Instant::now(),
        }
    }

    pub fn incr(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += by;
    }

    pub fn set(&mut self, name: &str, value: f64) {
        self.gauges.insert(name.to_string(), value);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        let mut counters = Json::obj();
        for (k, v) in &self.counters {
            counters.set(k, Json::Num(*v as f64));
        }
        let mut gauges = Json::obj();
        for (k, v) in &self.gauges {
            gauges.set(k, Json::Num(*v));
        }
        j.set("counters", counters)
            .set("gauges", gauges)
            .set("elapsed_secs", Json::Num(self.elapsed_secs()));
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges() {
        let mut m = Metrics::new();
        m.incr("jobs", 3);
        m.incr("jobs", 2);
        m.set("alpha", 0.25);
        assert_eq!(m.counter("jobs"), 5);
        assert_eq!(m.counter("missing"), 0);
        assert_eq!(m.gauge("alpha"), Some(0.25));
        let j = m.to_json();
        assert_eq!(j.get("counters").unwrap().get("jobs").unwrap().as_f64(), Some(5.0));
    }
}
