//! Run metrics: counters/gauges collected by the coordinator and dumped as
//! JSON for EXPERIMENTS.md.
//!
//! The JSON layout keeps the two telemetry planes separate (the same
//! contract as `dagcloud.telemetry/v1`): counters and gauges are
//! deterministic simulation state and live under `"deterministic"`;
//! elapsed wall time and latency histograms live under `"wall_clock"`. A
//! report that wants reproducible bytes embeds the `deterministic`
//! section only — it can no longer silently pick up `elapsed_secs` by
//! embedding the whole object.

use std::collections::BTreeMap;
use std::time::Instant;

use crate::telemetry::Histogram;
use crate::util::json::Json;

/// A lightweight metrics registry.
#[derive(Debug)]
pub struct Metrics {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    histograms: BTreeMap<String, Histogram>,
    started: Instant,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            counters: BTreeMap::new(),
            gauges: BTreeMap::new(),
            histograms: BTreeMap::new(),
            started: Instant::now(),
        }
    }

    pub fn incr(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += by;
    }

    pub fn set(&mut self, name: &str, value: f64) {
        self.gauges.insert(name.to_string(), value);
    }

    /// Record a wall-clock duration into the named log-scale histogram.
    pub fn observe_ns(&mut self, name: &str, ns: u64) {
        self.histograms
            .entry(name.to_string())
            .or_default()
            .observe(ns);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    pub fn histogram(&self, name: &str) -> Option<&Histogram> {
        self.histograms.get(name)
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// `{"deterministic": {"counters", "gauges"},
    ///   "wall_clock": {"elapsed_secs", "histograms"}}`.
    ///
    /// Only the `deterministic` section may ever be embedded in a
    /// byte-reproducible report.
    pub fn to_json(&self) -> Json {
        let mut counters = Json::obj();
        for (k, v) in &self.counters {
            counters.set(k, Json::Num(*v as f64));
        }
        let mut gauges = Json::obj();
        for (k, v) in &self.gauges {
            gauges.set(k, Json::Num(*v));
        }
        let mut det = Json::obj();
        det.set("counters", counters).set("gauges", gauges);

        let mut hists = Json::obj();
        for (k, h) in &self.histograms {
            hists.set(k, h.to_json());
        }
        let mut wall = Json::obj();
        wall.set("elapsed_secs", Json::Num(self.elapsed_secs()))
            .set("histograms", hists);

        let mut j = Json::obj();
        j.set("deterministic", det).set("wall_clock", wall);
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges() {
        let mut m = Metrics::new();
        m.incr("jobs", 3);
        m.incr("jobs", 2);
        m.set("alpha", 0.25);
        assert_eq!(m.counter("jobs"), 5);
        assert_eq!(m.counter("missing"), 0);
        assert_eq!(m.gauge("alpha"), Some(0.25));
        let j = m.to_json();
        let det = j.get("deterministic").unwrap();
        assert_eq!(
            det.get("counters").unwrap().get("jobs").unwrap().as_f64(),
            Some(5.0)
        );
        assert_eq!(
            det.get("gauges").unwrap().get("alpha").unwrap().as_f64(),
            Some(0.25)
        );
    }

    #[test]
    fn wall_clock_is_quarantined() {
        let mut m = Metrics::new();
        m.incr("jobs", 1);
        m.observe_ns("sweep", 1500);
        let j = m.to_json();
        // Nothing nondeterministic under "deterministic" ...
        let det = j.get("deterministic").unwrap();
        assert!(det.get("elapsed_secs").is_none());
        assert!(det.get("histograms").is_none());
        // ... and everything wall-clock under "wall_clock".
        let wall = j.get("wall_clock").unwrap();
        assert!(wall.get("elapsed_secs").unwrap().as_f64().unwrap() >= 0.0);
        assert_eq!(
            wall.get("histograms")
                .unwrap()
                .get("sweep")
                .unwrap()
                .get("count")
                .unwrap()
                .as_f64(),
            Some(1.0)
        );
        assert_eq!(m.histogram("sweep").unwrap().count(), 1);
        assert!(m.histogram("missing").is_none());
    }
}
