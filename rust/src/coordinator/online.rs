//! The long-running online coordinator loop: TOLA against a streaming
//! price feed.
//!
//! [`tola_run_online`] is the feed-driven counterpart of
//! [`super::tola_run_view`]. The event loop is the same fused
//! Algorithm 2 + Algorithm 4 — same heap order, same RNG stream, same
//! retire batching, same weight updates — with one added rule: **an event
//! may only be resolved once every feed has ingested the prices its
//! resolution reads**. Before each popped event the loop computes the slot
//! frontier that event's execution (or counterfactual sweep) will touch,
//! drains feed events until the [`crate::feed::FeedMux`] covers it, and
//! fails hard — a *lookahead error*, not a clamp — if the feed ends first.
//! Scheduling *decisions* (policy sampling, deadline allocation, the
//! self-owned grant) happen at arrival and read no prices at all.
//!
//! Because gating only ever interposes ingestion between events — never
//! reorders them, never touches the RNG — a run over a fully pre-loaded
//! feed is **bit-identical** to the batch `tola_run_view` on the same
//! trace (the streaming integration tests pin every report field).
//!
//! Between reporting windows the loop emits [`OnlineSnapshot`]s (realized
//! cost, regret vs the Prop. B.1 bound via
//! [`crate::learning::regret::RegretTracker::snapshot`], weight mass), so
//! a long-running process can be observed without waiting for the stream
//! to end.
//!
//! ## Bounded-memory streaming
//!
//! The hot loop is append-incremental end to end. View refreshes share the
//! ingested history ([`crate::feed::FeedBuffer`]'s Arc'd chunks — see
//! [`FeedMux::view`]), so a refresh costs O(new slots), not O(history).
//! Each live job carries a [`JobStream`]: its counterfactual window's
//! resampled prices and per-bid sweep prefix tables
//! ([`sweep::StreamingTables`]), grown a slot at a time as the shared
//! frontier advances past each sample midpoint. At retirement the
//! marshaling consumes the streamed window instead of re-reading the whole
//! trace, and the sweep adopts the streamed tables instead of rebuilding
//! them — bit-identical either way (the streaming property tests in
//! [`sweep`] pin exact equality under arbitrary append splits). Pool
//! availability (`navail`) cannot stream: `available_at` reflects
//! reservations made between arrival and retirement, so it is built at
//! retirement — once per job, shared across offers.
//!
//! With [`FeedMux::with_retention`] the feed evicts slots behind the
//! frontier and resident memory is O(retention). A window that reaches an
//! evicted slot is a hard error naming the slot (mirroring the lookahead
//! guard), never a silent clamp; when retention covers all live windows
//! the bounded run is byte-identical to the unbounded one.

use std::collections::BinaryHeap;

use anyhow::{bail, ensure, Result};

use crate::feed::FeedMux;
use crate::learning::counterfactual::{CfSpec, CounterfactualJob, S_MAX};
use crate::learning::regret::RegretTracker;
use crate::learning::{sweep, Tola};
use crate::market::{CapacityLedger, CostLedger, InstanceKind, MarketOffer, MarketView, PriceTrace, SelfOwnedPool, SLOTS_PER_UNIT};
use crate::policy::baselines::even_windows;
use crate::policy::dealloc::{dealloc, windows_to_deadlines};
use crate::policy::routing::{MigrationPolicy, RoutingPolicy};
use crate::policy::selfowned::{naive_allocation, rule12};
use crate::sim::executor::{
    execute_task, execute_task_routed_decide, execute_task_routed_migrating,
};
use crate::telemetry::{Recorder, SimEventKind, Telemetry};
use crate::util::rng::Pcg32;
use crate::workload::ChainJob;

use super::{evaluate_specs, spec_bid, Evaluator, LearningReport};

/// Options for an online run.
#[derive(Debug, Clone)]
pub struct OnlineOptions {
    pub routing: RoutingPolicy,
    /// Mid-window migration policy (disabled by default; enabling it only
    /// changes routed, non-degenerate runs).
    pub migration: MigrationPolicy,
    pub pool_capacity: u32,
    pub seed: u64,
    /// Emit an [`OnlineSnapshot`] every this many retired jobs
    /// (0 = final report only).
    pub snapshot_every: usize,
}

impl Default for OnlineOptions {
    fn default() -> Self {
        OnlineOptions {
            routing: RoutingPolicy::Home,
            migration: MigrationPolicy::disabled(),
            pool_capacity: 0,
            seed: 7,
            snapshot_every: 0,
        }
    }
}

/// Point-in-time progress of a streaming run.
#[derive(Debug, Clone)]
pub struct OnlineSnapshot {
    /// Jobs retired so far.
    pub jobs: u64,
    /// Simulated time of the retirement that triggered the snapshot.
    pub sim_time: f64,
    /// Shared feed frontier at the snapshot (slots ingested everywhere).
    pub ingested_slots: usize,
    /// Realized average unit cost over the retired jobs so far.
    pub average_unit_cost: f64,
    pub average_regret: f64,
    pub regret_bound: f64,
    /// Current maximum policy weight (convergence signal).
    pub max_weight: f64,
    /// Index of the currently most-probable policy.
    pub best_policy: usize,
}

/// Result of an online run: the batch-shaped final report plus the
/// streaming trajectory.
#[derive(Debug, Clone)]
pub struct OnlineReport {
    pub report: LearningReport,
    pub snapshots: Vec<OnlineSnapshot>,
    /// Final shared feed frontier (slots ingested on every feed).
    pub ingested_slots: usize,
}

/// The ingested market: a mux plus its latest materialized view. The view
/// is refreshed lazily — only when an event needs slots past what the
/// current materialization covers — so a fully pre-loaded feed
/// materializes exactly once.
struct LiveMarket {
    mux: FeedMux,
    view: MarketView,
    view_slots: usize,
}

impl LiveMarket {
    fn new(mut mux: FeedMux, tele: &Telemetry) -> Result<LiveMarket> {
        if !mux.advance_to_slot(1)? {
            bail!("feed delivered no price slots at all");
        }
        let view = {
            let _span = tele.span("online/view_refresh");
            mux.view()?
        };
        let view_slots = mux.frontier_slot();
        Ok(LiveMarket {
            mux,
            view,
            view_slots,
        })
    }

    /// Make the view cover `need` slots, ingesting as required. The
    /// lookahead guard lives here: an event that needs prices the feed has
    /// not delivered is a hard error.
    ///
    /// A view refresh shares the ingested history (Arc'd chunks), so it
    /// costs O(new slots); ingestion is still opportunistically advanced
    /// to double the current frontier whenever it must grow at all, so
    /// refresh count stays O(log S) on a pre-queued feed. Ingesting
    /// *queued feed data* ahead of `need` is not lookahead —
    /// only resolving an event whose reads outrun the feed is.
    fn ensure_slots(&mut self, need: usize, at: f64, tele: &Telemetry) -> Result<()> {
        if need > self.mux.frontier_slot() {
            let target = need.max(self.mux.frontier_slot().saturating_mul(2));
            self.mux.advance_to_slot(target)?;
            if self.mux.frontier_slot() < need {
                let (label, have) = self.mux.laggard();
                let dt = self.mux.slot_len();
                bail!(
                    "lookahead at t={at:.4}: resolving this event reads prices through \
                     slot {need} (t={:.4}) but feed '{label}' ends after {have} slots \
                     (t={:.4}); a streaming run never peeks past the ingested frontier",
                    need as f64 * dt,
                    have as f64 * dt
                );
            }
        }
        if need > self.view_slots {
            let _span = tele.span("online/view_refresh");
            self.view = self.mux.view()?;
            self.view_slots = self.mux.frontier_slot();
        }
        Ok(())
    }
}

/// Bounded-retention guard: every slot a window reads, starting at `slot`,
/// must still be resident in each trace it touches. Mirrors the lookahead
/// guard — reaching evicted history is a hard error naming the slot, never
/// a silent clamp.
fn ensure_resident(offers: &[MarketOffer], slot: usize, at: f64, what: &str) -> Result<()> {
    for o in offers {
        let first = o.trace.first_slot();
        if slot < first {
            bail!(
                "at t={at:.4}: {what} reads feed slot {slot}, but feed slot {slot} is \
                 evicted (retention starts at slot {first}); raise --retention so live \
                 windows stay resident"
            );
        }
    }
    Ok(())
}

/// Slots that must be ingested so every price read strictly before time
/// `t` is determined (the slot containing `t − ε`).
#[inline]
fn slots_through(t: f64, dt: f64) -> usize {
    (t / dt).ceil().max(0.0) as usize
}

/// Slots that must be ingested so the slot *containing* `t` is determined
/// (a read exactly at `t`, e.g. the router's `price_at(start)`).
#[inline]
fn slots_covering(t: f64, dt: f64) -> usize {
    (t / dt).floor().max(0.0) as usize + 1
}

/// One offer's streamed counterfactual window: the resampled prices plus
/// the per-bid sweep prefix tables, both grown one slot at a time.
struct OfferStream {
    prices: Vec<f64>,
    tables: sweep::StreamingTables,
}

/// A live job's append-incremental counterfactual state: the window
/// resampling that the retire-time `trace.resample_window` would perform,
/// replayed sample-by-sample as the shared frontier advances. Geometry
/// (`n`, `dt_out`, sample midpoints) replicates
/// [`PriceTrace::resample_window`] expression-for-expression, and table
/// appends replicate the batch table build, so a retirement that consumes
/// a complete stream is bit-identical to one that rebuilds from scratch.
struct JobStream {
    t0: f64,
    /// Resampled slot count before `+inf` padding (`native.clamp(1, S_MAX)`).
    n: usize,
    /// Resampled slot length `(t1 − t0) / n`.
    dt_out: f64,
    /// Sample midpoints streamed so far (`0..n`).
    filled: usize,
    /// Whether the out-of-window `+inf` table padding has been appended.
    padded: bool,
    /// One stream per sweep offer, in `MarketView::offers()` order.
    offers: Vec<OfferStream>,
}

impl JobStream {
    fn new(job: &ChainJob, slot_len: f64, n_offers: usize, bids: &[f64]) -> JobStream {
        // Same geometry as `PriceTrace::resample_window(arrival, deadline)`.
        let native = ((job.deadline - job.arrival) / slot_len).ceil() as usize;
        let n = native.clamp(1, S_MAX);
        let dt_out = (job.deadline - job.arrival) / n as f64;
        // Same shape the retire-time `SweepContext::new` will compute over
        // the S_MAX-padded price vector.
        let num_slots = sweep::sweep_num_slots(job.window(), dt_out, S_MAX);
        let offers = (0..n_offers)
            .map(|_| OfferStream {
                prices: Vec::with_capacity(n),
                tables: sweep::StreamingTables::new(bids, dt_out, num_slots),
            })
            .collect();
        JobStream { t0: job.arrival, n, dt_out, filled: 0, padded: false, offers }
    }

    /// Stream every sample midpoint the materialized view now covers.
    /// O(new slots) total across all calls; a no-op when the frontier has
    /// not passed the next midpoint. Errors when a midpoint's slot has
    /// already been evicted (retention too small for this live window).
    fn extend(&mut self, view: &MarketView, view_slots: usize, dt_feed: f64) -> Result<()> {
        while self.filled < self.n {
            // Same sample expression as `PriceTrace::resample_window`.
            let mid = self.t0 + (self.filled as f64 + 0.5) * self.dt_out;
            let slot = (mid / dt_feed).floor().max(0.0) as usize;
            if slot + 1 > view_slots {
                break;
            }
            ensure_resident(
                &view.offers()[..self.offers.len()],
                slot,
                mid,
                "this job's streamed counterfactual window",
            )?;
            for (k, os) in self.offers.iter_mut().enumerate() {
                let p = view.offers()[k].trace.price_at(mid);
                os.prices.push(p);
                os.tables.append(p);
            }
            self.filled += 1;
        }
        if self.filled == self.n && !self.padded {
            // Out-of-window padding slots carry +inf (never winning),
            // matching the `resize(S_MAX, +inf)` the batch resample does.
            for os in &mut self.offers {
                for _ in self.n..os.tables.num_slots() {
                    os.tables.append(f64::INFINITY);
                }
            }
            self.padded = true;
        }
        Ok(())
    }

    fn is_complete(&self) -> bool {
        self.padded
    }
}

/// Per-slot pool availability over a job's resampled window — built at
/// retirement (reservations between arrival and retirement change
/// `available_at`, so this cannot stream) and shared across all of the
/// job's per-offer marshalings as one allocation.
fn navail_for(
    pool: &Option<SelfOwnedPool>,
    job: &ChainJob,
    len: usize,
    dt: f64,
    horizon: f64,
) -> std::sync::Arc<[f64]> {
    match pool {
        Some(pl) => (0..len)
            .map(|k| {
                let t0 = job.arrival + k as f64 * dt;
                pl.available_at(t0.min(horizon)) as f64
            })
            .collect::<Vec<f64>>()
            .into(),
        None => vec![0.0; len].into(),
    }
}

/// Marshal one retired job's home-offer window: consume the streamed
/// prices/tables when complete, else fall back to the batch resample
/// (bit-identical values either way).
fn marshal_home(
    job: &ChainJob,
    stream: Option<JobStream>,
    trace: &PriceTrace,
) -> (Vec<f64>, f64, Option<sweep::StreamingTables>) {
    match stream {
        Some(js) if js.is_complete() => {
            let JobStream { dt_out, mut offers, .. } = js;
            let os = offers.swap_remove(0);
            let mut prices = os.prices;
            prices.resize(S_MAX, f64::INFINITY);
            (prices, dt_out, Some(os.tables))
        }
        _ => {
            let (prices, dt) = trace.resample_window(job.arrival, job.deadline, S_MAX);
            (prices, dt, None)
        }
    }
}

#[derive(Debug, PartialEq)]
enum EventKind {
    TaskStart(usize, usize),
    Retire(usize),
}

#[derive(Debug, PartialEq)]
struct Event {
    time: f64,
    seq: u64,
    kind: EventKind,
}

impl Eq for Event {}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        other
            .time
            .partial_cmp(&self.time)
            .unwrap()
            .then(other.seq.cmp(&self.seq))
    }
}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

struct JobState {
    spec: CfSpec,
    deadlines: Vec<f64>,
    cost: f64,
    done: bool,
}

/// Run TOLA online against a streaming market feed.
///
/// `jobs` is the arriving stream (arrival-ordered, like every coordinator
/// entry point); `feed` supplies prices incrementally. Each job is
/// scheduled using only already-ingested prices; task outcomes and
/// counterfactual sweeps resolve once the feed covers their windows, and
/// the run fails with a lookahead error if the feed ends while resolutions
/// are still pending — jobs are never silently priced against data the
/// stream did not deliver.
///
/// Over a fully pre-loaded feed ([`FeedMux::from_traces`]) the run is
/// bit-identical to [`super::tola_run_view`] on the same market.
pub fn tola_run_online(
    jobs: &[ChainJob],
    specs: &[CfSpec],
    feed: FeedMux,
    opts: &OnlineOptions,
    evaluator: &Evaluator,
) -> Result<OnlineReport> {
    tola_run_online_traced(
        jobs,
        specs,
        feed,
        opts,
        evaluator,
        &Telemetry::disabled(),
        &mut Recorder::disabled(),
    )
}

/// [`tola_run_online`] with telemetry: the batch-loop events plus
/// `frontier_advanced` whenever an event's ingestion gate grows the shared
/// feed frontier. Telemetry only observes — results are bit-identical
/// with the planes on or off.
pub fn tola_run_online_traced(
    jobs: &[ChainJob],
    specs: &[CfSpec],
    feed: FeedMux,
    opts: &OnlineOptions,
    evaluator: &Evaluator,
    tele: &Telemetry,
    rec: &mut Recorder,
) -> Result<OnlineReport> {
    ensure!(!jobs.is_empty() && !specs.is_empty(), "online run needs jobs and specs");
    let degenerate = feed.is_degenerate();
    let dt = feed.slot_len();
    let capacities = feed.capacities();
    let n_offers = feed.len();
    let routing = opts.routing;
    let migration = opts.migration;
    let mut market = LiveMarket::new(feed, tele)?;
    let od_price_home = market.view.home().od_price;

    // Streaming counterfactual state: one tracker per live job, over the
    // offers the retire-time sweep will marshal (home only for degenerate
    // feeds and Home routing; every offer otherwise).
    let track_offers = if degenerate || matches!(routing, RoutingPolicy::Home) {
        1
    } else {
        n_offers
    };
    let distinct_bids: Vec<f64> = specs.iter().map(spec_bid).collect();
    let mut streams: Vec<Option<JobStream>> = jobs.iter().map(|_| None).collect();

    // Identical sizing to the batch loop: lane/pool clamping near the
    // horizon must match for bit-identity.
    let horizon = jobs.iter().map(|j| j.deadline).fold(1.0, f64::max);
    let d_max = jobs.iter().map(|j| j.window()).fold(1.0, f64::max);
    let mut capacity = CapacityLedger::from_capacities(&capacities, dt, horizon + d_max + 1.0);
    let mut offer_work = vec![0.0f64; n_offers];
    let mut migrations = 0u64;
    let mut pool = (opts.pool_capacity > 0)
        .then(|| SelfOwnedPool::new(opts.pool_capacity, horizon, 1.0 / SLOTS_PER_UNIT as f64));
    let has_pool = pool.is_some();

    let mut tola = Tola::new(specs.len(), d_max);
    let mut regret = RegretTracker::new(specs.len(), d_max);
    let mut rng = Pcg32::new(opts.seed ^ 0x701A);
    let mut ledger = CostLedger::new();
    let mut weight_trajectory = Vec::new();
    let weight_sample_every = (jobs.len() / 200).max(1);

    let mut snapshots = Vec::new();
    let mut next_snapshot = if opts.snapshot_every > 0 {
        opts.snapshot_every as u64
    } else {
        u64::MAX
    };
    let mut retired_workload = 0.0f64;

    let mut heap = BinaryHeap::new();
    let mut seq = 0u64;
    let mut states: Vec<Option<JobState>> = jobs.iter().map(|_| None).collect();
    for (idx, job) in jobs.iter().enumerate() {
        heap.push(Event {
            time: job.arrival,
            seq,
            kind: EventKind::TaskStart(idx, 0),
        });
        seq += 1;
        heap.push(Event {
            time: job.deadline,
            seq,
            kind: EventKind::Retire(idx),
        });
        seq += 1;
    }

    while let Some(Event { time, kind, .. }) = heap.pop() {
        match kind {
            EventKind::TaskStart(ji, ti) => {
                let job = &jobs[ji];
                if ti == 0 {
                    // Arrival decisions (Algorithm 4 lines 8–9 + Algorithm
                    // 2 lines 1–5): policy sample + deadline allocation.
                    // No prices read — this is what "schedule using only
                    // already-ingested prices" means for arrivals.
                    let pick = tola.pick(&mut rng);
                    rec.emit(job.arrival, SimEventKind::SpecChosen { job: ji, spec: pick });
                    let spec = specs[pick];
                    let windows = match spec {
                        CfSpec::Proposed(p) => dealloc(job, p.dealloc_beta(has_pool)),
                        CfSpec::EvenNaive { .. } => even_windows(job),
                        CfSpec::DeallocNaive(p) => dealloc(job, p.beta),
                    };
                    states[ji] = Some(JobState {
                        spec,
                        deadlines: windows_to_deadlines(job, &windows),
                        cost: 0.0,
                        done: false,
                    });
                    let mut js = JobStream::new(job, dt, track_offers, &distinct_bids);
                    {
                        let _span = tele.span("online/stream_extend");
                        js.extend(&market.view, market.view_slots, dt)?;
                    }
                    streams[ji] = Some(js);
                }
                if ti >= job.num_tasks() {
                    let st = states[ji].as_mut().expect("state set at arrival");
                    st.done = true;
                    continue;
                }
                let (spec, deadline) = {
                    let st = states[ji].as_ref().expect("state set at arrival");
                    (st.spec, st.deadlines[ti].max(time))
                };
                let task = &job.tasks[ti];
                let start = time.min(deadline);
                rec.emit(start, SimEventKind::WindowOpened { job: ji, task: ti, start, deadline });
                let hat_s = (deadline - start).max(1e-12);
                let (bid, r) = match (&mut pool, spec) {
                    (None, s) => (spec_bid(&s), 0),
                    (Some(pl), CfSpec::Proposed(p)) => {
                        let r = match p.beta0 {
                            Some(beta0) => {
                                let n = pl.available_over(start, deadline);
                                let r =
                                    rule12(task.size, task.parallelism, hat_s, beta0, n);
                                pl.reserve(r, start, deadline);
                                r
                            }
                            None => 0,
                        };
                        (p.bid, r)
                    }
                    (Some(pl), s) => {
                        let n = pl.available_over(start, deadline);
                        let r = naive_allocation(task.parallelism, n);
                        pl.reserve(r, start, deadline);
                        (spec_bid(&s), r)
                    }
                };
                // Gate: the execution walk reads prices over
                // [start, deadline) — and, through its `t + ε` slot probe,
                // may touch the slot *containing* the deadline — while a
                // routed placement additionally reads the price at
                // `start`. `start == deadline` reads nothing (immediate
                // turning point).
                let need = if start < deadline {
                    slots_covering(deadline, dt)
                } else if !degenerate {
                    slots_covering(start, dt)
                } else {
                    0
                };
                if need > 0 {
                    let before = market.mux.frontier_slot();
                    let view_before = market.view_slots;
                    market.ensure_slots(need, time, tele)?;
                    let after = market.mux.frontier_slot();
                    if after > before {
                        rec.emit(time, SimEventKind::FrontierAdvanced { slots: after });
                    }
                    if market.view_slots > view_before {
                        let _span = tele.span("online/stream_extend");
                        for js in streams.iter_mut().flatten() {
                            js.extend(&market.view, market.view_slots, dt)?;
                        }
                    }
                    // The execution walk (and a routed placement) reads
                    // slots from the one containing `start` onward.
                    let read_offers = if degenerate {
                        &market.view.offers()[..1]
                    } else {
                        market.view.offers()
                    };
                    let first_read = (start / dt).floor().max(0.0) as usize;
                    ensure_resident(read_offers, first_read, time, "this task's window")?;
                    if rec.is_on() {
                        // Residency margin for the health plane: how far
                        // this read sat above the tightest eviction floor
                        // among the traces it touches.
                        let first_resident = read_offers
                            .iter()
                            .map(|o| o.trace.first_slot())
                            .max()
                            .unwrap_or(0);
                        rec.emit(
                            time,
                            SimEventKind::ResidencyProbe { slot: first_read, first_resident },
                        );
                    }
                }
                let (offer, out) = if degenerate {
                    (
                        0,
                        execute_task(
                            task.size,
                            task.parallelism,
                            start,
                            deadline,
                            r,
                            bid,
                            &market.view.home().trace,
                            od_price_home,
                        ),
                    )
                } else if migration.enabled() {
                    // Migration-capable walk. No extra ingestion gating is
                    // needed: `slots_covering(deadline, dt)` already covers
                    // every price the walk can read on ANY offer, because
                    // the FeedMux frontier is shared across all feeds.
                    // Work is charged to the task's final offer (matching
                    // the batch loop).
                    let (d, out, migs) = execute_task_routed_migrating(
                        task.size,
                        task.parallelism,
                        start,
                        deadline,
                        r,
                        bid,
                        &market.view,
                        &mut capacity,
                        routing,
                        migration,
                    );
                    rec.emit(
                        start,
                        SimEventKind::OfferRouted {
                            job: ji,
                            task: ti,
                            offer: d.offer,
                            spilled: d.offer != 0,
                        },
                    );
                    if !d.spot_capacity {
                        rec.emit(
                            start,
                            SimEventKind::CapacityExhausted { job: ji, task: ti, offer: d.offer },
                        );
                    }
                    for m in &migs {
                        rec.emit(
                            m.time,
                            SimEventKind::TaskMigrated {
                                job: ji,
                                task: ti,
                                from_offer: m.from_offer,
                                to_offer: m.to_offer,
                            },
                        );
                    }
                    migrations += migs.len() as u64;
                    let final_offer = migs.last().map(|m| m.to_offer).unwrap_or(d.offer);
                    (final_offer, out)
                } else {
                    // Migration disabled: the EXACT pre-migration code path
                    // (byte-identity by construction; see
                    // `tests/integration_migration.rs`).
                    let (d, out) = execute_task_routed_decide(
                        task.size,
                        task.parallelism,
                        start,
                        deadline,
                        r,
                        bid,
                        &market.view,
                        &mut capacity,
                        routing,
                    );
                    rec.emit(
                        start,
                        SimEventKind::OfferRouted {
                            job: ji,
                            task: ti,
                            offer: d.offer,
                            spilled: d.offer != 0,
                        },
                    );
                    if !d.spot_capacity {
                        rec.emit(
                            start,
                            SimEventKind::CapacityExhausted { job: ji, task: ti, offer: d.offer },
                        );
                    }
                    (d.offer, out)
                };
                offer_work[offer] += out.spot_work + out.od_work;
                ledger.charge(InstanceKind::SelfOwned, 1.0, out.so_work, 0.0);
                ledger.charge(InstanceKind::Spot, 1.0, out.spot_work, 0.0);
                ledger.cost_spot += out.spot_cost;
                ledger.charge(InstanceKind::OnDemand, 1.0, out.od_work, 0.0);
                ledger.cost_ondemand += out.od_cost;
                states[ji].as_mut().unwrap().cost += out.spot_cost + out.od_cost;
                heap.push(Event {
                    time: out.finish,
                    seq,
                    kind: EventKind::TaskStart(ji, ti + 1),
                });
                seq += 1;
            }
            EventKind::Retire(ji) => {
                // Identical retire batching to the batch loop (the drain
                // order is what makes the two bit-identical); the
                // counterfactual sweeps resample each job's whole window,
                // so gate on the latest deadline in the batch before
                // marshaling.
                let mut batch: Vec<(f64, usize)> = vec![(time, ji)];
                while matches!(
                    heap.peek().map(|e| &e.kind),
                    Some(EventKind::Retire(_))
                ) {
                    if let Some(Event { time: t2, kind: EventKind::Retire(j2), .. }) =
                        heap.pop()
                    {
                        batch.push((t2, j2));
                    }
                }
                let latest = batch.iter().map(|&(t, _)| t).fold(time, f64::max);
                let before = market.mux.frontier_slot();
                let view_before = market.view_slots;
                market.ensure_slots(slots_through(latest, dt), time, tele)?;
                let after = market.mux.frontier_slot();
                if after > before {
                    rec.emit(time, SimEventKind::FrontierAdvanced { slots: after });
                }
                if market.view_slots > view_before {
                    let _span = tele.span("online/stream_extend");
                    for js in streams.iter_mut().flatten() {
                        js.extend(&market.view, market.view_slots, dt)?;
                    }
                }
                rec.emit(
                    time,
                    SimEventKind::SweepBatch { retired: batch.len(), specs: specs.len() },
                );
                let sweep_span = tele.span("coordinator/sweep_batch");
                let trace = &market.view.home().trace;
                let all_costs: Vec<Vec<f64>> = if degenerate {
                    let marshal_span = tele.span("online/marshal");
                    let mut probe_slot = usize::MAX;
                    for &(_, ji) in &batch {
                        let start_slot = (jobs[ji].arrival / dt).floor().max(0.0) as usize;
                        probe_slot = probe_slot.min(start_slot);
                        ensure_resident(
                            &market.view.offers()[..1],
                            start_slot,
                            time,
                            "this job's counterfactual window",
                        )?;
                    }
                    if rec.is_on() {
                        // One probe per batch at the earliest slot the
                        // marshal re-reads (the batch's tightest margin).
                        let first_resident = market.view.home().trace.first_slot();
                        rec.emit(
                            time,
                            SimEventKind::ResidencyProbe { slot: probe_slot, first_resident },
                        );
                    }
                    let mut tabs: Vec<Option<sweep::StreamingTables>> =
                        Vec::with_capacity(batch.len());
                    let cfs: Vec<CounterfactualJob> = batch
                        .iter()
                        .map(|&(_, ji)| {
                            let job = &jobs[ji];
                            let (prices, dt, tab) =
                                marshal_home(job, streams[ji].take(), trace);
                            let navail = navail_for(&pool, job, prices.len(), dt, horizon);
                            tabs.push(tab);
                            CounterfactualJob::from_job(job, prices, dt, navail, od_price_home)
                        })
                        .collect();
                    drop(marshal_span);
                    match evaluator {
                        Evaluator::Native { threads } if cfs.len() > 1 => {
                            sweep::sweep_batch_costs_seeded(&cfs, &tabs, specs, has_pool, *threads)
                        }
                        Evaluator::Native { .. } => cfs
                            .iter()
                            .zip(&tabs)
                            .map(|(cf, tab)| {
                                sweep::eval_spec_costs_seeded(cf, tab.as_ref(), specs, has_pool)
                            })
                            .collect(),
                        _ => cfs
                            .iter()
                            .map(|cf| evaluate_specs(cf, specs, has_pool, evaluator))
                            .collect(),
                    }
                } else {
                    let marshal_span = tele.span("online/marshal");
                    let sweep_offers = match routing {
                        RoutingPolicy::Home => &market.view.offers()[..1],
                        _ => market.view.offers(),
                    };
                    let mut probe_slot = usize::MAX;
                    for &(_, ji) in &batch {
                        let start_slot = (jobs[ji].arrival / dt).floor().max(0.0) as usize;
                        probe_slot = probe_slot.min(start_slot);
                        ensure_resident(
                            sweep_offers,
                            start_slot,
                            time,
                            "this job's counterfactual window",
                        )?;
                    }
                    if rec.is_on() {
                        let first_resident = sweep_offers
                            .iter()
                            .map(|o| o.trace.first_slot())
                            .max()
                            .unwrap_or(0);
                        rec.emit(
                            time,
                            SimEventKind::ResidencyProbe { slot: probe_slot, first_resident },
                        );
                    }
                    let mut tabs: Vec<Vec<Option<sweep::StreamingTables>>> =
                        Vec::with_capacity(batch.len());
                    let cfs: Vec<Vec<CounterfactualJob>> = batch
                        .iter()
                        .map(|&(_, ji)| {
                            let job = &jobs[ji];
                            let streamed = streams[ji]
                                .take()
                                .filter(|js| {
                                    js.is_complete() && js.offers.len() == sweep_offers.len()
                                });
                            let (offer_data, dt): (
                                Vec<(Vec<f64>, Option<sweep::StreamingTables>)>,
                                f64,
                            ) = match streamed {
                                Some(js) => {
                                    let JobStream { dt_out, offers, .. } = js;
                                    let data = offers
                                        .into_iter()
                                        .map(|os| {
                                            let mut p = os.prices;
                                            p.resize(S_MAX, f64::INFINITY);
                                            (p, Some(os.tables))
                                        })
                                        .collect();
                                    (data, dt_out)
                                }
                                None => {
                                    let (home_prices, dt) = trace.resample_window(
                                        job.arrival,
                                        job.deadline,
                                        S_MAX,
                                    );
                                    let mut data = vec![(home_prices, None)];
                                    for o in &sweep_offers[1..] {
                                        data.push((
                                            o.trace
                                                .resample_window(job.arrival, job.deadline, S_MAX)
                                                .0,
                                            None,
                                        ));
                                    }
                                    (data, dt)
                                }
                            };
                            let navail = navail_for(&pool, job, S_MAX, dt, horizon);
                            let mut row_tabs = Vec::with_capacity(offer_data.len());
                            let row: Vec<CounterfactualJob> = offer_data
                                .into_iter()
                                .zip(sweep_offers)
                                .map(|((prices, tab), o)| {
                                    row_tabs.push(tab);
                                    CounterfactualJob::from_job(
                                        job,
                                        prices,
                                        dt,
                                        navail.clone(),
                                        o.od_price,
                                    )
                                })
                                .collect();
                            tabs.push(row_tabs);
                            row
                        })
                        .collect();
                    drop(marshal_span);
                    let threads = match evaluator {
                        Evaluator::Native { threads } => *threads,
                        Evaluator::Pjrt(_) => std::thread::available_parallelism()
                            .map(|n| n.get())
                            .unwrap_or(1),
                    };
                    sweep::sweep_batch_costs_multi_seeded(&cfs, &tabs, specs, has_pool, threads)
                };
                drop(sweep_span);
                for (&(t, ji), costs) in batch.iter().zip(&all_costs) {
                    let realized = states[ji].as_ref().map(|s| s.cost).unwrap_or(0.0);
                    tola.update(costs, t.max(d_max * 1.001));
                    regret.record(realized, costs);
                    retired_workload += jobs[ji].total_work();
                    let sampled = regret.jobs() % weight_sample_every as u64 == 0;
                    let snapshot_due = regret.jobs() >= next_snapshot;
                    // One max-weight fold per batch item, shared by the
                    // trajectory sample and the snapshot.
                    let wmax = if sampled || snapshot_due {
                        tola.weights().iter().cloned().fold(0.0f64, f64::max)
                    } else {
                        0.0
                    };
                    if sampled {
                        weight_trajectory.push(wmax);
                        if rec.is_on() {
                            rec.emit(
                                t,
                                SimEventKind::ParamSnapshot {
                                    jobs: regret.jobs() as usize,
                                    max_weight: wmax,
                                    best_policy: specs[tola.best()].label(),
                                    regret: regret.average_regret(),
                                    bound: regret.bound(0.05),
                                },
                            );
                        }
                    }
                    if snapshot_due {
                        let snap = regret.snapshot(0.05);
                        snapshots.push(OnlineSnapshot {
                            jobs: snap.jobs,
                            sim_time: t,
                            ingested_slots: market.mux.frontier_slot(),
                            average_unit_cost: if retired_workload > 0.0 {
                                ledger.total_cost() / retired_workload
                            } else {
                                0.0
                            },
                            average_regret: snap.average_regret,
                            regret_bound: snap.bound,
                            max_weight: wmax,
                            best_policy: tola.best(),
                        });
                        next_snapshot =
                            next_snapshot.saturating_add(opts.snapshot_every as u64);
                    }
                }
            }
        }
    }

    let total_workload: f64 = jobs.iter().map(|j| j.total_work()).sum();
    let pool_utilization = if opts.pool_capacity > 0 {
        ledger.work_selfowned / (opts.pool_capacity as f64 * horizon)
    } else {
        0.0
    };
    let report = LearningReport {
        jobs: jobs.len(),
        average_unit_cost: if total_workload > 0.0 {
            ledger.total_cost() / total_workload
        } else {
            0.0
        },
        total_workload,
        best_policy: tola.best(),
        final_weights: tola.weights().to_vec(),
        average_regret: regret.average_regret(),
        regret_bound: regret.bound(0.05),
        policy_mean_costs: regret.per_policy_means(),
        pool_utilization,
        weight_trajectory,
        offer_work,
        migrations,
        ledger,
    };
    Ok(OnlineReport {
        ingested_slots: market.mux.frontier_slot(),
        snapshots,
        report,
    })
}
