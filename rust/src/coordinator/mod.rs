//! The L3 coordinator: leader event loop tying together the workload
//! stream, the market, the policies, the online learner and the PJRT
//! runtime; plus the CLI front-end.
//!
//! The coordinator's event loop is Algorithm 2 + Algorithm 4 fused: at each
//! simulated moment it reacts to job arrivals (policy sampling + deadline
//! allocation), task starts (self-owned grants + spot/on-demand
//! allocation), and job retirements (counterfactual sweep + TOLA weight
//! update). The counterfactual sweep — the hot path — is dispatched to the
//! AOT-compiled PJRT kernel when artifacts are available, with a native
//! multi-threaded fallback.

pub mod config;
pub mod exec_pool;
pub mod metrics;
pub mod online;

pub use config::{Config, OfferConfig};
pub use exec_pool::parallel_map;
pub use metrics::Metrics;
pub use online::{
    tola_run_online, tola_run_online_traced, OnlineOptions, OnlineReport, OnlineSnapshot,
};

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::learning::counterfactual::{CfSpec, CounterfactualJob, S_MAX};
use crate::learning::regret::RegretTracker;
use crate::learning::{sweep, Tola};
use crate::market::{
    CapacityLedger, CostLedger, InstanceKind, MarketView, PriceTrace, SelfOwnedPool,
    SLOTS_PER_UNIT,
};
use crate::policy::baselines::even_windows;
use crate::policy::dealloc::{dealloc, windows_to_deadlines};
use crate::policy::routing::{MigrationPolicy, RoutingPolicy};
use crate::policy::selfowned::{naive_allocation, rule12};
use crate::policy::Policy;
use crate::runtime::ArtifactRuntime;
use crate::sim::executor::{
    execute_task, execute_task_routed_decide, execute_task_routed_migrating,
};
use crate::telemetry::{Recorder, SimEventKind, Telemetry};
use crate::util::rng::Pcg32;
use crate::workload::ChainJob;

/// How counterfactual sweeps are evaluated.
pub enum Evaluator<'a> {
    /// Native Rust sweep, chunked over `threads` workers.
    Native { threads: usize },
    /// The AOT PJRT kernel (proposed-policy grids only; benchmark specs
    /// fall back to native within the same run).
    Pjrt(&'a ArtifactRuntime),
}

/// Result of a TOLA learning run.
#[derive(Debug, Clone)]
pub struct LearningReport {
    pub jobs: usize,
    pub ledger: CostLedger,
    pub total_workload: f64,
    /// Realized average unit cost ᾱ.
    pub average_unit_cost: f64,
    /// Final weight distribution.
    pub final_weights: Vec<f64>,
    /// Index + label of the highest-weight policy.
    pub best_policy: usize,
    /// Average regret vs best fixed policy and the Prop. B.1 bound at 95%.
    pub average_regret: f64,
    pub regret_bound: f64,
    /// Per-policy mean counterfactual cost per job, in spec order — the
    /// fixed-policy cost surface ([`crate::learning::regret::RegretTracker::per_policy_means`])
    /// the fleet layer's cross-scenario robustness scoring consumes.
    pub policy_mean_costs: Vec<f64>,
    /// Self-owned utilization (busy fraction).
    pub pool_utilization: f64,
    /// Trajectory of the max weight (sampled every `weight_sample_every`
    /// updates) — for the convergence figure.
    pub weight_trajectory: Vec<f64>,
    /// Cloud work (spot + on-demand) charged per market offer, in view
    /// order; a single element for legacy single-trace runs.
    pub offer_work: Vec<f64>,
    /// Mid-window task migrations taken (0 whenever the run's
    /// [`MigrationPolicy`] is disabled — the default).
    pub migrations: u64,
}

#[derive(Debug, PartialEq)]
enum EventKind {
    TaskStart(usize, usize),
    Retire(usize),
}

#[derive(Debug, PartialEq)]
struct Event {
    time: f64,
    seq: u64,
    kind: EventKind,
}

impl Eq for Event {}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .time
            .partial_cmp(&self.time)
            .unwrap()
            .then(other.seq.cmp(&self.seq))
    }
}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Per-job live state during a learning run.
struct JobState {
    spec: CfSpec,
    deadlines: Vec<f64>,
    cost: f64,
    done: bool,
}

/// Run TOLA (Algorithm 4) over a stream of chain jobs against the legacy
/// single-trace market — the one-offer degenerate case of
/// [`tola_run_view`], kept as the convenience entry point every
/// pre-existing caller uses.
pub fn tola_run(
    jobs: &[ChainJob],
    specs: &[CfSpec],
    trace: &PriceTrace,
    pool_capacity: u32,
    od_price: f64,
    seed: u64,
    evaluator: &Evaluator,
) -> LearningReport {
    let view = MarketView::single(trace.clone(), od_price);
    tola_run_view(
        jobs,
        specs,
        &view,
        RoutingPolicy::Home,
        pool_capacity,
        seed,
        evaluator,
    )
}

/// [`tola_run`] with telemetry recording (see [`tola_run_view_traced`]).
#[allow(clippy::too_many_arguments)]
pub fn tola_run_traced(
    jobs: &[ChainJob],
    specs: &[CfSpec],
    trace: &PriceTrace,
    pool_capacity: u32,
    od_price: f64,
    seed: u64,
    evaluator: &Evaluator,
    tele: &Telemetry,
    rec: &mut Recorder,
) -> LearningReport {
    let view = MarketView::single(trace.clone(), od_price);
    tola_run_view_traced(
        jobs,
        specs,
        &view,
        RoutingPolicy::Home,
        MigrationPolicy::disabled(),
        pool_capacity,
        seed,
        evaluator,
        tele,
        rec,
    )
}

/// Run TOLA (Algorithm 4) over a stream of chain jobs against a
/// capacity-aware [`MarketView`].
///
/// `specs` is the policy set (the paper's `P` or `P'`); each arriving job
/// samples one spec from the current weights, is executed for real under
/// it (with pool contention, and — for multi-offer views — per-task
/// routing against remaining offer capacity), and at its deadline the
/// counterfactual sweep updates the weights.
///
/// A degenerate view (one offer, infinite capacity) takes the exact legacy
/// code path: direct `execute_task` against the home trace and the
/// single-offer sweep engine, so results are bit-identical to the
/// pre-`MarketView` single-trace implementation. Multi-offer or
/// finite-capacity views route every task ([`crate::policy::routing`]) and
/// sweep counterfactuals per offer (cheapest offer wins; capacity-free by
/// construction — see [`sweep::MultiSweepContext`]). The PJRT kernel only
/// accelerates the degenerate case; routed runs always use the native
/// engine.
pub fn tola_run_view(
    jobs: &[ChainJob],
    specs: &[CfSpec],
    view: &MarketView,
    routing: RoutingPolicy,
    pool_capacity: u32,
    seed: u64,
    evaluator: &Evaluator,
) -> LearningReport {
    tola_run_view_traced(
        jobs,
        specs,
        view,
        routing,
        MigrationPolicy::disabled(),
        pool_capacity,
        seed,
        evaluator,
        &Telemetry::disabled(),
        &mut Recorder::disabled(),
    )
}

/// [`tola_run_view`] with telemetry: sim-time events (spec sampled, window
/// opened, offer routed, capacity exhausted, sweep batch, parameter
/// snapshot) land in `rec`, wall-clock sweep spans in `tele`. With both
/// planes disabled every hook is a dead branch, and the learning results
/// are bit-identical either way — telemetry only *observes* the loop
/// (property-tested in `tests/integration_telemetry.rs`).
#[allow(clippy::too_many_arguments)]
pub fn tola_run_view_traced(
    jobs: &[ChainJob],
    specs: &[CfSpec],
    view: &MarketView,
    routing: RoutingPolicy,
    migration: MigrationPolicy,
    pool_capacity: u32,
    seed: u64,
    evaluator: &Evaluator,
    tele: &Telemetry,
    rec: &mut Recorder,
) -> LearningReport {
    assert!(!jobs.is_empty() && !specs.is_empty());
    let degenerate = view.is_degenerate();
    let home = view.home();
    let (trace, od_price) = (&home.trace, home.od_price);
    let horizon = jobs.iter().map(|j| j.deadline).fold(1.0, f64::max);
    let d_max = jobs.iter().map(|j| j.window()).fold(1.0, f64::max);
    let mut capacity = CapacityLedger::new(view, horizon + d_max + 1.0);
    let mut offer_work = vec![0.0f64; view.len()];
    let mut migrations = 0u64;
    let mut pool = (pool_capacity > 0)
        .then(|| SelfOwnedPool::new(pool_capacity, horizon, 1.0 / SLOTS_PER_UNIT as f64));
    let has_pool = pool.is_some();

    let mut tola = Tola::new(specs.len(), d_max);
    let mut regret = RegretTracker::new(specs.len(), d_max);
    let mut rng = Pcg32::new(seed ^ 0x701A);
    let mut ledger = CostLedger::new();
    let mut weight_trajectory = Vec::new();
    let weight_sample_every = (jobs.len() / 200).max(1);

    // Pre-sample policies and windows lazily at arrival: here arrival order
    // is the job order, and the heap interleaves task events across jobs.
    let mut heap = BinaryHeap::new();
    let mut seq = 0u64;
    let mut states: Vec<Option<JobState>> = jobs.iter().map(|_| None).collect();
    for (idx, job) in jobs.iter().enumerate() {
        heap.push(Event {
            time: job.arrival,
            seq,
            kind: EventKind::TaskStart(idx, 0),
        });
        seq += 1;
        heap.push(Event {
            time: job.deadline,
            seq,
            kind: EventKind::Retire(idx),
        });
        seq += 1;
    }

    while let Some(Event { time, kind, .. }) = heap.pop() {
        match kind {
            EventKind::TaskStart(ji, ti) => {
                let job = &jobs[ji];
                if ti == 0 {
                    // Arrival: sample a policy and allocate deadlines
                    // (Algorithm 4 lines 8–9 + Algorithm 2 lines 1–5).
                    let pick = tola.pick(&mut rng);
                    rec.emit(job.arrival, SimEventKind::SpecChosen { job: ji, spec: pick });
                    let spec = specs[pick];
                    let windows = match spec {
                        CfSpec::Proposed(p) => dealloc(job, p.dealloc_beta(has_pool)),
                        CfSpec::EvenNaive { .. } => even_windows(job),
                        CfSpec::DeallocNaive(p) => dealloc(job, p.beta),
                    };
                    states[ji] = Some(JobState {
                        spec,
                        deadlines: windows_to_deadlines(job, &windows),
                        cost: 0.0,
                        done: false,
                    });
                }
                if ti >= job.num_tasks() {
                    let st = states[ji].as_mut().expect("state set at arrival");
                    st.done = true;
                    continue;
                }
                let (spec, deadline) = {
                    let st = states[ji].as_ref().expect("state set at arrival");
                    (st.spec, st.deadlines[ti].max(time))
                };
                let task = &job.tasks[ti];
                let start = time.min(deadline);
                rec.emit(start, SimEventKind::WindowOpened { job: ji, task: ti, start, deadline });
                let hat_s = (deadline - start).max(1e-12);
                let (bid, r) = match (&mut pool, spec) {
                    (None, s) => (spec_bid(&s), 0),
                    (Some(pl), CfSpec::Proposed(p)) => {
                        let r = match p.beta0 {
                            Some(beta0) => {
                                let n = pl.available_over(start, deadline);
                                let r =
                                    rule12(task.size, task.parallelism, hat_s, beta0, n);
                                pl.reserve(r, start, deadline);
                                r
                            }
                            None => 0,
                        };
                        (p.bid, r)
                    }
                    (Some(pl), s) => {
                        let n = pl.available_over(start, deadline);
                        let r = naive_allocation(task.parallelism, n);
                        pl.reserve(r, start, deadline);
                        (spec_bid(&s), r)
                    }
                };
                let (offer, out) = if degenerate {
                    (
                        0,
                        execute_task(
                            task.size,
                            task.parallelism,
                            start,
                            deadline,
                            r,
                            bid,
                            trace,
                            od_price,
                        ),
                    )
                } else if migration.enabled() {
                    // Migration-capable walk. Work is charged to the task's
                    // FINAL offer: the view-order split only feeds the
                    // offer-share report, and per-boundary attribution
                    // would cost a per-move work ledger for no consumer.
                    let (d, out, migs) = execute_task_routed_migrating(
                        task.size,
                        task.parallelism,
                        start,
                        deadline,
                        r,
                        bid,
                        view,
                        &mut capacity,
                        routing,
                        migration,
                    );
                    rec.emit(
                        start,
                        SimEventKind::OfferRouted {
                            job: ji,
                            task: ti,
                            offer: d.offer,
                            spilled: d.offer != 0,
                        },
                    );
                    if !d.spot_capacity {
                        rec.emit(
                            start,
                            SimEventKind::CapacityExhausted { job: ji, task: ti, offer: d.offer },
                        );
                    }
                    for m in &migs {
                        rec.emit(
                            m.time,
                            SimEventKind::TaskMigrated {
                                job: ji,
                                task: ti,
                                from_offer: m.from_offer,
                                to_offer: m.to_offer,
                            },
                        );
                    }
                    migrations += migs.len() as u64;
                    let final_offer = migs.last().map(|m| m.to_offer).unwrap_or(d.offer);
                    (final_offer, out)
                } else {
                    // Migration disabled: the EXACT pre-migration code path
                    // (no new floating-point arithmetic executes), so
                    // disabling migration is byte-identical by construction.
                    let (d, out) = execute_task_routed_decide(
                        task.size,
                        task.parallelism,
                        start,
                        deadline,
                        r,
                        bid,
                        view,
                        &mut capacity,
                        routing,
                    );
                    rec.emit(
                        start,
                        SimEventKind::OfferRouted {
                            job: ji,
                            task: ti,
                            offer: d.offer,
                            spilled: d.offer != 0,
                        },
                    );
                    if !d.spot_capacity {
                        rec.emit(
                            start,
                            SimEventKind::CapacityExhausted { job: ji, task: ti, offer: d.offer },
                        );
                    }
                    (d.offer, out)
                };
                offer_work[offer] += out.spot_work + out.od_work;
                ledger.charge(InstanceKind::SelfOwned, 1.0, out.so_work, 0.0);
                ledger.charge(InstanceKind::Spot, 1.0, out.spot_work, 0.0);
                ledger.cost_spot += out.spot_cost;
                ledger.charge(InstanceKind::OnDemand, 1.0, out.od_work, 0.0);
                ledger.cost_ondemand += out.od_cost;
                states[ji].as_mut().unwrap().cost += out.spot_cost + out.od_cost;
                heap.push(Event {
                    time: out.finish,
                    seq,
                    kind: EventKind::TaskStart(ji, ti + 1),
                });
                seq += 1;
            }
            EventKind::Retire(ji) => {
                // Batch every retirement scheduled before the next task
                // event: nothing touches the pool in between, so the
                // counterfactual sweeps (Algorithm 4 lines 14–21) are
                // independent and fan across the worker pool. Weight
                // updates are applied afterwards in exact event order, so
                // results are identical to one-at-a-time retirement.
                let mut batch: Vec<(f64, usize)> = vec![(time, ji)];
                while matches!(
                    heap.peek().map(|e| &e.kind),
                    Some(EventKind::Retire(_))
                ) {
                    if let Some(Event { time: t2, kind: EventKind::Retire(j2), .. }) =
                        heap.pop()
                    {
                        batch.push((t2, j2));
                    }
                }
                rec.emit(
                    time,
                    SimEventKind::SweepBatch { retired: batch.len(), specs: specs.len() },
                );
                let sweep_span = tele.span("coordinator/sweep_batch");
                let all_costs: Vec<Vec<f64>> = if degenerate {
                    let cfs: Vec<CounterfactualJob> = batch
                        .iter()
                        .map(|&(_, ji)| {
                            let job = &jobs[ji];
                            let (prices, dt) =
                                trace.resample_window(job.arrival, job.deadline, S_MAX);
                            let navail: Vec<f64> = match &pool {
                                Some(pl) => (0..prices.len())
                                    .map(|k| {
                                        let t0 = job.arrival + k as f64 * dt;
                                        pl.available_at(t0.min(horizon)) as f64
                                    })
                                    .collect(),
                                None => vec![0.0; prices.len()],
                            };
                            CounterfactualJob::from_job(job, prices, dt, navail, od_price)
                        })
                        .collect();
                    match evaluator {
                        Evaluator::Native { threads } if cfs.len() > 1 => {
                            sweep::sweep_batch_costs(&cfs, specs, has_pool, *threads)
                        }
                        _ => cfs
                            .iter()
                            .map(|cf| evaluate_specs(cf, specs, has_pool, evaluator))
                            .collect(),
                    }
                } else {
                    // Multi-offer retirement: marshal the job once per
                    // *reachable* offer (that offer's resampled prices and
                    // od price — the window geometry and pool availability
                    // are offer-independent) and let the multi-sweep pick
                    // the cheapest offer per spec. Under Home routing only
                    // offer 0 is ever placeable, so the counterfactual
                    // market is restricted to it — sweeping unreachable
                    // offers would score specs against costs no policy can
                    // realize. Native engine only: the AOT kernel's fixed
                    // shape is single-market.
                    let sweep_offers = match routing {
                        RoutingPolicy::Home => &view.offers()[..1],
                        _ => view.offers(),
                    };
                    let cfs: Vec<Vec<CounterfactualJob>> = batch
                        .iter()
                        .map(|&(_, ji)| {
                            let job = &jobs[ji];
                            let (home_prices, dt) =
                                trace.resample_window(job.arrival, job.deadline, S_MAX);
                            // Offer-independent arrays are shared, not
                            // cloned, across the per-offer marshalings:
                            // one navail allocation per job, and offer 0
                            // borrows the home resample.
                            let home_prices: std::sync::Arc<[f64]> = home_prices.into();
                            let navail: std::sync::Arc<[f64]> = match &pool {
                                Some(pl) => (0..home_prices.len())
                                    .map(|k| {
                                        let t0 = job.arrival + k as f64 * dt;
                                        pl.available_at(t0.min(horizon)) as f64
                                    })
                                    .collect::<Vec<f64>>()
                                    .into(),
                                None => vec![0.0; home_prices.len()].into(),
                            };
                            sweep_offers
                                .iter()
                                .enumerate()
                                .map(|(k, o)| {
                                    let prices: std::sync::Arc<[f64]> = if k == 0 {
                                        home_prices.clone()
                                    } else {
                                        o.trace
                                            .resample_window(job.arrival, job.deadline, S_MAX)
                                            .0
                                            .into()
                                    };
                                    CounterfactualJob::from_job(
                                        job,
                                        prices,
                                        dt,
                                        navail.clone(),
                                        o.od_price,
                                    )
                                })
                                .collect()
                        })
                        .collect();
                    let threads = match evaluator {
                        Evaluator::Native { threads } => *threads,
                        // The kernel can't serve multi-offer sweeps; fall
                        // back to a fully-parallel native sweep rather
                        // than silently single-threading the hot path.
                        Evaluator::Pjrt(_) => std::thread::available_parallelism()
                            .map(|n| n.get())
                            .unwrap_or(1),
                    };
                    sweep::sweep_batch_costs_multi(&cfs, specs, has_pool, threads)
                };
                drop(sweep_span);
                for (&(t, ji), costs) in batch.iter().zip(&all_costs) {
                    let realized = states[ji].as_ref().map(|s| s.cost).unwrap_or(0.0);
                    tola.update(costs, t.max(d_max * 1.001));
                    regret.record(realized, costs);
                    if regret.jobs() % weight_sample_every as u64 == 0 {
                        let wmax = tola
                            .weights()
                            .iter()
                            .cloned()
                            .fold(0.0f64, f64::max);
                        weight_trajectory.push(wmax);
                        if rec.is_on() {
                            rec.emit(
                                t,
                                SimEventKind::ParamSnapshot {
                                    jobs: regret.jobs() as usize,
                                    max_weight: wmax,
                                    best_policy: specs[tola.best()].label(),
                                    regret: regret.average_regret(),
                                    bound: regret.bound(0.05),
                                },
                            );
                        }
                    }
                }
            }
        }
    }

    let total_workload: f64 = jobs.iter().map(|j| j.total_work()).sum();
    let pool_utilization = if pool_capacity > 0 {
        ledger.work_selfowned / (pool_capacity as f64 * horizon)
    } else {
        0.0
    };
    LearningReport {
        jobs: jobs.len(),
        average_unit_cost: if total_workload > 0.0 {
            ledger.total_cost() / total_workload
        } else {
            0.0
        },
        total_workload,
        best_policy: tola.best(),
        final_weights: tola.weights().to_vec(),
        average_regret: regret.average_regret(),
        regret_bound: regret.bound(0.05),
        policy_mean_costs: regret.per_policy_means(),
        pool_utilization,
        weight_trajectory,
        offer_work,
        migrations,
        ledger,
    }
}

pub(crate) fn spec_bid(spec: &CfSpec) -> f64 {
    match spec {
        CfSpec::Proposed(p) | CfSpec::DeallocNaive(p) => p.bid,
        CfSpec::EvenNaive { bid } => *bid,
    }
}

/// Evaluate all specs for one job, preferring the PJRT kernel for the
/// proposed-policy portion of the grid.
pub fn evaluate_specs(
    cf: &CounterfactualJob,
    specs: &[CfSpec],
    has_pool: bool,
    evaluator: &Evaluator,
) -> Vec<f64> {
    match evaluator {
        // One job is a single shared-structure sweep: O(L·log S) per spec
        // after the per-job precompute, so intra-job threading no longer
        // pays — `threads` fans *batches* of retirements instead
        // (see `tola_run` / `sweep::sweep_batch_costs`).
        Evaluator::Native { .. } => sweep::eval_spec_costs(cf, specs, has_pool),
        Evaluator::Pjrt(rt) => {
            // Split: contiguous Proposed prefix goes to the kernel,
            // everything else native (benchmark grids are tiny).
            let proposed: Vec<Policy> = specs
                .iter()
                .filter_map(|s| match s {
                    CfSpec::Proposed(p) => Some(*p),
                    _ => None,
                })
                .collect();
            let kernel_costs = if proposed.len() == specs.len() {
                rt.policy_cost
                    .eval(cf, &proposed, has_pool)
                    .map(|e| e.costs)
                    .ok()
            } else {
                None
            };
            match kernel_costs {
                Some(costs) => costs,
                None => sweep::eval_spec_costs(cf, specs, has_pool),
            }
        }
    }
}

/// CLI entrypoint (returns the process exit code).
pub fn cli_main() -> i32 {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match crate::experiments::dispatch(argv) {
        Ok(()) => 0,
        Err(e) => {
            crate::telemetry::Logger::default().error("repro", &format!("{e:#}"));
            1
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::market::SpotModel;
    use crate::policy::policy_set_spot_only;
    use crate::workload::{transform, GeneratorConfig, JobStream};

    fn setup(n: usize, seed: u64) -> (Vec<ChainJob>, PriceTrace) {
        let mut stream = JobStream::new(GeneratorConfig::small(), seed);
        let jobs: Vec<ChainJob> = stream.take_jobs(n).iter().map(transform).collect();
        let horizon = jobs.iter().map(|j| j.deadline).fold(0.0, f64::max) + 1.0;
        let trace = PriceTrace::generate(SpotModel::paper_default(), horizon, seed + 1);
        (jobs, trace)
    }

    #[test]
    fn tola_run_processes_all_jobs() {
        let (jobs, trace) = setup(60, 1);
        let specs: Vec<CfSpec> = policy_set_spot_only()
            .into_iter()
            .map(CfSpec::Proposed)
            .collect();
        let rep = tola_run(
            &jobs,
            &specs,
            &trace,
            0,
            1.0,
            42,
            &Evaluator::Native { threads: 1 },
        );
        assert_eq!(rep.jobs, 60);
        assert!(rep.average_unit_cost > 0.0 && rep.average_unit_cost <= 1.0);
        let wsum: f64 = rep.final_weights.iter().sum();
        assert!((wsum - 1.0).abs() < 1e-6);
        assert!((rep.ledger.total_work() - rep.total_workload).abs() < 1e-6 * rep.total_workload);
    }

    #[test]
    fn tola_learns_nontrivial_distribution() {
        let (jobs, trace) = setup(200, 3);
        let specs: Vec<CfSpec> = policy_set_spot_only()
            .into_iter()
            .map(CfSpec::Proposed)
            .collect();
        let rep = tola_run(
            &jobs,
            &specs,
            &trace,
            0,
            1.0,
            43,
            &Evaluator::Native { threads: 2 },
        );
        let wmax = rep.final_weights.iter().cloned().fold(0.0f64, f64::max);
        assert!(
            wmax > 2.0 / specs.len() as f64,
            "weights stayed ~uniform: max {wmax}"
        );
        assert!(rep.average_regret.is_finite());
    }

    #[test]
    fn tola_with_pool_uses_selfowned() {
        let (jobs, trace) = setup(60, 5);
        let specs: Vec<CfSpec> = crate::policy::policy_set_full()
            .into_iter()
            .map(CfSpec::Proposed)
            .collect();
        let rep = tola_run(
            &jobs,
            &specs,
            &trace,
            300,
            1.0,
            44,
            &Evaluator::Native { threads: 2 },
        );
        assert!(rep.ledger.work_selfowned > 0.0);
        assert!(rep.pool_utilization > 0.0);
    }

    #[test]
    fn one_offer_view_matches_legacy_entry_point_bitwise() {
        // The acceptance contract: a one-offer infinite-capacity view run
        // is the legacy single-trace run — same weights, same costs, under
        // every routing policy (routing makes no decision with one offer).
        let (jobs, trace) = setup(50, 11);
        let specs: Vec<CfSpec> = policy_set_spot_only()
            .into_iter()
            .map(CfSpec::Proposed)
            .collect();
        let legacy = tola_run(
            &jobs,
            &specs,
            &trace,
            120,
            1.0,
            46,
            &Evaluator::Native { threads: 2 },
        );
        for routing in [
            RoutingPolicy::Home,
            RoutingPolicy::CheapestFeasible,
            RoutingPolicy::Spillover,
        ] {
            let view = MarketView::single(trace.clone(), 1.0);
            let rep = tola_run_view(
                &jobs,
                &specs,
                &view,
                routing,
                120,
                46,
                &Evaluator::Native { threads: 2 },
            );
            assert_eq!(rep.average_unit_cost, legacy.average_unit_cost, "{routing:?}");
            assert_eq!(rep.average_regret, legacy.average_regret, "{routing:?}");
            assert_eq!(rep.final_weights, legacy.final_weights, "{routing:?}");
            assert_eq!(rep.best_policy, legacy.best_policy, "{routing:?}");
            assert_eq!(rep.offer_work.len(), 1);
            assert!(
                (rep.offer_work[0]
                    - (rep.ledger.work_spot + rep.ledger.work_ondemand))
                    .abs()
                    < 1e-9
            );
        }
    }

    #[test]
    fn routed_view_runs_and_spreads_work_across_offers() {
        use crate::market::MarketOffer;
        let (jobs, trace) = setup(80, 13);
        let horizon = jobs.iter().map(|j| j.deadline).fold(0.0, f64::max) + 1.0;
        // Home offer: capped tightly so contention forces routing; second
        // offer: always-available flat cheap market with pricier OD.
        let n = (horizon * crate::market::SLOTS_PER_UNIT as f64) as usize + 2;
        let flat = PriceTrace::from_prices(
            vec![0.25; n],
            1.0 / crate::market::SLOTS_PER_UNIT as f64,
        );
        let view = MarketView::new(vec![
            MarketOffer {
                region: "primary".into(),
                instance_type: "default".into(),
                od_price: 1.0,
                trace,
                capacity: Some(8),
            },
            MarketOffer {
                region: "overflow".into(),
                instance_type: "default".into(),
                od_price: 1.2,
                trace: flat,
                capacity: None,
            },
        ])
        .unwrap();
        let specs: Vec<CfSpec> = policy_set_spot_only()
            .into_iter()
            .map(CfSpec::Proposed)
            .collect();
        for routing in [RoutingPolicy::CheapestFeasible, RoutingPolicy::Spillover] {
            let rep = tola_run_view(
                &jobs,
                &specs,
                &view,
                routing,
                0,
                47,
                &Evaluator::Native { threads: 2 },
            );
            assert_eq!(rep.jobs, 80);
            assert_eq!(rep.offer_work.len(), 2);
            let total: f64 = rep.offer_work.iter().sum();
            assert!(
                (total - (rep.ledger.work_spot + rep.ledger.work_ondemand)).abs()
                    < 1e-6 * total.max(1.0),
                "{routing:?}: offer work {total}"
            );
            assert!(
                rep.offer_work[1] > 0.0,
                "{routing:?}: the 8-unit primary cap never spilled over"
            );
            assert!(rep.average_unit_cost > 0.0 && rep.average_unit_cost.is_finite());
        }
    }

    #[test]
    fn routed_run_is_reproducible() {
        use crate::market::MarketOffer;
        let (jobs, trace) = setup(40, 17);
        let horizon = jobs.iter().map(|j| j.deadline).fold(0.0, f64::max) + 1.0;
        let n = (horizon * crate::market::SLOTS_PER_UNIT as f64) as usize + 2;
        let alt = PriceTrace::from_prices(
            (0..n).map(|i| if i % 3 == 0 { 0.15 } else { 0.7 }).collect(),
            1.0 / crate::market::SLOTS_PER_UNIT as f64,
        );
        let view = MarketView::new(vec![
            MarketOffer {
                region: "a".into(),
                instance_type: "default".into(),
                od_price: 1.0,
                trace,
                capacity: Some(16),
            },
            MarketOffer {
                region: "b".into(),
                instance_type: "default".into(),
                od_price: 1.1,
                trace: alt,
                capacity: None,
            },
        ])
        .unwrap();
        let specs: Vec<CfSpec> = policy_set_spot_only()
            .into_iter()
            .map(CfSpec::Proposed)
            .collect();
        let run = |threads| {
            tola_run_view(
                &jobs,
                &specs,
                &view,
                RoutingPolicy::CheapestFeasible,
                0,
                48,
                &Evaluator::Native { threads },
            )
        };
        let a = run(1);
        let b = run(8);
        assert_eq!(a.average_unit_cost, b.average_unit_cost);
        assert_eq!(a.final_weights, b.final_weights);
        assert_eq!(a.offer_work, b.offer_work);
    }

    #[test]
    fn benchmark_specs_run_too() {
        let (jobs, trace) = setup(40, 7);
        let specs: Vec<CfSpec> = crate::policy::benchmark_bids()
            .into_iter()
            .map(|b| CfSpec::EvenNaive { bid: b })
            .collect();
        let rep = tola_run(
            &jobs,
            &specs,
            &trace,
            100,
            1.0,
            45,
            &Evaluator::Native { threads: 1 },
        );
        assert_eq!(rep.jobs, 40);
        assert!(rep.average_unit_cost > 0.0);
    }
}
