//! Leader/worker execution pool on std threads (tokio is unavailable
//! offline; the workload is CPU-bound policy sweeps, so scoped threads +
//! channels are the right tool anyway).

/// Run `f(i)` for `i in 0..n` across up to `threads` workers, collecting
/// results in index order. Panics in workers propagate.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let next = std::sync::atomic::AtomicUsize::new(0);
    let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let slots_ptr = SlotsPtr(slots.as_mut_ptr());

    std::thread::scope(|scope| {
        for _ in 0..threads {
            let next = &next;
            let f = &f;
            let slots_ptr = &slots_ptr;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let value = f(i);
                // SAFETY: each index i is claimed exactly once via the
                // atomic counter, so no two threads write the same slot, and
                // the scope guarantees the buffer outlives the workers.
                unsafe {
                    *slots_ptr.0.add(i) = Some(value);
                }
            });
        }
    });

    slots.into_iter().map(|s| s.expect("worker filled slot")).collect()
}

/// Send+Sync wrapper for the raw slot pointer (disjoint writes only).
struct SlotsPtr<T>(*mut Option<T>);
unsafe impl<T: Send> Send for SlotsPtr<T> {}
unsafe impl<T: Send> Sync for SlotsPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let out = parallel_map(100, 8, |i| i * i);
        assert_eq!(out.len(), 100);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn single_thread_path() {
        let out = parallel_map(5, 1, |i| i + 1);
        assert_eq!(out, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn empty_input() {
        let out: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn actually_parallel() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let peak = AtomicUsize::new(0);
        let live = AtomicUsize::new(0);
        parallel_map(32, 8, |_| {
            let cur = live.fetch_add(1, Ordering::SeqCst) + 1;
            peak.fetch_max(cur, Ordering::SeqCst);
            std::thread::sleep(std::time::Duration::from_millis(5));
            live.fetch_sub(1, Ordering::SeqCst);
        });
        assert!(peak.load(Ordering::SeqCst) >= 2, "never ran concurrently");
    }
}
