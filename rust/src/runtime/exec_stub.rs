//! Stub PJRT runtime for builds without the `pjrt` feature (the `xla`
//! bindings are not vendored; see Cargo.toml). Loading always fails with a
//! clear message, and every caller — the coordinator, the experiment
//! drivers, `bench_hotpath`, `pjrt_cross` — already treats a failed load as
//! "fall back to the native sweep engine", so default builds are fully
//! functional minus the kernel comparison paths.

use std::path::Path;

use anyhow::{bail, Result};

use crate::learning::counterfactual::{CounterfactualJob, PolicyGridEval};
use crate::policy::Policy;

/// Placeholder for the compiled policy-grid cost kernel.
pub struct PolicyCostKernel {
    _private: (),
}

/// Placeholder for the compiled TOLA weight-update kernel.
pub struct TolaUpdateKernel {
    _private: (),
}

/// Placeholder runtime: never constructible, so the kernel entry points
/// below are statically unreachable.
pub struct ArtifactRuntime {
    pub policy_cost: PolicyCostKernel,
    pub tola_update: Option<TolaUpdateKernel>,
}

impl ArtifactRuntime {
    pub fn load(_dir: &Path) -> Result<ArtifactRuntime> {
        bail!("built without the `pjrt` feature; PJRT artifacts cannot be loaded")
    }

    /// Load from the default artifact directory.
    pub fn load_default() -> Result<ArtifactRuntime> {
        Self::load(&super::artifact_dir())
    }
}

impl PolicyCostKernel {
    pub fn eval(
        &self,
        _job: &CounterfactualJob,
        _policies: &[Policy],
        _has_pool: bool,
    ) -> Result<PolicyGridEval> {
        bail!("built without the `pjrt` feature")
    }
}

impl TolaUpdateKernel {
    pub fn update(&self, _weights: &[f64], _costs: &[f64], _eta: f64) -> Result<Vec<f64>> {
        bail!("built without the `pjrt` feature")
    }
}
