//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts (HLO text) and
//! executes them on the request path. Python never runs here.
//!
//! Artifacts are produced once by `make artifacts`
//! (`python/compile/aot.py`):
//!
//! * `artifacts/policy_cost.hlo.txt` — the counterfactual policy-grid sweep
//!   ([`crate::learning::counterfactual`] semantics, shapes `L_MAX=128`,
//!   `S_MAX=2048`, `N_POL=192`);
//! * `artifacts/tola_update.hlo.txt` — the TOLA exponentiated-weights
//!   update.
//!
//! Interchange is HLO **text**: jax ≥ 0.5 serializes `HloModuleProto` with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md).

// The real runtime needs the unvendored `xla` bindings; enabling it means
// adding the dep AND a `pjrt = ["dep:xla"]` feature in Cargo.toml (see the
// note there). Default builds get the stub, whose loader always errors —
// every caller falls back to the native sweep engine.
#[cfg(feature = "pjrt")]
pub mod exec;
#[cfg(not(feature = "pjrt"))]
#[path = "exec_stub.rs"]
pub mod exec;
pub mod batch;

pub use batch::MarshalledJob;
pub use exec::{ArtifactRuntime, PolicyCostKernel, TolaUpdateKernel};

/// Default artifact directory, overridable with `DAGCLOUD_ARTIFACTS`.
pub fn artifact_dir() -> std::path::PathBuf {
    std::env::var("DAGCLOUD_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}
