//! Loading and executing the AOT artifacts through the PJRT CPU client.

use std::path::Path;

use anyhow::{Context, Result};

use super::batch::{MarshalledGrid, MarshalledJob};
use crate::learning::counterfactual::{CounterfactualJob, PolicyGridEval, L_MAX, N_POL, S_MAX};
use crate::policy::Policy;

/// The compiled policy-grid cost kernel.
pub struct PolicyCostKernel {
    exe: xla::PjRtLoadedExecutable,
}

/// The compiled TOLA weight-update kernel.
pub struct TolaUpdateKernel {
    exe: xla::PjRtLoadedExecutable,
}

/// Owns the PJRT client and the loaded executables.
pub struct ArtifactRuntime {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    pub policy_cost: PolicyCostKernel,
    pub tola_update: Option<TolaUpdateKernel>,
}

fn load_exe(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(path)
        .with_context(|| format!("loading HLO text from {}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .with_context(|| format!("compiling {}", path.display()))
}

impl ArtifactRuntime {
    /// Load all artifacts from `dir` (missing `tola_update` is tolerated:
    /// the native update is cheap; the policy-cost kernel is mandatory).
    pub fn load(dir: &Path) -> Result<ArtifactRuntime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let policy_cost = PolicyCostKernel {
            exe: load_exe(&client, &dir.join("policy_cost.hlo.txt"))?,
        };
        let tola_path = dir.join("tola_update.hlo.txt");
        let tola_update = if tola_path.exists() {
            Some(TolaUpdateKernel {
                exe: load_exe(&client, &tola_path)?,
            })
        } else {
            None
        };
        Ok(ArtifactRuntime {
            client,
            policy_cost,
            tola_update,
        })
    }

    /// Load from the default artifact directory.
    pub fn load_default() -> Result<ArtifactRuntime> {
        Self::load(&super::artifact_dir())
    }
}

fn lit_f32_1d(xs: &[f32]) -> xla::Literal {
    xla::Literal::vec1(xs)
}

fn lit_i32_1d(xs: &[i32]) -> xla::Literal {
    xla::Literal::vec1(xs)
}

fn lit_scalar(x: f32) -> xla::Literal {
    xla::Literal::scalar(x)
}

impl PolicyCostKernel {
    /// Run the policy-grid sweep for one job. Returns per-policy cost and
    /// work breakdown, truncated to the real grid size.
    pub fn eval(
        &self,
        job: &CounterfactualJob,
        policies: &[Policy],
        has_pool: bool,
    ) -> Result<PolicyGridEval> {
        let m = MarshalledJob::from_counterfactual(job);
        let g = MarshalledGrid::from_policies(policies, has_pool);
        debug_assert_eq!(m.e.len(), L_MAX);
        debug_assert_eq!(m.prices.len(), S_MAX);
        debug_assert_eq!(g.beta.len(), N_POL);

        let inputs = [
            lit_f32_1d(&m.e),
            lit_f32_1d(&m.delta),
            lit_f32_1d(&m.z),
            lit_f32_1d(&m.mask),
            lit_i32_1d(&m.order),
            lit_f32_1d(&m.prices),
            lit_f32_1d(&m.navail),
            lit_scalar(m.window),
            lit_scalar(m.dt),
            lit_f32_1d(&g.beta),
            lit_f32_1d(&g.beta0),
            lit_f32_1d(&g.bid_values),
            lit_i32_1d(&g.bid_idx),
            lit_f32_1d(&g.mask),
            lit_scalar(m.od_price),
            lit_scalar(g.has_pool),
        ];
        let result = self.exe.execute::<xla::Literal>(&inputs)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True; outputs: (cost, spot, od, so).
        let (cost, spot, od, so) = result.to_tuple4()?;
        let take = |lit: xla::Literal| -> Result<Vec<f64>> {
            Ok(lit
                .to_vec::<f32>()?
                .into_iter()
                .take(g.n)
                .map(|x| x as f64)
                .collect())
        };
        Ok(PolicyGridEval {
            costs: take(cost)?,
            spot_work: take(spot)?,
            od_work: take(od)?,
            so_work: take(so)?,
        })
    }
}

impl TolaUpdateKernel {
    /// `w' = normalize(w ⊙ exp(−η·(c − min c)))` on the padded grid.
    pub fn update(&self, weights: &[f64], costs: &[f64], eta: f64) -> Result<Vec<f64>> {
        assert_eq!(weights.len(), costs.len());
        assert!(weights.len() <= N_POL);
        let mut w = vec![0.0f32; N_POL];
        let mut c = vec![f32::MAX; N_POL]; // padded costs never win
        for (i, (&wi, &ci)) in weights.iter().zip(costs).enumerate() {
            w[i] = wi as f32;
            c[i] = ci as f32;
        }
        let inputs = [lit_f32_1d(&w), lit_f32_1d(&c), lit_scalar(eta as f32)];
        let result = self.exe.execute::<xla::Literal>(&inputs)?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out
            .to_vec::<f32>()?
            .into_iter()
            .take(weights.len())
            .map(|x| x as f64)
            .collect())
    }
}
