//! Marshalling between the native job/policy types and the fixed-shape f32
//! tensors of the AOT artifacts.

use crate::learning::counterfactual::{CounterfactualJob, L_MAX, NB_MAX, N_POL, S_MAX};
use crate::policy::Policy;

/// Padding price for unavailable/padded slots. A large finite value rather
/// than +inf: it never wins any bid and keeps f32 arithmetic NaN-free inside
/// the kernel.
pub const PRICE_PAD: f32 = 1.0e9;

/// A job padded to the artifact shapes.
#[derive(Debug, Clone)]
pub struct MarshalledJob {
    pub e: Vec<f32>,
    pub delta: Vec<f32>,
    pub z: Vec<f32>,
    pub mask: Vec<f32>,
    pub order: Vec<i32>,
    pub prices: Vec<f32>,
    pub navail: Vec<f32>,
    pub window: f32,
    pub dt: f32,
    pub od_price: f32,
    pub l: usize,
}

impl MarshalledJob {
    pub fn from_counterfactual(job: &CounterfactualJob) -> MarshalledJob {
        assert!(job.l <= L_MAX, "chain length {} exceeds L_MAX={L_MAX}", job.l);
        assert!(
            job.prices.len() <= S_MAX,
            "trace window {} exceeds S_MAX={S_MAX} (resample first)",
            job.prices.len()
        );
        let mut e = vec![0.0f32; L_MAX];
        let mut delta = vec![1.0f32; L_MAX]; // pad δ=1 avoids div-by-zero
        let mut z = vec![0.0f32; L_MAX];
        let mut mask = vec![0.0f32; L_MAX];
        // Padded order entries point at padded tasks (need = 0, no effect).
        let mut order: Vec<i32> = (0..L_MAX as i32).collect();
        for i in 0..job.l {
            e[i] = job.e[i] as f32;
            delta[i] = job.delta[i] as f32;
            z[i] = job.z[i] as f32;
            mask[i] = 1.0;
        }
        for (k, &oi) in job.order.iter().enumerate() {
            order[k] = oi as i32;
        }
        // Real tasks occupy order[0..l]; pads occupy the tail in index
        // order, skipping indices already used.
        let mut used = vec![false; L_MAX];
        for &oi in &job.order {
            used[oi] = true;
        }
        let mut tail = job.l;
        for i in 0..L_MAX {
            if !used[i] {
                order[tail] = i as i32;
                tail += 1;
            }
        }

        let mut prices = vec![PRICE_PAD; S_MAX];
        let mut navail = vec![0.0f32; S_MAX];
        for (k, &p) in job.prices.iter().enumerate() {
            prices[k] = if p.is_finite() { p as f32 } else { PRICE_PAD };
        }
        for (k, &n) in job.navail.iter().enumerate() {
            navail[k] = n as f32;
        }

        MarshalledJob {
            e,
            delta,
            z,
            mask,
            order,
            prices,
            navail,
            window: job.window as f32,
            dt: job.dt as f32,
            od_price: job.od_price as f32,
            l: job.l,
        }
    }
}

/// The policy grid padded to `N_POL` (masked tail). Bids are deduplicated
/// into `bid_values[NB_MAX]` + `bid_idx[N_POL]`: the AOT model resolves the
/// spot market once per distinct bid (the §6.1 grids have 5).
#[derive(Debug, Clone)]
pub struct MarshalledGrid {
    pub beta: Vec<f32>,
    pub beta0: Vec<f32>,
    pub bid_values: Vec<f32>,
    pub bid_idx: Vec<i32>,
    pub mask: Vec<f32>,
    pub has_pool: f32,
    pub n: usize,
}

impl MarshalledGrid {
    pub fn from_policies(policies: &[Policy], has_pool: bool) -> MarshalledGrid {
        assert!(
            policies.len() <= N_POL,
            "grid {} exceeds N_POL={N_POL}",
            policies.len()
        );
        let mut uniq: Vec<f32> = policies.iter().map(|p| p.bid as f32).collect();
        uniq.sort_by(|a, b| a.partial_cmp(b).unwrap());
        uniq.dedup();
        assert!(
            uniq.len() <= NB_MAX,
            "grid has {} distinct bids, max {NB_MAX}",
            uniq.len()
        );
        let mut beta = vec![1.0f32; N_POL];
        let mut beta0 = vec![0.0f32; N_POL];
        // Pad bid 0.0: wins nothing.
        let mut bid_values = vec![0.0f32; NB_MAX];
        bid_values[..uniq.len()].copy_from_slice(&uniq);
        let mut bid_idx = vec![0i32; N_POL];
        let mut mask = vec![0.0f32; N_POL];
        for (i, p) in policies.iter().enumerate() {
            beta[i] = p.beta as f32;
            beta0[i] = p.beta0.unwrap_or(0.0) as f32;
            bid_idx[i] = uniq
                .iter()
                .position(|&b| b == p.bid as f32)
                .expect("bid present") as i32;
            mask[i] = 1.0;
        }
        MarshalledGrid {
            beta,
            beta0,
            bid_values,
            bid_idx,
            mask,
            has_pool: if has_pool { 1.0 } else { 0.0 },
            n: policies.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::ChainJob;

    #[test]
    fn marshalling_pads_and_preserves() {
        let job = ChainJob::paper_example();
        let cf = CounterfactualJob::from_job(&job, vec![0.2; 48], 1.0 / 12.0, vec![3.0; 48], 1.0);
        let m = MarshalledJob::from_counterfactual(&cf);
        assert_eq!(m.l, 4);
        assert_eq!(m.e.len(), L_MAX);
        assert!((m.e[0] - 0.75).abs() < 1e-6);
        assert_eq!(m.mask[3], 1.0);
        assert_eq!(m.mask[4], 0.0);
        assert_eq!(m.delta[100], 1.0); // pad
        assert_eq!(m.prices[47], 0.2);
        assert_eq!(m.prices[48], PRICE_PAD);
        // Order is a permutation of 0..L_MAX.
        let mut sorted = m.order.clone();
        sorted.sort();
        assert_eq!(sorted, (0..L_MAX as i32).collect::<Vec<_>>());
        // Real tasks first: first 4 entries are the dealloc order (δ desc:
        // task 2 (δ=3), task 0 (δ=2), then tasks 1, 3 (δ=1)).
        assert_eq!(&m.order[..4], &[2, 0, 1, 3]);
    }

    #[test]
    fn grid_marshalling() {
        let grid = crate::policy::policy_set_full();
        let m = MarshalledGrid::from_policies(&grid, true);
        assert_eq!(m.n, 175);
        assert_eq!(m.mask[174], 1.0);
        assert_eq!(m.mask[175], 0.0);
        assert_eq!(m.has_pool, 1.0);
        assert!((m.beta0[0] - (2.0 / 12.0) as f32).abs() < 1e-6);
        // 5 distinct bids, dedup + indices roundtrip.
        assert_eq!(&m.bid_values[..5], &[0.18, 0.21, 0.24, 0.27, 0.3]);
        assert_eq!(m.bid_values[5], 0.0);
        for (i, p) in grid.iter().enumerate() {
            assert_eq!(m.bid_values[m.bid_idx[i] as usize], p.bid as f32);
        }
    }

    #[test]
    fn infinite_prices_become_pad() {
        let job = ChainJob::paper_example();
        let cf = CounterfactualJob::from_job(
            &job,
            vec![f64::INFINITY, 0.3],
            1.0 / 12.0,
            vec![0.0, 0.0],
            1.0,
        );
        let m = MarshalledJob::from_counterfactual(&cf);
        assert_eq!(m.prices[0], PRICE_PAD);
        assert_eq!(m.prices[1], 0.3);
    }
}
