//! Drivers regenerating Tables 2–6 of the paper.
//!
//! Each driver prints the table in the paper's layout and writes a JSON
//! result file. Absolute percentages differ from the paper (synthetic
//! market, our trace seeds), but the *shape* must hold: see EXPERIMENTS.md.

use anyhow::Result;

use crate::coordinator::{
    parallel_map, tola_run, tola_run_traced, tola_run_view_traced, Config, Evaluator,
};
use crate::learning::counterfactual::CfSpec;
use crate::market::PriceTrace;
use crate::policy::{benchmark_bids, policy_set_full, policy_set_spot_only, Policy};
use crate::sim::cost::{cost_improvement, min_unit_cost, utilization_ratio};
use crate::sim::horizon::{HorizonReport, HorizonRunner, StrategySpec};
use crate::util::json::Json;
use crate::workload::{transform, ChainJob, GeneratorConfig, JobStream};

/// Generate the chain workload for one job type.
pub fn workload(cfg: &Config, job_type: u8) -> (Vec<ChainJob>, PriceTrace) {
    let gen = GeneratorConfig::for_job_type(job_type);
    let mut stream = JobStream::new(gen, cfg.seed.wrapping_mul(1315423911) ^ job_type as u64);
    let jobs: Vec<ChainJob> = stream.take_jobs(cfg.jobs).iter().map(transform).collect();
    let horizon = jobs.iter().map(|j| j.deadline).fold(0.0, f64::max) + 1.0;
    let trace = PriceTrace::generate(cfg.spot_model.clone(), horizon, cfg.seed ^ 0x7ACE);
    (jobs, trace)
}

/// Sweep a list of strategy specs over a fixed workload in parallel,
/// returning one horizon report per spec.
fn sweep(
    jobs: &[ChainJob],
    trace: &PriceTrace,
    pool: u32,
    specs: &[StrategySpec],
    threads: usize,
) -> Vec<HorizonReport> {
    parallel_map(specs.len(), threads, |i| {
        HorizonRunner::new(trace, pool).run(jobs, specs[i])
    })
}

fn fmt_pct(x: f64) -> String {
    format!("{:6.2}%", 100.0 * x)
}

/// Experiment 1 / Table 2: cost improvement of the proposed deadline
/// allocation over Greedy and Even, spot + on-demand only.
pub fn run_table2(cfg: &Config, out_dir: &str) -> Result<()> {
    let log = *cfg.telemetry.logger();
    log.info("table2", "cost improvement, spot + on-demand only");
    log.info("table2", &format!("{} jobs/cell, seed {}", cfg.jobs, cfg.seed));
    let threads = cfg.effective_threads();
    let proposed_specs: Vec<StrategySpec> = policy_set_spot_only()
        .into_iter()
        .map(StrategySpec::Proposed)
        .collect();
    let greedy_specs: Vec<StrategySpec> = benchmark_bids()
        .into_iter()
        .map(|b| StrategySpec::GreedyBaseline { bid: b })
        .collect();
    let even_specs: Vec<StrategySpec> = benchmark_bids()
        .into_iter()
        .map(|b| StrategySpec::EvenBaseline { bid: b })
        .collect();

    let mut rho_greedy = Vec::new();
    let mut rho_even = Vec::new();
    let mut alphas = Vec::new();
    for x2 in 1..=4u8 {
        let (jobs, trace) = workload(cfg, x2);
        let (alpha, _) = min_unit_cost(&sweep(&jobs, &trace, 0, &proposed_specs, threads));
        let (alpha_greedy, _) = min_unit_cost(&sweep(&jobs, &trace, 0, &greedy_specs, threads));
        let (alpha_even, _) = min_unit_cost(&sweep(&jobs, &trace, 0, &even_specs, threads));
        rho_greedy.push(cost_improvement(alpha, alpha_greedy));
        rho_even.push(cost_improvement(alpha, alpha_even));
        alphas.push((alpha, alpha_greedy, alpha_even));
    }

    println!("          rho_0,1   rho_0,2   rho_0,3   rho_0,4");
    println!(
        "Greedy   {}  {}  {}  {}",
        fmt_pct(rho_greedy[0]),
        fmt_pct(rho_greedy[1]),
        fmt_pct(rho_greedy[2]),
        fmt_pct(rho_greedy[3])
    );
    println!(
        "Even     {}  {}  {}  {}",
        fmt_pct(rho_even[0]),
        fmt_pct(rho_even[1]),
        fmt_pct(rho_even[2]),
        fmt_pct(rho_even[3])
    );

    let mut j = Json::obj();
    j.set("table", Json::Str("2".into()))
        .set("jobs", Json::Num(cfg.jobs as f64))
        .set("seed", Json::Num(cfg.seed as f64))
        .set("rho_greedy", Json::from_f64_slice(&rho_greedy))
        .set("rho_even", Json::from_f64_slice(&rho_even))
        .set(
            "alpha",
            Json::Arr(
                alphas
                    .iter()
                    .map(|(a, g, e)| Json::from_f64_slice(&[*a, *g, *e]))
                    .collect(),
            ),
        );
    std::fs::write(format!("{out_dir}/table2.json"), j.pretty())?;
    Ok(())
}

/// Experiment 2 / Table 3: overall improvement with self-owned instances —
/// full framework vs Even + naive self-owned.
pub fn run_table3(cfg: &Config, out_dir: &str) -> Result<()> {
    let log = *cfg.telemetry.logger();
    log.info("table3", "overall cost improvement with self-owned instances");
    log.info("table3", &format!("{} jobs/cell, seed {}", cfg.jobs, cfg.seed));
    let threads = cfg.effective_threads();
    let proposed_specs: Vec<StrategySpec> = policy_set_full()
        .into_iter()
        .map(StrategySpec::Proposed)
        .collect();
    let even_specs: Vec<StrategySpec> = benchmark_bids()
        .into_iter()
        .map(|b| StrategySpec::EvenBaseline { bid: b })
        .collect();

    let mut rows = Vec::new();
    println!("  x1\\x2       1         2         3         4");
    for &x1 in &cfg.pool_sizes {
        let mut row = Vec::new();
        for x2 in 1..=4u8 {
            let (jobs, trace) = workload(cfg, x2);
            let (alpha, _) =
                min_unit_cost(&sweep(&jobs, &trace, x1 as u32, &proposed_specs, threads));
            let (alpha_bench, _) =
                min_unit_cost(&sweep(&jobs, &trace, x1 as u32, &even_specs, threads));
            row.push(cost_improvement(alpha, alpha_bench));
        }
        println!(
            "  {:>5}   {}  {}  {}  {}",
            x1,
            fmt_pct(row[0]),
            fmt_pct(row[1]),
            fmt_pct(row[2]),
            fmt_pct(row[3])
        );
        rows.push(row);
    }

    let mut j = Json::obj();
    j.set("table", Json::Str("3".into()))
        .set("jobs", Json::Num(cfg.jobs as f64))
        .set(
            "pool_sizes",
            Json::Arr(cfg.pool_sizes.iter().map(|&x| Json::Num(x as f64)).collect()),
        )
        .set(
            "rho",
            Json::Arr(rows.iter().map(|r| Json::from_f64_slice(r)).collect()),
        );
    std::fs::write(format!("{out_dir}/table3.json"), j.pretty())?;
    Ok(())
}

/// Experiment 3 / Tables 4+5: isolate rule (12) against the naive
/// self-owned policy (both sides use Dealloc windows); also report the
/// utilization ratio μ.
pub fn run_table4_5(cfg: &Config, out_dir: &str) -> Result<()> {
    let log = *cfg.telemetry.logger();
    log.info("table4+5", "self-owned policy (12) vs naive, same deadline allocation");
    log.info("table4+5", &format!("{} jobs/cell, seed {}", cfg.jobs, cfg.seed));
    let threads = cfg.effective_threads();
    let proposed_specs: Vec<StrategySpec> = policy_set_full()
        .into_iter()
        .map(StrategySpec::Proposed)
        .collect();
    // Benchmark: Dealloc(β) windows + naive self-owned, over (β, b) grid.
    let naive_specs: Vec<StrategySpec> = policy_set_spot_only()
        .into_iter()
        .map(StrategySpec::DeallocNaive)
        .collect();

    let mut rho_rows = Vec::new();
    let mut mu_rows = Vec::new();
    println!("  rho:  x1\\x2     1         2         3         4");
    for &x1 in &cfg.pool_sizes {
        let mut rho_row = Vec::new();
        let mut mu_row = Vec::new();
        for x2 in 1..=4u8 {
            let (jobs, trace) = workload(cfg, x2);
            let prop_reports = sweep(&jobs, &trace, x1 as u32, &proposed_specs, threads);
            let naive_reports = sweep(&jobs, &trace, x1 as u32, &naive_specs, threads);
            let (alpha, pi) = min_unit_cost(&prop_reports);
            let (alpha_naive, bi) = min_unit_cost(&naive_reports);
            rho_row.push(cost_improvement(alpha, alpha_naive));
            mu_row.push(utilization_ratio(&prop_reports[pi], &naive_reports[bi]));
        }
        println!(
            "  {:>5}   {}  {}  {}  {}",
            x1,
            fmt_pct(rho_row[0]),
            fmt_pct(rho_row[1]),
            fmt_pct(rho_row[2]),
            fmt_pct(rho_row[3])
        );
        rho_rows.push(rho_row);
        mu_rows.push(mu_row);
    }
    println!("  mu:   x1\\x2     1         2         3         4");
    for (k, &x1) in cfg.pool_sizes.iter().enumerate() {
        println!(
            "  {:>5}   {}  {}  {}  {}",
            x1,
            fmt_pct(mu_rows[k][0]),
            fmt_pct(mu_rows[k][1]),
            fmt_pct(mu_rows[k][2]),
            fmt_pct(mu_rows[k][3])
        );
    }

    let mut j = Json::obj();
    j.set("table", Json::Str("4+5".into()))
        .set("jobs", Json::Num(cfg.jobs as f64))
        .set(
            "pool_sizes",
            Json::Arr(cfg.pool_sizes.iter().map(|&x| Json::Num(x as f64)).collect()),
        )
        .set(
            "rho",
            Json::Arr(rho_rows.iter().map(|r| Json::from_f64_slice(r)).collect()),
        )
        .set(
            "mu",
            Json::Arr(mu_rows.iter().map(|r| Json::from_f64_slice(r)).collect()),
        );
    std::fs::write(format!("{out_dir}/table4_5.json"), j.pretty())?;
    Ok(())
}

fn make_evaluator(cfg: &Config) -> (Option<crate::runtime::ArtifactRuntime>, bool) {
    if !cfg.use_pjrt {
        return (None, false);
    }
    match crate::runtime::ArtifactRuntime::load_default() {
        Ok(rt) => (Some(rt), true),
        Err(e) => {
            cfg.telemetry
                .logger()
                .warn("pjrt", &format!("artifacts unavailable ({e}); using native sweeps"));
            (None, false)
        }
    }
}

/// Experiment 4 / Table 6: TOLA online learning, job type 2, pool sizes
/// {0} ∪ cfg.pool_sizes.
pub fn run_table6(cfg: &Config, out_dir: &str) -> Result<()> {
    let log = *cfg.telemetry.logger();
    log.info("table6", "cost improvement under online learning (x2 = 2)");
    log.info("table6", &format!("{} jobs/cell, seed {}", cfg.jobs, cfg.seed));
    let threads = cfg.effective_threads();
    let (rt, pjrt_active) = make_evaluator(cfg);
    log.info(
        "table6",
        &format!(
            "counterfactual evaluator: {}",
            if pjrt_active { "PJRT kernel" } else { "native" }
        ),
    );

    let (jobs, trace) = workload(cfg, 2);
    let mut pools: Vec<u64> = vec![0];
    pools.extend_from_slice(&cfg.pool_sizes);

    let mut rhos = Vec::new();
    for &x1 in &pools {
        let proposed: Vec<CfSpec> = if x1 == 0 {
            policy_set_spot_only().into_iter().map(CfSpec::Proposed).collect()
        } else {
            policy_set_full().into_iter().map(CfSpec::Proposed).collect()
        };
        let bench: Vec<CfSpec> = benchmark_bids()
            .into_iter()
            .map(|b| CfSpec::EvenNaive { bid: b })
            .collect();

        let evaluator = match &rt {
            Some(rt) => Evaluator::Pjrt(rt),
            None => Evaluator::Native { threads },
        };
        let rep_p = tola_run(&jobs, &proposed, &trace, x1 as u32, cfg.od_price, cfg.seed, &evaluator);
        let rep_b = tola_run(
            &jobs,
            &bench,
            &trace,
            x1 as u32,
            cfg.od_price,
            cfg.seed + 1,
            &Evaluator::Native { threads },
        );
        let rho = cost_improvement(rep_p.average_unit_cost, rep_b.average_unit_cost);
        println!(
            "  x1={:>5}: rho_bar = {}   (alpha_P={:.4}, alpha_P'={:.4}, regret={:.4} <= bound {:.4})",
            x1,
            fmt_pct(rho),
            rep_p.average_unit_cost,
            rep_b.average_unit_cost,
            rep_p.average_regret,
            rep_p.regret_bound
        );
        rhos.push(rho);
    }

    let mut j = Json::obj();
    j.set("table", Json::Str("6".into()))
        .set("jobs", Json::Num(cfg.jobs as f64))
        .set(
            "pools",
            Json::Arr(pools.iter().map(|&x| Json::Num(x as f64)).collect()),
        )
        .set("rho_bar", Json::from_f64_slice(&rhos))
        .set("pjrt", Json::Bool(pjrt_active));
    std::fs::write(format!("{out_dir}/table6.json"), j.pretty())?;
    Ok(())
}

/// `repro run`: one verbose TOLA learning run (the end-to-end demo).
pub fn run_single_tola(cfg: &Config, out_dir: &str) -> Result<()> {
    let log = *cfg.telemetry.logger();
    log.info(
        "run",
        &format!(
            "TOLA learning run: {} jobs, type {}, pool {}",
            cfg.jobs,
            cfg.job_type,
            cfg.pool_sizes.first().copied().unwrap_or(0)
        ),
    );
    let threads = cfg.effective_threads();
    // Multi-market configs (extra offers and/or a home capacity) realize
    // the full view and route; the default config is the degenerate
    // one-offer case and stays on the bit-identical legacy path. The PJRT
    // kernel only serves single-market sweeps, so routed runs go native.
    let multi = cfg.is_multi_market() || cfg.home_capacity.is_some();
    if cfg.migration.enabled() && !multi {
        log.info(
            "run",
            "migration is inert on a single-market config (nothing to migrate to)",
        );
    }
    let (rt, pjrt_active) = if multi { (None, false) } else { make_evaluator(cfg) };
    log.info(
        "run",
        &format!("evaluator: {}", if pjrt_active { "PJRT kernel" } else { "native" }),
    );
    let (jobs, trace) = workload(cfg, cfg.job_type);
    let pool = cfg.pool_sizes.first().copied().unwrap_or(0) as u32;
    let specs: Vec<CfSpec> = if pool == 0 {
        policy_set_spot_only().into_iter().map(CfSpec::Proposed).collect()
    } else {
        policy_set_full().into_iter().map(CfSpec::Proposed).collect()
    };
    let evaluator = match &rt {
        Some(rt) => Evaluator::Pjrt(rt),
        None => Evaluator::Native { threads },
    };
    let view = if multi {
        let horizon = jobs.iter().map(|j| j.deadline).fold(0.0, f64::max) + 1.0;
        let v = cfg.realize_view(trace.clone(), horizon)?;
        log.info(
            "run",
            &format!("market: {} offers, routing {}", v.len(), cfg.routing.as_str()),
        );
        Some(v)
    } else {
        None
    };
    let t0 = std::time::Instant::now();
    let mut rec = cfg.telemetry.recorder("run#0");
    let rep = match &view {
        Some(v) => tola_run_view_traced(
            &jobs,
            &specs,
            v,
            cfg.routing,
            cfg.migration,
            pool,
            cfg.seed,
            &evaluator,
            &cfg.telemetry,
            &mut rec,
        ),
        None => tola_run_traced(
            &jobs,
            &specs,
            &trace,
            pool,
            cfg.od_price,
            cfg.seed,
            &evaluator,
            &cfg.telemetry,
            &mut rec,
        ),
    };
    cfg.telemetry.absorb(rec);
    let dt = t0.elapsed().as_secs_f64();

    let best = match specs[rep.best_policy] {
        CfSpec::Proposed(p) => p,
        _ => unreachable!(),
    };
    println!("  processed {} jobs in {:.2}s ({:.0} jobs/s)", rep.jobs, dt, rep.jobs as f64 / dt);
    println!("  realized average unit cost: {:.4}", rep.average_unit_cost);
    println!(
        "  best policy: beta={:.3} beta0={} bid={:.2} (weight {:.3})",
        best.beta,
        best.beta0.map(|x| format!("{x:.3}")).unwrap_or("-".into()),
        best.bid,
        rep.final_weights[rep.best_policy]
    );
    println!(
        "  avg regret {:.4} (Prop B.1 bound {:.4}); pool util {:.1}%",
        rep.average_regret,
        rep.regret_bound,
        100.0 * rep.pool_utilization
    );

    let mut j = Json::obj();
    j.set("jobs", Json::Num(rep.jobs as f64))
        .set("alpha", Json::Num(rep.average_unit_cost))
        .set("regret", Json::Num(rep.average_regret))
        .set("regret_bound", Json::Num(rep.regret_bound))
        .set("pool_utilization", Json::Num(rep.pool_utilization))
        .set("weight_trajectory", Json::from_f64_slice(&rep.weight_trajectory))
        .set("elapsed_secs", Json::Num(dt))
        .set("jobs_per_sec", Json::Num(rep.jobs as f64 / dt));
    // Only routed runs add the market keys: degenerate tola_run.json stays
    // byte-identical to the pre-MarketView schema.
    if let Some(v) = &view {
        j.set("routing", Json::Str(cfg.routing.as_str().into()));
        let cloud: f64 = rep.offer_work.iter().sum::<f64>().max(1e-12);
        let mut shares = Json::obj();
        for (o, &w) in v.offers().iter().zip(&rep.offer_work) {
            shares.set(&o.label(), Json::Num(w / cloud));
        }
        j.set("offer_shares", shares);
        println!("  offer shares:");
        for (o, &w) in v.offers().iter().zip(&rep.offer_work) {
            println!("    {:<28} {:>5.1}%", o.label(), 100.0 * w / cloud);
        }
        // Migration-off runs keep the pre-migration byte shape.
        if cfg.migration.enabled() {
            j.set("migrations", Json::Num(rep.migrations as f64));
            println!(
                "  mid-window migrations: {} (switch cost {}, hysteresis {} slots)",
                rep.migrations, cfg.migration.switch_cost, cfg.migration.hysteresis_slots
            );
        }
    }
    std::fs::write(format!("{out_dir}/tola_run.json"), j.pretty())?;
    Ok(())
}

/// A policy from the §6.1 grids by index (test helper).
pub fn nth_policy(i: usize) -> Policy {
    let grid = policy_set_full();
    grid[i % grid.len()]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> Config {
        Config {
            jobs: 40,
            seed: 11,
            threads: 2,
            pool_sizes: vec![50],
            use_pjrt: false,
            ..Config::default()
        }
    }

    #[test]
    fn table2_shape_small() {
        let cfg = tiny_cfg();
        let dir = std::env::temp_dir().join("dagcloud_t2");
        std::fs::create_dir_all(&dir).unwrap();
        run_table2(&cfg, dir.to_str().unwrap()).unwrap();
        let j = Json::parse(
            &std::fs::read_to_string(dir.join("table2.json")).unwrap(),
        )
        .unwrap();
        let rho = j.get("rho_even").unwrap().as_arr().unwrap();
        assert_eq!(rho.len(), 4);
        // Proposed should never lose badly to the baselines.
        for r in rho {
            assert!(r.as_f64().unwrap() > -0.05);
        }
    }

    #[test]
    fn table6_runs_small() {
        let mut cfg = tiny_cfg();
        cfg.pool_sizes = vec![60];
        let dir = std::env::temp_dir().join("dagcloud_t6");
        std::fs::create_dir_all(&dir).unwrap();
        run_table6(&cfg, dir.to_str().unwrap()).unwrap();
        assert!(dir.join("table6.json").exists());
    }
}
