//! `repro scenarios`: run the built-in scenario registry (or a named
//! subset, or a custom spec file) across seeds and emit one comparable
//! report table (`results/scenarios.json`).

use anyhow::Result;

use crate::coordinator::Config;
use crate::scenario::{self, BatchOptions, ScenarioSpec};

/// CLI-level options for the `scenarios` subcommand.
#[derive(Debug, Clone, Default)]
pub struct ScenarioCliOptions {
    /// Restrict to these registry names (None = the full registry).
    pub names: Option<Vec<String>>,
    /// Replicates per scenario.
    pub seeds: u64,
    /// Reduced-size runs: small task chains and a small job count, so the
    /// full registry completes in seconds (CI smoke).
    pub smoke: bool,
    /// Additional custom spec file (JSON) appended to the batch.
    pub spec_file: Option<String>,
    /// Explicit `--jobs` override.
    pub jobs_override: Option<usize>,
}

/// Jobs per run under `--smoke` (unless `--jobs` says otherwise). Shared
/// with `repro fleet` so a fleet smoke run covers the same cells.
pub(crate) const SMOKE_JOBS: usize = 48;

/// Resolve a batch's worlds: the named registry subset (or the full
/// registry) plus an optional custom spec file, with duplicate names
/// rejected (names key both the seed derivation and the report grouping —
/// a duplicate would collide run seeds and merge two worlds into one
/// aggregate row). Shared by `repro scenarios` and `repro fleet`.
pub(crate) fn resolve_specs(
    names: &Option<Vec<String>>,
    spec_file: &Option<String>,
) -> Result<Vec<ScenarioSpec>> {
    let mut specs: Vec<ScenarioSpec> = match names {
        None => scenario::builtins(),
        Some(names) => names
            .iter()
            .map(|n| {
                scenario::find(n).ok_or_else(|| {
                    anyhow::anyhow!(
                        "unknown scenario '{n}'; known: {}",
                        scenario::builtin_names().join(", ")
                    )
                })
            })
            .collect::<Result<_>>()?,
    };
    if let Some(path) = spec_file {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("spec file '{path}': {e}"))?;
        specs.push(ScenarioSpec::parse(&text)?);
    }
    anyhow::ensure!(!specs.is_empty(), "no scenarios selected");
    for (i, s) in specs.iter().enumerate() {
        anyhow::ensure!(
            !specs[..i].iter().any(|o| o.name == s.name),
            "duplicate scenario name '{}' in batch (rename the --spec world)",
            s.name
        );
    }
    Ok(specs)
}

/// `repro scenarios --list`: print every registry world with its regime
/// tags and a one-line description (the only other way to discover world
/// names is reading `registry.rs`). With `--derive N`, additionally
/// print how many derived worlds each robustness operator would
/// contribute ([`crate::robustness::derivation_plan`]).
pub fn list_scenarios(derive: Option<usize>) {
    let worlds = scenario::builtins();
    println!("{} built-in scenario worlds:\n", worlds.len());
    for s in &worlds {
        // Descriptions are wrapped in the source; collapse to one line.
        let one_line = s.description.split_whitespace().collect::<Vec<_>>().join(" ");
        println!("  {:<24} [{}] {one_line}", s.name, s.tags.join(","));
    }
    if let Some(total) = derive {
        let plan = crate::robustness::derivation_plan(&worlds, total);
        let mut per_op: std::collections::BTreeMap<&str, usize> =
            std::collections::BTreeMap::new();
        for (_, op, n) in &plan {
            *per_op.entry(op).or_default() += n;
        }
        println!(
            "\n--derive {total} deals across {} (base, operator) pairs; per operator:",
            plan.len()
        );
        for (op, n) in per_op {
            println!("  {op:<12} {n:>5}");
        }
    }
    println!("\nrun one with: repro scenarios --scenario NAME  (or repro run --scenario NAME)");
}

pub fn run_scenarios(cfg: &Config, opts: &ScenarioCliOptions, out_dir: &str) -> Result<()> {
    let mut specs = resolve_specs(&opts.names, &opts.spec_file)?;

    let jobs_override = match (opts.smoke, opts.jobs_override) {
        (_, Some(j)) => {
            anyhow::ensure!(j > 0, "--jobs must be positive");
            Some(j)
        }
        (true, None) => Some(SMOKE_JOBS),
        (false, None) => None,
    };
    if opts.smoke {
        for s in &mut specs {
            s.workload.small_tasks = true;
        }
    }
    for s in &specs {
        s.validate()?;
    }

    let batch = BatchOptions {
        seeds: opts.seeds.max(1),
        base_seed: cfg.seed,
        threads: cfg.effective_threads(),
        jobs_override,
        telemetry: cfg.telemetry.clone(),
    };
    let log = *cfg.telemetry.logger();
    log.info(
        "scenarios",
        &format!(
            "{} worlds x {} seeds (base seed {}, threads {}{})",
            specs.len(),
            batch.seeds,
            batch.base_seed,
            batch.threads,
            if opts.smoke { ", smoke" } else { "" }
        ),
    );
    log.debug(
        "scenarios",
        &format!(
            "worlds: {}",
            specs.iter().map(|s| s.name.as_str()).collect::<Vec<_>>().join(", ")
        ),
    );
    let t0 = std::time::Instant::now();
    let outcomes = scenario::run_batch(&specs, &batch)?;
    let dt = t0.elapsed().as_secs_f64();

    println!(
        "  {:<24} {:>6} {:>8} {:>8} {:>7} {:>7} {:>7}",
        "scenario", "runs", "alpha", "regret", "util", "spot%", "od%"
    );
    for a in scenario::aggregate(&outcomes) {
        println!(
            "  {:<24} {:>6} {:>8.4} {:>8.4} {:>6.1}% {:>6.1}% {:>6.1}%",
            a.scenario,
            a.runs,
            a.alpha_mean,
            a.regret_mean,
            100.0 * a.pool_utilization_mean,
            100.0 * a.spot_share_mean,
            100.0 * a.od_share_mean
        );
    }
    log.info("scenarios", &format!("{} runs in {dt:.2}s", outcomes.len()));

    let j = scenario::report_json(&outcomes, batch.seeds, batch.base_seed, opts.smoke);
    let path = format!("{out_dir}/scenarios.json");
    std::fs::write(&path, j.pretty())?;
    log.info("scenarios", &format!("written to {path}"));
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn smoke_subset_writes_report() {
        let cfg = Config {
            jobs: 2000, // must be ignored: smoke picks its own size
            seed: 21,
            threads: 2,
            use_pjrt: false,
            ..Config::default()
        };
        let opts = ScenarioCliOptions {
            names: Some(vec!["paper-default".into(), "replayed-trace".into()]),
            seeds: 1,
            smoke: true,
            spec_file: None,
            jobs_override: Some(10),
        };
        let dir = std::env::temp_dir().join("dagcloud_scenarios");
        std::fs::create_dir_all(&dir).unwrap();
        run_scenarios(&cfg, &opts, dir.to_str().unwrap()).unwrap();
        let j = Json::parse(
            &std::fs::read_to_string(dir.join("scenarios.json")).unwrap(),
        )
        .unwrap();
        let arr = j.get("scenarios").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("name").unwrap().as_str().unwrap(), "paper-default");
        assert!(j.get("smoke").unwrap().as_bool().unwrap());
    }

    #[test]
    fn unknown_scenario_name_errors() {
        let cfg = Config {
            use_pjrt: false,
            ..Config::default()
        };
        let opts = ScenarioCliOptions {
            names: Some(vec!["not-a-world".into()]),
            seeds: 1,
            smoke: true,
            spec_file: None,
            jobs_override: None,
        };
        let err = run_scenarios(&cfg, &opts, "/tmp").unwrap_err();
        assert!(err.to_string().contains("unknown scenario"));
    }
}
