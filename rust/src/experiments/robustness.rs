//! `repro robustness`: derive a world population from registry bases,
//! run it as a sharded fleet, and gate policies on cross-regime tail
//! risk.
//!
//! The run writes, under `--out`:
//!
//! * `fleet_manifest.json` / `fleet_shard_<k>.json` / `fleet.json` — the
//!   ordinary fleet artifacts over `bases + derived` worlds (the derived
//!   population is computed *once*, before sharding, so the merged bytes
//!   stay invariant under `--shards`);
//! * `robustness.json` — the `dagcloud.robustness/v1` promotion-gate
//!   verdict table ([`crate::robustness::gate`]).
//!
//! Everything downstream of derivation reuses the fleet path unchanged:
//! a derived world is just a `ScenarioSpec` with an inline replay
//! market, so shard dealing, report merging, and byte-determinism come
//! for free (property-tested in `rust/tests/integration_robustness.rs`).

use anyhow::{ensure, Result};

use crate::coordinator::Config;
use crate::fleet::FleetAccumulator;
use crate::robustness::{
    derive_population, evaluate_gate, gate_json, render_gate_table, DeriveParams, GateConfig,
};

use super::fleet::run_sharded;
use super::scenarios::{resolve_specs, SMOKE_JOBS};

/// CLI-level options for the `robustness` subcommand.
#[derive(Debug, Clone)]
pub struct RobustnessCliOptions {
    /// Base registry worlds to derive from (None = the full registry).
    pub bases: Option<Vec<String>>,
    /// Derived worlds to add on top of the bases.
    pub derive: usize,
    /// Replicates per world (the population supplies the variance, so 1
    /// is the default).
    pub seeds: u64,
    /// Coordinators to deal the worlds across.
    pub shards: usize,
    /// Reduced-size runs (CI smoke).
    pub smoke: bool,
    /// Explicit `--jobs` override.
    pub jobs_override: Option<usize>,
    /// Promotion-gate threshold (`--gate-threshold`).
    pub gate_threshold: f64,
    /// Bootstrap block length in slots (`--block-slots`).
    pub block_slots: usize,
}

impl Default for RobustnessCliOptions {
    fn default() -> RobustnessCliOptions {
        RobustnessCliOptions {
            bases: None,
            derive: 64,
            seeds: 1,
            shards: 4,
            smoke: false,
            jobs_override: None,
            gate_threshold: GateConfig::default().threshold,
            block_slots: DeriveParams::default().block_slots,
        }
    }
}

/// Console rows of the verdict table before eliding to the JSON file.
const TABLE_HEAD: usize = 14;

pub fn run_robustness(cfg: &Config, opts: &RobustnessCliOptions, out_dir: &str) -> Result<()> {
    let mut bases = resolve_specs(&opts.bases, &None)?;
    if opts.smoke {
        // Before deriving, so derived worlds inherit the small chains.
        for s in &mut bases {
            s.workload.small_tasks = true;
        }
    }
    let params = DeriveParams {
        block_slots: opts.block_slots,
        ..DeriveParams::default()
    };
    let derived = derive_population(&bases, opts.derive, cfg.seed, &params)?;
    let log = *cfg.telemetry.logger();
    log.info(
        "robustness",
        &format!(
            "{} base world(s) + {} derived (seed {})",
            bases.len(),
            derived.len(),
            cfg.seed
        ),
    );
    let mut specs = bases;
    specs.extend(derived);

    let jobs_override = match (opts.smoke, opts.jobs_override) {
        (_, Some(j)) => {
            ensure!(j > 0, "--jobs must be positive");
            Some(j)
        }
        (true, None) => Some(SMOKE_JOBS),
        (false, None) => None,
    };

    let mut acc = FleetAccumulator::new();
    run_sharded(
        &mut acc,
        "robustness",
        &specs,
        cfg,
        opts.shards,
        opts.seeds,
        opts.smoke,
        jobs_override,
        out_dir,
    )?;

    let fleet = acc.fleet_json(None)?;
    let fleet_path = format!("{out_dir}/fleet.json");
    std::fs::write(&fleet_path, fleet.pretty())?;

    let report = evaluate_gate(
        &acc.canonical_outcomes(),
        &GateConfig {
            threshold: opts.gate_threshold,
        },
    );
    let mut rec = cfg.telemetry.recorder("robustness/gate");
    for v in &report.verdicts {
        if v.promoted {
            continue;
        }
        for regime in &v.failing_regimes {
            rec.emit(
                0.0,
                crate::telemetry::SimEventKind::GateDemotion {
                    policy: v.policy.clone(),
                    regime: regime.clone(),
                },
            );
        }
    }
    cfg.telemetry.absorb(rec);
    let table = render_gate_table(&report);
    for (i, line) in table.lines().enumerate() {
        if i < TABLE_HEAD {
            println!("  {line}");
        } else {
            println!(
                "  ... {} more policies (full table in robustness.json)",
                report.verdicts.len() + 2 - i
            );
            break;
        }
    }
    let gate_path = format!("{out_dir}/robustness.json");
    std::fs::write(&gate_path, gate_json(&report).pretty())?;
    log.info("robustness", &format!("written to {fleet_path} and {gate_path}"));
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn cfg() -> Config {
        Config {
            seed: 31,
            threads: 2,
            use_pjrt: false,
            ..Config::default()
        }
    }

    fn opts(shards: usize) -> RobustnessCliOptions {
        RobustnessCliOptions {
            bases: Some(vec!["paper-default".into()]),
            derive: 4,
            shards,
            smoke: true,
            jobs_override: Some(8),
            ..RobustnessCliOptions::default()
        }
    }

    fn tmp_dir(name: &str) -> String {
        let dir = std::env::temp_dir().join(name);
        std::fs::create_dir_all(&dir).unwrap();
        dir.to_string_lossy().into_owned()
    }

    #[test]
    fn robustness_outputs_are_invariant_under_shard_count() {
        let d1 = tmp_dir("dagcloud_robustness_k1");
        let d2 = tmp_dir("dagcloud_robustness_k2");
        run_robustness(&cfg(), &opts(1), &d1).unwrap();
        run_robustness(&cfg(), &opts(2), &d2).unwrap();
        for f in ["fleet.json", "robustness.json"] {
            let a = std::fs::read_to_string(format!("{d1}/{f}")).unwrap();
            let b = std::fs::read_to_string(format!("{d2}/{f}")).unwrap();
            assert_eq!(a, b, "{f} differs between --shards 1 and --shards 2");
        }
        let j =
            Json::parse(&std::fs::read_to_string(format!("{d1}/robustness.json")).unwrap())
                .unwrap();
        assert_eq!(
            j.get("schema").unwrap().as_str().unwrap(),
            "dagcloud.robustness/v1"
        );
        // 1 base + 4 derived worlds.
        assert_eq!(j.get("worlds").unwrap().as_u64().unwrap(), 5);
        let regimes = j.get("regimes").unwrap().as_arr().unwrap();
        assert!(
            regimes
                .iter()
                .any(|r| r.opt_str("tag", "") == "fault"),
            "derived fault worlds must appear as a regime"
        );
    }
}
