//! Experiment harness: one driver per table/figure of the paper's
//! evaluation (§6), plus the CLI dispatch.

pub mod tables;
pub mod figures;
pub mod perf;
pub mod scenarios;
pub mod feed;
pub mod fleet;
pub mod forensics;
pub mod robustness;

use crate::util::cli::Args;

const HELP: &str = "\
repro — reproduction of 'Towards Cost-Optimal Policies for DAGs to Utilize
IaaS Clouds with Online Learning' (Wu, Yu, Casale, Gao, 2021)

USAGE: repro <command> [options]

COMMANDS
  table2      Experiment 1: Dealloc vs Greedy/Even, spot+on-demand only
  table3      Experiment 2: full framework vs Even+naive, with self-owned pool
  table4      Experiment 3: rule (12) vs naive self-owned (cost improvement)
  table5      Experiment 3: self-owned utilization ratio μ
  table6      Experiment 4: TOLA online learning, proposed vs benchmark
  figures     Regenerate data series for Figures 1–4 (CSV to --out dir)
  sweep       Counterfactual sweep-engine throughput (naive vs closed-form
              vs batched; EXPERIMENTS.md §Perf)
  scenarios   Run the scenario registry (or a subset) across seeds and emit
              results/scenarios.json (see EXPERIMENTS.md §Scenarios)
  feed        Stream a real price dump through the online coordinator loop
              (ingestion stats, per-window snapshots, results/feed_run.json;
              see EXPERIMENTS.md §Streaming)
  fleet       Shard the scenario registry across coordinators, merge their
              reports into results/fleet.json, and rank cross-scenario
              policy robustness (see EXPERIMENTS.md §Fleet)
  robustness  Derive a world population from registry bases (bootstrap /
              oversample / spike / capdrop / gap operators), run it as a
              sharded fleet, and gate policies on cross-regime tail risk
              (results/robustness.json; see EXPERIMENTS.md §Robustness)
  run         One TOLA learning run with progress output
  trace       Like `run`, with the wall-clock span profiler forced on; the
              spans land in <out>/trace.json (Chrome trace-event JSON,
              loadable in chrome://tracing or Perfetto); add --events to
              also dump the deterministic event plane as <out>/events.jsonl
              (one canonical-order JSON event per line, grep-able)
  health      Fold telemetry.json event logs into <out>/health.json
              (dagcloud.health/v1: per-cell feed lag, eviction margins,
              capacity headroom, regret-vs-bound; see EXPERIMENTS.md §Health)
  diff        Structural diff of two dagcloud.* documents; when both carry
              deterministic event logs, also prints the first diverging
              (sim_time, source, seq) event with ±K context. Exits non-zero
              when the documents differ
  all         Run every table (tables 2–6) and figures

TELEMETRY OPTIONS (every command)
  --telemetry     record both telemetry planes and write <out>/telemetry.json
                  (dagcloud.telemetry/v1); never changes report bytes
  --health        record the deterministic event plane and additionally fold
                  it into <out>/health.json (dagcloud.health/v1); never
                  changes report bytes
  --chrome-trace  record wall-clock spans and write <out>/trace.json
                  (--trace is kept as a deprecated alias everywhere except
                  `repro feed`, where --trace names the input price dump)
  -v, --verbose   debug-level status lines on stderr
  -q, --quiet     silence status lines (machine-readable output only)

OPTIONS
  --jobs N        jobs per cell (default 2000; paper uses ~10000)
  --seed N        RNG seed (default 7)
  --threads N     worker threads (default: all cores)
  --pool LIST     self-owned pool sizes, e.g. 300,600,900,1200
  --job-type N    job type x2 for `run` (default 2)
  --out DIR       output directory for JSON/CSV results (default results)
  --no-pjrt       disable the PJRT kernel (native counterfactuals only)
  --config FILE   load a JSON config (CLI flags override)
  --switch-cost X enable slot-level mid-window migration for `run`/`trace`
                  on routed multi-offer configs: an in-flight task moves to
                  a cheaper feasible offer when the projected saving exceeds
                  X (see EXPERIMENTS.md §Migration)
  --hysteresis N  hold N slots after each migration before the next switch
                  is considered (requires --switch-cost; default 0)

SCENARIO OPTIONS (`repro scenarios`; `--scenario` also configures `run`)
  --list          print the registry worlds with regime tags and one-line
                  descriptions (add --derive N for the derivation census)
  --scenario LIST comma-separated registry names (default: all built-ins)
  --seeds N       replicates per scenario (default 3)
  --spec FILE     append a custom scenario spec (JSON) to the batch
  --smoke         reduced-size deterministic runs for CI (small chains,
                  48 jobs unless --jobs overrides)

FLEET OPTIONS (`repro fleet`; also honors --scenario/--seeds/--spec/--smoke
and --jobs with the `scenarios` semantics)
  --shards K      coordinators to deal the worlds across (default 4); the
                  merged fleet.json is byte-identical for every K
  --merge-only L  comma-separated existing dagcloud.scenarios/v1 shard
                  reports: merge them instead of running anything
  --online L      comma-separated dagcloud.feed/v1 reports (repro feed)
                  merged as online snapshot sources into fleet.json

ROBUSTNESS OPTIONS (`repro robustness`; also honors --seeds/--smoke/--jobs)
  --base LIST     base registry worlds to derive from (default: all)
  --derive N      derived worlds on top of the bases (default 64)
  --shards K      coordinators (default 4); fleet.json and robustness.json
                  are byte-identical for every K
  --gate-threshold X  per-regime mean regret/bound ceiling (default 0.25)
  --block-slots N bootstrap block length in slots (default 24)

HEALTH / DIFF OPTIONS
  health INPUT... one or more dagcloud.telemetry/v1 files (duplicate cell
                  sources across inputs are a hard error; harness sources
                  are excluded, so the doc is shard-invariant)
  diff A B        the two documents to compare
  --context K     events of context around the first divergence (default 8)

FEED OPTIONS (`repro feed`)
  --trace PATH    price dump to stream (required)
  --format F      ec2-json | csv (default: inferred from the extension)
  --scenario NAME take workload/pool/policy set from a registry world
                  (the market always comes from the feed)
  --time-scale X  timestamps -> simulated units (default: 1/3600 when the
                  dump carries ISO epoch-second timestamps, 1.0 for
                  numeric time,price rows)
  --price-scale X price normalization vs on-demand (default 1.0)
  --az NAME       restrict a multi-series dump to one availability zone
  --instance-type NAME  restrict to one instance type
  --snapshot-every N    snapshot cadence in retired jobs (default ~10/run)
  --retention SLOTS     bounded retention: evict feed slots more than SLOTS
                  behind the frontier (resident memory O(SLOTS); report is
                  byte-identical to unbounded while live windows stay
                  resident, and a window reaching an evicted slot is a
                  hard error). Default: retain the full history
";

/// Comma-separated list option (`--key a,b,c`), `None` when absent.
fn csv_list(args: &Args, key: &str) -> Option<Vec<String>> {
    args.get(key).map(|s| {
        s.split(',')
            .map(|x| x.trim().to_string())
            .filter(|x| !x.is_empty())
            .collect()
    })
}

/// CLI dispatch for `repro`.
pub fn dispatch(argv: Vec<String>) -> anyhow::Result<()> {
    // The Chrome-export flag is --chrome-trace on every subcommand;
    // `repro feed` predates it and uses --trace as a valued option (the
    // input price dump), so the deprecated boolean alias --trace is only
    // registered elsewhere.
    let is_feed = argv.first().is_some_and(|s| s == "feed");
    let mut flag_names = vec![
        "no-pjrt",
        "verbose",
        "smoke",
        "list",
        "telemetry",
        "quiet",
        "health",
        "chrome-trace",
        "events",
    ];
    if !is_feed {
        flag_names.push("trace");
    }
    let args = Args::parse(argv, &flag_names);
    let cmd = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("help");

    let level = if args.flag("quiet") {
        crate::telemetry::LogLevel::Quiet
    } else if args.flag("verbose") {
        crate::telemetry::LogLevel::Debug
    } else {
        crate::telemetry::LogLevel::Info
    };
    let events_on = args.flag("telemetry")
        || args.flag("health")
        || (cmd == "trace" && args.flag("events"));
    let trace_on =
        cmd == "trace" || args.flag("chrome-trace") || (!is_feed && args.flag("trace"));
    let tele = crate::telemetry::Telemetry::new(crate::telemetry::TelemetryOptions {
        events: events_on,
        spans: events_on || trace_on,
        level,
    });

    let mut cfg = match args.get("config") {
        Some(path) => crate::coordinator::Config::from_json_file(path)?,
        None => crate::coordinator::Config::default(),
    };
    cfg.jobs = args.get_u64("jobs", cfg.jobs as u64)? as usize;
    cfg.seed = args.get_u64("seed", cfg.seed)?;
    cfg.threads = args.get_u64("threads", cfg.threads as u64)? as usize;
    cfg.job_type = args.get_u64("job-type", cfg.job_type as u64)? as u8;
    cfg.pool_sizes = args.get_u64_list("pool", &cfg.pool_sizes)?;
    if args.flag("no-pjrt") {
        cfg.use_pjrt = false;
    }
    // Migration is enabled by a finite switch cost; --hysteresis alone is
    // dead weight (there is nothing to dampen) and refused loudly.
    if args.get("switch-cost").is_some() {
        let m = crate::policy::routing::MigrationPolicy {
            switch_cost: args.get_f64("switch-cost", 0.0)?,
            hysteresis_slots: args.get_u64("hysteresis", 0)? as u32,
        };
        m.validate()?;
        cfg.migration = m;
    } else if args.get("hysteresis").is_some() {
        anyhow::bail!("--hysteresis requires --switch-cost (a finite switch cost enables migration)");
    }
    cfg.telemetry = tele.clone();
    let out_dir = args.get_str("out", "results");
    std::fs::create_dir_all(&out_dir).ok();

    match cmd {
        "table2" => tables::run_table2(&cfg, &out_dir)?,
        "table3" => tables::run_table3(&cfg, &out_dir)?,
        "table4" => tables::run_table4_5(&cfg, &out_dir)?,
        "table5" => tables::run_table4_5(&cfg, &out_dir)?,
        "table6" => tables::run_table6(&cfg, &out_dir)?,
        "figures" => figures::run_all(cfg.telemetry.logger(), &out_dir)?,
        "sweep" => perf::run_sweep_bench(&cfg, &out_dir)?,
        "feed" => {
            let trace_path = args
                .get("trace")
                .ok_or_else(|| anyhow::anyhow!("`repro feed` needs --trace PATH"))?
                .to_string();
            let format = args
                .get("format")
                .map(crate::feed::FeedFormat::from_str)
                .transpose()?;
            let time_scale = args
                .get("time-scale")
                .is_some()
                .then(|| args.get_f64("time-scale", 1.0))
                .transpose()?;
            let snapshot_every = args
                .get("snapshot-every")
                .is_some()
                .then(|| args.get_u64("snapshot-every", 0).map(|v| v as usize))
                .transpose()?;
            let retention = args
                .get("retention")
                .is_some()
                .then(|| args.get_u64("retention", 0).map(|v| v as usize))
                .transpose()?;
            let opts = feed::FeedCliOptions {
                trace_path,
                format,
                scenario: args.get("scenario").map(String::from),
                time_scale,
                price_scale: args.get_f64("price-scale", 1.0)?,
                az: args.get("az").map(String::from),
                instance_type: args.get("instance-type").map(String::from),
                snapshot_every,
                jobs_override: args.get("jobs").is_some().then_some(cfg.jobs),
                retention,
            };
            feed::run_feed(&cfg, &opts, &out_dir)?
        }
        "fleet" => {
            let opts = fleet::FleetCliOptions {
                names: csv_list(&args, "scenario"),
                spec_file: args.get("spec").map(String::from),
                seeds: args.get_u64("seeds", 3)?,
                shards: args.get_u64("shards", 4)? as usize,
                smoke: args.flag("smoke"),
                jobs_override: args.get("jobs").is_some().then_some(cfg.jobs),
                merge_only: csv_list(&args, "merge-only"),
                online: csv_list(&args, "online").unwrap_or_default(),
            };
            fleet::run_fleet(&cfg, &opts, &out_dir)?
        }
        "robustness" => {
            let opts = robustness::RobustnessCliOptions {
                bases: csv_list(&args, "base"),
                derive: args.get_u64("derive", 64)? as usize,
                seeds: args.get_u64("seeds", 1)?,
                shards: args.get_u64("shards", 4)? as usize,
                smoke: args.flag("smoke"),
                jobs_override: args.get("jobs").is_some().then_some(cfg.jobs),
                gate_threshold: args.get_f64("gate-threshold", 0.25)?,
                block_slots: args.get_u64("block-slots", 24)? as usize,
            };
            robustness::run_robustness(&cfg, &opts, &out_dir)?
        }
        "scenarios" if args.flag("list") => {
            let derive = args
                .get("derive")
                .is_some()
                .then(|| args.get_u64("derive", 64).map(|v| v as usize))
                .transpose()?;
            scenarios::list_scenarios(derive)
        }
        "scenarios" => {
            let opts = scenarios::ScenarioCliOptions {
                names: csv_list(&args, "scenario"),
                seeds: args.get_u64("seeds", 3)?,
                smoke: args.flag("smoke"),
                spec_file: args.get("spec").map(String::from),
                // Only an explicit --jobs overrides the per-scenario size.
                jobs_override: args.get("jobs").is_some().then_some(cfg.jobs),
            };
            scenarios::run_scenarios(&cfg, &opts, &out_dir)?
        }
        "run" | "trace" => {
            // `--scenario NAME` configures the single run from a registry
            // world (its market model, pool, job mix type) via
            // Config::from_scenario; other CLI flags still apply on top.
            let run_cfg = match args.get("scenario") {
                Some(name) => {
                    let spec = crate::scenario::find(name).ok_or_else(|| {
                        anyhow::anyhow!(
                            "unknown scenario '{name}'; known: {}",
                            crate::scenario::builtin_names().join(", ")
                        )
                    })?;
                    // `run` executes against synthetic price models only
                    // (single market, or routed multi-offer with every
                    // offer synthetic); refuse worlds that need the full
                    // scenario runner (replay/regime traces, arbitrage
                    // composites) so we never silently simulate a
                    // different market than named.
                    let offers = spec.market.flattened_offers();
                    let all_models = offers
                        .iter()
                        .all(|o| matches!(o.price, crate::scenario::PriceSpec::Model(_)));
                    let runnable = match spec.market.routing {
                        crate::scenario::RoutingSpec::Home => matches!(
                            spec.market.regions[0].price,
                            crate::scenario::PriceSpec::Model(_)
                        ),
                        crate::scenario::RoutingSpec::Arbitrage => false,
                        crate::scenario::RoutingSpec::Cheapest
                        | crate::scenario::RoutingSpec::Spillover => all_models,
                    };
                    anyhow::ensure!(
                        runnable,
                        "scenario '{name}' uses a replayed/regime/arbitrage \
                         market; use `repro scenarios --scenario {name}` instead"
                    );
                    let mut sc = crate::coordinator::Config::from_scenario(&spec)?;
                    // Explicit CLI flags beat the scenario's values; seed /
                    // threads / pjrt are run-level and always carry over.
                    sc.jobs = args.get_u64("jobs", sc.jobs as u64)? as usize;
                    if args.get("pool").is_some() {
                        sc.pool_sizes = cfg.pool_sizes.clone();
                    }
                    if args.get("job-type").is_some() {
                        sc.job_type = cfg.job_type;
                    }
                    sc.seed = cfg.seed;
                    sc.threads = cfg.threads;
                    sc.use_pjrt = cfg.use_pjrt;
                    if args.get("switch-cost").is_some() {
                        sc.migration = cfg.migration;
                    }
                    sc.telemetry = cfg.telemetry.clone();
                    sc
                }
                None => cfg.clone(),
            };
            tables::run_single_tola(&run_cfg, &out_dir)?
        }
        "health" => {
            let inputs: Vec<String> = args.positional[1..].to_vec();
            forensics::run_health(&inputs, &out_dir, tele.logger())?
        }
        "diff" => {
            let rest = &args.positional[1..];
            anyhow::ensure!(
                rest.len() == 2,
                "`repro diff` needs exactly two document paths; see `repro help`"
            );
            let context =
                args.get_u64("context", crate::telemetry::diff::DEFAULT_CONTEXT as u64)? as usize;
            forensics::run_diff(&rest[0], &rest[1], context, tele.logger())?
        }
        "all" => {
            tables::run_table2(&cfg, &out_dir)?;
            tables::run_table3(&cfg, &out_dir)?;
            tables::run_table4_5(&cfg, &out_dir)?;
            tables::run_table6(&cfg, &out_dir)?;
            figures::run_all(cfg.telemetry.logger(), &out_dir)?;
        }
        "help" | "--help" | "-h" => print!("{HELP}"),
        other => {
            anyhow::bail!("unknown command '{other}'; see `repro help`");
        }
    }

    if tele.enabled() {
        let path = format!("{out_dir}/telemetry.json");
        std::fs::write(&path, tele.telemetry_json().pretty())?;
        tele.logger().info("telemetry", &format!("wrote {path}"));
    }
    // `repro health` writes its own folded doc from its inputs; the flag
    // path folds this run's in-process event log instead.
    if args.flag("health") && cmd != "health" {
        let path = format!("{out_dir}/health.json");
        std::fs::write(&path, tele.health_json().pretty())?;
        tele.logger().info("health", &format!("wrote {path}"));
    }
    if cmd == "trace" && args.flag("events") {
        let path = format!("{out_dir}/events.jsonl");
        let det = tele.deterministic_json();
        let events = crate::telemetry::health::events_of_doc(&det).unwrap_or(&[]);
        let mut lines = String::new();
        for e in events {
            lines.push_str(&e.to_string());
            lines.push('\n');
        }
        std::fs::write(&path, lines)?;
        tele.logger()
            .info("telemetry", &format!("wrote {path} ({} events)", events.len()));
    }
    if trace_on {
        let path = format!("{out_dir}/trace.json");
        std::fs::write(&path, tele.chrome_trace_json().pretty())?;
        tele.logger()
            .info("telemetry", &format!("wrote {path} (chrome://tracing)"));
    }
    Ok(())
}
