//! Experiment harness: one driver per table/figure of the paper's
//! evaluation (§6), plus the CLI dispatch.

pub mod tables;
pub mod figures;
pub mod perf;

use crate::util::cli::Args;

const HELP: &str = "\
repro — reproduction of 'Towards Cost-Optimal Policies for DAGs to Utilize
IaaS Clouds with Online Learning' (Wu, Yu, Casale, Gao, 2021)

USAGE: repro <command> [options]

COMMANDS
  table2      Experiment 1: Dealloc vs Greedy/Even, spot+on-demand only
  table3      Experiment 2: full framework vs Even+naive, with self-owned pool
  table4      Experiment 3: rule (12) vs naive self-owned (cost improvement)
  table5      Experiment 3: self-owned utilization ratio μ
  table6      Experiment 4: TOLA online learning, proposed vs benchmark
  figures     Regenerate data series for Figures 1–4 (CSV to --out dir)
  sweep       Counterfactual sweep-engine throughput (naive vs closed-form
              vs batched; EXPERIMENTS.md §Perf)
  run         One TOLA learning run with progress output
  all         Run every table (tables 2–6) and figures

OPTIONS
  --jobs N        jobs per cell (default 2000; paper uses ~10000)
  --seed N        RNG seed (default 7)
  --threads N     worker threads (default: all cores)
  --pool LIST     self-owned pool sizes, e.g. 300,600,900,1200
  --job-type N    job type x2 for `run` (default 2)
  --out DIR       output directory for JSON/CSV results (default results)
  --no-pjrt       disable the PJRT kernel (native counterfactuals only)
  --config FILE   load a JSON config (CLI flags override)
";

/// CLI dispatch for `repro`.
pub fn dispatch(argv: Vec<String>) -> anyhow::Result<()> {
    let args = Args::parse(argv, &["no-pjrt", "verbose"]);
    let cmd = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("help");

    let mut cfg = match args.get("config") {
        Some(path) => crate::coordinator::Config::from_json_file(path)?,
        None => crate::coordinator::Config::default(),
    };
    cfg.jobs = args.get_u64("jobs", cfg.jobs as u64)? as usize;
    cfg.seed = args.get_u64("seed", cfg.seed)?;
    cfg.threads = args.get_u64("threads", cfg.threads as u64)? as usize;
    cfg.job_type = args.get_u64("job-type", cfg.job_type as u64)? as u8;
    cfg.pool_sizes = args.get_u64_list("pool", &cfg.pool_sizes)?;
    if args.flag("no-pjrt") {
        cfg.use_pjrt = false;
    }
    let out_dir = args.get_str("out", "results");
    std::fs::create_dir_all(&out_dir).ok();

    match cmd {
        "table2" => tables::run_table2(&cfg, &out_dir)?,
        "table3" => tables::run_table3(&cfg, &out_dir)?,
        "table4" => tables::run_table4_5(&cfg, &out_dir)?,
        "table5" => tables::run_table4_5(&cfg, &out_dir)?,
        "table6" => tables::run_table6(&cfg, &out_dir)?,
        "figures" => figures::run_all(&out_dir)?,
        "sweep" => perf::run_sweep_bench(&cfg, &out_dir)?,
        "run" => tables::run_single_tola(&cfg, &out_dir)?,
        "all" => {
            tables::run_table2(&cfg, &out_dir)?;
            tables::run_table3(&cfg, &out_dir)?;
            tables::run_table4_5(&cfg, &out_dir)?;
            tables::run_table6(&cfg, &out_dir)?;
            figures::run_all(&out_dir)?;
        }
        "help" | "--help" | "-h" => print!("{HELP}"),
        other => {
            anyhow::bail!("unknown command '{other}'; see `repro help`");
        }
    }
    Ok(())
}
