//! `repro fleet`: shard the scenario registry across many coordinators,
//! merge their reports, and score cross-scenario policy robustness.
//!
//! The run writes, under `--out`:
//!
//! * `fleet_manifest.json` — the serialized shard plan
//!   (`dagcloud.fleet-manifest/v1`); self-contained, so the same shards
//!   can later be run by separate processes;
//! * `fleet_shard_<k>.json` — one ordinary `dagcloud.scenarios/v1` report
//!   per shard coordinator;
//! * `fleet.json` — the merged `dagcloud.fleet/v1` document (canonical
//!   row order, recomputed aggregates, robustness ranking, optional
//!   merged online timeline).
//!
//! The merged bytes are invariant under `--shards` and merge order (see
//! [`crate::fleet::merge`]); CI runs the `--shards 4` vs `--shards 1`
//! comparison on every push. `--merge-only` skips the running half and
//! merges existing shard reports — the entry point for shards that were
//! produced elsewhere. `--online` folds `dagcloud.feed/v1` reports from
//! `repro feed` coordinators into the same document.

use anyhow::{ensure, Result};

use crate::coordinator::Config;
use crate::fleet::{merge_online, FleetAccumulator, OnlineSource, ShardManifest};
use crate::scenario::{self, BatchOptions, ScenarioSpec};
use crate::util::json::Json;

use super::scenarios::{resolve_specs, SMOKE_JOBS};

/// CLI-level options for the `fleet` subcommand.
#[derive(Debug, Clone, Default)]
pub struct FleetCliOptions {
    /// Restrict to these registry names (None = the full registry).
    pub names: Option<Vec<String>>,
    /// Additional custom spec file (JSON) appended to the batch.
    pub spec_file: Option<String>,
    /// Replicates per scenario.
    pub seeds: u64,
    /// Coordinators to deal the worlds across.
    pub shards: usize,
    /// Reduced-size runs (CI smoke).
    pub smoke: bool,
    /// Explicit `--jobs` override.
    pub jobs_override: Option<usize>,
    /// Merge these existing shard reports instead of running anything.
    pub merge_only: Option<Vec<String>>,
    /// `dagcloud.feed/v1` reports to merge as online snapshot sources.
    pub online: Vec<String>,
}

pub fn run_fleet(cfg: &Config, opts: &FleetCliOptions, out_dir: &str) -> Result<()> {
    let mut acc = FleetAccumulator::new();
    let log = *cfg.telemetry.logger();

    match &opts.merge_only {
        Some(paths) => {
            ensure!(!paths.is_empty(), "--merge-only needs at least one report path");
            log.info("fleet", &format!("merging {} shard report(s)", paths.len()));
            let mut rec = cfg.telemetry.recorder("fleet/merge");
            for path in paths {
                let text = std::fs::read_to_string(path)
                    .map_err(|e| anyhow::anyhow!("shard report '{path}': {e}"))?;
                let doc = Json::parse(&text)
                    .map_err(|e| anyhow::anyhow!("shard report '{path}': {e}"))?;
                let before = acc.len();
                acc.absorb(&doc)
                    .map_err(|e| anyhow::anyhow!("shard report '{path}': {e}"))?;
                log.debug("fleet", &format!("absorbed {path}"));
                rec.emit(
                    0.0,
                    crate::telemetry::SimEventKind::ReportAbsorbed { rows: acc.len() - before },
                );
            }
            cfg.telemetry.absorb(rec);
        }
        None => {
            let mut specs = resolve_specs(&opts.names, &opts.spec_file)?;
            let jobs_override = match (opts.smoke, opts.jobs_override) {
                (_, Some(j)) => {
                    ensure!(j > 0, "--jobs must be positive");
                    Some(j)
                }
                (true, None) => Some(SMOKE_JOBS),
                (false, None) => None,
            };
            if opts.smoke {
                for s in &mut specs {
                    s.workload.small_tasks = true;
                }
            }
            run_sharded(
                &mut acc,
                "fleet",
                &specs,
                cfg,
                opts.shards,
                opts.seeds,
                opts.smoke,
                jobs_override,
                out_dir,
            )?;
        }
    }

    let online = if opts.online.is_empty() {
        None
    } else {
        let sources: Vec<OnlineSource> = opts
            .online
            .iter()
            .map(|path| {
                let text = std::fs::read_to_string(path)
                    .map_err(|e| anyhow::anyhow!("online report '{path}': {e}"))?;
                let doc = Json::parse(&text)
                    .map_err(|e| anyhow::anyhow!("online report '{path}': {e}"))?;
                crate::fleet::online_source_from_feed_report(&doc, path)
            })
            .collect::<Result<_>>()?;
        let merged = merge_online(&sources)?;
        log.info(
            "fleet",
            &format!(
                "online: {} source(s), {} snapshot(s), {} jobs total",
                merged.sources.len(),
                merged.points.len(),
                merged.total_jobs
            ),
        );
        Some(merged)
    };

    let fleet = acc.fleet_json(online.as_ref())?;
    print_summary(&fleet);
    let path = format!("{out_dir}/fleet.json");
    std::fs::write(&path, fleet.pretty())?;
    log.info("fleet", &format!("written to {path}"));
    Ok(())
}

/// Plan, run, and absorb one sharded batch: write `fleet_manifest.json`
/// and one `dagcloud.scenarios/v1` shard report per coordinator under
/// `out_dir`, absorbing each *serialized* report into `acc` — the merge
/// path is then identical for in-process shards and `--merge-only`
/// reports from elsewhere, so the shard count can never leak into the
/// merged bytes. Shared by `repro fleet` and `repro robustness`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_sharded(
    acc: &mut FleetAccumulator,
    label: &str,
    specs: &[ScenarioSpec],
    cfg: &Config,
    shards: usize,
    seeds: u64,
    smoke: bool,
    jobs_override: Option<usize>,
    out_dir: &str,
) -> Result<()> {
    let manifest = ShardManifest::plan(
        specs,
        shards.max(1),
        seeds.max(1),
        cfg.seed,
        smoke,
        jobs_override,
    )?;
    let manifest_path = format!("{out_dir}/fleet_manifest.json");
    std::fs::write(&manifest_path, manifest.to_json().pretty())?;
    let log = *cfg.telemetry.logger();
    log.info(
        label,
        &format!(
            "{} worlds x {} seeds across {} shard coordinator(s) \
             (base seed {}, threads {}{}); manifest written to {manifest_path}",
            manifest.worlds(),
            manifest.seeds,
            manifest.shards.len(),
            manifest.base_seed,
            cfg.effective_threads(),
            if smoke { ", smoke" } else { "" }
        ),
    );

    let mut rec = cfg.telemetry.recorder(&format!("{label}/merge"));
    let t0 = std::time::Instant::now();
    for shard in &manifest.shards {
        // One coordinator per shard: the shard's cells fan across this
        // process's worker pool; separate-process shards would run the
        // identical batch from the manifest entry alone.
        let outcomes = scenario::run_batch(
            &shard.scenarios,
            &BatchOptions {
                seeds: manifest.seeds,
                base_seed: manifest.base_seed,
                threads: cfg.effective_threads(),
                jobs_override: manifest.jobs_override,
                telemetry: cfg.telemetry.clone(),
            },
        )?;
        let doc = scenario::report_json(&outcomes, manifest.seeds, manifest.base_seed, smoke);
        let path = format!("{out_dir}/{}", shard.report);
        std::fs::write(&path, doc.pretty())?;
        log.info(
            label,
            &format!(
                "shard {}: {} world(s), {} cell(s) -> {path}",
                shard.shard,
                shard.scenarios.len(),
                outcomes.len()
            ),
        );
        acc.absorb(&doc)?;
        rec.emit(
            0.0,
            crate::telemetry::SimEventKind::ReportAbsorbed { rows: outcomes.len() },
        );
    }
    cfg.telemetry.absorb(rec);
    log.info(
        label,
        &format!("{} cells in {:.2}s", acc.len(), t0.elapsed().as_secs_f64()),
    );
    Ok(())
}

/// Console summary: per-world aggregates plus the top of the robustness
/// ranking.
fn print_summary(fleet: &Json) {
    println!(
        "  {:<24} {:>6} {:>8} {:>8} {:>8}",
        "world", "runs", "alpha", "regret", "bound"
    );
    for s in fleet
        .get("scenarios")
        .and_then(Json::as_arr)
        .unwrap_or(&[])
    {
        println!(
            "  {:<24} {:>6} {:>8.4} {:>8.4} {:>8.4}",
            s.opt_str("name", "?"),
            s.get("runs").and_then(Json::as_u64).unwrap_or(0),
            s.get("alpha_mean").and_then(Json::as_f64).unwrap_or(f64::NAN),
            s.get("regret_mean").and_then(Json::as_f64).unwrap_or(f64::NAN),
            s.get("regret_bound_mean")
                .and_then(Json::as_f64)
                .unwrap_or(f64::NAN),
        );
    }
    if let Some(rob) = fleet.get("robustness") {
        let policies = rob.get("policies").and_then(Json::as_arr).unwrap_or(&[]);
        let ranked = rob.get("ranked").and_then(Json::as_u64).unwrap_or(0);
        println!(
            "  robustness: {} policies scored across {} world(s), {} ranked; least-bad:",
            policies.len(),
            rob.get("worlds").and_then(Json::as_u64).unwrap_or(0),
            ranked
        );
        for p in policies.iter().filter(|p| p.get("rank").is_some()).take(5) {
            println!(
                "    #{} {:<36} worst {:.4} (in {}), mean {:.4}",
                p.get("rank").and_then(Json::as_u64).unwrap_or(0),
                p.opt_str("policy", "?"),
                p.get("worst_regret_ratio")
                    .and_then(Json::as_f64)
                    .unwrap_or(f64::NAN),
                p.opt_str("worst_world", "?"),
                p.get("mean_regret_ratio")
                    .and_then(Json::as_f64)
                    .unwrap_or(f64::NAN),
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> Config {
        Config {
            seed: 17,
            threads: 2,
            use_pjrt: false,
            ..Config::default()
        }
    }

    fn opts(shards: usize) -> FleetCliOptions {
        FleetCliOptions {
            names: Some(vec![
                "paper-default".into(),
                "bursty-arrivals".into(),
                "deadline-tight".into(),
            ]),
            spec_file: None,
            seeds: 2,
            shards,
            smoke: true,
            jobs_override: Some(8),
            merge_only: None,
            online: Vec::new(),
        }
    }

    fn tmp_dir(name: &str) -> String {
        let dir = std::env::temp_dir().join(name);
        std::fs::create_dir_all(&dir).unwrap();
        dir.to_string_lossy().into_owned()
    }

    #[test]
    fn fleet_report_bytes_are_invariant_under_shard_count() {
        let d1 = tmp_dir("dagcloud_fleet_k1");
        let d3 = tmp_dir("dagcloud_fleet_k3");
        run_fleet(&cfg(), &opts(1), &d1).unwrap();
        run_fleet(&cfg(), &opts(3), &d3).unwrap();
        let a = std::fs::read_to_string(format!("{d1}/fleet.json")).unwrap();
        let b = std::fs::read_to_string(format!("{d3}/fleet.json")).unwrap();
        assert_eq!(a, b, "fleet.json differs between --shards 1 and --shards 3");
        let j = Json::parse(&a).unwrap();
        assert_eq!(j.get("schema").unwrap().as_str().unwrap(), "dagcloud.fleet/v1");
        assert_eq!(j.get("cells").unwrap().as_u64().unwrap(), 6);
        assert_eq!(j.get("worlds").unwrap().as_u64().unwrap(), 3);
        // Every fixed policy is scored in all three (spot-only) worlds.
        let rob = j.get("robustness").unwrap();
        assert_eq!(rob.get("worlds").unwrap().as_u64().unwrap(), 3);
        assert!(rob.get("ranked").unwrap().as_u64().unwrap() >= 25);
        // One shard report per shard actually landed on disk.
        assert!(std::path::Path::new(&format!("{d3}/fleet_shard_2.json")).exists());
        assert!(std::path::Path::new(&format!("{d3}/fleet_manifest.json")).exists());
    }

    #[test]
    fn merge_only_reproduces_the_in_process_merge() {
        let dir = tmp_dir("dagcloud_fleet_mergeonly");
        run_fleet(&cfg(), &opts(2), &dir).unwrap();
        let direct = std::fs::read_to_string(format!("{dir}/fleet.json")).unwrap();
        // Re-merge the written shard reports, in reverse order.
        let merged_dir = tmp_dir("dagcloud_fleet_mergeonly_out");
        let mut mo = opts(2);
        mo.merge_only = Some(vec![
            format!("{dir}/fleet_shard_1.json"),
            format!("{dir}/fleet_shard_0.json"),
        ]);
        run_fleet(&cfg(), &mo, &merged_dir).unwrap();
        let remerged = std::fs::read_to_string(format!("{merged_dir}/fleet.json")).unwrap();
        assert_eq!(direct, remerged);
    }

    #[test]
    fn unknown_world_and_empty_merge_error() {
        let mut o = opts(2);
        o.names = Some(vec!["not-a-world".into()]);
        let err = run_fleet(&cfg(), &o, "/tmp").unwrap_err().to_string();
        assert!(err.contains("unknown scenario"), "{err}");
        let mut o = opts(2);
        o.merge_only = Some(Vec::new());
        assert!(run_fleet(&cfg(), &o, "/tmp").is_err());
    }
}
