//! CLI drivers for the health plane and determinism forensics:
//! `repro health` (fold telemetry docs into `dagcloud.health/v1`) and
//! `repro diff` (structural diff + first-divergence event bisection).
//!
//! Both are offline consumers of already-written documents — they never
//! run a simulation, so they cannot perturb report bytes by construction.

use anyhow::{anyhow, ensure, Result};

use crate::fleet::merge_health;
use crate::telemetry::{diff, health, Logger};
use crate::util::json::Json;

fn load_doc(path: &str) -> Result<Json> {
    let text = std::fs::read_to_string(path).map_err(|e| anyhow!("{path}: {e}"))?;
    Json::parse(&text).map_err(|e| anyhow!("{path}: {e}"))
}

/// `repro health INPUT...` — fold each document's deterministic event log
/// into per-cell health sections, merge them order-independently (the
/// fleet-merge shape: duplicate sources are a hard error), and write
/// `<out>/health.json`.
pub fn run_health(inputs: &[String], out_dir: &str, log: &Logger) -> Result<()> {
    ensure!(
        !inputs.is_empty(),
        "`repro health` needs at least one telemetry.json (run with --telemetry first, \
         or pass --health to any run command to fold in-process)"
    );
    let mut sections = Vec::new();
    for path in inputs {
        let doc = load_doc(path)?;
        let events = health::events_of_doc(&doc).ok_or_else(|| {
            anyhow!(
                "{path}: no deterministic event log (expected a dagcloud.telemetry/v1 \
                 document or its bare deterministic section)"
            )
        })?;
        let folded = health::fold_events(events);
        log.info(
            "health",
            &format!(
                "{path}: folded {} events into {} cell section(s)",
                events.len(),
                folded.len()
            ),
        );
        sections.extend(folded);
    }
    let doc = merge_health(&sections)?;
    let path = format!("{out_dir}/health.json");
    std::fs::write(&path, doc.pretty()).map_err(|e| anyhow!("{path}: {e}"))?;
    log.info("health", &format!("wrote {path}"));
    println!(
        "health: {} source(s), {} event(s), {} anomaly annotation(s) -> {}",
        doc.opt_u64("sources", 0),
        doc.opt_u64("events", 0),
        doc.opt_u64("anomalies", 0),
        path
    );
    for s in &sections {
        println!("  {:<40} {:>8} events  {:>3} anomalies", s.source, s.events, s.anomalies);
    }
    Ok(())
}

/// `repro diff A B` — byte check, structural diff, and (for documents
/// carrying deterministic event logs) the first diverging
/// `(sim_time, source, seq)` triple with ±`context` events of context.
/// Exits non-zero when the documents differ, so CI can chain it after a
/// failed `cmp` and still fail the job.
pub fn run_diff(a_path: &str, b_path: &str, context: usize, log: &Logger) -> Result<()> {
    let ta = std::fs::read_to_string(a_path).map_err(|e| anyhow!("{a_path}: {e}"))?;
    let tb = std::fs::read_to_string(b_path).map_err(|e| anyhow!("{b_path}: {e}"))?;
    if ta == tb {
        println!("{a_path} and {b_path}: byte-identical");
        return Ok(());
    }
    let a = Json::parse(&ta).map_err(|e| anyhow!("{a_path}: {e}"))?;
    let b = Json::parse(&tb).map_err(|e| anyhow!("{b_path}: {e}"))?;
    let report = diff::diff_docs(&a, &b, context);
    print!("{}", diff::render(a_path, b_path, &report));
    log.info(
        "diff",
        &format!(
            "{} structural difference(s), divergence {}",
            report.struct_count,
            if report.divergence.is_some() { "localized" } else { "n/a" }
        ),
    );
    if report.identical {
        anyhow::bail!(
            "documents differ in bytes but are structurally identical \
             (formatting/whitespace only)"
        );
    }
    match &report.divergence {
        Some(d) => {
            let at = d
                .left
                .as_ref()
                .or(d.right.as_ref())
                .map(|r| format!("sim_time={} source={} seq={}", r.sim_time, r.source, r.seq))
                .unwrap_or_else(|| "<empty logs>".to_string());
            anyhow::bail!(
                "documents diverge: first diverging event at index {} ({at})",
                d.index
            )
        }
        None => anyhow::bail!(
            "documents differ: {} structural difference(s)",
            report.struct_count
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::{SimEvent, SimEventKind};

    fn tmp_dir(tag: &str) -> String {
        let d = std::env::temp_dir().join(format!("dagcloud_forensics_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d.to_string_lossy().to_string()
    }

    fn telemetry_doc_with(spec: usize, path: &str) {
        let rows: Vec<Json> = (0..64)
            .map(|i| {
                SimEvent {
                    sim_time: i as f64,
                    seq: i,
                    kind: SimEventKind::SpecChosen {
                        job: i as usize,
                        spec: if i == 41 { spec } else { 1 },
                    },
                }
                .to_json("w#0")
            })
            .collect();
        let mut det = Json::obj();
        det.set("events", Json::Arr(rows));
        let mut doc = Json::obj();
        doc.set("schema", Json::Str("dagcloud.telemetry/v1".into()))
            .set("deterministic", det);
        std::fs::write(path, doc.pretty()).unwrap();
    }

    #[test]
    fn diff_cli_names_the_seeded_divergent_event() {
        let dir = tmp_dir("diff");
        let a = format!("{dir}/a.json");
        let b = format!("{dir}/b.json");
        telemetry_doc_with(1, &a); // identical everywhere...
        telemetry_doc_with(9, &b); // ...except the seeded event at seq 41
        let log = Logger::default();
        let err = run_diff(&a, &b, 2, &log).unwrap_err().to_string();
        assert!(err.contains("index 41"), "{err}");
        assert!(err.contains("seq=41"), "{err}");
        // Identical files succeed.
        assert!(run_diff(&a, &a.clone(), 2, &log).is_ok());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn health_cli_folds_and_writes_the_doc() {
        let dir = tmp_dir("health");
        let a = format!("{dir}/telemetry.json");
        telemetry_doc_with(1, &a);
        let log = Logger::default();
        run_health(&[a.clone()], &dir, &log).unwrap();
        let doc = Json::parse(&std::fs::read_to_string(format!("{dir}/health.json")).unwrap())
            .unwrap();
        assert_eq!(doc.opt_str("schema", ""), "dagcloud.health/v1");
        assert_eq!(doc.opt_u64("events", 0), 64);
        // Feeding the same file twice duplicates sources: hard error.
        assert!(run_health(&[a.clone(), a], &dir, &log).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
