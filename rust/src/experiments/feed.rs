//! `repro feed`: ingest a real spot-price dump (EC2 JSON-lines or CSV)
//! and drive the long-running online coordinator loop over it.
//!
//! The market comes from the feed; the workload, pool, and policy grid
//! come from `--scenario NAME` (or §6.1 defaults). Jobs whose windows
//! extend past the feed's horizon are dropped up front — the online loop
//! treats reading past the ingested frontier as a hard error, and a job
//! the stream cannot price is exactly that.

use anyhow::{ensure, Result};

use crate::coordinator::{tola_run_online_traced, Config, Evaluator, OnlineOptions};
use crate::feed::{FeedBinding, FeedFilter, FeedFormat, FeedMux};
use crate::market::{SpotModel, SLOTS_PER_UNIT};
use crate::policy::routing::RoutingPolicy;
use crate::scenario::{self, MarketSpec, PolicySetSpec, ScenarioSpec, WorkloadSpec};
use crate::util::json::Json;

/// CLI-level options for the `feed` subcommand.
#[derive(Debug, Clone, Default)]
pub struct FeedCliOptions {
    /// Path to the dump (`--trace`).
    pub trace_path: String,
    /// Explicit format; `None` infers from the extension.
    pub format: Option<FeedFormat>,
    /// Take workload / pool / policy grid from a registry world.
    pub scenario: Option<String>,
    /// Timestamp scale; `None` picks the format default (1/3600 for the
    /// epoch-second EC2 shapes, 1.0 for the simple numeric shape).
    pub time_scale: Option<f64>,
    pub price_scale: f64,
    pub az: Option<String>,
    pub instance_type: Option<String>,
    /// Snapshot cadence in retired jobs; `None` = ~10 per run.
    pub snapshot_every: Option<usize>,
    /// Explicit `--jobs` override of the scenario's job count.
    pub jobs_override: Option<usize>,
    /// Bounded retention (`--retention SLOTS`): evict feed slots more than
    /// this many behind the frontier, keeping resident memory O(retention).
    /// `None` retains the full history. The report is byte-identical either
    /// way as long as retention covers every live job window; a window
    /// reaching an evicted slot is a hard error.
    pub retention: Option<usize>,
}

pub fn run_feed(cfg: &Config, opts: &FeedCliOptions, out_dir: &str) -> Result<()> {
    let format = opts.format.unwrap_or_else(|| FeedFormat::infer(&opts.trace_path));
    let filter = FeedFilter {
        availability_zone: opts.az.clone(),
        instance_type: opts.instance_type.clone(),
    };
    // Load in raw time units first: only the loader knows whether a CSV
    // carried ISO (epoch-second) or already-simulated timestamps, and the
    // sensible default scale differs (an epoch-second dump at scale 1.0
    // would become a ~400k-unit horizon). Rescaling the shifted events
    // afterwards is bit-identical to loading with the scale applied.
    let mut load = crate::feed::load_events_file(
        &opts.trace_path,
        Some(format),
        &filter,
        1.0,
        opts.price_scale,
    )?;
    let time_scale = opts
        .time_scale
        .unwrap_or(if load.iso_timestamps { 1.0 / 3600.0 } else { 1.0 });
    anyhow::ensure!(time_scale > 0.0, "--time-scale must be positive");
    for e in &mut load.events {
        e.time *= time_scale;
    }
    let slot_len = 1.0 / SLOTS_PER_UNIT as f64;
    let last = load.events.last().expect("loader guarantees ≥1 event").time;
    // The buffer commits the final observation's own slot on close.
    let feed_horizon = ((last / slot_len + 0.5).ceil()).max(1.0) * slot_len;
    let log = *cfg.telemetry.logger();
    log.info(
        "feed",
        &format!(
            "{} ({}): {} records -> {} events (series {}, {} duplicates, \
             {} out-of-order), horizon {:.1} units ({} slots)",
            opts.trace_path,
            format.as_str(),
            load.records,
            load.events.len(),
            load.series,
            load.duplicates,
            load.out_of_order,
            feed_horizon,
            (feed_horizon / slot_len).round() as usize
        ),
    );

    // Workload / pool / policy grid: a registry world or §6.1 defaults.
    let spec = match &opts.scenario {
        Some(name) => scenario::find(name).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown scenario '{name}'; known: {}",
                scenario::builtin_names().join(", ")
            )
        })?,
        None => ScenarioSpec {
            name: "feed-adhoc".into(),
            description: "workload defaults for a feed-driven run".into(),
            // The market side is supplied by the feed; this placeholder is
            // never realized.
            market: MarketSpec::single(SpotModel::paper_default(), cfg.od_price),
            workload: WorkloadSpec::uniform(cfg.job_type),
            pool_capacity: 0,
            policy_set: PolicySetSpec::Auto,
            jobs: cfg.jobs,
            tags: Vec::new(),
            migration: crate::policy::routing::MigrationPolicy::disabled(),
        },
    };
    let target_jobs = opts.jobs_override.unwrap_or(spec.jobs);
    ensure!(target_jobs > 0, "--jobs must be positive");
    let all_jobs = scenario::build_workload(&spec, target_jobs, cfg.seed ^ 0x10AD);
    // Keep a margin past the deadline: finished-late tasks probe at most a
    // hair past their window, never a full unit.
    let jobs: Vec<_> = all_jobs
        .into_iter()
        .filter(|j| j.deadline + 1.0 <= feed_horizon)
        .collect();
    ensure!(
        !jobs.is_empty(),
        "feed horizon {feed_horizon:.1} units is too short for any of the {target_jobs} \
         generated jobs; lower --jobs/--time-scale or use a longer dump"
    );
    if jobs.len() < target_jobs {
        log.info(
            "feed",
            &format!(
                "{} of {} jobs fit the feed horizon (the rest arrive after the stream ends)",
                jobs.len(),
                target_jobs
            ),
        );
    }

    let specs = scenario::cf_specs(&spec);
    let mut mux = FeedMux::new(
        vec![FeedBinding {
            region: if load.series == "-" { "feed".into() } else { load.series.clone() },
            instance_type: "default".into(),
            od_price: cfg.od_price,
            capacity: None,
            events: load.events.clone(),
        }],
        slot_len,
    )?;
    if let Some(max_slots) = opts.retention {
        ensure!(max_slots > 0, "--retention must be positive");
        mux = mux.with_retention(max_slots);
        log.info("feed", &format!("bounded retention: {max_slots} slots resident"));
    }
    let snapshot_every = opts
        .snapshot_every
        .unwrap_or_else(|| (jobs.len() / 10).max(1));
    let online = OnlineOptions {
        routing: RoutingPolicy::Home,
        migration: crate::policy::routing::MigrationPolicy::disabled(),
        pool_capacity: spec.pool_capacity,
        seed: cfg.seed,
        snapshot_every,
    };
    let t0 = std::time::Instant::now();
    let mut rec = cfg.telemetry.recorder(&format!("{}#feed", spec.name));
    let out = tola_run_online_traced(
        &jobs,
        &specs,
        mux,
        &online,
        &Evaluator::Native {
            threads: cfg.effective_threads(),
        },
        &cfg.telemetry,
        &mut rec,
    )?;
    cfg.telemetry.absorb(rec);
    let dt_s = t0.elapsed().as_secs_f64();

    println!(
        "  {:<8} {:>10} {:>8} {:>8} {:>8} {:>8}",
        "jobs", "slots", "alpha", "regret", "bound", "w_max"
    );
    for s in &out.snapshots {
        println!(
            "  {:<8} {:>10} {:>8.4} {:>8.4} {:>8.4} {:>8.4}",
            s.jobs, s.ingested_slots, s.average_unit_cost, s.average_regret, s.regret_bound, s.max_weight
        );
    }
    let rep = &out.report;
    println!(
        "  final: {} jobs, alpha {:.4}, regret {:.4} (bound {:.4}), best {}\n  \
         {} slots ingested, {:.2}s wall ({:.0} jobs/s)",
        rep.jobs,
        rep.average_unit_cost,
        rep.average_regret,
        rep.regret_bound,
        specs[rep.best_policy].label(),
        out.ingested_slots,
        dt_s,
        rep.jobs as f64 / dt_s.max(1e-9)
    );

    let mut j = Json::obj();
    j.set("schema", Json::Str("dagcloud.feed/v1".into()))
        .set("trace", Json::Str(opts.trace_path.clone()))
        .set("format", Json::Str(format.as_str().into()))
        .set("series", Json::Str(load.series.clone()))
        .set("records", Json::Num(load.records as f64))
        .set("events", Json::Num(load.events.len() as f64))
        .set("duplicates", Json::Num(load.duplicates as f64))
        .set("out_of_order", Json::Num(load.out_of_order as f64))
        .set("scenario", Json::Str(spec.name.clone()))
        .set("jobs", Json::Num(rep.jobs as f64))
        .set("ingested_slots", Json::Num(out.ingested_slots as f64))
        .set("average_unit_cost", Json::Num(rep.average_unit_cost))
        .set("average_regret", Json::Num(rep.average_regret))
        .set("regret_bound", Json::Num(rep.regret_bound))
        .set("best_policy", Json::Str(specs[rep.best_policy].label()))
        .set(
            "snapshots",
            Json::Arr(
                out.snapshots
                    .iter()
                    .map(|s| {
                        let mut sj = Json::obj();
                        sj.set("jobs", Json::Num(s.jobs as f64))
                            .set("sim_time", Json::Num(s.sim_time))
                            .set("ingested_slots", Json::Num(s.ingested_slots as f64))
                            .set("average_unit_cost", Json::Num(s.average_unit_cost))
                            .set("average_regret", Json::Num(s.average_regret))
                            .set("regret_bound", Json::Num(s.regret_bound))
                            .set("max_weight", Json::Num(s.max_weight));
                        sj
                    })
                    .collect(),
            ),
        );
    let path = format!("{out_dir}/feed_run.json");
    std::fs::write(&path, j.pretty())?;
    log.info("feed", &format!("written to {path}"));
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli(path: &str, scenario: Option<&str>, jobs: usize) -> FeedCliOptions {
        FeedCliOptions {
            trace_path: path.into(),
            format: None,
            scenario: scenario.map(String::from),
            time_scale: None,
            price_scale: 1.0,
            az: None,
            instance_type: None,
            snapshot_every: Some(8),
            jobs_override: Some(jobs),
            retention: None,
        }
    }

    fn write_sample(name: &str, content: &str) -> String {
        let dir = std::env::temp_dir().join("dagcloud_feed_cli");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(name);
        std::fs::write(&p, content).unwrap();
        p.to_string_lossy().into_owned()
    }

    #[test]
    fn csv_feed_run_writes_report() {
        let path = write_sample(
            "sample.csv",
            include_str!("../../../examples/traces/spot_sample.csv"),
        );
        let cfg = Config {
            jobs: 64,
            seed: 5,
            threads: 2,
            use_pjrt: false,
            ..Config::default()
        };
        let dir = std::env::temp_dir().join("dagcloud_feed_out");
        std::fs::create_dir_all(&dir).unwrap();
        run_feed(&cfg, &cli(&path, None, 64), dir.to_str().unwrap()).unwrap();
        let j = Json::parse(
            &std::fs::read_to_string(dir.join("feed_run.json")).unwrap(),
        )
        .unwrap();
        assert_eq!(j.get("schema").unwrap().as_str().unwrap(), "dagcloud.feed/v1");
        assert!(j.get("jobs").unwrap().as_f64().unwrap() > 0.0);
        assert!(j.get("average_unit_cost").unwrap().as_f64().unwrap() > 0.0);
        assert!(!j.get("snapshots").unwrap().as_arr().unwrap().is_empty());
    }

    #[test]
    fn ec2_jsonl_feed_run_with_scenario_workload() {
        let path = write_sample(
            "sample.jsonl",
            include_str!("../../../examples/traces/ec2_sample.jsonl"),
        );
        let cfg = Config {
            jobs: 9999, // ignored: --jobs override below
            seed: 7,
            threads: 2,
            use_pjrt: false,
            ..Config::default()
        };
        let mut opts = cli(&path, Some("bursty-arrivals"), 48);
        opts.price_scale = 1.0 / crate::scenario::registry::EC2_SAMPLE_OD_USD;
        let dir = std::env::temp_dir().join("dagcloud_feed_out_ec2");
        std::fs::create_dir_all(&dir).unwrap();
        run_feed(&cfg, &opts, dir.to_str().unwrap()).unwrap();
        let j = Json::parse(
            &std::fs::read_to_string(dir.join("feed_run.json")).unwrap(),
        )
        .unwrap();
        assert_eq!(j.get("format").unwrap().as_str().unwrap(), "ec2-json");
        assert_eq!(j.get("scenario").unwrap().as_str().unwrap(), "bursty-arrivals");
        assert_eq!(
            j.get("series").unwrap().as_str().unwrap(),
            "us-east-1a/m5.large"
        );
        assert!(j.get("out_of_order").unwrap().as_f64().unwrap() > 0.0);
        assert!(j.get("duplicates").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn unknown_scenario_and_short_feed_error() {
        let path = write_sample("tiny.csv", "time,price\n0,0.2\n0.5,0.3\n");
        let cfg = Config {
            use_pjrt: false,
            ..Config::default()
        };
        let err = run_feed(&cfg, &cli(&path, Some("nope"), 8), "/tmp")
            .unwrap_err()
            .to_string();
        assert!(err.contains("unknown scenario"), "{err}");
        // A half-unit feed cannot hold any real job window.
        let err = run_feed(&cfg, &cli(&path, None, 8), "/tmp")
            .unwrap_err()
            .to_string();
        assert!(err.contains("too short"), "{err}");
    }
}
