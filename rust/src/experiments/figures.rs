//! Data-series generators for the paper's figures.
//!
//! * **Figure 1** — availability of cloud instances over time (spot
//!   on/off segments vs always-on on-demand);
//! * **Figure 2** — single-task allocation phases of the §3.3.1 toy
//!   example (a: no turning point, b: turning point at t = 1);
//! * **Figure 3** — the naive schedule of the §4.1.1 chain (spot workload
//!   2);
//! * **Figure 4** — the optimal schedule (spot workload 22/6).
//!
//! Each writes a CSV the paper's plot can be regenerated from; the exact
//! fractions are asserted in unit tests.

use anyhow::Result;

use crate::market::{PriceTrace, SpotModel};
use crate::policy::dealloc::{dealloc, windows_to_deadlines};
use crate::policy::single_task::{expected_turning_point, expected_turning_point_mixed};
use crate::workload::ChainJob;

/// One rectangle of a schedule plot: a resource band over a time span.
#[derive(Debug, Clone, PartialEq)]
pub struct Segment {
    pub task: usize,
    pub kind: &'static str, // "spot" | "ondemand" | "selfowned" | "idle"
    pub t0: f64,
    pub t1: f64,
    pub instances: f64,
}

impl Segment {
    pub fn work(&self) -> f64 {
        self.instances * (self.t1 - self.t0)
    }
}

/// Figure 2: the §3.3.1 toy task (δ=3, window [0,2], r=1, β=0.5, and the
/// paper's mixed request o = s = 1) for z = 3.5 (a) and z = 5.5 (b).
/// Expected-case phases via [`expected_turning_point_mixed`].
pub fn figure2(z: f64) -> Vec<Segment> {
    let (delta, r, window, beta) = (3.0f64, 1.0f64, 2.0f64, 0.5f64);
    let (s, o) = (1.0f64, 1.0f64); // the toy's fixed request mix
    let zt = z - r * window;
    let delta_eff = delta - r;
    let mut segs = vec![Segment {
        task: 0,
        kind: "selfowned",
        t0: 0.0,
        t1: window,
        instances: r,
    }];
    match expected_turning_point_mixed(zt, delta_eff, window, beta, s, o) {
        None => {
            // No turning point: s spot + o on-demand drain z̃ at rate
            // o + β·s until expected completion.
            let t_done = zt / (o + beta * s);
            segs.push(Segment {
                task: 0,
                kind: "spot",
                t0: 0.0,
                t1: t_done,
                instances: s,
            });
            segs.push(Segment {
                task: 0,
                kind: "ondemand",
                t0: 0.0,
                t1: t_done,
                instances: o,
            });
        }
        Some(tau) => {
            segs.push(Segment {
                task: 0,
                kind: "spot",
                t0: 0.0,
                t1: tau,
                instances: s,
            });
            segs.push(Segment {
                task: 0,
                kind: "ondemand",
                t0: 0.0,
                t1: tau,
                instances: o,
            });
            // Phase (ii): δ−r on-demand instances through the deadline.
            segs.push(Segment {
                task: 0,
                kind: "ondemand",
                t0: tau,
                t1: window,
                instances: delta_eff,
            });
        }
    }
    segs
}

/// Figure 3: the naive schedule of the §4.1.1 example — deadlines ς_i = i,
/// expected phases with β = 0.5. Returns the segments.
pub fn figure3() -> Vec<Segment> {
    expected_schedule(&ChainJob::paper_example(), &[1.0, 2.0, 3.0, 4.0], 0.5)
}

/// Figure 4: the optimal schedule (Dealloc windows).
pub fn figure4() -> Vec<Segment> {
    let job = ChainJob::paper_example();
    let alloc = dealloc(&job, 0.5);
    let deadlines = windows_to_deadlines(&job, &alloc);
    expected_schedule(&job, &deadlines, 0.5)
}

/// Expected-case schedule of a chain given task deadlines: each task runs
/// in `[ς_{i-1}, ς_i]`, all-spot until the expected turning point, then
/// on-demand (Prop. 4.1). Spot processes at rate β·δ in expectation.
pub fn expected_schedule(job: &ChainJob, deadlines: &[f64], beta: f64) -> Vec<Segment> {
    assert_eq!(deadlines.len(), job.num_tasks());
    let mut segs = Vec::new();
    let mut start = job.arrival;
    for (i, task) in job.tasks.iter().enumerate() {
        let deadline = deadlines[i];
        let hat_s = deadline - start;
        match expected_turning_point(task.size, task.parallelism, hat_s, beta) {
            Some(tau) if tau > 1e-12 => {
                segs.push(Segment {
                    task: i,
                    kind: "spot",
                    t0: start,
                    t1: start + tau,
                    instances: task.parallelism,
                });
                segs.push(Segment {
                    task: i,
                    kind: "ondemand",
                    t0: start + tau,
                    t1: deadline,
                    instances: task.parallelism,
                });
            }
            Some(_) => {
                segs.push(Segment {
                    task: i,
                    kind: "ondemand",
                    t0: start,
                    t1: deadline,
                    instances: task.parallelism,
                });
            }
            None => {
                let t_done = start + task.min_exec_time() / beta;
                segs.push(Segment {
                    task: i,
                    kind: "spot",
                    t0: start,
                    t1: t_done,
                    instances: task.parallelism,
                });
                if t_done < deadline - 1e-12 {
                    segs.push(Segment {
                        task: i,
                        kind: "idle",
                        t0: t_done,
                        t1: deadline,
                        instances: 0.0,
                    });
                }
            }
        }
        start = deadline;
    }
    segs
}

/// Expected spot workload of a schedule (β-weighted spot segments).
pub fn spot_workload(segs: &[Segment], beta: f64) -> f64 {
    segs.iter()
        .filter(|s| s.kind == "spot")
        .map(|s| beta * s.work())
        .sum()
}

fn write_segments(path: &str, segs: &[Segment]) -> Result<()> {
    let mut out = String::from("task,kind,t0,t1,instances,work\n");
    for s in segs {
        out.push_str(&format!(
            "{},{},{:.6},{:.6},{},{:.6}\n",
            s.task,
            s.kind,
            s.t0,
            s.t1,
            s.instances,
            s.work()
        ));
    }
    std::fs::write(path, out)?;
    Ok(())
}

/// Figure 1: availability segments of a generated trace at bid 0.24, plus
/// the on-demand always-on band.
pub fn figure1(out_dir: &str) -> Result<()> {
    let trace = PriceTrace::generate(SpotModel::paper_default(), 8.0, 42);
    let mut out = String::from("resource,t0,t1,available\n");
    for (t0, t1, avail) in trace.availability_segments(0.0, 8.0, 0.24) {
        out.push_str(&format!("spot,{t0:.4},{t1:.4},{}\n", avail as u8));
    }
    out.push_str("ondemand,0.0000,8.0000,1\n");
    std::fs::write(format!("{out_dir}/figure1.csv"), out)?;
    Ok(())
}

/// Generate every figure's CSV into `out_dir`.
pub fn run_all(log: &crate::telemetry::Logger, out_dir: &str) -> Result<()> {
    std::fs::create_dir_all(out_dir).ok();
    figure1(out_dir)?;
    write_segments(&format!("{out_dir}/figure2a.csv"), &figure2(3.5))?;
    write_segments(&format!("{out_dir}/figure2b.csv"), &figure2(5.5))?;
    let f3 = figure3();
    let f4 = figure4();
    write_segments(&format!("{out_dir}/figure3.csv"), &f3)?;
    write_segments(&format!("{out_dir}/figure4.csv"), &f4)?;
    log.info(
        "figures",
        &format!(
            "written to {out_dir}/ — fig3 spot workload {:.4} (paper: 2), fig4 {:.4} (paper: 22/6 = {:.4})",
            spot_workload(&f3, 0.5),
            spot_workload(&f4, 0.5),
            22.0 / 6.0
        ),
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figure2a_has_no_turning_point() {
        // §3.3.1 / Fig. 2(a): z=3.5 → z̃=1.5, drained by 1 spot + 1 OD at
        // rate 1.5 → done exactly at t=1 (the paper: "at time 1, task i
        // gets enough execution time"), no turning point.
        let segs = figure2(3.5);
        assert!(segs.iter().any(|s| s.kind == "selfowned"));
        let spot = segs.iter().find(|s| s.kind == "spot").unwrap();
        assert!((spot.t1 - 1.0).abs() < 1e-12, "completion {}", spot.t1);
        // Only the phase-1 on-demand instance; no full-δeff tail.
        assert!(segs
            .iter()
            .filter(|s| s.kind == "ondemand")
            .all(|s| s.instances == 1.0));
    }

    #[test]
    fn figure2b_turning_point_at_one() {
        // §3.3.1 / Fig. 2(b): z=5.5 → z̃=3.5 → turning point ς^c = 1, then
        // δ−r = 2 on-demand instances in [1, 2].
        let segs = figure2(5.5);
        let spot = segs.iter().find(|s| s.kind == "spot").unwrap();
        assert!((spot.t1 - 1.0).abs() < 1e-12, "turning point {}", spot.t1);
        let tail = segs
            .iter()
            .find(|s| s.kind == "ondemand" && s.instances == 2.0)
            .expect("phase-2 tail");
        assert_eq!(tail.t0, spot.t1);
        assert_eq!(tail.t1, 2.0);
    }

    #[test]
    fn figure3_spot_workload_is_two() {
        // Paper §4.1.1: the naive deadlines give spot workload 2.
        let w = spot_workload(&figure3(), 0.5);
        assert!((w - 2.0).abs() < 1e-9, "fig3 spot workload {w}");
    }

    #[test]
    fn figure4_spot_workload_is_22_over_6() {
        let segs = figure4();
        let w = spot_workload(&segs, 0.5);
        assert!((w - 22.0 / 6.0).abs() < 1e-9, "fig4 spot workload {w}");
        // First task: spot in [0, 7/6], on-demand in [7/6, 4/3] (paper).
        let t0_spot = segs.iter().find(|s| s.task == 0 && s.kind == "spot").unwrap();
        assert!((t0_spot.t1 - 7.0 / 6.0).abs() < 1e-9);
        let t0_od = segs
            .iter()
            .find(|s| s.task == 0 && s.kind == "ondemand")
            .unwrap();
        assert!((t0_od.t1 - 4.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn all_figures_write_files() {
        let dir = std::env::temp_dir().join("dagcloud_figs");
        std::fs::create_dir_all(&dir).unwrap();
        run_all(&crate::telemetry::Logger::default(), dir.to_str().unwrap()).unwrap();
        for f in [
            "figure1.csv",
            "figure2a.csv",
            "figure2b.csv",
            "figure3.csv",
            "figure4.csv",
        ] {
            assert!(dir.join(f).exists(), "{f} missing");
        }
    }
}
