//! `repro sweep`: the counterfactual sweep-engine throughput driver — the
//! perf trajectory behind EXPERIMENTS.md §Perf, runnable as a plain
//! subcommand (CI uses `bench_hotpath` for the same numbers with the full
//! micro-bench harness).
//!
//! Measures the per-job all-policy evaluation three ways on one workload:
//! the naive O(N_POL·S) slot walk (the oracle), the structure-sharing
//! closed-form engine, and the batched engine fanned across the worker
//! pool — and writes `sweep_bench.json` with policy-evals/s for each.
//!
//! A fourth, streaming pass measures the online hot loop's
//! append-incremental table path ([`sweep::StreamingTables`]): the cost of
//! growing the per-bid prefix tables slot-by-slot, and the per-retirement
//! sweep consuming them seeded vs rebuilding the tables from scratch.

use std::time::Instant;

use anyhow::Result;

use crate::coordinator::Config;
use crate::learning::counterfactual::{eval_grid_naive, CfSpec, CounterfactualJob, S_MAX};
use crate::learning::sweep;
use crate::policy::policy_set_full;
use crate::util::json::Json;

/// Jobs measured per pass (also the batch size of the batched pass).
const BATCH: usize = 64;

pub fn run_sweep_bench(cfg: &Config, out_dir: &str) -> Result<()> {
    let log = *cfg.telemetry.logger();
    log.info("sweep", "counterfactual engine throughput");
    let (jobs, trace) = super::tables::workload(cfg, 2);
    let take = jobs.len().min(BATCH);
    anyhow::ensure!(take > 0, "no jobs generated");
    let cf_jobs: Vec<CounterfactualJob> = jobs
        .iter()
        .take(take)
        .map(|job| {
            let (prices, dt) = trace.resample_window(job.arrival, job.deadline, S_MAX);
            let n = prices.len();
            CounterfactualJob::from_job(job, prices, dt, vec![8.0; n], cfg.od_price)
        })
        .collect();
    let grid = policy_set_full();
    let evals = (take * grid.len()) as f64;

    // Realized spot availability per grid bid over the whole horizon, via
    // the trace's prefix-sum index (no per-bid rescans).
    let idx = trace.availability_index();
    let s_last = trace.num_slots().saturating_sub(1);
    let bids: Vec<f64> = idx.bids().to_vec();
    let avail: Vec<f64> = bids
        .iter()
        .map(|&b| idx.availability(0, s_last, b).unwrap_or(0.0))
        .collect();
    log.debug("sweep", &format!("realized availability per bid: {avail:.3?}"));

    // Naive oracle pass (single-threaded, one pass — it is the slow one).
    let t0 = Instant::now();
    for cf in &cf_jobs {
        std::hint::black_box(eval_grid_naive(cf, &grid, true));
    }
    let naive_s = t0.elapsed().as_secs_f64();

    // Closed-form engine, single-threaded, averaged over repetitions.
    let reps = 5;
    let t0 = Instant::now();
    for _ in 0..reps {
        for cf in &cf_jobs {
            std::hint::black_box(sweep::eval_grid(cf, &grid, true));
        }
    }
    let sweep_s = t0.elapsed().as_secs_f64() / reps as f64;

    // Batched engine across the worker pool.
    let threads = cfg.effective_threads();
    let t0 = Instant::now();
    for _ in 0..reps {
        std::hint::black_box(sweep::sweep_batch(&cf_jobs, &grid, true, threads));
    }
    let batch_s = t0.elapsed().as_secs_f64() / reps as f64;

    // Streaming mode: grow the per-bid tables append-incrementally (the
    // online loop's path) and sweep seeded vs unseeded.
    let specs: Vec<CfSpec> = grid.iter().cloned().map(CfSpec::Proposed).collect();
    let grid_bids: Vec<f64> = grid.iter().map(|p| p.bid).collect();
    let t0 = Instant::now();
    let tables: Vec<sweep::StreamingTables> = cf_jobs
        .iter()
        .map(|cf| {
            let ns = sweep::sweep_num_slots(cf.window, cf.dt, cf.prices.len());
            let mut st = sweep::StreamingTables::new(&grid_bids, cf.dt, ns);
            for k in 0..ns {
                st.append(cf.prices[k]);
            }
            st
        })
        .collect();
    let extend_s = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    for _ in 0..reps {
        for cf in &cf_jobs {
            std::hint::black_box(sweep::eval_spec_costs(cf, &specs, true));
        }
    }
    let unseeded_s = t0.elapsed().as_secs_f64() / reps as f64;
    let t0 = Instant::now();
    for _ in 0..reps {
        for (cf, st) in cf_jobs.iter().zip(&tables) {
            std::hint::black_box(sweep::eval_spec_costs_seeded(cf, Some(st), &specs, true));
        }
    }
    let seeded_s = t0.elapsed().as_secs_f64() / reps as f64;

    let report = [
        ("naive_walk", naive_s),
        ("sweep_engine", sweep_s),
        ("sweep_batch", batch_s),
        ("sweep_unseeded", unseeded_s),
        ("sweep_seeded", seeded_s),
    ];
    for (name, secs) in report {
        println!(
            "  {name:<14} {:>10.1} policy-evals/s  ({:.2} ms / {take} jobs x {} policies)",
            evals / secs,
            secs * 1e3,
            grid.len()
        );
    }
    println!(
        "  speedup: engine {:.1}x, batched {:.1}x over the naive walk",
        naive_s / sweep_s,
        naive_s / batch_s
    );
    println!(
        "  streaming: {:.2} ms to grow tables incrementally ({take} jobs), \
         seeded sweep {:.2}x over rebuild-per-retirement",
        extend_s * 1e3,
        unseeded_s / seeded_s
    );

    let mut j = Json::obj();
    j.set("jobs", Json::Num(take as f64))
        .set("policies", Json::Num(grid.len() as f64))
        .set("threads", Json::Num(threads as f64))
        .set("naive_evals_per_s", Json::Num(evals / naive_s))
        .set("sweep_evals_per_s", Json::Num(evals / sweep_s))
        .set("batch_evals_per_s", Json::Num(evals / batch_s))
        .set("speedup_sweep", Json::Num(naive_s / sweep_s))
        .set("speedup_batch", Json::Num(naive_s / batch_s))
        .set("stream_extend_s", Json::Num(extend_s))
        .set("unseeded_evals_per_s", Json::Num(evals / unseeded_s))
        .set("seeded_evals_per_s", Json::Num(evals / seeded_s))
        .set("table_seed_speedup", Json::Num(unseeded_s / seeded_s))
        .set("bids", Json::from_f64_slice(&bids))
        .set("availability", Json::from_f64_slice(&avail));
    std::fs::write(format!("{out_dir}/sweep_bench.json"), j.pretty())?;
    log.info("sweep", &format!("written to {out_dir}/sweep_bench.json"));
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_bench_runs_small() {
        let cfg = Config {
            jobs: 8,
            seed: 13,
            threads: 2,
            use_pjrt: false,
            ..Config::default()
        };
        let dir = std::env::temp_dir().join("dagcloud_sweepbench");
        std::fs::create_dir_all(&dir).unwrap();
        run_sweep_bench(&cfg, dir.to_str().unwrap()).unwrap();
        let j = Json::parse(
            &std::fs::read_to_string(dir.join("sweep_bench.json")).unwrap(),
        )
        .unwrap();
        assert!(j.get("speedup_sweep").unwrap().as_f64().unwrap() > 0.0);
        assert!(j.get("table_seed_speedup").unwrap().as_f64().unwrap() > 0.0);
        assert!(j.get("stream_extend_s").unwrap().as_f64().unwrap() >= 0.0);
        assert_eq!(j.get("policies").unwrap().as_f64().unwrap(), 175.0);
    }
}
