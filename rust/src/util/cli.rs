//! Tiny argument parser (clap is unavailable offline).
//!
//! Supports `command --flag`, `--key value`, `--key=value`, a small set of
//! single-dash aliases (`-v`, `-q`) and positional arguments, with typed
//! getters and an auto-generated usage string.

use std::collections::BTreeMap;

/// Single-dash shorthands mapped onto their long flag names before parsing.
const SHORT_ALIASES: &[(&str, &str)] = &[("-v", "verbose"), ("-q", "quiet")];

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse raw argv (not including the program name).
    /// `flag_names` lists options that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I, flag_names: &[&str]) -> Args {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some((_, long)) = SHORT_ALIASES.iter().find(|(s, _)| *s == arg) {
                out.flags.push(long.to_string());
            } else if let Some(stripped) = arg.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&stripped) {
                    out.flags.push(stripped.to_string());
                } else if let Some(next) = iter.peek() {
                    if next.starts_with("--") {
                        out.flags.push(stripped.to_string());
                    } else {
                        let v = iter.next().unwrap();
                        out.options.insert(stripped.to_string(), v);
                    }
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_u64(&self, name: &str, default: u64) -> anyhow::Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name}: expected integer, got '{s}'")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name}: expected number, got '{s}'")),
        }
    }

    pub fn get_str(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    /// Comma-separated list of u64 (e.g. `--pool 300,600,900`).
    pub fn get_u64_list(&self, name: &str, default: &[u64]) -> anyhow::Result<Vec<u64>> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(s) => s
                .split(',')
                .map(|p| {
                    p.trim()
                        .parse()
                        .map_err(|_| anyhow::anyhow!("--{name}: bad integer '{p}'"))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(parts: &[&str]) -> Vec<String> {
        parts.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_mixed_forms() {
        let a = Args::parse(
            argv(&["table2", "--jobs", "100", "--seed=7", "--verbose", "extra"]),
            &["verbose"],
        );
        assert_eq!(a.positional, vec!["table2", "extra"]);
        assert_eq!(a.get("jobs"), Some("100"));
        assert_eq!(a.get("seed"), Some("7"));
        assert!(a.flag("verbose"));
    }

    #[test]
    fn flag_before_flag() {
        let a = Args::parse(argv(&["--dry-run", "--jobs", "5"]), &[]);
        assert!(a.flag("dry-run"));
        assert_eq!(a.get_u64("jobs", 0).unwrap(), 5);
    }

    #[test]
    fn trailing_flag() {
        let a = Args::parse(argv(&["--fast"]), &[]);
        assert!(a.flag("fast"));
    }

    #[test]
    fn typed_getters_and_errors() {
        let a = Args::parse(argv(&["--x", "1.5", "--bad", "zz"]), &[]);
        assert_eq!(a.get_f64("x", 0.0).unwrap(), 1.5);
        assert_eq!(a.get_f64("missing", 9.0).unwrap(), 9.0);
        assert!(a.get_u64("bad", 0).is_err());
    }

    #[test]
    fn short_aliases() {
        let a = Args::parse(argv(&["run", "-v", "--jobs", "5", "-q"]), &[]);
        assert!(a.flag("verbose"));
        assert!(a.flag("quiet"));
        assert_eq!(a.get_u64("jobs", 0).unwrap(), 5);
        assert_eq!(a.positional, vec!["run"]);
    }

    #[test]
    fn u64_list() {
        let a = Args::parse(argv(&["--pool", "300, 600,900"]), &[]);
        assert_eq!(a.get_u64_list("pool", &[]).unwrap(), vec![300, 600, 900]);
        assert_eq!(a.get_u64_list("none", &[1, 2]).unwrap(), vec![1, 2]);
    }
}
