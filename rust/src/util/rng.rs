//! Deterministic, seedable random number generation.
//!
//! `rand` is not available offline, so we implement the two standard small
//! generators used throughout the crate:
//!
//! * [`SplitMix64`] — seeding / stream splitting (Steele et al., 2014);
//! * [`Pcg32`] — the PCG-XSH-RR 64/32 generator (O'Neill, 2014), the default
//!   workhorse for all simulations.
//!
//! All experiment entry points take explicit seeds so every table and figure
//! in `EXPERIMENTS.md` is exactly reproducible.

/// SplitMix64: used to expand a single `u64` seed into independent streams.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// PCG-XSH-RR 64/32. Deterministic, 2^64 period, passes BigCrush.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Create from a 64-bit seed; the stream id is derived via SplitMix64 so
    /// that nearby seeds give unrelated sequences.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self::from_parts(sm.next_u64(), sm.next_u64())
    }

    /// Create with explicit state/stream (stream is forced odd).
    pub fn from_parts(initstate: u64, initseq: u64) -> Self {
        let mut rng = Self {
            state: 0,
            inc: (initseq << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(initstate);
        rng.next_u32();
        rng
    }

    /// Derive an independent child generator (for per-job / per-thread
    /// streams).
    pub fn split(&mut self) -> Pcg32 {
        let a = self.next_u64();
        let b = self.next_u64();
        Pcg32::from_parts(a, b)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | (self.next_u32() as u64)
    }

    /// Uniform in `[0, 1)` with 32 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u32() as f64) / (u32::MAX as f64 + 1.0)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, n)` (Lemire's unbiased method, simplified via
    /// rejection).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        // Rejection sampling over the widest multiple of n.
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    pub fn range_inclusive(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential with the given mean (inverse-CDF).
    pub fn exponential(&mut self, mean: f64) -> f64 {
        // 1 - f64() is in (0, 1]; ln of it is finite.
        -mean * (1.0 - self.f64()).ln()
    }

    /// Poisson-distributed count (Knuth for small mean, normal approx for
    /// large). Means in this project are O(1)–O(10).
    pub fn poisson(&mut self, mean: f64) -> u64 {
        assert!(mean >= 0.0);
        if mean == 0.0 {
            return 0;
        }
        if mean < 30.0 {
            let l = (-mean).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            // Normal approximation with continuity correction.
            let g = self.gaussian();
            let v = mean + mean.sqrt() * g + 0.5;
            if v < 0.0 {
                0
            } else {
                v as u64
            }
        }
    }

    /// Standard normal via Box–Muller.
    pub fn gaussian(&mut self) -> f64 {
        let u1 = 1.0 - self.f64(); // (0, 1]
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick an index according to the (not necessarily normalized) weights.
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted_index: zero total weight");
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            if x < *w {
                return i;
            }
            x -= w;
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pcg_is_deterministic() {
        let mut a = Pcg32::new(42);
        let mut b = Pcg32::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn pcg_seeds_differ() {
        let mut a = Pcg32::new(1);
        let mut b = Pcg32::new(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg32::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_close() {
        let mut r = Pcg32::new(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform(2.0, 4.0)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn below_unbiased_small() {
        let mut r = Pcg32::new(11);
        let mut counts = [0u32; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn exponential_mean() {
        let mut r = Pcg32::new(5);
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| r.exponential(0.13)).sum::<f64>() / n as f64;
        assert!((mean - 0.13).abs() < 0.005, "mean={mean}");
    }

    #[test]
    fn poisson_mean_and_variance() {
        let mut r = Pcg32::new(9);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.poisson(4.0) as f64).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!((mean - 4.0).abs() < 0.1, "mean={mean}");
        assert!((var - 4.0).abs() < 0.2, "var={var}");
    }

    #[test]
    fn poisson_large_mean_normal_branch() {
        let mut r = Pcg32::new(10);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.poisson(100.0) as f64).sum::<f64>() / n as f64;
        assert!((mean - 100.0).abs() < 0.5, "mean={mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Pcg32::new(13);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn weighted_index_prefers_heavy() {
        let mut r = Pcg32::new(17);
        let w = [0.1, 0.0, 0.9];
        let mut counts = [0u32; 3];
        for _ in 0..10_000 {
            counts[r.weighted_index(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::new(19);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn split_streams_independent() {
        let mut root = Pcg32::new(23);
        let mut a = root.split();
        let mut b = root.split();
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn range_inclusive_hits_bounds() {
        let mut r = Pcg32::new(29);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..10_000 {
            match r.range_inclusive(3, 5) {
                3 => lo_seen = true,
                5 => hi_seen = true,
                4 => {}
                other => panic!("out of range: {other}"),
            }
        }
        assert!(lo_seen && hi_seen);
    }
}
