//! Mini property-based testing framework (proptest is unavailable offline).
//!
//! Usage (`no_run`: doctest binaries miss the xla rpath in this image):
//!
//! ```no_run
//! use dagcloud::util::prop::{Config, for_all};
//! for_all(Config::cases(200).seed(42), |rng| {
//!     let n = rng.range_inclusive(1, 10) as usize;
//!     let v: Vec<f64> = (0..n).map(|_| rng.uniform(0.0, 1.0)).collect();
//!     let s: f64 = v.iter().sum();
//!     if s < -1e-9 { return Err(format!("negative sum {s}")); }
//!     Ok(())
//! });
//! ```
//!
//! Each case gets an independent PCG stream derived from `(seed, case_idx)`,
//! so a failure report like `case 17 of seed 42` is exactly re-runnable with
//! [`replay`]. This is the failure-reproduction story proptest's persistence
//! files provide, without the dependency.

use super::rng::Pcg32;

/// Property-run configuration.
#[derive(Debug, Clone, Copy)]
pub struct Config {
    pub cases: u64,
    pub seed: u64,
}

impl Config {
    pub fn cases(cases: u64) -> Config {
        Config { cases, seed: 0xDA6C_10_0D }
    }

    pub fn seed(mut self, seed: u64) -> Config {
        self.seed = seed;
        self
    }
}

/// Run `property` for `config.cases` independent cases. The property draws
/// its own inputs from the provided RNG and returns `Err(description)` to
/// signal a counterexample. Panics (with a replayable case id) on failure.
pub fn for_all<F>(config: Config, mut property: F)
where
    F: FnMut(&mut Pcg32) -> Result<(), String>,
{
    for case in 0..config.cases {
        let mut rng = case_rng(config.seed, case);
        if let Err(msg) = property(&mut rng) {
            panic!(
                "property failed at case {case} (seed {}): {msg}\n\
                 replay with: prop::replay(seed={}, case={case}, ..)",
                config.seed, config.seed
            );
        }
    }
}

/// Re-run a single failing case (for debugging counterexamples).
pub fn replay<F>(seed: u64, case: u64, mut property: F) -> Result<(), String>
where
    F: FnMut(&mut Pcg32) -> Result<(), String>,
{
    let mut rng = case_rng(seed, case);
    property(&mut rng)
}

fn case_rng(seed: u64, case: u64) -> Pcg32 {
    Pcg32::from_parts(seed.wrapping_mul(0x9E37_79B9).wrapping_add(case), case ^ seed)
}

/// Helpers to draw common structured inputs.
pub mod gen {
    use crate::util::rng::Pcg32;

    /// Vector of `n` values drawn from `f`.
    pub fn vec_of<T>(rng: &mut Pcg32, n: usize, mut f: impl FnMut(&mut Pcg32) -> T) -> Vec<T> {
        (0..n).map(|_| f(rng)).collect()
    }

    /// Vector with random length in `[lo, hi]`.
    pub fn vec_between<T>(
        rng: &mut Pcg32,
        lo: usize,
        hi: usize,
        f: impl FnMut(&mut Pcg32) -> T,
    ) -> Vec<T> {
        let n = rng.range_inclusive(lo as u64, hi as u64) as usize;
        vec_of(rng, n, f)
    }

    /// Positive float in `[lo, hi)`, log-uniform so both magnitudes appear.
    pub fn log_uniform(rng: &mut Pcg32, lo: f64, hi: f64) -> f64 {
        assert!(lo > 0.0 && hi > lo);
        (rng.uniform(lo.ln(), hi.ln())).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        for_all(Config::cases(50).seed(1), |rng| {
            count += 1;
            let x = rng.f64();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("x out of range: {x}"))
            }
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_case() {
        for_all(Config::cases(100).seed(2), |rng| {
            if rng.f64() < 0.2 {
                Err("expected failure".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn replay_reproduces_case_exactly() {
        // Find a failing case, then replay must fail identically.
        let mut failing = None;
        for case in 0..100 {
            let r = replay(3, case, |rng| {
                if rng.f64() < 0.1 {
                    Err("boom".into())
                } else {
                    Ok(())
                }
            });
            if r.is_err() {
                failing = Some(case);
                break;
            }
        }
        let case = failing.expect("some case should fail");
        let again = replay(3, case, |rng| {
            if rng.f64() < 0.1 {
                Err("boom".into())
            } else {
                Ok(())
            }
        });
        assert!(again.is_err());
    }

    #[test]
    fn gen_vec_between_respects_bounds() {
        for_all(Config::cases(100).seed(4), |rng| {
            let v = gen::vec_between(rng, 2, 7, |r| r.f64());
            if (2..=7).contains(&v.len()) {
                Ok(())
            } else {
                Err(format!("len {}", v.len()))
            }
        });
    }
}
