//! Small self-contained utilities.
//!
//! The build image is offline and only the `xla` crate's dependency closure
//! is vendored, so the usual ecosystem crates (rand, serde, clap, proptest,
//! criterion) are re-implemented here at the scale this project needs.
//! Each module carries its own unit tests.

pub mod rng;
pub mod stats;
pub mod json;
pub mod cli;
pub mod prop;
pub mod bench;
