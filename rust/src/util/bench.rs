//! Micro/milli-benchmark harness (criterion is unavailable offline).
//!
//! Wired into `cargo bench` via `[[bench]] harness = false` targets. Provides
//! warmup, a time-budgeted measurement loop, and mean/p50/p95 reporting in a
//! criterion-like one-line format, plus machine-readable JSON dumps for
//! `EXPERIMENTS.md`.

use std::time::{Duration, Instant};

use super::json::Json;
use super::stats::{mean, percentile};
use crate::telemetry::Logger;

/// One benchmark result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    /// Optional domain-specific throughput annotation, e.g. "jobs/s".
    pub throughput: Option<(f64, String)>,
}

impl BenchResult {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("name", Json::Str(self.name.clone()))
            .set("iters", Json::Num(self.iters as f64))
            .set("mean_ns", Json::Num(self.mean_ns))
            .set("p50_ns", Json::Num(self.p50_ns))
            .set("p95_ns", Json::Num(self.p95_ns));
        if let Some((v, unit)) = &self.throughput {
            j.set("throughput", Json::Num(*v))
                .set("throughput_unit", Json::Str(unit.clone()));
        }
        j
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.1} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:.3} s", ns / 1_000_000_000.0)
    }
}

/// Bench runner collecting results for a final report.
pub struct Bencher {
    pub results: Vec<BenchResult>,
    warmup: Duration,
    budget: Duration,
    min_iters: u64,
    max_iters: u64,
    /// Result lines go through the status logger (stderr), so redirecting
    /// stdout to capture a JSON report can never pick up progress text.
    log: Logger,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    pub fn new() -> Self {
        // Quick mode for CI-ish runs: DAGCLOUD_BENCH_FAST=1.
        let fast = std::env::var("DAGCLOUD_BENCH_FAST").is_ok();
        Self {
            results: Vec::new(),
            warmup: if fast { Duration::from_millis(50) } else { Duration::from_millis(300) },
            budget: if fast { Duration::from_millis(300) } else { Duration::from_secs(2) },
            min_iters: 5,
            max_iters: 1_000_000,
            log: Logger::from_env(),
        }
    }

    pub fn with_budget(mut self, budget: Duration) -> Self {
        self.budget = budget;
        self
    }

    /// Time `f`, which should return some value to keep the optimizer honest
    /// (the value is black-boxed).
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        // Warmup.
        let wstart = Instant::now();
        let mut warm_iters = 0u64;
        while wstart.elapsed() < self.warmup || warm_iters < 1 {
            std::hint::black_box(f());
            warm_iters += 1;
        }
        // Measurement: sample per-iteration times until the budget runs out.
        let mut samples: Vec<f64> = Vec::new();
        let start = Instant::now();
        let mut iters = 0u64;
        while (start.elapsed() < self.budget || iters < self.min_iters)
            && iters < self.max_iters
        {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_nanos() as f64);
            iters += 1;
        }
        let result = BenchResult {
            name: name.to_string(),
            iters,
            mean_ns: mean(&samples),
            p50_ns: percentile(&samples, 50.0),
            p95_ns: percentile(&samples, 95.0),
            throughput: None,
        };
        self.log.info(
            "bench",
            &format!(
                "{:<52} time: [{} {} {}]  ({} iters)",
                result.name,
                fmt_ns(result.p50_ns),
                fmt_ns(result.mean_ns),
                fmt_ns(result.p95_ns),
                result.iters
            ),
        );
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// Like `bench`, but annotates a throughput figure computed from the mean
    /// time: `items_per_iter / mean_seconds`.
    pub fn bench_throughput<T>(
        &mut self,
        name: &str,
        items_per_iter: f64,
        unit: &str,
        f: impl FnMut() -> T,
    ) {
        self.bench(name, f);
        let last = self.results.last_mut().unwrap();
        let per_s = items_per_iter / (last.mean_ns / 1e9);
        last.throughput = Some((per_s, unit.to_string()));
        self.log
            .info("bench", &format!("{:<52} thrpt: {:.1} {}", "", per_s, unit));
    }

    /// Write all results as a JSON report.
    pub fn write_json(&self, path: &str) -> anyhow::Result<()> {
        let arr = Json::Arr(self.results.iter().map(|r| r.to_json()).collect());
        std::fs::write(path, arr.pretty())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        std::env::set_var("DAGCLOUD_BENCH_FAST", "1");
        let mut b = Bencher::new().with_budget(Duration::from_millis(30));
        b.bench("noop_sum", || (0..100u64).sum::<u64>());
        let r = &b.results[0];
        assert!(r.iters >= 5);
        assert!(r.mean_ns > 0.0);
        assert!(r.p50_ns <= r.p95_ns * 1.0001);
    }

    #[test]
    fn throughput_annotation() {
        std::env::set_var("DAGCLOUD_BENCH_FAST", "1");
        let mut b = Bencher::new().with_budget(Duration::from_millis(20));
        b.bench_throughput("t", 1000.0, "items/s", || {
            std::hint::black_box((0..1000u64).sum::<u64>())
        });
        assert!(b.results[0].throughput.as_ref().unwrap().0 > 0.0);
    }

    #[test]
    fn json_report_roundtrips() {
        let r = BenchResult {
            name: "x".into(),
            iters: 10,
            mean_ns: 1.0,
            p50_ns: 1.0,
            p95_ns: 2.0,
            throughput: Some((5.0, "jobs/s".into())),
        };
        let j = r.to_json();
        assert_eq!(j.get("name").unwrap().as_str().unwrap(), "x");
        assert_eq!(j.get("throughput").unwrap().as_f64().unwrap(), 5.0);
    }
}
