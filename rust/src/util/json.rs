//! Minimal JSON support (serde is unavailable offline).
//!
//! Covers the subset this project needs for experiment configs and metric
//! reports: the full JSON data model, a recursive-descent parser with
//! location-carrying errors, and a deterministic pretty serializer
//! (object keys keep insertion order).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Objects use a sorted map: deterministic output, simple diffing.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, value: Json) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), value);
        } else {
            panic!("Json::set on non-object");
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as u64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Typed getters with path-style error messages, for config loading.
    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("config: missing/invalid number '{key}'"))
    }

    pub fn opt_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Json::as_f64).unwrap_or(default)
    }

    pub fn opt_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(Json::as_u64).unwrap_or(default)
    }

    pub fn opt_bool(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Json::as_bool).unwrap_or(default)
    }

    pub fn opt_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(Json::as_str).unwrap_or(default)
    }

    pub fn from_f64_slice(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indent.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(map) => {
                if map.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, x: f64) {
    if x.is_finite() {
        if x == x.trunc() && x.abs() < 1e15 {
            out.push_str(&format!("{}", x as i64));
        } else {
            out.push_str(&format!("{x}"));
        }
    } else {
        // JSON has no NaN/Inf; emit null (documented lossy behaviour).
        out.push_str("null");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            offset: self.pos,
            message: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while let Some(b) = self.peek() {
            match b {
                b' ' | b'\t' | b'\n' | b'\r' => self.pos += 1,
                _ => break,
            }
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(b'-' | b'0'..=b'9') => self.number(),
            Some(c) => Err(self.err(&format!("unexpected character '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let value = self.value()?;
            map.insert(key, value);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{0008}'),
                    Some(b'f') => s.push('\u{000C}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pairs.
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let combined =
                                0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(combined)
                        } else {
                            char::from_u32(cp)
                        };
                        match c {
                            Some(c) => s.push(c),
                            None => return Err(self.err("invalid unicode escape")),
                        }
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(b) if b < 0x20 => return Err(self.err("control char in string")),
                Some(b) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if b < 0x80 {
                        s.push(b as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(b);
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        match std::str::from_utf8(&self.bytes[start..end]) {
                            Ok(chunk) => {
                                s.push_str(chunk);
                                self.pos = end;
                            }
                            Err(_) => return Err(self.err("invalid utf-8")),
                        }
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let d = match self.bump() {
                Some(c @ b'0'..=b'9') => (c - b'0') as u32,
                Some(c @ b'a'..=b'f') => (c - b'a' + 10) as u32,
                Some(c @ b'A'..=b'F') => (c - b'A' + 10) as u32,
                _ => return Err(self.err("invalid hex digit")),
            };
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-2e3").unwrap(), Json::Num(-2000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1], Json::Num(2.0));
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"name":"dagcloud","n":42,"xs":[1,2.5,-3],"flag":true,"nothing":null}"#;
        let j = Json::parse(src).unwrap();
        for text in [j.to_string(), j.pretty()] {
            assert_eq!(Json::parse(&text).unwrap(), j);
        }
    }

    #[test]
    fn unicode_escapes() {
        let j = Json::parse(r#""aéb😀c""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "aéb😀c");
        // And raw multibyte passthrough:
        let k = Json::parse("\"héllo 😀\"").unwrap();
        assert_eq!(k.as_str().unwrap(), "héllo 😀");
    }

    #[test]
    fn errors_carry_offsets() {
        let e = Json::parse("[1, 2").unwrap_err();
        assert!(e.offset >= 5, "{e}");
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("01x").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn trailing_garbage_rejected() {
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{} []").is_err());
    }

    #[test]
    fn typed_getters() {
        let j = Json::parse(r#"{"n": 3, "s": "x", "b": true, "f": 1.5}"#).unwrap();
        assert_eq!(j.opt_u64("n", 0), 3);
        assert_eq!(j.opt_u64("missing", 9), 9);
        assert_eq!(j.opt_str("s", "d"), "x");
        assert!(j.opt_bool("b", false));
        assert_eq!(j.req_f64("f").unwrap(), 1.5);
        assert!(j.req_f64("s").is_err());
    }

    #[test]
    fn integer_formatting_stays_integral() {
        let j = Json::Num(1200.0);
        assert_eq!(j.to_string(), "1200");
    }

    #[test]
    fn nan_serializes_to_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }
}
