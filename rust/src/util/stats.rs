//! Streaming and batch statistics used by the metrics registry and the bench
//! harness.

/// Online mean/variance accumulator (Welford).
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance (0 for n < 2).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        let m2 =
            self.m2 + other.m2 + d * d * (self.n as f64) * (other.n as f64) / n as f64;
        *self = Welford { n, mean, m2 };
    }
}

/// Percentile of a sample (linear interpolation, like numpy's default).
/// `q` in `[0, 100]`. Sorts a copy; fine for bench-sized samples.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty(), "percentile of empty sample");
    assert!((0.0..=100.0).contains(&q));
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q / 100.0 * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let frac = pos - lo as f64;
        v[lo] * (1.0 - frac) + v[hi] * frac
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Max relative deviation between two equally-long vectors, for test
/// tolerances: `max_i |a-b| / max(1, |a|, |b|)`.
pub fn max_rel_err(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs() / x.abs().max(y.abs()).max(1.0))
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_batch() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let m = mean(&xs);
        let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64;
        assert!((w.mean() - m).abs() < 1e-12);
        assert!((w.variance() - var).abs() < 1e-12);
    }

    #[test]
    fn welford_merge_equals_concat() {
        let a = [1.0, 5.0, 2.0];
        let b = [9.0, -3.0, 4.0, 7.0];
        let mut wa = Welford::new();
        let mut wb = Welford::new();
        for &x in &a {
            wa.push(x);
        }
        for &x in &b {
            wb.push(x);
        }
        let mut wall = Welford::new();
        for &x in a.iter().chain(&b) {
            wall.push(x);
        }
        wa.merge(&wb);
        assert_eq!(wa.count(), wall.count());
        assert!((wa.mean() - wall.mean()).abs() < 1e-12);
        assert!((wa.variance() - wall.variance()).abs() < 1e-12);
    }

    #[test]
    fn percentile_basics() {
        let xs = [3.0, 1.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_single() {
        assert_eq!(percentile(&[7.0], 50.0), 7.0);
    }

    #[test]
    fn max_rel_err_zero_for_equal() {
        let a = [1.0, -2.0, 1e9];
        assert_eq!(max_rel_err(&a, &a), 0.0);
    }
}
