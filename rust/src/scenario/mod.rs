//! Scenario engine: declarative multi-market worlds, trace replay, and a
//! sharded deterministic scenario runner.
//!
//! The paper's policies are *parametric* precisely so online learning can
//! track shifting market dynamics — but a reproduction that only ever sees
//! the §6.1 bounded-exp market cannot show that. This subsystem turns the
//! single-run reproduction into an evaluation platform:
//!
//! * [`spec`] — a JSON-round-trippable [`ScenarioSpec`] composing a market
//!   (multi-region, multi-instance-type price processes with per-offer
//!   spot capacity, regime-switch schedules, or CSV trace replay, plus a
//!   routing mode: home / arbitrage composite / capacity-aware routing),
//!   a workload mix with arrival-rate schedules, a pool, and a policy
//!   grid;
//! * [`registry`] — thirteen built-in named worlds, from `paper-default`
//!   to `multi-region-arbitrage`, the capacity-aware `capacity-crunch` /
//!   `multi-region-routed`, the migration seesaw `spot-spike-migration`,
//!   and the streamed-dump `ec2-feed-replay` / `ec2-az-select` (per-series
//!   selection out of a multi-series dump);
//! * [`runner`] — fans `scenarios × seeds` cells across the worker pool
//!   with per-run seed derivation, so a batch is bit-identical under any
//!   `--threads`;
//! * [`report`] — folds the outcomes into one comparable JSON table
//!   (`results/scenarios.json`, tracked by CI as `BENCH_scenarios.json`).

pub mod spec;
pub mod registry;
pub mod runner;
pub mod report;

pub use registry::{builtin_names, builtins, find};
pub use report::{
    aggregate, outcome_from_json, outcomes_from_report, report_json, scenario_sections_json,
    ReportMeta, ScenarioAggregate,
};
pub use runner::{
    build_market, build_market_view, build_workload, cf_specs, derive_run_seed, run_batch,
    run_scenario_once, run_scenario_once_traced, BatchOptions, ScenarioOutcome,
};
pub use spec::{
    FlatOffer, InstanceTypeSpec, MarketSpec, PolicySetSpec, PriceSpec, RegionSpec, ReplayFormat,
    ReplaySpec, RoutingSpec, ScenarioSpec, WorkloadSpec,
};
